import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                           + os.environ.get("REPRO_SMOKE_DEVICES", "512"))

# ^ must precede every other import (jax locks the device count on first
# init) — same contract as repro.launch.dryrun. The multi-process smoke
# workers (--smoke-mp) override the per-process device count via
# REPRO_SMOKE_DEVICES so P processes x 2 devices stay CI-sized.

"""Dry-run for the PAPER'S ALGORITHM on the production mesh.

Lowers one inner-loop sweep (Alg.1 lines 10-14: the unit the paper's
communication bound is stated for) plus the full while-loop fit, for three
distribution variants:

  paper-1d   faithful Alg.1: rows sharded over ALL 256/512 workers,
             landmark columns replicated, K^i(p) materialized per device.
  2d         beyond-paper: rows over (pod, data), landmark columns over
             model — per-device K block shrinks by the model-axis size,
             letting s -> 1 survive bigger mini-batches (DESIGN.md §2).
  fused      beyond-paper: the Gram block is recomputed inside the
             assignment each sweep and never materialized in HBM (the
             Pallas kernel's structure; the dry-run uses the jnp body the
             TPU kernel replaces 1:1).

Default problem size (production regime, fits 16 GB/chip):
  N/B = 1,048,576 rows x d=768 fp32, C=64, |L|=65,536 (s = 1/16).

Writes the same JSON schema as repro.launch.dryrun so benchmarks/roofline.py
ingests these cells alongside the LM zoo.
"""

import argparse          # noqa: E402
import json              # noqa: E402
import math              # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402
from functools import partial  # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp

from repro.distributed.compat import shard_map  # noqa: E402

from repro.core.kernels import KernelSpec                       # noqa: E402
from repro.distributed.inner import DistributedInnerConfig  # noqa: E402,F401
from repro.launch.dryrun import collective_bytes                # noqa: E402
from repro.launch.mesh import data_axes, make_production_mesh   # noqa: E402

MODES = {
    # mode -> (row_axes(sp), col_axis, inner mode, K dtype)
    "paper-1d": (("data", "model"), None, "materialize", jnp.float32),
    "2d": (("data",), "model", "materialize", jnp.float32),
    "fused": (("data",), "model", "fused", jnp.float32),
    # §Perf hillclimb A: K block stored bf16 (f32 accumulation in the
    # f-matmul is unchanged — MXU-native); halves the dominant memory term.
    "2d-bf16k": (("data",), "model", "materialize", jnp.bfloat16),
}


def _analyze(compiled):
    from repro.distributed.compat import cost_analysis as _ca
    cost = _ca(compiled)
    try:
        from repro.distributed.compat import memory_stats
        mem_info = memory_stats(compiled)
    except Exception as e:
        mem_info = {"error": str(e)}
    hlo_text = compiled.as_text()
    from repro.launch import hlocost
    return cost, mem_info, collective_bytes(hlo_text), \
        hlocost.analyze(hlo_text)


def lower_cluster(mode: str, *, multi_pod: bool = False, n_rows: int = 2**20,
                  d: int = 768, c: int = 64, n_landmarks: int = 65536):
    """Lower ONE assignment sweep (Alg.1 lines 10-14 — the unit of the
    paper's communication bound) + the per-batch Gram evaluation.

    materialize modes: the sweep consumes a precomputed K block (input);
    the Gram evaluation is lowered separately and amortized over sweeps.
    fused mode: the sweep recomputes the Gram block inside the assignment
    (never materialized in HBM) — the Pallas-kernel structure."""
    row_axes, col_axis, inner_mode, k_dtype = MODES[mode]
    if multi_pod:
        row_axes = ("pod",) + row_axes
    mesh = make_production_mesh(multi_pod=multi_pod)
    spec = KernelSpec("rbf", gamma=0.05)
    t0 = time.time()

    from jax.sharding import PartitionSpec as P
    from repro.core.engine import (GramEngine, ReducePlan, assign_from_stats,
                                   engine_stats)

    d_size = math.prod(mesh.shape[a] for a in row_axes)
    m_size = mesh.shape[col_axis] if col_axis else 1
    rows_p = n_rows // d_size
    cols_p = n_landmarks // m_size

    x = jax.ShapeDtypeStruct((n_rows, d), jnp.float32)
    lm = jax.ShapeDtypeStruct((n_landmarks, d), jnp.float32)
    lidx = jax.ShapeDtypeStruct((n_landmarks,), jnp.int32)
    k_xl = jax.ShapeDtypeStruct((n_rows, n_landmarks), k_dtype)
    k_ll = jax.ShapeDtypeStruct((n_landmarks, n_landmarks), k_dtype)
    u = jax.ShapeDtypeStruct((n_rows,), jnp.int32)

    rowspec = P(row_axes)
    colspec = P(col_axis) if col_axis else P()
    kspec = P(row_axes, col_axis)
    # 1-D: K_ll row-sharded (the paper's layout); 2-D: replicated over the
    # row axes so g joins the fused stats psum (distributed.inner).
    llspec = P(row_axes, col_axis) if col_axis is None else P(None, col_axis)
    lrowspec = rowspec if col_axis is None else P()

    # the mesh's ONE batched reduction, handed to the SHARED engine stats
    # as a ReducePlan (identical structure to distributed.inner
    # ._body_factory): 2-D reduces the whole counts/f/g payload in one
    # flat psum over the model axis; 1-D reduces only g over the rows
    # (counts/f are local there — the real loop appends its cost/changed
    # scalars to the same buffer).
    if col_axis is not None:
        def _fused(counts_p, f_p, g_p):
            flat = jnp.concatenate(
                [f_p, counts_p[None, :], g_p[None, :]], axis=0)
            flat = jax.lax.psum(flat, col_axis)
            return flat[-2], flat[:-2], flat[-1]
    else:
        def _fused(counts_p, f_p, g_p):
            return counts_p, f_p, jax.lax.psum(g_p, row_axes)
    reduce_plan = ReducePlan(_fused)

    def _sweep(op_xl, op_ll, lidx_cols, lidx_rows, u_full, eng):
        f, g, counts = engine_stats(
            eng, spec, op_xl, op_ll, jnp.take(u_full, lidx_cols),
            jnp.take(u_full, lidx_rows), c, reduce=reduce_plan)
        labels, _ = assign_from_stats(f, g, counts)
        return labels

    def sweep_mat(k_local, kll_local, lidx_cols, lidx_rows, u_local):
        u_full = jax.lax.all_gather(u_local, row_axes, tiled=True)
        return _sweep(GramEngine.from_matrix(k_local),
                      GramEngine.from_matrix(kll_local),
                      lidx_cols, lidx_rows, u_full,
                      GramEngine("materialize"))

    def sweep_fused(x_local, lm_cols, lm_rows, lidx_cols, lidx_rows,
                    u_local):
        u_full = jax.lax.all_gather(u_local, row_axes, tiled=True)
        # the portable recompute structure (Gram rebuilt inside the sweep,
        # never stored) — the Pallas kernel replaces it on real TPUs.
        eng = GramEngine("fused", pallas="never")
        return _sweep(eng.prepare(spec, x_local, lm_cols),
                      eng.prepare(spec, lm_rows, lm_cols),
                      lidx_cols, lidx_rows, u_full, eng)

    def gram(x_local, lm_cols):
        return spec(x_local, lm_cols).astype(k_dtype)

    with mesh:
        if inner_mode == "fused":
            fn = shard_map(
                sweep_fused, mesh=mesh,
                in_specs=(P(row_axes, None),
                          P(col_axis, None) if col_axis else P(None, None),
                          P(row_axes, None) if col_axis is None
                          else P(None, None),
                          colspec, lrowspec, rowspec),
                out_specs=rowspec, check_vma=False)
            lowered = jax.jit(lambda *a: fn(*a)).lower(
                x, lm, lm, lidx, lidx, u)
            sweep_compiled = lowered.compile()
            gram_compiled = None
        else:
            fn = shard_map(
                sweep_mat, mesh=mesh,
                in_specs=(kspec, llspec, colspec, lrowspec, rowspec),
                out_specs=rowspec, check_vma=False)
            lowered = jax.jit(lambda *a: fn(*a)).lower(
                k_xl, k_ll, lidx, lidx, u)
            sweep_compiled = lowered.compile()
            gfn = shard_map(
                gram, mesh=mesh,
                in_specs=(P(row_axes, None),
                          P(col_axis, None) if col_axis else P(None, None)),
                out_specs=kspec, check_vma=False)
            gram_compiled = jax.jit(lambda *a: gfn(*a)).lower(
                x, lm).compile()

    cost, mem_info, coll, la = _analyze(sweep_compiled)
    amortize_sweeps = 20.0      # typical inner iterations per batch
    if gram_compiled is not None:
        _, gmem, gcoll, gla = _analyze(gram_compiled)
        la += gla.scaled(1.0 / amortize_sweeps)    # Cost defines __iadd__
        mem_info["gram_peak_bytes"] = gmem.get("peak_bytes")
        mem_info["k_block_bytes_per_device"] = rows_p * cols_p * 4

    # useful work per sweep: f-matmul 2 rows L C (+ Gram 2 rows L d, fully
    # for fused, amortized for materialize)
    gram_f = 2.0 * n_rows * n_landmarks * d
    fmat = 2.0 * n_rows * n_landmarks * c
    model_flops = fmat + (gram_f if inner_mode == "fused"
                          else gram_f / amortize_sweeps)

    return {
        "arch": f"kkmeans-{mode}", "shape": "minibatch_1m",
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_params": n_rows * d,
        "n_active_params": n_rows * d,
        "tokens_per_step": n_rows,
        "model_flops_total": model_flops,
        "flops_per_device": cost.get("flops"),
        "bytes_per_device": cost.get("bytes accessed"),
        "loop_aware": {
            "flops_per_device": la.flops,
            "bytes_per_device": la.bytes,
            "collective_bytes_by_kind": la.coll,
            "collective_counts": la.coll_counts,
            "collective_bytes": la.coll_bytes,
        },
        "problem": {"n_rows": n_rows, "d": d, "c": c,
                    "n_landmarks": n_landmarks, "mode": mode,
                    "per_sweep": True},
        "memory_analysis": mem_info,
        "collectives": coll,
        "compile_seconds": round(time.time() - t0, 2),
        "ok": True,
    }


def smoke_driver(args) -> int:
    """Spawn ``--smoke-mp`` ranks of ``repro.launch.smoke_mp`` (REAL
    cross-process gloo collectives through the s-step fit path) and wait.
    Exits 0 (with a message) when the jax build cannot do multi-process
    CPU collectives — CI must not go red over a missing gloo backend."""
    import socket
    import subprocess
    import sys

    from repro.launch.smoke_mp import SKIP_EXIT

    with socket.socket() as s:   # grab a free coordinator port
        s.bind(("localhost", 0))
        port = s.getsockname()[1]
    env = dict(os.environ,
               REPRO_SMOKE_DEVICES="2",
               REPRO_SMOKE_NPROCS=str(args.smoke_mp),
               REPRO_SMOKE_COORD=f"localhost:{port}")
    cmd = [sys.executable, "-m", "repro.launch.smoke_mp",
           "--s-step", str(args.s_step)]
    if args.obs:
        cmd += ["--obs", args.obs]
    procs = [subprocess.Popen(cmd, env=dict(env, REPRO_SMOKE_RANK=str(r)))
             for r in range(args.smoke_mp)]
    codes = [p.wait() for p in procs]
    if any(c == SKIP_EXIT for c in codes):
        print(f"[skip] multi-process CPU smoke unsupported here "
              f"(exit codes {codes})")
        return 0
    if any(codes):
        print(f"[FAIL] smoke worker exit codes {codes}")
        return 1
    print(f"[ok] multi-process smoke: {args.smoke_mp} processes clean")
    return 0


def main():
    ap = argparse.ArgumentParser(description="clustering dry-run")
    ap.add_argument("--mode", default=None, choices=sorted(MODES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--rows", type=int, default=2**20)
    ap.add_argument("--d", type=int, default=768)
    ap.add_argument("--clusters", type=int, default=64)
    ap.add_argument("--landmarks", type=int, default=65536)
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--smoke-mp", type=int, default=0, metavar="P",
                    help="run the multi-process CPU smoke with P "
                         "processes (real gloo collectives through the "
                         "s-step fit path) instead of the lowering sweep")
    ap.add_argument("--s-step", type=int, default=2,
                    help="s-step depth for the smoke fit")
    ap.add_argument("--obs", default=None, metavar="PATH",
                    help="smoke: rank-0 flight-recorder JSONL (CI "
                         "artifact)")
    args = ap.parse_args()

    if args.smoke_mp:
        raise SystemExit(smoke_driver(args))

    modes = sorted(MODES) if args.all else [args.mode]
    meshes = [False, True] if (args.both_meshes or args.all) \
        else [args.multi_pod]
    os.makedirs(args.out, exist_ok=True)
    n_fail = 0
    for mode in modes:
        for mp in meshes:
            tag = f"kkmeans-{mode}__minibatch_1m__{'mp' if mp else 'sp'}"
            path = os.path.join(args.out, tag + ".json")
            if os.path.exists(path):
                print(f"[skip] {tag} (cached)")
                continue
            try:
                res = lower_cluster(mode, multi_pod=mp, n_rows=args.rows,
                                    d=args.d, c=args.clusters,
                                    n_landmarks=args.landmarks)
                print(f"[ok]   {tag}  compile={res['compile_seconds']}s "
                      f"coll/sweep="
                      f"{res['loop_aware']['collective_bytes']:.3e}B")
            except Exception as e:
                n_fail += 1
                res = {"arch": f"kkmeans-{mode}", "shape": "minibatch_1m",
                       "mesh": "2x16x16" if mp else "16x16", "ok": False,
                       "error": f"{type(e).__name__}: {e}",
                       "traceback": traceback.format_exc()}
                print(f"[FAIL] {tag}: {type(e).__name__}: {e}")
            with open(path, "w") as f:
                json.dump(res, f, indent=1)
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
