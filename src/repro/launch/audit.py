"""Static program audit — ``python -m repro.launch.audit``.

Traces every hot path the repo ships (the three GramEngine modes of the
exact inner loop, the mesh program of ``distributed/inner``, the embedded
Lloyd program, the serving ``predict``, and every shape-bucket program of
the assignment service — ``audit_assign_buckets`` additionally AOT-warms
an ``AssignService`` and pins its compiled-program count to the bucket-
ladder size) WITHOUT running any of them, and proves from the jaxprs
(``repro.analysis``):

  * collective counts — the mesh programs' per-iteration psum/all_gather
    counts equal ``collectives_per_iteration``'s analytic bill exactly;
  * memory residency — peak live intermediate bytes stay within a slack
    factor of ``core.memory.engine_footprint_bytes``'s priced footprint,
    and no single intermediate reaches the full [n, |L|] Gram block unless
    the mode is ``materialize`` (the tiled/fused residency promise);
  * Pallas dispatch — ``pallas_call`` present iff mode == "fused" (the
    PR 5 dead-kernel bug, decided before anything runs);
  * host-sync hygiene — no callback primitives inside inner loops;
  * accumulation precision — every Pallas kernel the repo ships
    (kernel_matrix, assign_fused, embed_assign, sketch_assign,
    flash_attention) is traced in BOTH tile dtypes ("f32" and "bf16",
    ``repro.kernels.precision``) and ``check_precision`` proves each
    in-kernel ``dot_general``/``reduce_sum`` statically accumulates f32 —
    the invariant the mixed-precision policy rests on. The engine-mode
    audits run at both precisions too, with the memory budget re-priced
    at the tile dtype (``engine_footprint_bytes(q_tile=...)``).

``--gpu-trace`` repeats the kernel-wrapper sweep with ``backend="gpu"``:
the Triton-lowering bodies (kernels/backend.py) are dry-traced — jaxpr
only, nothing compiles or runs, so this works on the CPU CI host — and
held to the same f32-accumulation standard as the Mosaic bodies.

``--hlo`` additionally compiles each single-host program and attaches
``launch/hlocost.py``'s loop-aware FLOPs / HBM bytes plus XLA's own
``cost_analysis`` numbers to the report. ``--out FILE`` writes the full
``ProgramReport`` JSON (the CI artifact). Exit code 1 on any violation.

On CPU the fused path is audited in Pallas interpret mode (same jaxpr
structure, ``pallas_call`` primitive included) — pass ``--no-interpret``
on a real accelerator.
"""
from __future__ import annotations

import argparse
import json
import sys

import jax
import jax.numpy as jnp

from repro.analysis import ProgramReport, audit
from repro.core.engine import ENGINE_MODES, GramEngine
from repro.core.kernels import KernelSpec
from repro.core.memory import engine_footprint_bytes
from repro.kernels.precision import PRECISIONS

#: jaxpr-level liveness double-counts what XLA fuses (see
#: ProgramReport.check_memory) — 4x absorbs the elementwise-chain
#: inflation on every mode without hiding a resident Gram block, which
#: overshoots by x(rows / tile_rows) >> 4.
MEMORY_SLACK = 4.0


def _round_up(v: int, m: int) -> int:
    return -(-v // m) * m


def mode_budget(n: int, d: int, n_landmarks: int, c: int, mode: str,
                tile_rows: int, *, pallas: bool,
                precision: str = "f32") -> float:
    """The planner's priced per-iteration footprint for one audit shape.

    The Pallas path (fused mode on an accelerator, or interpret mode here)
    pads rows/landmarks/features up to its 128-multiple block grid before
    dispatch, so its *traced* intermediates are the padded arrays — price
    the budget at the padded shape or the audit would compare apples to
    oranges. ``precision`` re-prices the tile terms at the policy dtype
    (bf16 tiles are half the bytes the trace actually carries)."""
    if pallas:
        n = _round_up(n, 128)
        d = _round_up(d, 128)
        n_landmarks = _round_up(n_landmarks, 128)
    return engine_footprint_bytes(
        n, 1, c, 1, s=n_landmarks / n, d=d, mode=mode, tile_rows=tile_rows,
        q_tile=2 if precision == "bf16" else None)


def _attach_hlo(report: ProgramReport, fn, *args, **kwargs) -> None:
    from repro.launch.hlocost import compiled_cost_terms
    try:
        report.hlo = compiled_cost_terms(fn, *args, **kwargs)
    except Exception as e:   # pragma: no cover - backend-dependent
        report.hlo = {"error": repr(e)}


def audit_engine_modes(*, n: int, d: int, n_landmarks: int, c: int,
                       tile_rows: int, interpret: bool,
                       with_hlo: bool) -> list:
    """(report, violations) per GramEngine mode on the single-host inner
    loop — no mesh, so ANY collective in the trace is a violation."""
    from repro.core import kkmeans

    spec = KernelSpec(name="rbf", gamma=0.5)
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (n, d), jnp.float32)
    l_idx = jnp.arange(n_landmarks, dtype=jnp.int32)
    diag = spec.diag(x)
    labels0 = jnp.zeros((n,), jnp.int32)
    out = []
    for mode in ENGINE_MODES:
        for precision in PRECISIONS:
            engine = GramEngine(mode=mode, tile_rows=tile_rows,
                                interpret=interpret, precision=precision)
            uses_pallas = engine._use_pallas(spec)
            report = audit(kkmeans.kkmeans_fit, x, l_idx, diag, labels0,
                           spec=spec, n_clusters=c, max_iters=10,
                           engine=engine,
                           name=f"kkmeans_fit[{mode},{precision}]")
            budget = mode_budget(n, d, n_landmarks, c, mode, tile_rows,
                                 pallas=uses_pallas and mode == "fused",
                                 precision=precision)
            violations = []
            violations += report.check_pallas(mode == "fused" and
                                              uses_pallas)
            violations += report.check_precision()
            # slack absorbs f32 elementwise chains the jaxpr double-counts
            # (check_memory docstring); those chains stay f32 whatever the
            # tile dtype, so measured in budget units they inflate by
            # q/q_tile when the budget shrinks with the tiles.
            slack = MEMORY_SLACK * (2.0 if precision == "bf16" else 1.0)
            violations += report.check_memory(budget, slack=slack)
            if mode != "materialize":
                # the residency promise: nothing the size of the full Gram
                # block may ever be materialized (pad-aware for Pallas).
                # The threshold stays f32-priced in BOTH precision sweeps:
                # an illegally materialized block always appears in the
                # trace via its f32 producer (the spec contraction runs
                # f32 before any cast), and a bf16-priced threshold can
                # collide with the legitimate f32 f panel ([rows, C_pad]
                # == bf16 [rows, |L|] bytes when |L| = 2*C_pad).
                rows = _round_up(n, 128) if uses_pallas else n
                cols = _round_up(n_landmarks, 128) if uses_pallas \
                    else n_landmarks
                violations += report.check_max_intermediate(
                    4 * rows * cols)
            violations += report.check_host_sync()
            if (report.collectives_per_iteration
                    or report.collectives_outside):
                violations.append(f"{report.name}: collectives in a "
                                  f"single-host program")
            if with_hlo and precision == "f32":
                _attach_hlo(report, kkmeans.kkmeans_fit, x, l_idx, diag,
                            labels0, spec=spec, n_clusters=c, max_iters=10,
                            engine=engine)
            out.append((report, violations))
    return out


#: every Pallas kernel wrapper the repo ships, audited per precision (and
#: per backend with --gpu-trace). flash_attention is live code — reachable
#: via repro.models.attention (attn_impl="flash"); see the "Precision
#: policy & backends" README section — so it is held to the same
#: f32-accumulation invariant as the clustering kernels.
KERNEL_WRAPPERS = ("kernel_matrix", "assign_fused", "embed_assign",
                   "sketch_assign", "flash_attention")


def audit_kernel_wrappers(*, n: int, d: int, c: int, interpret: bool,
                          backend: str = "tpu") -> list:
    """(report, violations) per Pallas kernel wrapper x tile precision.

    Each wrapper is traced (abstract — nothing runs, so the gpu backend's
    Triton bodies dry-trace fine on a CPU host) in both policy dtypes and
    must (a) actually dispatch a ``pallas_call`` and (b) pass
    ``check_precision`` — every in-kernel accumulation statically f32.
    """
    from repro.approx.rff import make_rff
    from repro.approx.sketch import make_count_sketch
    from repro.kernels import ops as kops

    spec = KernelSpec(name="rbf", gamma=0.5)
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (n, d), jnp.float32)
    landmarks = x[: max(c, 32)]
    m_embed = 64
    rff = make_rff(key, d, m_embed, spec)
    sketch = make_count_sketch(key, d, m_embed, KernelSpec(name="linear"))
    centroids = jax.random.normal(jax.random.fold_in(key, 1),
                                  (c, m_embed), jnp.float32)
    counts = jnp.ones((c,), jnp.float32)
    labels_l = jnp.zeros((landmarks.shape[0],), jnp.int32)
    g = jnp.zeros((c,), jnp.float32)
    qkv = jax.random.normal(jax.random.fold_in(key, 2),
                            (1, 2, 128, 32), jnp.float32)

    out = []
    for precision in PRECISIONS:
        wrappers = {
            "kernel_matrix": lambda x, y: kops.kernel_matrix(
                x, y, kind=spec.name, gamma=spec.gamma, interpret=interpret,
                precision=precision, backend=backend),
            "assign_fused": lambda x, l: kops.assign_fused(
                x, l, labels_l, counts, g, n_clusters=c, kind=spec.name,
                gamma=spec.gamma, interpret=interpret, precision=precision,
                backend=backend),
            "embed_assign": lambda x: kops.embed_assign(
                x, rff, centroids, counts, interpret=interpret,
                precision=precision, backend=backend),
            "sketch_assign": lambda x: kops.sketch_assign(
                x, sketch, centroids, counts, interpret=interpret,
                precision=precision, backend=backend),
            "flash_attention": lambda q: kops.flash_attention(
                q, q, q, causal=True, interpret=interpret,
                precision=precision),
        }
        if backend == "gpu":
            # flash has a single (Mosaic-shaped) body; the gpu sweep
            # covers the four clustering kernels that grew Triton bodies.
            del wrappers["flash_attention"]
        for kname, fn in wrappers.items():
            args = {"kernel_matrix": (x, landmarks),
                    "assign_fused": (x, landmarks)}.get(kname, (x,))
            if kname == "flash_attention":
                args = (qkv,)
            report = audit(fn, *args,
                           name=f"{kname}[{precision},{backend}]")
            violations = report.check_pallas(True)
            violations += report.check_precision()
            out.append((report, violations))
    return out


def audit_mesh_path(*, n: int, d: int, n_landmarks: int, c: int,
                    with_model_axis: bool, s_step: int = 1) -> tuple:
    """(report, violations) for ``distributed_kkmeans_fit`` on a 1x1 mesh
    — the jaxpr (and therefore the bill) is the same program every device
    runs, whatever the axis sizes. ``s_step > 1`` audits the
    communication-avoiding variant: the bill per SYNC is unchanged
    (1 allgather + 1 fused psum), the s-1 extra local refinements must
    add zero collectives."""
    from repro.distributed import inner as dinner
    from repro.distributed.compat import make_mesh

    spec = KernelSpec(name="rbf", gamma=0.5)
    mesh = make_mesh((1, 1), ("data", "model")) if with_model_axis \
        else make_mesh((1,), ("data",))
    cfg = dinner.DistributedInnerConfig(
        n_clusters=c, kernel=spec, max_iters=10,
        engine=GramEngine(mode="materialize"),
        col_axis="model" if with_model_axis else None, s_step=s_step)
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (n, d), jnp.float32)
    landmarks = x[:n_landmarks]
    l_idx = jnp.arange(n_landmarks, dtype=jnp.int32)
    diag = spec.diag(x)
    u0 = jnp.zeros((n,), jnp.int32)
    tag = "data x model" if with_model_axis else "data"
    if s_step > 1:
        tag += f", s={s_step}"
    report = audit(
        lambda *a: dinner.distributed_kkmeans_fit(mesh, *a, cfg=cfg),
        x, landmarks, l_idx, diag, u0, name=f"distributed_inner[{tag}]")
    bill = dinner.collectives_per_iteration(cfg)
    # s-step contract: exactly ONE allgather + ONE fused psum per sync,
    # and the prologue sync outside the loop pays the identical pair
    # (the fixpoint epilogue is gone — the pipelined body syncs the
    # stats of the labels it just wrote).
    violations = report.check_collectives(
        bill, {"psum": bill["psum"], "allgather": bill["allgather"]})
    violations += report.check_host_sync()
    if len(report.loops) != 1:
        violations.append(f"{report.name}: expected exactly one inner "
                          f"while loop, found {len(report.loops)}")
    return report, violations


def audit_embed_path(*, n: int, d: int, m: int, c: int) -> tuple:
    """(report, violations) for the embedded-space Lloyd mesh program."""
    from repro.core.minibatch import MiniBatchConfig
    from repro.distributed import embed as dembed
    from repro.distributed.compat import make_mesh

    mesh = make_mesh((1,), ("data",))
    cfg = MiniBatchConfig(n_clusters=c, n_batches=1,
                          kernel=KernelSpec(name="rbf", gamma=0.5),
                          method="rff", embed_dim=m, max_inner_iters=10)
    km = dembed.DistributedEmbedKMeans(mesh, cfg)
    key = jax.random.PRNGKey(0)
    z = jax.random.normal(key, (n, m), jnp.float32)
    wgt = jnp.ones((n,), jnp.float32)
    centroids0 = z[:c]
    mask0 = jnp.ones((c,), bool)
    report = audit(km._lloyd_fn, z, wgt, centroids0, mask0,
                   name="embed_lloyd")
    bill = dembed.collectives_per_iteration(c, m)
    violations = report.check_collectives({"psum": bill["psum"]},
                                          {"psum": bill["final_psum"]})
    violations += report.check_host_sync()
    return report, violations


def audit_predict_path(*, n: int, d: int, c: int) -> tuple:
    """(report, violations) for serving ``predict`` — a pure map: no
    collectives, no loops, no host syncs."""
    from repro.core.minibatch import predict

    spec = KernelSpec(name="rbf", gamma=0.5)
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (n, d), jnp.float32)
    medoids = x[:c]
    report = audit(predict, x, medoids, spec.diag(medoids), spec=spec,
                   name="serving_predict")
    violations = report.check_host_sync()
    if report.primitive_counts.get("while", 0):
        violations.append(f"{report.name}: serving predict must be "
                          f"loop-free")
    if report.collectives_per_iteration or report.collectives_outside:
        violations.append(f"{report.name}: collectives in the serving "
                          f"path")
    return report, violations


def audit_assign_buckets(*, d: int, c: int, m: int,
                         buckets: tuple = (1, 8, 64, 512),
                         interpret: bool = True) -> list:
    """(report, violations) per serving shape bucket + the ladder proof.

    Builds a synthetic frozen artifact (``serving.freeze_map`` — no fit
    needed) and traces the dense bucket program at every ladder rung: the
    predict hot path must be loop-free, collective-free, host-sync-free,
    actually dispatch its fused Pallas pass, and accumulate f32. Then an
    ``AssignService`` is AOT-warmed on the same ladder (compile only —
    nothing executes) and its resident compiled-program count is pinned to
    the ladder size: the proof that ragged request traffic cannot
    compile-amplify the serving path.
    """
    from repro.approx.rff import make_rff
    from repro.serving import assign as sassign
    from repro.serving.artifact import freeze_map

    spec = KernelSpec(name="rbf", gamma=0.5)
    key = jax.random.PRNGKey(0)
    fmap = make_rff(key, d, m, spec)
    centroids = jax.random.normal(jax.random.fold_in(key, 1), (c, m),
                                  jnp.float32)
    art = freeze_map(fmap, centroids, jnp.ones((c,), jnp.float32))
    out = []
    for b in buckets:
        xp = jnp.zeros((b, d), jnp.float32)
        report = audit(
            lambda xq: sassign._predict_padded(art, xq, fused=True,
                                               interpret=interpret,
                                               backend="tpu"),
            xp, name=f"serve_bucket[{b}]")
        violations = report.check_pallas(True)
        violations += report.check_precision()
        violations += report.check_host_sync()
        if report.primitive_counts.get("while", 0):
            violations.append(f"{report.name}: the serving bucket program "
                              f"must be loop-free")
        if report.collectives_per_iteration or report.collectives_outside:
            violations.append(f"{report.name}: collectives in the serving "
                              f"hot path")
        out.append((report, violations))
    svc = sassign.AssignService(art, sassign.AssignServeConfig(
        buckets=tuple(buckets), fused=True, interpret=interpret,
        backend="tpu"))
    if svc.compiled_programs != len(set(buckets)):
        out[-1][1].append(
            f"serve_bucket ladder: {svc.compiled_programs} compiled "
            f"programs != ladder size {len(set(buckets))}")
    return out


def run_audits(*, n: int, d: int, n_landmarks: int, c: int, m: int,
               tile_rows: int, interpret: bool, with_hlo: bool,
               gpu_trace: bool = False) -> list:
    results = audit_engine_modes(
        n=n, d=d, n_landmarks=n_landmarks, c=c, tile_rows=tile_rows,
        interpret=interpret, with_hlo=with_hlo)
    results += audit_kernel_wrappers(n=256, d=d, c=c, interpret=interpret,
                                     backend="tpu")
    if gpu_trace:
        results += audit_kernel_wrappers(n=256, d=d, c=c,
                                         interpret=interpret,
                                         backend="gpu")
    results.append(audit_mesh_path(n=n, d=d, n_landmarks=n_landmarks, c=c,
                                   with_model_axis=True))
    results.append(audit_mesh_path(n=n, d=d, n_landmarks=n_landmarks, c=c,
                                   with_model_axis=False))
    # the communication-avoiding s-step variant must keep the identical
    # per-sync bill on both layouts — local refinements are collective-free.
    results.append(audit_mesh_path(n=n, d=d, n_landmarks=n_landmarks, c=c,
                                   with_model_axis=True, s_step=2))
    results.append(audit_mesh_path(n=n, d=d, n_landmarks=n_landmarks, c=c,
                                   with_model_axis=False, s_step=2))
    results.append(audit_embed_path(n=n, d=d, m=m, c=c))
    results.append(audit_predict_path(n=n, d=d, c=c))
    results += audit_assign_buckets(d=d, c=c, m=m, interpret=interpret)
    return results


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.launch.audit",
        description="static audit of every hot path (no execution); "
                    "exit 1 on any violated invariant")
    # defaults keep the padded landmark axis (Pallas pads to 128-multiples)
    # strictly wider than the padded feature axis, so a feature panel can
    # never alias the Gram-block residency threshold.
    ap.add_argument("--n", type=int, default=512, help="audit batch rows")
    ap.add_argument("--d", type=int, default=16, help="feature dim")
    ap.add_argument("--landmarks", type=int, default=256)
    ap.add_argument("--clusters", type=int, default=8)
    ap.add_argument("--embed-dim", type=int, default=32)
    ap.add_argument("--tile-rows", type=int, default=64)
    ap.add_argument("--no-interpret", action="store_true",
                    help="audit the real Pallas lowering (accelerator)")
    ap.add_argument("--gpu-trace", action="store_true",
                    help="also dry-trace the Triton (backend='gpu') kernel "
                         "bodies and audit their accumulator dtypes — "
                         "jaxpr only, runs on a CPU host")
    ap.add_argument("--hlo", action="store_true",
                    help="compile single-host programs and attach "
                         "hlocost FLOPs/bytes to the reports")
    ap.add_argument("--out", default=None,
                    help="write the ProgramReport JSON here (CI artifact)")
    args = ap.parse_args(argv)

    results = run_audits(
        n=args.n, d=args.d, n_landmarks=args.landmarks, c=args.clusters,
        m=args.embed_dim, tile_rows=args.tile_rows,
        interpret=not args.no_interpret, with_hlo=args.hlo,
        gpu_trace=args.gpu_trace)

    all_violations = []
    for report, violations in results:
        status = "FAIL" if violations else "ok"
        per = report.collectives_per_iteration
        print(f"[{status}] {report.name}: peak_live="
              f"{report.peak_live_bytes:,}B largest="
              f"{report.largest_intermediate_bytes:,}B "
              f"pallas={report.pallas_calls} "
              f"per-iter={per or '{}'} "
              f"outside={report.collectives_outside or '{}'}")
        for v in violations:
            print(f"       {v}")
        all_violations += violations

    if args.out:
        payload = {
            "ok": not all_violations,
            "violations": all_violations,
            "reports": [r.to_dict() for r, _ in results],
        }
        with open(args.out, "w") as fh:
            json.dump(payload, fh, indent=2, default=str)
        print(f"report written to {args.out}")

    if all_violations:
        print(f"{len(all_violations)} violation(s)")
        return 1
    print(f"all {len(results)} program audits clean")
    return 0


if __name__ == "__main__":
    sys.exit(main())
