"""Loop-aware cost analysis over post-SPMD HLO text.

``compiled.cost_analysis()`` visits every while body exactly ONCE, so any
scan-over-layers program under-reports FLOPs by ~n_layers x (verified in
EXPERIMENTS.md §Roofline methodology). This module re-derives per-device
FLOPs / HBM bytes / collective link-bytes from ``compiled.as_text()`` with
while-loop trip counts multiplied through the call graph:

  * dot:             2 * prod(result dims) * prod(lhs contracting dims)
  * elementwise:     prod(result dims) (transcendentals cost 1 like XLA)
  * fusion:          FLOPs traverse inside; HBM bytes counted ONLY at the
                     call site (operands + result) — fused intermediates
                     never touch HBM, matching HloCostAnalysis.
  * while:           trip count x (body + cond); the trip count is the
                     integer constant compared against the induction var in
                     the condition computation (exact for lax.scan; an upper
                     bound for lax.while_loop with a dynamic predicate).
  * collectives:     ring-cost link bytes by kind, also trip-multiplied.

This is a static-analysis estimate of the same kind XLA itself makes; its
purpose is ROOFLINE TERMS, not cycle accuracy.
"""
from __future__ import annotations

import dataclasses
import re

import jax

_DT_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4,
             "s64": 8, "u64": 8, "s16": 2, "u16": 2, "pred": 1, "s8": 1,
             "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16,
             "s4": 1, "u4": 1}

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(?[^=]*?\)?)\s*([\w\-]+)\(")
_CALLS_RE = re.compile(r"(?:calls|to_apply|body)=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")
_GROUPS_ALT = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_LHS_CONTRACT = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_CONST_RE = re.compile(r"s(?:32|64)\[\]\s+constant\((\d+)\)")

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "power", "maximum", "minimum",
    "exponential", "exponential-minus-one", "log", "log-plus-one", "tanh",
    "rsqrt", "sqrt", "negate", "abs", "sign", "floor", "ceil", "cosine",
    "sine", "logistic", "atan2", "remainder", "and", "or", "xor", "not",
    "select", "compare", "clamp", "round-nearest-afz", "round-nearest-even",
    "cbrt", "erf", "shift-left", "shift-right-logical",
    "shift-right-arithmetic",
}
_REDUCES = {"reduce", "reduce-window"}
_DATA_MOVE = {"copy", "dynamic-slice", "dynamic-update-slice", "gather",
              "scatter", "pad", "slice", "concatenate", "reverse",
              "broadcast", "iota", "transpose", "reshape", "convert",
              "reduce", "reduce-window", "sort", "select-and-scatter",
              "cholesky", "triangular-solve", "rng", "rng-bit-generator"}
_COLLECTIVES = {"all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute"}
_ZERO_COST = {"parameter", "constant", "tuple", "get-tuple-element",
              "bitcast", "after-all", "partition-id", "replica-id",
              "custom-call", "optimization-barrier", "domain", "copy-start",
              "copy-done", "send", "recv", "infeed", "outfeed"}


def _shapes_in(text: str):
    return [(dt, [int(x) for x in dims.split(",") if x])
            for dt, dims in _SHAPE_RE.findall(text)]


def _nelems(dims) -> int:
    n = 1
    for d in dims:
        n *= d
    return n


def _bytes_of(shapes) -> int:
    return sum(_DT_BYTES.get(dt, 4) * _nelems(dims) for dt, dims in shapes)


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: dict = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in _COLLECTIVES})
    coll_counts: dict = dataclasses.field(
        default_factory=lambda: {k: 0 for k in _COLLECTIVES})

    def __iadd__(self, other):
        self.flops += other.flops
        self.bytes += other.bytes
        for k in self.coll:
            self.coll[k] += other.coll[k]
            self.coll_counts[k] += other.coll_counts[k]
        return self

    def scaled(self, m: float) -> "Cost":
        return Cost(self.flops * m, self.bytes * m,
                    {k: v * m for k, v in self.coll.items()},
                    {k: int(v * m) for k, v in self.coll_counts.items()})

    @property
    def coll_bytes(self) -> float:
        return sum(self.coll.values())


_COMMENT_RE = re.compile(r"/\*.*?\*/")


def _split_computations(hlo: str) -> dict[str, list[str]]:
    comps: dict[str, list[str]] = {}
    cur = None
    for line in hlo.splitlines():
        # long tuples embed ``/*index=5*/`` comments whose '=' breaks the
        # instruction regex — strip all inline comments first.
        stripped = _COMMENT_RE.sub("", line).strip()
        # computation headers: ``%name (params...) -> type {`` — params may
        # contain NESTED parens (tuple-typed while-body args), so match the
        # name and require the `-> ... {` tail rather than balancing parens.
        if (stripped.endswith("{") and "->" in stripped
                and " = " not in stripped.split("->", 1)[0]):
            m = re.match(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(", stripped)
            if m:
                cur = m.group(1)
                comps[cur] = []
                continue
        if stripped.startswith("}"):
            cur = None
            continue
        if cur is not None and "=" in stripped:
            comps[cur].append(stripped)
    return comps


def _entry_name(hlo: str) -> str | None:
    m = re.search(r"^ENTRY\s+%?([\w.\-]+)", hlo, re.MULTILINE)
    return m.group(1) if m else None


_KNOWN_TRIPS = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')


def _trip_count(while_line: str, cond_lines: list[str]) -> int:
    """Trip count of a while: prefer XLA's own ``known_trip_count`` backend
    config (exact for lax.scan). Fallback: the constant operand of the
    condition's ROOT compare (conditions can contain OTHER large constants
    — vocab sizes, sequence lengths — that must not be mistaken for trips);
    last resort, the max integer constant in the condition."""
    m = _KNOWN_TRIPS.search(while_line)
    if m:
        return int(m.group(1))
    consts: dict[str, int] = {}
    root_ops: list[str] = []
    for line in cond_lines:
        mm = re.match(r"^(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*s(?:32|64)\[\]\s+"
                      r"constant\((\d+)\)", line)
        if mm:
            consts[mm.group(1)] = int(mm.group(2))
        if line.startswith("ROOT") and " compare(" in line:
            inner = line.split(" compare(", 1)[1].split(")", 1)[0]
            root_ops = re.findall(r"%([\w.\-]+)", inner)
    for name in root_ops:
        if name in consts:
            return max(consts[name], 1)
    best = 1
    for line in cond_lines:
        for mm in _CONST_RE.finditer(line):
            best = max(best, int(mm.group(1)))
    return best


_OPERAND_NAME = re.compile(r"%([\w.\-]+)")


def _operand_text(line: str, op: str) -> str:
    """The text inside the op's call parens (balanced)."""
    paren = line.find(op + "(")
    if paren < 0:
        return ""
    rest = line[paren + len(op) + 1:]
    depth = 1
    for i, ch in enumerate(rest):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                return rest[:i]
    return rest


_SLICE_READS = ("dynamic-slice", "slice", "gather")


def fusion_site_bytes(fusion_name: str, result_part: str, operands: str,
                      comps: dict, shape_map: dict) -> float:
    """HBM traffic of one fusion call, slice-aware.

    XLA hoists stacked (per-layer) buffers out of scan loops and the body
    fusion takes the FULL stack as an operand, slicing one layer inside —
    charging the full operand per trip inflates scan programs by ~n_layers
    x. Per-operand rule: if the matching fusion parameter is consumed ONLY
    by slice-family ops, charge the slice RESULTS; if consumed as the
    in-place target of a dynamic-update-slice, charge the update region;
    else charge the full operand. The fusion result is charged in full
    unless the fusion ROOT is itself a dynamic-update-slice (in-place
    region write).
    """
    lines = comps.get(fusion_name, ())
    params: dict[int, str] = {}
    consumers: dict[str, list] = {}
    root_op, root_operands = None, ""
    for line in lines:
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, res_part, op = m.groups()
        if op == "parameter":
            mi = re.search(r"parameter\((\d+)\)", line)
            if mi:
                params[int(mi.group(1))] = name
            continue
        otext = _operand_text(line, op)
        for oname in _OPERAND_NAME.findall(otext):
            consumers.setdefault(oname, []).append(
                (op, _shapes_in(res_part), otext))
        if line.startswith("ROOT"):
            root_op, root_operands = op, otext

    total = 0.0
    op_names = _OPERAND_NAME.findall(operands)
    for i, oname in enumerate(op_names):
        full = _bytes_of(shape_map.get(oname, ()))
        pname = params.get(i)
        cons = consumers.get(pname, []) if pname else []
        if cons and all(c[0] in _SLICE_READS for c in cons):
            total += sum(_bytes_of(c[1]) for c in cons)
        elif cons and all(
                c[0] == "dynamic-update-slice"
                and c[2].split(",")[0].strip().lstrip("%") == pname
                for c in cons):
            total += 0.0        # in-place DUS target: write counted at root
        else:
            total += full

    res_shapes = _shapes_in(result_part)
    if root_op == "dynamic-update-slice":
        # region write: the update operand (2nd DUS arg; params and inner
        # instructions are both named in shape_map)
        upd_names = _OPERAND_NAME.findall(root_operands)[1:2]
        upd = sum(_bytes_of(shape_map.get(u, ())) for u in upd_names)
        total += 2.0 * upd
    else:
        total += _bytes_of(res_shapes)
    return total


def build_shape_map(comps: dict[str, list[str]]) -> dict[str, list]:
    """instruction name -> result shapes, across every computation.

    Post-optimization HLO prints operands as bare ``%name`` references
    (no inline types), so operand shapes must be resolved by definition.
    """
    out: dict[str, list] = {}
    for lines in comps.values():
        for line in lines:
            m = _INSTR_RE.match(line)
            if m:
                name, result_part, _ = m.groups()
                out[name] = _shapes_in(result_part)
    return out


def _instr_cost(line: str, op: str, result_part: str,
                shape_map: dict | None = None) -> Cost:
    c = Cost()
    res_shapes = _shapes_in(result_part)
    # operand shapes: inside the call parens
    paren = line.find(op + "(")
    operand_part = line[paren + len(op) + 1:]
    depth = 1
    end = 0
    for i, ch in enumerate(operand_part):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                end = i
                break
    operands = operand_part[:end]
    op_shapes = _shapes_in(operands)
    if not op_shapes and shape_map:
        # bare-name operands: resolve through the definition map
        for name in _OPERAND_NAME.findall(operands):
            op_shapes.extend(shape_map.get(name, ()))

    if op == "dot":
        mcon = _LHS_CONTRACT.search(line)
        contract = 1
        if mcon and op_shapes:
            lhs_dims = op_shapes[0][1]
            for idx in mcon.group(1).split(","):
                if idx:
                    contract *= lhs_dims[int(idx)]
        c.flops = 2.0 * _nelems(res_shapes[0][1]) * contract \
            if res_shapes else 0.0
        c.bytes = _bytes_of(op_shapes) + _bytes_of(res_shapes)
    elif op == "convolution":
        # not emitted by this code base; approximate as dot on shapes
        c.flops = 2.0 * _nelems(res_shapes[0][1]) if res_shapes else 0.0
        c.bytes = _bytes_of(op_shapes) + _bytes_of(res_shapes)
    elif op in _ELEMENTWISE:
        c.flops = float(_nelems(res_shapes[0][1])) if res_shapes else 0.0
        c.bytes = _bytes_of(op_shapes) + _bytes_of(res_shapes)
    elif op in _COLLECTIVES or op.removesuffix("-start") in _COLLECTIVES:
        kind = op.removesuffix("-start")
        out_b = _bytes_of(res_shapes)
        g = 1
        mg = _GROUPS_RE.search(line)
        if mg:
            g = len(mg.group(1).split(","))
        else:
            mg2 = _GROUPS_ALT.search(line)
            if mg2:
                g = int(mg2.group(2))
        if kind == "all-gather":
            moved = out_b * (g - 1) / max(g, 1)
        elif kind == "all-reduce":
            moved = 2.0 * out_b * (g - 1) / max(g, 1)
        elif kind == "reduce-scatter":
            moved = out_b * (g - 1)
        elif kind == "all-to-all":
            moved = out_b * (g - 1) / max(g, 1)
        else:
            moved = out_b
        if g > 1 or kind == "collective-permute":
            c.coll[kind] += moved
            c.coll_counts[kind] += 1
        c.bytes = _bytes_of(op_shapes) + _bytes_of(res_shapes)
    elif op in ("dynamic-slice", "slice", "gather"):
        # reads only the sliced REGION (~= result), not the whole operand —
        # counting the full buffer inflates scan-over-stacked-weights
        # programs by ~n_layers x. Index operands are negligible.
        c.bytes = 2.0 * _bytes_of(res_shapes)
    elif op in ("dynamic-update-slice", "scatter", "select-and-scatter"):
        # writes only the updated region: read update + write region.
        # The update is every operand except the (largest) target buffer.
        sizes = [_bytes_of([s]) for s in op_shapes]
        upd = sum(sizes) - max(sizes) if sizes else 0.0
        c.bytes = 2.0 * upd
    elif op in _DATA_MOVE:
        c.bytes = _bytes_of(op_shapes) + _bytes_of(res_shapes)
        if op == "reduce":
            c.flops = float(_nelems(op_shapes[0][1])) if op_shapes else 0.0
    elif op in _ZERO_COST:
        pass
    else:
        # unknown op: count result bytes, zero flops
        c.bytes = _bytes_of(res_shapes)
    return c


def analyze(hlo: str) -> Cost:
    comps = _split_computations(hlo)
    entry = _entry_name(hlo)
    shape_map = build_shape_map(comps)
    memo: dict[tuple[str, bool], Cost] = {}

    def comp_cost(name: str, inside_fusion: bool) -> Cost:
        key = (name, inside_fusion)
        if key in memo:
            return memo[key]
        total = Cost()
        for line in comps.get(name, ()):
            m = _INSTR_RE.match(line)
            if not m:
                continue
            _, result_part, op = m.groups()
            if op == "fusion":
                mc = _CALLS_RE.search(line)
                inner = comp_cost(mc.group(1), True) if mc else Cost()
                site = Cost()
                # HBM bytes at the call site only (slice-aware — see
                # fusion_site_bytes)
                site.bytes = fusion_site_bytes(
                    mc.group(1) if mc else "", result_part,
                    _operand_text(line, op), comps, shape_map)
                site.flops = inner.flops
                for k in inner.coll:
                    site.coll[k] = inner.coll[k]
                    site.coll_counts[k] = inner.coll_counts[k]
                total += site
            elif op == "while":
                mb = _CALLS_RE.search(line)       # body=
                mcnd = _COND_RE.search(line)
                body = comp_cost(mb.group(1), False) if mb else Cost()
                cond = comp_cost(mcnd.group(1), False) if mcnd else Cost()
                trips = _trip_count(line, comps.get(
                    mcnd.group(1) if mcnd else "", []))
                total += body.scaled(trips)
                total += cond.scaled(trips)
            elif op in ("call", "conditional", "async-start"):
                for cname in _CALLS_RE.findall(line):
                    total += comp_cost(cname, inside_fusion)
                mb = re.search(r"branch_computations=\{([^}]*)\}", line)
                if mb:
                    for cname in re.findall(r"%?([\w.\-]+)", mb.group(1)):
                        total += comp_cost(cname, inside_fusion)
            else:
                ic = _instr_cost(line, op, result_part, shape_map)
                if inside_fusion:
                    ic.bytes = 0.0   # fused intermediates stay on-chip
                total += ic
        memo[key] = total
        return total

    if entry is None:
        return Cost()
    return comp_cost(entry, False)


# ---------------------------------------------------------------------------
# compiled-program helpers (repro.analysis integration)
#
# The static auditor wants XLA's own cost/memory numbers NEXT TO the
# loop-aware text analysis above, in one dict. Getting them portably is the
# same compat minefield PR 1 patched in launch/dryrun*: the pinned JAX's
# ``compiled.cost_analysis()`` returns a one-element LIST of dicts (newer
# return the dict), and on CPU ``memory_analysis()`` can return None, raise,
# or lack ``peak_memory_in_bytes`` — every attribute must be guarded.


def xla_cost(compiled) -> dict:
    """``compiled.cost_analysis()`` normalized to a flat dict, or ``{}``
    when the backend provides none (list-vs-dict and None-safe)."""
    try:
        from repro.distributed.compat import cost_analysis
        return cost_analysis(compiled)
    except Exception:
        return {}


def xla_memory(compiled) -> dict:
    """``compiled.memory_analysis()`` as the dryrun report dict, all-None
    when the backend has no memory analysis (CPU)."""
    empty = {"bytes_per_device": None, "argument_bytes": None,
             "output_bytes": None, "peak_bytes": None}
    try:
        from repro.distributed.compat import memory_stats
        if compiled.memory_analysis() is None:
            return empty
        return memory_stats(compiled)
    except Exception:
        return empty


def compiled_cost_terms(fn, *args, **kwargs) -> dict:
    """Compile ``fn(*args, **kwargs)`` and return every static cost term in
    one dict: XLA's ``cost_analysis`` FLOPs/bytes (once-per-while-body, see
    module docstring), the compat-guarded memory analysis, and this
    module's loop-aware re-derivation over the HLO text. kwargs are closed
    over, so static (hashable) config objects pass through untouched."""
    compiled = jax.jit(lambda *a: fn(*a, **kwargs)).lower(*args).compile()
    xla = xla_cost(compiled)
    mem = xla_memory(compiled)
    loop = analyze(compiled.as_text())
    return {
        "xla_flops": xla.get("flops"),
        "xla_bytes_accessed": xla.get("bytes accessed"),
        "memory": mem,
        "flops": loop.flops,
        "hbm_bytes": loop.bytes,
        "coll_bytes": loop.coll_bytes,
        "coll_counts": {k: v for k, v in loop.coll_counts.items() if v},
    }
