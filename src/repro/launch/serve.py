"""Serving launcher: ``python -m repro.launch.serve --arch <id> [...]``.

Spins up the continuous-batching engine on a synthetic request stream and
reports throughput + per-request latency percentiles. The same engine object
serves the production mesh (cache shardings from ``api.cache_specs``).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.models import Axes, get_model
from repro.serving import ServeConfig, ServingEngine, greedy, sample_top_p

from .train import build_mesh


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--mesh", default="1x1")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--max-new-tokens", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--top-p", type=float, default=0.0,
                    help="0 -> greedy; else nucleus sampling")
    ap.add_argument("--obs", default=None, metavar="PATH",
                    help="write a repro.obs flight-recorder JSONL here")
    args = ap.parse_args(argv)

    mesh = build_mesh(args.mesh)
    dp_axes = tuple(a for a in mesh.axis_names if a != "model")
    axes = Axes(dp=dp_axes, tp="model")
    cfg = get_arch(args.arch, smoke=args.smoke)
    api = get_model(cfg, tp_size=mesh.shape["model"])
    params, _ = api.init(jax.random.PRNGKey(0))

    sampler = greedy if args.top_p <= 0 else \
        (lambda logits, key: sample_top_p(logits, key, top_p=args.top_p))
    eng = ServingEngine(api, params, ServeConfig(
        max_batch=args.max_batch, max_len=args.max_len,
        max_new_tokens=args.max_new_tokens, eos_token=-1), sampler=sampler)

    rng = np.random.default_rng(0)
    lens = rng.integers(2, args.prompt_len + 1, size=args.requests)
    for l in lens:
        eng.submit(rng.integers(1, cfg.vocab_size, size=int(l)))

    rec = None
    if args.obs:
        from repro.obs import JsonlRecorder, export
        rec = JsonlRecorder(args.obs, header=export.run_header(
            entry="launch.serve", arch=args.arch,
            mesh={k: int(v) for k, v in mesh.shape.items()}))
    results = {}
    t0 = time.time()
    try:
        with mesh:
            results = eng.run(axes)
    finally:
        dt = time.time() - t0
        if rec is not None:
            rec.event("serve/summary", requests=len(lens),
                      tokens=sum(len(v) for v in results.values()),
                      seconds=dt, ticks=eng.ticks)
            rec.close()
    n_tokens = sum(len(v) for v in results.values())
    print(f"[serve] {args.arch}: {len(results)} requests, "
          f"{n_tokens} tokens in {dt:.2f}s "
          f"({n_tokens/dt:.1f} tok/s, {eng.ticks} batched ticks)")
    return results


if __name__ == "__main__":
    main()
