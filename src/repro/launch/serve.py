"""Serving launcher: ``python -m repro.launch.serve [...]``.

Two services share this entry point:

* ``--arch <id>`` — the LM-zoo continuous-batching engine
  (``repro.serving.engine``) on a synthetic request stream, reporting
  throughput + per-request latency.
* ``--assign <artifact.npz | synth>`` — the ASSIGNMENT service
  (``repro.serving.assign``): load a frozen predict artifact (or fit +
  freeze a small synthetic model for smoke runs), AOT-warm one compiled
  program per shape bucket, and drive a ragged request stream through the
  continuous-batching queue, reporting p50/p99 latency and rows/sec.

Both report into the same ``--obs`` flight-recorder JSONL.
"""
from __future__ import annotations

import argparse
import time

# XLA_FLAGS / JAX_PLATFORM_NAME must be staged BEFORE the first jax
# import: the latency-hiding scheduler flags are a compile-time,
# process-level switch, and the --platform pin (which also selects the
# Mosaic/Triton/interpret kernel lowering, kernels/backend.py — i.e.
# which body the AOT-warmed bucket programs compile) is read once at
# backend init (repro.launch.env) — importing jax first would freeze
# both as-is. --platform is therefore pre-parsed from raw argv here; the
# argparse entry below only documents and validates it.
from .env import configure as _configure_env, platform_from_argv
_ENV = _configure_env(platform=platform_from_argv())

import jax   # noqa: E402  (env staging above is load-bearing)
import jax.numpy as jnp   # noqa: E402
import numpy as np   # noqa: E402

from repro.configs import get_arch   # noqa: E402
from repro.models import Axes, get_model   # noqa: E402
from repro.serving import (AssignServeConfig, AssignService,   # noqa: E402
                           ServeConfig, ServingEngine, artifact_nbytes,
                           freeze, greedy, load_artifact, sample_top_p)

from .train import build_mesh   # noqa: E402


def _make_recorder(args, **extra):
    if not args.obs:
        return None
    from repro.obs import JsonlRecorder, export
    return JsonlRecorder(args.obs, header=export.run_header(
        entry="launch.serve", **extra))


def _synth_artifact(precision: str):
    """Fit a small rbf/RFF model on blobs and freeze it (smoke path)."""
    from repro.core.minibatch import MiniBatchConfig, fit_dataset
    from repro.data.synthetic import make_blobs
    x, _ = make_blobs(2048, 16, 8, seed=0)
    cfg = MiniBatchConfig(n_clusters=8, n_batches=4, method="rff",
                          embed_dim=64, seed=0)
    return freeze(fit_dataset(np.asarray(x), cfg), precision=precision)


def _assign_main(args):
    art = (_synth_artifact(args.precision) if args.assign == "synth"
           else load_artifact(args.assign))
    buckets = tuple(int(b) for b in args.buckets.split(","))
    rec = _make_recorder(args, mode="assign", kind=art.kind,
                         precision=art.precision, buckets=list(buckets))
    t0 = time.time()
    svc = AssignService(art, AssignServeConfig(buckets=buckets),
                        recorder=rec)
    warm_s = time.time() - t0

    rng = np.random.default_rng(0)
    sizes = rng.integers(1, args.rows_max + 1, size=args.requests)
    lat, rows = [], 0
    t0 = time.time()
    for n in sizes:
        ts = time.time()
        svc.predict(rng.normal(size=(int(n), art.in_dim)).astype(np.float32))
        lat.append(time.time() - ts)
        rows += int(n)
    dt = time.time() - t0
    p50, p99 = np.percentile(lat, [50, 99])
    if rec is not None:
        rec.event("serve/summary", requests=len(sizes), rows=rows,
                  seconds=dt, p50_seconds=float(p50),
                  p99_seconds=float(p99), warm_seconds=warm_s,
                  programs=svc.compiled_programs,
                  artifact_bytes=artifact_nbytes(art))
        rec.close()
    print(f"[serve.assign] kind={art.kind} precision={art.precision} "
          f"programs={svc.compiled_programs} (warm {warm_s:.2f}s) | "
          f"{len(sizes)} requests / {rows} rows in {dt:.2f}s "
          f"({rows/dt:.0f} rows/s, p50 {p50*1e3:.2f}ms, p99 {p99*1e3:.2f}ms)")
    return svc


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None,
                    help="LM-zoo arch id (LM serving mode)")
    ap.add_argument("--assign", default=None, metavar="ARTIFACT",
                    help="assignment-serving mode: path to a frozen "
                    "artifact .npz (repro.serving.save_artifact) or "
                    "'synth' for a self-contained smoke model")
    ap.add_argument("--platform", choices=("cpu", "gpu", "tpu"),
                    default=None,
                    help="pin the jax backend (pre-parsed from raw argv "
                    "before the first jax import; see repro.launch.env)")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--mesh", default="1x1")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--max-new-tokens", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--top-p", type=float, default=0.0,
                    help="0 -> greedy; else nucleus sampling")
    # -- assignment-serving knobs --
    ap.add_argument("--buckets", default="1,8,64,512",
                    help="shape-bucket ladder (comma-separated row counts)")
    ap.add_argument("--rows-max", type=int, default=64,
                    help="synthetic request sizes draw from [1, rows-max]")
    ap.add_argument("--precision", choices=("f32", "bf16"), default="f32",
                    help="tile dtype for --assign synth freezing")
    ap.add_argument("--obs", default=None, metavar="PATH",
                    help="write a repro.obs flight-recorder JSONL here")
    args = ap.parse_args(argv)

    if args.assign is not None:
        return _assign_main(args)
    if args.arch is None:
        ap.error("one of --arch (LM serving) or --assign is required")

    mesh = build_mesh(args.mesh)
    dp_axes = tuple(a for a in mesh.axis_names if a != "model")
    axes = Axes(dp=dp_axes, tp="model")
    cfg = get_arch(args.arch, smoke=args.smoke)
    api = get_model(cfg, tp_size=mesh.shape["model"])
    params, _ = api.init(jax.random.PRNGKey(0))

    sampler = greedy if args.top_p <= 0 else \
        (lambda logits, key: sample_top_p(logits, key, top_p=args.top_p))
    eng = ServingEngine(api, params, ServeConfig(
        max_batch=args.max_batch, max_len=args.max_len,
        max_new_tokens=args.max_new_tokens, eos_token=-1), sampler=sampler)

    rng = np.random.default_rng(0)
    lens = rng.integers(2, args.prompt_len + 1, size=args.requests)
    for l in lens:
        eng.submit(rng.integers(1, cfg.vocab_size, size=int(l)))

    rec = _make_recorder(args, arch=args.arch,
                         mesh={k: int(v) for k, v in mesh.shape.items()})
    results = {}
    t0 = time.time()
    try:
        with mesh:
            results = eng.run(axes)
    finally:
        dt = time.time() - t0
        if rec is not None:
            rec.event("serve/summary", requests=len(lens),
                      tokens=sum(len(v) for v in results.values()),
                      seconds=dt, ticks=eng.ticks)
            rec.close()
    n_tokens = sum(len(v) for v in results.values())
    print(f"[serve] {args.arch}: {len(results)} requests, "
          f"{n_tokens} tokens in {dt:.2f}s "
          f"({n_tokens/dt:.1f} tok/s, {eng.ticks} batched ticks)")
    return results


if __name__ == "__main__":
    main()
