"""Clustering launcher — the paper's algorithm as the production entry point.

``python -m repro.launch.cluster --n 100000 --d 64 --clusters 16 [...]``

End-to-end flow (paper §3 + §4.2 model selection, automated):

  1. plan (B, s) from the per-chip memory budget (Eq.19, repro.core.memory),
  2. build the mesh, shard rows over the data axes, landmarks over model,
  3. run distributed mini-batch kernel k-means with per-batch checkpointing
     (restart loses at most one mini-batch),
  4. report accuracy/NMI (when labels exist) + the Fig.4b displacement
     diagnostic for sampling quality.
"""
from __future__ import annotations

import argparse
import time

# XLA_FLAGS / JAX_PLATFORM_NAME must be staged BEFORE the first jax
# import: the latency-hiding scheduler that overlaps the s-step loop's one
# fused collective per sync with the next Gram panel is a compile-time,
# process-level switch, and the --platform pin (which also selects the
# Mosaic/Triton/interpret kernel lowering, kernels/backend.py) is read
# once at backend init (repro.launch.env) — importing jax first would
# freeze both as-is. --platform is therefore pre-parsed from raw argv
# here; the argparse entry below only documents and validates it.
from .env import configure as _configure_env, platform_from_argv
_ENV = _configure_env(platform=platform_from_argv())

import jax   # noqa: E402  (env staging above is load-bearing)
import numpy as np   # noqa: E402

from repro.core import (KernelSpec, MachineSpec, MiniBatchConfig,
                        clustering_accuracy, gamma_from_dmax,
                        mean_displacement, nmi, plan)
from repro.core.minibatch import predict
from repro.data.sampling import split_batches
from repro.data.synthetic import make_blobs
from repro.distributed.outer import DistributedMiniBatchKMeans
from repro.ft.checkpoint import CheckpointManager

from .train import build_mesh


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=20000)
    ap.add_argument("--d", type=int, default=32)
    ap.add_argument("--clusters", type=int, default=8)
    ap.add_argument("--mesh", default="1x1")
    ap.add_argument("--platform", default=None,
                    choices=["cpu", "gpu", "tpu"],
                    help="pin the jax backend (and with it the kernel "
                         "lowering: Mosaic on tpu, Triton on gpu, "
                         "interpret on cpu). Consumed from raw argv "
                         "before the first jax import; listed here for "
                         "--help and validation")
    ap.add_argument("--memory-gb", type=float, default=0.5,
                    help="per-processor budget R for the Eq.19 planner")
    ap.add_argument("--s", type=float, default=None,
                    help="override the planned landmark fraction")
    ap.add_argument("--b", type=int, default=None,
                    help="override the planned number of mini-batches")
    ap.add_argument("--sampling", default="stride",
                    choices=["stride", "block"])
    ap.add_argument("--mode", default="auto",
                    choices=["auto", "materialize", "fused", "tiled"],
                    help="Gram residency of the exact inner loop "
                         "(repro.core.engine); auto = the planner's pick")
    ap.add_argument("--s-step", type=int, default=1,
                    help="communication-avoiding s-step depth: s local "
                         "Lloyd refinements per global sync — the "
                         "collective bill drops to (1 allgather + 1 "
                         "psum)/s at the price of replicating the batch "
                         "labels on every device")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--obs", default=None, metavar="PATH",
                    help="write a repro.obs flight-recorder JSONL here "
                         "(per-batch wall time, collective counts, HBM "
                         "watermarks vs the plan)")
    ap.add_argument("--profile", default=None, metavar="DIR",
                    help="dump a TensorBoard profiler trace of the fit")
    args = ap.parse_args(argv)

    mesh = build_mesh(args.mesh)
    n_proc = len(jax.devices())

    # -- data (synthetic stand-in for the MD/UCI streams; DESIGN.md §8.5)
    x, y = make_blobs(args.n, args.d, args.clusters, sep=8.0,
                      seed=args.seed)

    # -- memory-aware (B, s) plan — the paper's §4.2 rationale
    machine = MachineSpec(memory_bytes=args.memory_gb * 1e9,
                          n_processors=n_proc)
    p = plan(args.n, args.clusters, machine, d=args.d,
             s_step=args.s_step)
    b = args.b or p.b
    s = args.s if args.s is not None else p.s
    gamma = gamma_from_dmax(jax.numpy.asarray(x[:4096]))
    print(f"[cluster] N={args.n} d={args.d} C={args.clusters} "
          f"mesh={dict(mesh.shape)}")
    mode = p.engine if args.mode == "auto" else args.mode
    print(f"[cluster] plan: B={b} s={s:.2f} ({p.note}); "
          f"footprint/node {p.footprint/1e6:.1f} MB "
          f"(fused {p.fused_footprint/1e6:.1f} MB); "
          f"engine={mode}; gamma={gamma:.2e}")

    cfg = MiniBatchConfig(n_clusters=args.clusters, n_batches=b, s=s,
                          kernel=KernelSpec("rbf", gamma=gamma),
                          sampling=args.sampling, seed=args.seed,
                          s_step=args.s_step)

    rec = None
    if args.obs:
        from repro.obs import JsonlRecorder, export
        rec = JsonlRecorder(args.obs, header=export.run_header(
            entry="launch.cluster", plan=p, b=b, s=s, engine=str(mode),
            s_step=args.s_step, xla_flags=_ENV.get("xla_flags", ""),
            mesh={k: int(v) for k, v in mesh.shape.items()}))
    km = DistributedMiniBatchKMeans(mesh, cfg, mode=mode, recorder=rec)

    cb = None
    if args.ckpt_dir:
        cm = CheckpointManager(args.ckpt_dir)
        cb = lambda state, i: cm.save(i, state,  # noqa: E731
                                      extra={"B": b, "s": s})

    if args.profile:
        from repro.obs import start_profile
        start_profile(args.profile)
    t0 = time.time()
    try:
        res = km.fit(split_batches(x, b, strategy=args.sampling),
                     checkpoint_cb=cb)
    finally:
        if args.profile:
            from repro.obs import stop_profile
            stop_profile()
            print(f"[cluster] profiler trace -> {args.profile}")
        if rec is not None:
            rec.close()
    dt = time.time() - t0

    labels = np.asarray(predict(jax.numpy.asarray(x), res.state.medoids,
                                res.state.medoid_diag, spec=cfg.kernel))
    acc = clustering_accuracy(y, labels)
    disp = mean_displacement(res.history)
    print(f"[cluster] {dt:.2f}s  acc={acc:.4f} nmi={nmi(y, labels):.4f}")
    print(f"[cluster] displacement/batch (Fig.4b): "
          f"{np.array2string(disp, precision=4)}")
    print(f"[cluster] inner iters/batch: "
          f"{[h.inner_iters for h in res.history]}")
    if args.obs:
        from repro.obs import export
        s_ = export.summarize(args.obs)
        print(f"[cluster] obs: {s_['events']} events -> {args.obs}")
    return acc


if __name__ == "__main__":
    main()
