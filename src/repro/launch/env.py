"""Process-level JAX/XLA environment setup for the launchers.

The s-step inner loop (``repro.distributed.inner``) is built so its one
fused psum per sync can overlap the next Gram panel: the tiled engine
double-buffers panel builds against contractions, and the loop body has no
host sync the scheduler must serialize around. Whether XLA actually hides
the collective behind compute is decided at COMPILE time by the
latency-hiding scheduler, which is switched on with process-level flags
that must be in ``XLA_FLAGS`` before the first ``import jax`` touches the
backend. This module owns that dance:

  * ``configure(...)`` — call it FIRST (before importing anything that
    imports jax). It merges the GPU latency-hiding/async-collective flag
    set into ``XLA_FLAGS`` without clobbering flags the caller (or a test
    harness — ``--xla_force_host_platform_device_count``) already set,
    and (``platform=``) pins ``JAX_PLATFORM_NAME`` — the backend pin that
    also selects the kernel lowering (kernels/backend.py follows
    ``jax.default_backend()``: Mosaic on tpu, Triton on gpu, interpret
    elsewhere).
  * ``platform_from_argv(...)`` — pre-parses ``--platform`` from the raw
    argv so launchers can pin the backend BEFORE their argparse runs
    (argparse lives after the jax import, which is too late for the env
    var).
  * ``set_platform(...)`` — the post-import half: pins
    ``jax_platform_name`` the way the jax gpu-performance-tips page
    recommends.

Flag availability is jaxlib-version-gated (``_GATED_GPU_FLAGS``): XLA
deletes flags once their behavior becomes the default, and a jaxlib that
no longer knows a flag hard-ABORTS at backend init — so every
since-removed flag (``--xla_gpu_enable_async_collectives``,
``--xla_gpu_enable_triton_softmax_fusion``, ...) carries the first jaxlib
version WITHOUT it and is only emitted for provably older installs; an
undeterminable jaxlib version fails closed (no gated flag at all).
Everything here is a plain env-var edit — no jax import happens in this
module at call time unless ``set_platform`` is used.
"""
from __future__ import annotations

import os
import sys
import warnings

# the jax gpu-performance-tips flag set (latency-hiding scheduler + fusion
# knobs). Safe to parse on CPU-only jaxlib builds: DebugOptions registers
# xla_gpu_* flags regardless of backend. Only flags still present in
# current XLA live here unconditionally; everything XLA has since deleted
# goes through the version-gated table below.
GPU_PERF_FLAGS = (
    "--xla_gpu_triton_gemm_any=True",
    "--xla_gpu_enable_latency_hiding_scheduler=true",
)

# Flags XLA has removed upstream (their behavior became the default).
# Newer jaxlibs hard-ABORT at backend init on an unknown XLA flag, so each
# is emitted only when the installed jaxlib is provably older than the
# release that dropped it: (flag, first jaxlib WITHOUT the flag). When the
# jaxlib version cannot be determined we fail CLOSED and emit none of
# them — losing a hint flag is harmless, aborting the process is not.
_GATED_GPU_FLAGS = (
    # async collectives became the default in mid-0.4.x
    ("--xla_gpu_enable_async_collectives=true", (0, 4, 30)),
    # both dropped in the 0.5 line (still parsed by 0.4.36)
    ("--xla_gpu_enable_triton_softmax_fusion=true", (0, 5, 0)),
    ("--xla_gpu_enable_highest_priority_async_stream=true", (0, 5, 0)),
)


PLATFORMS = ("cpu", "gpu", "tpu")


def platform_from_argv(argv=None) -> str | None:
    """Extract ``--platform <p>`` / ``--platform=<p>`` from raw argv
    (default: ``sys.argv``) without argparse — launchers call this at
    module import, before jax exists in the process, so the pin can land
    in ``JAX_PLATFORM_NAME`` while it still matters. Returns None when the
    flag is absent; validation happens in ``configure``."""
    argv = sys.argv[1:] if argv is None else list(argv)
    for i, tok in enumerate(argv):
        if tok == "--platform" and i + 1 < len(argv):
            return argv[i + 1]
        if tok.startswith("--platform="):
            return tok.split("=", 1)[1]
    return None


def _jaxlib_version() -> tuple | None:
    """Installed jaxlib version triple, or None when unknown (fail closed:
    callers must then treat every version-gated flag as unavailable)."""
    try:
        from importlib.metadata import version
        return tuple(int(p) for p in version("jaxlib").split(".")[:3])
    except Exception:                      # pragma: no cover - defensive
        return None


def _merge_xla_flags(new_flags) -> bool:
    """Append flags to ``XLA_FLAGS``; existing settings of the same flag
    win (never clobber what the caller/test harness already pinned).
    Returns whether anything was actually added."""
    current = os.environ.get("XLA_FLAGS", "").split()
    have = {f.split("=", 1)[0] for f in current}
    added = [f for f in new_flags if f.split("=", 1)[0] not in have]
    if added:
        os.environ["XLA_FLAGS"] = " ".join(current + added)
    return bool(added)


def configure(*, gpu_flags: bool = True,
              host_device_count: int | None = None,
              platform: str | None = None) -> dict:
    """Prepare the process environment for a launcher run.

    Must run before the first jax import in the process — XLA parses
    ``XLA_FLAGS`` once at backend init and never re-reads it (and jax
    reads ``JAX_PLATFORM_NAME`` at the same moment). Idempotent: a second
    call that would change nothing is a silent no-op, so every launcher
    module can stage the env at import without worrying about which one
    ran first. Returns the settings actually applied (for logging / the
    obs run header).

    ``platform`` pins the jax backend ('cpu' | 'gpu' | 'tpu', typically
    from ``platform_from_argv()``). An explicit pin wins over an inherited
    ``JAX_PLATFORM_NAME``; None leaves whatever the environment says.
    """
    applied: dict = {}
    changed = False
    if platform is not None:
        if platform not in PLATFORMS:
            raise ValueError(f"unknown platform {platform!r}; "
                             f"have {PLATFORMS}")
        if os.environ.get("JAX_PLATFORM_NAME") != platform:
            os.environ["JAX_PLATFORM_NAME"] = platform
            changed = True
        applied["platform"] = platform
    if host_device_count:
        changed |= _merge_xla_flags(
            [f"--xla_force_host_platform_device_count={host_device_count}"])
        applied["host_device_count"] = host_device_count
    if gpu_flags:
        flags = list(GPU_PERF_FLAGS)
        ver = _jaxlib_version()
        if ver is not None:   # unknown version -> skip every gated flag
            flags += [f for f, removed_in in _GATED_GPU_FLAGS
                      if ver < removed_in]
        changed |= _merge_xla_flags(flags)
        applied["gpu_flags"] = flags
    if changed and "jax" in sys.modules:   # too late for XLA_FLAGS
        warnings.warn(
            "repro.launch.env.configure() changed XLA_FLAGS after jax was "
            "imported; the changes will not take effect in this process",
            RuntimeWarning, stacklevel=2)
    applied["xla_flags"] = os.environ.get("XLA_FLAGS", "")
    return applied


def set_platform(platform: str | None = None) -> None:
    """Pin the jax platform ('cpu' | 'gpu' | 'tpu'). The one jax-importing
    call here; only effective at the beginning of the program."""
    if platform is None:
        return
    import jax
    jax.config.update("jax_platform_name", platform)
