"""Production meshes (the dry-run targets).

single-pod: (16, 16) = 256 chips, axes (data, model)
multi-pod : (2, 16, 16) = 512 chips, axes (pod, data, model)

Functions (never module-level constants) so importing this module never
touches jax device state — device count is locked on first jax init, and the
smoke tests must keep seeing 1 device.
"""
from __future__ import annotations

import jax

from repro.distributed.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def data_axes(multi_pod: bool = False) -> tuple[str, ...]:
    return ("pod", "data") if multi_pod else ("data",)
