import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ^ MUST precede every other import (jax locks the device count on first
# init). 512 placeholder host devices back the production meshes below; the
# dry-run lowers + compiles but never executes.

import argparse          # noqa: E402
import dataclasses      # noqa: E402
import json              # noqa: E402
import re                # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import SHAPES, TrainConfig, cells, get_arch  # noqa: E402
from repro.launch.mesh import data_axes, make_production_mesh   # noqa: E402
from repro.models import Axes, get_model                        # noqa: E402
from repro.training.optim import adamw_init, opt_state_specs    # noqa: E402
from repro.training.step import make_train_step                 # noqa: E402

# ---------------------------------------------------------------------------
# collective-bytes extraction from the post-SPMD HLO
# ---------------------------------------------------------------------------

_KIND_RE = re.compile(
    r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_SHAPE_TOK = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUP_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")
_GROUP_ALT = re.compile(r"replica_groups=\[(\d+),(\d+)\]")

_DT_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4,
             "s64": 8, "u64": 8, "s16": 2, "u16": 2, "pred": 1, "s8": 1,
             "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16}


def _dims_prod(dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n


def collective_bytes(hlo_text: str) -> dict:
    """Per-device link-bytes estimate per collective kind (ring costs):
    all-gather: out*(g-1)/g; all-reduce: 2*out*(g-1)/g;
    reduce-scatter: out*(g-1); all-to-all: out*(g-1)/g; permute: out."""
    totals = {"all-gather": 0.0, "all-reduce": 0.0, "reduce-scatter": 0.0,
              "all-to-all": 0.0, "collective-permute": 0.0}
    counts = dict.fromkeys(totals, 0)
    for line in hlo_text.splitlines():
        if " = " not in line:
            continue
        rhs = line.split(" = ", 1)[1]
        m = _KIND_RE.search(rhs)
        if m is None:
            continue
        kind = m.group(1)
        result = rhs[:m.start()]          # everything before the op name
        out_bytes = sum(
            _DT_BYTES.get(dt, 4) * _dims_prod(dims)
            for dt, dims in _SHAPE_TOK.findall(result))
        g = 1
        mg = _GROUP_RE.search(line)
        if mg:
            g = len(mg.group(1).split(","))
        else:
            mg2 = _GROUP_ALT.search(line)
            if mg2:
                g = int(mg2.group(2))
        if g <= 1 and kind != "collective-permute":
            continue
        if kind == "all-gather":
            moved = out_bytes * (g - 1) / g
        elif kind == "all-reduce":
            moved = 2.0 * out_bytes * (g - 1) / g
        elif kind == "reduce-scatter":
            moved = out_bytes * (g - 1)
        elif kind == "all-to-all":
            moved = out_bytes * (g - 1) / g
        else:
            moved = out_bytes
        totals[kind] += moved
        counts[kind] += 1
    return {"bytes_by_kind": totals, "counts": counts,
            "total_bytes": sum(totals.values())}


# ---------------------------------------------------------------------------
# per-cell lowering
# ---------------------------------------------------------------------------


def _specs_of(api, key):
    """(param ShapeDtypeStructs, PartitionSpec tree) without allocating."""
    cell = {}

    def initf(k):
        p, s = api.init(k)
        cell["specs"] = s
        return p

    shapes = jax.eval_shape(initf, key)
    return shapes, cell["specs"]


def _sharding_tree(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


# §Perf hillclimb variants: config deltas applied on top of an arch config
# (results are written under "<arch>+<variant>"; see EXPERIMENTS.md §Perf).
VARIANTS = {
    "ep": lambda cfg, dp: dataclasses.replace(
        cfg, moe_ep_groups=dp),          # expert-parallel MoE dispatch
    "qc1024": lambda cfg, dp: dataclasses.replace(
        cfg, q_chunk=1024),              # half the attention chunk trips
    "qc2048": lambda cfg, dp: dataclasses.replace(
        cfg, q_chunk=2048),
    # flash-attention Pallas kernel: meaningful only on a real TPU lowering
    # (on CPU the kernel lowers via interpret mode — enormous HLO); listed
    # for completeness, see EXPERIMENTS.md §Perf C3 for the analytic delta.
    "flash": lambda cfg, dp: dataclasses.replace(cfg, attn_impl="flash"),
}


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
               smoke: bool = False, opt_dtype: str | None = None,
               variant: str | None = None):
    """Lower + compile one (arch x shape x mesh) cell; return roofline facts."""
    mesh = make_production_mesh(multi_pod=multi_pod)
    axes = Axes(dp=data_axes(multi_pod), tp="model")
    cfg = get_arch(arch, smoke=smoke)
    shape = SHAPES[shape_name]
    dp_size = 1
    for a in data_axes(multi_pod):
        dp_size *= mesh.shape[a]
    if variant:
        cfg = VARIANTS[variant](cfg, dp_size)
        arch = f"{arch}+{variant}"
    from repro.models.common import set_ambient_mesh
    set_ambient_mesh(mesh)     # shard_map-based layers (EP MoE) need it
    api = get_model(cfg, tp_size=mesh.shape["model"], dp_size=dp_size)
    key = jax.random.PRNGKey(0)
    t0 = time.time()

    param_shapes, param_specs = _specs_of(api, key)
    param_sh = _sharding_tree(mesh, param_specs)
    import math
    n_params = sum(math.prod(p.shape) for p in jax.tree.leaves(param_shapes))

    if shape.kind == "train":
        if opt_dtype is None:
            # bf16 optimizer state for the >=200B configs (HBM budget).
            opt_dtype = "bfloat16" if n_params > 1e11 else "float32"
        tcfg = TrainConfig(opt_state_dtype=opt_dtype)
        opt_shapes = jax.eval_shape(lambda p: adamw_init(p, tcfg),
                                    param_shapes)
        opt_sh = _sharding_tree(mesh, opt_state_specs(param_specs))
        batch_shapes = api.input_specs(shape)
        batch_sh = _sharding_tree(mesh, api.batch_partition(shape, axes))
        step = make_train_step(api, tcfg, axes)
        jitted = jax.jit(step,
                         in_shardings=(param_sh, opt_sh, batch_sh),
                         donate_argnums=(0, 1))
        with mesh:
            lowered = jitted.lower(param_shapes, opt_shapes, batch_shapes)
            compiled = lowered.compile()
    elif shape.kind == "prefill":
        batch_shapes = api.input_specs(shape)
        batch_sh = _sharding_tree(mesh, api.batch_partition(shape, axes))
        cache_shapes, cache_specs = api.cache_specs(shape, axes)
        cache_sh = _sharding_tree(mesh, cache_specs)

        def prefill_fn(params, batch):
            return api.prefill(params, batch, axes, max_len=shape.seq_len)

        jitted = jax.jit(prefill_fn, in_shardings=(param_sh, batch_sh),
                         out_shardings=(cache_sh, None))
        with mesh:
            lowered = jitted.lower(param_shapes, batch_shapes)
            compiled = lowered.compile()
    else:  # decode
        batch_shapes = api.input_specs(shape)
        batch_sh = _sharding_tree(mesh, api.batch_partition(shape, axes))
        cache_shapes, cache_specs = api.cache_specs(shape, axes)
        cache_sh = _sharding_tree(mesh, cache_specs)

        def serve_step(params, cache, token, pos):
            return api.decode(params, cache, token, pos, axes)

        jitted = jax.jit(serve_step,
                         in_shardings=(param_sh, cache_sh,
                                       batch_sh["token"], batch_sh["pos"]),
                         out_shardings=(None, cache_sh),
                         donate_argnums=(1,))
        with mesh:
            lowered = jitted.lower(param_shapes, cache_shapes,
                                   batch_shapes["token"],
                                   batch_shapes["pos"])
            compiled = lowered.compile()

    from repro.distributed.compat import cost_analysis as _ca
    cost = _ca(compiled)
    try:
        from repro.distributed.compat import memory_stats
        mem_info = memory_stats(compiled)
    except Exception as e:  # CPU backend may not implement it
        mem_info = {"error": str(e)}
    hlo_text = compiled.as_text()
    coll = collective_bytes(hlo_text)

    # loop-aware re-analysis: cost_analysis() visits every while body ONCE,
    # so scan-over-layers programs under-report by ~n_layers x. hlocost
    # multiplies trip counts through the call graph (see §Roofline method).
    from repro.launch import hlocost
    la = hlocost.analyze(hlo_text)

    # active params (MoE: top_k/n_experts of expert weights participate
    # per token) for the MODEL_FLOPS = 6 N_active D roofline numerator.
    n_active = n_params
    if cfg.n_experts:
        expert = sum(
            math.prod(p.shape)
            for kp, p in jax.tree_util.tree_flatten_with_path(param_shapes)[0]
            if any(getattr(k, "key", "").startswith("e_")
                   for k in kp))
        n_active = n_params - expert \
            + expert * cfg.moe_top_k // cfg.n_experts

    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 6.0 * n_active * tokens
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 2.0 * n_active * tokens
    else:  # decode: one token per sequence
        tokens = shape.global_batch
        model_flops = 2.0 * n_active * tokens

    return {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_params": int(n_params),
        "n_active_params": int(n_active),
        "tokens_per_step": int(tokens),
        "model_flops_total": model_flops,
        "flops_per_device": cost.get("flops"),
        "bytes_per_device": cost.get("bytes accessed"),
        "loop_aware": {
            "flops_per_device": la.flops,
            "bytes_per_device": la.bytes,
            "collective_bytes_by_kind": la.coll,
            "collective_counts": la.coll_counts,
            "collective_bytes": la.coll_bytes,
        },
        "cost_analysis": {k: v for k, v in cost.items()
                          if isinstance(v, (int, float)) and
                          ("flops" in k or "bytes" in k or "utilization" in k)},
        "memory_analysis": mem_info,
        "collectives": coll,
        "compile_seconds": round(time.time() - t0, 2),
        "ok": True,
    }


def main():
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--variant", default=None, choices=sorted(VARIANTS))
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args()

    todo = cells() if args.all else [(args.arch, args.shape)]
    meshes = [False, True] if (args.both_meshes or args.all) \
        else [args.multi_pod]
    os.makedirs(args.out, exist_ok=True)

    n_fail = 0
    for arch, shape in todo:
        for mp in meshes:
            vtag = f"+{args.variant}" if args.variant else ""
            tag = f"{arch}{vtag}__{shape}__{'mp' if mp else 'sp'}"
            path = os.path.join(args.out, tag + ".json")
            if os.path.exists(path):
                print(f"[skip] {tag} (cached)")
                continue
            try:
                res = lower_cell(arch, shape, multi_pod=mp,
                                 smoke=args.smoke,
                                 variant=args.variant)
                print(f"[ok]   {tag}  compile={res['compile_seconds']}s "
                      f"flops/dev={res['flops_per_device']:.3e} "
                      f"coll={res['collectives']['total_bytes']:.3e}B")
            except Exception as e:
                n_fail += 1
                res = {"arch": arch, "shape": shape,
                       "mesh": "2x16x16" if mp else "16x16", "ok": False,
                       "error": f"{type(e).__name__}: {e}",
                       "traceback": traceback.format_exc()}
                print(f"[FAIL] {tag}: {type(e).__name__}: {e}")
            with open(path, "w") as f:
                json.dump(res, f, indent=1)
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
