"""One rank of the multi-process CPU smoke (spawned by
``repro.launch.dryrun_cluster --smoke-mp P``).

Import order here is load-bearing and is the whole reason this worker is
its own module: ``jax.distributed.initialize`` must run before ANY jax
computation, and most ``repro.*`` modules touch the backend at import
(module-level jnp constants). So: stage XLA_FLAGS -> import bare jax ->
gloo init -> only then import the production fit path.

Exit codes: 0 ok, 1 smoke assertion failed, 75 (EX_TEMPFAIL) when this
jax build cannot do multi-process CPU collectives — the driver maps 75
to a soft skip so CI does not go red over a missing gloo backend.
"""
import argparse
import os
import sys

os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                           + os.environ.get("REPRO_SMOKE_DEVICES", "2"))

import jax               # noqa: E402  (flags staged above)
import jax.numpy as jnp  # noqa: E402

SKIP_EXIT = 75


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--s-step", type=int, default=2)
    ap.add_argument("--obs", default=None)
    args = ap.parse_args()

    rank = int(os.environ["REPRO_SMOKE_RANK"])
    nprocs = int(os.environ["REPRO_SMOKE_NPROCS"])
    coord = os.environ["REPRO_SMOKE_COORD"]
    try:
        jax.config.update("jax_cpu_collectives_implementation", "gloo")
        jax.distributed.initialize(coordinator_address=coord,
                                   num_processes=nprocs, process_id=rank)
    except Exception as e:   # no gloo / no distributed runtime -> skip
        print(f"[skip] rank {rank}: multi-process CPU init unsupported: "
              f"{type(e).__name__}: {e}")
        return SKIP_EXIT

    # production path imports AFTER the distributed runtime is up.
    import numpy as np
    from jax.sharding import Mesh
    from repro.core import KernelSpec, MiniBatchConfig, clustering_accuracy
    from repro.core.minibatch import predict
    from repro.data.sampling import split_batches
    from repro.data.synthetic import make_blobs
    from repro.distributed.outer import DistributedMiniBatchKMeans

    mesh = Mesh(np.asarray(jax.devices()), ("data",))
    # identical host data on every process (same seed) — the SPMD contract.
    x, y = make_blobs(1024, 8, 4, sep=8.0, seed=0)
    cfg = MiniBatchConfig(n_clusters=4, n_batches=2, s=1.0,
                          kernel=KernelSpec("rbf", gamma=2.0),
                          seed=0, s_step=args.s_step)
    rec = None
    if rank == 0 and args.obs:
        from repro.obs import JsonlRecorder, export
        rec = JsonlRecorder(args.obs, header=export.run_header(
            entry="dryrun_cluster.smoke_mp", nprocs=nprocs,
            s_step=args.s_step))
    km = DistributedMiniBatchKMeans(mesh, cfg, mode="materialize",
                                    recorder=rec)
    try:
        res = km.fit(split_batches(x, cfg.n_batches, strategy="stride"))
    finally:
        if rec is not None:
            rec.close()
    labels = np.asarray(predict(jnp.asarray(x), res.state.medoids,
                                res.state.medoid_diag, spec=cfg.kernel))
    acc = clustering_accuracy(y, labels)
    costs = [h.cost for h in res.history]
    if rank == 0:
        print(f"[smoke] {nprocs} processes x "
              f"{len(jax.local_devices())} devices, s_step={args.s_step}: "
              f"acc={acc:.4f} iters={[h.inner_iters for h in res.history]} "
              f"costs={[round(c, 4) for c in costs]}")
    if not all(np.isfinite(costs)):
        print(f"[FAIL] rank {rank}: non-finite inner cost {costs}")
        return 1
    if acc < 0.95:   # 4 blobs at sep=8 are trivially separable
        print(f"[FAIL] rank {rank}: accuracy {acc:.4f} < 0.95")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
