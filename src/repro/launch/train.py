"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

Runs the full production loop on whatever devices exist (1 CPU device for
local smoke, a forced-device mesh for integration tests, a real pod via the
same flags). Features exercised end-to-end:

  * sharded params/optimizer from the model's PartitionSpecs,
  * synthetic token pipeline with double-buffered prefetch,
  * microbatch gradient accumulation,
  * step-granular checkpoint/restart (atomic manifest, elastic restore),
  * straggler-aware step timing log.

On a multi-host pod this module is launched once per host (JAX distributed
init is orthogonal to the program) — the mesh axes and shardings used here
are exactly the dry-run-validated production ones.
"""
from __future__ import annotations

import argparse
import math
import time

# stage XLA_FLAGS (latency-hiding scheduler / async-collective overlap)
# and the --platform backend pin before the first jax import — see
# repro.launch.env; --platform is pre-parsed from raw argv because the
# argparse in main() runs long after the backend is frozen.
from .env import configure as _configure_env, platform_from_argv
_ENV = _configure_env(platform=platform_from_argv())

import jax   # noqa: E402  (env staging above is load-bearing)

from repro.distributed.compat import make_mesh   # noqa: E402
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, TrainConfig, get_arch
from repro.ft.checkpoint import CheckpointManager
from repro.models import Axes, get_model
from repro.training.optim import adamw_init, opt_state_specs
from repro.training.step import make_train_step


def build_mesh(spec: str):
    """'4x2' -> mesh (data=4, model=2) over the available devices."""
    dims = tuple(int(x) for x in spec.split("x"))
    n = math.prod(dims)
    if n != len(jax.devices()):
        raise SystemExit(
            f"mesh {spec} needs {n} devices, have {len(jax.devices())} "
            "(set XLA_FLAGS=--xla_force_host_platform_device_count=N)")
    names = ("data", "model")[:len(dims)] if len(dims) <= 2 else \
        ("pod", "data", "model")
    return make_mesh(dims, names)


def synthetic_batches(vocab: int, batch: int, seq: int, steps: int,
                      seed: int = 0):
    """Self-labelled LM batches: labels are next-token shifted tokens."""
    rng = np.random.default_rng(seed)
    for _ in range(steps):
        tok = rng.integers(1, vocab, size=(batch, seq), dtype=np.int64)
        yield {"tokens": tok.astype(np.int32),
               "labels": np.roll(tok, -1, axis=1).astype(np.int32)}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-runnable)")
    ap.add_argument("--mesh", default="1x1")
    ap.add_argument("--platform", default=None,
                    choices=["cpu", "gpu", "tpu"],
                    help="pin the jax backend (consumed from raw argv "
                         "before the first jax import; listed here for "
                         "--help and validation)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    mesh = build_mesh(args.mesh)
    dp_axes = tuple(a for a in mesh.axis_names if a != "model")
    axes = Axes(dp=dp_axes, tp="model")
    dp_size = math.prod(mesh.shape[a] for a in dp_axes)

    cfg = get_arch(args.arch, smoke=args.smoke)
    api = get_model(cfg, tp_size=mesh.shape["model"], dp_size=dp_size)
    tcfg = TrainConfig(learning_rate=args.lr, total_steps=args.steps,
                       warmup_steps=max(args.steps // 10, 1),
                       microbatches=args.microbatches,
                       remat=not args.smoke)

    params, specs = api.init(jax.random.PRNGKey(0))
    opt = adamw_init(params, tcfg)
    n_params = sum(p.size for p in jax.tree.leaves(params))
    print(f"[train] {args.arch} ({'smoke' if args.smoke else 'full'}): "
          f"{n_params/1e6:.1f}M params, mesh={dict(mesh.shape)}")

    param_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                            is_leaf=lambda x: isinstance(x, P))
    opt_sh = jax.tree.map(lambda s: NamedSharding(mesh, s),
                          opt_state_specs(specs),
                          is_leaf=lambda x: isinstance(x, P))
    params = jax.device_put(params, param_sh)
    opt = jax.device_put(opt, opt_sh)

    start_step = 0
    cm = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    if cm and args.resume and cm.latest_step() is not None:
        s = cm.latest_step()
        restored = cm.restore(s, {"params": params, "opt": opt},
                              shardings={"params": param_sh, "opt": opt_sh})
        params, opt = restored["params"], restored["opt"]
        start_step = s
        print(f"[train] resumed from step {s}")

    step_fn = jax.jit(make_train_step(api, tcfg, axes),
                      donate_argnums=(0, 1))

    batch_spec = api.batch_partition(
        type("S", (), {"kind": "train", "global_batch": args.batch,
                       "seq_len": args.seq})(), axes)
    batch_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), batch_spec,
                            is_leaf=lambda x: isinstance(x, P))

    times = []
    with mesh:
        gen = synthetic_batches(cfg.vocab_size, args.batch, args.seq,
                                args.steps - start_step, seed=start_step)
        for i, batch in enumerate(gen, start=start_step):
            batch = jax.tree.map(
                lambda a, sh: jax.device_put(jnp.asarray(a), sh),
                batch, batch_sh)
            t0 = time.time()
            params, opt, metrics = step_fn(params, opt, batch)
            jax.block_until_ready(metrics["loss"])
            times.append(time.time() - t0)
            if (i + 1) % args.log_every == 0 or i == start_step:
                print(f"  step {i+1:5d}  loss={float(metrics['loss']):.4f} "
                      f"gnorm={float(metrics['grad_norm']):.3f} "
                      f"lr={float(metrics['lr']):.2e} "
                      f"dt={times[-1]*1e3:.0f}ms")
            if cm and (i + 1) % args.ckpt_every == 0:
                cm.save(i + 1, {"params": params, "opt": opt},
                        extra={"arch": args.arch})
    med = float(np.median(times[1:])) if len(times) > 1 else float("nan")
    print(f"[train] done. median step {med*1e3:.0f}ms "
          f"(first/compile {times[0]*1e3:.0f}ms)")
    return float(metrics["loss"])


if __name__ == "__main__":
    main()
