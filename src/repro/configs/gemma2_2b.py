"""gemma2-2b [dense] — local+global alternating, logit softcap, sandwich
norms, (1+w) RMSNorm, tied embeddings. [arXiv:2408.00118; hf]"""
import dataclasses

from .base import ModelConfig

FULL = ModelConfig(
    name="gemma2-2b", family="dense",
    n_layers=26, d_model=2304, n_heads=8, n_kv_heads=4, d_head=256,
    d_ff=9216, vocab_size=256000,
    window=4096, local_global_period=2,
    attn_softcap=50.0, final_softcap=30.0,
    sandwich_norm=True, gemma_plus_one=True, tie_embeddings=True,
)

SMOKE = dataclasses.replace(
    FULL, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
    d_ff=128, vocab_size=256, window=8)
