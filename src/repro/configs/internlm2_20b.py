"""internlm2-20b [dense] — GQA. [arXiv:2403.17297; hf]"""
import dataclasses

from .base import ModelConfig

FULL = ModelConfig(
    name="internlm2-20b", family="dense",
    n_layers=48, d_model=6144, n_heads=48, n_kv_heads=8, d_head=128,
    d_ff=16384, vocab_size=92544,
    rope_theta=1e6, tie_embeddings=False,
)

SMOKE = dataclasses.replace(
    FULL, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
    d_ff=128, vocab_size=256)
