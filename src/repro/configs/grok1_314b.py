"""grok-1-314b [moe] — 8 experts top-2, GQA kv=8, attention logit softcap.
[hf:xai-org/grok-1; unverified]"""
import dataclasses

from .base import ModelConfig

FULL = ModelConfig(
    name="grok-1-314b", family="moe",
    n_layers=64, d_model=6144, n_heads=48, n_kv_heads=8, d_head=128,
    d_ff=32768, vocab_size=131072,
    n_experts=8, moe_top_k=2,
    attn_softcap=30.0, final_softcap=30.0, tie_embeddings=False,
)

SMOKE = dataclasses.replace(
    FULL, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
    d_ff=128, vocab_size=256, n_experts=4, moe_top_k=2)
