"""qwen3-32b [dense] — qk_norm, GQA. [hf:Qwen/Qwen3-8B; hf]"""
import dataclasses

from .base import ModelConfig

FULL = ModelConfig(
    name="qwen3-32b", family="dense",
    n_layers=64, d_model=5120, n_heads=64, n_kv_heads=8, d_head=128,
    d_ff=25600, vocab_size=151936,
    qk_norm=True, rope_theta=1e6, tie_embeddings=False,
)

SMOKE = dataclasses.replace(
    FULL, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
    d_ff=128, vocab_size=256)
