"""qwen3-moe-235b-a22b [moe] — 128 experts top-8, GQA kv=4, qk_norm.
[hf:Qwen/Qwen3-30B-A3B; hf]"""
import dataclasses

from .base import ModelConfig

FULL = ModelConfig(
    name="qwen3-moe-235b-a22b", family="moe",
    n_layers=94, d_model=4096, n_heads=64, n_kv_heads=4, d_head=128,
    d_ff=1536, vocab_size=151936,
    n_experts=128, moe_top_k=8,
    qk_norm=True, rope_theta=1e6, tie_embeddings=False,
)

SMOKE = dataclasses.replace(
    FULL, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
    d_ff=64, vocab_size=256, n_experts=4, moe_top_k=2)
