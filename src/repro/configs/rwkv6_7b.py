"""rwkv6-7b [ssm] — Finch: attn-free, data-dependent decay. 64 wkv heads of
64 channels. [arXiv:2404.05892; hf]"""
import dataclasses

from .base import ModelConfig

FULL = ModelConfig(
    name="rwkv6-7b", family="ssm",
    n_layers=32, d_model=4096, n_heads=64, n_kv_heads=64, d_head=64,
    d_ff=14336, vocab_size=65536,
    tie_embeddings=False,
)

SMOKE = dataclasses.replace(
    FULL, n_layers=2, d_model=128, n_heads=2, n_kv_heads=2, d_head=64,
    d_ff=256, vocab_size=256)
