"""Config schema: model architecture + input shapes + run/mesh settings."""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | encdec | hybrid | ssm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab_size: int
    # attention flavour
    qk_norm: bool = False
    attn_softcap: float | None = None
    final_softcap: float | None = None
    window: int | None = None          # sliding-window size for local layers
    local_global_period: int = 0       # gemma2: 2 -> [local, global] alternate
    sandwich_norm: bool = False        # gemma2 post-norms
    parametric_norm: bool = True       # olmo: False (non-parametric LN)
    gemma_plus_one: bool = False       # (1+w) RMSNorm parameterization
    rope_theta: float = 1e4
    tie_embeddings: bool = True
    q_chunk: int = 512              # chunked-attention query-block size
    attn_impl: str = "chunked"      # "chunked" (pure JAX) | "flash" (Pallas
                                    # kernel, TPU target; interpret on CPU)
    # moe
    n_experts: int = 0
    moe_top_k: int = 0
    capacity_factor: float = 1.25
    # expert parallelism (beyond-paper, see EXPERIMENTS.md §Perf): 0 -> dense
    # GSPMD dispatch (global capacity buffer); > 0 -> GShard-style grouped
    # dispatch with per-group capacity + expert sharding over the data axis
    # (set moe_ep_groups == dp size on the production mesh).
    moe_ep_groups: int = 0
    # ssm / hybrid
    ssm_state: int = 0
    ssm_heads: int = 0
    ssm_expand: int = 2
    conv_kernel: int = 4
    attn_period: int = 0               # zamba2: shared attn every N layers
    shared_attn_window: int = 4096     # zamba2 long-context adaptation
    # encoder-decoder
    n_enc_layers: int = 0
    n_dec_layers: int = 0
    # modality stubs ([audio]/[vlm]: precomputed frontend embeddings)
    modality: str = "text"             # text | audio_stub | vlm_stub
    frontend_dim: int = 0              # stub embedding dim (== d_model)

    @property
    def gqa_groups(self) -> int:
        return self.n_heads // self.n_kv_heads


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str            # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


TRAIN_4K = ShapeConfig("train_4k", "train", 4096, 256)
PREFILL_32K = ShapeConfig("prefill_32k", "prefill", 32768, 32)
DECODE_32K = ShapeConfig("decode_32k", "decode", 32768, 128)
LONG_500K = ShapeConfig("long_500k", "decode", 524288, 1)

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    opt_state_dtype: str = "float32"   # "bfloat16" for the 314B config
    remat: bool = True
    microbatches: int = 1
