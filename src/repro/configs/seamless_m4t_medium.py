"""seamless-m4t-medium [audio] — enc-dec, multimodal; speech frontend is a
STUB (input_specs provides precomputed frame embeddings).
[arXiv:2308.11596; hf]"""
import dataclasses

from .base import ModelConfig

FULL = ModelConfig(
    name="seamless-m4t-medium", family="encdec",
    n_layers=24, n_enc_layers=12, n_dec_layers=12,
    d_model=1024, n_heads=16, n_kv_heads=16, d_head=64,
    d_ff=4096, vocab_size=256206,
    tie_embeddings=True, modality="audio_stub", frontend_dim=1024,
)

SMOKE = dataclasses.replace(
    FULL, n_layers=4, n_enc_layers=2, n_dec_layers=2, d_model=64, n_heads=4,
    n_kv_heads=4, d_head=16, d_ff=128, vocab_size=256, frontend_dim=64)
