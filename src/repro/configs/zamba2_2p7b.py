"""zamba2-2.7b [hybrid] — Mamba2 backbone + ONE shared attention/MLP block
applied every 6 layers (weight sharing). ssm_state=64.
[arXiv:2411.15242; hf]"""
import dataclasses

from .base import ModelConfig

FULL = ModelConfig(
    name="zamba2-2.7b", family="hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32, d_head=80,
    d_ff=10240, vocab_size=32000,
    ssm_state=64, ssm_expand=2, conv_kernel=4, attn_period=6,
    shared_attn_window=4096, tie_embeddings=True,
)

SMOKE = dataclasses.replace(
    FULL, n_layers=4, d_model=128, n_heads=4, n_kv_heads=4, d_head=32,
    d_ff=256, vocab_size=256, ssm_state=16, attn_period=2,
    shared_attn_window=16)
