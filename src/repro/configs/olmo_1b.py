"""olmo-1b [dense] — non-parametric LN (no affine), MHA (kv == heads), tied.
[arXiv:2402.00838; hf]"""
import dataclasses

from .base import ModelConfig

FULL = ModelConfig(
    name="olmo-1b", family="dense",
    n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16, d_head=128,
    d_ff=8192, vocab_size=50304,
    parametric_norm=False, tie_embeddings=True,
)

SMOKE = dataclasses.replace(
    FULL, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_head=16,
    d_ff=128, vocab_size=256)
