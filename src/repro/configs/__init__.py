"""Architecture registry: ``--arch <id>`` resolves here."""
from . import (chameleon_34b, gemma2_2b, grok1_314b, internlm2_20b, olmo_1b,
               qwen3_32b, qwen3_moe_235b, rwkv6_7b, seamless_m4t_medium,
               zamba2_2p7b)
from .base import (DECODE_32K, LONG_500K, PREFILL_32K, SHAPES, TRAIN_4K,
                   ModelConfig, ShapeConfig, TrainConfig)

ARCHS = {
    "qwen3-32b": qwen3_32b,
    "internlm2-20b": internlm2_20b,
    "gemma2-2b": gemma2_2b,
    "olmo-1b": olmo_1b,
    "qwen3-moe-235b-a22b": qwen3_moe_235b,
    "grok-1-314b": grok1_314b,
    "seamless-m4t-medium": seamless_m4t_medium,
    "chameleon-34b": chameleon_34b,
    "zamba2-2.7b": zamba2_2p7b,
    "rwkv6-7b": rwkv6_7b,
}

# long_500k needs sub-quadratic sequence mixing: run for ssm/hybrid only
# (DESIGN.md §5 — pure full-attention archs are skipped per the assignment).
LONG_CONTEXT_ARCHS = {"zamba2-2.7b", "rwkv6-7b"}


def get_arch(name: str, *, smoke: bool = False) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; have {sorted(ARCHS)}")
    mod = ARCHS[name]
    return mod.SMOKE if smoke else mod.FULL


def cells(include_long: bool = True):
    """Every (arch, shape) dry-run cell, with the documented skips."""
    out = []
    for arch in ARCHS:
        for shape in ("train_4k", "prefill_32k", "decode_32k", "long_500k"):
            if shape == "long_500k" and arch not in LONG_CONTEXT_ARCHS:
                continue
            if shape == "long_500k" and not include_long:
                continue
            out.append((arch, shape))
    return out


__all__ = ["ARCHS", "LONG_CONTEXT_ARCHS", "SHAPES", "ModelConfig",
           "ShapeConfig", "TrainConfig", "TRAIN_4K", "PREFILL_32K",
           "DECODE_32K", "LONG_500K", "get_arch", "cells"]
