"""chameleon-34b [vlm] — early-fusion, VQ image tokens (the image tokenizer is
a stub: VQ codes are ordinary ids in the 65536 vocab), qk-norm.
[arXiv:2405.09818; unverified]"""
import dataclasses

from .base import ModelConfig

FULL = ModelConfig(
    name="chameleon-34b", family="dense",
    n_layers=48, d_model=8192, n_heads=64, n_kv_heads=8, d_head=128,
    d_ff=22016, vocab_size=65536,
    qk_norm=True, tie_embeddings=False, modality="vlm_stub",
)

SMOKE = dataclasses.replace(
    FULL, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
    d_ff=128, vocab_size=256)
