"""Standard (linear) k-means — the paper's scikit-learn baseline (§4.4).

Lloyd iterations with k-means++ seeding, jitted, n_init restarts keeping the
lowest-cost solution (the paper uses 5 restarts in §4.5).
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array


class KMeansResult(NamedTuple):
    centers: Array   # [C, d]
    labels: Array    # [n]
    cost: Array      # [] inertia
    n_iter: Array


def _pp_init(x: Array, key: Array, n_clusters: int) -> Array:
    n = x.shape[0]
    key, sub = jax.random.split(key)
    first = jax.random.randint(sub, (), 0, n)
    centers0 = jnp.zeros((n_clusters, x.shape[1]), x.dtype).at[0].set(x[first])

    def step(carry, key_t):
        centers, mind2, t = carry
        d2 = jnp.sum((x - centers[t]) ** 2, axis=-1)
        mind2 = jnp.minimum(mind2, d2)
        logp = jnp.where(mind2 > 0, jnp.log(jnp.maximum(mind2, 1e-30)), -jnp.inf)
        logp = jnp.where(jnp.all(~jnp.isfinite(logp)), jnp.zeros_like(logp), logp)
        nxt = jax.random.categorical(key_t, logp)
        centers = centers.at[t + 1].set(x[nxt])
        return (centers, mind2, t + 1), None

    keys = jax.random.split(key, n_clusters - 1)
    (centers, _, _), _ = jax.lax.scan(
        step, (centers0, jnp.full((n,), jnp.inf, jnp.float32), 0), keys)
    return centers


@partial(jax.jit, static_argnames=("n_clusters", "max_iters"))
def _fit_once(x: Array, key: Array, *, n_clusters: int, max_iters: int):
    centers0 = _pp_init(x, key, n_clusters)

    def dists(centers):
        # ||x||^2 - 2 x.c + ||c||^2 ; first term constant for argmin but kept
        # so `cost` is the true inertia.
        return (jnp.sum(x * x, axis=1)[:, None]
                - 2.0 * x @ centers.T + jnp.sum(centers * centers, axis=1)[None])

    def body(carry):
        centers, _, changed, t = carry
        d = dists(centers)
        labels = jnp.argmin(d, axis=1)
        h = jax.nn.one_hot(labels, n_clusters, dtype=x.dtype)    # [n, C]
        counts = h.sum(axis=0)
        sums = h.T @ x                                           # [C, d]
        new = jnp.where(counts[:, None] > 0,
                        sums / jnp.maximum(counts, 1.0)[:, None], centers)
        changed = jnp.any(jnp.abs(new - centers) > 1e-7)
        return new, labels, changed, t + 1

    def cond(carry):
        _, _, changed, t = carry
        return jnp.logical_and(changed, t < max_iters)

    init = (centers0, jnp.zeros((x.shape[0],), jnp.int32), jnp.array(True), 0)
    centers, labels, _, t = jax.lax.while_loop(cond, body, init)
    d = dists(centers)
    labels = jnp.argmin(d, axis=1).astype(jnp.int32)
    cost = jnp.sum(jnp.min(d, axis=1))
    return KMeansResult(centers, labels, cost, t)


def kmeans(x, n_clusters: int, *, n_init: int = 5, max_iters: int = 300,
           seed: int = 0) -> KMeansResult:
    x = jnp.asarray(x, jnp.float32)
    best: KMeansResult | None = None
    for i in range(n_init):
        res = _fit_once(x, jax.random.PRNGKey(seed + i),
                        n_clusters=n_clusters, max_iters=max_iters)
        if best is None or float(res.cost) < float(best.cost):
            best = res
    assert best is not None
    return best
