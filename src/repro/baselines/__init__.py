from .lloyd import kmeans as lloyd_kmeans
from .sculley import sgd_minibatch_kmeans

__all__ = ["lloyd_kmeans", "sgd_minibatch_kmeans"]
