"""Sculley's web-scale SGD mini-batch k-means [9] — the paper's Fig.8
comparison baseline.

Per Sculley (WWW 2010): small mini-batches (~10^3), per-center learning rate
1/n_c where n_c counts every assignment ever made to center c, a fixed a-priori
number of iterations, centers updated by a gradient step toward each assigned
sample. This is the algorithm the paper argues against (noisier, no inner
convergence loop).
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


class SGDKMeansResult(NamedTuple):
    centers: Array
    labels: Array    # labels for the full dataset at the end
    cost: Array


@partial(jax.jit, static_argnames=())
def _sgd_step(centers: Array, counts: Array, xb: Array):
    d = (jnp.sum(xb * xb, axis=1)[:, None] - 2.0 * xb @ centers.T
         + jnp.sum(centers * centers, axis=1)[None])
    labels = jnp.argmin(d, axis=1)
    h = jax.nn.one_hot(labels, centers.shape[0], dtype=xb.dtype)   # [b, C]
    batch_counts = h.sum(axis=0)                                   # [C]
    new_counts = counts + batch_counts
    # per-center learning rate eta_c = batch_count_c / new_count_c gives the
    # exact streaming mean: c <- (1-eta) c + eta * batch_mean_c.
    batch_mean = (h.T @ xb) / jnp.maximum(batch_counts, 1.0)[:, None]
    eta = jnp.where(new_counts > 0, batch_counts / jnp.maximum(new_counts, 1.0), 0.0)
    centers = centers + eta[:, None] * (batch_mean - centers)
    return centers, new_counts


def sgd_minibatch_kmeans(x: np.ndarray, n_clusters: int, *,
                         batch_size: int = 1000, n_iters: int = 200,
                         seed: int = 0) -> SGDKMeansResult:
    rng = np.random.default_rng(seed)
    x = np.asarray(x, np.float32)
    init_idx = rng.choice(len(x), n_clusters, replace=False)
    centers = jnp.asarray(x[init_idx])
    counts = jnp.zeros((n_clusters,), jnp.float32)
    for _ in range(n_iters):
        idx = rng.integers(0, len(x), size=batch_size)
        centers, counts = _sgd_step(centers, counts, jnp.asarray(x[idx]))
    xj = jnp.asarray(x)
    d = (jnp.sum(xj * xj, axis=1)[:, None] - 2.0 * xj @ centers.T
         + jnp.sum(centers * centers, axis=1)[None])
    labels = jnp.argmin(d, axis=1).astype(jnp.int32)
    cost = jnp.sum(jnp.min(d, axis=1))
    return SGDKMeansResult(centers, labels, cost)
