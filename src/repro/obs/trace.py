"""Profiler spans over the named hot paths + on-demand trace dumps.

Two span flavors, matching where the work happens:

  ``span(name)``      -> ``jax.named_scope``: names the ops traced under it,
                         so the Gram panel build, engine stats, psum hooks
                         and embed/assign kernels show up as labelled
                         regions in a TensorBoard/XProf device trace. Free
                         at run time of an already-compiled program (the
                         scope only exists while tracing) and identical
                         with the recorder on or off — it cannot change a
                         lowered program.
  ``annotate(name)``  -> ``jax.profiler.TraceAnnotation``: marks HOST-side
                         activity (PrefetchLoader H2D staging, checkpoint
                         writes) on the profiler timeline.

``start_profile(logdir)`` / ``stop_profile()`` wrap
``jax.profiler.start_trace`` / ``stop_trace``: dump a TensorBoard-loadable
trace of a chosen window on demand (``tensorboard --logdir <dir>`` or
``xprof`` opens it). The launchers expose this as ``--profile <dir>``.
"""
from __future__ import annotations

import contextlib

import jax


def span(name: str):
    """Named scope for device-side work (see module docstring)."""
    return jax.named_scope(name)


def annotate(name: str, **kwargs):
    """Host-side profiler timeline annotation; no-op context manager when
    the running jax has no TraceAnnotation (very old CPU builds)."""
    ta = getattr(jax.profiler, "TraceAnnotation", None)
    if ta is None:
        return contextlib.nullcontext()
    return ta(name, **kwargs)


_active_logdir: str | None = None


def start_profile(logdir: str) -> None:
    """Begin capturing a profiler trace into ``logdir`` (idempotent —
    starting while active restarts nothing and keeps the first window)."""
    global _active_logdir
    if _active_logdir is not None:
        return
    jax.profiler.start_trace(logdir)
    _active_logdir = logdir


def stop_profile() -> str | None:
    """Stop the capture; returns the logdir the trace was written to
    (None when no capture was active)."""
    global _active_logdir
    if _active_logdir is None:
        return None
    out, _active_logdir = _active_logdir, None
    jax.profiler.stop_trace()
    return out
