"""Events manifest: the run header every JSONL log opens with, readers for
the log, and the fold into the ``results/BENCH_*.json`` perf trajectory.

The header pins the run to a code state and a machine (commit, backend,
device inventory, jax version) plus whatever the caller knows (the
``core.memory.plan`` dict, the benchmark name) — a log file is then
self-describing: no out-of-band context needed to interpret it.

``summarize`` reduces a log to per-name aggregates (count/total/mean/max
for timers, series and gauges; final totals for counters; the last
measured-vs-predicted watermark pair) — the compact form
``benchmarks.common.record_bench`` embeds into ``BENCH_<name>.json`` so
the perf trajectory carries measured costs, not just end-to-end wall time.
"""
from __future__ import annotations

import dataclasses
import json
import os
import subprocess
import time


def git_commit() -> str:
    try:
        out = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, timeout=10,
            cwd=os.path.dirname(os.path.abspath(__file__)))
        return out.stdout.strip() or "unknown"
    except Exception:
        return "unknown"


def run_header(**extra) -> dict:
    """First line of every flight-recorder log (see module docstring).
    ``extra`` may carry a plan (dataclasses are flattened to dicts)."""
    import jax
    devs = jax.devices()
    header = {
        "kind": "header",
        "t": time.time(),
        "commit": git_commit(),
        "jax": jax.__version__,
        "backend": jax.default_backend(),
        "device_kind": devs[0].device_kind if devs else "none",
        "n_devices": len(devs),
        "n_processes": jax.process_count(),
    }
    for k, v in extra.items():
        header[k] = dataclasses.asdict(v) if dataclasses.is_dataclass(v) \
            else v
    return header


def read_events(path: str) -> list[dict]:
    """All records of a JSONL log (header included)."""
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out


def summarize(path: str) -> dict:
    """Fold a log into per-name aggregates (see module docstring)."""
    stats: dict[str, dict] = {}
    counters: dict[str, float] = {}
    watermark = None
    n = 0
    for rec in read_events(path):
        n += 1
        kind = rec.get("kind")
        if kind == "counter":
            counters[rec["name"]] = rec.get("total", 0.0)
        elif kind in ("timer", "series", "gauge"):
            v = rec.get("seconds") if kind == "timer" else rec.get("value")
            if v is None:
                continue
            s = stats.setdefault(rec["name"], {"count": 0, "total": 0.0,
                                               "max": float("-inf")})
            s["count"] += 1
            s["total"] += v
            s["max"] = max(s["max"], v)
        elif kind == "event" and rec.get("name") == "hbm_watermark":
            watermark = {k: rec.get(k) for k in
                         ("measured_bytes", "peak_bytes", "predicted_bytes",
                          "source", "batch")}
    for s in stats.values():
        s["mean"] = s["total"] / max(s["count"], 1)
    return {"events": n, "stats": stats, "counters": counters,
            "last_watermark": watermark, "path": path}
