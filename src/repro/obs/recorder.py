"""MetricsRecorder contract + the two implementations.

``MetricsRecorder`` defines the vocabulary every instrumented layer speaks:

  counter(name, inc)      monotonically accumulating count (psums issued,
                          batches staged, checkpoints written)
  gauge(name, value)      instantaneous host scalar (queue depth, empty
                          clusters) — recorded immediately
  series(name, value)     per-iteration measurement; ``value`` MAY be a
                          live ``jax.Array`` — it is parked unconverted and
                          drained in one batched fetch at ``batch_boundary``
                          (never a mid-loop blocking sync)
  timer(name)             context manager measuring host wall seconds
  event(name, **fields)   structured one-off (straggler_detected, resume,
                          hbm_watermark)
  batch_boundary(batch)   drain deferred device scalars + flush the sink

``NullRecorder`` (singleton ``NULL``) is the zero-overhead default: every
hook is a no-op, ``timer`` returns a shared null context manager, and no
state is kept. ``JsonlRecorder`` appends one JSON object per record to a
file; it is thread-safe (the PrefetchLoader producer thread records stage
timings concurrently with the consumer loop) and buffers lines host-side,
flushing only at batch boundaries and on ``close``.

Nothing in this module imports jax at call time beyond ``device_get`` in
the drain — recorder hooks must stay cheap enough to leave on.

The ``collectives/*`` counters the mesh fit loops emit are derived from
the STATIC audit (``repro.analysis.collective_bill`` over the traced
inner program, cached per batch shape): per-iteration while-body counts x
realized ``n_iter`` + the audited outside-the-loop prologue sync. If that
trace-time audit ever fails, the loops fall back to the analytic
``collectives_per_iteration`` bill and emit an ``audit_error`` event with
the exception — billing must never take a fit down.
"""
from __future__ import annotations

import json
import os
import threading
import time
from typing import Optional


class _NullTimer:
    """Shared no-op context manager (``NullRecorder.timer``)."""
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_TIMER = _NullTimer()


class MetricsRecorder:
    """The contract (and the no-op base — see module docstring)."""

    enabled: bool = False

    def counter(self, name: str, inc: float = 1, **tags) -> None:
        pass

    def gauge(self, name: str, value, **tags) -> None:
        pass

    def series(self, name: str, value, **tags) -> None:
        pass

    def event(self, name: str, **fields) -> None:
        pass

    def timer(self, name: str, **tags):
        return _NULL_TIMER

    def batch_boundary(self, batch: int) -> None:
        pass

    def close(self) -> None:
        pass

    def __enter__(self) -> "MetricsRecorder":
        return self

    def __exit__(self, *exc) -> bool:
        self.close()
        return False


class NullRecorder(MetricsRecorder):
    """Zero-overhead default; every hook is a no-op."""


NULL = NullRecorder()


def resolve(recorder: Optional[MetricsRecorder]) -> MetricsRecorder:
    """The threading currency: ``recorder=None`` anywhere means ``NULL``."""
    return NULL if recorder is None else recorder


class _Timer:
    __slots__ = ("_rec", "_name", "_tags", "_t0", "seconds")

    def __init__(self, rec: "JsonlRecorder", name: str, tags: dict):
        self._rec = rec
        self._name = name
        self._tags = tags

    def __enter__(self):
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self.seconds = time.perf_counter() - self._t0
        self._rec._append(dict(kind="timer", name=self._name,
                               seconds=self.seconds, **self._tags))
        return False


class JsonlRecorder(MetricsRecorder):
    """Flight recorder writing one JSON object per line.

    ``header`` (see ``repro.obs.export.run_header``) is written as the
    first line so a log is self-describing: commit, backend, device
    inventory, plan. Counter increments are written as they happen AND
    accumulated into per-name totals (``totals``) for cheap end-of-run
    summaries. Deferred ``series`` values (live ``jax.Array``s) are parked
    in ``_pending`` and drained by ``batch_boundary`` with ONE
    ``jax.device_get`` over the whole list — the only place this class
    touches device values.
    """

    enabled = True

    def __init__(self, path: str, *, header: Optional[dict] = None):
        self.path = path
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        self._lock = threading.Lock()
        self._lines: list[dict] = []
        self._pending: list[dict] = []      # deferred device-valued series
        self.totals: dict[str, float] = {}
        self._file = open(path, "w")
        if header is not None:
            self._append(header)
            self._flush()

    # -- record vocabulary --------------------------------------------------

    def counter(self, name: str, inc: float = 1, **tags) -> None:
        with self._lock:
            self.totals[name] = self.totals.get(name, 0.0) + inc
        self._append(dict(kind="counter", name=name, inc=inc,
                          total=self.totals[name], **tags))

    def gauge(self, name: str, value, **tags) -> None:
        self._append(dict(kind="gauge", name=name, value=float(value),
                          **tags))

    def series(self, name: str, value, **tags) -> None:
        # a jax.Array stays a future here; plain floats are written now.
        if hasattr(value, "device") or hasattr(value, "devices"):
            with self._lock:
                self._pending.append(dict(kind="series", name=name,
                                          value=value, **tags))
            return
        self._append(dict(kind="series", name=name, value=float(value),
                          **tags))

    def event(self, name: str, **fields) -> None:
        self._append(dict(kind="event", name=name, **fields))

    def timer(self, name: str, **tags):
        return _Timer(self, name, tags)

    def batch_boundary(self, batch: int) -> None:
        """Drain deferred device scalars (one batched fetch) and flush."""
        with self._lock:
            pending, self._pending = self._pending, []
        if pending:
            import jax
            vals = jax.device_get([p["value"] for p in pending])
            for p, v in zip(pending, vals):
                p["value"] = float(v)
                self._append(p)
        self._append(dict(kind="boundary", batch=int(batch)))
        self._flush()

    # -- sink ---------------------------------------------------------------

    def _append(self, rec: dict) -> None:
        rec.setdefault("t", time.time())
        with self._lock:
            self._lines.append(rec)

    def _flush(self) -> None:
        with self._lock:
            lines, self._lines = self._lines, []
            if lines and self._file is not None:
                self._file.write("".join(
                    json.dumps(l, default=_jsonable) + "\n" for l in lines))
                self._file.flush()

    def close(self) -> None:
        if self._file is None:
            return
        self.batch_boundary(-1)     # final drain (marks end-of-run)
        with self._lock:
            self._file.close()
            self._file = None


def _jsonable(v):
    """json.dumps fallback: numpy / jax scalars and arrays -> python."""
    try:
        import numpy as np
        a = np.asarray(v)
        return a.item() if a.ndim == 0 else a.tolist()
    except Exception:
        return str(v)
