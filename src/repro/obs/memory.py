"""Live HBM watermarks, recorded next to the planner's predicted footprint.

``core.memory.plan`` prices every Gram residency / embedding method from a
static analytic byte model; nothing so far checked that model against the
allocator. ``watermark`` samples ``device.memory_stats()`` (bytes in use +
peak) on every local device at a mini-batch boundary and records it in the
SAME event as the predicted per-device bytes for that batch and mode — one
``hbm_watermark`` line per batch is exactly the measured-vs-predicted
dataset the self-tuning planner (ROADMAP) needs to calibrate on.

Backends without allocator stats (CPU jax returns ``memory_stats() ==
None``) fall back to the host's peak RSS (``resource.getrusage``), tagged
``source: "host_rss"`` so readers never mistake process memory for HBM.

``predicted_batch_footprint`` re-prices one mini-batch with the
``core.memory`` formulas at (n = batch rows, B = 1): the per-device bytes
the planner would claim for the exact engine mode / embedded method the
fit is actually running.
"""
from __future__ import annotations

from typing import Optional

from .recorder import MetricsRecorder


def device_memory_stats() -> list[dict]:
    """One dict per local device: ``{"device", "bytes_in_use",
    "peak_bytes_in_use"}``; empty list when no device reports stats."""
    import jax
    out = []
    for dev in jax.local_devices():
        stats = dev.memory_stats() if hasattr(dev, "memory_stats") else None
        if not stats:
            continue
        out.append({
            "device": f"{dev.platform}:{dev.id}",
            "bytes_in_use": int(stats.get("bytes_in_use", 0)),
            "peak_bytes_in_use": int(
                stats.get("peak_bytes_in_use",
                          stats.get("bytes_in_use", 0))),
        })
    return out


def host_rss_peak_bytes() -> Optional[int]:
    """Peak resident set size of this process (the CPU fallback)."""
    try:
        import resource
        peak = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
        # ru_maxrss is KiB on Linux, bytes on macOS.
        import sys
        return int(peak if sys.platform == "darwin" else peak * 1024)
    except Exception:
        return None


def watermark(recorder: MetricsRecorder, *, batch: int,
              predicted_bytes: Optional[float] = None, **tags) -> None:
    """Record one ``hbm_watermark`` event: measured allocator state on
    every local device next to the planner's predicted per-device bytes."""
    if not recorder.enabled:
        return                       # skip the stats syscalls entirely
    devs = device_memory_stats()
    if devs:
        measured = max(d["bytes_in_use"] for d in devs)
        peak = max(d["peak_bytes_in_use"] for d in devs)
        source = "device"
    else:
        measured = peak = host_rss_peak_bytes()
        source = "host_rss"
    recorder.event(
        "hbm_watermark", batch=int(batch), source=source,
        measured_bytes=measured, peak_bytes=peak,
        predicted_bytes=(float(predicted_bytes)
                         if predicted_bytes is not None else None),
        devices=devs, **tags)


def predicted_batch_footprint(cfg, n_rows: int, d: int, *,
                              n_devices: int = 1,
                              density: float = 1.0) -> float:
    """Planner-predicted per-device bytes for ONE mini-batch of ``n_rows``
    rows under ``cfg`` (a ``MiniBatchConfig``) — the static model the
    ``watermark`` events are diffed against.

    Exact method: ``engine_footprint_bytes`` at the fit's actual GramEngine
    mode; embedded methods: ``embed_footprint_bytes`` /
    ``sketch_footprint_bytes`` at the fit's m.
    """
    from repro.core import memory as cm

    c = cfg.n_clusters
    if cfg.method == "exact":
        from repro.core.engine import resolve_engine
        eng = resolve_engine(cfg.engine)
        return cm.engine_footprint_bytes(
            n_rows, 1, c, n_devices, s=cfg.s, d=d,
            mode=eng.mode, tile_rows=eng.tile_rows)
    m = cfg.embed_dim
    if not m:
        from repro.approx import default_embed_dim
        m = default_embed_dim(c)
    if cfg.method in ("sketch", "tensorsketch"):
        return cm.sketch_footprint_bytes(n_rows, 1, c, n_devices, m=m, d=d,
                                         density=density)
    return cm.embed_footprint_bytes(n_rows, 1, c, n_devices, m=m, d=d)


def predicted_embed_footprint(n_rows: int, c: int, fmap, *,
                              sparse: bool = False, density: float = 1.0,
                              n_devices: int = 1) -> Optional[float]:
    """Predicted per-device bytes of one embedded-space batch, priced from
    the live feature map (m = ``fmap.dim``, d = ``fmap.in_dim``) — what the
    embedded fit loops record next to their measured watermark. Sparse
    batches take the O(nnz) sketch pricing at the batch's density."""
    from repro.core import memory as cm

    m = getattr(fmap, "dim", 0)
    d = getattr(fmap, "in_dim", 0)
    if not m:
        return None
    if sparse:
        return cm.sketch_footprint_bytes(n_rows, 1, c, n_devices, m=m, d=d,
                                         density=density)
    return cm.embed_footprint_bytes(n_rows, 1, c, n_devices, m=m, d=d)
