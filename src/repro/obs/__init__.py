"""repro.obs — the flight recorder: jit-safe runtime metrics, profiler
spans, and HBM watermarks for the clustering runtime.

Three rules make it safe to leave on in production:

  1. The recorder NEVER crosses the jit boundary. Every hook is host-side,
     around already-jitted calls or on values those calls return — so
     enabling metrics cannot change a traced program or trigger a single
     extra compilation (tests/test_obs.py proves the lowered-program count
     is identical with the recorder on and off).
  2. Device scalars are DEFERRED, never synced mid-loop: ``series`` accepts
     live ``jax.Array`` values and parks them; ``batch_boundary`` drains
     all of them with one batched ``device_get`` at the mini-batch edge,
     where the host loop is about to block on the next dispatch anyway.
  3. The default is ``NullRecorder`` — every hook is a no-op attribute
     lookup, so uninstrumented runs pay nothing.

Contrast with ``repro.core.metrics``: that module scores clustering
*quality* (NMI, accuracy, elbow); this package records where the *runtime*
spends time and bytes. ``repro.obs.export.summarize`` folds a JSONL event
log into the ``results/BENCH_*.json`` perf trajectory
(``benchmarks.common.record_bench``) — the measured-cost substrate the
self-tuning planner consumes.
"""
from .recorder import (JsonlRecorder, MetricsRecorder, NullRecorder, NULL,
                       resolve)
from .trace import annotate, span, start_profile, stop_profile
from . import export, memory

__all__ = [
    "JsonlRecorder", "MetricsRecorder", "NullRecorder", "NULL", "resolve",
    "annotate", "span", "start_profile", "stop_profile", "export", "memory",
]
