"""Sharded numpy checkpointing with an atomic manifest + elastic restore.

Layout (one directory per step):

    <root>/step_000042.tmp/          # written first
        manifest.json                # pytree paths, shapes, dtypes
        <leafpath>.npy               # one file per leaf
    <root>/step_000042/              # atomic os.rename on completion

A restart can restore onto a DIFFERENT mesh (elastic scaling): arrays are
loaded host-side and ``device_put`` with the new NamedSharding reshards them.
On a real multi-host pod each host would write/read only its addressable
shards; the manifest format (leaf -> file) already supports per-shard files,
which keeps this compatible with that deployment (DESIGN.md §6).
Partially-written checkpoints (crash mid-save) are invisible: the .tmp dir
is never listed and is cleaned on the next save.
"""
from __future__ import annotations

import json
import os
import re
import shutil
from typing import Any, Optional

import jax
import ml_dtypes
import numpy as np

PyTree = Any

_STEP_RE = re.compile(r"^step_(\d{9})$")

# np.save stores ml_dtypes (bf16, fp8) as raw void bytes; the manifest dtype
# string lets restore view them back losslessly.
_CUSTOM_DTYPES = {
    "bfloat16": ml_dtypes.bfloat16,
    "float8_e4m3fn": ml_dtypes.float8_e4m3fn,
    "float8_e5m2": ml_dtypes.float8_e5m2,
}


def _revive_dtype(arr: np.ndarray, dtype_str: str) -> np.ndarray:
    if arr.dtype.kind == "V" and dtype_str in _CUSTOM_DTYPES:
        return arr.view(_CUSTOM_DTYPES[dtype_str])
    return arr


def _leaf_paths(tree: PyTree) -> list[tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        name = "/".join(_key_str(k) for k in path)
        out.append((name, leaf))
    return out


def _key_str(k) -> str:
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "idx"):
        return f"[{k.idx}]"
    return str(k)


class CheckpointManager:
    def __init__(self, root: str, *, keep: int = 3):
        self.root = root
        self.keep = keep
        os.makedirs(root, exist_ok=True)

    # -- write --------------------------------------------------------------

    def save(self, step: int, tree: PyTree, *, extra: dict | None = None):
        final = os.path.join(self.root, f"step_{step:09d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        manifest = {"step": step, "extra": extra or {}, "leaves": {}}
        for name, leaf in _leaf_paths(tree):
            arr = np.asarray(jax.device_get(leaf))
            fname = name.replace("/", "__") + ".npy"
            np.save(os.path.join(tmp, fname), arr)
            manifest["leaves"][name] = {
                "file": fname, "shape": list(arr.shape), "dtype": str(arr.dtype)}
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)                       # atomic commit
        self._gc()

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.root, f"step_{s:09d}"),
                          ignore_errors=True)

    # -- read ---------------------------------------------------------------

    def all_steps(self) -> list[int]:
        out = []
        for d in os.listdir(self.root):
            m = _STEP_RE.match(d)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, like: PyTree, *, shardings: PyTree = None
                ) -> PyTree:
        """Restore into the structure of ``like``; if ``shardings`` (a
        matching tree of jax.sharding.Sharding) is given, device_put each
        leaf with it — this is the elastic re-shard path."""
        d = os.path.join(self.root, f"step_{step:09d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        leaves_meta = manifest["leaves"]

        flat, treedef = jax.tree_util.tree_flatten_with_path(like)
        shard_flat = None
        if shardings is not None:
            shard_flat = treedef.flatten_up_to(shardings)
        out = []
        for i, (path, leaf) in enumerate(flat):
            name = "/".join(_key_str(k) for k in path)
            meta = leaves_meta[name]
            arr = _revive_dtype(np.load(os.path.join(d, meta["file"])),
                                meta["dtype"])
            if shard_flat is not None:
                out.append(jax.device_put(arr, shard_flat[i]))
            else:
                out.append(jax.device_put(arr))
        return treedef.unflatten(out)

    def extra(self, step: int) -> dict:
        d = os.path.join(self.root, f"step_{step:09d}")
        with open(os.path.join(d, "manifest.json")) as f:
            return json.load(f)["extra"]
