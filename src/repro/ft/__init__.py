from .checkpoint import CheckpointManager
from .elastic import ElasticClusteringRunner
from .straggler import WorkerStatus, replan_rows

__all__ = ["CheckpointManager", "ElasticClusteringRunner", "WorkerStatus",
           "replan_rows"]
