"""Straggler mitigation for the clustering runtime.

Between mini-batches the only live state is O(C*d) (medoids + cardinalities),
so re-partitioning work is nearly free. The planner assigns each worker a row
range proportional to its measured throughput; dead workers get nothing and
their rows are redistributed (the paper's row-wise layout makes this a pure
index calculation — no data migration of K, which is recomputed per batch
anyway).

``StragglerMonitor`` is the live wiring: the distributed outer loop feeds
it per-worker mini-batch timings after every batch, it keeps a rolling
throughput estimate per worker, and when one worker falls past the median
threshold it emits a ``straggler_detected`` event through the flight
recorder (``repro.obs``) carrying the per-worker timings and the row
replan that would absorb the skew. On a single-controller mesh all devices
run one program, so the timing unit is the *process* (the unit
``replan_rows`` re-partitions); a multi-host pod contributes one timing
per host.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class WorkerStatus:
    worker_id: int
    healthy: bool = True
    rows_per_second: float = 1.0   # measured on the previous mini-batch


def replan_rows(n_rows: int, statuses: list[WorkerStatus], *,
                quantum: int = 8) -> dict[int, tuple[int, int]]:
    """-> {worker_id: (row_start, n_rows)}; proportional to throughput,
    quantized to ``quantum`` rows (tile alignment), exact cover of n_rows."""
    alive = [s for s in statuses if s.healthy]
    if not alive:
        raise RuntimeError("no healthy workers")
    speed = np.array([max(s.rows_per_second, 1e-9) for s in alive])
    frac = speed / speed.sum()
    sizes = np.floor(frac * n_rows / quantum).astype(int) * quantum
    # distribute the remainder to the fastest workers, quantum at a time
    rem = n_rows - sizes.sum()
    order = np.argsort(-speed)
    i = 0
    while rem >= quantum:
        sizes[order[i % len(alive)]] += quantum
        rem -= quantum
        i += 1
    if rem:
        sizes[order[0]] += rem
    plan = {}
    start = 0
    for s, sz in zip(alive, sizes):
        plan[s.worker_id] = (start, int(sz))
        start += int(sz)
    assert start == n_rows
    return plan


def detect_stragglers(batch_seconds: dict[int, float], *,
                      threshold: float = 1.5) -> list[int]:
    """Workers slower than ``threshold`` x median are flagged."""
    if not batch_seconds:
        return []
    med = float(np.median(list(batch_seconds.values())))
    return [w for w, t in batch_seconds.items() if t > threshold * med]


class StragglerMonitor:
    """Per-batch straggler watch, reporting through the flight recorder.

    ``observe(batch, timings, n_rows)`` takes this batch's per-worker wall
    seconds; every call records a ``batch_timing`` event and updates the
    rolling ``WorkerStatus`` throughputs (EWMA over ``decay``). When
    ``detect_stragglers`` flags anyone, a ``straggler_detected`` event is
    emitted with the timings and — when ``n_rows`` is known — the
    ``replan_rows`` partition that would rebalance the next batch. Returns
    the flagged worker ids so a driver can act on them.
    """

    def __init__(self, recorder=None, *, threshold: float = 1.5,
                 decay: float = 0.5, quantum: int = 8):
        from repro.obs import resolve
        self.rec = resolve(recorder)
        self.threshold = threshold
        self.decay = decay
        self.quantum = quantum
        self.statuses: dict[object, WorkerStatus] = {}

    def observe(self, batch: int, timings: dict[object, float],
                n_rows: int | None = None) -> list:
        if not timings:
            return []
        rows_each = (n_rows / max(len(timings), 1)) if n_rows else None
        for w, dt in timings.items():
            rps = (rows_each / max(dt, 1e-9)) if rows_each else \
                1.0 / max(dt, 1e-9)
            st = self.statuses.get(w)
            if st is None:
                self.statuses[w] = WorkerStatus(worker_id=w,
                                                rows_per_second=rps)
            else:
                st.rows_per_second = (self.decay * rps
                                      + (1.0 - self.decay)
                                      * st.rows_per_second)
        self.rec.event("batch_timing", batch=int(batch),
                       timings={str(k): v for k, v in timings.items()})
        slow = detect_stragglers(timings, threshold=self.threshold)
        if slow:
            replan = None
            if n_rows and len(self.statuses) > 1:
                plan = replan_rows(
                    int(n_rows - n_rows % self.quantum) or self.quantum,
                    list(self.statuses.values()), quantum=self.quantum)
                replan = {str(k): v for k, v in plan.items()}
            self.rec.event(
                "straggler_detected", batch=int(batch),
                stragglers=[str(w) for w in slow],
                timings={str(k): v for k, v in timings.items()},
                replan=replan)
        return slow
