"""Straggler mitigation for the clustering runtime.

Between mini-batches the only live state is O(C*d) (medoids + cardinalities),
so re-partitioning work is nearly free. The planner assigns each worker a row
range proportional to its measured throughput; dead workers get nothing and
their rows are redistributed (the paper's row-wise layout makes this a pure
index calculation — no data migration of K, which is recomputed per batch
anyway).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class WorkerStatus:
    worker_id: int
    healthy: bool = True
    rows_per_second: float = 1.0   # measured on the previous mini-batch


def replan_rows(n_rows: int, statuses: list[WorkerStatus], *,
                quantum: int = 8) -> dict[int, tuple[int, int]]:
    """-> {worker_id: (row_start, n_rows)}; proportional to throughput,
    quantized to ``quantum`` rows (tile alignment), exact cover of n_rows."""
    alive = [s for s in statuses if s.healthy]
    if not alive:
        raise RuntimeError("no healthy workers")
    speed = np.array([max(s.rows_per_second, 1e-9) for s in alive])
    frac = speed / speed.sum()
    sizes = np.floor(frac * n_rows / quantum).astype(int) * quantum
    # distribute the remainder to the fastest workers, quantum at a time
    rem = n_rows - sizes.sum()
    order = np.argsort(-speed)
    i = 0
    while rem >= quantum:
        sizes[order[i % len(alive)]] += quantum
        rem -= quantum
        i += 1
    if rem:
        sizes[order[0]] += rem
    plan = {}
    start = 0
    for s, sz in zip(alive, sizes):
        plan[s.worker_id] = (start, int(sz))
        start += int(sz)
    assert start == n_rows
    return plan


def detect_stragglers(batch_seconds: dict[int, float], *,
                      threshold: float = 1.5) -> list[int]:
    """Workers slower than ``threshold`` x median are flagged."""
    if not batch_seconds:
        return []
    med = float(np.median(list(batch_seconds.values())))
    return [w for w, t in batch_seconds.items() if t > threshold * med]
