"""Elastic execution of the clustering outer loop.

The mini-batch boundary is the natural failure/rescale domain: the global
state is O(C*d) and mesh-independent, and the memory plan (Eq.19) is a pure
function of (N, C, P, R) — so on any mesh change we re-plan B and resume from
the last committed checkpoint, losing at most one mini-batch of work.
"""
from __future__ import annotations

from typing import Callable, Iterable, Optional

import jax
import numpy as np
from jax.sharding import Mesh

from repro.core.minibatch import FitResult, GlobalState, MiniBatchConfig
from repro.distributed.outer import DistributedMiniBatchKMeans

from .checkpoint import CheckpointManager


class ElasticClusteringRunner:
    def __init__(self, cfg: MiniBatchConfig, ckpt: CheckpointManager, *,
                 mode: str = "materialize"):
        self.cfg = cfg
        self.ckpt = ckpt
        self.mode = mode

    def _restore(self) -> Optional[GlobalState]:
        step = self.ckpt.latest_step()
        if step is None:
            return None
        like = GlobalState(
            medoids=np.zeros((1,)), medoid_diag=np.zeros((1,)),
            cardinalities=np.zeros((1,)), batches_done=np.zeros((), np.int32))
        # shapes come from the manifest; ``like`` only fixes the structure.
        return GlobalState(*self.ckpt.restore(step, like))

    def run(self, mesh: Mesh, batches: Iterable[np.ndarray], *,
            fail_after: Optional[int] = None) -> FitResult:
        """Run (or resume) on ``mesh``. ``fail_after=k`` injects a simulated
        failure after k mini-batches (tests / chaos drills)."""
        state = self._restore()
        start = int(state.batches_done) if state is not None else 0

        def cb(s: GlobalState, i: int):
            self.ckpt.save(i, s, extra={"n_batches": self.cfg.n_batches,
                                        "s": self.cfg.s})

        runner = DistributedMiniBatchKMeans(mesh, self.cfg, mode=self.mode)
        it = iter(batches)
        # skip already-committed batches on resume
        for _ in range(start):
            next(it)

        if fail_after is not None:
            consumed = []
            for i, b in enumerate(it):
                consumed.append(b)
                if i + 1 >= fail_after:
                    break
            result = runner.fit(consumed, state=state, checkpoint_cb=cb)
            raise SimulatedFailure(result)
        return runner.fit(it, state=state, checkpoint_cb=cb)


class SimulatedFailure(RuntimeError):
    def __init__(self, partial: FitResult):
        super().__init__("injected failure")
        self.partial = partial
