"""Elastic execution of the clustering outer loop.

The mini-batch boundary is the natural failure/rescale domain: the global
state is O(C*d) (exact) or O(C*m) (embedded) and mesh-independent, and the
memory plan (Eq.19) is a pure function of (N, C, P, R) — so on any mesh
change we re-plan B and resume from the last committed checkpoint, losing
at most one mini-batch of work.

Works on a live stream: ``run`` accepts any batch iterable or a
``repro.data.BatchSource``; on resume the committed prefix is skipped
host-side (never staged), and the source is closed on every exit path so
the prefetch producer thread survives neither a failure nor a re-mesh.

Embedded methods (``cfg.method != "exact"``) checkpoint the sampled feature
map next to the ``EmbedState`` — the map is part of the model, and a
restart (possibly on a different mesh) must embed with bit-identical
parameters or the resumed stream diverges. The landmark-selection strategy
(``cfg.selector``) is recorded in the manifest; a streaming selection
pre-pass (``repro.approx.selectors.select_streaming``) checkpoints its
``SelectorState`` pytree through the same ``CheckpointManager``, so a
restart mid-selection folds the remaining batches and re-selects
bit-identically (selector draws are fold_in-keyed per global row, never
per process).
"""
from __future__ import annotations

from typing import Iterable, Optional

import jax
import numpy as np
from jax.sharding import Mesh

from repro.approx.embed_kmeans import EmbedState
from repro.core.minibatch import FitResult, GlobalState, MiniBatchConfig
from repro.data.loader import BatchSource, closing_source
from repro.distributed.embed import DistributedEmbedKMeans
from repro.distributed.outer import DistributedMiniBatchKMeans

from .checkpoint import CheckpointManager


class ElasticClusteringRunner:
    def __init__(self, cfg: MiniBatchConfig, ckpt: CheckpointManager, *,
                 mode: object = None, prefetch: int = 0, recorder=None):
        """``mode`` overrides the exact inner loop's GramEngine; default
        None defers to ``cfg.engine`` (the planner-threaded pick) — an
        elastic restart must not silently demote a tiled/fused plan back
        to the resident-block layout. ``recorder`` (``repro.obs``) is
        threaded into the mesh runner and the checkpoint callback, so a
        flight-recorder log shows every ``elastic/resume`` and
        ``elastic/checkpoint`` next to the per-batch metrics."""
        from repro.obs import resolve
        self.cfg = cfg
        self.ckpt = ckpt
        self.mode = mode
        self.prefetch = prefetch
        self.rec = resolve(recorder)

    # -- checkpoint structure ------------------------------------------------

    def _fmap_like(self, extra: dict):
        """Structural twin of the checkpointed feature map: same pytree
        treedef (aux data incl. m comes from cfg + the manifest extra), leaf
        values irrelevant — ``CheckpointManager.restore`` only keeps the
        structure and reloads every leaf from disk. (The landmark selector
        does not change the NystromMap structure, so the twin is built with
        the default uniform selection; the checkpointed leaves carry the
        actually-selected landmarks.)"""
        from repro import approx
        m, d = int(extra["m"]), int(extra["d"])
        sample = np.zeros((max(m, 2), d), np.float32)
        return approx.make_feature_map(
            self.cfg.method, jax.random.PRNGKey(0), sample, m,
            self.cfg.kernel, orthogonal=self.cfg.rff_orthogonal)

    def _restore(self):
        """-> (state | None, fmap | None) from the latest committed step."""
        step = self.ckpt.latest_step()
        if step is None:
            return None, None
        if self.cfg.method == "exact":
            like = GlobalState(
                medoids=np.zeros((1,)), medoid_diag=np.zeros((1,)),
                cardinalities=np.zeros((1,)),
                batches_done=np.zeros((), np.int32))
            # shapes come from the manifest; ``like`` only fixes structure.
            return GlobalState(*self.ckpt.restore(step, like)), None
        like = {
            "state": EmbedState(
                centroids=np.zeros((1,)), cardinalities=np.zeros((1,)),
                batches_done=np.zeros((), np.int32)),
            "fmap": self._fmap_like(self.ckpt.extra(step)),
        }
        got = self.ckpt.restore(step, like)
        return EmbedState(*got["state"]), got["fmap"]

    # -- driver --------------------------------------------------------------

    def run(self, mesh: Mesh, batches: Iterable, *,
            fail_after: Optional[int] = None) -> FitResult:
        """Run (or resume) on ``mesh``. ``fail_after=k`` injects a simulated
        failure after k mini-batches (tests / chaos drills)."""
        state, fmap = self._restore()
        start = int(state.batches_done) if state is not None else 0
        cfg = self.cfg
        rec = self.rec
        rec.event("elastic/resume", start_batch=start,
                  resumed=state is not None, method=cfg.method,
                  mesh_shape={k: int(v) for k, v in mesh.shape.items()})

        if cfg.method == "exact":
            runner = DistributedMiniBatchKMeans(mesh, cfg, mode=self.mode,
                                                recorder=rec)

            def cb(s, i: int):
                self.ckpt.save(i, s, extra={"n_batches": cfg.n_batches,
                                            "s": cfg.s})
                rec.event("elastic/checkpoint", batch=i)
        else:
            runner = DistributedEmbedKMeans(mesh, cfg, fmap=fmap,
                                            recorder=rec)

            def cb(s, i: int):
                from repro.approx.selectors import name_of
                fm = runner.fmap
                self.ckpt.save(i, {"state": s, "fmap": fm},
                               extra={"n_batches": cfg.n_batches,
                                      "s": cfg.s, "method": cfg.method,
                                      "m": fm.dim, "d": fm.in_dim,
                                      "selector": name_of(cfg.selector)})
                rec.event("elastic/checkpoint", batch=i)

        if isinstance(batches, BatchSource):
            src = batches
        else:
            # prefetch staging: mesh-aware for the embedded runner (H2D
            # lands pre-sharded); host-identity for the exact runner, whose
            # fit stages its own rows — the loader default would bounce
            # every batch through the default device and back.
            stage = runner.stage if cfg.method != "exact" else (lambda b: b)
            src = BatchSource(batches, prefetch=self.prefetch, stage=stage)
        src.skip(start)     # committed prefix: dropped host-side, not staged
        with closing_source(src):
            if fail_after is not None:
                consumed = []
                for i, b in enumerate(src):
                    consumed.append(b)
                    if i + 1 >= fail_after:
                        break
                result = runner.fit(consumed, state=state, checkpoint_cb=cb)
                raise SimulatedFailure(result)
            return runner.fit(src, state=state, checkpoint_cb=cb)


class SimulatedFailure(RuntimeError):
    def __init__(self, partial: FitResult):
        super().__init__("injected failure")
        self.partial = partial
