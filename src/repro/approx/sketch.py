"""Data-oblivious sketch feature maps: count-sketch and TensorSketch.

Third knob next to the paper's (B, s) and PR 1's sampled maps (RFF/Nystrom):
*sketching* per Chitta et al. (Approximate Kernel k-means) and Pham & Pagh
(Fast and scalable polynomial kernels via explicit feature maps).

Count-sketch (a.k.a. feature hashing / sparse JL) for the **linear** kernel:
with a uniform bucket hash ``h: [d] -> [m]`` and Rademacher signs
``s: [d] -> {+-1}``,

    z(x)_j = sum_{i : h(i) = j} s_i x_i          z: R^d -> R^m

satisfies ``E[z(x) . z(y)] = x . y`` with variance O(|x|^2 |y|^2 / m).
Crucially z touches only the *nonzero* coordinates of x — on a CSR batch the
application is O(nnz), independent of d, which is what opens RCV1-style
high-dimensional sparse workloads (d ~ 50k, ~100 nnz/row) to the embedded
mini-batch path: the dense RFF projection would need the [n, d] batch
materialized and an O(n d m) matmul.

TensorSketch for the **polynomial** kernel ``(gamma x.y + coef0)^p``: sketch
the degree-p tensor product implicitly by count-sketching the augmented
input ``x' = [sqrt(gamma) x, sqrt(coef0)]`` with p independent hash pairs
and convolving in Fourier space,

    z(x) = IFFT( prod_k FFT(CS_k(x')) )          E[z(x).z(y)] = (x'.y')^p

(O(p (nnz + m log m)) per row — still free of d).

Both maps implement the FeatureMap contract (``dim``, ``in_dim``,
``__call__`` accepting dense rows or a ``repro.data.sparse.CSRBatch``,
pytree registration) so they flow unchanged through
``MiniBatchConfig(method="sketch"|"tensorsketch")``, the embedded driver,
``FitResult.predict`` and the row-sharded distributed path.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.core.kernels import KernelSpec
from repro.data.sparse import CSRBatch, is_sparse, row_ids

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class CountSketchMap:
    """Frozen count-sketch: z(x)_j = sum_{i: h_i = j} sign_i * x_i."""

    h: Array          # [d] int32 bucket per input coordinate
    sign: Array       # [d] f32 Rademacher signs
    m: int            # embedding dim (static: h holds values, not shape)

    @property
    def dim(self) -> int:
        return self.m

    @property
    def in_dim(self) -> int:
        return self.h.shape[0]

    def __call__(self, x) -> Array:
        if is_sparse(x):
            return count_sketch_features_csr(x, self)
        return count_sketch_features(jnp.asarray(x), self)


@dataclasses.dataclass(frozen=True)
class TensorSketchMap:
    """Frozen TensorSketch for ``(gamma x.y + coef0)^degree``.

    ``hs``/``signs`` are [degree, d+1]: one independent count-sketch per
    polynomial factor, the trailing column sketching the constant
    ``sqrt(coef0)`` coordinate of the augmented input.
    """

    hs: Array         # [p, d+1] int32
    signs: Array      # [p, d+1] f32
    m: int
    degree: int
    gamma: float
    coef0: float

    @property
    def dim(self) -> int:
        return self.m

    @property
    def in_dim(self) -> int:
        return self.hs.shape[1] - 1

    def __call__(self, x) -> Array:
        if is_sparse(x):
            return tensor_sketch_features_csr(x, self)
        return tensor_sketch_features(jnp.asarray(x), self)


def make_count_sketch(key: Array, d: int, m: int,
                      spec: KernelSpec) -> CountSketchMap:
    """Sample an m-bucket count-sketch over R^d for the linear kernel.

    Count-sketch preserves *inner products*; for any other kernel it would
    silently approximate the wrong Gram matrix (gate, like RFF does for
    non-rbf kernels).
    """
    if spec.name != "linear":
        raise ValueError(
            f"count-sketch approximates the linear kernel; got {spec.name!r} "
            "(use method='tensorsketch' for polynomial, 'rff'/'nystrom' "
            "for rbf)")
    if m < 1:
        raise ValueError(f"embedding dim m must be >= 1, got {m}")
    k_h, k_s = jax.random.split(key)
    h = jax.random.randint(k_h, (d,), 0, m, jnp.int32)
    sign = jax.random.rademacher(k_s, (d,), jnp.int32).astype(jnp.float32)
    return CountSketchMap(h=h, sign=sign, m=m)


def make_tensor_sketch(key: Array, d: int, m: int,
                       spec: KernelSpec) -> TensorSketchMap:
    """Sample a degree-``spec.degree`` TensorSketch over R^d.

    Requires the polynomial kernel with ``gamma > 0`` and ``coef0 >= 0``
    (the augmentation uses their square roots).
    """
    if spec.name != "polynomial":
        raise ValueError(
            f"TensorSketch approximates the polynomial kernel; got "
            f"{spec.name!r}")
    if spec.gamma <= 0 or spec.coef0 < 0:
        raise ValueError(
            f"TensorSketch needs gamma > 0 and coef0 >= 0, got "
            f"gamma={spec.gamma}, coef0={spec.coef0}")
    if m < 1:
        raise ValueError(f"embedding dim m must be >= 1, got {m}")
    if spec.degree < 1:
        raise ValueError(f"polynomial degree must be >= 1, got {spec.degree}")
    k_h, k_s = jax.random.split(key)
    p = spec.degree
    hs = jax.random.randint(k_h, (p, d + 1), 0, m, jnp.int32)
    signs = jax.random.rademacher(k_s, (p, d + 1), jnp.int32
                                  ).astype(jnp.float32)
    return TensorSketchMap(hs=hs, signs=signs, m=m, degree=p,
                           gamma=spec.gamma, coef0=spec.coef0)


# ---------------------------------------------------------------------------
# application — dense [n, d] rows
# ---------------------------------------------------------------------------


@jax.jit
def count_sketch_features(x: Array, fmap: CountSketchMap) -> Array:
    """z(X) -> [n, m] f32: one scatter-add over the d columns."""
    signed = x.astype(jnp.float32) * fmap.sign[None, :]
    return jax.ops.segment_sum(signed.T, fmap.h,
                               num_segments=fmap.dim).T


def _stage_sketch_dense(x: Array, h: Array, sign: Array, m: int) -> Array:
    return jax.ops.segment_sum((x * sign[None, :]).T, h, num_segments=m).T


@jax.jit
def tensor_sketch_features(x: Array, fmap: TensorSketchMap) -> Array:
    """z(X) -> [n, m] f32 via the FFT convolution of per-factor sketches."""
    n = x.shape[0]
    x_aug = jnp.concatenate(
        [x.astype(jnp.float32) * math.sqrt(fmap.gamma),
         jnp.full((n, 1), math.sqrt(fmap.coef0), jnp.float32)], axis=1)
    prod = None
    for k in range(fmap.degree):
        cs = _stage_sketch_dense(x_aug, fmap.hs[k], fmap.signs[k], fmap.dim)
        f = jnp.fft.fft(cs, axis=1)
        prod = f if prod is None else prod * f
    return jnp.real(jnp.fft.ifft(prod, axis=1)).astype(jnp.float32)


# ---------------------------------------------------------------------------
# application — CSR batches, O(nnz)
# ---------------------------------------------------------------------------


@jax.jit
def count_sketch_features_csr(batch: CSRBatch, fmap: CountSketchMap) -> Array:
    """z(X) -> [n, m] f32 touching only the stored nonzeros.

    Each stored value lands in one output slot ``(row, h[col])`` — a single
    flat scatter-add of nnz values; nothing scales with d.
    """
    n = batch.shape[0]
    m = fmap.dim
    data = jnp.asarray(batch.data).astype(jnp.float32)
    cols = jnp.asarray(batch.indices)
    rows = row_ids(batch)
    vals = data * fmap.sign[cols]
    flat = rows * m + fmap.h[cols]
    z = jnp.zeros((n * m,), jnp.float32).at[flat].add(vals)
    return z.reshape(n, m)


@jax.jit
def tensor_sketch_features_csr(batch: CSRBatch,
                               fmap: TensorSketchMap) -> Array:
    """z(X) -> [n, m] f32; O(p * (nnz + n m log m)), free of d.

    The constant sqrt(coef0) coordinate of the augmented input is dense in
    every row — added as a rank-1 one-hot after the sparse scatter.
    """
    n = batch.shape[0]
    m = fmap.dim
    d = fmap.in_dim
    data = jnp.asarray(batch.data).astype(jnp.float32)
    cols = jnp.asarray(batch.indices)
    rows = row_ids(batch)
    scaled = data * math.sqrt(fmap.gamma)
    prod = None
    for k in range(fmap.degree):
        vals = scaled * fmap.signs[k, cols]
        flat = rows * m + fmap.hs[k, cols]
        cs = jnp.zeros((n * m,), jnp.float32).at[flat].add(vals).reshape(n, m)
        const = (fmap.signs[k, d] * math.sqrt(fmap.coef0)
                 * jax.nn.one_hot(fmap.hs[k, d], m, dtype=jnp.float32))
        cs = cs + const[None, :]
        f = jnp.fft.fft(cs, axis=1)
        prod = f if prod is None else prod * f
    return jnp.real(jnp.fft.ifft(prod, axis=1)).astype(jnp.float32)


jax.tree_util.register_pytree_node(
    CountSketchMap,
    lambda f: ((f.h, f.sign), f.m),
    lambda m, leaves: CountSketchMap(h=leaves[0], sign=leaves[1], m=m),
)

jax.tree_util.register_pytree_node(
    TensorSketchMap,
    lambda f: ((f.hs, f.signs), (f.m, f.degree, f.gamma, f.coef0)),
    lambda aux, leaves: TensorSketchMap(hs=leaves[0], signs=leaves[1],
                                        m=aux[0], degree=aux[1],
                                        gamma=aux[2], coef0=aux[3]),
)
