"""Explicit feature maps that turn kernel k-means into linear k-means.

Every map obeys one FeatureMap contract — ``dim`` (embedding width m),
``in_dim`` (d), ``__call__`` (rows -> [n, m] f32) and pytree registration —
so the embedded mini-batch driver, ``FitResult.predict``, the fused Pallas
kernels and the row-sharded distributed path are map-agnostic; dispatch
happens in ``repro.core.minibatch`` via ``MiniBatchConfig.method``.

Choosing a method
-----------------
* ``exact`` — the paper's medoid algorithm; any Mercer kernel, no
  approximation beyond (B, s). Kernel evaluations cost O(s (N/B)^2) per
  batch: the right choice when batches are small or the kernel is exotic.
* ``rff`` (+ ``rff_orthogonal=True``) — random Fourier features, **rbf
  only**; O(n d m) dense projection, error O(1/sqrt(m)) independent of the
  data. Use for dense mid-dimensional rbf workloads (images, trajectories).
* ``nystrom`` — landmark embedding, **any Mercer kernel**, exact on the
  landmark subspace, error tracks the kernel's spectral decay; costs an
  [m, m] eigendecomposition up front plus O(n m) kernel evaluations per
  batch. Best accuracy-per-m on smooth kernels; the only embedded choice
  for non-rbf, non-polynomial kernels. Landmark choice is a strategy
  (``repro.approx.selectors``): ``selector="rls"`` ridge-leverage-score
  sampling buys more accuracy for the same m bytes than the default
  uniform sample.
* ``sketch`` — count-sketch / feature hashing, **linear kernel**; applying
  it touches only nonzero coordinates, so on CSR batches
  (``repro.data.sparse``) the embedding is O(nnz) — independent of d. The
  sparse path wins whenever d is huge and rows are sparse (RCV1-style text:
  d ~ 50k, ~100 nnz/row) where even materializing the dense batch is the
  bottleneck; the map itself stores two O(d) integer tables, vs the O(m d)
  dense RFF frequency matrix.
* ``tensorsketch`` — Pham-Pagh FFT composition of count-sketches,
  **polynomial kernel** ``(gamma x.y + coef0)^degree``; O(p (nnz + m log m))
  per row, also d-free. The only embedded polynomial map that never forms
  the degree-p tensor product.

``core.memory.plan`` compares the kernel-block, dense-embedded and sketch
footprints and names the cheapest method for a workload.
"""
from __future__ import annotations

import jax

from repro.core.kernels import KernelSpec

from .embed_kmeans import (EmbedInnerResult, EmbedState, assign_embedded,
                           fit_embedded, lloyd_fit, predict_embedded)
from .nystrom import (NystromMap, make_nystrom, nystrom_features,
                      nystrom_from_landmarks, whiten_gram)
from .rff import RFFMap, make_rff, rff_features
from .selectors import (KPPSelector, LandmarkSelector, RLSSelector,
                        SelectorState, UniformSelector, select_streaming)
from . import selectors
from .sketch import (CountSketchMap, TensorSketchMap, count_sketch_features,
                     count_sketch_features_csr, make_count_sketch,
                     make_tensor_sketch, tensor_sketch_features,
                     tensor_sketch_features_csr)

METHODS = ("rff", "nystrom", "sketch", "tensorsketch")


def default_embed_dim(n_clusters: int) -> int:
    """m = 4*C — the smallest m at which both maps reliably recover the
    exact clustering on separable data (tests/test_approx.py pins this)."""
    return 4 * n_clusters


def make_feature_map(method: str, key: jax.Array, x_sample, m: int,
                     spec: KernelSpec, *, orthogonal: bool = False,
                     selector=None):
    """Build a feature map from a data sample (first mini-batch).

    ``x_sample`` may be dense [n, d] or a ``repro.data.sparse.CSRBatch``;
    the data-oblivious sketch maps only read its column count, while
    RFF/Nystrom need dense rows (Nystrom gathers landmark rows, RFF the
    feature dim) — a sparse sample is rejected for those.

    ``selector`` (a ``repro.approx.selectors`` name or instance) picks the
    landmark rows for ``nystrom``; the other maps have no landmarks, so a
    non-uniform selector with them is rejected rather than ignored.
    """
    from repro.data.sparse import is_sparse

    from .selectors import name_of

    if method != "nystrom" and name_of(selector) != "uniform":
        raise ValueError(
            f"selector {name_of(selector)!r} only applies to landmark-based "
            f"maps (method 'nystrom', or the exact path); method {method!r} "
            "is data-oblivious")
    d = x_sample.shape[1]
    if method == "sketch":
        return make_count_sketch(key, d, m, spec)
    if method == "tensorsketch":
        return make_tensor_sketch(key, d, m, spec)
    if is_sparse(x_sample):
        raise ValueError(
            f"method {method!r} needs dense samples; only the sketch maps "
            "('sketch' | 'tensorsketch') accept CSR batches")
    if method == "rff":
        return make_rff(key, d, m, spec, orthogonal=orthogonal)
    if method == "nystrom":
        return make_nystrom(key, x_sample, m, spec, selector=selector)
    raise ValueError(f"unknown feature-map method {method!r}; have {METHODS}")


__all__ = [
    "METHODS", "default_embed_dim", "make_feature_map",
    "RFFMap", "make_rff", "rff_features",
    "NystromMap", "make_nystrom", "nystrom_features",
    "nystrom_from_landmarks", "whiten_gram",
    "selectors", "LandmarkSelector", "SelectorState", "UniformSelector",
    "RLSSelector", "KPPSelector", "select_streaming",
    "CountSketchMap", "make_count_sketch", "count_sketch_features",
    "count_sketch_features_csr",
    "TensorSketchMap", "make_tensor_sketch", "tensor_sketch_features",
    "tensor_sketch_features_csr",
    "EmbedState", "EmbedInnerResult", "assign_embedded", "fit_embedded",
    "lloyd_fit", "predict_embedded",
]
