# Explicit low-rank feature maps (RFF / Nystrom) that turn kernel k-means
# into linear k-means in an m-dimensional embedded space — the second
# accuracy/velocity knob next to the paper's (B, s). See DESIGN notes in
# each module; dispatch happens in repro.core.minibatch via cfg.method.
from __future__ import annotations

import jax

from repro.core.kernels import KernelSpec

from .embed_kmeans import (EmbedInnerResult, EmbedState, assign_embedded,
                           fit_embedded, lloyd_fit, predict_embedded)
from .nystrom import NystromMap, make_nystrom, nystrom_features
from .rff import RFFMap, make_rff, rff_features

METHODS = ("rff", "nystrom")


def default_embed_dim(n_clusters: int) -> int:
    """m = 4*C — the smallest m at which both maps reliably recover the
    exact clustering on separable data (tests/test_approx.py pins this)."""
    return 4 * n_clusters


def make_feature_map(method: str, key: jax.Array, x_sample: jax.Array,
                     m: int, spec: KernelSpec, *, orthogonal: bool = False):
    """Build an RFF or Nystrom map from a data sample (first mini-batch)."""
    if method == "rff":
        return make_rff(key, x_sample.shape[1], m, spec,
                        orthogonal=orthogonal)
    if method == "nystrom":
        return make_nystrom(key, x_sample, m, spec)
    raise ValueError(f"unknown feature-map method {method!r}; have {METHODS}")


__all__ = [
    "METHODS", "default_embed_dim", "make_feature_map",
    "RFFMap", "make_rff", "rff_features",
    "NystromMap", "make_nystrom", "nystrom_features",
    "EmbedState", "EmbedInnerResult", "assign_embedded", "fit_embedded",
    "lloyd_fit", "predict_embedded",
]
