"""Nystrom landmark embedding (Williams & Seeger; Chitta et al. for k-means).

Pick m landmarks L from a data sample, then whiten the landmark Gram matrix

    K_LL = U diag(lam) U^T        (eigendecomposition, clamped at eps)
    z(x) = K(x, L) U diag(lam)^{-1/2}          z: R^d -> R^m

so that ``z(x) . z(y) = K(x, L) K_LL^+ K(L, y)`` — the rank-m Nystrom
approximation of the full Gram matrix. Unlike RFF this works for *any*
Mercer kernel and is exact on the landmark subspace, so the error decays
with the kernel's spectrum rather than 1/sqrt(m).

How the m landmarks are picked is a pluggable strategy
(``repro.approx.selectors``): the paper's uniform sample is now just one of
three — ``selector="rls"`` ridge-leverage-score sampling covers the
kernel's spectrum measurably better at the same m (better accuracy for the
same O(m) memory; see ``core.memory.plan(...).frontier()``), and
``selector="kpp"`` D^2-spreads the landmarks. ``make_nystrom`` defaults to
uniform, bit-compatible with the historical behavior.

Gram blocks (K_LL here, K_xL per application) go through the same dispatch
as the rest of the system: the Pallas tiled Gram kernel on TPU, the jnp
Gram-block evaluator elsewhere (``repro.kernels.ops.use_pallas``).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.kernels import KernelSpec

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class NystromMap:
    """Frozen landmark embedding: z(x) = K(x, L) @ proj."""

    landmarks: Array   # [m, d] landmark features
    proj: Array        # [m, m] U diag(lam)^{-1/2} whitening
    spec: KernelSpec   # kernel the map approximates

    @property
    def dim(self) -> int:
        return self.proj.shape[1]

    @property
    def in_dim(self) -> int:
        return self.landmarks.shape[1]

    def __call__(self, x: Array) -> Array:
        return nystrom_features(x, self)


def _gram(x: Array, y: Array, spec: KernelSpec) -> Array:
    """Gram block through the Pallas kernel on TPU, jnp otherwise."""
    from repro.kernels.ops import kernel_matrix, use_pallas
    if use_pallas():
        return kernel_matrix(x, y, kind=spec.name, gamma=spec.gamma,
                             coef0=spec.coef0, degree=spec.degree,
                             interpret=False)
    return spec(x, y).astype(jnp.float32)


def whiten_gram(k: Array, *, eps: float = 1e-6) -> Array:
    """K^{-1/2} of a PSD Gram block via clamped eigh.

    Eigenvalues below ``eps * lam_max`` are zeroed (their directions carry
    no reliable kernel mass — inverting them amplifies noise). The ONE
    whitening used everywhere a landmark Gram is inverted — the NystromMap
    projection AND the RLS pilot (``selectors.pilot_whitening``) — so the
    two can never numerically drift apart (the mesh==single-host landmark
    bit-identity depends on them agreeing).
    """
    k = 0.5 * (k + k.T)                                          # exact symmetry
    lam, u = jnp.linalg.eigh(k)
    good = lam > eps * jnp.maximum(jnp.max(lam), eps)
    inv_sqrt = jnp.where(good, 1.0 / jnp.sqrt(jnp.maximum(lam, eps)), 0.0)
    return u * inv_sqrt[None, :]


def nystrom_from_landmarks(landmarks: Array, spec: KernelSpec, *,
                           eps: float = 1e-6) -> NystromMap:
    """Whiten an already-selected landmark set into a ``NystromMap``.

    The effective rank may be < m on near-degenerate samples (see
    ``whiten_gram``); the embedding dim stays m for shape stability.
    """
    k_ll = _gram(landmarks, landmarks, spec)                     # [m, m]
    return NystromMap(landmarks=landmarks, proj=whiten_gram(k_ll, eps=eps),
                      spec=spec)


def make_nystrom(key: Array, x: Array, m: int, spec: KernelSpec, *,
                 eps: float = 1e-6, selector=None) -> NystromMap:
    """Build an m-landmark Nystrom map from a data sample ``x`` [n, d].

    ``selector`` picks the landmark rows — a name or
    ``repro.approx.selectors.LandmarkSelector``; ``None``/``"uniform"`` is
    the historical uniform sample (bit-identical draws), ``"rls"``/
    ``"kpp"`` the leverage-aware strategies.
    """
    n = x.shape[0]
    if not (1 <= m <= n):
        raise ValueError(f"need 1 <= m <= n={n} landmarks, got m={m}")
    from .selectors import resolve
    # selector=None resolves to uniform, whose draw IS choose_landmarks —
    # the historical make_nystrom sample, bit-for-bit.
    l_idx = resolve(selector).select_indices(key, x, m, spec)
    return nystrom_from_landmarks(jnp.take(x, l_idx, axis=0), spec, eps=eps)


def nystrom_features(x: Array, fmap: NystromMap) -> Array:
    """z(X) -> [n, m] fp32."""
    k_xl = _gram(x, fmap.landmarks, fmap.spec)                   # [n, m]
    return jnp.dot(k_xl, fmap.proj, preferred_element_type=jnp.float32)


jax.tree_util.register_pytree_node(
    NystromMap,
    lambda f: ((f.landmarks, f.proj), f.spec),
    lambda spec, leaves: NystromMap(landmarks=leaves[0], proj=leaves[1],
                                    spec=spec),
)
