"""Mini-batch Lloyd in explicit feature space (the embedded-space driver).

With an explicit map z = phi_m(x) (RFF, Nystrom or a sketch map), kernel
k-means becomes
linear k-means on Z — centroids are real [C, m] vectors, so the paper's
medoid machinery (Eq.7/10) is unnecessary: batch centroids are exact cluster
means and the Eq.12 convex merge

    c_j <- (1 - a) c_j + a c_j^i,   a = |w_j^i| / (|w_j^i| + |w_j|)

is computed *exactly* instead of re-approximated on the batch. Empty batch
clusters (a = 0) leave the global centroid untouched — same empty-cluster
rule as the exact path.

Per batch the embedding is applied once ([n, m] resident for the whole inner
loop: the Lloyd sweep then costs O(n*m*C) matmuls, no kernel evaluations at
all); prediction can instead go through the fused Pallas embed+assign kernel
(repro.kernels.embed_assign) where Z never round-trips HBM.
"""
from __future__ import annotations

from functools import partial
from typing import Callable, Iterable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.init import kmeans_pp_indices
from repro.core.kernels import KernelSpec
from repro.core.kkmeans import BIG
from repro.data.sparse import is_sparse

Array = jax.Array

_LINEAR = KernelSpec("linear")


class EmbedState(NamedTuple):
    """O(C*m) cross-batch state of the embedded-space outer loop."""
    centroids: Array      # [C, m] explicit feature-space centroids
    cardinalities: Array  # [C]    accumulated |w_j|
    batches_done: Array   # []     int32


class EmbedInnerResult(NamedTuple):
    labels: Array      # [n] int32
    centroids: Array   # [C, m] batch cluster means
    counts: Array      # [C]
    n_iter: Array
    cost: Array        # sum_i ||z_i - c_{u_i}||^2 at the fixpoint


def assign_embedded(z: Array, centroids: Array, counts: Array | None = None,
                    *, precision: str = "f32") -> tuple[Array, Array]:
    """Nearest-centroid labels + squared distances in embedded space.

    Clusters with ``counts == 0`` are unjoinable (+BIG), mirroring the exact
    inner loop's empty-cluster rule. ``precision`` rounds the embedded rows
    to the policy tile dtype (kernels/precision.py) before the f32-accumulated
    contraction — the jnp image of the fused kernel's bf16-tile path;
    centroids stay f32 (they are the value panel, not a tile operand).
    """
    if precision != "f32":
        from repro.kernels.precision import resolve_precision
        z = resolve_precision(precision).cast_tiles(z)
    zsq = jnp.sum(z.astype(jnp.float32) ** 2, axis=1)            # [n]
    csq = jnp.sum(centroids.astype(jnp.float32) ** 2, axis=1)    # [C]
    # explicit f32 upcast on both dot operands: z may be a bf16 tile while
    # centroids are always f32, and lax.dot_general takes matched dtypes
    cross = jax.lax.dot_general(
        z.astype(jnp.float32), centroids.astype(jnp.float32),
        (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)                      # [n, C]
    d2 = jnp.maximum(zsq[:, None] + csq[None, :] - 2.0 * cross, 0.0)
    if counts is not None:
        d2 = jnp.where(counts[None, :] > 0, d2, BIG)
    return jnp.argmin(d2, axis=1).astype(jnp.int32), jnp.min(d2, axis=1)


def _means(z: Array, labels: Array, n_clusters: int):
    h = jax.nn.one_hot(labels, n_clusters, dtype=jnp.float32)    # [n, C]
    counts = jnp.sum(h, axis=0)
    sums = jax.lax.dot_general(h, z.astype(jnp.float32),
                               (((0,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32)  # [C, m]
    return sums / jnp.maximum(counts, 1.0)[:, None], counts


@partial(jax.jit, static_argnames=("n_clusters", "max_iters"))
def lloyd_fit(z: Array, labels0: Array, *, n_clusters: int,
              max_iters: int = 100) -> EmbedInnerResult:
    """Lloyd's iteration on embedded rows ``z`` [n, m] to label fixpoint."""

    def body(state):
        labels, _, t, _ = state
        cents, counts = _means(z, labels, n_clusters)
        new_labels, mind = assign_embedded(z, cents, counts)
        changed = jnp.any(new_labels != labels)
        return new_labels, changed, t + 1, jnp.sum(mind)

    def cond(state):
        _, changed, t, _ = state
        return jnp.logical_and(changed, t < max_iters)

    init = (labels0.astype(jnp.int32), jnp.array(True),
            jnp.array(0, jnp.int32), jnp.array(jnp.inf, jnp.float32))
    labels, _, t, cost = jax.lax.while_loop(cond, body, init)
    cents, counts = _means(z, labels, n_clusters)
    return EmbedInnerResult(labels, cents, counts, t, cost)


@partial(jax.jit, static_argnames=("n_clusters", "max_iters"))
def _first_batch_step(z: Array, key: Array, *, n_clusters: int,
                      max_iters: int):
    """Batch 0: k-means++ seeding (linear kernel == embedded space)."""
    diag = jnp.sum(z.astype(jnp.float32) ** 2, axis=1)
    seeds = kmeans_pp_indices(z, diag, key, n_clusters=n_clusters,
                              spec=_LINEAR)
    labels0, _ = assign_embedded(z, jnp.take(z, seeds, axis=0))
    res = lloyd_fit(z, labels0, n_clusters=n_clusters, max_iters=max_iters)
    state = EmbedState(
        centroids=res.centroids,
        cardinalities=res.counts,
        batches_done=jnp.array(1, jnp.int32),
    )
    return state, res


@partial(jax.jit, static_argnames=("n_clusters", "max_iters"))
def _next_batch_step(z: Array, state: EmbedState, *, n_clusters: int,
                     max_iters: int):
    """Batch i > 0: warm-start from global centroids, Lloyd, convex merge."""
    labels0, _ = assign_embedded(z, state.centroids, state.cardinalities)
    res = lloyd_fit(z, labels0, n_clusters=n_clusters, max_iters=max_iters)

    alpha = res.counts / jnp.maximum(res.counts + state.cardinalities, 1.0)
    merged = ((1.0 - alpha)[:, None] * state.centroids
              + alpha[:, None] * res.centroids)
    keep = (res.counts == 0)[:, None]
    new_centroids = jnp.where(keep, state.centroids, merged)
    disp = jnp.sum((new_centroids - state.centroids) ** 2, axis=1)

    new_state = EmbedState(
        centroids=new_centroids,
        cardinalities=state.cardinalities + res.counts,
        batches_done=state.batches_done + 1,
    )
    return new_state, res, disp


def fit_embedded(
    batches: Iterable[np.ndarray],
    fmap: Callable[[Array], Array],
    *,
    n_clusters: int,
    max_iters: int = 100,
    seed: int = 0,
    state: Optional[EmbedState] = None,
    checkpoint_cb: Optional[Callable[[EmbedState, int], None]] = None,
    recorder=None,
    precision: str = "f32",
):
    """Embedded-space outer loop. Returns ``(EmbedState, [BatchStats])``.

    Mirrors ``repro.core.minibatch.fit``: host-side sequential batches,
    O(C*m) state across batches, checkpoint callback after every merge.
    Consumes ``batches``: a closable source (``repro.data.BatchSource``) is
    closed on exit, success or failure. ``recorder`` (``repro.obs``) logs
    per-batch wall time, cost series and the measured-vs-predicted HBM
    watermark — all hooks host-side, outside the jitted steps.

    ``precision`` ("f32" | "bf16", kernels/precision.py) rounds each
    embedded batch Z ONCE to the tile dtype — under bf16 that halves the
    batch-resident [n, m] term (the dominant footprint of this path) while
    every contraction still accumulates f32.
    """
    from repro.data.loader import closing_source
    with closing_source(batches):
        return _fit_embedded_loop(batches, fmap, n_clusters=n_clusters,
                                  max_iters=max_iters, seed=seed,
                                  state=state, checkpoint_cb=checkpoint_cb,
                                  recorder=recorder, precision=precision)


def _fit_embedded_loop(batches, fmap, *, n_clusters, max_iters, seed, state,
                       checkpoint_cb, recorder=None, precision="f32"):
    import time

    from repro.core.minibatch import BatchStats  # cycle-free late import
    from repro.obs import memory as obs_memory
    from repro.obs import resolve as resolve_recorder

    rec = resolve_recorder(recorder)
    key = jax.random.PRNGKey(seed)
    history: list = []
    start = int(state.batches_done) if state is not None else 0

    from repro.kernels.precision import resolve_precision
    prec = resolve_precision(precision)

    for i, xb in enumerate(batches, start=start):
        t_batch = time.perf_counter()
        sparse = is_sparse(xb)
        z = prec.cast_tiles(fmap(xb if sparse else jnp.asarray(xb)))
        sub = jax.random.fold_in(key, i)
        if state is None:
            state, res = _first_batch_step(z, sub, n_clusters=n_clusters,
                                           max_iters=max_iters)
            disp = jnp.zeros((n_clusters,), jnp.float32)
        else:
            state, res, disp = _next_batch_step(z, state,
                                                n_clusters=n_clusters,
                                                max_iters=max_iters)
        rec.series("inner/cost", res.cost, batch=i)     # deferred fetch
        rec.series("inner/iters", res.n_iter, batch=i)
        history.append(BatchStats(
            inner_iters=int(res.n_iter),
            cost=float(res.cost),
            displacement=np.asarray(disp),
            counts=np.asarray(res.counts),
        ))
        if checkpoint_cb is not None:
            checkpoint_cb(state, i)
        if rec.enabled:
            n_rows, d = xb.shape
            rec.series("batch/wall_seconds",
                       time.perf_counter() - t_batch, batch=i, rows=n_rows)
            rec.gauge("clusters/empty",
                      int((history[-1].counts == 0).sum()), batch=i)
            density = (xb.nnz / max(n_rows * d, 1)) if sparse else 1.0
            obs_memory.watermark(
                rec, batch=i, predicted_bytes=(
                    obs_memory.predicted_embed_footprint(
                        n_rows, n_clusters, fmap, sparse=sparse,
                        density=density)))
            rec.batch_boundary(i)
    if state is None:
        raise ValueError("empty batch iterable")
    return state, history


def predict_embedded(x, state: EmbedState, fmap, *,
                     use_fused: bool | None = None,
                     precision: str = "f32") -> Array:
    """Label new samples by nearest centroid in embedded space.

    On TPU/GPU (or with ``use_fused=True``) this goes through the fused
    Pallas embed+assign kernel — the [n, m] embedding never materializes in
    HBM; the lowering (Mosaic vs Triton) follows the live jax backend
    (kernels/backend.py). CSR batches take the O(nnz) jnp sketch path
    instead (the fused kernel consumes dense row tiles). ``precision``
    is the kernel-layer tile-dtype policy ("f32" | "bf16").
    """
    from repro.kernels.backend import kernel_backend
    from repro.kernels.ops import embed_assign, use_pallas
    if is_sparse(x):
        labels, _ = assign_embedded(fmap(x), state.centroids,
                                    state.cardinalities, precision=precision)
        return labels
    fused = use_pallas() if use_fused is None else use_fused
    if fused:
        labels, _ = embed_assign(x, fmap, state.centroids,
                                 state.cardinalities,
                                 interpret=jax.default_backend()
                                 not in ("tpu", "gpu"),
                                 precision=precision,
                                 backend=kernel_backend())
        return labels
    labels, _ = assign_embedded(fmap(x), state.centroids,
                                state.cardinalities, precision=precision)
    return labels
