"""Landmark selection as a first-class strategy subsystem.

Every rank-m approximation in this repo — the exact path's Eq.14 landmark
restriction, the Nystrom feature map, the planner's accuracy-per-byte
frontier — starts from the same question: *which* m rows represent the
kernel best? The paper (and this repo until now) answered "uniform", but
the approximation error of rank-m kernel methods is governed by how well
the landmarks cover the kernel's *spectrum*, and ridge-leverage-score (RLS)
sampling provably covers it better at the same m (El Alaoui & Mahoney;
Musco & Musco, *Recursive Sampling for the Nystrom Method*;
Pourkamali-Anaraki & Becker).

The ``LandmarkSelector`` contract
---------------------------------
A selector is a frozen (hashable, jit-static) dataclass with two faces:

* **offline** — ``select_indices(key, x, m, spec) -> [m] sorted int32``
  picks m landmark rows from a resident sample ``x`` [n, d]. Pure and
  jit-traceable with static shapes: the exact mini-batch steps call it
  inside their jitted bodies.
* **streaming** — ``init(key, d)`` / ``fold(state, xb)`` /
  ``finalize(state, m, spec)`` folds mini-batches of a ``BatchSource``
  into a bounded ``SelectorState`` (a checkpointable pytree) and selects
  from the folded pool, so selection works without materializing the
  dataset.

Determinism is the load-bearing property: every random draw is keyed by
``fold_in(key, tag)`` and — per row — ``fold_in(., global_row_id)``, never
by how many batches a process has already folded. Consequences:

* the same key always selects the same landmarks (restart determinism);
* the streaming fold is *batch-boundary invariant*: re-chunking the stream
  does not change the selection;
* a ``SelectorState`` checkpointed mid-stream (``repro.ft.checkpoint``)
  and restored resumes to bit-identical landmarks;
* whenever the stream fits the candidate pool (``pool`` rows, default
  8192), ``finalize`` is bit-identical to ``select_indices`` on the
  materialized concatenation. Beyond the pool cap the fold keeps a
  uniform-priority coreset — still deterministic and boundary-invariant,
  just no longer equal to the uncapped offline selection.

Strategies
----------
* ``uniform`` — the paper's §3.2 behavior, extracted verbatim from
  ``core.landmarks.choose_landmarks``; zero selection cost.
* ``rls`` — approximate ridge leverage scores: a uniform *pilot* of m rows
  whitens the sample into pilot coordinates ``C = K(X, S) K_SS^{-1/2}``;
  the m x m sketch ``G = C^T C`` (one ``psum`` of per-device partials on a
  mesh — see ``distributed.embed``) yields the leverage estimate

      score_i = c_i (G + lam I)^{-1} c_i^T + (k_ii - ||c_i||^2)_+ / lam

  (projection leverage of the Nystrom approximation plus the Musco-style
  residual term that catches rows the pilot does not cover, so small/far
  clusters cannot be starved). m landmarks are then drawn ~ score without
  replacement via per-row Gumbel top-m. O(n m^2) on an n-row sample.
* ``kpp`` — kernel k-means++ seeding with m seeds, reusing the greedy
  candidate machinery of ``core.init.kmeans_pp_indices``: D^2-spread
  landmarks, a deterministic middle ground between uniform and RLS.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.kernels import KernelSpec

Array = jax.Array

NAMES = ("uniform", "rls", "kpp")

# fold_in tags: one stream of per-row randomness per concern, so the pool
# priorities, the RLS pilot and the final draw never share bits.
_TAG_POOL, _TAG_PILOT, _TAG_SELECT = 0, 1, 2


class SelectorState(NamedTuple):
    """Streaming fold state — a checkpointable pytree (``repro.ft``).

    The pool holds up to ``selector.pool`` candidate rows sorted by global
    row id, each with its fold_in-keyed uniform priority; eviction keeps
    the running top-``pool`` priorities, which makes the fold associative
    and therefore batch-boundary invariant.
    """
    key: Array        # the selection PRNG key (all draws fold_in from it)
    rows: Array       # [r, d] candidate pool rows
    gids: Array       # [r]    int32 global row ids (ascending)
    pri: Array        # [r]    f32 per-gid uniform priorities
    rows_seen: Array  # []     int32: next global row id
    folds: Array      # []     int32: batches folded (resume bookkeeping)


def _per_gid_uniform(key: Array, gids: Array) -> Array:
    """One U(0,1) draw per global row id, keyed fold_in(key, gid) — the
    same id gets the same draw no matter which batch it arrives in."""
    keys = jax.vmap(lambda g: jax.random.fold_in(key, g))(gids)
    return jax.vmap(lambda k: jax.random.uniform(k, dtype=jnp.float32))(keys)


def _per_gid_gumbel(key: Array, gids: Array) -> Array:
    u = jnp.clip(_per_gid_uniform(key, gids), 1e-12, 1.0 - 1e-7)
    return -jnp.log(-jnp.log(u))


def rls_scores(c: Array, diag_k: Array, g: Array, *, delta: float) -> Array:
    """Approximate ridge leverage scores from pilot coordinates.

    ``c`` [n, m] are rows in whitened pilot coordinates (``K(X, S)`` times
    the K_SS whitening), ``g = c^T c`` the [m, m] sketch (on a mesh: the
    psum of per-device partials), ``diag_k`` [n] the kernel diagonal. The
    ridge ``lam = delta * tr(g) / m`` is data-adaptive and scale-free.
    """
    m = g.shape[0]
    lam = delta * jnp.trace(g) / m + 1e-12
    b = g + lam * jnp.eye(m, dtype=jnp.float32)
    sol = jnp.linalg.solve(b, c.T)                             # [m, n]
    proj = jnp.sum(c * sol.T, axis=1)                          # [n]
    resid = jnp.maximum(diag_k.astype(jnp.float32)
                        - jnp.sum(c * c, axis=1), 0.0)
    return proj + resid / lam


def pilot_whitening(pilot: Array, spec: KernelSpec, *,
                    eps: float = 1e-6) -> Array:
    """K_SS^{-1/2} — the NystromMap's own clamped-eigh whitening
    (``nystrom.whiten_gram``), shared so the two can't drift apart."""
    from .nystrom import whiten_gram
    return whiten_gram(spec(pilot, pilot).astype(jnp.float32), eps=eps)


@dataclasses.dataclass(frozen=True)
class LandmarkSelector:
    """Shared contract + streaming pool machinery (see module docstring)."""

    pool: int = 8192   # candidate-pool cap for the streaming fold

    name = "base"

    # -- per-strategy core: indices into ``x`` given per-row global ids ----

    def _indices(self, key: Array, x: Array, gids: Array, m: int,
                 spec: KernelSpec) -> Array:
        raise NotImplementedError

    # -- offline ----------------------------------------------------------

    def select_indices(self, key: Array, x, m: int,
                       spec: KernelSpec) -> Array:
        """[m] sorted int32 indices into the resident sample ``x``."""
        n = x.shape[0]
        if m > n:
            raise ValueError(f"|L|={m} > sample rows {n}")
        if m == n:
            return jnp.arange(n, dtype=jnp.int32)
        gids = jnp.arange(n, dtype=jnp.int32)
        return self._indices(key, jnp.asarray(x), gids, m, spec)

    def select(self, key: Array, x, m: int, spec: KernelSpec) -> Array:
        """[m, d] landmark rows from a resident sample."""
        x = jnp.asarray(x)
        return jnp.take(x, self.select_indices(key, x, m, spec), axis=0)

    # -- streaming --------------------------------------------------------

    def init(self, key: Array, d: int) -> SelectorState:
        z = jnp.zeros((0,), jnp.float32)
        return SelectorState(
            key=key,
            rows=jnp.zeros((0, d), jnp.float32),
            gids=jnp.zeros((0,), jnp.int32),
            pri=z,
            rows_seen=jnp.array(0, jnp.int32),
            folds=jnp.array(0, jnp.int32),
        )

    def fold(self, state: SelectorState, xb) -> SelectorState:
        """Fold one dense mini-batch into the candidate pool."""
        from repro.data.sparse import is_sparse
        if is_sparse(xb):
            raise ValueError(
                "landmark selection needs dense rows (Nystrom gathers "
                "landmark coordinates); densify the selection sample or "
                "use a sketch method")
        xb = jnp.asarray(xb, jnp.float32)
        n = xb.shape[0]
        gids_new = state.rows_seen + jnp.arange(n, dtype=jnp.int32)
        pri_new = _per_gid_uniform(
            jax.random.fold_in(state.key, _TAG_POOL), gids_new)
        rows = jnp.concatenate([state.rows, xb], axis=0)
        gids = jnp.concatenate([state.gids, gids_new])
        pri = jnp.concatenate([state.pri, pri_new])
        if rows.shape[0] > self.pool:
            # keep the running top-`pool` priorities; top-k of a union is
            # the fold of per-batch top-k's, so the pool is independent of
            # how the stream was chunked.
            _, keep = jax.lax.top_k(pri, self.pool)
            keep = jnp.sort(keep)          # pool stays in global-id order
            rows = jnp.take(rows, keep, axis=0)
            gids = jnp.take(gids, keep)
            pri = jnp.take(pri, keep)
        return SelectorState(key=state.key, rows=rows, gids=gids, pri=pri,
                             rows_seen=state.rows_seen + n,
                             folds=state.folds + 1)

    def finalize(self, state: SelectorState, m: int,
                 spec: KernelSpec) -> Array:
        """[m, d] landmark rows from the folded pool. Bit-identical to
        ``select`` on the materialized stream whenever it fit the pool."""
        n = int(state.rows.shape[0])
        if n < 1:
            raise ValueError("empty selector state: fold at least one batch")
        if m > n:
            raise ValueError(f"|L|={m} > pooled candidate rows {n}")
        if m == n:
            return state.rows
        idx = self._indices(state.key, state.rows, state.gids, m, spec)
        return jnp.take(state.rows, idx, axis=0)


@dataclasses.dataclass(frozen=True)
class UniformSelector(LandmarkSelector):
    """The paper's §3.2 uniform landmark sample (sorted, no replacement)."""

    name = "uniform"

    def _indices(self, key, x, gids, m, spec):
        from repro.core.landmarks import choose_landmarks
        return choose_landmarks(key, x.shape[0], m)


@dataclasses.dataclass(frozen=True)
class RLSSelector(LandmarkSelector):
    """Approximate ridge-leverage-score sampling (module docstring)."""

    delta: float = 1e-2   # ridge: lam = delta * tr(G) / m
    eps: float = 1e-6     # pilot whitening clamp

    name = "rls"

    # The pieces below are also the building blocks of the mesh-native
    # selection in ``distributed.embed`` (same keys, same math; only the
    # [m, m] sketch G arrives via a psum of per-device partials there).

    def pilot_indices(self, key, gids, m: int) -> Array:
        """[m] sorted indices of the uniform pilot (gid-keyed draw)."""
        pri = _per_gid_uniform(jax.random.fold_in(key, _TAG_PILOT), gids)
        _, pidx = jax.lax.top_k(pri, m)
        return jnp.sort(pidx).astype(jnp.int32)

    def gumbel_top_m(self, key, scores, gids, m: int) -> Array:
        """Sample m indices ~ scores without replacement (Gumbel top-m),
        keyed per global row id so the draw survives re-chunking."""
        noise = _per_gid_gumbel(jax.random.fold_in(key, _TAG_SELECT), gids)
        logits = jnp.log(jnp.maximum(scores, 1e-30)) + noise
        _, idx = jax.lax.top_k(logits, m)
        return jnp.sort(idx).astype(jnp.int32)

    def scores(self, key, x, gids, m, spec):
        """[n] leverage estimates (the Gumbel draw is not applied)."""
        pilot = jnp.take(x, self.pilot_indices(key, gids, m), axis=0)
        c = jnp.dot(spec(x, pilot).astype(jnp.float32),
                    pilot_whitening(pilot, spec, eps=self.eps),
                    preferred_element_type=jnp.float32)  # [n, m]
        g = jnp.dot(c.T, c, preferred_element_type=jnp.float32)
        return rls_scores(c, spec.diag(x), g, delta=self.delta)

    def _indices(self, key, x, gids, m, spec):
        return self.gumbel_top_m(key, self.scores(key, x, gids, m, spec),
                                 gids, m)


@dataclasses.dataclass(frozen=True)
class KPPSelector(LandmarkSelector):
    """Kernel k-means++ landmark seeding (greedy candidate variant)."""

    name = "kpp"

    def _indices(self, key, x, gids, m, spec):
        from repro.core.init import kmeans_pp_indices
        idx = kmeans_pp_indices(x, spec.diag(x),
                                jax.random.fold_in(key, _TAG_SELECT),
                                n_clusters=m, spec=spec)
        return jnp.sort(idx).astype(jnp.int32)


_REGISTRY = {
    "uniform": UniformSelector(),
    "rls": RLSSelector(),
    "kpp": KPPSelector(),
}

SelectorLike = Union[str, LandmarkSelector, None]


def resolve(selector: SelectorLike) -> LandmarkSelector:
    """Name or instance -> selector instance (None -> uniform)."""
    if selector is None:
        return _REGISTRY["uniform"]
    if isinstance(selector, LandmarkSelector):
        return selector
    try:
        return _REGISTRY[selector]
    except (KeyError, TypeError):
        raise ValueError(
            f"unknown landmark selector {selector!r}; have {NAMES}") from None


def name_of(selector: SelectorLike) -> str:
    return resolve(selector).name


def select_streaming(selector: SelectorLike, key: Array, batches, m: int,
                     spec: KernelSpec, *, state: SelectorState = None,
                     checkpoint_cb=None):
    """Fold a batch iterable / ``BatchSource`` and select m landmarks.

    Bounded memory (``selector.pool`` rows), one pass, no materialized
    dataset. ``state`` resumes a previous fold (skip the committed prefix
    with ``source.skip(int(state.folds))`` first); ``checkpoint_cb(state,
    i)`` is invoked after every folded batch — checkpoint the
    ``SelectorState`` pytree next to the feature map (``repro.ft``) and a
    mid-stream restart re-selects identically.

    Returns ``(landmarks [m, d], final_state)``.
    """
    from repro.data.loader import closing_source
    sel = resolve(selector)
    with closing_source(batches):
        it = iter(batches)
        start = int(state.folds) if state is not None else 0
        for i, xb in enumerate(it, start=start):
            if state is None:
                # .shape covers ndarray AND CSRBatch, so a sparse first
                # batch reaches fold()'s clear needs-dense-rows error
                # instead of dying inside an asarray coercion.
                d = (xb.shape[1] if hasattr(xb, "shape")
                     else np.asarray(xb).shape[1])
                state = sel.init(key, d)
            state = sel.fold(state, xb)
            if checkpoint_cb is not None:
                checkpoint_cb(state, i)
    if state is None:
        raise ValueError("empty batch iterable")
    return sel.finalize(state, m, spec), state


def state_like(d: int) -> SelectorState:
    """Structural twin for ``CheckpointManager.restore`` (shapes come from
    the manifest; only the pytree structure matters)."""
    sel = UniformSelector()
    return sel.init(jax.random.PRNGKey(0), d)


__all__ = [
    "NAMES", "LandmarkSelector", "SelectorState",
    "UniformSelector", "RLSSelector", "KPPSelector",
    "resolve", "name_of", "select_streaming", "state_like",
    "rls_scores", "pilot_whitening",
]
