"""Random Fourier feature maps for the rbf kernel (Rahimi & Recht).

Bochner's theorem: the shift-invariant rbf kernel
``K(x, y) = exp(-gamma ||x - y||^2)`` is the Fourier transform of a Gaussian
spectral density, so with ``w_r ~ N(0, 2*gamma*I_d)`` and
``b_r ~ U[0, 2*pi]`` the explicit map

    z(x) = sqrt(2/m) * cos(W x + b)             z: R^d -> R^m

satisfies ``E[z(x) . z(y)] = K(x, y)`` with variance O(1/m). Kernel k-means
on X then becomes *linear* k-means on Z = z(X) — the second accuracy/velocity
knob (embedding dim m), orthogonal to the paper's (B, s).

The orthogonal variant (Yu et al., Orthogonal Random Features) replaces the
iid Gaussian rows of W with scaled orthonormal blocks (QR of a Gaussian,
rows re-scaled by chi-distributed norms), which provably lowers the kernel
approximation variance at the same m — worth it whenever m >= d.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.core.kernels import KernelSpec

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class RFFMap:
    """Frozen sampled feature map: z(x) = scale * cos(x @ w.T + b)."""

    w: Array          # [m, d] spectral frequencies
    b: Array          # [m]    phases in [0, 2*pi)
    scale: float      # sqrt(2/m)

    @property
    def dim(self) -> int:
        return self.w.shape[0]

    @property
    def in_dim(self) -> int:
        return self.w.shape[1]

    def __call__(self, x: Array) -> Array:
        return rff_features(x, self)


def _orthogonal_frequencies(key: Array, m: int, d: int) -> Array:
    """[m, d] block-orthogonal Gaussian-norm rows (ORF construction).

    Stacks ceil(m/d) independent d x d QR blocks; each block's rows are
    orthonormal directions re-scaled by chi(d)-distributed norms so the
    marginal row distribution matches N(0, I_d).
    """
    n_blocks = -(-m // d)
    k_q, k_s = jax.random.split(key)
    g = jax.random.normal(k_q, (n_blocks, d, d), jnp.float32)
    q = jnp.linalg.qr(g)[0]                                  # [nb, d, d]
    norms = jnp.sqrt(jnp.sum(
        jax.random.normal(k_s, (n_blocks, d, d), jnp.float32) ** 2, axis=-1))
    w = q * norms[..., None]                                 # [nb, d, d]
    return w.reshape(n_blocks * d, d)[:m]


def make_rff(key: Array, d: int, m: int, spec: KernelSpec, *,
             orthogonal: bool = False) -> RFFMap:
    """Sample an m-dimensional random Fourier map for ``spec`` over R^d.

    Only shift-invariant kernels have a spectral measure; the rbf kernel is
    the one this code base ships (gate here, not silently mis-approximate).
    """
    if spec.name != "rbf":
        raise ValueError(
            f"RFF requires a shift-invariant kernel; got {spec.name!r} "
            "(use method='nystrom' for non-rbf kernels)")
    if m < 1:
        raise ValueError(f"embedding dim m must be >= 1, got {m}")
    k_w, k_b = jax.random.split(key)
    if orthogonal:
        w = _orthogonal_frequencies(k_w, m, d)
    else:
        w = jax.random.normal(k_w, (m, d), jnp.float32)
    # N(0, 2*gamma*I): exp(-gamma||x-y||^2) = exp(-||x-y||^2 / (2 sigma^2))
    # with sigma^2 = 1/(2 gamma) -> frequency std = 1/sigma = sqrt(2 gamma).
    w = w * math.sqrt(2.0 * spec.gamma)
    b = jax.random.uniform(k_b, (m,), jnp.float32, 0.0, 2.0 * math.pi)
    return RFFMap(w=w, b=b, scale=math.sqrt(2.0 / m))


@jax.jit
def rff_features(x: Array, fmap: RFFMap) -> Array:
    """z(X) -> [n, m] fp32 (fp32 projection regardless of input dtype)."""
    proj = jax.lax.dot_general(
        x, fmap.w, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)
    return fmap.scale * jnp.cos(proj + fmap.b[None, :])


jax.tree_util.register_pytree_node(
    RFFMap,
    lambda f: ((f.w, f.b), f.scale),
    lambda scale, leaves: RFFMap(w=leaves[0], b=leaves[1], scale=scale),
)
