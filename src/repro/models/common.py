"""Shared model substrate: norms, RoPE, sharding helpers, chunked attention,
chunked cross-entropy, parameter initialization with PartitionSpec metadata.

Conventions
-----------
* Params are nested dicts of arrays. Stacked layers carry a leading [L] (or
  [groups, period]) dim and are consumed by ``lax.scan``.
* Every ``init_*`` returns ``(params, specs)`` where ``specs`` mirrors the
  params pytree with ``PartitionSpec``s — the launcher turns those into
  ``NamedSharding``s for jit in_shardings (FSDP over the data axes x TP over
  the model axis — DESIGN.md §6).
* Logical mesh axes: ``dp`` = all data axes (("pod","data") on the multi-pod
  mesh), ``tp`` = "model". ``Axes`` carries the mapping.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

Array = jax.Array
PyTree = Any


# ambient concrete mesh for model code that needs shard_map (the GSPMD/jit
# path cannot recover the mesh from tracing context — launchers set it).
_AMBIENT_MESH = None


def set_ambient_mesh(mesh) -> None:
    global _AMBIENT_MESH
    _AMBIENT_MESH = mesh


def ambient_mesh():
    return _AMBIENT_MESH


@dataclasses.dataclass(frozen=True)
class Axes:
    """Logical -> physical mesh-axis mapping."""
    dp: tuple[str, ...] = ("data",)
    tp: str | None = "model"

    def spec(self, *dims) -> P:
        """Translate logical dims ('dp' | 'tp' | None) to a PartitionSpec."""
        out = []
        for d in dims:
            if d == "dp":
                out.append(self.dp if len(self.dp) > 1 else self.dp[0])
            elif d == "tp":
                out.append(self.tp)
            else:
                out.append(None)
        return P(*out)


def shard(x: Array, axes: Axes, *dims) -> Array:
    """with_sharding_constraint against the ambient mesh (no-op outside jit
    with mesh context)."""
    try:
        return jax.lax.with_sharding_constraint(x, axes.spec(*dims))
    except (ValueError, RuntimeError):
        return x


# ---------------------------------------------------------------------------
# initialization
# ---------------------------------------------------------------------------


def dense_init(key, shape, spec, *, dtype=jnp.bfloat16, scale: float | None = None):
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    std = scale if scale is not None else fan_in ** -0.5
    return jax.random.normal(key, shape, jnp.float32).astype(dtype) * std, spec


def zeros_init(shape, spec, *, dtype=jnp.float32):
    return jnp.zeros(shape, dtype), spec


def ones_init(shape, spec, *, dtype=jnp.float32):
    return jnp.ones(shape, dtype), spec


class ParamBuilder:
    """Collects (params, specs) trees with a split-as-you-go PRNG."""

    def __init__(self, key: Array, dtype=jnp.bfloat16):
        self.key = key
        self.dtype = dtype
        self.params: dict = {}
        self.specs: dict = {}

    def sub(self) -> Array:
        self.key, k = jax.random.split(self.key)
        return k

    def dense(self, name: str, shape, spec, *, scale=None, dtype=None):
        p, s = dense_init(self.sub(), shape, spec,
                          dtype=dtype or self.dtype, scale=scale)
        self.params[name], self.specs[name] = p, s

    def zeros(self, name: str, shape, spec, *, dtype=jnp.float32):
        self.params[name], self.specs[name] = zeros_init(shape, spec, dtype=dtype)

    def ones(self, name: str, shape, spec, *, dtype=jnp.float32):
        self.params[name], self.specs[name] = ones_init(shape, spec, dtype=dtype)

    def child(self, name: str, builder: "ParamBuilder"):
        self.params[name], self.specs[name] = builder.params, builder.specs

    def build(self):
        return self.params, self.specs


def stack_params(trees: list[PyTree]):
    """Stack a list of per-layer param trees along a new leading axis."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def stack_specs(spec_tree: PyTree):
    """Prepend None (layer dim) to every PartitionSpec in a tree."""
    return jax.tree.map(lambda s: P(None, *s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# norms / activations
# ---------------------------------------------------------------------------


def rms_norm(x: Array, weight: Array | None, *, eps: float = 1e-6,
             plus_one: bool = False) -> Array:
    """RMSNorm; ``weight=None`` -> OLMo's non-parametric LN (no affine).
    ``plus_one`` -> gemma-style (1 + w) parameterization."""
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    if weight is not None:
        w = weight.astype(jnp.float32)
        y = y * (1.0 + w if plus_one else w)
    return y.astype(x.dtype)


def softcap(x: Array, cap: float | None) -> Array:
    """gemma2 logit soft-capping: cap * tanh(x / cap)."""
    if cap is None:
        return x
    return (cap * jnp.tanh(x.astype(jnp.float32) / cap)).astype(x.dtype)


def swiglu(gate: Array, up: Array) -> Array:
    return jax.nn.silu(gate.astype(jnp.float32)).astype(gate.dtype) * up


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------


def rope_freqs(d_head: int, theta: float) -> Array:
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2, jnp.float32) / d_head))


def apply_rope(x: Array, positions: Array, *, theta: float = 10000.0) -> Array:
    """x: [..., S, H, dh]; positions: broadcastable to [..., S]."""
    freqs = rope_freqs(x.shape[-1], theta)                      # [dh/2]
    angles = positions[..., None].astype(jnp.float32) * freqs   # [..., S, dh/2]
    angles = angles[..., None, :]                               # [..., S, 1, dh/2]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# chunked causal attention (pure-JAX flash-style; memory O(chunk * S))
# ---------------------------------------------------------------------------


def chunked_attention(q: Array, k: Array, v: Array, *,
                      causal: bool = True,
                      window: int | None = None,
                      attn_softcap: float | None = None,
                      q_chunk: int = 512,
                      q_offset: int = 0) -> Array:
    """q: [B, Sq, H, dh], k/v: [B, Sk, KH, dh] (GQA: H % KH == 0).

    Scans over query chunks; scores for one chunk are [B, H, cq, Sk] — the
    full [Sq, Sk] score matrix never materializes. ``window`` adds a local
    (sliding-window) mask; ``q_offset`` is the absolute position of q[0]
    (prefill continuation / decode).
    """
    b, sq, h, dh = q.shape
    sk, kh = k.shape[1], k.shape[2]
    groups = h // kh
    scale = dh ** -0.5
    cq = min(q_chunk, sq)
    n_chunks = sq // cq if sq % cq == 0 else -(-sq // cq)
    pad = n_chunks * cq - sq
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    qr = q.reshape(b, n_chunks, cq, h, dh).transpose(1, 0, 2, 3, 4)

    kpos = jnp.arange(sk)

    def chunk_fn(carry, args):
        qc, ci = args                                   # [B, cq, H, dh]
        qpos = q_offset + ci * cq + jnp.arange(cq)
        # scores: [B, KH, G, cq, Sk]
        qg = qc.reshape(b, cq, kh, groups, dh)
        scores = jnp.einsum("bqkgd,bskd->bkgqs", qg.astype(jnp.float32),
                            k.astype(jnp.float32)) * scale
        if attn_softcap is not None:
            scores = attn_softcap * jnp.tanh(scores / attn_softcap)
        mask = jnp.ones((cq, sk), bool)
        if causal:
            mask &= qpos[:, None] >= kpos[None, :]
        if window is not None:
            mask &= qpos[:, None] - kpos[None, :] < window
        scores = jnp.where(mask[None, None, None], scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v.astype(jnp.float32))
        return carry, out.reshape(b, cq, h, dh).astype(q.dtype)

    _, outs = jax.lax.scan(chunk_fn, None,
                           (qr, jnp.arange(n_chunks)))
    out = outs.transpose(1, 0, 2, 3, 4).reshape(b, n_chunks * cq, h, dh)
    return out[:, :sq]


# ---------------------------------------------------------------------------
# chunked cross-entropy (full logits never materialize)
# ---------------------------------------------------------------------------


VOCAB_ALIGN = 128


def padded_vocab_size(v: int, multiple: int = VOCAB_ALIGN) -> int:
    """Embedding tables are vocab-sharded over ``model``; odd vocabularies
    (seamless: 256206) are padded up to a lane/TP-aligned multiple. Loss and
    sampling mask the padded rows, so results are exact."""
    return -(-v // multiple) * multiple


def mask_vocab_pad(logits: Array, n_valid: int) -> Array:
    """-inf the padded tail of a [..., V_pad] logit block."""
    vp = logits.shape[-1]
    if n_valid >= vp:
        return logits
    mask = jnp.arange(vp) < n_valid
    return jnp.where(mask, logits, -1e30)


def chunked_cross_entropy(hidden: Array, emb: Array, labels: Array, *,
                          chunk: int = 2048,
                          logit_softcap: float | None = None,
                          n_valid_vocab: int | None = None) -> Array:
    """Mean CE of tied-embedding logits, scanning over token chunks.

    hidden: [T, D] (already flattened), emb: [V, D], labels: [T].
    Each chunk materializes [chunk, V] logits only transiently (remat'd).
    ``n_valid_vocab`` masks padded embedding rows out of the partition
    function (exact loss on padded tables).
    """
    t, d = hidden.shape
    n_chunks = -(-t // chunk)
    pad = n_chunks * chunk - t
    if pad:
        hidden = jnp.pad(hidden, ((0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, pad),), constant_values=-1)
    hr = hidden.reshape(n_chunks, chunk, d)
    lr = labels.reshape(n_chunks, chunk)

    @jax.checkpoint
    def chunk_loss(hc, lc):
        logits = jnp.dot(hc, emb.T.astype(hc.dtype),
                         preferred_element_type=jnp.float32)
        if logit_softcap is not None:
            logits = logit_softcap * jnp.tanh(logits / logit_softcap)
        if n_valid_vocab is not None:
            logits = mask_vocab_pad(logits, n_valid_vocab)
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, jnp.maximum(lc, 0)[:, None], axis=1)[:, 0]
        valid = lc >= 0
        return jnp.sum(jnp.where(valid, lse - gold, 0.0)), jnp.sum(valid)

    def body(carry, args):
        hc, lc = args
        s, n = chunk_loss(hc, lc)
        return (carry[0] + s, carry[1] + n), None

    (total, count), _ = jax.lax.scan(
        body, (jnp.float32(0.0), jnp.float32(0.0)), (hr, lr))
    return total / jnp.maximum(count, 1.0)
