"""Uniform model API over the four families + abstract input/cache specs.

``get_model(cfg)`` returns a ``ModelAPI`` whose members close over the config:

  init(key)                     -> (params, partition-spec tree)
  loss(params, batch, axes)     -> scalar CE
  prefill(params, batch, axes)  -> (cache, last-token logits)
  decode(params, cache, token, pos, axes) -> (logits, cache)
  input_specs(shape)            -> ShapeDtypeStruct batch stand-ins
  batch_partition(shape, axes)  -> matching PartitionSpec tree
  cache_specs(shape)            -> (ShapeDtypeStruct, PartitionSpec) trees

The spec functions never allocate — they are what the multi-pod dry-run
lowers against (assignment requirement e).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from . import encdec, rwkv, transformer, zamba
from .common import Axes
from .ssm import ssm_dims
from .transformer import _cache_len, _layer_kinds

Array = jax.Array


def _kv_policy(cfg: ModelConfig, tp_size: int) -> str:
    """'heads' when kv heads divide the TP axis, else 'seq' (flash-decode
    sequence sharding) — DESIGN.md §6."""
    return "heads" if tp_size and cfg.n_kv_heads % max(tp_size, 1) == 0 \
        else "seq"


@dataclasses.dataclass(frozen=True)
class ModelAPI:
    cfg: ModelConfig
    init: Callable[..., Any]
    loss: Callable[..., Array]
    prefill: Callable[..., Any]
    decode: Callable[..., Any]
    input_specs: Callable[..., Any]
    batch_partition: Callable[..., Any]
    cache_specs: Callable[..., Any]


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins, no allocation)
# ---------------------------------------------------------------------------


def _token_batch(shape: ShapeConfig):
    b, s = shape.global_batch, shape.seq_len
    tok = jax.ShapeDtypeStruct((b, s), jnp.int32)
    return {"tokens": tok, "labels": tok}


def _input_specs(cfg: ModelConfig, shape: ShapeConfig):
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        batch = _token_batch(shape)
        if cfg.family == "encdec":
            batch["frames"] = jax.ShapeDtypeStruct((b, s, cfg.d_model),
                                                   jnp.bfloat16)
        return batch
    if shape.kind == "prefill":
        if cfg.family == "encdec":
            return {"frames": jax.ShapeDtypeStruct((b, s, cfg.d_model),
                                                   jnp.bfloat16),
                    "tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32)}
        return {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
    # decode: one new token against a seq_len cache
    return {"token": jax.ShapeDtypeStruct((b,), jnp.int32),
            "pos": jax.ShapeDtypeStruct((), jnp.int32)}


def _dp_for_batch(axes: Axes, dp_size: int, global_batch: int):
    """Batch-dim partition: the data axes, unless the global batch does not
    divide them (e.g. long_500k's batch of 1) — then the batch dim stays
    unsharded and dp capacity is left to the sequence/feature dims."""
    if dp_size > 1 and global_batch % dp_size != 0:
        return None
    return axes.dp if len(axes.dp) > 1 else axes.dp[0]


def _batch_partition(cfg: ModelConfig, shape: ShapeConfig, axes: Axes,
                     dp_size: int):
    dp = _dp_for_batch(axes, dp_size, shape.global_batch)
    if shape.kind == "train":
        out = {"tokens": P(dp, None), "labels": P(dp, None)}
        if cfg.family == "encdec":
            out["frames"] = P(dp, None, None)
        return out
    if shape.kind == "prefill":
        if cfg.family == "encdec":
            return {"frames": P(dp, None, None), "tokens": P(dp, None)}
        return {"tokens": P(dp, None)}
    return {"token": P(dp), "pos": P()}


# ---------------------------------------------------------------------------
# cache specs per family
# ---------------------------------------------------------------------------


def _kv_part(policy: str, dp, *, lead: int = 1):
    """PartitionSpec for [*, B, S, KH, dh] with ``lead`` leading layer dims."""
    lead_dims = (None,) * lead
    if policy == "heads":
        return P(*lead_dims, dp, None, "model", None)
    return P(*lead_dims, dp, "model", None, None)


def _cache_specs(cfg: ModelConfig, shape: ShapeConfig, axes: Axes,
                 tp_size: int, dp_size: int):
    b, s = shape.global_batch, shape.seq_len
    dp = _dp_for_batch(axes, dp_size, shape.global_batch)
    policy = _kv_policy(cfg, tp_size)
    kh, dh = cfg.n_kv_heads, cfg.d_head

    if cfg.family in ("dense", "moe"):
        kinds = _layer_kinds(cfg)
        g = cfg.n_layers // len(kinds)
        shapes, parts = {}, {}
        for j, kind in enumerate(kinds):
            clen = _cache_len(cfg, kind, s)
            sds = jax.ShapeDtypeStruct((g, b, clen, kh, dh), jnp.bfloat16)
            shapes[f"k{j}"] = shapes[f"v{j}"] = sds
            parts[f"k{j}"] = parts[f"v{j}"] = _kv_part(policy, dp)
        return shapes, parts

    if cfg.family == "encdec":
        ld = cfg.n_dec_layers
        kv = jax.ShapeDtypeStruct((ld, b, s, kh, dh), jnp.bfloat16)
        part = _kv_part(policy, dp)
        return ({"k": kv, "v": kv, "xk": kv, "xv": kv},
                {"k": part, "v": part, "xk": part, "xv": part})

    if cfg.family == "hybrid":
        g, period = _zgroups(cfg)
        d_inner, n_heads, conv_dim = ssm_dims(cfg)
        clen = min(cfg.shared_attn_window, s)
        kv = jax.ShapeDtypeStruct((g, b, clen, kh, dh), jnp.bfloat16)
        ssm = tuple(jax.ShapeDtypeStruct(
            (g, b, n_heads, cfg.ssm_state, 64), jnp.float32)
            for _ in range(period))
        conv = tuple(jax.ShapeDtypeStruct(
            (g, b, cfg.conv_kernel - 1, conv_dim), jnp.bfloat16)
            for _ in range(period))
        shapes = {"k": kv, "v": kv, "ssm": ssm, "conv": conv}
        parts = {"k": _kv_part(policy, dp), "v": _kv_part(policy, dp),
                 "ssm": tuple(P(None, dp, None, None, None)
                              for _ in range(period)),
                 "conv": tuple(P(None, dp, None, "model")
                               for _ in range(period))}
        return shapes, parts

    if cfg.family == "ssm":
        l, d = cfg.n_layers, cfg.d_model
        nh = d // 64
        shapes = {
            "tm_x": jax.ShapeDtypeStruct((l, b, d), jnp.bfloat16),
            "wkv": jax.ShapeDtypeStruct((l, b, nh, 64, 64), jnp.float32),
            "cm_x": jax.ShapeDtypeStruct((l, b, d), jnp.bfloat16),
        }
        parts = {"tm_x": P(None, dp, "model"),
                 "wkv": P(None, dp, "model", None, None),
                 "cm_x": P(None, dp, "model")}
        return shapes, parts

    raise ValueError(cfg.family)


def _zgroups(cfg: ModelConfig):
    return cfg.n_layers // cfg.attn_period, cfg.attn_period


# ---------------------------------------------------------------------------
# family bindings
# ---------------------------------------------------------------------------


def get_model(cfg: ModelConfig, *, tp_size: int = 16,
              dp_size: int = 1) -> ModelAPI:
    fam = cfg.family

    if fam in ("dense", "moe"):
        init = lambda key, dtype=jnp.bfloat16: transformer.init_lm(cfg, key, dtype)  # noqa: E731
        loss = lambda p, batch, axes, **kw: transformer.lm_loss(p, batch, cfg, axes, **kw)  # noqa: E731
        pre = lambda p, batch, axes, **kw: transformer.prefill(p, batch["tokens"], cfg, axes, **kw)  # noqa: E731
        dec = lambda p, cache, token, pos, axes: transformer.decode_step(p, cache, token, pos, cfg, axes)  # noqa: E731
    elif fam == "encdec":
        init = lambda key, dtype=jnp.bfloat16: encdec.init_encdec(cfg, key, dtype)  # noqa: E731
        loss = lambda p, batch, axes, **kw: encdec.seq2seq_loss(p, batch, cfg, axes, **kw)  # noqa: E731

        def pre(p, batch, axes, *, max_len=None):
            return encdec.prefill(p, batch["frames"], batch["tokens"], cfg,
                                  axes, max_len=max_len or batch["frames"].shape[1])
        dec = lambda p, cache, token, pos, axes: encdec.decode_step(p, cache, token, pos, cfg, axes)  # noqa: E731
    elif fam == "hybrid":
        init = lambda key, dtype=jnp.bfloat16: zamba.init_zamba(cfg, key, dtype)  # noqa: E731
        loss = lambda p, batch, axes, **kw: zamba.lm_loss(p, batch, cfg, axes, **kw)  # noqa: E731
        pre = lambda p, batch, axes, **kw: zamba.prefill(p, batch["tokens"], cfg, axes, **kw)  # noqa: E731
        dec = lambda p, cache, token, pos, axes: zamba.decode_step(p, cache, token, pos, cfg, axes)  # noqa: E731
    elif fam == "ssm":
        init = lambda key, dtype=jnp.bfloat16: rwkv.init_rwkv_lm(cfg, key, dtype)  # noqa: E731
        loss = lambda p, batch, axes, **kw: rwkv.lm_loss(p, batch, cfg, axes, **kw)  # noqa: E731
        pre = lambda p, batch, axes, **kw: rwkv.prefill(p, batch["tokens"], cfg, axes)  # noqa: E731
        dec = lambda p, cache, token, pos, axes: rwkv.decode_step(p, cache, token, pos, cfg, axes)  # noqa: E731
    else:
        raise ValueError(fam)

    return ModelAPI(
        cfg=cfg, init=init, loss=loss, prefill=pre, decode=dec,
        input_specs=lambda shape: _input_specs(cfg, shape),
        batch_partition=lambda shape, axes: _batch_partition(cfg, shape, axes,
                                                             dp_size),
        cache_specs=lambda shape, axes: _cache_specs(cfg, shape, axes,
                                                     tp_size, dp_size),
    )
