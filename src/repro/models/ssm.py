"""Mamba2 (SSD) block — the state-space component of zamba2.

Training/prefill uses the chunked state-space-dual form: a single
``lax.scan`` walks the chunks carrying the [B, H, N, P] state; each step
computes the intra-chunk quadratic path and the inter-chunk state
contribution for its chunk only, so the peak transient is one chunk's
[B, Q, Q, H] decay tensor (~10 MB at production shapes) instead of the
full sequence. All decay algebra in log space; exponents are <= 0 by
construction (A < 0, dt > 0).

    h_t = exp(dt_t A) h_{t-1} + dt_t * b_t x_t^T        (per head)
    y_t = c_t^T h_t + D * x_t
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from .common import Axes, ParamBuilder, rms_norm, shard

Array = jax.Array

_P_HEAD = 64   # mamba2 head dim


def ssm_dims(cfg: ModelConfig):
    d_inner = cfg.ssm_expand * cfg.d_model
    n_heads = d_inner // _P_HEAD
    conv_dim = d_inner + 2 * cfg.ssm_state
    return d_inner, n_heads, conv_dim


def init_mamba2(b: ParamBuilder, cfg: ModelConfig, prefix: str = ""):
    d = cfg.d_model
    d_inner, n_heads, conv_dim = ssm_dims(cfg)
    proj_out = 2 * d_inner + 2 * cfg.ssm_state + n_heads   # z, x, B, C, dt
    b.dense(prefix + "in_proj", (d, proj_out), P("data", "model"))
    b.dense(prefix + "conv_w", (cfg.conv_kernel, conv_dim), P(None, "model"),
            scale=0.5)
    b.zeros(prefix + "conv_b", (conv_dim,), P("model"))
    b.zeros(prefix + "dt_bias", (n_heads,), P(None))
    # A = -exp(A_log) in [-16, -1].
    b.params[prefix + "A_log"] = jnp.log(
        jnp.linspace(1.0, 16.0, n_heads, dtype=jnp.float32))
    b.specs[prefix + "A_log"] = P(None)
    b.ones(prefix + "D", (n_heads,), P(None))
    b.ones(prefix + "ssm_norm", (d_inner,), P("model"))
    b.dense(prefix + "out_proj", (d_inner, d), P("model", "data"))


def _split_proj(proj, cfg: ModelConfig):
    d_inner, n_heads, _ = ssm_dims(cfg)
    n = cfg.ssm_state
    z, xs, bb, cc, dt = jnp.split(
        proj, [d_inner, 2 * d_inner, 2 * d_inner + n, 2 * d_inner + 2 * n],
        axis=-1)
    return z, xs, bb, cc, dt


def _causal_conv(xbc, conv_w, conv_b, kernel: int):
    """Depthwise causal conv over [B, S, C]."""
    pad = jnp.pad(xbc, ((0, 0), (kernel - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xbc.shape[1], :] * conv_w[i][None, None, :]
              for i in range(kernel))
    return jax.nn.silu((out + conv_b).astype(jnp.float32)).astype(xbc.dtype)


def mamba2_block(p, x, cfg: ModelConfig, axes: Axes, *, chunk: int = 128,
                 prefix: str = "", initial_state=None, return_state=False):
    """x: [B, S, D] -> [B, S, D]. Optionally thread/return the SSM state."""
    bsz, s, _ = x.shape
    d_inner, n_heads, conv_dim = ssm_dims(cfg)
    n = cfg.ssm_state

    proj = x @ p[prefix + "in_proj"]
    z, xs, bmat, cmat, dt = _split_proj(proj, cfg)
    xbc_raw = jnp.concatenate([xs, bmat, cmat], axis=-1)   # pre-conv (state)
    xbc = _causal_conv(xbc_raw, p[prefix + "conv_w"], p[prefix + "conv_b"],
                       cfg.conv_kernel)
    xs, bmat, cmat = jnp.split(xbc, [d_inner, d_inner + n], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p[prefix + "dt_bias"])          # [B, S, H]
    a = -jnp.exp(p[prefix + "A_log"])                      # [H]
    ldec = dt * a[None, None, :]                           # [B, S, H] (<= 0)

    q = min(chunk, s)
    nc = -(-s // q)
    pad = nc * q - s
    if pad:
        xs = jnp.pad(xs, ((0, 0), (0, pad), (0, 0)))
        bmat = jnp.pad(bmat, ((0, 0), (0, pad), (0, 0)))
        cmat = jnp.pad(cmat, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        ldec = jnp.pad(ldec, ((0, 0), (0, pad), (0, 0)))

    def chunked(t, *feat):   # [B, nc*q, ...] -> [nc, B, q, ...]
        return t.reshape(bsz, nc, q, *feat).transpose(1, 0, 2, *range(3, 3 + len(feat)))

    xs_c = chunked(xs.reshape(bsz, nc * q, n_heads, _P_HEAD), n_heads, _P_HEAD)
    b_c = chunked(bmat, n)
    c_c = chunked(cmat, n)
    dt_c = chunked(dt, n_heads)
    l_c = chunked(ldec, n_heads)
    tri = jnp.tril(jnp.ones((q, q), bool))

    def scan_fn(state, inp):
        xc, bc, cc_, dtc, lc = inp                    # [B, q, ...]
        cum = jnp.cumsum(lc, axis=1)                  # [B, q, H]
        xf = xc.astype(jnp.float32)
        bf = bc.astype(jnp.float32)
        cf = cc_.astype(jnp.float32)
        # intra: y[t] = sum_{i<=t} (c_t.b_i) exp(cum_t-cum_i) dt_i x_i
        dots = jnp.einsum("bts,bis->bti", cf, bf)     # [B, q, q]
        # mask the EXPONENT, not the exponential: for i > t the difference is
        # positive and exp overflows to +inf; where(tri, inf, 0) then leaks
        # 0 * inf = NaN into the cotangent of exp in the backward pass.
        diff = cum[:, :, None, :] - cum[:, None, :, :]           # [B,q,q,H]
        ddec = jnp.exp(jnp.where(tri[None, :, :, None], diff, -jnp.inf))
        g = ddec * dots[..., None] * dtc[:, None, :, :]
        y = jnp.einsum("btih,bihp->bthp", g, xf)
        # inter: y[t] += exp(cum_t) c_t . state
        y += jnp.einsum("bth,bts,bhsp->bthp", jnp.exp(cum), cf, state)
        # state update: S <- exp(cum_Q) S + sum_i exp(cum_Q-cum_i) dt_i b_i x_i
        tail = jnp.exp(cum[:, -1:, :] - cum) * dtc     # [B, q, H]
        s_new = state * jnp.exp(cum[:, -1, :])[:, :, None, None] \
            + jnp.einsum("bih,bis,bihp->bhsp", tail, bf, xf)
        return s_new, y

    init = initial_state if initial_state is not None else \
        jnp.zeros((bsz, n_heads, n, _P_HEAD), jnp.float32)
    final_state, ys = jax.lax.scan(scan_fn, init, (xs_c, b_c, c_c, dt_c, l_c))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(bsz, nc * q, n_heads, _P_HEAD)
    y = y[:, :s] + p[prefix + "D"][None, None, :, None] \
        * xs[:, :s].reshape(bsz, s, n_heads, _P_HEAD).astype(jnp.float32)
    y = y.reshape(bsz, s, d_inner).astype(x.dtype)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    y = rms_norm(y, p[prefix + "ssm_norm"])
    y = shard(y, axes, "dp", None, "tp")
    out = y @ p[prefix + "out_proj"]
    if return_state:
        conv_state = xbc_raw[:, s - (cfg.conv_kernel - 1):s]
        return out, (final_state, conv_state)
    return out


def mamba2_decode(p, x, state, cfg: ModelConfig, axes: Axes,
                  prefix: str = ""):
    """One-token step. x: [B, 1, D]; state = (ssm [B,H,N,P], conv [B,k-1,C]).

    conv_state holds the last kernel-1 PRE-conv xBC rows (same convention as
    ``mamba2_block(return_state=True)``), so prefill -> decode handoff is
    exact."""
    bsz = x.shape[0]
    d_inner, n_heads, conv_dim = ssm_dims(cfg)
    n = cfg.ssm_state
    ssm_state, conv_state = state

    proj = x[:, 0] @ p[prefix + "in_proj"]
    z, xs, bmat, cmat, dt = _split_proj(proj, cfg)
    xbc_new = jnp.concatenate([xs, bmat, cmat], axis=-1)    # [B, C]
    window = jnp.concatenate([conv_state, xbc_new[:, None]], axis=1)
    conv_w = p[prefix + "conv_w"]
    out = jnp.einsum("bkc,kc->bc", window.astype(jnp.float32),
                     conv_w.astype(jnp.float32)) + p[prefix + "conv_b"]
    xbc = jax.nn.silu(out).astype(x.dtype)
    xs, bmat, cmat = jnp.split(xbc, [d_inner, d_inner + n], axis=-1)
    xs = xs.reshape(bsz, n_heads, _P_HEAD)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p[prefix + "dt_bias"])
    a = -jnp.exp(p[prefix + "A_log"])
    dec = jnp.exp(dt * a[None, :])                          # [B, H]
    upd = jnp.einsum("bh,bs,bhp->bhsp", dt, bmat.astype(jnp.float32),
                     xs.astype(jnp.float32))
    ssm_state = ssm_state * dec[:, :, None, None] + upd
    y = jnp.einsum("bs,bhsp->bhp", cmat.astype(jnp.float32), ssm_state)
    y = y + p[prefix + "D"][None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(bsz, d_inner).astype(x.dtype)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    y = rms_norm(y, p[prefix + "ssm_norm"])
    out = (y @ p[prefix + "out_proj"])[:, None]
    return out, (ssm_state, window[:, 1:])
