"""Zamba2 hybrid: a Mamba2 backbone with ONE shared attention+MLP block
applied every ``attn_period`` layers (weight sharing — the Zamba trick).

Layout: 54 mamba layers in groups of 6; after each group the shared
transformer block runs (same weights every time, its own KV cache per
application: cache leaves carry a leading [n_groups] dim).

long_500k adaptation (DESIGN.md §5): the shared attention runs on a
``shared_attn_window`` ring buffer when the cache length exceeds it — the
Mamba2 state carries long-range information.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from .attention import attention_block, decode_attention, init_attention
from .common import (Axes, ParamBuilder, chunked_cross_entropy, rms_norm,
                     shard, stack_params)
from .mlp import init_mlp, mlp_block
from .ssm import init_mamba2, mamba2_block, mamba2_decode, ssm_dims

Array = jax.Array


def _n_groups(cfg: ModelConfig) -> int:
    assert cfg.n_layers % cfg.attn_period == 0
    return cfg.n_layers // cfg.attn_period


def init_zamba(cfg: ModelConfig, key: Array, dtype=jnp.bfloat16):
    period = cfg.attn_period
    groups = _n_groups(cfg)
    keys = jax.random.split(key, cfg.n_layers + 2)
    blocks = []
    for i in range(cfg.n_layers):
        b = ParamBuilder(keys[i], dtype)
        init_mamba2(b, cfg)
        b.ones("ln", (cfg.d_model,), P(None))
        blocks.append(b.build())
    stacked = stack_params([p for p, _ in blocks])
    stacked = jax.tree.map(
        lambda a: a.reshape(groups, period, *a.shape[1:]), stacked)
    layer_specs = jax.tree.map(lambda s: P(None, None, *s), blocks[0][1],
                               is_leaf=lambda x: isinstance(x, P))

    sb = ParamBuilder(keys[-2], dtype)          # the ONE shared block
    init_attention(sb, cfg)
    init_mlp(sb, cfg.d_model, cfg.d_ff)
    sb.ones("ln1", (cfg.d_model,), P(None))
    sb.ones("ln2", (cfg.d_model,), P(None))
    shared, shared_specs = sb.build()

    b = ParamBuilder(keys[-1], dtype)
    b.dense("embed", (cfg.vocab_size, cfg.d_model), P("model", "data"),
            scale=cfg.d_model ** -0.5)
    b.ones("final_norm", (cfg.d_model,), P(None))
    params, specs = b.build()
    params["layers"], specs["layers"] = stacked, layer_specs
    params["shared"], specs["shared"] = shared, shared_specs
    return params, specs


def _shared_block_fwd(sp, x, cfg: ModelConfig, axes: Axes,
                      collect_cache: bool):
    a, kv = attention_block(sp, rms_norm(x, sp["ln1"]), cfg, axes,
                            window=None)
    x = x + a
    x = x + mlp_block(sp, rms_norm(x, sp["ln2"]), axes)
    return x, (kv if collect_cache else None)


def forward(params, tokens, cfg: ModelConfig, axes: Axes, *,
            remat: bool = True, collect_state: bool = False):
    period = cfg.attn_period
    x = jnp.take(params["embed"], tokens, axis=0)
    x = shard(x, axes, "dp", "tp", None)
    shared = params["shared"]

    def group_fn(x, gp):
        ssm_states = []
        for j in range(period):
            pj = jax.tree.map(lambda a: a[j], gp)
            h = mamba2_block(pj, rms_norm(x, pj["ln"]), cfg, axes,
                             return_state=collect_state)
            if collect_state:
                h, st = h
                ssm_states.append(st)
            x = x + h
            x = shard(x, axes, "dp", "tp", None)
        x, kv = _shared_block_fwd(shared, x, cfg, axes, collect_state)
        ys = (tuple(ssm_states), kv) if collect_state else None
        return x, ys

    body = group_fn
    if remat:
        body = jax.checkpoint(
            group_fn, policy=jax.checkpoint_policies.nothing_saveable)
    x, states = jax.lax.scan(body, x, params["layers"])
    x = rms_norm(x, params["final_norm"])
    return x, states


def lm_loss(params, batch, cfg: ModelConfig, axes: Axes, *,
            remat: bool = True) -> Array:
    hidden, _ = forward(params, batch["tokens"], cfg, axes, remat=remat)
    b, s, d = hidden.shape
    return chunked_cross_entropy(hidden.reshape(b * s, d), params["embed"],
                                 batch["labels"].reshape(b * s))


def prefill(params, tokens, cfg: ModelConfig, axes: Axes, *,
            max_len: int | None = None):
    b, s = tokens.shape
    max_len = max_len or s
    hidden, states = forward(params, tokens, cfg, axes, remat=False,
                             collect_state=True)
    ssm_states, (k, v) = states           # tuples over period slots
    clen = min(cfg.shared_attn_window, max_len)
    if clen < s:
        k = jnp.roll(k[:, :, -clen:], s % clen, axis=2)
        v = jnp.roll(v[:, :, -clen:], s % clen, axis=2)
    elif clen > s:
        padw = ((0, 0), (0, 0), (0, clen - s), (0, 0), (0, 0))
        k, v = jnp.pad(k, padw), jnp.pad(v, padw)
    cache = {"k": k, "v": v,
             "ssm": tuple(st[0] for st in ssm_states),
             "conv": tuple(st[1] for st in ssm_states)}
    logits = (hidden[:, -1] @ params["embed"].T.astype(hidden.dtype)
              ).astype(jnp.float32)
    return cache, logits


def decode_step(params, cache, token, pos, cfg: ModelConfig, axes: Axes):
    period = cfg.attn_period
    x = jnp.take(params["embed"], token[:, None], axis=0)
    shared = params["shared"]
    window = cache["k"].shape[2]

    def group_fn(x, xs):
        gp, gcache = xs
        new_ssm, new_conv = [], []
        for j in range(period):
            pj = jax.tree.map(lambda a: a[j], gp)
            st = (gcache["ssm"][j], gcache["conv"][j])
            h, st = mamba2_decode(pj, rms_norm(x, pj["ln"]), st, cfg, axes)
            new_ssm.append(st[0])
            new_conv.append(st[1])
            x = x + h
        a, ck, cv = decode_attention(
            shared, rms_norm(x, shared["ln1"]), gcache["k"], gcache["v"],
            pos, cfg, axes, window=cfg.shared_attn_window
            if window == cfg.shared_attn_window else None)
        x = x + a
        x = x + mlp_block(shared, rms_norm(x, shared["ln2"]), axes)
        return x, {"k": ck, "v": cv, "ssm": tuple(new_ssm),
                   "conv": tuple(new_conv)}

    x, new_cache = jax.lax.scan(group_fn, x, (params["layers"], cache))
    x = rms_norm(x, params["final_norm"])
    logits = (x[:, 0] @ params["embed"].T.astype(x.dtype)).astype(jnp.float32)
    return logits, new_cache
