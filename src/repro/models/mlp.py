"""Dense SwiGLU FFN and capacity-based top-k MoE.

MoE baseline is a sort-free GShard-style capacity dispatch expressed entirely
in jit-level ops (scatter into an [E, cap, D] buffer, expert einsum, gather
back). Expert d_ff is TP-sharded over ``model``; the expert dim is replicated
and FSDP-sharded over ``data``. The EP all-to-all variant is the documented
§Perf hillclimb for the MoE cells (see EXPERIMENTS.md).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.compat import shard_map
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from .common import Axes, ambient_mesh, shard, swiglu

Array = jax.Array


def init_mlp(b, d_model: int, d_ff: int, prefix: str = ""):
    b.dense(prefix + "w_gate", (d_model, d_ff), P("data", "model"))
    b.dense(prefix + "w_up", (d_model, d_ff), P("data", "model"))
    b.dense(prefix + "w_down", (d_ff, d_model), P("model", "data"))


def mlp_block(p, x, axes: Axes, prefix: str = "") -> Array:
    h = swiglu(x @ p[prefix + "w_gate"], x @ p[prefix + "w_up"])
    h = shard(h, axes, "dp", None, "tp")
    return h @ p[prefix + "w_down"]


def init_moe(b, cfg: ModelConfig, prefix: str = ""):
    e, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff
    if cfg.moe_ep_groups:
        # replicated router (2 MB/layer) — the shard_map body consumes it
        # whole; a D-sharded router would force a gather per layer.
        b.dense(prefix + "router", (d, e), P(None, None), dtype=jnp.float32)
    else:
        b.dense(prefix + "router", (d, e), P("data", None),
                dtype=jnp.float32)
    if cfg.moe_ep_groups:
        # expert parallelism: experts sharded over the data axis (weights
        # stay local to their expert group; dispatch moves tokens instead)
        b.dense(prefix + "e_gate", (e, d, f), P("data", None, "model"))
        b.dense(prefix + "e_up", (e, d, f), P("data", None, "model"))
        b.dense(prefix + "e_down", (e, f, d), P("data", "model", None))
    else:
        b.dense(prefix + "e_gate", (e, d, f), P(None, "data", "model"))
        b.dense(prefix + "e_up", (e, d, f), P(None, "data", "model"))
        b.dense(prefix + "e_down", (e, f, d), P(None, "model", "data"))


def moe_block(p, x, cfg: ModelConfig, axes: Axes, prefix: str = "") -> Array:
    """Top-k capacity-dropping MoE. x: [B, S, D] -> [B, S, D].

    Dropped tokens (capacity overflow) contribute 0 (residual passthrough).
    Dispatches to the expert-parallel grouped path when cfg.moe_ep_groups
    is set (EXPERIMENTS.md §Perf hillclimb B).
    """
    if cfg.moe_ep_groups:
        return moe_block_ep(p, x, cfg, axes, prefix=prefix)
    bsz, s, d = x.shape
    e, k = cfg.n_experts, cfg.moe_top_k
    t = bsz * s
    xt = x.reshape(t, d)
    cap = int(t * k / e * cfg.capacity_factor)
    cap = max(128, -(-cap // 128) * 128)           # lane-aligned

    logits = (xt @ p[prefix + "router"]).astype(jnp.float32)     # [T, E]
    top_w, top_e = jax.lax.top_k(logits, k)                      # [T, k]
    top_w = jax.nn.softmax(top_w, axis=-1).astype(x.dtype)

    e_ids = top_e.reshape(-1)                                     # [T*k]
    onehot = jax.nn.one_hot(e_ids, e, dtype=jnp.int32)            # [T*k, E]
    pos_all = jnp.cumsum(onehot, axis=0) - onehot                 # exclusive
    pos = jnp.sum(pos_all * onehot, axis=1)                       # [T*k]

    tok_ids = jnp.arange(t * k) // k
    x_slots = jnp.take(xt, tok_ids, axis=0)                       # [T*k, D]
    buf = jnp.zeros((e, cap, d), x.dtype)
    buf = buf.at[e_ids, pos].set(x_slots, mode="drop")
    buf = shard(buf, axes, None, "dp", None)

    h = swiglu(jnp.einsum("ecd,edf->ecf", buf, p[prefix + "e_gate"]),
               jnp.einsum("ecd,edf->ecf", buf, p[prefix + "e_up"]))
    h = shard(h, axes, None, "dp", "tp")
    y = jnp.einsum("ecf,efd->ecd", h, p[prefix + "e_down"])       # [E, cap, D]

    kept = pos < cap
    out_slots = y[e_ids, jnp.minimum(pos, cap - 1)]               # [T*k, D]
    out_slots = jnp.where(kept[:, None], out_slots, 0.0)
    out_slots = out_slots * top_w.reshape(-1)[:, None]
    return jnp.sum(out_slots.reshape(t, k, d), axis=1).reshape(bsz, s, d)


def moe_block_ep(p, x, cfg: ModelConfig, axes: Axes,
                 prefix: str = "") -> Array:
    """Expert-parallel top-k MoE — shard_map dispatch when the launcher has
    set an ambient mesh (explicit all_to_all; EXPERIMENTS.md §Perf hillclimb
    B v3), else the GSPMD-annotation fallback below."""
    mesh = ambient_mesh()
    if mesh is not None and "data" in mesh.axis_names:
        return _moe_block_ep_shardmap(p, x, cfg, axes, mesh, prefix=prefix)
    return _moe_block_ep_gspmd(p, x, cfg, axes, prefix=prefix)


def _moe_block_ep_shardmap(p, x, cfg: ModelConfig, axes: Axes, mesh,
                           prefix: str = "") -> Array:
    """GShard-on-TPU dispatch, hand-written collectives (one all_to_all over
    'data' each way, one all-gather + one psum_scatter over 'model').

    Per device: tokens route into a LOCAL [E, cap_local, D] buffer
    (cap_local = T_local*k/E*cf — G x smaller than the global-capacity
    buffer); the 'data' all_to_all moves each expert's slots to its owner
    shard; the 'model' all-gather assembles every model-shard's token set so
    the F-sharded expert weights see full rows; psum_scatter returns each
    shard its own tokens reduced over F.
    """
    e, k = cfg.n_experts, cfg.moe_top_k
    b, s, d = x.shape
    dpd = mesh.shape["data"]
    tp = mesh.shape.get("model", 1) if axes.tp else 1
    assert e % dpd == 0, (e, dpd)
    s_spec = "model" if (tp > 1 and s % tp == 0) else None
    dp = axes.dp if len(axes.dp) > 1 else axes.dp[0]

    from jax.sharding import PartitionSpec as P

    def body(xl, router, wg, wu, wd):
        bl, sl, _ = xl.shape
        tl = bl * sl
        xt = xl.reshape(tl, d)
        logits = (xt @ router).astype(jnp.float32)             # [tl, E]
        top_w, top_e = jax.lax.top_k(logits, k)
        top_w = jax.nn.softmax(top_w, axis=-1).astype(xl.dtype)
        capl = max(8, -(-int(tl * k / e * cfg.capacity_factor) // 8) * 8)

        e_ids = top_e.reshape(-1)                              # [tl*k]
        onehot = jax.nn.one_hot(e_ids, e, dtype=jnp.int32)
        pos = jnp.sum((jnp.cumsum(onehot, axis=0) - onehot) * onehot,
                      axis=1)
        kept = pos < capl
        slot = jnp.where(kept, e_ids * capl + pos, e * capl)   # OOB = drop
        xslots = jnp.take(xt, jnp.arange(tl * k) // k, axis=0)
        buf = jnp.zeros((e * capl, d), xl.dtype)
        buf = buf.at[slot].set(xslots, mode="drop").reshape(e, capl, d)

        if dpd > 1:   # tokens -> expert owners   [E/dpd, dpd*capl, D]
            buf = jax.lax.all_to_all(buf, "data", split_axis=0,
                                     concat_axis=1, tiled=True)
        if tp > 1:    # assemble every model shard's tokens
            buf = jax.lax.all_gather(buf, "model", axis=1, tiled=True)

        h = swiglu(jnp.einsum("ecd,edf->ecf", buf, wg),
                   jnp.einsum("ecd,edf->ecf", buf, wu))
        y = jnp.einsum("ecf,efd->ecd", h, wd)                  # partial on F
        if tp > 1:
            y = jax.lax.psum_scatter(y, "model", scatter_dimension=1,
                                     tiled=True)
        if dpd > 1:   # back to token owners  [E, capl, D]
            y = jax.lax.all_to_all(y, "data", split_axis=1,
                                   concat_axis=0, tiled=True)

        yflat = y.reshape(e * capl, d)
        out = jnp.take(yflat, jnp.where(kept, slot, 0), axis=0)
        out = jnp.where(kept[:, None], out, 0.0)             * top_w.reshape(-1)[:, None]
        return jnp.sum(out.reshape(tl, k, d), axis=1).reshape(bl, sl, d)

    fn = shard_map(
        body, mesh=mesh,
        in_specs=(P(dp, s_spec, None), P(None, None),
                  P("data", None, "model"), P("data", None, "model"),
                  P("data", "model", None)),
        out_specs=P(dp, s_spec, None), check_vma=False)
    return fn(x, p[prefix + "router"], p[prefix + "e_gate"],
              p[prefix + "e_up"], p[prefix + "e_down"])


def _moe_block_ep_gspmd(p, x, cfg: ModelConfig, axes: Axes,
                        prefix: str = "") -> Array:
    """GShard-style expert-parallel top-k MoE (beyond-paper §Perf).

    Differences vs the dense-dispatch ``moe_block``:
      * tokens are processed in G = cfg.moe_ep_groups groups (the data
        shards); CAPACITY IS PER GROUP: cap_g = T_g * k / E * cf — the
        dispatch buffer shrinks by G x vs the global-capacity formulation;
      * experts are sharded over the data axis (weights local to their
        group), so moving the [G, E, cap_g, D] buffer from group-major to
        expert-major sharding is ONE all-to-all each way (GSPMD inserts
        exactly that for the G<->E resharding), instead of per-layer
        all-reduces of global-capacity buffers.
    """
    bsz, s, d = x.shape
    e, k = cfg.n_experts, cfg.moe_top_k
    g = cfg.moe_ep_groups
    t = bsz * s
    assert t % g == 0, (t, g)
    tg = t // g
    cap = int(tg * k / e * cfg.capacity_factor)
    cap = max(8, -(-cap // 8) * 8)

    xt = x.reshape(g, tg, d)                       # groups = dp shards
    xt = shard(xt, axes, "dp", None, None)

    logits = (xt @ p[prefix + "router"]).astype(jnp.float32)   # [G, TG, E]
    top_w, top_e = jax.lax.top_k(logits, k)                    # [G, TG, k]
    top_w = jax.nn.softmax(top_w, axis=-1).astype(x.dtype)

    e_ids = top_e.reshape(g, tg * k)                           # [G, TG*k]
    onehot = jax.nn.one_hot(e_ids, e, dtype=jnp.int32)         # [G, TG*k, E]
    pos_all = jnp.cumsum(onehot, axis=1) - onehot              # per-group
    pos = jnp.sum(pos_all * onehot, axis=2)                    # [G, TG*k]

    tok_ids = jnp.arange(tg * k) // k
    x_slots = jnp.take(xt, tok_ids, axis=1)                    # [G, TG*k, D]
    # single-axis scatter on the flattened (g, e, cap) slot space: multi-dim
    # fancy indexing lowers to scatters with BROADCAST index tensors
    # ([G, TG*k, D] u32) whose resharding swamps the step — flat row ids
    # lower to collapsed-dim scatters with no index blow-up.
    kept = pos < cap
    slot_ids = (jnp.arange(g)[:, None] * (e * cap) + e_ids * cap + pos)
    slot_ids = jnp.where(kept, slot_ids, g * e * cap)          # drop -> OOB
    buf = jnp.zeros((g * e * cap, d), x.dtype)
    buf = buf.at[slot_ids.reshape(-1)].set(
        x_slots.reshape(-1, d), mode="drop")
    buf = buf.reshape(g, e, cap, d)
    # group-major: G over dp (each group built its own dispatch locally)
    buf = shard(buf, axes, "dp", None, None, None)
    # expert-major: E over dp -> GSPMD inserts the all-to-all
    buf = shard(buf, axes, None, "dp", None, None)

    h = swiglu(jnp.einsum("gecd,edf->gecf", buf, p[prefix + "e_gate"]),
               jnp.einsum("gecd,edf->gecf", buf, p[prefix + "e_up"]))
    h = shard(h, axes, None, "dp", None, "tp")
    y = jnp.einsum("gecf,efd->gecd", h, p[prefix + "e_down"])
    y = shard(y, axes, None, "dp", None, None)
    # back to group-major (second all-to-all)
    y = shard(y, axes, "dp", None, None, None)

    y_flat = y.reshape(g * e * cap, d)
    gather_ids = jnp.where(kept, slot_ids, 0).reshape(-1)      # [G*TG*k]
    out_slots = jnp.take(y_flat, gather_ids, axis=0).reshape(g, tg * k, d)
    out_slots = jnp.where(kept[..., None], out_slots, 0.0)
    out_slots = out_slots * top_w.reshape(g, tg * k)[..., None]
    out = jnp.sum(out_slots.reshape(g, tg, k, d), axis=2)
    return out.reshape(bsz, s, d)
