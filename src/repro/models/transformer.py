"""Decoder-only LM family: qwen3 / internlm2 / gemma2 / olmo / qwen3-moe /
grok-1 / chameleon (VQ-token early fusion shares the text backbone).

Layers are stacked [n_groups, period, ...] and consumed by ``lax.scan`` over
groups (period = 2 for gemma2's local/global alternation, else 1) so the HLO
stays O(1) in depth — essential for compiling 64-94 layer models for 512
SPMD devices on this box. Remat policy: save only the residual stream at
group boundaries (``jax.checkpoint`` on the scan body).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from .attention import (attention_block, decode_attention, init_attention)
from .common import (Axes, ParamBuilder, chunked_cross_entropy, rms_norm,
                     shard, stack_params, stack_specs)
from .mlp import init_mlp, init_moe, mlp_block, moe_block

Array = jax.Array


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _layer_kinds(cfg: ModelConfig) -> tuple[str, ...]:
    """Attention kind per slot within one pattern group."""
    if cfg.local_global_period:
        # gemma2: [local, global] alternating.
        return tuple("local" if j % 2 == 0 else "global"
                     for j in range(cfg.local_global_period))
    return ("local" if cfg.window else "global",)


def _norm_name(cfg: ModelConfig):
    return None if not cfg.parametric_norm else "w"


def _init_block(key, cfg: ModelConfig, dtype) -> tuple[dict, dict]:
    b = ParamBuilder(key, dtype)
    init_attention(b, cfg)
    if cfg.n_experts:
        init_moe(b, cfg)
    else:
        init_mlp(b, cfg.d_model, cfg.d_ff)
    if cfg.parametric_norm:
        norm_init = b.zeros if cfg.gemma_plus_one else b.ones
        norm_init("ln1", (cfg.d_model,), P(None))
        norm_init("ln2", (cfg.d_model,), P(None))
        if cfg.sandwich_norm:
            norm_init("post_ln1", (cfg.d_model,), P(None))
            norm_init("post_ln2", (cfg.d_model,), P(None))
    return b.build()


def init_lm(cfg: ModelConfig, key: Array, dtype=jnp.bfloat16):
    period = max(cfg.local_global_period, 1)
    assert cfg.n_layers % period == 0
    n_groups = cfg.n_layers // period

    keys = jax.random.split(key, cfg.n_layers + 2)
    blocks = []
    spec_block = None
    for i in range(cfg.n_layers):
        p, s = _init_block(keys[i], cfg, dtype)
        blocks.append(p)
        spec_block = s
    # stack to [G, period, ...]
    stacked = stack_params(blocks)
    stacked = jax.tree.map(
        lambda a: a.reshape(n_groups, period, *a.shape[1:]), stacked)
    layer_specs = jax.tree.map(lambda s: P(None, None, *s), spec_block,
                               is_leaf=lambda x: isinstance(x, P))

    b = ParamBuilder(keys[-1], dtype)
    b.dense("embed", (cfg.vocab_size, cfg.d_model), P("model", "data"),
            scale=cfg.d_model ** -0.5)
    if not cfg.tie_embeddings:
        b.dense("lm_head", (cfg.d_model, cfg.vocab_size), P("data", "model"))
    if cfg.parametric_norm:
        (b.zeros if cfg.gemma_plus_one else b.ones)(
            "final_norm", (cfg.d_model,), P(None))
    params, specs = b.build()
    params["layers"], specs["layers"] = stacked, layer_specs
    return params, specs


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------


def _maybe_norm(p, name: str, x, cfg: ModelConfig):
    w = p.get(name) if cfg.parametric_norm else None
    return rms_norm(x, w, plus_one=cfg.gemma_plus_one)


def _block_fwd(pj, x, cfg: ModelConfig, axes: Axes, kind: str, *,
               positions=None, collect_cache=False, q_chunk=512):
    window = cfg.window if kind == "local" else None
    h = _maybe_norm(pj, "ln1", x, cfg)
    a, kv = attention_block(pj, h, cfg, axes, window=window,
                            positions=positions, q_chunk=q_chunk)
    # constrain the row-parallel block OUTPUT before the residual add: the
    # partial-sum over 'model' then lowers to one reduce-scatter into the
    # sequence-sharded layout instead of all-reduce + slice (§Perf cell C).
    a = shard(a, axes, "dp", "tp", None)
    if cfg.sandwich_norm:
        a = _maybe_norm(pj, "post_ln1", a, cfg)
    x = shard(x + a, axes, "dp", "tp", None)      # sequence-parallel residual
    h = _maybe_norm(pj, "ln2", x, cfg)
    m = (moe_block(pj, h, cfg, axes) if cfg.n_experts
         else mlp_block(pj, h, axes))
    m = shard(m, axes, "dp", "tp", None)
    if cfg.sandwich_norm:
        m = _maybe_norm(pj, "post_ln2", m, cfg)
    x = shard(x + m, axes, "dp", "tp", None)
    return x, (kv if collect_cache else None)


def _block_decode(pj, x, cache_j, pos, cfg: ModelConfig, axes: Axes,
                  kind: str):
    window = cfg.window if kind == "local" else None
    h = _maybe_norm(pj, "ln1", x, cfg)
    a, ck, cv = decode_attention(pj, h, cache_j["k"], cache_j["v"], pos, cfg,
                                 axes, window=window)
    if cfg.sandwich_norm:
        a = _maybe_norm(pj, "post_ln1", a, cfg)
    x = x + a
    h = _maybe_norm(pj, "ln2", x, cfg)
    m = (moe_block(pj, h, cfg, axes) if cfg.n_experts
         else mlp_block(pj, h, axes))
    if cfg.sandwich_norm:
        m = _maybe_norm(pj, "post_ln2", m, cfg)
    return x + m, {"k": ck, "v": cv}


# ---------------------------------------------------------------------------
# forward passes
# ---------------------------------------------------------------------------


def _embed(params, tokens, cfg: ModelConfig):
    x = jnp.take(params["embed"], tokens, axis=0)
    if cfg.gemma_plus_one:                          # gemma scales embeddings
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    return x


def forward(params, tokens, cfg: ModelConfig, axes: Axes, *,
            remat: bool = True, collect_cache: bool = False,
            inputs_embeds: Array | None = None,
            q_chunk: int | None = None):
    q_chunk = q_chunk or cfg.q_chunk
    """Full-sequence forward. Returns (hidden [B,S,D], caches | None)."""
    kinds = _layer_kinds(cfg)
    period = len(kinds)
    x = inputs_embeds if inputs_embeds is not None \
        else _embed(params, tokens, cfg)
    x = shard(x, axes, "dp", "tp", None)

    def group_fn(x, gp):
        caches = []
        for j, kind in enumerate(kinds):
            pj = jax.tree.map(lambda a: a[j], gp)
            x, kv = _block_fwd(pj, x, cfg, axes, kind,
                               collect_cache=collect_cache, q_chunk=q_chunk)
            caches.append(kv)
        ys = tuple(caches) if collect_cache else None
        return x, ys

    body = group_fn
    if remat:
        body = jax.checkpoint(
            group_fn, policy=jax.checkpoint_policies.nothing_saveable)
    x, caches = jax.lax.scan(body, x, params["layers"])
    x = _maybe_norm(params, "final_norm", x, cfg)
    return x, caches


def lm_loss(params, batch, cfg: ModelConfig, axes: Axes, *,
            remat: bool = True, q_chunk: int | None = None) -> Array:
    q_chunk = q_chunk or cfg.q_chunk
    hidden, _ = forward(params, batch["tokens"], cfg, axes, remat=remat,
                        q_chunk=q_chunk)
    b, s, d = hidden.shape
    emb = params.get("lm_head")
    emb = params["embed"] if emb is None else emb.T
    return chunked_cross_entropy(
        hidden.reshape(b * s, d), emb, batch["labels"].reshape(b * s),
        logit_softcap=cfg.final_softcap)


def _logits_last(params, hidden_last, cfg: ModelConfig):
    """hidden_last: [B, D] -> [B, V]."""
    emb = params.get("lm_head")
    w = params["embed"].T if emb is None else emb
    logits = (hidden_last @ w.astype(hidden_last.dtype)).astype(jnp.float32)
    if cfg.final_softcap is not None:
        logits = cfg.final_softcap * jnp.tanh(logits / cfg.final_softcap)
    return logits


def _cache_len(cfg: ModelConfig, kind: str, seq_len: int) -> int:
    return min(cfg.window, seq_len) if (kind == "local" and cfg.window) \
        else seq_len


def prefill(params, tokens, cfg: ModelConfig, axes: Axes, *,
            max_len: int | None = None, q_chunk: int = 512):
    """Run the prompt, return (cache pytree, last-token logits [B, V]).

    Local (windowed) layers keep a ring buffer of the last ``window``
    positions (jnp.roll aligns absolute-position slots — see DESIGN.md)."""
    kinds = _layer_kinds(cfg)
    b, s = tokens.shape
    max_len = max_len or s
    hidden, caches = forward(params, tokens, cfg, axes, collect_cache=True)
    # caches: tuple over period slots of (k, v) each [G, B, S, KH, dh]
    cache = {}
    for j, kind in enumerate(kinds):
        k, v = caches[j]
        clen = _cache_len(cfg, kind, max_len)
        if clen < s:
            k = jnp.roll(k[:, :, -clen:], s % clen, axis=2)
            v = jnp.roll(v[:, :, -clen:], s % clen, axis=2)
        elif clen > s:
            padw = ((0, 0), (0, 0), (0, clen - s), (0, 0), (0, 0))
            k, v = jnp.pad(k, padw), jnp.pad(v, padw)
        cache[f"k{j}"], cache[f"v{j}"] = k, v
    return cache, _logits_last(params, hidden[:, -1], cfg)


def decode_step(params, cache, token, pos, cfg: ModelConfig, axes: Axes):
    """One token for the whole stack. token: [B] int32, pos: scalar int32.

    Returns (logits [B, V] fp32, updated cache)."""
    kinds = _layer_kinds(cfg)
    x = _embed(params, token[:, None], cfg)         # [B, 1, D]

    def group_fn(x, xs):
        gp, gcache = xs
        new_cache = {}
        for j, kind in enumerate(kinds):
            pj = jax.tree.map(lambda a: a[j], gp)
            cj = {"k": gcache[f"k{j}"], "v": gcache[f"v{j}"]}
            x, cj = _block_decode(pj, x, cj, pos, cfg, axes, kind)
            new_cache[f"k{j}"], new_cache[f"v{j}"] = cj["k"], cj["v"]
        return x, new_cache

    x, new_cache = jax.lax.scan(group_fn, x, (params["layers"], cache))
    x = _maybe_norm(params, "final_norm", x, cfg)
    return _logits_last(params, x[:, 0], cfg), new_cache
