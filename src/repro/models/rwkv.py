"""RWKV6 "Finch" (attn-free, data-dependent decay) — the rwkv6-7b arch.

Recurrence per head (K = V = 64 per-head channels):

    out_t = r_t . (diag(u) k_t v_t^T + S_{t-1})
    S_t   = diag(w_t) S_{t-1} + k_t v_t^T          w_t = exp(-exp(wx_t))

Training/prefill uses a GLA-style chunked form: one ``lax.scan`` over chunks
carrying the [B, H, K, V] state; the intra-chunk quadratic path works in
log-decay space with the per-chunk cumulative clamped at -30 (decay products
below e^-30 are exactly 0 in fp32 regardless).

Simplification vs the released checkpoints (DESIGN.md §8): the token-shift
interpolation uses static per-channel mu for r/k/v/g; the decay w keeps the
full data-dependent LoRA (that *is* the Finch contribution). Channel-mix is
faithful (r-gated squared-ReLU).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from .common import (Axes, ParamBuilder, chunked_cross_entropy, rms_norm,
                     shard, stack_params)

Array = jax.Array

_K_HEAD = 64
_LORA = 64


def rwkv_dims(cfg: ModelConfig):
    n_heads = cfg.d_model // _K_HEAD
    return n_heads


def init_time_mix(b: ParamBuilder, cfg: ModelConfig):
    d = cfg.d_model
    b.dense("wr", (d, d), P("data", "model"))
    b.dense("wk", (d, d), P("data", "model"))
    b.dense("wv", (d, d), P("data", "model"))
    b.dense("wg", (d, d), P("data", "model"))
    b.dense("wo", (d, d), P("model", "data"))
    for nm in ("mu_r", "mu_k", "mu_v", "mu_g", "mu_w"):
        b.params[nm] = jnp.full((d,), 0.5, jnp.float32)
        b.specs[nm] = P(None)
    b.zeros("w0", (d,), P(None))
    b.dense("w1", (d, _LORA), P("data", None), scale=0.1)
    b.dense("w2", (_LORA, d), P(None, "data"), scale=0.1)
    b.zeros("u", (d,), P(None))            # bonus, per channel
    b.ones("ln_x", (d,), P(None))          # per-head group norm weight


def init_channel_mix(b: ParamBuilder, cfg: ModelConfig):
    d, f = cfg.d_model, cfg.d_ff
    b.params["cmu_k"] = jnp.full((d,), 0.5, jnp.float32)
    b.specs["cmu_k"] = P(None)
    b.params["cmu_r"] = jnp.full((d,), 0.5, jnp.float32)
    b.specs["cmu_r"] = P(None)
    b.dense("ck", (d, f), P("data", "model"))
    b.dense("cv", (f, d), P("model", "data"))
    b.dense("cr", (d, d), P("data", "model"))


def _token_shift(x, x_last=None):
    """[B, S, D] -> previous-token features (zeros / carried at t=0)."""
    prev = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    if x_last is not None:
        prev = prev.at[:, 0].set(x_last)
    return prev


def _wkv_chunked(r, k, v, logw, u, n_heads: int, *, chunk: int = 64,
                 initial_state=None):
    """r/k/v/logw: [B, S, D]; u: [D]. Returns ([B, S, D], final_state)."""
    bsz, s, d = r.shape
    q = min(chunk, s)
    nc = -(-s // q)
    pad = nc * q - s
    if pad:
        r, k, v = (jnp.pad(t, ((0, 0), (0, pad), (0, 0))) for t in (r, k, v))
        logw = jnp.pad(logw, ((0, 0), (0, pad), (0, 0)))

    def heads(t):   # [B, nc*q, D] -> [nc, B, H, q, Kh]
        return t.reshape(bsz, nc, q, n_heads, _K_HEAD).transpose(1, 0, 3, 2, 4)

    rc, kc, vc, lw = heads(r), heads(k), heads(v), heads(logw)
    uh = u.reshape(n_heads, _K_HEAD)
    tri_strict = jnp.tril(jnp.ones((q, q), bool), k=-1)

    def scan_fn(state, inp):
        rr, kk, vv, ww = (t.astype(jnp.float32) for t in inp)  # [B,H,q,K]
        cum = jnp.clip(jnp.cumsum(ww, axis=2), -30.0, 0.0)     # [B,H,q,K]
        # intra: out_t = sum_{i<t} (r_t . exp(cum_{t-1} - cum_i) k_i) v_i
        cum_prev = jnp.pad(cum, ((0, 0), (0, 0), (1, 0), (0, 0)))[:, :, :-1]
        a = rr * jnp.exp(cum_prev)                             # [B,H,q,K]
        bmat = kk * jnp.exp(-cum)                              # [B,H,q,K]
        scores = jnp.einsum("bhtk,bhik->bhti", a, bmat)
        scores = jnp.where(tri_strict[None, None], scores, 0.0)
        out = jnp.einsum("bhti,bhiv->bhtv", scores, vv)
        # diagonal bonus: (r_t . u k_t) v_t
        diag = jnp.sum(rr * kk * uh[None, :, None, :], axis=-1)
        out += diag[..., None] * vv
        # inter: out_t += (r_t . exp(cum_{t-1})) @ state
        out += jnp.einsum("bhtk,bhkv->bhtv", a, state)
        # state update: S <- diag(exp(cum_Q)) S + sum_i exp(cum_Q - cum_i) k_i v_i
        wq = cum[:, :, -1:, :]
        kdec = kk * jnp.exp(jnp.clip(wq - cum, -30.0, 0.0))
        s_new = state * jnp.exp(wq[:, :, 0, :])[..., None] \
            + jnp.einsum("bhik,bhiv->bhkv", kdec, vv)
        return s_new, out

    init = initial_state if initial_state is not None else \
        jnp.zeros((bsz, n_heads, _K_HEAD, _K_HEAD), jnp.float32)
    final_state, ys = jax.lax.scan(scan_fn, init, (rc, kc, vc, lw))
    out = ys.transpose(1, 0, 3, 2, 4).reshape(bsz, nc * q, d)
    return out[:, :s], final_state


def time_mix(p, x, cfg: ModelConfig, axes: Axes, *, state=None,
             chunk: int = 64):
    """RWKV6 attention analogue. state = (x_last [B,D], wkv [B,H,K,V]) or
    None (training). Returns (out, new_state)."""
    n_heads = rwkv_dims(cfg)
    bsz, s, d = x.shape
    x_last, wkv0 = state if state is not None else (None, None)
    prev = _token_shift(x, x_last)

    def lerp(mu):
        return x + (prev - x) * mu[None, None, :].astype(x.dtype)

    r = lerp(p["mu_r"]) @ p["wr"]
    k = lerp(p["mu_k"]) @ p["wk"]
    v = lerp(p["mu_v"]) @ p["wv"]
    g = lerp(p["mu_g"]) @ p["wg"]
    # data-dependent decay (the Finch mechanism).
    xw = lerp(p["mu_w"]).astype(jnp.float32)
    wx = p["w0"] + jnp.tanh(xw @ p["w1"].astype(jnp.float32)) \
        @ p["w2"].astype(jnp.float32)
    logw = -jnp.exp(wx)                                     # [B, S, D] < 0

    r = shard(r, axes, "dp", None, "tp")
    k = shard(k, axes, "dp", None, "tp")
    v = shard(v, axes, "dp", None, "tp")
    out, wkv = _wkv_chunked(r, k, v, logw, p["u"], n_heads, chunk=chunk,
                            initial_state=wkv0)
    # per-head group norm (RMS over each head's K channels) + ln_x gain
    out = out.reshape(bsz, s, n_heads, _K_HEAD)
    out = rms_norm(out, None)
    out = out.reshape(bsz, s, d) * p["ln_x"][None, None, :].astype(out.dtype)
    out = out.astype(x.dtype) * jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype)
    new_state = (x[:, -1], wkv)
    return out @ p["wo"], new_state


def channel_mix(p, x, cfg: ModelConfig, *, x_last=None):
    prev = _token_shift(x, x_last)

    def lerp(mu):
        return x + (prev - x) * mu[None, None, :].astype(x.dtype)

    k = jax.nn.relu((lerp(p["cmu_k"]) @ p["ck"]).astype(jnp.float32)) ** 2
    v = k.astype(x.dtype) @ p["cv"]
    r = jax.nn.sigmoid((lerp(p["cmu_r"]) @ p["cr"]).astype(jnp.float32))
    return r.astype(x.dtype) * v, x[:, -1]


# ---------------------------------------------------------------------------
# full RWKV6 LM
# ---------------------------------------------------------------------------


def init_rwkv_lm(cfg: ModelConfig, key: Array, dtype=jnp.bfloat16):
    keys = jax.random.split(key, cfg.n_layers + 1)
    blocks = []
    for i in range(cfg.n_layers):
        b = ParamBuilder(keys[i], dtype)
        init_time_mix(b, cfg)
        init_channel_mix(b, cfg)
        b.ones("ln1", (cfg.d_model,), P(None))
        b.ones("ln2", (cfg.d_model,), P(None))
        blocks.append(b.build())
    stacked = stack_params([p for p, _ in blocks])
    layer_specs = jax.tree.map(lambda s: P(None, *s), blocks[0][1],
                               is_leaf=lambda x: isinstance(x, P))
    b = ParamBuilder(keys[-1], dtype)
    b.dense("embed", (cfg.vocab_size, cfg.d_model), P("model", "data"),
            scale=cfg.d_model ** -0.5)
    b.ones("ln_in", (cfg.d_model,), P(None))
    b.ones("final_norm", (cfg.d_model,), P(None))
    params, specs = b.build()
    params["layers"], specs["layers"] = stacked, layer_specs
    return params, specs


def forward(params, tokens, cfg: ModelConfig, axes: Axes, *,
            remat: bool = True, collect_state: bool = False,
            chunk: int = 64):
    x = jnp.take(params["embed"], tokens, axis=0)
    x = rms_norm(x, params["ln_in"])
    x = shard(x, axes, "dp", "tp", None)

    def layer_fn(x, lp):
        h, tm_state = time_mix(lp, rms_norm(x, lp["ln1"]), cfg, axes,
                               chunk=chunk)
        x = x + h
        h, cm_last = channel_mix(lp, rms_norm(x, lp["ln2"]), cfg)
        x = x + h
        ys = (tm_state, cm_last) if collect_state else None
        return x, ys

    body = layer_fn
    if remat:
        body = jax.checkpoint(
            layer_fn, policy=jax.checkpoint_policies.nothing_saveable)
    x, states = jax.lax.scan(body, x, params["layers"])
    x = rms_norm(x, params["final_norm"])
    return x, states


def lm_loss(params, batch, cfg: ModelConfig, axes: Axes, *,
            remat: bool = True) -> Array:
    hidden, _ = forward(params, batch["tokens"], cfg, axes, remat=remat)
    b, s, d = hidden.shape
    return chunked_cross_entropy(hidden.reshape(b * s, d), params["embed"],
                                 batch["labels"].reshape(b * s))


def prefill(params, tokens, cfg: ModelConfig, axes: Axes, *, chunk: int = 64):
    hidden, states = forward(params, tokens, cfg, axes, remat=False,
                             collect_state=True, chunk=chunk)
    (x_last, wkv), cm_last = states
    cache = {"tm_x": x_last, "wkv": wkv, "cm_x": cm_last}
    logits = (hidden[:, -1] @ params["embed"].T.astype(hidden.dtype)
              ).astype(jnp.float32)
    return cache, logits


def decode_step(params, cache, token, pos, cfg: ModelConfig, axes: Axes):
    x = jnp.take(params["embed"], token[:, None], axis=0)
    x = rms_norm(x, params["ln_in"])

    def layer_fn(x, xs):
        lp, tm_x, wkv, cm_x = xs
        h, (tm_x_new, wkv_new) = time_mix(
            lp, rms_norm(x, lp["ln1"]), cfg, axes, state=(tm_x, wkv))
        x = x + h
        h, cm_x_new = channel_mix(lp, rms_norm(x, lp["ln2"]), cfg,
                                  x_last=cm_x)
        x = x + h
        return x, {"tm_x": tm_x_new, "wkv": wkv_new, "cm_x": cm_x_new}

    x, new_cache = jax.lax.scan(
        layer_fn, x, (params["layers"], cache["tm_x"], cache["wkv"],
                      cache["cm_x"]))
    x = rms_norm(x, params["final_norm"])
    logits = (x[:, 0] @ params["embed"].T.astype(x.dtype)).astype(jnp.float32)
    return logits, new_cache
