"""GQA attention: training/prefill (chunked flash-style) and decode paths.

Decode KV-cache sharding policy (DESIGN.md §6):
  * n_kv_heads %  tp-size == 0  -> cache sharded over kv heads (classic TP);
  * otherwise                   -> cache sharded over the SEQUENCE dim with a
    numerically-stable partial-softmax combine (flash-decode) expressed so
    GSPMD keeps the reduction local and psums only [B, H, dh]-sized partials.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from .common import Axes, apply_rope, chunked_attention, rms_norm, shard

Array = jax.Array


class AttnParams(NamedTuple):
    wq: Array          # [D, H*dh]
    wk: Array          # [D, KH*dh]
    wv: Array          # [D, KH*dh]
    wo: Array          # [H*dh, D]
    q_norm: Array | None
    k_norm: Array | None


def init_attention(b, cfg: ModelConfig, prefix: str = ""):
    """Add attention params to a ParamBuilder ``b``."""
    d, h, kh, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    from jax.sharding import PartitionSpec as P
    b.dense(prefix + "wq", (d, h * dh), P("data", "model"))
    b.dense(prefix + "wk", (d, kh * dh), P("data", "model"))
    b.dense(prefix + "wv", (d, kh * dh), P("data", "model"))
    b.dense(prefix + "wo", (h * dh, d), P("model", "data"))
    if cfg.qk_norm:
        b.ones(prefix + "qn", (dh,), P(None))
        b.ones(prefix + "kn", (dh,), P(None))


def _project_qkv(p, x, cfg: ModelConfig, axes: Axes, positions, prefix=""):
    b, s, _ = x.shape
    h, kh, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    q = (x @ p[prefix + "wq"]).reshape(b, s, h, dh)
    k = (x @ p[prefix + "wk"]).reshape(b, s, kh, dh)
    v = (x @ p[prefix + "wv"]).reshape(b, s, kh, dh)
    q = shard(q, axes, "dp", None, "tp", None)
    k = shard(k, axes, "dp", None, None, None)
    v = shard(v, axes, "dp", None, None, None)
    if cfg.qk_norm:
        q = rms_norm(q, p[prefix + "qn"])
        k = rms_norm(k, p[prefix + "kn"])
    q = apply_rope(q, positions, theta=cfg.rope_theta)
    k = apply_rope(k, positions, theta=cfg.rope_theta)
    return q, k, v


def attention_block(p, x, cfg: ModelConfig, axes: Axes, *,
                    window: int | None, causal: bool = True,
                    positions: Array | None = None, prefix: str = "",
                    q_chunk: int = 512):
    """Full-sequence attention (training / prefill). Returns (out, (k, v))."""
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.arange(s)[None, :]
    q, k, v = _project_qkv(p, x, cfg, axes, positions, prefix)
    if cfg.attn_impl == "flash" and window is None:
        # Pallas flash kernel (scores stay in VMEM — EXPERIMENTS.md §Perf
        # C3). [B,S,H,dh] -> [B,H,S,dh]; interpret mode off-TPU.
        from repro.kernels.ops import flash_attention, use_pallas
        out = flash_attention(
            q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
            v.transpose(0, 2, 1, 3), causal=causal,
            softcap=cfg.attn_softcap,
            interpret=not use_pallas()).transpose(0, 2, 1, 3)
    else:
        out = chunked_attention(q, k, v, causal=causal, window=window,
                                attn_softcap=cfg.attn_softcap,
                                q_chunk=q_chunk)
    out = out.reshape(b, s, cfg.n_heads * cfg.d_head)
    return out @ p[prefix + "wo"], (k, v)


def cross_attention_block(p, x, memory_kv, cfg: ModelConfig, axes: Axes,
                          prefix: str = "x_"):
    """Decoder cross-attention against precomputed encoder (k, v)."""
    b, s, _ = x.shape
    h, dh = cfg.n_heads, cfg.d_head
    q = (x @ p[prefix + "wq"]).reshape(b, s, h, dh)
    q = shard(q, axes, "dp", None, "tp", None)
    k, v = memory_kv
    out = chunked_attention(q, k, v, causal=False, window=None,
                            attn_softcap=cfg.attn_softcap)
    out = out.reshape(b, s, h * dh)
    return out @ p[prefix + "wo"]


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------


def decode_attention(p, x, cache_k, cache_v, pos, cfg: ModelConfig,
                     axes: Axes, *, window: int | None = None,
                     prefix: str = "") -> tuple[Array, Array, Array]:
    """One-token decode: update cache at ``pos``, attend over the cache.

    x: [B, 1, D]; cache_k/v: [B, S, KH, dh] (ring buffer when ``window``).
    ``pos`` is a scalar OR a per-slot [B] vector (continuous batching: each
    request in the batch sits at its own cursor).
    Returns (out [B, 1, D], cache_k, cache_v).
    """
    b = x.shape[0]
    h, kh, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    s = cache_k.shape[1]
    pos_b = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))   # [B]
    q, k, v = _project_qkv(p, x, cfg, axes, pos_b[:, None], prefix)

    slot_b = pos_b % s if window is not None else pos_b
    # per-slot scatter along the sequence dim (one row per batch element)
    cache_k = cache_k.at[jnp.arange(b), slot_b].set(
        k[:, 0].astype(cache_k.dtype))
    cache_v = cache_v.at[jnp.arange(b), slot_b].set(
        v[:, 0].astype(cache_v.dtype))

    # scores over the cache: [B, KH, G, S]
    groups = h // kh
    qg = q.reshape(b, kh, groups, dh)
    scores = jnp.einsum("bkgd,bskd->bkgs", qg.astype(jnp.float32),
                        cache_k.astype(jnp.float32)) * dh ** -0.5
    if cfg.attn_softcap is not None:
        scores = cfg.attn_softcap * jnp.tanh(scores / cfg.attn_softcap)
    kpos = jnp.arange(s)
    if window is not None:
        # ring buffer: before wrap-around only slots <= pos hold data; after
        # the first wrap every slot is a live (windowed) entry.
        valid = (kpos[None, :] <= pos_b[:, None]) | (pos_b[:, None] >= s)
    else:
        valid = kpos[None, :] <= pos_b[:, None]
    scores = jnp.where(valid[:, None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", probs, cache_v.astype(jnp.float32))
    out = out.reshape(b, 1, h * dh).astype(x.dtype)
    return out @ p[prefix + "wo"], cache_k, cache_v


def decode_cross_attention(p, x, memory_kv, cfg: ModelConfig, axes: Axes,
                           prefix: str = "x_") -> Array:
    b = x.shape[0]
    h, dh = cfg.n_heads, cfg.d_head
    q = (x @ p[prefix + "wq"]).reshape(b, 1, h, dh)
    k, v = memory_kv                              # [B, Sm, KH, dh]
    kh = k.shape[2]
    groups = h // kh
    qg = q.reshape(b, kh, groups, dh)
    scores = jnp.einsum("bkgd,bskd->bkgs", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * dh ** -0.5
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", probs, v.astype(jnp.float32))
    return out.reshape(b, 1, h * dh).astype(x.dtype) @ p[prefix + "wo"]
