from .common import Axes
from .registry import ModelAPI, get_model

__all__ = ["Axes", "ModelAPI", "get_model"]
