"""Encoder-decoder backbone (seamless-m4t-medium).

[audio] modality: the speech frontend is a STUB per the assignment —
``input_specs()`` provides precomputed frame embeddings [B, S, D] for the
encoder; the decoder is a standard text decoder with cross-attention.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from .attention import (attention_block, cross_attention_block,
                        decode_attention, decode_cross_attention,
                        init_attention)
from .common import (Axes, ParamBuilder, chunked_cross_entropy,
                     mask_vocab_pad, padded_vocab_size, rms_norm, shard,
                     stack_params)
from .mlp import init_mlp, mlp_block

Array = jax.Array


def _init_enc_block(key, cfg, dtype):
    b = ParamBuilder(key, dtype)
    init_attention(b, cfg)
    init_mlp(b, cfg.d_model, cfg.d_ff)
    b.ones("ln1", (cfg.d_model,), P(None))
    b.ones("ln2", (cfg.d_model,), P(None))
    return b.build()


def _init_dec_block(key, cfg, dtype):
    b = ParamBuilder(key, dtype)
    init_attention(b, cfg)                      # self-attention
    init_attention(b, cfg, prefix="x_")         # cross-attention
    init_mlp(b, cfg.d_model, cfg.d_ff)
    b.ones("ln1", (cfg.d_model,), P(None))
    b.ones("lnx", (cfg.d_model,), P(None))
    b.ones("ln2", (cfg.d_model,), P(None))
    return b.build()


def init_encdec(cfg: ModelConfig, key: Array, dtype=jnp.bfloat16):
    ke, kd, ko = jax.random.split(key, 3)
    enc = [_init_enc_block(k, cfg, dtype)
           for k in jax.random.split(ke, cfg.n_enc_layers)]
    dec = [_init_dec_block(k, cfg, dtype)
           for k in jax.random.split(kd, cfg.n_dec_layers)]
    enc_p = stack_params([p for p, _ in enc])
    dec_p = stack_params([p for p, _ in dec])
    lspec = lambda tree: jax.tree.map(      # noqa: E731
        lambda s: P(None, *s), tree, is_leaf=lambda x: isinstance(x, P))
    b = ParamBuilder(ko, dtype)
    b.dense("embed", (padded_vocab_size(cfg.vocab_size), cfg.d_model),
            P("model", "data"), scale=cfg.d_model ** -0.5)
    b.ones("enc_final", (cfg.d_model,), P(None))
    b.ones("dec_final", (cfg.d_model,), P(None))
    params, specs = b.build()
    params["encoder"], specs["encoder"] = enc_p, lspec(enc[0][1])
    params["decoder"], specs["decoder"] = dec_p, lspec(dec[0][1])
    return params, specs


def encode(params, frames, cfg: ModelConfig, axes: Axes, *,
           remat: bool = True):
    """frames: [B, S_enc, D] precomputed frontend embeddings (stub)."""
    x = shard(frames, axes, "dp", "tp", None)

    def block(x, lp):
        a, _ = attention_block(lp, rms_norm(x, lp["ln1"]), cfg, axes,
                               window=None, causal=False)
        x = shard(x + a, axes, "dp", "tp", None)
        x = x + mlp_block(lp, rms_norm(x, lp["ln2"]), axes)
        return shard(x, axes, "dp", "tp", None), None

    body = jax.checkpoint(block, policy=jax.checkpoint_policies
                          .nothing_saveable) if remat else block
    x, _ = jax.lax.scan(body, x, params["encoder"])
    return rms_norm(x, params["enc_final"])


def _memory_kv(lp, memory, cfg: ModelConfig):
    """Per-decoder-layer cross-attention K/V from encoder output."""
    b, s, _ = memory.shape
    kh, dh = cfg.n_kv_heads, cfg.d_head
    k = (memory @ lp["x_wk"]).reshape(b, s, kh, dh)
    v = (memory @ lp["x_wv"]).reshape(b, s, kh, dh)
    return k, v


def decode_train(params, tokens, memory, cfg: ModelConfig, axes: Axes, *,
                 remat: bool = True, collect_cache: bool = False):
    x = jnp.take(params["embed"], tokens, axis=0)
    x = shard(x, axes, "dp", "tp", None)

    def block(x, lp):
        a, kv = attention_block(lp, rms_norm(x, lp["ln1"]), cfg, axes,
                                window=None, causal=True)
        x = x + a
        mem_kv = _memory_kv(lp, memory, cfg)
        x = x + cross_attention_block(lp, rms_norm(x, lp["lnx"]), mem_kv,
                                      cfg, axes)
        x = x + mlp_block(lp, rms_norm(x, lp["ln2"]), axes)
        x = shard(x, axes, "dp", "tp", None)
        ys = (kv, mem_kv) if collect_cache else None
        return x, ys

    body = jax.checkpoint(block, policy=jax.checkpoint_policies
                          .nothing_saveable) if remat else block
    x, caches = jax.lax.scan(body, x, params["decoder"])
    return rms_norm(x, params["dec_final"]), caches


def seq2seq_loss(params, batch, cfg: ModelConfig, axes: Axes, *,
                 remat: bool = True) -> Array:
    memory = encode(params, batch["frames"], cfg, axes, remat=remat)
    hidden, _ = decode_train(params, batch["tokens"], memory, cfg, axes,
                             remat=remat)
    b, s, d = hidden.shape
    return chunked_cross_entropy(hidden.reshape(b * s, d), params["embed"],
                                 batch["labels"].reshape(b * s),
                                 n_valid_vocab=cfg.vocab_size)


def prefill(params, frames, tokens, cfg: ModelConfig, axes: Axes, *,
            max_len: int):
    """Encode + prime the decoder with ``tokens``; cache self KV (padded to
    max_len) and cross KV."""
    memory = encode(params, frames, cfg, axes, remat=False)
    hidden, caches = decode_train(params, tokens, memory, cfg, axes,
                                  remat=False, collect_cache=True)
    (k, v), (xk, xv) = caches
    s = tokens.shape[1]
    if max_len > s:
        padw = ((0, 0), (0, 0), (0, max_len - s), (0, 0), (0, 0))
        k, v = jnp.pad(k, padw), jnp.pad(v, padw)
    cache = {"k": k, "v": v, "xk": xk, "xv": xv}
    logits = (hidden[:, -1] @ params["embed"].T.astype(hidden.dtype)
              ).astype(jnp.float32)
    return cache, mask_vocab_pad(logits, cfg.vocab_size)


def decode_step(params, cache, token, pos, cfg: ModelConfig, axes: Axes):
    x = jnp.take(params["embed"], token[:, None], axis=0)

    def block(x, xs):
        lp, c = xs
        a, ck, cv = decode_attention(lp, rms_norm(x, lp["ln1"]), c["k"],
                                     c["v"], pos, cfg, axes)
        x = x + a
        x = x + decode_cross_attention(lp, rms_norm(x, lp["lnx"]),
                                       (c["xk"], c["xv"]), cfg, axes)
        x = x + mlp_block(lp, rms_norm(x, lp["ln2"]), axes)
        return x, {"k": ck, "v": cv, "xk": c["xk"], "xv": c["xv"]}

    x, new_cache = jax.lax.scan(block, x, (params["decoder"], cache))
    x = rms_norm(x, params["dec_final"])
    logits = (x[:, 0] @ params["embed"].T.astype(x.dtype)).astype(jnp.float32)
    return mask_vocab_pad(logits, cfg.vocab_size), new_cache
