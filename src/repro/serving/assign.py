"""Continuous-batching assignment service over a frozen predict artifact.

The serving problem is ragged: requests arrive with 1..N query rows, and a
naive ``jit(predict)`` retraces (and recompiles) per distinct row count —
unbounded compile amplification on the hottest path. This engine applies
the PR 3 memoized-mesh-program trick to inference: every request is padded
into a SMALL FIXED LADDER of shape buckets (rows in ``DEFAULT_BUCKETS``),
so the whole service runs on ``len(buckets)`` compiled programs, total.
Those programs are AOT-compiled at load time (``AssignService.warm`` via
``jax.jit(...).lower(...).compile()``) so the first request pays zero
compile; ``AssignService.compiled_programs`` is the literal program count
the bucket audit (``launch/audit.py``) pins to the ladder size.

Padding safety: padded rows are zeros and the per-row argmin is row-
independent (``score_ij = |c_j|^2 - 2 z_i . c_j`` — no cross-row term), so
a padded row can never perturb a real row's assignment; real labels are
sliced back out before they leave the engine (booby-trapped test in
tests/test_serving_assign.py feeds garbage padding and asserts identity).

Ingestion:
  * dense rows -> the AOT bucket program over ``ops.predict_assign``
    (fused Pallas pass on TPU/GPU — Z never in HBM — jnp oracle math off-
    accelerator; one program per bucket either way);
  * CSR rows (sketch kinds) -> per-request O(nnz) path: rows pad to the
    bucket, stored slots pad to a power-of-two nnz ladder
    (``data.sparse.pad_csr_capacity``), so the jit cache stays bounded by
    buckets x nnz-rungs. rff/nystrom/exact artifacts have no O(nnz)
    embedding — CSR requests densify at ingestion (row-local, documented);
  * tensorsketch dense -> the documented jnp FFT program (no fused tile
    kernel), still one program per bucket.

Per-request queue/compute latency lands in ``repro.obs``
(serve/queue_seconds, serve/compute_seconds, serve/request events);
``benchmarks/serve_bench.py`` drives an offered-QPS open loop over this
engine and records p50/p99 into BENCH_serve.json.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.sparse import (CSRBatch, is_sparse, pad_csr_capacity,
                               slice_rows, to_dense)
from repro.kernels import ops
from repro.obs import resolve

from .artifact import FUSED_KINDS, FrozenArtifact

Array = jax.Array

#: the shape ladder: requests pad to the smallest bucket that fits; bigger
#: requests chunk by the largest. 4 buckets == 4 compiled programs, total.
DEFAULT_BUCKETS = (1, 8, 64, 512)

#: kinds whose artifact carries an O(nnz) sketch map for CSR ingestion.
SKETCH_KINDS = ("sketch", "tensorsketch")


class QueueFull(RuntimeError):
    """Admission control: the queue holds ``max_queue_rows`` already."""


def bucket_for(n: int, buckets: tuple[int, ...]) -> int:
    """Smallest ladder bucket holding ``n`` rows (callers chunk by the
    largest bucket first, so ``n <= buckets[-1]`` always)."""
    for b in buckets:
        if n <= b:
            return b
    raise ValueError(f"{n} rows exceed the largest bucket {buckets[-1]}")


def _resolve_runtime(art: FrozenArtifact, fused=None, interpret=None,
                     backend=None):
    """Fill the runtime knobs from the live jax backend (cpu CI defaults:
    fused=False / interpret=True; TPU/GPU: fused Pallas, native lowering)."""
    from repro.kernels.backend import kernel_backend
    platform = jax.default_backend()
    if fused is None:
        fused = ops.use_pallas() and art.kind in FUSED_KINDS
    elif fused and art.kind not in FUSED_KINDS:
        raise ValueError(
            f"kind {art.kind!r} has no fused kernel (FUSED_KINDS="
            f"{FUSED_KINDS}); its documented jnp program serves instead")
    if interpret is None:
        interpret = platform not in ("tpu", "gpu")
    if backend is None or backend == "auto":
        backend = kernel_backend()
    return bool(fused), bool(interpret), backend


def _statics(art: FrozenArtifact) -> dict:
    """The jit-static kwargs of ``ops.predict_assign`` for this artifact."""
    s = art.statics
    if art.kind == "sketch":
        return dict(map_kind="sketch")
    return dict(map_kind=s["map_kind"], gamma=float(s["gamma"]),
                coef0=float(s["coef0"]), degree=int(s["degree"]),
                scale=float(s["scale"]))


@jax.jit
def _score_assign(z: Array, v: Array, csq: Array) -> Array:
    """argmin_j csq_j - 2 z.v_j over an already-embedded bucket."""
    f = jax.lax.dot_general(z, v.astype(jnp.float32),
                            (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    score = csq[None, :].astype(jnp.float32) - 2.0 * f
    return jnp.argmin(score, axis=1).astype(jnp.int32)


@jax.jit
def _ts_assign(x: Array, fmap, v: Array, csq: Array) -> Array:
    """TensorSketch bucket program (documented jnp FFT path — the map has
    no Pallas tile lowering; see kernels/ops.embed_assign)."""
    return _score_assign(fmap(x), v, csq)


@partial(jax.jit, static_argnames=("precision",))
def _csr_assign(batch: CSRBatch, fmap, v: Array, csq: Array, *,
                precision: str = "f32") -> Array:
    """Per-request O(nnz) CSR bucket program (sketch kinds).

    The stored values are the tile operand: rounded to the policy dtype
    then accumulated f32, matching the dense sketch path's semantics.
    Slack slots beyond ``indptr[-1]`` hold zeros (capacity contract) and
    scatter nothing.
    """
    if precision != "f32":
        from repro.kernels.precision import resolve_precision
        p = resolve_precision(precision)
        batch = dataclasses.replace(
            batch, data=p.cast_tiles(jnp.asarray(batch.data))
            .astype(jnp.float32))
    return _score_assign(fmap(batch), v, csq)


def _predict_padded(art: FrozenArtifact, xp: Array, *, fused: bool,
                    interpret: bool, backend: str) -> Array:
    """One already-padded dense bucket -> labels (jit-cached per bucket)."""
    a = art.arrays
    if art.kind == "exact":
        from repro.core.minibatch import predict as exact_predict
        return exact_predict(xp, a["medoids"], a["medoid_diag"],
                             spec=art.kernel_spec())
    if art.kind == "tensorsketch":
        # precision: TS has no tile knob (documented f32 FFT fallback)
        return _ts_assign(xp, art.feature_map(), a["v"], a["csq"])
    w_key, aux_key = ("h", "sign") if art.kind == "sketch" else ("w", "aux")
    labels, _ = ops.predict_assign(
        xp, a[w_key], a[aux_key], a["v"], a["csq"], fused=fused,
        interpret=interpret, precision=art.precision, backend=backend,
        **_statics(art))
    return labels


def _pad_csr(piece: CSRBatch, rows: int) -> CSRBatch:
    """Pad a CSR piece to ``rows`` bucket rows and a power-of-two stored-
    slot capacity, bounding the jit cache to buckets x nnz-rungs."""
    stored = int(np.asarray(piece.indptr)[-1])
    cap = 1 << max(0, (max(stored, 1) - 1).bit_length())
    return pad_csr_capacity([piece], rows=rows, nnz_multiple=cap)[0]


def predict(art: FrozenArtifact, x, *,
            buckets: tuple[int, ...] = DEFAULT_BUCKETS,
            fused: bool | None = None, interpret: bool | None = None,
            backend: str | None = None) -> Array:
    """Offline bucket-routed prediction (the ``FitResult.predict`` path).

    Chunks ``x`` by the largest bucket, zero-pads the remainder to the
    smallest bucket that fits, runs the per-bucket compiled program and
    slices the real labels back — so ANY query count reuses the same
    ``len(buckets)`` programs instead of retracing per shape.
    """
    buckets = tuple(sorted({int(b) for b in buckets}))
    fused, interpret, backend = _resolve_runtime(art, fused, interpret,
                                                 backend)
    if is_sparse(x):
        return _predict_csr(art, x, buckets, fused=fused,
                            interpret=interpret, backend=backend)
    xh = np.asarray(x, np.float32)
    if xh.ndim != 2 or xh.shape[1] != art.in_dim:
        raise ValueError(f"queries must be [n, {art.in_dim}], "
                         f"got {xh.shape}")
    n, d = xh.shape
    out = np.empty((n,), np.int32)
    start, bmax = 0, buckets[-1]
    while start < n:
        take = min(bmax, n - start)
        b = bucket_for(take, buckets)
        xp = np.zeros((b, d), np.float32)
        xp[:take] = xh[start:start + take]
        labels = _predict_padded(art, jnp.asarray(xp), fused=fused,
                                 interpret=interpret, backend=backend)
        out[start:start + take] = np.asarray(labels)[:take]
        start += take
    return jnp.asarray(out)


def _predict_csr(art: FrozenArtifact, batch: CSRBatch,
                 buckets: tuple[int, ...], *, fused: bool, interpret: bool,
                 backend: str) -> Array:
    if art.kind not in SKETCH_KINDS:
        # no O(nnz) embedding for these maps — densify (row-local; the
        # documented CSR story for rff/nystrom/exact artifacts)
        return predict(art, to_dense(batch), buckets=buckets, fused=fused,
                       interpret=interpret, backend=backend)
    fmap = art.feature_map()
    a = art.arrays
    n = batch.shape[0]
    out = np.empty((n,), np.int32)
    start, bmax = 0, buckets[-1]
    while start < n:
        take = min(bmax, n - start)
        b = bucket_for(take, buckets)
        piece = _pad_csr(slice_rows(batch, start, start + take), b)
        labels = _csr_assign(piece, fmap, a["v"], a["csq"],
                             precision=art.precision)
        out[start:start + take] = np.asarray(labels)[:take]
        start += take
    return jnp.asarray(out)


@dataclasses.dataclass(frozen=True)
class AssignServeConfig:
    """Knobs of the continuous-batching engine.

    ``fused``/``interpret``/``backend`` default to the live jax platform
    (``None`` -> auto); ``warm`` AOT-compiles every bucket program at
    construction so the first request pays no compile.
    """
    buckets: tuple[int, ...] = DEFAULT_BUCKETS
    max_queue_rows: int = 4096
    fused: bool | None = None
    interpret: bool | None = None
    backend: str | None = None
    warm: bool = True

    def __post_init__(self):
        if not self.buckets:
            raise ValueError("need at least one bucket")
        object.__setattr__(self, "buckets",
                           tuple(sorted({int(b) for b in self.buckets})))


@dataclasses.dataclass
class _Request:
    uid: int
    x: object            # np dense [n, d] or CSRBatch
    n: int
    t_submit: float
    labels: np.ndarray   # [n] int32, filled as ticks complete rows
    filled: int = 0


class AssignService:
    """Continuous-batching assignment server over a ``FrozenArtifact``.

    ``submit`` enqueues a request (admission-controlled); ``step`` packs
    the FIFO head into the smallest bucket that fits, runs ONE compiled
    program, and scatters labels back to their requests — partial
    consumption lets a 512-row request drain across ticks while 1-row
    requests ride along in the same bucket. ``drain`` ticks until empty.
    """

    def __init__(self, artifact: FrozenArtifact,
                 cfg: AssignServeConfig = AssignServeConfig(), *,
                 recorder=None):
        self.artifact = artifact
        self.cfg = cfg
        self.rec = resolve(recorder)
        self._fused, self._interpret, self._backend = _resolve_runtime(
            artifact, cfg.fused, cfg.interpret, cfg.backend)
        self._queue: collections.deque[_Request] = collections.deque()
        self._pending_rows = 0
        self._uid = 0
        self._programs: dict[int, object] = {}
        self._fmap = (artifact.feature_map()
                      if artifact.kind in SKETCH_KINDS else None)
        if cfg.warm:
            self.warm()

    # -- programs -----------------------------------------------------------

    @property
    def compiled_programs(self) -> int:
        """Resident program count — the audit pins this to len(buckets)."""
        return len(self._programs)

    def warm(self) -> None:
        """AOT-compile one program per bucket (compile only, nothing
        executes) so the first request pays zero compile."""
        t0 = time.perf_counter()
        for b in self.cfg.buckets:
            self._program(b)
        self.rec.event("serve/warm", seconds=time.perf_counter() - t0,
                       programs=len(self._programs))

    def _entry(self, bucket: int):
        """(jitted fn, abstract x, trailing dynamic args, static kwargs,
        output postprocessor) for one dense bucket program."""
        art = self.artifact
        a = art.arrays
        x0 = jax.ShapeDtypeStruct((bucket, art.in_dim), jnp.float32)
        if art.kind == "exact":
            from repro.core.minibatch import predict as exact_predict
            return (exact_predict, x0, (a["medoids"], a["medoid_diag"]),
                    dict(spec=art.kernel_spec()), lambda out: out)
        if art.kind == "tensorsketch":
            return (_ts_assign, x0, (art.feature_map(), a["v"], a["csq"]),
                    {}, lambda out: out)
        w_key, aux_key = ("h", "sign") if art.kind == "sketch" \
            else ("w", "aux")
        kw = dict(fused=self._fused, interpret=self._interpret,
                  precision=art.precision, backend=self._backend,
                  **_statics(art))
        return (ops.predict_assign, x0,
                (a[w_key], a[aux_key], a["v"], a["csq"]), kw,
                lambda out: out[0])

    def _program(self, bucket: int):
        if bucket not in self._programs:
            jitfn, x0, args, kw, post = self._entry(bucket)
            compiled = jitfn.lower(x0, *args, **kw).compile()
            self._programs[bucket] = \
                lambda xp, c=compiled, a=args, p=post: p(c(xp, *a))
        return self._programs[bucket]

    # -- queue --------------------------------------------------------------

    def submit(self, x) -> int:
        """Enqueue one request; returns its uid. Raises ``QueueFull`` when
        admission would exceed ``max_queue_rows`` pending rows."""
        if is_sparse(x):
            if self.artifact.kind not in SKETCH_KINDS:
                x = to_dense(x)
        if not is_sparse(x):
            x = np.asarray(x, np.float32)
            if x.ndim != 2 or x.shape[1] != self.artifact.in_dim:
                raise ValueError(
                    f"queries must be [n, {self.artifact.in_dim}], "
                    f"got {x.shape}")
        n = x.shape[0]
        if n == 0:
            raise ValueError("empty request")
        if self._pending_rows + n > self.cfg.max_queue_rows:
            self.rec.counter("serve/rejected", rows=n)
            raise QueueFull(
                f"{self._pending_rows} rows pending + {n} > "
                f"max_queue_rows={self.cfg.max_queue_rows}")
        self._uid += 1
        self._queue.append(_Request(self._uid, x, n, time.perf_counter(),
                                    np.empty((n,), np.int32)))
        self._pending_rows += n
        self.rec.counter("serve/submitted", rows=n)
        self.rec.gauge("serve/queue_rows", self._pending_rows)
        return self._uid

    def step(self) -> dict[int, np.ndarray]:
        """One scheduler tick -> {uid: labels} for requests completed now."""
        if not self._queue:
            return {}
        if is_sparse(self._queue[0].x):
            return self._step_csr()
        bmax = self.cfg.buckets[-1]
        # pack consecutive dense FIFO heads (partial consumption allowed)
        items, total = [], 0
        for req in self._queue:
            if is_sparse(req.x) or total >= bmax:
                break
            take = min(req.n - req.filled, bmax - total)
            items.append((req, req.filled, take))
            total += take
        bucket = bucket_for(total, self.cfg.buckets)
        xp = np.zeros((bucket, self.artifact.in_dim), np.float32)
        ofs = 0
        for req, s, t in items:
            xp[ofs:ofs + t] = req.x[s:s + t]
            ofs += t
        t0 = time.perf_counter()
        labels = self._program(bucket)(jnp.asarray(xp))
        labels = np.asarray(jax.block_until_ready(labels))[:total]
        compute_s = time.perf_counter() - t0
        ofs = 0
        for req, s, t in items:
            req.labels[s:s + t] = labels[ofs:ofs + t]
            ofs += t
            req.filled += t
            self._pending_rows -= t
        return self._complete(t0, compute_s, bucket)

    def _step_csr(self) -> dict[int, np.ndarray]:
        """One tick over the CSR head request (per-request O(nnz) path)."""
        req = self._queue[0]
        bmax = self.cfg.buckets[-1]
        take = min(req.n - req.filled, bmax)
        bucket = bucket_for(take, self.cfg.buckets)
        piece = _pad_csr(slice_rows(req.x, req.filled, req.filled + take),
                         bucket)
        a = self.artifact.arrays
        t0 = time.perf_counter()
        labels = _csr_assign(piece, self._fmap, a["v"], a["csq"],
                             precision=self.artifact.precision)
        labels = np.asarray(jax.block_until_ready(labels))[:take]
        compute_s = time.perf_counter() - t0
        req.labels[req.filled:req.filled + take] = labels
        req.filled += take
        self._pending_rows -= take
        return self._complete(t0, compute_s, bucket)

    def _complete(self, t0: float, compute_s: float,
                  bucket: int) -> dict[int, np.ndarray]:
        done = {}
        now = time.perf_counter()
        while self._queue and self._queue[0].filled == self._queue[0].n:
            req = self._queue.popleft()
            done[req.uid] = req.labels
            queue_s = t0 - req.t_submit
            self.rec.series("serve/queue_seconds", queue_s, uid=req.uid)
            self.rec.series("serve/compute_seconds", compute_s, uid=req.uid)
            self.rec.event("serve/request", uid=req.uid, rows=req.n,
                           bucket=bucket, queue_seconds=queue_s,
                           compute_seconds=compute_s,
                           total_seconds=now - req.t_submit)
        self.rec.gauge("serve/queue_rows", self._pending_rows)
        return done

    def drain(self) -> dict[int, np.ndarray]:
        """Tick until the queue is empty; returns every completed request."""
        done = {}
        while self._queue:
            done.update(self.step())
        return done

    def predict(self, x) -> Array:
        """Synchronous convenience: submit + drain one request."""
        uid = self.submit(x)
        out = self.drain()
        return jnp.asarray(out[uid])
