"""Frozen predict artifact — the immutable deployable of a finished fit.

``FitResult.predict`` used to re-derive everything a query needs (value
panels, masked centroid norms, landmark norms) from the live training
objects on every call. ``freeze(result)`` does that derivation ONCE and
packs the outcome into a single immutable pytree:

  * the feature-map tables — RFF frequencies + phases, Nystrom landmarks,
    count-sketch hash/sign, TensorSketch hash/sign stacks — optionally
    stored at the bf16 tile dtype (``kernels/precision.py``; accumulation
    stays f32 in every consumer, signs store int8 under bf16);
  * the centroids in embedded space and their MASKED squared norms
    (empty clusters carry +BIG so they are never assigned — baked in at
    freeze time, not recomputed per request);
  * the value panel ``v = proj @ centroids.T`` for Nystrom (the per-call
    matmul ``embed_panels`` used to pay is gone) / ``centroids.T``
    otherwise;
  * for ``method="exact"`` fits: the global medoids + their kernel
    diagonal and the KernelSpec scalars.

The artifact is exactly what ``kernels.ops.predict_assign`` consumes —
the serving engine (``repro.serving.assign``) AOT-compiles one program
per shape bucket over these arrays and nothing else. Its resident bytes
are priced by ``core.memory.serve_footprint_bytes`` (reported by
``artifact_nbytes`` next to the analytic price in the serve benchmark).

Save/load round-trips through one ``.npz`` (arrays; bf16 tiles stored as
their exact f32 lift — bf16 -> f32 -> bf16 is lossless — and re-rounded
at load) plus a JSON member for the static scalars, so a pod-scale fit
ships to a serving host as one file.
"""
from __future__ import annotations

import dataclasses
import io
import json

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array

#: artifact kinds (== MiniBatchConfig.method values).
KINDS = ("rff", "nystrom", "sketch", "tensorsketch", "exact")

#: kinds the fused Pallas predict kernel serves (ops.predict_assign
#: fused=True). TensorSketch (FFT conv) and exact (medoid Gram row) run
#: their documented jnp programs instead — still one program per bucket.
FUSED_KINDS = ("rff", "nystrom", "sketch")


@dataclasses.dataclass(frozen=True)
class FrozenArtifact:
    """Immutable predict artifact: arrays + hashable statics.

    ``arrays`` maps name -> device array (the pytree leaves); ``statics``
    holds the compile-time scalars (map_kind/gamma/coef0/degree/scale/
    m/c/d ...) that bake into the bucket programs. ``precision`` is the
    tile dtype the map tables were frozen at ("f32" | "bf16").
    """

    kind: str
    precision: str
    arrays: dict
    statics: dict

    @property
    def n_clusters(self) -> int:
        return int(self.statics["c"])

    @property
    def in_dim(self) -> int:
        return int(self.statics["d"])

    @property
    def dim(self) -> int:
        """Embedded dim m (== C for exact: one medoid Gram column each)."""
        return int(self.statics.get("m", self.statics["c"]))

    def feature_map(self):
        """Rebuild the O(nnz) sketch map for CSR ingestion (sketch kinds).

        Signs may be stored int8 (bf16 policy) — lifted back to f32 here;
        ±1 is exact in every format so the rebuilt map is bit-identical.
        """
        if self.kind == "sketch":
            from repro.approx.sketch import CountSketchMap
            return CountSketchMap(
                h=self.arrays["h"],
                sign=self.arrays["sign"].astype(jnp.float32),
                m=int(self.statics["m"]))
        if self.kind == "tensorsketch":
            from repro.approx.sketch import TensorSketchMap
            return TensorSketchMap(
                hs=self.arrays["hs"],
                signs=self.arrays["signs"].astype(jnp.float32),
                m=int(self.statics["m"]),
                degree=int(self.statics["degree"]),
                gamma=float(self.statics["gamma"]),
                coef0=float(self.statics["coef0"]))
        raise ValueError(
            f"kind {self.kind!r} has no O(nnz) sketch map; CSR requests "
            "are densified at ingestion (repro.serving.assign)")

    def kernel_spec(self):
        """The KernelSpec of an exact-kind artifact."""
        from repro.core.kernels import KernelSpec
        if self.kind != "exact":
            raise ValueError(f"kind {self.kind!r} carries no KernelSpec")
        s = self.statics
        return KernelSpec(name=s["kernel"], gamma=float(s["gamma"]),
                          coef0=float(s["coef0"]), degree=int(s["degree"]))


def _flatten(a: FrozenArtifact):
    keys = tuple(sorted(a.arrays))
    leaves = tuple(a.arrays[k] for k in keys)
    aux = (a.kind, a.precision, keys, tuple(sorted(a.statics.items())))
    return leaves, aux


def _unflatten(aux, leaves) -> FrozenArtifact:
    kind, precision, keys, statics = aux
    return FrozenArtifact(kind=kind, precision=precision,
                          arrays=dict(zip(keys, leaves)),
                          statics=dict(statics))


jax.tree_util.register_pytree_node(FrozenArtifact, _flatten, _unflatten)


def _panels(centroids: Array, counts: Array):
    """f32 centroids, transposed value panel, and MASKED squared norms."""
    from repro.kernels.ops import _masked_csq
    c32, csq = _masked_csq(centroids, counts)
    return c32, c32.T, csq


def freeze_map(fmap, centroids: Array, counts: Array, *,
               precision: str = "f32") -> FrozenArtifact:
    """Freeze an embedded-space model (feature map + centroids).

    The artifact-build half of ``freeze`` that needs no ``FitResult`` —
    the audit CLI and tests build serving programs from synthetic parts
    through this. ``precision`` stores the map TILES (frequencies /
    landmarks / signs) at the policy dtype; panels and norms stay f32
    (they are accumulator-side values, never tiles).
    """
    from repro.approx.nystrom import NystromMap
    from repro.approx.rff import RFFMap
    from repro.approx.sketch import CountSketchMap, TensorSketchMap
    from repro.kernels.precision import resolve_precision

    p = resolve_precision(precision)
    counts = jnp.asarray(counts, jnp.float32)
    c32, v, csq = _panels(jnp.asarray(centroids), counts)
    c, m = c32.shape
    common = dict(c=c, m=m)

    if isinstance(fmap, RFFMap):
        w = p.cast_tiles(fmap.w)
        arrays = dict(w=w, aux=fmap.b.astype(jnp.float32)[:, None],
                      v=v, csq=csq, centroids=c32, counts=counts)
        statics = dict(map_kind="rff", gamma=1.0, coef0=1.0, degree=1,
                       scale=float(fmap.scale), d=int(fmap.in_dim), **common)
        return FrozenArtifact("rff", precision, arrays, statics)
    if isinstance(fmap, NystromMap):
        w = p.cast_tiles(fmap.landmarks)
        # norms of the CAST landmarks: the Mercer epilogue's norm/dot terms
        # must cancel exactly the way the tile-dtype kernel computes them.
        aux = jnp.sum(w.astype(jnp.float32) ** 2, axis=1, keepdims=True)
        spec = fmap.spec
        arrays = dict(w=w, aux=aux,
                      v=fmap.proj.astype(jnp.float32) @ c32.T,
                      csq=csq, centroids=c32, counts=counts)
        statics = dict(map_kind=spec.name, gamma=float(spec.gamma),
                       coef0=float(spec.coef0), degree=int(spec.degree),
                       scale=1.0, d=int(fmap.in_dim), **common)
        return FrozenArtifact("nystrom", precision, arrays, statics)
    if isinstance(fmap, CountSketchMap):
        arrays = dict(h=fmap.h.astype(jnp.int32),
                      sign=fmap.sign.astype(p.sign_dtype),
                      v=v, csq=csq, centroids=c32, counts=counts)
        statics = dict(map_kind="sketch", d=int(fmap.in_dim), **common)
        return FrozenArtifact("sketch", precision, arrays, statics)
    if isinstance(fmap, TensorSketchMap):
        arrays = dict(hs=fmap.hs.astype(jnp.int32),
                      signs=fmap.signs.astype(p.sign_dtype),
                      v=v, csq=csq, centroids=c32, counts=counts)
        statics = dict(map_kind="tensorsketch", degree=int(fmap.degree),
                       gamma=float(fmap.gamma), coef0=float(fmap.coef0),
                       d=int(fmap.in_dim), **common)
        return FrozenArtifact("tensorsketch", precision, arrays, statics)
    raise TypeError(f"unsupported feature map {type(fmap).__name__}")


def freeze(result, *, precision: str = "f32") -> FrozenArtifact:
    """``FitResult`` -> ``FrozenArtifact`` (the deployable predict model).

    f32 artifacts predict bit-identically to the fit-time path;
    ``precision="bf16"`` halves the map-table bytes with the bounded NMI
    drift the precision tests pin (tile rounding only — every consumer
    still accumulates f32).
    """
    if result.fmap is not None:
        return freeze_map(result.fmap, result.state.centroids,
                          result.state.cardinalities, precision=precision)
    if result.spec is None:
        raise ValueError(
            "cannot freeze an exact-path FitResult without its KernelSpec "
            "(FitResult.spec) — prediction would use the wrong kernel")
    state = result.state
    c, d = state.medoids.shape
    arrays = dict(medoids=jnp.asarray(state.medoids, jnp.float32),
                  medoid_diag=jnp.asarray(state.medoid_diag, jnp.float32))
    statics = dict(kernel=result.spec.name, gamma=float(result.spec.gamma),
                   coef0=float(result.spec.coef0),
                   degree=int(result.spec.degree), c=int(c), d=int(d))
    return FrozenArtifact("exact", precision, arrays, statics)


def artifact_nbytes(art: FrozenArtifact) -> int:
    """Resident bytes of the artifact's arrays (the measured counterpart
    of ``core.memory.serve_footprint_bytes`` at bucket=0)."""
    return int(sum(np.asarray(a).nbytes for a in art.arrays.values()))


def save_artifact(art: FrozenArtifact, path: str) -> str:
    """Write one ``.npz``: arrays + a JSON member with kind/precision/
    statics/dtypes. bf16 tiles are stored as their exact f32 lift
    (bf16 -> f32 is lossless) and re-rounded at load."""
    arrays, dtypes = {}, {}
    for k, a in art.arrays.items():
        a = np.asarray(a)
        dtypes[k] = str(a.dtype)
        if a.dtype.name == "bfloat16":
            a = a.astype(np.float32)
        arrays[k] = a
    meta = json.dumps({"kind": art.kind, "precision": art.precision,
                       "statics": art.statics, "dtypes": dtypes})
    buf = io.BytesIO()
    np.savez(buf, __meta__=np.frombuffer(meta.encode(), np.uint8), **arrays)
    with open(path, "wb") as fh:
        fh.write(buf.getvalue())
    return path


def load_artifact(path: str) -> FrozenArtifact:
    """Read a ``save_artifact`` file back into device arrays."""
    with np.load(path) as z:
        meta = json.loads(bytes(z["__meta__"]).decode())
        arrays = {}
        for k, dt in meta["dtypes"].items():
            arrays[k] = jnp.asarray(z[k]).astype(dt)
    if meta["kind"] not in KINDS:
        raise ValueError(f"unknown artifact kind {meta['kind']!r} in {path}")
    return FrozenArtifact(kind=meta["kind"], precision=meta["precision"],
                          arrays=arrays, statics=meta["statics"])
