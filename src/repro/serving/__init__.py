from .engine import ServeConfig, ServingEngine
from .sampling import greedy, sample_top_p

__all__ = ["ServeConfig", "ServingEngine", "greedy", "sample_top_p"]
