from .artifact import (FrozenArtifact, artifact_nbytes, freeze, freeze_map,
                       load_artifact, save_artifact)
from .assign import (DEFAULT_BUCKETS, AssignServeConfig, AssignService,
                     QueueFull, bucket_for)
from .assign import predict as predict_frozen
from .engine import ServeConfig, ServingEngine
from .sampling import greedy, sample_top_p

__all__ = [
    "ServeConfig", "ServingEngine", "greedy", "sample_top_p",
    "FrozenArtifact", "freeze", "freeze_map", "artifact_nbytes",
    "save_artifact", "load_artifact",
    "DEFAULT_BUCKETS", "AssignServeConfig", "AssignService", "QueueFull",
    "bucket_for", "predict_frozen",
]
