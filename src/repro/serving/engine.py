"""Batched serving engine: continuous-batching request scheduler over the
model zoo's prefill/decode API.

Design (vLLM-style continuous batching, adapted to the static-shape JAX
world — no paged KV, slots instead):

  * A fixed decode batch of ``max_batch`` slots; the KV/state cache pytree
    is allocated ONCE at [B = max_batch, S = max_len] (batch is dim 1 of
    every cache leaf across all families).
  * Admission: each new request is prefilled alone (batch = 1, one chunked
    full-sequence pass — the FLOPs-efficient path) and its cache is
    scattered into its slot with one ``dynamic_update_slice`` per leaf.
  * Generation: ONE batched decode step advances every active slot per tick,
    each at its own cursor — the decode paths accept a per-slot position
    vector [B] (repro.models.attention.decode_attention). Parked slots write
    to a scratch position and are fully overwritten on the next admission.
  * Finished slots (EOS or length cap) free immediately and are refilled
    from the queue on the next tick (continuous batching).

The engine is mesh-agnostic: on a mesh the cache carries the NamedShardings
from ``api.cache_specs`` and the same program runs SPMD (the production
decode shardings are exercised by the dry-run's decode_32k / long_500k
cells). Decoder-only and hybrid/ssm families are supported; enc-dec serving
needs per-request encoder memory and uses its own example driver.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.registry import ModelAPI

from .sampling import greedy

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    max_batch: int = 8           # decode slots
    max_len: int = 256           # cache capacity per slot
    eos_token: int = 2
    max_new_tokens: int = 64


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray           # [S] int32
    out_tokens: list = dataclasses.field(default_factory=list)
    done: bool = False


class ServingEngine:
    """Continuous batching over ModelAPI prefill/decode (decoder-only)."""

    def __init__(self, api: ModelAPI, params, cfg: ServeConfig, *,
                 sampler: Callable[..., Array] = greedy,
                 key: Optional[Array] = None):
        if api.cfg.family == "encdec":
            raise ValueError("enc-dec serving needs per-request encoder "
                             "memory; use examples/serve_lm.py's encdec path")
        self.api = api
        self.params = params
        self.cfg = cfg
        self.sampler = sampler
        self.key = key if key is not None else jax.random.PRNGKey(0)

        self.queue: deque[Request] = deque()
        self.slots: list[Optional[Request]] = [None] * cfg.max_batch
        self.slot_pos = np.zeros(cfg.max_batch, np.int32)
        self._cache = None
        self._uid = 0
        self.ticks = 0

    # ------------------------------------------------------------------ api

    def submit(self, prompt) -> int:
        self._uid += 1
        self.queue.append(Request(self._uid, np.asarray(prompt, np.int32)))
        return self._uid

    def run(self, axes) -> dict:
        """Drive everything to completion; returns {uid: generated tokens}."""
        results: dict = {}
        while self.queue or any(s is not None for s in self.slots):
            self._admit(axes)
            self._decode_tick(axes)
            for i, req in enumerate(self.slots):
                if req is not None and req.done:
                    results[req.uid] = list(req.out_tokens)
                    self.slots[i] = None
        return results

    # ------------------------------------------------------------ internals

    def _fresh_cache(self, axes):
        shape = _ShapeStub(self.cfg.max_batch, self.cfg.max_len)
        cache_shapes, _ = self.api.cache_specs(shape, axes)
        return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                            cache_shapes)

    def _admit(self, axes):
        """Prefill queued requests into free slots (batch-1 prefill, then a
        per-leaf slice write into batch dim 1 of the shared cache)."""
        if self._cache is None:
            self._cache = self._fresh_cache(axes)
        for i in range(self.cfg.max_batch):
            if self.slots[i] is not None or not self.queue:
                continue
            req = self.queue.popleft()
            prompt = jnp.asarray(req.prompt[None, :])       # [1, S]
            cache1, logits1 = self.api.prefill(
                self.params, {"tokens": prompt}, axes,
                max_len=self.cfg.max_len)
            slot = i
            self._cache = jax.tree.map(
                lambda big, small: jax.lax.dynamic_update_slice_in_dim(
                    big, small.astype(big.dtype), slot, axis=1),
                self._cache, cache1)
            self.slots[i] = req
            self.slot_pos[i] = len(req.prompt)
            self._sample_and_record(i, np.asarray(logits1[0]))

    def _decode_tick(self, axes):
        """One batched decode step for ALL active slots, each at its own
        cursor (per-slot position vector)."""
        active = [i for i, r in enumerate(self.slots)
                  if r is not None and not r.done]
        if not active:
            return
        b = self.cfg.max_batch
        tokens = np.zeros(b, np.int32)
        # parked slots write their K/V into the last cache row; admission
        # rewrites the whole slot so the scratch write is harmless.
        pos = np.full(b, self.cfg.max_len - 1, np.int32)
        for i in active:
            tokens[i] = self.slots[i].out_tokens[-1]
            pos[i] = self.slot_pos[i]
        logits, self._cache = self.api.decode(
            self.params, self._cache, jnp.asarray(tokens),
            jnp.asarray(pos), axes)
        self.ticks += 1
        logits_np = np.asarray(logits)
        for i in active:
            self.slot_pos[i] += 1
            self._sample_and_record(i, logits_np[i])

    def _sample_and_record(self, slot: int, logits: np.ndarray):
        req = self.slots[slot]
        self.key, sub = jax.random.split(self.key)
        tok = int(self.sampler(jnp.asarray(logits)[None, :], sub)[0])
        req.out_tokens.append(tok)
        if (tok == self.cfg.eos_token
                or len(req.out_tokens) >= self.cfg.max_new_tokens
                or int(self.slot_pos[slot]) >= self.cfg.max_len - 1):
            req.done = True


class _ShapeStub:
    """Duck-typed ShapeConfig for cache allocation."""
    kind = "decode"

    def __init__(self, global_batch: int, seq_len: int):
        self.global_batch = global_batch
        self.seq_len = seq_len
        self.name = f"serve_{seq_len}"
