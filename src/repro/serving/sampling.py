"""Token samplers for the serving engine (fp32 logits in, int32 tokens out)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def greedy(logits: Array, key: Array | None = None) -> Array:
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def sample_top_p(logits: Array, key: Array, *, top_p: float = 0.9,
                 temperature: float = 1.0) -> Array:
    """Nucleus sampling. logits: [B, V] -> [B] int32."""
    logits = logits / jnp.maximum(temperature, 1e-6)
    sorted_logits = jnp.sort(logits, axis=-1)[:, ::-1]
    sorted_probs = jax.nn.softmax(sorted_logits, axis=-1)
    cum = jnp.cumsum(sorted_probs, axis=-1)
    # smallest prefix with cumulative mass >= top_p stays
    cutoff_idx = jnp.sum(cum < top_p, axis=-1, keepdims=True)
    cutoff = jnp.take_along_axis(sorted_logits, cutoff_idx, axis=-1)
    masked = jnp.where(logits < cutoff, -jnp.inf, logits)
    return jax.random.categorical(key, masked, axis=-1).astype(jnp.int32)
