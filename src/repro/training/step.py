"""train_step factory: value_and_grad -> clip -> AdamW, with optional
microbatch gradient accumulation (a ``lax.scan`` over microbatch slices so
the HLO stays O(1) in the accumulation factor)."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig
from repro.models.common import Axes
from repro.models.registry import ModelAPI

from .optim import AdamWState, adamw_update


def make_train_step(api: ModelAPI, tcfg: TrainConfig, axes: Axes):
    """Returns ``train_step(params, opt_state, batch) -> (params, opt_state,
    metrics)`` ready for jit with in_shardings from the spec trees."""

    def loss_fn(params, batch):
        return api.loss(params, batch, axes, remat=tcfg.remat)

    def compute_grads(params, batch):
        if tcfg.microbatches <= 1:
            return jax.value_and_grad(loss_fn)(params, batch)

        n = tcfg.microbatches

        def slice_mb(x):
            b = x.shape[0]
            return x.reshape(n, b // n, *x.shape[1:])

        mbatches = jax.tree.map(slice_mb, batch)

        def body(carry, mb):
            loss_acc, grad_acc = carry
            loss, grads = jax.value_and_grad(loss_fn)(params, mb)
            grad_acc = jax.tree.map(
                lambda a, g: a + g.astype(jnp.float32), grad_acc, grads)
            return (loss_acc + loss, grad_acc), None

        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (loss_sum, grad_sum), _ = jax.lax.scan(
            body, (jnp.float32(0.0), zeros), mbatches)
        inv = 1.0 / n
        return loss_sum * inv, jax.tree.map(lambda g: g * inv, grad_sum)

    def train_step(params, opt_state: AdamWState, batch):
        loss, grads = compute_grads(params, batch)
        params, opt_state, metrics = adamw_update(params, grads, opt_state,
                                                  tcfg)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return train_step
