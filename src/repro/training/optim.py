"""Pure-JAX AdamW with sharded state (no optax in this environment).

m/v mirror the parameter PartitionSpecs exactly, so optimizer state is
FSDP+TP sharded for free. ``opt_state_dtype='bfloat16'`` halves optimizer
HBM for the 314B config (DESIGN.md §6 memory budget).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig

Array = jax.Array


class AdamWState(NamedTuple):
    step: Array
    m: Any
    v: Any


def adamw_init(params, tcfg: TrainConfig) -> AdamWState:
    dt = jnp.dtype(tcfg.opt_state_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)   # noqa: E731
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
    )


def opt_state_specs(param_specs) -> AdamWState:
    from jax.sharding import PartitionSpec as P
    return AdamWState(step=P(), m=param_specs, v=param_specs)


def lr_schedule(step: Array, tcfg: TrainConfig) -> Array:
    """Linear warmup then cosine decay to 10%."""
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(tcfg.warmup_steps, 1)
    frac = (step - tcfg.warmup_steps) / jnp.maximum(
        tcfg.total_steps - tcfg.warmup_steps, 1)
    frac = jnp.clip(frac, 0.0, 1.0)
    cos = 0.1 + 0.45 * (1.0 + jnp.cos(jnp.pi * frac))
    return tcfg.learning_rate * jnp.where(step < tcfg.warmup_steps,
                                          jnp.minimum(warm, 1.0), cos)


def global_norm(tree) -> Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in leaves))


def adamw_update(params, grads, state: AdamWState, tcfg: TrainConfig):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, tcfg.grad_clip / jnp.maximum(gnorm, 1e-9)) \
        if tcfg.grad_clip else jnp.float32(1.0)
    step = state.step + 1
    lr = lr_schedule(step, tcfg)
    b1, b2, eps = tcfg.b1, tcfg.b2, tcfg.eps
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32) * scale
        m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g32
        v32 = b2 * v.astype(jnp.float32) + (1 - b2) * g32 * g32
        mh = m32 / bc1
        vh = v32 / bc2
        delta = mh / (jnp.sqrt(vh) + eps) + tcfg.weight_decay \
            * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return new_p, m32.astype(m.dtype), v32.astype(v.dtype)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, AdamWState(step, new_m, new_v), metrics
