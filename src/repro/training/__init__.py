from .optim import AdamWState, adamw_init, adamw_update, lr_schedule
from .step import make_train_step

__all__ = ["AdamWState", "adamw_init", "adamw_update", "lr_schedule",
           "make_train_step"]
