"""Kernel k-means inner loop (paper §2, Eq.4-7; landmark variant §3.2, Eq.14-17).

The self-consistent label update is

    u_i <- argmin_j  g_j - 2 f_{i,j}                                   (Eq.4)
    g_j   = (1/|w_j|^2) sum_{m,n in L} K_{m,n} d(u_m,j) d(u_n,j)       (Eq.5/16)
    f_i,j = (1/|w_j|)   sum_{m in L}   K_{i,m} d(u_m,j)                (Eq.6/17)

where L is the landmark set (L = whole mini-batch when s = 1, in which case
this is *exact* kernel k-means on the mini-batch).

The Gram blocks behind f and g live wherever the ``GramEngine``
(repro.core.engine) puts them: resident in HBM (``materialize``, the
paper's layout), rebuilt in VMEM per iteration (``fused``, Pallas), or
streamed as row panels (``tiled``, so ``s = 1`` survives batches whose
full [n, |L|] block cannot fit). All three run the same stats code and the
same argmin tie-break (lowest cluster index), so engine choice never
changes labels — only the memory/FLOP bill.

Everything below is shape-static and jit/`shard_map`-friendly: labels are
int32, reductions accumulate in fp32.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .engine import BIG, GramEngine, engine_step, resolve_engine

Array = jax.Array


class InnerState(NamedTuple):
    labels: Array      # [n] int32 current labels
    changed: Array     # [] bool   did the last sweep change anything
    t: Array           # [] int32  iteration counter
    cost: Array        # [] f32    current mini-batch cost Omega(W^i)


class InnerResult(NamedTuple):
    labels: Array      # [n] int32 converged labels
    f: Array           # [n, C] f32 cluster average similarity at convergence
    g: Array           # [C] f32 cluster compactness at convergence
    counts: Array      # [C] f32 landmark cardinality per cluster
    n_iter: Array      # [] int32
    cost: Array        # [] f32  converged mini-batch cost


def _cost(diag_k: Array, mind: Array) -> Array:
    """Omega = sum_i K_ii + min_j(g_j - 2 f_ij)   (||phi(x)-w||^2 expansion)."""
    return jnp.sum(diag_k.astype(jnp.float32) + mind)


def _run_inner(engine: GramEngine, spec, op_xl, op_ll, l_idx: Array,
               diag_k: Array, labels0: Array, *, n_clusters: int,
               max_iters: int) -> InnerResult:
    """Shared GD loop over a prepared pair of Gram operators."""

    def body(state: InnerState) -> InnerState:
        labels_l = jnp.take(state.labels, l_idx)
        _, _, _, labels, mind = engine_step(
            engine, spec, op_xl, op_ll, labels_l, n_clusters)
        changed = jnp.any(labels != state.labels)
        return InnerState(labels, changed, state.t + 1, _cost(diag_k, mind))

    def cond(state: InnerState) -> Array:
        return jnp.logical_and(state.changed, state.t < max_iters)

    init = InnerState(
        labels=labels0.astype(jnp.int32),
        changed=jnp.array(True),
        t=jnp.array(0, jnp.int32),
        cost=jnp.array(jnp.inf, jnp.float32),
    )
    final = jax.lax.while_loop(cond, body, init)

    # one more stats pass at the fixpoint (cheap relative to the loop) so the
    # caller gets f/g consistent with the final labels for Eq.7 medoids.
    f, g, counts, _, _ = engine_step(
        engine, spec, op_xl, op_ll, jnp.take(final.labels, l_idx), n_clusters)
    return InnerResult(final.labels, f, g, counts, final.t, final.cost)


@partial(jax.jit, static_argnames=("spec", "n_clusters", "max_iters",
                                   "engine"))
def kkmeans_fit(
    x: Array,
    l_idx: Array,
    diag_k: Array,
    labels0: Array,
    *,
    spec,
    n_clusters: int,
    max_iters: int = 100,
    engine: GramEngine = GramEngine(),
) -> InnerResult:
    """Run the inner GD loop (Eq.4) to convergence on one mini-batch.

    Args:
      x: [n, d] mini-batch rows (features — the engine decides whether and
        where the Gram blocks they imply get materialized).
      l_idx: [L] int32 indices of the landmarks within the batch.
      diag_k: [n] K(x_i, x_i).
      labels0: [n] initial labels (from k-means++ or the previous batch's
        global medoids, Eq.8).
      spec: KernelSpec evaluating the Gram blocks.
      n_clusters: C.
      max_iters: hard iteration cap (the paper iterates to label fixpoint;
        Bottou & Bengio guarantee a.s. convergence for the exact case).
      engine: GramEngine naming the Gram residency (materialize/fused/tiled).
    """
    engine = resolve_engine(engine)
    landmarks = jnp.take(x, l_idx, axis=0)
    op_xl = engine.prepare(spec, x, landmarks)
    if op_xl.k is not None:
        # materialize: the landmark block is a row-gather of the resident
        # batch block (landmarks ARE batch rows) — today's exact math, no
        # second kernel evaluation.
        op_ll = GramEngine.from_matrix(jnp.take(op_xl.k, l_idx, axis=0))
    else:
        op_ll = engine.prepare(spec, landmarks, landmarks)
    return _run_inner(engine, spec, op_xl, op_ll, l_idx, diag_k, labels0,
                      n_clusters=n_clusters, max_iters=max_iters)


@partial(jax.jit, static_argnames=("n_clusters", "max_iters"))
def kkmeans_fit_gram(
    k_xl: Array,
    l_idx: Array,
    diag_k: Array,
    labels0: Array,
    *,
    n_clusters: int,
    max_iters: int = 100,
) -> InnerResult:
    """A-posteriori entry: run the inner loop on a caller-precomputed
    [n, L] kernel block (k_ll is the row-gather ``k_xl[l_idx]``). This is
    the materialize layout with the evaluation already paid — the oracle
    the engine modes are tested against."""
    engine = GramEngine("materialize")
    op_xl = GramEngine.from_matrix(k_xl)
    op_ll = GramEngine.from_matrix(jnp.take(k_xl, l_idx, axis=0))
    return _run_inner(engine, None, op_xl, op_ll, l_idx, diag_k, labels0,
                      n_clusters=n_clusters, max_iters=max_iters)


def medoid_indices(diag_k: Array, f: Array, labels: Array, counts: Array,
                   *, restrict_to_members: bool = False) -> Array:
    """Eq.7: m_j = argmin_{x_l} K_ll - 2 f_{l,j}  (medoid approximation).

    The paper's argmin runs over the whole mini-batch; with
    ``restrict_to_members=True`` it runs over cluster members only (never
    worse, occasionally more robust — kept as an option, default faithful).
    Empty clusters return index 0; callers must mask on ``counts == 0``
    (their alpha is 0 so the value is never used, Eq.11 remark).
    """
    score = diag_k.astype(jnp.float32)[:, None] - 2.0 * f            # [n, C]
    if restrict_to_members:
        member = jax.nn.one_hot(labels, f.shape[1], dtype=jnp.bool_)
        score = jnp.where(member, score, BIG)
    return jnp.argmin(score, axis=0).astype(jnp.int32)               # [C]


@partial(jax.jit, static_argnames=("n_clusters", "max_iters"))
def kkmeans_fit_full(
    k: Array,
    diag_k: Array,
    labels0: Array,
    *,
    n_clusters: int,
    max_iters: int = 100,
) -> InnerResult:
    """Exact (s = 1) kernel k-means on a precomputed full Gram matrix:
    landmarks == every sample."""
    n = k.shape[0]
    return kkmeans_fit_gram.__wrapped__(
        k, jnp.arange(n, dtype=jnp.int32), diag_k, labels0,
        n_clusters=n_clusters, max_iters=max_iters,
    )
