"""Kernel k-means inner loop (paper §2, Eq.4-7; landmark variant §3.2, Eq.14-17).

The self-consistent label update is

    u_i <- argmin_j  g_j - 2 f_{i,j}                                   (Eq.4)
    g_j   = (1/|w_j|^2) sum_{m,n in L} K_{m,n} d(u_m,j) d(u_n,j)       (Eq.5/16)
    f_i,j = (1/|w_j|)   sum_{m in L}   K_{i,m} d(u_m,j)                (Eq.6/17)

where L is the landmark set (L = whole mini-batch when s = 1, in which case
this is *exact* kernel k-means on the mini-batch).

Everything below is shape-static and jit/`shard_map`-friendly:
the landmark Gram block ``k_ll`` is the row-gather ``k_xl[l_idx]`` (landmarks
are mini-batch samples), labels are int32, reductions accumulate in fp32.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array

BIG = jnp.float32(1e30)  # "+inf" that survives argmin/min on bf16-ish inputs


class InnerState(NamedTuple):
    labels: Array      # [n] int32 current labels
    changed: Array     # [] bool   did the last sweep change anything
    t: Array           # [] int32  iteration counter
    cost: Array        # [] f32    current mini-batch cost Omega(W^i)


class InnerResult(NamedTuple):
    labels: Array      # [n] int32 converged labels
    f: Array           # [n, C] f32 cluster average similarity at convergence
    g: Array           # [C] f32 cluster compactness at convergence
    counts: Array      # [C] f32 landmark cardinality per cluster
    n_iter: Array      # [] int32
    cost: Array        # [] f32  converged mini-batch cost


def _stats(k_xl: Array, k_ll: Array, labels_l: Array, n_clusters: int):
    """f, g, counts from the landmark Gram blocks and landmark labels.

    k_xl: [n, L]   rows x landmarks
    k_ll: [L, L]   landmarks x landmarks
    labels_l: [L]  labels of the landmarks
    """
    h = jax.nn.one_hot(labels_l, n_clusters, dtype=jnp.float32)      # [L, C]
    counts = jnp.sum(h, axis=0)                                      # [C]
    safe = jnp.maximum(counts, 1.0)
    # f_{i,j}: masked row-sum == one matmul on the MXU.
    f = jnp.dot(k_xl.astype(jnp.float32), h) / safe[None, :]         # [n, C]
    # g_j = (H^T K_ll H)_jj / counts_j^2, via S = K_ll @ H.
    s = jnp.dot(k_ll.astype(jnp.float32), h)                         # [L, C]
    g = jnp.sum(h * s, axis=0) / (safe * safe)                       # [C]
    return f, g, counts


def _assign(f: Array, g: Array, counts: Array) -> tuple[Array, Array]:
    """argmin_j (g_j - 2 f_ij); empty clusters are unjoinable (+BIG)."""
    dist = jnp.where(counts[None, :] > 0, g[None, :] - 2.0 * f, BIG)  # [n, C]
    labels = jnp.argmin(dist, axis=1).astype(jnp.int32)
    mind = jnp.min(dist, axis=1)
    return labels, mind


def _cost(diag_k: Array, mind: Array) -> Array:
    """Omega = sum_i K_ii + min_j(g_j - 2 f_ij)   (||phi(x)-w||^2 expansion)."""
    return jnp.sum(diag_k.astype(jnp.float32) + mind)


@partial(jax.jit, static_argnames=("n_clusters", "max_iters"))
def kkmeans_fit(
    k_xl: Array,
    l_idx: Array,
    diag_k: Array,
    labels0: Array,
    *,
    n_clusters: int,
    max_iters: int = 100,
) -> InnerResult:
    """Run the inner GD loop (Eq.4) to convergence on one mini-batch.

    Args:
      k_xl: [n, L] kernel block between every batch row and the landmarks.
      l_idx: [L] int32 indices of the landmarks within the batch.
      diag_k: [n] K(x_i, x_i).
      labels0: [n] initial labels (from k-means++ or the previous batch's
        global medoids, Eq.8).
      n_clusters: C.
      max_iters: hard iteration cap (the paper iterates to label fixpoint;
        Bottou & Bengio guarantee a.s. convergence for the exact case).
    """
    k_ll = jnp.take(k_xl, l_idx, axis=0)  # [L, L]

    def body(state: InnerState) -> InnerState:
        f, g, counts = _stats(k_xl, k_ll, jnp.take(state.labels, l_idx), n_clusters)
        labels, mind = _assign(f, g, counts)
        changed = jnp.any(labels != state.labels)
        return InnerState(labels, changed, state.t + 1, _cost(diag_k, mind))

    def cond(state: InnerState) -> Array:
        return jnp.logical_and(state.changed, state.t < max_iters)

    init = InnerState(
        labels=labels0.astype(jnp.int32),
        changed=jnp.array(True),
        t=jnp.array(0, jnp.int32),
        cost=jnp.array(jnp.inf, jnp.float32),
    )
    final = jax.lax.while_loop(cond, body, init)

    # one more stats pass at the fixpoint (cheap relative to the loop) so the
    # caller gets f/g consistent with the final labels for Eq.7 medoids.
    f, g, counts = _stats(k_xl, k_ll, jnp.take(final.labels, l_idx), n_clusters)
    return InnerResult(final.labels, f, g, counts, final.t, final.cost)


def medoid_indices(diag_k: Array, f: Array, labels: Array, counts: Array,
                   *, restrict_to_members: bool = False) -> Array:
    """Eq.7: m_j = argmin_{x_l} K_ll - 2 f_{l,j}  (medoid approximation).

    The paper's argmin runs over the whole mini-batch; with
    ``restrict_to_members=True`` it runs over cluster members only (never
    worse, occasionally more robust — kept as an option, default faithful).
    Empty clusters return index 0; callers must mask on ``counts == 0``
    (their alpha is 0 so the value is never used, Eq.11 remark).
    """
    score = diag_k.astype(jnp.float32)[:, None] - 2.0 * f            # [n, C]
    if restrict_to_members:
        member = jax.nn.one_hot(labels, f.shape[1], dtype=jnp.bool_)
        score = jnp.where(member, score, BIG)
    return jnp.argmin(score, axis=0).astype(jnp.int32)               # [C]


@partial(jax.jit, static_argnames=("n_clusters", "max_iters"))
def kkmeans_fit_full(
    k: Array,
    diag_k: Array,
    labels0: Array,
    *,
    n_clusters: int,
    max_iters: int = 100,
) -> InnerResult:
    """Exact (s = 1) kernel k-means: landmarks == every sample."""
    n = k.shape[0]
    return kkmeans_fit.__wrapped__(
        k, jnp.arange(n, dtype=jnp.int32), diag_k, labels0,
        n_clusters=n_clusters, max_iters=max_iters,
    )
