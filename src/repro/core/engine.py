"""One Gram engine for the exact path — §3.2/§3.3 as an architecture.

The inner-loop step (Eq.4-7 / Eq.14-17) is two contractions against the
label one-hot H and an argmin:

    f = K_xl @ H / counts          [n, C]   (Eq.6/17)
    g = diag(H^T K_ll H) / counts^2   [C]   (Eq.5/16)
    u = argmin_j (g_j - 2 f_ij)    [n]      (Eq.4/15)

*Where the Gram blocks live* while those contractions run is the whole
accuracy/velocity trade the paper says is "ruled by the available system
memory" (§3.2-3.3) — and it is a strategy, not a constant. ``GramEngine``
owns that choice behind one contract with three interchangeable modes:

================  ======================  =========================
mode              residency               per-iteration cost
================  ======================  =========================
``materialize``   K blocks in HBM,        1 matvec read of K;
                  built once per batch    peak HBM O(rows*|L|)
``fused``         K tiles in VMEM only    Gram rebuilt every
                  (Pallas; jnp fallback   iteration (+rows*|L|*d
                  recomputes per iter)    FLOPs); peak HBM O(rows*C)
``tiled``         one [bm, |L|] panel     Gram rebuilt every
                  at a time, streamed     iteration; peak HBM
                  (portable jnp)          O(bm*|L| + rows*C)
================  ======================  =========================

``materialize`` is the paper's producer/consumer layout (§3.3, Fig.3);
``fused`` is the beyond-paper VMEM-resident kernel (kernels/assign.py);
``tiled`` is the middle ground that lets ``s = 1`` survive batches whose
full [n, |L|] block cannot fit — the planner (``repro.core.memory.plan``)
prices all three and names the cheapest feasible one.

The single-host inner loop (core.kkmeans) and the mesh inner loop
(distributed.inner, inside shard_map) run literally the same stats code
(``engine_stats``): the mesh passes ONE batched ``ReducePlan`` that reduces
the whole raw payload (counts, K@H, g partials) in a single fused
collective, the single host passes nothing. The raw/finalize split
(``engine_stats_raw`` / ``finalize_stats``) is public so the s-step
communication-avoiding loop can do delta bookkeeping on the un-normalized
partials between syncs. The argmin authority is ``assign_from_stats`` —
jnp.argmin, FIRST (lowest) cluster index on ties — and the Pallas kernel
implements the identical rule, so engine choice never changes labels.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.kernels.precision import PRECISIONS, resolve_precision

Array = jax.Array

BIG = jnp.float32(1e30)  # "+inf" that survives argmin/min on bf16-ish inputs

ENGINE_MODES = ("materialize", "fused", "tiled")

# kernels the Pallas epilogue can evaluate in-tile (kernel_matrix._epilogue);
# anything else (laplacian) silently takes the jnp recompute fallback.
_PALLAS_KINDS = ("rbf", "linear", "polynomial", "cosine")


class GramOp(NamedTuple):
    """One side of the inner-loop contraction, prepared per mini-batch.

    ``k`` is the resident Gram block (materialize mode / caller-precomputed);
    ``x``/``y`` are the row/column features the other modes rebuild it from.
    """
    x: Optional[Array]     # [rows, d] or None when k is precomputed
    y: Optional[Array]     # [cols, d] landmark features
    k: Optional[Array]     # [rows, cols] fp32 resident block, or None


@dataclasses.dataclass(frozen=True)
class ReducePlan:
    """The mesh's ONE batched cross-device reduction per stats pass.

    ``fn`` receives the raw partial payload — counts [C], f_raw [rows, C]
    (the un-normalized K@H partial), g_raw [C] — and returns the reduced
    triple. Packing all three into a single flat psum is the caller's job
    (``distributed.inner`` concatenates them into one [rows+2, C] buffer),
    which is what turns "exactly one psum per sync" into a statically
    provable property (``launch.audit``). ``None`` in ``engine_stats``
    means single host: no reduction at all.
    """
    fn: Callable

    def __call__(self, counts: Array, f_raw: Array, g_raw: Array):
        return self.fn(counts, f_raw, g_raw)


@dataclasses.dataclass(frozen=True)
class GramEngine:
    """Hashable (jit-static) strategy handle for the exact inner loop.

    mode:          Gram residency — "materialize" | "fused" | "tiled".
    tile_rows:     row-panel height of the tiled mode (bounds its peak HBM).
    pallas:        fused-mode dispatch — "auto" (TPU/GPU) | "always" | "never".
    interpret:     run the Pallas kernel in interpret mode (CPU tests).
    double_buffer: software-pipeline the tiled mode — build Gram panel
                   i+1 while panel i is being contracted, so XLA's
                   latency-hiding scheduler can overlap the build with the
                   contraction (and, on the mesh, with in-flight
                   collectives). Peak HBM holds two panels instead of one.
                   The fused Pallas kernel reuses the flag for its in-kernel
                   DMA slot pipelining (kernels/assign.py).
    precision:     tile-dtype policy (kernels/precision.py) — "f32" | "bf16".
                   ``prepare`` rounds the feature panels ONCE to the tile
                   dtype, so every mode (resident block, Pallas tiles, jnp
                   recompute) contracts the same rounded values and labels
                   stay mode-independent at either precision. materialize
                   additionally STORES the resident K block in the tile
                   dtype — under bf16 that halves the dominant HBM term the
                   planner prices (core.memory ``q_tile``). Accumulation is
                   f32 everywhere, statically enforced by
                   ``repro.analysis.check_precision``.
    """
    mode: str = "materialize"
    tile_rows: int = 256
    pallas: str = "auto"
    interpret: bool = False
    double_buffer: bool = True
    precision: str = "f32"

    def __post_init__(self):
        if self.mode not in ENGINE_MODES:
            raise ValueError(
                f"unknown engine mode {self.mode!r}; have {ENGINE_MODES}")
        if self.pallas not in ("auto", "always", "never"):
            raise ValueError(
                f"pallas must be 'auto'|'always'|'never', got {self.pallas!r}")
        if self.tile_rows < 1:
            raise ValueError(f"tile_rows must be >= 1, got {self.tile_rows}")
        if self.precision not in PRECISIONS:
            raise ValueError(
                f"unknown precision {self.precision!r}; have {PRECISIONS}")

    # -- per-batch setup -----------------------------------------------------

    def prepare(self, spec, x: Array, y: Array) -> GramOp:
        """Set up one contraction side: materialize evaluates (and keeps)
        the block; fused/tiled only record the features. Feature panels are
        rounded to the policy's tile dtype HERE — once per batch — so every
        downstream consumer (Pallas tiles, jnp recompute, resident block)
        sees identical values."""
        p = resolve_precision(self.precision)
        x, y = p.cast_tiles(x), p.cast_tiles(y)
        if self.mode == "materialize":
            # named profiler span (repro.obs.trace): the once-per-batch
            # Gram panel build shows up labelled in a device trace.
            with jax.named_scope("obs:gram_panel_build"):
                # spec() accumulates f32 over the rounded tiles; the
                # RESIDENT copy then stores in the tile dtype (the
                # footprint knob), upcast again at matvec time.
                return GramOp(x=x, y=y, k=spec(x, y).astype(p.tile_dtype))
        return GramOp(x=x, y=y, k=None)

    @staticmethod
    def from_matrix(k: Array) -> GramOp:
        """Wrap a caller-precomputed Gram block (the a-posteriori entry:
        kkmeans_fit_gram / the oracle tests / the dryrun cells). Always
        resident; kept in the caller's dtype (a bf16 K block stays bf16 in
        HBM — the contraction always accumulates fp32)."""
        return GramOp(x=None, y=None, k=k)

    # -- per-iteration contraction -------------------------------------------

    def _use_pallas(self, spec) -> bool:
        if spec is None or spec.name not in _PALLAS_KINDS:
            return False
        if self.pallas == "never":
            return False
        if self.pallas == "always" or self.interpret:
            return True
        # both Pallas lowerings count: Mosaic on TPU, Triton on GPU
        return jax.default_backend() in ("tpu", "gpu")

    @staticmethod
    def _kernel_backend() -> str:
        from repro.kernels.backend import kernel_backend
        return kernel_backend()

    def matvec(self, spec, op: GramOp, h: Array) -> Array:
        """(K @ h) -> [rows, C] fp32 — the Eq.6/17 contraction under this
        mode's residency. ``h`` is any [cols, C] panel (one-hot or
        normalized one-hot of the landmark labels)."""
        h = h.astype(jnp.float32)
        if op.k is not None:           # resident block (materialize / gram)
            return jax.lax.dot_general(op.k.astype(jnp.float32), h,
                                       (((1,), (0,)), ((), ())),
                                       preferred_element_type=jnp.float32)
        if self.mode == "fused" and self._use_pallas(spec):
            from repro.kernels import ops as kops
            return kops.gram_matvec(
                op.x, op.y, h, kind=spec.name, gamma=spec.gamma,
                coef0=spec.coef0, degree=spec.degree,
                interpret=self.interpret, precision=self.precision,
                backend=self._kernel_backend(),
                double_buffer=self.double_buffer)
        if self.mode == "tiled":
            return _tiled_matvec(spec, op.x, op.y, h, self.tile_rows,
                                 double_buffer=self.double_buffer)
        # fused portable fallback: recompute the block, contract, drop it —
        # same math and shapes as materialize, HBM residency only transient.
        k = spec(op.x, op.y).astype(jnp.float32)
        return jax.lax.dot_general(k, h, (((1,), (0,)), ((), ())),
                                   preferred_element_type=jnp.float32)

    def wants_fused_assign(self, spec, op: GramOp) -> bool:
        """True when the one-shot Pallas f+argmin pass applies (fused mode,
        feature-backed op, Pallas-lowerable kernel)."""
        return (self.mode == "fused" and op.k is None
                and self._use_pallas(spec))


def resolve_engine(engine, precision: Optional[str] = None) -> GramEngine:
    """Accept a GramEngine or a mode name (the MiniBatchConfig /
    DistributedInnerConfig currency) and return the engine. ``precision``
    (config-level tile-dtype override) replaces the engine's policy when
    given — configs carry precision as a plain string next to the engine
    mode string, and this is where the two meet."""
    if isinstance(engine, str) and engine in ENGINE_MODES:
        engine = GramEngine(mode=engine)
    if not isinstance(engine, GramEngine):
        raise ValueError(
            f"engine must be a GramEngine or one of {ENGINE_MODES}, "
            f"got {engine!r}")
    if precision is not None and precision != engine.precision:
        engine = dataclasses.replace(engine, precision=precision)
    return engine


def _tiled_matvec(spec, x: Array, y: Array, h: Array,
                  tile_rows: int, *, double_buffer: bool = True) -> Array:
    """Stream [bm, |L|] Gram panels: each panel is built, contracted against
    h and dropped, so peak memory is one panel (two when double-buffered)
    plus the [rows, C] accumulator — never the full block.

    With ``double_buffer`` the loop is software-pipelined: inside each scan
    step the carried panel i is contracted while panel i+1 is built — the
    two are data-independent, so the latency-hiding scheduler is free to
    overlap the build with the contraction (and with any in-flight
    collective the mesh loop has issued). Output is bit-identical either
    way: the same panels are built and contracted in the same order.
    """
    n, d = x.shape
    bm = min(tile_rows, n)
    n_pad = -(-n // bm) * bm
    xp = jnp.pad(x, ((0, n_pad - n), (0, 0)))
    panels = xp.reshape(n_pad // bm, bm, d)

    def build(xt):
        with jax.named_scope("obs:gram_tiled_panel"):
            return spec(xt, y).astype(jnp.float32)

    def contract(kt):
        return jax.lax.dot_general(kt, h, (((1,), (0,)), ((), ())),
                                   preferred_element_type=jnp.float32)

    if not double_buffer or panels.shape[0] == 1:
        f = jax.lax.map(lambda xt: contract(build(xt)), panels)
        return f.reshape(n_pad, h.shape[1])[:n]

    def step(kt, xt_next):
        return build(xt_next), contract(kt)

    k_last, outs = jax.lax.scan(step, build(panels[0]), panels[1:])
    f = jnp.concatenate([outs, contract(k_last)[None]], axis=0)
    return f.reshape(n_pad, h.shape[1])[:n]


def engine_stats_raw(engine: GramEngine, spec, op_xl: GramOp, op_ll: GramOp,
                     labels_l_cols: Array, labels_l_rows: Array,
                     n_clusters: int):
    """Raw (pre-reduction, un-normalized) Eq.5-6/16-17 partials.

    Returns (counts [C], f_raw [rows, C] = K_xl @ H, g_raw [C] =
    diag(H^T K_ll H)) — exactly the payload a mesh shard must reduce
    before ``finalize_stats`` normalizes. Public so the s-step loop can
    keep local/remote partials separate between syncs.
    """
    with jax.named_scope(f"obs:engine_stats[{engine.mode}]"):
        h_cols = jax.nn.one_hot(labels_l_cols, n_clusters, dtype=jnp.float32)
        counts = jnp.sum(h_cols, axis=0)
        f_raw = engine.matvec(spec, op_xl, h_cols)
        h_rows = jax.nn.one_hot(labels_l_rows, n_clusters, dtype=jnp.float32)
        t = engine.matvec(spec, op_ll, h_cols)                 # [Lrows, C]
        g_raw = jnp.sum(h_rows * t, axis=0)
        return counts, f_raw, g_raw


def finalize_stats(counts: Array, f_raw: Array, g_raw: Array):
    """Normalize reduced raw partials into (f, g, counts) — the empty-safe
    divisions every caller shares (same ops, same order, as the historical
    in-line normalization: bit-identical results)."""
    safe = jnp.maximum(counts, 1.0)
    return f_raw / safe[None, :], g_raw / (safe * safe), counts


def engine_stats(engine: GramEngine, spec, op_xl: GramOp, op_ll: GramOp,
                 labels_l_cols: Array, labels_l_rows: Array, n_clusters: int,
                 *, reduce: Optional[ReducePlan] = None):
    """Eq.5-6/16-17 stats — THE shared code path of the single-host and mesh
    inner loops.

    op_xl: batch rows x landmark cols; op_ll: landmark rows x landmark cols.
    labels_l_cols/rows: labels of the column/row landmark slices (identical
    single-host). ``reduce`` is the mesh's single batched collective
    (``ReducePlan``), applied ONCE to the whole raw payload; None means
    single-host. Returns (f [rows, C], g [C], counts [C]), all fp32.
    """
    counts, f_raw, g_raw = engine_stats_raw(
        engine, spec, op_xl, op_ll, labels_l_cols, labels_l_rows, n_clusters)
    if reduce is not None:
        counts, f_raw, g_raw = reduce(counts, f_raw, g_raw)
    return finalize_stats(counts, f_raw, g_raw)


def assign_from_stats(f: Array, g: Array,
                      counts: Array) -> tuple[Array, Array]:
    """Eq.4/15 argmin — the tie-break authority: jnp.argmin returns the
    FIRST (lowest) cluster index among tied minima, and the Pallas fused
    kernel implements the same rule, so every engine mode labels
    identically. Empty clusters are unjoinable (+BIG)."""
    dist = jnp.where(counts[None, :] > 0, g[None, :] - 2.0 * f, BIG)
    labels = jnp.argmin(dist, axis=1).astype(jnp.int32)
    mind = jnp.min(dist, axis=1)
    return labels, mind


def engine_step(engine: GramEngine, spec, op_xl: GramOp, op_ll: GramOp,
                labels_l: Array, n_clusters: int):
    """One full inner-loop sweep: stats + assignment.

    Returns (f, g, counts, labels, mind) with f/g/counts consistent with the
    INPUT labels (what the fixpoint pass needs) and labels/mind the Eq.4
    update. The fused mode folds f + argmin into one Pallas pass (g must be
    known first, so the landmark-rows contraction still runs separately);
    every other mode contracts then calls the shared jnp argmin.
    """
    if engine.wants_fused_assign(spec, op_xl):
        from repro.kernels import ops as kops
        h = jax.nn.one_hot(labels_l, n_clusters, dtype=jnp.float32)
        counts = jnp.sum(h, axis=0)
        safe = jnp.maximum(counts, 1.0)
        t = engine.matvec(spec, op_ll, h)
        g = jnp.sum(h * t, axis=0) / (safe * safe)
        labels, mind, f = kops.assign_fused(
            op_xl.x, op_xl.y, labels_l, counts, g, n_clusters=n_clusters,
            kind=spec.name, gamma=spec.gamma, coef0=spec.coef0,
            degree=spec.degree, interpret=engine.interpret,
            precision=engine.precision, backend=engine._kernel_backend(),
            double_buffer=engine.double_buffer)
        return f, g, counts, labels, mind
    f, g, counts = engine_stats(engine, spec, op_xl, op_ll,
                                labels_l, labels_l, n_clusters)
    labels, mind = assign_from_stats(f, g, counts)
    return f, g, counts, labels, mind
