"""Mini-batch kernel k-means outer loop (paper §3.1, Alg.1).

Per mini-batch i:
  1. fetch X^i (stride or block sampling — repro.data.sampling)
  2. hand the batch to the GramEngine (repro.core.engine): the landmark
     kernel block K^i = K(X^i, X^i[L]) is materialized in HBM, rebuilt in
     VMEM per iteration, or streamed in row panels, per ``cfg.engine``
  3. initialize labels: kernel k-means++ (i = 0) or nearest global medoid via
     the auxiliary matrix K~^i (Eq.8)
  4. inner GD loop to label fixpoint (repro.core.kkmeans)
  5. medoid approximation of the batch prototypes (Eq.7/10)
  6. merge into the global prototypes with the convex combination
     w_j <- (1-a) phi(m_j) + a phi(m_j^i),  a = |w_j^i| / (|w_j^i| + |w_j|)
     re-approximated on the batch (Eq.12); empty batch clusters (a = 0) leave
     the global medoid untouched (paper's empty-cluster rule).

The outer loop is host-side Python (it is inherently sequential — §3.3) and
streams mini-batches; each numbered step above is a single jitted function, so
the whole batch step runs as 2 device programs. Global state between batches
is O(C·d): medoid coordinates, their kernel diagonal, and cardinalities —
exactly what Alg.1 communicates.

Checkpoint/restart: ``fit`` accepts a checkpoint callback invoked after every
merged mini-batch with a serializable ``GlobalState`` — restart loses at most
one mini-batch of work (DESIGN.md §6).
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Callable, Iterable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs import memory as obs_memory
from repro.obs import resolve as resolve_recorder

from .engine import GramEngine, resolve_engine
from .init import assign_to_medoids, kmeans_pp_indices
from .kernels import KernelSpec
from .kkmeans import kkmeans_fit, medoid_indices
from .landmarks import num_landmarks, select_landmark_indices

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class MiniBatchConfig:
    n_clusters: int
    n_batches: int = 1                   # B
    s: float = 1.0                       # landmark fraction knob (Eq.18)
    kernel: KernelSpec = KernelSpec("rbf", gamma=1.0)
    max_inner_iters: int = 100
    sampling: str = "stride"             # "stride" | "block"  (§3.1, Fig.1b)
    seed: int = 0
    restrict_medoids_to_members: bool = False  # Eq.7 is unrestricted
    landmark_multiple_of: int = 1        # distributed runtime alignment
    # -- explicit feature-map knobs (repro.approx; orthogonal to (B, s)) --
    method: str = "exact"  # "exact" | "rff" | "nystrom" | "sketch" | "tensorsketch"
    embed_dim: int = 0                   # m; 0 -> approx.default_embed_dim(C)
    rff_orthogonal: bool = False         # ORF variant (lower variance)
    # landmark-selection strategy (repro.approx.selectors): "uniform" |
    # "rls" | "kpp" or a LandmarkSelector instance. Applies to the paths
    # that pick landmark rows — method="exact" (the Eq.14 expansion) and
    # method="nystrom" (the embedding's landmark set).
    selector: object = "uniform"
    # Gram residency of the exact inner loop (repro.core.engine):
    # "materialize" | "fused" | "tiled" or a GramEngine instance — the
    # planner (core.memory.plan) names the cheapest feasible mode as
    # ``Plan.engine``. Only meaningful for method="exact" (the embedded
    # methods never evaluate Gram blocks).
    engine: object = "materialize"
    # tile-dtype policy of the kernel layer (repro.kernels.precision):
    # "f32" | "bf16". bf16 halves the HBM bytes of every feature/Gram tile
    # (and the resident K block / embedded batch Z) while ALL accumulation
    # stays f32 — the planner (core.memory.plan) prices it via ``q_tile``
    # and may move the engine-mode frontier. Applies to both the exact
    # engine path and the embedded methods.
    precision: str = "f32"
    # s-step communication-avoiding depth of the distributed exact inner
    # loop (distributed.inner.DistributedInnerConfig.s_step): Lloyd
    # refinements per global sync. 1 = fully synchronous (bit-identical
    # to the single-host loop); >1 cuts the collective bill to
    # (1 allgather + 1 psum)/s_step. Single-host fits ignore it.
    s_step: int = 1

    _METHODS = ("exact", "rff", "nystrom", "sketch", "tensorsketch")

    def __post_init__(self):
        if self.s_step < 1:
            raise ValueError(f"s_step must be >= 1, got {self.s_step}")
        if self.method not in self._METHODS:
            raise ValueError(
                f"method must be one of {self._METHODS}, "
                f"got {self.method!r}")
        from repro.approx.selectors import name_of
        if (name_of(self.selector) != "uniform"
                and self.method not in ("exact", "nystrom")):
            raise ValueError(
                f"selector {name_of(self.selector)!r} only applies to "
                f"landmark-based methods ('exact', 'nystrom'); "
                f"method {self.method!r} has no landmarks")
        # validates mode name + precision string (resolve_engine raises on
        # either); the precision override itself is threaded at the
        # resolve_engine call sites below.
        eng = resolve_engine(self.engine, self.precision)
        eng = dataclasses.replace(eng, precision="f32")
        if eng != GramEngine() and self.method != "exact":
            raise ValueError(
                f"engine {eng.mode!r} only applies to method='exact' (the "
                f"embedded method {self.method!r} never evaluates Gram "
                f"blocks — its fused kernel is kernels/embed_assign.py)")


class GlobalState(NamedTuple):
    """O(C·d) cross-batch state — the only thing that survives a batch."""
    medoids: Array        # [C, d] medoid coordinates
    medoid_diag: Array    # [C]    K(m_j, m_j)
    cardinalities: Array  # [C]    accumulated |w_j| (f32; counts are exact)
    batches_done: Array   # []     int32


class BatchStats(NamedTuple):
    inner_iters: int
    cost: float                  # Omega(W^i) at the inner fixpoint (Eq.9)
    displacement: np.ndarray     # [C] feature-space medoid displacement^2
    counts: np.ndarray           # [C] batch cluster cardinalities


class FitResult(NamedTuple):
    state: GlobalState          # EmbedState for embedded methods
    history: list[BatchStats]
    fmap: object = None         # FeatureMap when method != "exact"
    spec: Optional[KernelSpec] = None

    def predict(self, x) -> Array:
        """Label new samples with whatever space this result was fit in.

        ``x`` may be dense rows or a ``repro.data.sparse.CSRBatch`` (O(nnz)
        for the sketch maps; densified row-locally otherwise).

        Routed through the serving bucket ladder
        (``repro.serving.assign.predict``): queries pad to a small fixed
        set of shape buckets, so repeated predicts at ragged query counts
        reuse ~len(DEFAULT_BUCKETS) compiled programs instead of retracing
        per distinct shape. The freeze here is per-call (a cheap panel
        build); a long-lived service should ``serving.freeze(result)``
        once and hold the artifact / an ``AssignService``.
        """
        if self.fmap is None and self.spec is None:
            raise ValueError(
                "FitResult.spec is not set: exact-path prediction needs the "
                "KernelSpec the model was fit with (a default rbf/gamma=1.0 "
                "would silently assign with the wrong kernel)")
        from repro.serving.artifact import freeze
        from repro.serving.assign import predict as predict_frozen
        return predict_frozen(freeze(self), x)


# ---------------------------------------------------------------------------
# jitted batch-step bodies
# ---------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("cfg", "n_landmarks"))
def _first_batch_step(x: Array, key: Array, *, cfg: MiniBatchConfig,
                      n_landmarks: int):
    """Batch 0: k-means++ seeding, inner loop, medoid extraction."""
    spec = cfg.kernel
    diag_k = spec.diag(x)
    k_lm, k_pp = jax.random.split(key)
    l_idx = select_landmark_indices(k_lm, x, n_landmarks, spec,
                                    selector=cfg.selector)

    seeds = kmeans_pp_indices(x, diag_k, k_pp, n_clusters=cfg.n_clusters,
                              spec=spec)
    seed_x = jnp.take(x, seeds, axis=0)
    labels0, _ = assign_to_medoids(x, diag_k, seed_x, spec.diag(seed_x),
                                   spec=spec)

    res = kkmeans_fit(x, l_idx, diag_k, labels0, spec=spec,
                      n_clusters=cfg.n_clusters,
                      max_iters=cfg.max_inner_iters,
                      engine=resolve_engine(cfg.engine, cfg.precision))
    m_idx = medoid_indices(diag_k, res.f, res.labels, res.counts,
                           restrict_to_members=cfg.restrict_medoids_to_members)
    medoids = jnp.take(x, m_idx, axis=0)                           # [C, d]
    state = GlobalState(
        medoids=medoids,
        medoid_diag=spec.diag(medoids),
        cardinalities=res.counts,
        batches_done=jnp.array(1, jnp.int32),
    )
    return state, res


@partial(jax.jit, static_argnames=("cfg", "n_landmarks"))
def _next_batch_step(x: Array, key: Array, state: GlobalState, *,
                     cfg: MiniBatchConfig, n_landmarks: int):
    """Batch i > 0: Eq.8 init, inner loop, Eq.7 medoids, Eq.12 merge."""
    spec = cfg.kernel
    diag_k = spec.diag(x)
    # Same (k_lm, .) split as the first batch and the distributed outer
    # loop: one key schedule across paths means a distributed fit resumed
    # from this state draws the same landmarks as the single-host run.
    k_lm, _ = jax.random.split(key)
    l_idx = select_landmark_indices(k_lm, x, n_landmarks, spec,
                                    selector=cfg.selector)

    # -- init from the previous global medoids (Eq.8); K~^i is [n, C].
    labels0, k_tilde = assign_to_medoids(x, diag_k, state.medoids,
                                         state.medoid_diag, spec=spec)

    res = kkmeans_fit(x, l_idx, diag_k, labels0, spec=spec,
                      n_clusters=cfg.n_clusters,
                      max_iters=cfg.max_inner_iters,
                      engine=resolve_engine(cfg.engine, cfg.precision))

    # -- batch medoids (Eq.7/10).
    m_idx = medoid_indices(diag_k, res.f, res.labels, res.counts,
                           restrict_to_members=cfg.restrict_medoids_to_members)
    k_xm = spec(x, jnp.take(x, m_idx, axis=0)).astype(jnp.float32)  # [n, C]

    # -- merge (Eq.11-13): minimize over the batch
    #    || phi(x_l) - (1-a) phi(m_j) - a phi(m_j^i) ||^2
    #    = K_ll - 2(1-a) K(x_l, m_j) - 2a K(x_l, m_j^i) + const(j).
    alpha = res.counts / jnp.maximum(res.counts + state.cardinalities, 1.0)
    score = (diag_k.astype(jnp.float32)[:, None]
             - 2.0 * (1.0 - alpha)[None, :] * k_tilde
             - 2.0 * alpha[None, :] * k_xm)                         # [n, C]
    merge_idx = jnp.argmin(score, axis=0)                           # [C]
    merged = jnp.take(x, merge_idx, axis=0)                         # [C, d]

    # empty batch cluster -> alpha = 0 -> keep the old global medoid verbatim
    # (the re-approximation argmin would otherwise pull it into this batch).
    keep = (res.counts == 0)[:, None]
    new_medoids = jnp.where(keep, state.medoids, merged)
    new_diag = jnp.where(keep[:, 0], state.medoid_diag, spec.diag(merged))

    # displacement diagnostic (Fig.4b): ||phi(m_new) - phi(m_old)||^2.
    cross = jax.vmap(lambda a, b: spec(a[None, :], b[None, :])[0, 0])(
        new_medoids, state.medoids)
    disp = jnp.maximum(new_diag + state.medoid_diag - 2.0 * cross, 0.0)

    new_state = GlobalState(
        medoids=new_medoids,
        medoid_diag=new_diag,
        cardinalities=state.cardinalities + res.counts,
        batches_done=state.batches_done + 1,
    )
    return new_state, res, disp


@partial(jax.jit, static_argnames=("spec",))
def predict(x: Array, medoids: Array, medoid_diag: Array, *,
            spec: KernelSpec) -> Array:
    """Label new samples by nearest global medoid in feature space."""
    labels, _ = assign_to_medoids(x, spec.diag(x), medoids, medoid_diag,
                                  spec=spec)
    return labels


# ---------------------------------------------------------------------------
# host-side driver
# ---------------------------------------------------------------------------


def fit(
    batches: Iterable[np.ndarray],
    cfg: MiniBatchConfig,
    *,
    state: Optional[GlobalState] = None,
    checkpoint_cb: Optional[Callable[[GlobalState, int], None]] = None,
    fmap=None,
    recorder=None,
) -> FitResult:
    """Run the outer loop over an iterable of mini-batches.

    ``batches`` may be a generator (block sampling over a stream) or a list
    (stride sampling over a known dataset) — see ``repro.data.sampling``.
    Passing a previous ``state`` resumes after a restart (the iterable should
    then yield only the remaining batches).

    With ``cfg.method != "exact"`` the loop runs in the explicit
    m-dimensional embedded space instead (repro.approx): the feature map is
    sampled from the first mini-batch, every batch is embedded once, and the
    inner loop is plain Lloyd — no kernel-block evaluation at all. ``fmap``
    carries a previously sampled map across a restart (required when
    resuming an embedded fit; the map is part of the model). The sketch
    methods additionally accept ``repro.data.sparse.CSRBatch`` mini-batches,
    keeping the embedding step O(nnz) for high-dimensional sparse rows.

    ``batches`` may also be a ``repro.data.BatchSource`` (the unified
    ingestion handle: list / live stream / prefetch); fit consumes it, so a
    closable source is closed on exit — success or failure — and the
    prefetch producer thread never leaks.

    ``recorder`` (``repro.obs``) is the flight recorder: per-batch wall
    time, cost/displacement series, empty-cluster counts and an HBM
    watermark next to the planner-predicted footprint. All hooks are
    host-side, outside the jitted steps — enabling metrics changes no
    traced program (tests/test_obs.py asserts the compile counts match).
    """
    from repro.data.loader import closing_source
    with closing_source(batches):
        return _fit(batches, cfg, state=state, checkpoint_cb=checkpoint_cb,
                    fmap=fmap, recorder=recorder)


def _fit(batches, cfg, *, state, checkpoint_cb, fmap,
         recorder=None) -> FitResult:
    rec = resolve_recorder(recorder)
    if cfg.method != "exact":
        return _fit_embedded(batches, cfg, state=state,
                             checkpoint_cb=checkpoint_cb, fmap=fmap,
                             recorder=rec)
    from repro.data.sparse import is_sparse

    key = jax.random.PRNGKey(cfg.seed)
    history: list[BatchStats] = []
    start = int(state.batches_done) if state is not None else 0

    for i, xb in enumerate(batches, start=start):
        t_batch = time.perf_counter()
        if is_sparse(xb):
            raise ValueError(
                "method='exact' evaluates kernel blocks on dense rows and "
                "cannot take CSRBatch mini-batches; use a sketch method "
                "(method='sketch'|'tensorsketch') to stay O(nnz), or "
                "densify explicitly with repro.data.sparse.to_dense")
        xb = jnp.asarray(xb)
        n = xb.shape[0]
        n_l = num_landmarks(n, cfg.s, n_clusters=cfg.n_clusters,
                            multiple_of=cfg.landmark_multiple_of)
        # Pure per-batch key schedule: batch i's key depends only on
        # (cfg.seed, i), never on how many batches this process has already
        # run — a resumed fit (state restored, i starting at batches_done)
        # must draw the same landmarks as the uninterrupted run
        # (checkpoint/restart guarantee; same schedule as the embedded path).
        sub = jax.random.fold_in(key, i)
        if state is None:
            state, res = _first_batch_step(xb, sub, cfg=cfg, n_landmarks=n_l)
            disp = jnp.zeros((cfg.n_clusters,), jnp.float32)
        else:
            state, res, disp = _next_batch_step(xb, sub, state, cfg=cfg,
                                                n_landmarks=n_l)
        # flight recorder: device scalars are parked unconverted (the
        # batch_boundary drain fetches them in one batched device_get) —
        # a mid-loop blocking sync would serialize the dispatch stream.
        rec.series("inner/cost", res.cost, batch=i)
        rec.series("inner/iters", res.n_iter, batch=i)
        history.append(BatchStats(
            inner_iters=int(res.n_iter),
            cost=float(res.cost),
            displacement=np.asarray(disp),
            counts=np.asarray(res.counts),
        ))
        if checkpoint_cb is not None:
            checkpoint_cb(state, i)
        if rec.enabled:
            h = history[-1]
            rec.series("batch/wall_seconds",
                       time.perf_counter() - t_batch, batch=i, rows=n)
            rec.gauge("clusters/empty", int((h.counts == 0).sum()), batch=i)
            rec.gauge("medoids/mean_displacement",
                      float(np.mean(h.displacement)), batch=i)
            obs_memory.watermark(
                rec, batch=i, engine=resolve_engine(cfg.engine, cfg.precision).mode,
                predicted_bytes=obs_memory.predicted_batch_footprint(
                    cfg, n, int(xb.shape[1])))
            rec.batch_boundary(i)
    if state is None:
        raise ValueError("empty batch iterable")
    return FitResult(state, history, spec=cfg.kernel)


def _fit_embedded(batches, cfg: MiniBatchConfig, *, state=None,
                  checkpoint_cb=None, fmap=None, recorder=None) -> FitResult:
    """Embedded-space dispatch target of ``fit`` (cfg.method != 'exact')."""
    import itertools

    from repro import approx
    from repro.data.sparse import is_sparse

    it = iter(batches)
    if fmap is None:
        if state is not None:
            raise ValueError(
                "resuming an embedded fit requires the original fmap "
                "(the sampled feature map is part of the model)")
        try:
            first = next(it)
        except StopIteration:
            raise ValueError("empty batch iterable") from None
        if not is_sparse(first):
            first = jnp.asarray(first)
        m = cfg.embed_dim or approx.default_embed_dim(cfg.n_clusters)
        fmap = approx.make_feature_map(
            cfg.method, jax.random.PRNGKey(cfg.seed), first, m, cfg.kernel,
            orthogonal=cfg.rff_orthogonal, selector=cfg.selector)
        it = itertools.chain([first], it)
    est, history = approx.fit_embedded(
        it, fmap, n_clusters=cfg.n_clusters, max_iters=cfg.max_inner_iters,
        seed=cfg.seed, state=state, checkpoint_cb=checkpoint_cb,
        recorder=recorder, precision=cfg.precision)
    return FitResult(est, history, fmap=fmap, spec=cfg.kernel)


def fit_dataset(x, cfg: MiniBatchConfig, **kw) -> FitResult:
    """Convenience: stride/block-split a resident dataset (dense [n, d] or
    ``CSRBatch``) into the unified ``BatchSource``, then ``fit``."""
    from repro.data.loader import BatchSource
    return fit(BatchSource.from_dataset(x, cfg.n_batches,
                                        strategy=cfg.sampling), cfg, **kw)
