"""Mercer kernel functions (Gram-block evaluation).

The paper (Eq.4) replaces the transformed-space inner product
``<phi(x_m), phi(x_n)>`` with a generic Mercer kernel ``K(x_m, x_n)``.
Every kernel here evaluates a *block* ``K(X, Y) -> [m, n]`` so that the
distributed runtime / Pallas kernels can tile it freely.

All kernels accumulate in fp32 regardless of the input dtype (bf16 features
are fine; norms and the exp are always fp32) — see DESIGN.md §2 item 3.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp

Array = jax.Array

# ---------------------------------------------------------------------------
# primitive Gram-block evaluators
# ---------------------------------------------------------------------------


def _dot(x: Array, y: Array) -> Array:
    """fp32-accumulated X @ Y^T."""
    return jax.lax.dot_general(
        x, y,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


def sq_distances(x: Array, y: Array) -> Array:
    """Pairwise squared euclidean distances ||x_i - y_j||^2, clamped >= 0."""
    xx = jnp.sum(x.astype(jnp.float32) ** 2, axis=-1)[:, None]
    yy = jnp.sum(y.astype(jnp.float32) ** 2, axis=-1)[None, :]
    d2 = xx + yy - 2.0 * _dot(x, y)
    return jnp.maximum(d2, 0.0)


def linear_kernel(x: Array, y: Array) -> Array:
    return _dot(x, y)


def rbf_kernel(x: Array, y: Array, *, gamma: float) -> Array:
    return jnp.exp(-gamma * sq_distances(x, y))


def laplacian_kernel(x: Array, y: Array, *, gamma: float) -> Array:
    # L1 distances do not factor through the MXU; this kernel is the
    # "non-symmetric-friendly" example the paper alludes to (any similarity).
    d1 = jnp.sum(
        jnp.abs(x.astype(jnp.float32)[:, None, :] - y.astype(jnp.float32)[None, :, :]),
        axis=-1,
    )
    return jnp.exp(-gamma * d1)


def polynomial_kernel(x: Array, y: Array, *, gamma: float, coef0: float, degree: int) -> Array:
    return (gamma * _dot(x, y) + coef0) ** degree


def cosine_kernel(x: Array, y: Array, *, eps: float = 1e-12) -> Array:
    xn = jnp.sqrt(jnp.sum(x.astype(jnp.float32) ** 2, axis=-1))[:, None]
    yn = jnp.sqrt(jnp.sum(y.astype(jnp.float32) ** 2, axis=-1))[None, :]
    return _dot(x, y) / jnp.maximum(xn * yn, eps)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

KernelFn = Callable[[Array, Array], Array]


@dataclasses.dataclass(frozen=True)
class KernelSpec:
    """Declarative kernel description (hashable -> safe as a jit static arg)."""

    name: str = "rbf"
    gamma: float = 1.0
    coef0: float = 1.0
    degree: int = 3

    def __call__(self, x: Array, y: Array) -> Array:
        return get_kernel(self)(x, y)

    def diag(self, x: Array) -> Array:
        """K(x_i, x_i) for every row — cheap, no Gram block."""
        if self.name in ("rbf", "laplacian", "cosine"):
            return jnp.ones((x.shape[0],), jnp.float32)
        if self.name == "linear":
            return jnp.sum(x.astype(jnp.float32) ** 2, axis=-1)
        if self.name == "polynomial":
            sq = jnp.sum(x.astype(jnp.float32) ** 2, axis=-1)
            return (self.gamma * sq + self.coef0) ** self.degree
        raise ValueError(f"unknown kernel {self.name!r}")


_REGISTRY: dict[str, Callable[..., Array]] = {
    "linear": linear_kernel,
    "rbf": rbf_kernel,
    "laplacian": laplacian_kernel,
    "polynomial": polynomial_kernel,
    "cosine": cosine_kernel,
}


def get_kernel(spec: KernelSpec) -> KernelFn:
    """Resolve a KernelSpec to a Gram-block function ``(X, Y) -> [m, n]``."""
    name = spec.name
    if name not in _REGISTRY:
        raise ValueError(f"unknown kernel {name!r}; have {sorted(_REGISTRY)}")
    if name == "linear":
        return linear_kernel
    if name == "cosine":
        return cosine_kernel
    if name in ("rbf", "laplacian"):
        return partial(_REGISTRY[name], gamma=spec.gamma)
    return partial(
        polynomial_kernel, gamma=spec.gamma, coef0=spec.coef0, degree=spec.degree
    )


def gamma_from_dmax(x: Array, *, factor: float = 4.0) -> float:
    """The paper's sigma = 4*d_max rule (§4.4) to mimic linear behaviour.

    sigma = factor * d_max  ->  gamma = 1 / (2 sigma^2).
    d_max is estimated as the diameter of the bounding box (exact pairwise
    d_max is O(N^2), which is exactly what this code base exists to avoid).
    """
    span = jnp.max(x, axis=0) - jnp.min(x, axis=0)
    d_max = float(jnp.sqrt(jnp.sum(span.astype(jnp.float32) ** 2)))
    sigma = factor * max(d_max, 1e-12)
    return 1.0 / (2.0 * sigma * sigma)
