"""Clustering quality measures (paper §4): accuracy via majority-vote mapping,
normalized mutual information, the elbow criterion, and the sampling-quality
displacement diagnostic.

These score the MODEL (how good is the clustering). Runtime metrics — how
the run behaved: per-batch wall time, collective counts, HBM watermarks,
prefetch-queue health — are a different subsystem, the ``repro.obs``
flight recorder; see the "Reading the flight recorder" section in
``repro.core.memory``."""
from __future__ import annotations

import numpy as np


def contingency(labels_true: np.ndarray, labels_pred: np.ndarray,
                n_true: int | None = None, n_pred: int | None = None) -> np.ndarray:
    """o_{i,j} = #{k : u_k = i and y_k = j}   (rows = predicted clusters)."""
    labels_true = np.asarray(labels_true).astype(np.int64)
    labels_pred = np.asarray(labels_pred).astype(np.int64)
    nt = int(n_true if n_true is not None else labels_true.max() + 1)
    npred = int(n_pred if n_pred is not None else labels_pred.max() + 1)
    o = np.zeros((npred, nt), dtype=np.int64)
    np.add.at(o, (labels_pred, labels_true), 1)
    return o


def clustering_accuracy(labels_true: np.ndarray, labels_pred: np.ndarray) -> float:
    """mu(y, u) with the paper's majority-voting cluster->class mapping psi."""
    o = contingency(labels_true, labels_pred)
    # psi maps every predicted cluster to its majority true class.
    return float(o.max(axis=1).sum() / max(len(np.asarray(labels_true)), 1))


def nmi(labels_true: np.ndarray, labels_pred: np.ndarray) -> float:
    """Normalized mutual information, NMI(y, u) (paper §4 definition)."""
    o = contingency(labels_true, labels_pred).astype(np.float64)
    n = o.sum()
    if n == 0:
        return 0.0
    pi = o.sum(axis=1)  # predicted-cluster sizes  n_i
    pj = o.sum(axis=0)  # true-class sizes         m_j
    with np.errstate(divide="ignore", invalid="ignore"):
        num = o * np.log((n * o) / np.outer(pi, pj))
    mi = np.nansum(num) / n
    hu = -np.sum((pi[pi > 0] / n) * np.log(pi[pi > 0] / n))
    hy = -np.sum((pj[pj > 0] / n) * np.log(pj[pj > 0] / n))
    denom = np.sqrt(hu * hy)
    return float(mi / denom) if denom > 0 else 0.0


def elbow(costs: list[float] | np.ndarray) -> int:
    """Elbow criterion (paper §4.4/§4.5): index of maximum curvature of the
    cost-vs-C curve (largest positive second difference)."""
    c = np.asarray(costs, dtype=np.float64)
    if len(c) < 3:
        return 0
    d2 = c[:-2] - 2 * c[1:-1] + c[2:]
    return int(np.argmax(d2) + 1)


def mean_displacement(history) -> np.ndarray:
    """Average medoid displacement per outer iteration (Fig.4b observable).

    Small & flat => the sampling strategy represents the dataset well;
    spikes => concept drift (block sampling over a drifting stream).
    """
    return np.asarray([float(np.mean(h.displacement)) for h in history])
