"""A-priori sparse centroid representation — landmark selection (paper §3.2).

The centroid expansion (Eq.14) is restricted to |L| landmarks uniformly
sampled from each mini-batch; the sparsity knob is

    s = (|L| / N) * B          (Eq.18)   <=>   |L| = s * (N / B)

so ``s = 1`` recovers the exact mini-batch algorithm and the number of kernel
evaluations per batch drops from (N/B)^2 to s * (N/B)^2.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def num_landmarks(batch_size: int, s: float, *, n_clusters: int, multiple_of: int = 1) -> int:
    """|L| = ceil(s * batch_size), clamped to [C, batch_size].

    ``multiple_of`` lets the distributed runtime round |L| up to a multiple of
    the landmark-sharding axis size so every device gets an equal slice.
    """
    if not (0.0 < s <= 1.0):
        raise ValueError(f"s must be in (0, 1], got {s}")
    l = max(int(-(-s * batch_size // 1)), n_clusters)  # ceil, >= C
    if multiple_of > 1:
        l = -(-l // multiple_of) * multiple_of         # round up to multiple
        if l > batch_size:                             # can't exceed the batch
            l = (batch_size // multiple_of) * multiple_of
        if l < n_clusters:
            raise ValueError(
                f"batch={batch_size} too small for C={n_clusters} landmarks "
                f"in multiples of {multiple_of}")
    return min(l, batch_size)


def choose_landmarks(key: Array, batch_size: int, n_landmarks: int) -> Array:
    """Uniform sample WITHOUT replacement of landmark indices (sorted).

    Sorted order keeps the row-gather ``k_xl[l_idx]`` cache/DMA friendly.
    """
    if n_landmarks > batch_size:
        raise ValueError(f"|L|={n_landmarks} > batch={batch_size}")
    if n_landmarks == batch_size:
        return jnp.arange(batch_size, dtype=jnp.int32)
    idx = jax.random.choice(key, batch_size, (n_landmarks,), replace=False)
    return jnp.sort(idx).astype(jnp.int32)
