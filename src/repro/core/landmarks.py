"""A-priori sparse centroid representation — landmark selection (paper §3.2).

The centroid expansion (Eq.14) is restricted to |L| landmarks sampled from
each mini-batch; the sparsity knob is

    s = (|L| / N) * B          (Eq.18)   <=>   |L| = s * (N / B)

so ``s = 1`` recovers the exact mini-batch algorithm and the number of kernel
evaluations per batch drops from (N/B)^2 to s * (N/B)^2.

*Which* |L| rows get picked is a strategy, not a constant: the paper samples
uniformly (``choose_landmarks``), but the Eq.14 expansion can instead be
restricted to high-ridge-leverage rows — ``repro.approx.selectors`` owns the
strategy contract (uniform / rls / kpp) and ``select_landmark_indices`` is
the dispatch the mini-batch steps call.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def num_landmarks(batch_size: int, s: float, *, n_clusters: int, multiple_of: int = 1) -> int:
    """|L| = ceil(s * batch_size), clamped to [C, batch_size].

    ``multiple_of`` lets the distributed runtime round |L| up to a multiple of
    the landmark-sharding axis size so every device gets an equal slice.
    All clamping happens here, in one place: an infeasible combination —
    fewer batch rows than clusters, or no multiple of ``multiple_of`` in
    [C, batch_size] — raises instead of silently shrinking |L| below C.
    """
    if not (0.0 < s <= 1.0):
        raise ValueError(f"s must be in (0, 1], got {s}")
    if batch_size < n_clusters:
        raise ValueError(
            f"infeasible landmark count: the centroid expansion needs at "
            f"least C={n_clusters} landmarks but the mini-batch has only "
            f"{batch_size} rows — grow the batch (lower B) or lower C")
    l = max(int(-(-s * batch_size // 1)), n_clusters)  # ceil, >= C
    if multiple_of > 1:
        l = -(-l // multiple_of) * multiple_of         # round up to multiple
        if l > batch_size:                             # can't exceed the batch
            l = (batch_size // multiple_of) * multiple_of
        if l < n_clusters:
            raise ValueError(
                f"infeasible landmark count: no multiple of {multiple_of} in "
                f"[C={n_clusters}, batch={batch_size}] — shrink the mesh's "
                f"landmark axis, grow the batch (lower B), or lower C")
    return l


def choose_landmarks(key: Array, batch_size: int, n_landmarks: int) -> Array:
    """Uniform sample WITHOUT replacement of landmark indices (sorted).

    Sorted order keeps the row-gather ``k_xl[l_idx]`` cache/DMA friendly.
    This is the ``selector="uniform"`` strategy; see
    ``repro.approx.selectors`` for the leverage-aware alternatives.
    """
    if n_landmarks > batch_size:
        raise ValueError(f"|L|={n_landmarks} > batch={batch_size}")
    if n_landmarks == batch_size:
        return jnp.arange(batch_size, dtype=jnp.int32)
    idx = jax.random.choice(key, batch_size, (n_landmarks,), replace=False)
    return jnp.sort(idx).astype(jnp.int32)


def select_landmark_indices(key: Array, x: Array, n_landmarks: int, spec,
                            selector="uniform") -> Array:
    """Strategy-dispatched landmark indices for one mini-batch.

    ``selector`` is a name or ``repro.approx.selectors.LandmarkSelector``;
    ``spec`` is the ``KernelSpec`` leverage-aware strategies score with
    (ignored by ``uniform``). Jit-traceable with static shapes.
    """
    from repro.approx.selectors import resolve
    return resolve(selector).select_indices(key, x, n_landmarks, spec)
