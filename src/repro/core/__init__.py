# The paper's primary contribution: approximate distributed mini-batch
# kernel k-means (Ferrarotti, Decherchi & Rocchia, CS.DC 2017).
from .engine import (GramEngine, ReducePlan, assign_from_stats,
                     engine_stats, engine_stats_raw, finalize_stats,
                     resolve_engine)
from .kernels import KernelSpec, gamma_from_dmax, get_kernel, sq_distances
from .kkmeans import (InnerResult, kkmeans_fit, kkmeans_fit_full,
                      kkmeans_fit_gram, medoid_indices)
from .init import assign_to_medoids, kmeans_pp_indices
from .landmarks import (choose_landmarks, num_landmarks,
                        select_landmark_indices)
from .memory import (MachineSpec, Plan, b_min, b_min_paper,
                     embed_footprint_bytes, engine_footprint_bytes,
                     footprint_bytes, host_staging_bytes, plan,
                     predicted_accuracy, s_step_state_bytes,
                     selector_footprint_bytes, sketch_footprint_bytes)
from .metrics import clustering_accuracy, elbow, mean_displacement, nmi
from .minibatch import (FitResult, GlobalState, MiniBatchConfig, fit,
                        fit_dataset, predict)

__all__ = [
    "GramEngine", "ReducePlan", "assign_from_stats", "engine_stats",
    "engine_stats_raw", "finalize_stats", "resolve_engine",
    "KernelSpec", "gamma_from_dmax", "get_kernel", "sq_distances",
    "InnerResult", "kkmeans_fit", "kkmeans_fit_full", "kkmeans_fit_gram",
    "medoid_indices",
    "assign_to_medoids", "kmeans_pp_indices",
    "choose_landmarks", "num_landmarks", "select_landmark_indices",
    "MachineSpec", "Plan", "b_min", "b_min_paper", "embed_footprint_bytes",
    "engine_footprint_bytes", "footprint_bytes", "host_staging_bytes",
    "plan", "predicted_accuracy", "s_step_state_bytes",
    "selector_footprint_bytes", "sketch_footprint_bytes",
    "clustering_accuracy", "elbow", "mean_displacement", "nmi",
    "FitResult", "GlobalState", "MiniBatchConfig", "fit", "fit_dataset",
    "predict",
]
