"""Kernelized k-means++ seeding (paper §3.1, after Arthur & Vassilvitskii [8]).

Seeds are picked with probability proportional to the squared feature-space
distance to the nearest already-chosen seed:

    d^2(x_i, x_c) = K_ii + K_cc - 2 K_ic

Greedy variant (Arthur & Vassilvitskii's remark; sklearn default): each step
samples ``2 + floor(ln C)`` candidates from the D^2 distribution and keeps
the one minimizing the resulting potential sum_i min d^2 — substantially
more robust to unlucky draws (two seeds in one cluster) at the cost of a few
extra kernel columns per step.

Only O(C log C) kernel *columns* are ever evaluated — the full mini-batch
Gram matrix is NOT required, which keeps seeding memory-aware in the same
spirit as the rest of the paper.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .kernels import KernelSpec

Array = jax.Array


@partial(jax.jit, static_argnames=("n_clusters", "spec"))
def kmeans_pp_indices(
    x: Array,
    diag_k: Array,
    key: Array,
    *,
    n_clusters: int,
    spec: KernelSpec,
) -> Array:
    """Pick C seed indices from the batch ``x`` via kernel k-means++.

    Returns [C] int32 indices into ``x``.
    """
    import math

    n = x.shape[0]
    diag_k = diag_k.astype(jnp.float32)
    n_cand = 2 + int(math.log(max(n_clusters, 1)))  # greedy candidate pool

    key, sub = jax.random.split(key)
    first = jax.random.randint(sub, (), 0, n, dtype=jnp.int32)

    def step(carry, key_t):
        mind2, chosen, t = carry
        # d^2 to the latest chosen seed; keep the running minimum.
        c = chosen[t]
        kc = spec(x, x[c][None, :])[:, 0]                    # [n] one column
        d2 = jnp.maximum(diag_k + diag_k[c] - 2.0 * kc, 0.0)
        mind2 = jnp.minimum(mind2, d2)
        # sample candidate seeds ~ mind2 (categorical over log-probs).
        logp = jnp.where(mind2 > 0, jnp.log(jnp.maximum(mind2, 1e-30)), -jnp.inf)
        # all-zero guard (duplicate points): fall back to uniform.
        logp = jnp.where(jnp.all(~jnp.isfinite(logp)), jnp.zeros_like(logp), logp)
        cands = jax.random.categorical(key_t, logp,
                                       shape=(n_cand,)).astype(jnp.int32)
        # greedy: keep the candidate with the smallest resulting potential.
        kc2 = spec(x, jnp.take(x, cands, axis=0))            # [n, n_cand]
        d2c = jnp.maximum(diag_k[:, None] + jnp.take(diag_k, cands)[None, :]
                          - 2.0 * kc2, 0.0)
        pot = jnp.sum(jnp.minimum(mind2[:, None], d2c), axis=0)  # [n_cand]
        nxt = cands[jnp.argmin(pot)]
        chosen = chosen.at[t + 1].set(nxt)
        return (mind2, chosen, t + 1), None

    chosen0 = jnp.zeros((n_clusters,), jnp.int32).at[0].set(first)
    mind0 = jnp.full((n,), jnp.inf, jnp.float32)
    keys = jax.random.split(key, n_clusters - 1)
    (_, chosen, _), _ = jax.lax.scan(step, (mind0, chosen0, 0), keys)
    return chosen


@partial(jax.jit, static_argnames=("spec",))
def assign_to_medoids(
    x: Array,
    diag_k: Array,
    medoids: Array,
    medoid_diag: Array,
    *,
    spec: KernelSpec,
) -> tuple[Array, Array]:
    """Eq.8: nearest-medoid labels for a fresh mini-batch.

    This evaluates the auxiliary kernel matrix K~^i of size [n, C] (the only
    extra cost the initialization step introduces, §3.1).

    Returns (labels [n] int32, k_tilde [n, C] f32).
    """
    k_tilde = spec(x, medoids).astype(jnp.float32)                  # [n, C]
    d2 = diag_k.astype(jnp.float32)[:, None] + medoid_diag[None, :] - 2.0 * k_tilde
    return jnp.argmin(d2, axis=1).astype(jnp.int32), k_tilde
