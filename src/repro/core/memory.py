"""Memory-aware planning of (B, s) — the paper's Eq.19 and §4.2 rationale.

The per-node footprint of one mini-batch iteration (paper §3.3, s = 1) is

    M(B) = Q * ( N/(B*P) * (N/B + C) + N/B + 2C )        [bytes]

(K rows + f rows + labels + g + medoid bookkeeping). Setting M(B) <= R and
solving for B gives B_min. The paper's printed Eq.19 drops a 4/P factor on
R/Q under the square root; ``b_min_paper`` reproduces the printed formula,
``b_min`` solves the quadratic exactly (they agree in the paper's regime
C << R/Q). With landmarks the K-row term shrinks by s; with the fused
assignment path (DESIGN.md §2) the K term disappears entirely and B_min is
driven by feature storage — ``plan`` reports all three.

The exact path's Gram residency is itself a priced strategy
(``repro.core.engine``): ``engine_footprint_bytes`` gives the per-node
bytes of one inner iteration under each GramEngine mode —

    materialize:  rows*|L| (K resident)        + rows*C (f)
    fused:        0        (K tiles in VMEM)   + rows*C
    tiled:        bm*|L|   (one streamed panel)+ rows*C

— and ``plan`` names the cheapest-FLOP mode that fits the budget as
``Plan.engine`` (materialize reads K, the others rebuild it every
iteration), with all three bills in ``Plan.engine_footprints``. This is the
paper's §3.3 producer/consumer offload as a menu: when the caller pins B
below B_min (``plan(b=...)``) the resident block stops fitting and the plan
degrades to ``tiled`` (portable) instead of failing — ``s = 1`` survives
any batch the panel fits.

Explicit feature maps (repro.approx) change the footprint shape entirely:
the embedded mini-batch is linear in the batch size,

    M_embed(B) = Q * ( N/(B*P) * m + C*m + map )         [bytes]

(embedded rows + embedded centroids + the map parameters: m*d for RFF
frequencies / Nystrom landmarks+whitening). ``plan`` computes this next to
the kernel-block footprint and picks whichever method is cheaper at the
chosen (B, s) — the embedded method wins whenever m < s*N/B + C.

Sketch maps (count-sketch / TensorSketch, repro.approx.sketch) shrink the
map-parameter term to O(d) integer tables and — on sparse inputs — the
batch storage to O(nnz):

    M_sketch(B) = Q * ( N/(B*P) * m + C*m ) + 5*d + 2*Q*rho*d*N/(B*P)

(rho = input density; data+index pairs for the CSR rows, 4-byte hash +
1-byte sign per input dim). ``plan(sketchable=True)`` lets the auto-pick
name "sketch" for linear/polynomial-kernel workloads.

Streaming mode adds a HOST-side term the device formulas above ignore: the
prefetch pipeline (``repro.data.PrefetchLoader``) keeps up to ``depth``
staged batches in flight next to the one being consumed, so the host
footprint is (1 + depth) batches — dense ``Q * N/B * d`` each, or
``(Q+4)*rho*d*N/B`` (Q-byte value, int32 index) pairs when the stream
stays CSR.
``plan(prefetch_depth=)`` reports it as ``Plan.host_footprint``; it is what
bounds ``depth`` on a RAM-tight ingest node, exactly the §3.3
producer/consumer trade the paper makes on the CPU side.

Landmark selection is itself a costed strategy (``repro.approx.selectors``).
``plan(selector=)`` adds the selection footprint to the embedded-method
competition:

    M_sel(uniform) = 4m                                   [index vector]
    M_sel(rls)     = Q * (3 m^2 + 2 N/(B*P))
    M_sel(kpp)     = Q * (N/(B*P) * (2 + ln m) + 2 N/(B*P))

(rls: three m x m blocks — K_SS, its whitening, the psum'd sketch G —
plus per-row score/priority vectors; the whitened pilot panel C [rows, m]
reuses the Z allocation the embedded fit needs every batch anyway, and
the input rows are already priced by the embed term. kpp: the greedy
candidate kernel columns plus the running D^2 vector.)

What those selection bytes BUY is the point: ``Plan.frontier()`` ranks the
strategies by *predicted accuracy per byte at a fixed budget* — and the
exact path competes on it: the ``exact-tiled`` candidate prices the Eq.14
landmark expansion at |L| = m landmarks under the tiled engine (one
streamed panel instead of a resident block), with the same
landmark-quality accuracy model as Nystrom, so "keep the exact inner loop
but stream its Gram block" is ranked against "switch representation"
on the same accuracy-per-byte axis. The
accuracy model is deliberately coarse — Nystrom error tracks the kernel's
spectral tail, and RLS-sampled landmarks cover that tail like ~1.6x as
many uniform ones (kpp ~1.25x; constants from the RLS literature's
k-log-k vs k/eps sampling bounds, validated qualitatively by the
``fig5_approx_sweep`` selector grid), while a count-sketch behaves like a
JL projection with error ~ sqrt(C/m). At a fixed byte budget each
candidate gets its maximal feasible m, the model predicts its accuracy,
and the report is sorted by accuracy-per-byte — uniform sampling pays the
same bytes per landmark but buys measurably less accuracy with them.

Reading the flight recorder
---------------------------

Everything this module PREDICTS, the ``repro.obs`` flight recorder
MEASURES. A fit run with a ``JsonlRecorder`` writes one JSON object per
line; the lines that close the loop with the planner:

* ``{"kind": "event", "name": "hbm_watermark", ...}`` — one per
  mini-batch: ``measured_bytes``/``peak_bytes`` from the allocator
  (``device.memory_stats()``; ``source: "host_rss"`` on backends without
  allocator stats) NEXT TO ``predicted_bytes``, which is exactly
  ``engine_footprint_bytes`` / ``embed_footprint_bytes`` /
  ``sketch_footprint_bytes`` re-priced at that batch's (rows, mode, m).
  A systematic measured/predicted gap is the calibration signal the
  self-tuning planner needs.
* ``{"kind": "series", "name": "batch/wall_seconds", ...}`` — per-batch
  wall time (tags: batch, rows); ``inner/cost`` and ``inner/iters`` are
  the per-batch convergence trajectory.
* ``{"kind": "counter", "name": "collectives/psum", ...}`` — the
  analytic communication bill (``distributed.inner/embed
  .collectives_per_iteration`` x the batch's realized inner iterations):
  the measurable counterpart of the paper's Q*(N/(B*P) + 2C) bound.
* ``prefetch/queue_depth`` (gauge), ``prefetch/stage_seconds`` and
  ``prefetch/starve_seconds`` (series) — ingestion-pipeline health: a
  shallow queue with a starved consumer means the host, not the mesh, is
  the bottleneck (the §3.3 trade, observed live).
* ``straggler_detected`` / ``batch_timing`` events come from
  ``repro.ft.straggler.StragglerMonitor``; ``elastic/resume`` and
  ``elastic/checkpoint`` from the elastic runner.

``repro.obs.export.summarize(path)`` folds a log into the per-series
count/total/max/mean digest that ``benchmarks/common.record_bench`` stores
in ``results/BENCH_*.json``. For device-side timelines, wrap a run with
``repro.obs.start_profile(logdir)``/``stop_profile()`` and open the dump
in TensorBoard — the hot paths are labelled with ``obs:*`` named scopes
(``obs:gram_panel_build``, ``obs:engine_stats[mode]``, ``obs:psum_*``,
``obs:embed_phi``, ``obs:stage``).

These are RUNTIME metrics; clustering QUALITY metrics (accuracy, NMI,
elbow, displacement) live in ``repro.core.metrics``.
"""
from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass(frozen=True)
class MachineSpec:
    """Per-processor memory budget. Defaults: one TPU v5e chip."""
    memory_bytes: float = 16e9        # R
    n_processors: int = 256           # P
    bytes_per_scalar: int = 4         # Q (fp32 kernel rows)
    hbm_gbps: float = 819.0
    peak_tflops_bf16: float = 197.0
    ici_gbps_per_link: float = 50.0


def footprint_bytes(n: int, b: int, c: int, p: int, q: int = 4, *,
                    s: float = 1.0, d: int = 0, fused: bool = False) -> float:
    """Per-node bytes for one mini-batch inner-loop iteration.

    Paper formula plus: landmark scaling of the K-block columns (s), optional
    feature storage (d > 0: the batch itself + landmarks live on-node for
    kernel evaluation), and the fused path that never materializes K.
    """
    nb = n / b                       # mini-batch size
    rows = nb / p                    # rows owned by this node
    cols = s * nb                    # landmark columns
    k_term = 0.0 if fused else rows * (cols + c)   # K rows + f rows
    feat = d * (rows + cols) if d else 0.0         # X rows + landmark rows
    return q * (k_term + nb + 2 * c + feat)


ENGINE_MODES = ("materialize", "fused", "tiled")

# bytes per element of the kernel-layer TILE dtype (repro.kernels.precision
# .Precision.tile_itemsize, duplicated here so the planner stays importable
# without jax). Accumulators are always f32 — only tile terms reprice.
_TILE_BYTES = {"f32": 4, "bf16": 2}


def engine_footprint_bytes(n: int, b: int, c: int, p: int, q: int = 4, *,
                           s: float = 1.0, d: int = 0,
                           mode: str = "materialize",
                           tile_rows: int = 256,
                           q_tile: int | None = None) -> float:
    """Per-node bytes of one exact inner-loop iteration under a GramEngine
    mode (module docstring, engine paragraph).

    materialize keeps the [rows, |L|] block resident; fused rebuilds it in
    VMEM (nothing but the [rows, C] f panel in HBM); tiled streams
    ``tile_rows``-high panels. All modes pay the f panel, the label/medoid
    bookkeeping, and (d > 0) the feature rows the rebuild needs on-node.

    ``q_tile`` is the dtype-aware half of the price (default: ``q``): bytes
    per element of the TILE terms — the Gram block/panels and the feature
    rows, exactly the arrays the precision policy
    (``repro.kernels.precision``) stores in the tile dtype. Under bf16
    (``q_tile=2``) the dominant ``rows*cols`` materialize term and the
    feature term halve while the f panel and bookkeeping stay f32-priced
    (they are accumulator outputs, never tiles) — which is why a bf16
    policy can move the planner's materialize/tiled/fused frontier: a
    resident block that misses the budget at q=4 may fit at q_tile=2, and
    ``plan(precision="bf16")`` prices exactly that.

    This price is not only what the planner optimizes against — it is a
    statically *enforced* residency contract: ``repro.analysis.audit``
    walks the traced inner-loop jaxpr and checks its peak live
    intermediate bytes against this function's value for the chosen mode
    (within a fusion-slack factor), and checks that no single intermediate
    reaches the full [rows, |L|] Gram block unless mode=="materialize".
    A tiled program that accidentally materializes the block it promised
    to stream fails ``launch/audit.py`` before anything runs, rather than
    OOMing at scale (see the "Auditing the program" README section).
    """
    qt = q if q_tile is None else q_tile
    nb = n / b
    rows = nb / p
    cols = s * nb
    feat = d * (rows + cols) if d else 0.0
    if mode == "materialize":
        k_term = rows * cols
    elif mode == "fused":
        k_term = 0.0
    elif mode == "tiled":
        # two panels live at once: the tiled matvec is double-buffered
        # (GramEngine.double_buffer — panel i+1 builds while i contracts).
        k_term = 2.0 * min(tile_rows, rows) * cols
    else:
        raise ValueError(f"unknown engine mode {mode!r}; have {ENGINE_MODES}")
    return qt * (k_term + feat) + q * (rows * c + nb + 2 * c)


def s_step_state_bytes(n: int, b: int, c: int, p: int, q: int = 4, *,
                       s_step: int = 1) -> float:
    """Per-device bytes of the s-step communication-avoiding carry
    (``distributed.inner``, ``s_step > 1``): the replicated global-label
    estimate u_full [N/B] (int32) each shard scatters its refinements
    into, plus the frozen remote raw partials it holds between syncs
    (F_rem [rows, C] + the counts/g remainders [2C]). ``s_step == 1``
    carries nothing beyond the engine footprint — the stats the loop
    carries then are the same arrays the engine already prices. (The 2-D
    layout's canonicalizing sync gathers an M-fold label buffer, but that
    is a TRANSIENT freed inside the sync, not carried state; it is ~q*M*
    N/B bytes, negligible against F_rem whenever M*D << rows*C.)"""
    if s_step <= 1:
        return 0.0
    nb = n / b
    rows = nb / p
    return q * (nb + rows * c + 2 * c)


def embed_footprint_bytes(n: int, b: int, c: int, p: int, q: int = 4, *,
                          m: int, d: int = 0) -> float:
    """Per-node bytes for one embedded-space (RFF/Nystrom) batch iteration.

    Embedded rows Z [rows, m] + centroids [C, m] + the replicated map
    parameters (frequencies/landmarks [m, d] and, generously, an [m, m]
    whitening block for Nystrom) + the dense input rows themselves (d > 0:
    the batch must live on-node to be projected — the term the sparse
    sketch path shrinks to O(nnz)). The fused embed+assign kernel would
    drop the Z term too, but this reports the materialized (default) path.
    """
    nb = n / b
    rows = nb / p
    map_params = (m * d + m * m + rows * d) if d else 0.0
    return q * (rows * m + c * m + rows + map_params)


def sketch_footprint_bytes(n: int, b: int, c: int, p: int, q: int = 4, *,
                           m: int, d: int = 0,
                           density: float = 1.0) -> float:
    """Per-node bytes for one sketch-embedded (count-sketch) batch iteration.

    Embedded rows Z [rows, m] + centroids [C, m] like the dense-embedded
    path, but the map parameters are two O(d) tables (int32 hash + int8
    sign = 5 bytes/dim, replicated) instead of the [m, d] float projection,
    and the input rows are stored sparse: ``density`` * d (value, index)
    pairs per row. At RCV1-like density (~1e-2) this is what makes d ~ 50k
    workloads fit where the dense-embedded path cannot even hold X.
    """
    nb = n / b
    rows = nb / p
    sparse_rows = 2.0 * q * rows * d * density if d else 0.0
    tables = 5.0 * d
    return q * (rows * m + c * m + rows) + tables + sparse_rows


def serve_footprint_bytes(c: int, m: int, d: int, *, method: str = "rff",
                          q: int = 4, q_tile: int | None = None,
                          degree: int = 2, bucket: int = 0) -> float:
    """Resident bytes of a frozen predict artifact
    (``repro.serving.artifact``) plus the transient working set of one
    ``bucket``-row request — the serving-side counterpart of the fit-side
    footprints above, and what ``artifact_nbytes`` measures at bucket=0.

    Every embedded method carries the value panel v [m, C], the centroids
    [C, m] and the csq/counts vectors (f32 — accumulator-side, never
    tiles); the map tables are the method-shaped term and the only one
    ``q_tile`` (bf16 = 2) reprices:

        rff/nystrom:   q_tile*m*d  (frequencies / landmarks) + q*m (phases
                       / landmark norms)
        sketch:        4d int32 hash + sign (int8 under bf16, else f32)
        tensorsketch:  degree stacked (d+1)-wide hash+sign tables
        exact:         q*(C*d + C)  (medoids + kernel diagonal; no panels)

    The transient term is one padded query tile (q_tile*bucket*d) + the
    score panel (q*bucket*C) — plus the materialized embedding
    q*bucket*m for tensorsketch, whose FFT path has no fused kernel.
    """
    qt = q if q_tile is None else q_tile
    sign_b = 1.0 if qt < 4 else 4.0
    if method == "exact":
        return q * (c * d + c) + qt * bucket * d + q * bucket * c
    panels = q * (2.0 * m * c + 2.0 * c)          # v + centroids + csq/counts
    if method in ("rff", "nystrom"):
        tables = qt * m * d + q * float(m)
    elif method == "sketch":
        tables = (4.0 + sign_b) * d
    elif method == "tensorsketch":
        tables = degree * (d + 1) * (4.0 + sign_b)
    else:
        raise ValueError(f"unknown serve method {method!r}")
    z_term = q * bucket * m if method == "tensorsketch" else 0.0
    return tables + panels + qt * bucket * d + z_term + q * bucket * c


_SELECTOR_EFF = {"uniform": 1.0, "kpp": 1.25, "rls": 1.6}


def selector_footprint_bytes(n: int, b: int, p: int, q: int = 4, *,
                             m: int, selector: str = "uniform") -> float:
    """Per-node bytes the landmark-selection strategy needs on top of the
    embedded footprint (module docstring, selection paragraph)."""
    rows = n / b / p
    if selector == "uniform":
        return 4.0 * m
    if selector == "rls":
        return q * (3.0 * m * m + 2.0 * rows)
    if selector == "kpp":
        return q * (rows * (2.0 + math.log(max(m, 2))) + 2.0 * rows)
    raise ValueError(f"unknown selector {selector!r}; "
                     f"have {tuple(_SELECTOR_EFF)}")


def predicted_accuracy(method: str, selector: str | None, m: int,
                       c: int) -> float:
    """Coarse accuracy model behind ``Plan.frontier()`` (module docstring):
    landmark methods (nystrom AND the exact-tiled Eq.14 expansion, which is
    a landmark approximation of the same rank) ~ 1 - (1 + m_eff/C)^-1 with
    the selector's effective-landmark multiplier; sketch ~ 1 - sqrt(C/m).
    Only the *ordering* is trusted."""
    if m < 1:
        return 0.0
    if method == "sketch":
        return 1.0 - min(1.0, math.sqrt(c / m))
    eff = _SELECTOR_EFF.get(selector or "uniform")
    if eff is None:
        raise ValueError(f"unknown selector {selector!r}; "
                         f"have {tuple(_SELECTOR_EFF)}")
    return 1.0 - 1.0 / (1.0 + m * eff / max(c, 1))


def b_min(n: int, c: int, machine: MachineSpec, *, s: float = 1.0) -> int:
    """Smallest B such that footprint fits in machine.memory_bytes (exact).

    Solves  Q*( s*N^2/(B^2*P) + C*N/(B*P) + N/B + 2C ) <= R  for 1/B.
    """
    p, q, r = machine.n_processors, machine.bytes_per_scalar, machine.memory_bytes
    # quadratic a*x^2 + b*x + c0 <= 0 with x = 1/B
    a = q * s * n * n / p
    b = q * n * (c / p + 1.0)
    c0 = q * 2.0 * c - r
    if c0 >= 0:
        raise ValueError("machine cannot hold even the O(C) bookkeeping")
    x = (-b + math.sqrt(b * b - 4.0 * a * c0)) / (2.0 * a)
    return max(1, math.ceil(1.0 / x))


def b_min_paper(n: int, c: int, machine: MachineSpec) -> int:
    """The paper's printed Eq.19 (kept verbatim for fidelity; see module doc)."""
    p, q, r = machine.n_processors, machine.bytes_per_scalar, machine.memory_bytes
    t = c / p + 1.0
    disc = t * t - 8.0 * c / p + r / q
    denom = -t + math.sqrt(disc)
    return max(1, math.ceil((2.0 * n / p) / denom))


def host_staging_bytes(n: int, b: int, q: int = 4, *, d: int = 0,
                       density: float = 1.0, sparse: bool = False,
                       prefetch_depth: int = 2) -> float:
    """Host bytes for the streaming ingest pipeline: the resident batch plus
    ``prefetch_depth`` staged batches in the producer queue.

    Dense batches cost ``Q * (N/B) * d`` each; CSR batches cost the
    (value, index) pairs of their nonzeros — Q-byte values plus int32
    (4-byte) indices, whatever Q is — plus the int32 indptr."""
    nb = n / b
    if sparse:
        batch = (q + 4.0) * density * nb * d + 4.0 * (nb + 1)
    else:
        batch = q * nb * d
    return (1.0 + max(0, prefetch_depth)) * batch


@dataclasses.dataclass(frozen=True)
class Plan:
    b: int
    s: float
    footprint: float
    fused_footprint: float
    note: str
    embed_dim: int = 0                   # m used for the embedded estimate
    embed_footprint: float = float("inf")
    method: str = "exact"        # "exact" | "embed" | "sketch" (cheapest)
    sketch_footprint: float = float("inf")
    host_footprint: float = 0.0  # ingest node: (1 + prefetch_depth) batches
    selector: str = "uniform"    # landmark-selection strategy priced in
    selector_footprint: float = 0.0
    # -- exact-path Gram residency (repro.core.engine): the cheapest-FLOP
    #    mode that fits the budget, plus the full per-mode bill.
    engine: str = "materialize"
    engine_footprints: dict = dataclasses.field(default_factory=dict)
    tile_rows: int = 256
    # -- kernel-layer tile dtype the engine bills were priced at
    #    (repro.kernels.precision): "bf16" halves the Gram/feature terms.
    precision: str = "f32"
    # -- s-step communication-avoiding depth (distributed.inner.s_step):
    #    Lloyd refinements per global sync, and the replicated-carry bytes
    #    that depth costs per device (s_step_state_bytes).
    s_step: int = 1
    s_step_footprint: float = 0.0

    def gram_engine(self):
        """The priced pick as a runnable ``GramEngine`` — mode AND the
        ``tile_rows`` the tiled footprint was validated with (threading the
        bare ``Plan.engine`` string would silently run default-height
        panels the budget check never saw), AND the tile ``precision`` the
        bills were priced at (a bf16-priced materialize plan run at f32
        would carry twice the Gram bytes the budget check approved). Hand
        this to ``MiniBatchConfig(engine=plan.gram_engine())``."""
        from .engine import GramEngine
        return GramEngine(self.engine, tile_rows=self.tile_rows,
                          precision=self.precision)
    # -- the workload this plan was made for (frontier() re-prices with it)
    n: int = 0
    c: int = 0
    d: int = 0
    p: int = 1
    q: int = 4
    density: float = 1.0
    sketchable: bool = False

    def frontier(self, budget_bytes: float | None = None) -> list[dict]:
        """Rank landmark/sketch strategies by predicted accuracy-per-byte
        at a fixed per-node byte budget.

        Every candidate — Nystrom with each selector, the exact path under
        the tiled engine (|L| = m landmarks, streamed Gram panels), plus
        the count-sketch when the workload was declared ``sketchable`` —
        gets the largest
        embedding dim m its footprint affords within ``budget_bytes``
        (default: what this plan already spends on the embedded method);
        the coarse accuracy model (``predicted_accuracy``) then prices what
        those bytes buy. Returns records sorted best-first:
        ``{"method", "selector", "m", "bytes", "predicted_accuracy",
        "accuracy_per_byte"}``. Only the ordering is meaningful — the
        ``fig5_approx_sweep`` selector grid is the measured counterpart.
        """
        if self.n <= 0:
            raise ValueError("frontier() needs a plan built by plan() — "
                             "workload context (n, c, ...) is missing")
        budget = budget_bytes if budget_bytes is not None else (
            self.embed_footprint + self.selector_footprint)

        def nystrom_bytes(m: int, sel: str) -> float:
            return (embed_footprint_bytes(self.n, self.b, self.c, self.p,
                                          self.q, m=m, d=self.d)
                    + selector_footprint_bytes(self.n, self.b, self.p,
                                               self.q, m=m, selector=sel))

        def sketch_bytes(m: int, sel) -> float:
            return sketch_footprint_bytes(self.n, self.b, self.c, self.p,
                                          self.q, m=m, d=self.d,
                                          density=self.density)

        nb = self.n / self.b

        def exact_tiled_bytes(m: int, sel: str) -> float:
            # the Eq.14 expansion at |L| = m landmarks under the tiled
            # engine: one streamed [tile_rows, m] panel instead of a
            # resident [rows, m] block, plus the selection bill the exact
            # path pays for its own landmarks.
            return (engine_footprint_bytes(self.n, self.b, self.c, self.p,
                                           self.q, s=m / nb, d=self.d,
                                           mode="tiled",
                                           tile_rows=self.tile_rows,
                                           q_tile=_TILE_BYTES.get(
                                               self.precision, self.q))
                    + selector_footprint_bytes(self.n, self.b, self.p,
                                               self.q, m=m, selector=sel))

        cands = [("nystrom", s, nystrom_bytes)
                 for s in ("rls", "kpp", "uniform")]
        # the exact path competes at the SAME budget: landmarks cost panel
        # bytes, not resident-block bytes, and buy nystrom-grade accuracy.
        cands.append(("exact-tiled", self.selector, exact_tiled_bytes))
        if self.sketchable:
            cands.append(("sketch", None, sketch_bytes))
        out = []
        for method, sel, bytes_fn in cands:
            m = _max_m_within(lambda mm: bytes_fn(mm, sel), budget)
            if method == "exact-tiled":
                m = min(m, int(nb))     # |L| cannot exceed the mini-batch
            if m < 1:
                continue
            cost = bytes_fn(m, sel)
            acc = predicted_accuracy(method, sel, m, self.c)
            out.append({"method": method, "selector": sel or "-", "m": m,
                        "bytes": cost, "predicted_accuracy": acc,
                        "accuracy_per_byte": acc / max(cost, 1.0)})
        out.sort(key=lambda r: r["accuracy_per_byte"], reverse=True)
        return out


def _max_m_within(bytes_fn, budget: float, *, m_cap: int = 1 << 20) -> int:
    """Largest m with bytes_fn(m) <= budget (bytes_fn monotone in m)."""
    if bytes_fn(1) > budget:
        return 0
    lo, hi = 1, 2
    while hi < m_cap and bytes_fn(hi) <= budget:
        lo, hi = hi, hi * 2
    while lo + 1 < hi:
        mid = (lo + hi) // 2
        if bytes_fn(mid) <= budget:
            lo = mid
        else:
            hi = mid
    return lo


def plan(n: int, c: int, machine: MachineSpec, *, d: int = 0,
         b: int | None = None,
         embed_dim: int | None = None,
         sketchable: bool = False, density: float = 1.0,
         selector: str = "uniform",
         prefetch_depth: int = 2,
         tile_rows: int = 256,
         precision: str = "f32",
         s_step: int = 1,
         target_batch_seconds: float | None = None,
         measured_batch_seconds: float | None = None) -> Plan:
    """§4.2 model-selection rationale, automated.

    Start at (B_min, s=1). If a target per-batch time is given together with a
    measured single-batch time, first shrink s (down to 0.2 — the paper's
    accuracy cliff), then increase B. Passing ``b`` pins the batch count
    instead (a pipeline constraint the planner must live with) — B_min is
    skipped and the GramEngine pick below absorbs the memory pressure.

    The exact path's Gram residency is priced per mode
    (``engine_footprint_bytes``, ``tile_rows`` sizing the tiled panels) and
    ``Plan.engine`` names the cheapest-FLOP mode that fits: ``materialize``
    when the resident block fits (it amortizes the kernel evaluations over
    every inner iteration), else ``tiled`` (portable streamed panels —
    rebuilds the Gram every iteration), else ``fused`` (VMEM-resident tiles
    only; the TPU Pallas path — its portable jnp fallback transiently
    materializes the block, so off-TPU the degrade order effectively stops
    at tiled). All three bills are in ``Plan.engine_footprints``; thread
    the pick as ``MiniBatchConfig(engine=plan.gram_engine())`` (mode plus
    the validated ``tile_rows``).

    The embedded-space footprint (RFF/Nystrom at ``embed_dim``; default
    m = 4*C, the tested accuracy floor) is always reported alongside, and
    ``method`` names the cheaper representation at the chosen (B, s):
    ``"exact"`` or ``"embed"``. ``"embed"`` means pick one of
    ``MiniBatchConfig(method="rff")`` / ``method="nystrom"`` — the memory
    model cannot choose between them (same footprint shape); that choice
    follows from the kernel (rbf -> either; anything else -> nystrom).

    ``sketchable=True`` declares the workload sketch-compatible (linear or
    polynomial kernel — the planner cannot infer that from shapes): the
    sketch footprint (O(d) map tables + ``density``-sparse input rows,
    ``sketch_footprint_bytes``) then competes in the auto-pick and
    ``method`` may come back ``"sketch"`` — i.e.
    ``MiniBatchConfig(method="sketch" | "tensorsketch")`` on CSR batches.

    ``prefetch_depth`` sizes the streaming host footprint
    (``Plan.host_footprint``): the resident batch plus that many staged
    batches in the prefetch queue, CSR-priced when the sketch method wins
    (the stream then never densifies) and dense-priced otherwise.

    ``selector`` names the landmark-selection strategy
    (``repro.approx.selectors``); its footprint
    (``selector_footprint_bytes``) joins the embedded method in the
    auto-pick, and ``Plan.frontier()`` ranks all strategies by what their
    bytes buy at a fixed budget.

    ``precision`` is the kernel-layer tile dtype
    (``repro.kernels.precision``): "bf16" prices the Gram-block/panel and
    feature terms of every engine mode at 2 bytes/element instead of 4
    (``engine_footprint_bytes(q_tile=2)``) — accumulator outputs stay
    f32-priced — which can move the materialize/tiled/fused pick: a
    resident block over budget at f32 may fit at bf16. The pick is
    threaded back out via ``Plan.precision`` / ``plan.gram_engine()`` so
    the runtime engine actually stores tiles at the priced dtype.

    ``s_step`` is the communication-avoiding depth of the distributed
    inner loop (``DistributedInnerConfig.s_step``): s Lloyd refinements
    per global sync cut the collective bill to (1 allgather + 1 psum)/s
    but cost the replicated carry ``s_step_state_bytes`` per device —
    priced into every engine-mode budget check below and reported as
    ``Plan.s_step_footprint``.
    """
    if b is None:
        b = b_min(n, c, machine)
        note = "B_min at s=1 (optimal for the available memory)"
    else:
        note = f"B={b} pinned by caller"
    s = 1.0
    if target_batch_seconds and measured_batch_seconds:
        ratio = measured_batch_seconds / target_batch_seconds
        if ratio > 1.0:
            # kernel evaluations scale ~ s * (N/B)^2: first knob is s ...
            s = max(0.2, 1.0 / ratio)
            residual = ratio * s
            if residual > 1.0:
                # ... then B (execution time ~ 1/B per batch).
                b = math.ceil(b * residual)
                note = f"s floored at 0.2 (accuracy cliff), B raised x{residual:.2f}"
            else:
                note = f"s lowered to {s:.3f} to hit the time target"
    m = embed_dim if embed_dim is not None else 4 * c
    p, q = machine.n_processors, machine.bytes_per_scalar
    fp = footprint_bytes(n, b, c, p, q, s=s, d=d)
    # -- Gram residency of the exact inner loop: cheapest-FLOP mode that
    #    fits (materialize amortizes the kernel evaluations; tiled/fused
    #    rebuild per iteration but cap the resident bytes).
    if precision not in _TILE_BYTES:
        raise ValueError(f"unknown precision {precision!r}; "
                         f"have {tuple(_TILE_BYTES)}")
    q_tile = _TILE_BYTES[precision]
    eng_fp = {mode: engine_footprint_bytes(n, b, c, p, q, s=s, d=d,
                                           mode=mode, tile_rows=tile_rows,
                                           q_tile=q_tile)
              for mode in ENGINE_MODES}
    if precision != "f32":
        note += (f"; tiles priced at {precision} "
                 f"({q_tile} B/elem; accumulators stay f32)")
    # the s-step replicated carry rides along whatever the Gram residency
    # is, so it tightens every mode's budget check equally.
    fp_sstep = s_step_state_bytes(n, b, c, p, q, s_step=s_step)
    if s_step > 1:
        note += (f"; s_step={s_step} (collectives /{s_step}, replicated "
                 f"carry {fp_sstep / 1e6:.1f} MB/device)")
    if eng_fp["materialize"] + fp_sstep <= machine.memory_bytes:
        engine = "materialize"
    elif eng_fp["tiled"] + fp_sstep <= machine.memory_bytes:
        engine = "tiled"
        note += (f"; exact engine: tiled (resident Gram block "
                 f"{eng_fp['materialize']/1e6:.0f} MB > budget — streaming "
                 f"{tile_rows}-row panels)")
    elif eng_fp["fused"] + fp_sstep <= machine.memory_bytes:
        engine = "fused"
        note += ("; exact engine: fused (even one Gram panel is tight — "
                 "needs the Pallas VMEM-tile path; the portable jnp "
                 "fallback transiently materializes the block)")
    else:
        # nothing fits — report the smallest bill honestly instead of
        # pretending a mode rescues this (B, s); the caller must grow B,
        # shrink s, or switch representation (see Plan.method/frontier()).
        engine = "fused"
        note += (f"; exact path DOES NOT FIT: even the fused f panel is "
                 f"{eng_fp['fused']/1e6:.1f} MB > budget — raise B, lower "
                 f"s, or use an embedded method")
    fp_embed = embed_footprint_bytes(n, b, c, p, q, m=m, d=d)
    fp_sel = selector_footprint_bytes(n, b, p, q, m=m, selector=selector)
    # the exact path selects |L| = s*N/B landmarks per batch with the SAME
    # strategy (MiniBatchConfig.selector drives Eq.14 too), so it pays its
    # own — typically larger — selection bill in the comparison.
    fp_sel_exact = selector_footprint_bytes(
        n, b, p, q, m=max(c, int(s * n / b)), selector=selector)
    fp_sketch = (sketch_footprint_bytes(n, b, c, p, q, m=m, d=d,
                                        density=density)
                 if sketchable else float("inf"))
    method = "exact"
    if fp_sketch < min(fp + fp_sel_exact, fp_embed + fp_sel):
        method = "sketch"
        note += (f"; O(nnz) sketch (m={m}, density={density:g}) is cheapest "
                 "— consider method='sketch'/'tensorsketch' on CSR batches")
    elif fp_embed + fp_sel < fp + fp_sel_exact:
        method = "embed"
        note += f"; embedded space (m={m}) is cheaper — consider method='rff'/'nystrom'"
    return Plan(
        b=b, s=s,
        footprint=fp,
        fused_footprint=footprint_bytes(n, b, c, p, q, s=s, d=d, fused=True),
        note=note,
        embed_dim=m,
        embed_footprint=fp_embed,
        method=method,
        sketch_footprint=fp_sketch,
        host_footprint=host_staging_bytes(
            n, b, q, d=d, density=density, sparse=(method == "sketch"),
            prefetch_depth=prefetch_depth),
        selector=selector,
        selector_footprint=fp_sel,
        engine=engine,
        engine_footprints=eng_fp,
        tile_rows=tile_rows,
        precision=precision,
        s_step=s_step,
        s_step_footprint=fp_sstep,
        n=n, c=c, d=d, p=p, q=q, density=density, sketchable=sketchable,
    )
