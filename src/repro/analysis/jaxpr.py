"""Layer 1 of the program auditor: static jaxpr analysis of the hot paths.

``audit(fn, *args)`` traces ``fn`` to a ClosedJaxpr (abstract evaluation —
nothing executes, no device memory is touched) and walks it recursively,
descending into every sub-jaxpr a primitive carries (``pjit`` bodies,
``scan``/``while`` loops, ``cond`` branches, ``shard_map`` programs, custom
derivative wrappers) to produce a ``ProgramReport``:

* **collectives** — static counts per primitive (``psum``, ``all_gather``,
  ``ppermute``, ...), split into *per-iteration* counts inside each
  ``while`` loop and *outside* counts, with payload bytes from the avals.
  The per-iteration bill is exact: the traced program is static, so "how
  many psums does one Lloyd iteration issue" is a decidable property — it
  must equal ``distributed.inner.collectives_per_iteration``'s analytic
  bill, and the flight recorder bills from this count (satellite of PR 7).
* **memory residency** — peak live intermediate bytes from a liveness walk
  over the jaxpr (values die at their last use), plus the largest single
  intermediate. A ``tiled``-mode program that materializes the full
  ``[n, |L|]`` Gram block is a *static* failure here; no runtime spy
  needed. Checked against ``core.memory.engine_footprint_bytes``.
* **Pallas dispatch** — ``pallas_call`` occurrence counts. The PR 5 bug
  (a "fused" mode that never invoked its kernel) becomes unrepresentable:
  ``check_pallas`` fails the audit when presence mismatches the mode.
* **accumulation precision** — the mixed-precision policy
  (``repro.kernels.precision``) lets tiles be bf16 but requires every
  accumulation to run f32. That is a *static* property of the kernel
  jaxpr: ``check_precision`` scans each ``pallas_call``'s inner jaxpr and
  flags any ``dot_general``/``reduce_sum`` whose output dtype is a
  non-f32 float — a kernel that silently accumulates in bf16 (e.g. a
  missing ``preferred_element_type``) fails the audit in BOTH dtype
  configurations, before anything runs.
* **host syncs** — callback primitives (``pure_callback``/``io_callback``/
  ``debug_callback``) that force a device⇄host round-trip, flagged
  especially inside loops where they serialize the dispatch stream.

Scan bodies are counted with their trip count multiplied through
(``length`` is static); ``while`` trip counts are dynamic, so their bodies
are reported per-iteration and the caller supplies the realized ``n_iter``
(``ProgramReport.collective_totals``). ``cond`` branches are merged by
elementwise max (a conservative upper bound — branches of the audited hot
paths are collective-free). ``pallas_call`` inner jaxprs are NOT descended
into by the residency walk (their refs live in VMEM and would pollute the
HBM picture); they are collected aside and scanned by ``check_precision``.

jnp-only analysis — no XLA compilation. The HLO-level cross-check (FLOPs,
compiled peak bytes) is ``launch/audit.py`` + ``launch/hlocost.py``.
"""
from __future__ import annotations

import dataclasses
from collections import Counter
from typing import Any, Optional

import jax

try:  # jax >= 0.5 public location
    from jax.extend import core as _core
except ImportError:  # pragma: no cover - pinned-jax fallback
    import jax.core as _core

_Jaxpr = _core.Jaxpr
_ClosedJaxpr = _core.ClosedJaxpr
_Var = _core.Var
_Literal = _core.Literal


#: jaxpr-level collective primitives (what crosses the mesh network).
COLLECTIVE_PRIMS = frozenset({
    "psum", "psum2", "pmax", "pmin", "ppermute", "pbroadcast",
    "all_gather", "all_to_all", "reduce_scatter", "psum_scatter",
})

#: primitives that force a host<->device round-trip (or stage one).
HOST_SYNC_PRIMS = frozenset({
    "pure_callback", "io_callback", "debug_callback", "callback",
    "outside_call", "infeed", "outfeed",
})

#: sub-jaxprs never descended into (off-HBM address spaces).
_OPAQUE_PRIMS = frozenset({"pallas_call"})

#: primitives that ACCUMULATE inside a Pallas kernel — their output dtype
#: is the accumulator dtype, and the precision policy
#: (``repro.kernels.precision``) requires it to be f32 even when the tile
#: operands are bf16 (``preferred_element_type=jnp.float32`` on the MXU
#: contraction; ``.astype(f32)`` before row reductions).
_ACCUM_PRIMS = frozenset({"dot_general", "reduce_sum"})


class AuditError(AssertionError):
    """A statically-decidable program invariant does not hold."""


def _aval_bytes(aval) -> int:
    try:
        return int(aval.size) * int(aval.dtype.itemsize)
    except Exception:       # tokens / abstract values without a layout
        return 0


def _is_var(v) -> bool:
    return isinstance(v, _Var) and not isinstance(v, _Literal)


def _subjaxprs(params: dict):
    """Every jaxpr-valued entry in eqn.params (version-robust discovery)."""
    for val in params.values():
        if isinstance(val, (_Jaxpr, _ClosedJaxpr)):
            yield val
        elif isinstance(val, (tuple, list)):
            for item in val:
                if isinstance(item, (_Jaxpr, _ClosedJaxpr)):
                    yield item


def _open(j):
    return j.jaxpr if isinstance(j, _ClosedJaxpr) else j


@dataclasses.dataclass
class LoopReport:
    """One ``while`` loop: its per-iteration collective/host-sync bill."""
    path: str                                   # nesting path, e.g. "pjit/while"
    collectives: dict = dataclasses.field(default_factory=dict)
    collective_bytes: dict = dataclasses.field(default_factory=dict)
    host_callbacks: dict = dataclasses.field(default_factory=dict)
    pallas_calls: int = 0

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class ProgramReport:
    """What is statically true of one traced program. See module docstring."""
    name: str
    input_bytes: int = 0
    output_bytes: int = 0
    peak_live_bytes: int = 0
    largest_intermediate_bytes: int = 0
    largest_intermediate_shape: str = ""
    collectives_outside: dict = dataclasses.field(default_factory=dict)
    collective_bytes_outside: dict = dataclasses.field(default_factory=dict)
    loops: list = dataclasses.field(default_factory=list)
    pallas_calls: int = 0
    pallas_calls_in_loop: int = 0
    host_callbacks: dict = dataclasses.field(default_factory=dict)
    host_callbacks_in_loop: dict = dataclasses.field(default_factory=dict)
    primitive_counts: dict = dataclasses.field(default_factory=dict)
    hlo: Optional[dict] = None      # launch/audit.py fills in hlocost terms
    # (path, inner jaxpr) of every pallas_call — the VMEM programs the
    # residency walk skips, kept for check_precision. Not serialized.
    pallas_kernel_jaxprs: list = dataclasses.field(
        default_factory=list, repr=False)

    # -- derived views -------------------------------------------------------

    @property
    def collectives_per_iteration(self) -> dict:
        """Merged per-iteration collective counts over every while loop —
        for the single-while inner-loop programs this IS the bill the
        analytic ``collectives_per_iteration`` functions predict."""
        out: Counter = Counter()
        for loop in self.loops:
            out.update(loop.collectives)
        return dict(out)

    @property
    def collective_bytes_per_iteration(self) -> dict:
        out: Counter = Counter()
        for loop in self.loops:
            out.update(loop.collective_bytes)
        return dict(out)

    def collective_totals(self, n_iter: int) -> dict:
        """Realized bill: per-iteration counts x ``n_iter`` + the
        outside-the-loop epilogue/prologue collectives. This is what the
        flight recorder records (exact, unlike the PR 6 analytic
        ``bill x (n_iter + 1)`` which charged the fixpoint pass a full
        iteration)."""
        out = Counter({k: v * n_iter
                       for k, v in self.collectives_per_iteration.items()})
        out.update(self.collectives_outside)
        return dict(out)

    def collective_byte_totals(self, n_iter: int) -> dict:
        out = Counter({k: v * n_iter
                       for k, v in self.collective_bytes_per_iteration.items()})
        out.update(self.collective_bytes_outside)
        return dict(out)

    # -- checks (each returns a list of violation strings) -------------------

    def check_collectives(self, expected_per_iteration: dict,
                          expected_outside: Optional[dict] = None) -> list:
        """Per-iteration counts must match the analytic bill exactly; with
        ``expected_outside`` given, the unconditional (outside-any-while,
        scan-multiplied) counts are held to the same standard. Both dicts
        use the analytic-bill vocabulary: ``{"psum": n, "allgather": m}``
        (``allgather`` is normalized to the jaxpr primitive
        ``all_gather``; ``*_bytes`` keys are ignored)."""
        alias = {"allgather": "all_gather", "allreduce": "psum"}

        def compare(got: dict, expected: dict, where: str) -> list:
            out = []
            for key, want in expected.items():
                if key.endswith("_bytes"):
                    continue
                prim = alias.get(key, key)
                have = got.get(prim, 0)
                if have != want:
                    out.append(
                        f"{self.name}: {prim} {where} is {have}, analytic "
                        f"bill says {want}")
            known = {alias.get(k, k) for k in expected
                     if not k.endswith("_bytes")}
            for prim, have in sorted(got.items()):
                if prim not in known and have:
                    out.append(
                        f"{self.name}: unbilled collective {prim} x{have} "
                        f"{where} (analytic bill has no entry for it)")
            return out

        out = compare(self.collectives_per_iteration,
                      expected_per_iteration, "per iteration")
        if expected_outside is not None:
            out += compare(dict(self.collectives_outside),
                           expected_outside, "outside the loop")
        return out

    def check_memory(self, budget_bytes: float, *, slack: float = 3.0) -> list:
        """Peak live bytes <= slack x the planner's priced footprint.

        ``slack`` absorbs what the jaxpr view cannot see: XLA fuses
        elementwise chains the jaxpr shows as distinct simultaneously-live
        values (a - b -> exp chain on an [n, L] block is one fusion on
        device but ~3 live blocks here). It does NOT absorb an extra
        resident Gram block: a materialized [n, L] in tiled mode overshoots
        any per-mode budget by x(n/bm), far beyond slack."""
        if self.peak_live_bytes > slack * budget_bytes:
            return [f"{self.name}: peak live bytes "
                    f"{self.peak_live_bytes:,} > {slack:g} x budget "
                    f"{budget_bytes:,.0f}"]
        return []

    def check_max_intermediate(self, limit_bytes: float) -> list:
        """No single intermediate may reach ``limit_bytes`` — the tiled
        booby-trap: one materialized [n, |L|] Gram block trips this."""
        if self.largest_intermediate_bytes >= limit_bytes:
            return [f"{self.name}: intermediate "
                    f"{self.largest_intermediate_shape} of "
                    f"{self.largest_intermediate_bytes:,} bytes >= limit "
                    f"{limit_bytes:,.0f}"]
        return []

    def check_pallas(self, expected: bool) -> list:
        """pallas_call present iff the mode says so (the PR 5 dead-kernel
        class of bug, decided before anything runs)."""
        if expected and self.pallas_calls == 0:
            return [f"{self.name}: expected a pallas_call dispatch, the "
                    f"traced program contains none (dead-kernel bug)"]
        if not expected and self.pallas_calls > 0:
            return [f"{self.name}: unexpected pallas_call x"
                    f"{self.pallas_calls} (mode promises a Pallas-free "
                    f"program)"]
        return []

    def check_precision(self) -> list:
        """Every accumulation inside every ``pallas_call`` kernel is
        statically f32 — the invariant the mixed-precision policy
        (``repro.kernels.precision``) rests on. Tiles may be bf16 (that is
        the point), but a ``dot_general`` or ``reduce_sum`` whose OUTPUT is
        a non-f32 float means the kernel accumulates at tile precision:
        unbounded rounding error growth with the contraction depth, and
        exactly the bug a missing ``preferred_element_type`` introduces.
        Integer outputs (argmin indices, hash tables) are exempt.

        Dtype classification goes through ``jnp.issubdtype``: the extended
        float dtypes (bfloat16 lives in ml_dtypes) are NOT ``np.floating``
        subtypes — ``np.issubdtype`` calls them void and would wave the
        exact bug this check exists for straight through."""
        import jax.numpy as jnp
        import numpy as np
        out = []

        def scan(jaxpr, where: str) -> None:
            for eqn in jaxpr.eqns:
                prim = eqn.primitive.name
                if prim in _ACCUM_PRIMS:
                    for v in eqn.outvars:
                        dt = getattr(v.aval, "dtype", None)
                        if (dt is not None
                                and jnp.issubdtype(dt, jnp.floating)
                                and dt != np.dtype(np.float32)):
                            out.append(
                                f"{self.name}: {prim} inside pallas kernel "
                                f"[{where}] accumulates in {dt} (policy: "
                                f"tiles may be bf16, accumulators must be "
                                f"f32)")
                for sub in _subjaxprs(eqn.params):
                    scan(_open(sub), where)

        for where, kj in self.pallas_kernel_jaxprs:
            scan(kj, where)
        return out

    def check_host_sync(self) -> list:
        """No host round-trip primitive inside an inner loop."""
        out = []
        for prim, cnt in sorted(self.host_callbacks_in_loop.items()):
            out.append(f"{self.name}: host-sync primitive {prim} x{cnt} "
                       f"inside a while/scan body (serializes the dispatch "
                       f"stream every iteration)")
        return out

    def verify(self, *violation_lists) -> "ProgramReport":
        """Raise AuditError with every violation, or return self."""
        flat = [v for vs in violation_lists for v in vs]
        if flat:
            raise AuditError(
                "static audit failed:\n  " + "\n  ".join(flat))
        return self

    def to_dict(self) -> dict:
        d = dataclasses.asdict(
            dataclasses.replace(self, pallas_kernel_jaxprs=[]))
        del d["pallas_kernel_jaxprs"]
        d["collectives_per_iteration"] = self.collectives_per_iteration
        d["collective_bytes_per_iteration"] = \
            self.collective_bytes_per_iteration
        return d


class _Walker:
    """Recursive jaxpr walk accumulating the ProgramReport fields."""

    def __init__(self, report: ProgramReport):
        self.r = report
        self._loop_stack: list[LoopReport] = []

    # -- counting ------------------------------------------------------------

    def _count(self, prim: str, eqn, mult: int) -> None:
        counts = self.r.primitive_counts
        counts[prim] = counts.get(prim, 0) + mult
        if prim in COLLECTIVE_PRIMS:
            payload = sum(_aval_bytes(v.aval) for v in eqn.outvars)
            if self._loop_stack:
                loop = self._loop_stack[-1]
                loop.collectives[prim] = loop.collectives.get(prim, 0) + mult
                loop.collective_bytes[prim] = \
                    loop.collective_bytes.get(prim, 0) + payload * mult
            else:
                co = self.r.collectives_outside
                co[prim] = co.get(prim, 0) + mult
                cb = self.r.collective_bytes_outside
                cb[prim] = cb.get(prim, 0) + payload * mult
        if prim in HOST_SYNC_PRIMS:
            hc = self.r.host_callbacks
            hc[prim] = hc.get(prim, 0) + mult
            if self._loop_stack:
                hl = self.r.host_callbacks_in_loop
                hl[prim] = hl.get(prim, 0) + mult
                self._loop_stack[-1].host_callbacks[prim] = \
                    self._loop_stack[-1].host_callbacks.get(prim, 0) + mult
        if prim in _OPAQUE_PRIMS:
            self.r.pallas_calls += mult
            if self._loop_stack:
                self.r.pallas_calls_in_loop += mult
                self._loop_stack[-1].pallas_calls += mult

    def _note_intermediate(self, var) -> None:
        b = _aval_bytes(var.aval)
        if b > self.r.largest_intermediate_bytes:
            self.r.largest_intermediate_bytes = b
            self.r.largest_intermediate_shape = str(var.aval)

    # -- liveness walk -------------------------------------------------------

    def walk(self, jaxpr, *, mult: int = 1, path: str = "") -> int:
        """Walk one (open) jaxpr; returns its peak live bytes given that
        its invars/constvars are resident for its whole extent."""
        eqns = jaxpr.eqns
        last_use: dict = {}
        for i, eqn in enumerate(eqns):
            for v in eqn.invars:
                if _is_var(v):
                    last_use[v] = i
        for v in jaxpr.outvars:
            if _is_var(v):
                last_use[v] = len(eqns)

        live: dict = {}
        for v in list(jaxpr.invars) + list(jaxpr.constvars):
            if _is_var(v):
                live[v] = _aval_bytes(v.aval)
        cur = sum(live.values())
        peak = cur

        for i, eqn in enumerate(eqns):
            prim = eqn.primitive.name
            self._count(prim, eqn, mult)
            sub_peak = self._descend(prim, eqn, mult, path)
            out_bytes = 0
            for v in eqn.outvars:
                if _is_var(v):
                    b = _aval_bytes(v.aval)
                    live[v] = b
                    out_bytes += b
                    self._note_intermediate(v)
            cur = sum(live.values())
            # transient high-water mark: inputs still live + the callee's
            # own peak + the outputs being written.
            peak = max(peak, cur + sub_peak)
            for v in list(eqn.invars) + list(eqn.outvars):
                if _is_var(v) and last_use.get(v, -1) <= i and v in live:
                    del live[v]
        return max(peak, sum(live.values()))

    def _descend(self, prim: str, eqn, mult: int, path: str) -> int:
        """Recurse into sub-jaxprs; returns the callee peak live bytes."""
        if prim in _OPAQUE_PRIMS:
            # VMEM address space, not HBM — but keep the kernel program
            # for the check_precision accumulator-dtype scan.
            for sub in _subjaxprs(eqn.params):
                self.r.pallas_kernel_jaxprs.append(
                    (f"{path}/{prim}".lstrip("/"), _open(sub)))
            return 0
        if prim == "while":
            loop = LoopReport(path=f"{path}/while".lstrip("/"))
            self.r.loops.append(loop)
            self._loop_stack.append(loop)
            try:
                body = self.walk(_open(eqn.params["body_jaxpr"]), mult=1,
                                 path=loop.path)
                cond = self.walk(_open(eqn.params["cond_jaxpr"]), mult=1,
                                 path=loop.path)
            finally:
                self._loop_stack.pop()
            return max(body, cond)
        if prim == "scan":
            length = int(eqn.params.get("length", 1) or 1)
            # scan trips are static: multiply counts through, but memory is
            # per-iteration (stacked outputs are the eqn's outvars).
            return self.walk(_open(eqn.params["jaxpr"]), mult=mult * length,
                             path=f"{path}/scan".lstrip("/"))
        if prim == "cond":
            branches = eqn.params.get("branches", ())
            # conservative: memory is the max branch; counts are max-merged
            # by counting only the heaviest branch (branches of audited hot
            # paths are collective-free, so this never hides a psum).
            best, best_peak = None, -1
            for b in branches:
                probe = _Walker(ProgramReport(name="_probe"))
                p = probe.walk(_open(b), mult=mult)
                if p > best_peak or best is None:
                    best, best_peak = b, p
            if best is None:
                return 0
            return self.walk(_open(best), mult=mult,
                             path=f"{path}/cond".lstrip("/"))
        peak = 0
        for sub in _subjaxprs(eqn.params):
            peak = max(peak, self.walk(_open(sub), mult=mult,
                                       path=f"{path}/{prim}".lstrip("/")))
        return peak


def audit(fn, *args, name: Optional[str] = None, **kwargs) -> ProgramReport:
    """Trace ``fn(*args, **kwargs)`` (abstract — nothing runs) and return
    its ``ProgramReport``. Args may be concrete arrays or
    ``jax.ShapeDtypeStruct`` placeholders."""
    closed = jax.make_jaxpr(lambda *a: fn(*a, **kwargs))(*args)
    report = ProgramReport(name=name or getattr(fn, "__name__", "program"))
    report.input_bytes = sum(_aval_bytes(v.aval)
                             for v in closed.jaxpr.invars)
    report.output_bytes = sum(
        _aval_bytes(v.aval) for v in closed.jaxpr.outvars if _is_var(v))
    walker = _Walker(report)
    report.peak_live_bytes = walker.walk(closed.jaxpr)
    return report


def collective_bill(fn, *args, name: Optional[str] = None,
                    **kwargs) -> dict:
    """The audit-derived communication bill of one traced program:

    ``{"per_iteration": {prim: count}, "outside": {prim: count},
    "per_iteration_bytes": {prim: bytes}, "outside_bytes": {prim: bytes}}``

    ``per_iteration`` is the while-body bill (exact — the traced loop body
    is static); ``outside`` is the prologue/epilogue (e.g. the fixpoint
    stats pass after the inner loop). The flight recorder records
    ``per_iteration x n_iter + outside`` — see ``distributed.outer``.
    """
    r = audit(fn, *args, name=name, **kwargs)
    return {
        "per_iteration": r.collectives_per_iteration,
        "outside": dict(r.collectives_outside),
        "per_iteration_bytes": r.collective_bytes_per_iteration,
        "outside_bytes": dict(r.collective_bytes_outside),
    }
