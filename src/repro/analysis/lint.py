"""Layer 2 of the program auditor: repo-specific AST lint rules.

Four rules, each encoding a discipline this repo has shipped a bug by
violating (see ISSUE 7 / CHANGES.md):

* **RK001 — key reuse.** The same PRNG key variable consumed by two
  ``jax.random.*`` sampling calls without a rebind between them. The
  repo's resume/elasticity guarantees hang on fold_in discipline (every
  draw keyed by ``fold_in(key, tag)`` / per-row id, never a shared key
  consumed twice) — reuse silently correlates draws and breaks
  bit-identical resume (the PR 2/3 class of bug).
* **RK002 — tracer-leaking coercion.** ``float()``/``int()``/``bool()``
  on a non-literal, ``np.asarray``/``np.array``, ``.item()``/
  ``.tolist()``/``.numpy()`` inside a function that is jitted (decorated
  with ``jax.jit``/``partial(jax.jit, ...)``, or any function nested in
  one). Under trace these either raise ``ConcretizationTypeError`` at the
  worst moment or force a silent host sync.
* **RK003 — dead Pallas kernel.** A function in ``kernels/`` whose body
  issues ``pl.pallas_call`` but whose name is never referenced outside
  its defining module: a kernel no dispatch table can reach. The PR 5
  fused-mode bug — kernel written, never invoked — as a lint.
* **RK004 — non-hashable static arg.** A jit ``static_argnums``/
  ``static_argnames`` entry whose parameter default is a list/dict/set
  display. Hashing fails on first call — but only on the code path that
  hits the default, so it escapes shallow tests.

Findings can be waived via a checked-in JSON file (see ``waivers.json``):
``[{"rule": "RK003", "path": "src/repro/...", "symbol": "...",
"reason": "..."}]`` — every waiver must carry a reason, and unused
waivers are reported so the file cannot rot. Run as
``python -m repro.analysis [paths] [--waivers FILE]``.
"""
from __future__ import annotations

import ast
import dataclasses
import json
import os
from typing import Iterable, Optional

#: jax.random functions that *derive* keys rather than consume them.
_KEY_DERIVERS = frozenset({
    "split", "fold_in", "PRNGKey", "key", "key_data", "wrap_key_data",
    "clone", "key_impl",
})

#: numpy-ish coercions that leak tracers / force host syncs under jit.
_NP_COERCIONS = frozenset({"asarray", "array", "asanyarray"})
_METHOD_COERCIONS = frozenset({"item", "tolist", "numpy"})


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str
    path: str
    line: int
    symbol: str
    message: str

    def render(self) -> str:
        return f"{self.path}:{self.line}: {self.rule} {self.message}"


@dataclasses.dataclass(frozen=True)
class Waiver:
    rule: str
    path: str
    symbol: str = ""
    reason: str = ""

    def matches(self, f: Finding) -> bool:
        if self.rule != f.rule:
            return False
        if not f.path.endswith(self.path):
            return False
        return (not self.symbol) or self.symbol == f.symbol


def load_waivers(path: str) -> list:
    with open(path) as fh:
        raw = json.load(fh)
    out = []
    for entry in raw:
        if not entry.get("reason"):
            raise ValueError(
                f"waiver {entry} has no reason — every waiver must say why")
        out.append(Waiver(rule=entry["rule"], path=entry["path"],
                          symbol=entry.get("symbol", ""),
                          reason=entry["reason"]))
    return out


# ---------------------------------------------------------------------------
# helpers over the AST


def _dotted(node) -> str:
    """'jax.random.uniform' for an Attribute/Name chain, '' otherwise."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def _is_jit_decorator(dec) -> bool:
    """@jax.jit / @jit / @partial(jax.jit, ...) / @functools.partial(...)."""
    d = _dotted(dec)
    if d in ("jax.jit", "jit"):
        return True
    if isinstance(dec, ast.Call):
        head = _dotted(dec.func)
        if head in ("jax.jit", "jit"):
            return True
        if head in ("partial", "functools.partial") and dec.args:
            return _dotted(dec.args[0]) in ("jax.jit", "jit")
    return False


def _jit_static_params(call: ast.Call):
    """(static_argnums tuple, static_argnames tuple) from a jit call."""
    nums: tuple = ()
    names: tuple = ()
    for kw in call.keywords:
        try:
            val = ast.literal_eval(kw.value)
        except (ValueError, SyntaxError):
            continue
        if kw.arg == "static_argnums":
            nums = tuple(val) if isinstance(val, (tuple, list)) else (val,)
        elif kw.arg == "static_argnames":
            names = (val,) if isinstance(val, str) else tuple(val)
    return nums, names


def _is_unhashable_literal(node) -> bool:
    return isinstance(node, (ast.List, ast.Dict, ast.Set, ast.ListComp,
                             ast.DictComp, ast.SetComp))


# ---------------------------------------------------------------------------
# RK001 — key reuse


def _scoped_walk(root):
    """Pre-order (source-order) walk that does NOT descend into nested
    function/lambda scopes — their key parameters are different keys."""
    for child in ast.iter_child_nodes(root):
        if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                              ast.Lambda)):
            continue
        yield child
        yield from _scoped_walk(child)


def _check_key_reuse(tree: ast.AST, path: str) -> Iterable[Finding]:
    for fn in ast.walk(tree):
        if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        consumed: dict = {}   # key var name -> line of first consumption
        # Source-order walk of this function's own scope only (nested defs
        # get their own pass via the outer ast.walk).
        for node in _scoped_walk(fn):
            if isinstance(node, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
                targets = node.targets if isinstance(node, ast.Assign) \
                    else [node.target]
                for t in targets:
                    for leaf in ast.walk(t):
                        if isinstance(leaf, ast.Name):
                            consumed.pop(leaf.id, None)
            if not isinstance(node, ast.Call):
                continue
            head = _dotted(node.func)
            if not head.startswith(("jax.random.", "jrandom.", "random.")):
                continue
            leaf_fn = head.rsplit(".", 1)[-1]
            if leaf_fn in _KEY_DERIVERS or not node.args:
                continue
            first = node.args[0]
            if isinstance(first, ast.Name):
                name = first.id
                if name in consumed:
                    yield Finding(
                        "RK001", path, node.lineno, fn.name,
                        f"key `{name}` consumed again by jax.random."
                        f"{leaf_fn} (first consumed at line "
                        f"{consumed[name]}) — derive per-use keys with "
                        f"fold_in/split instead of reusing one key")
                else:
                    consumed[name] = node.lineno


# ---------------------------------------------------------------------------
# RK002 — tracer-leaking coercions inside jitted functions


def _traced_names(fn) -> set:
    """Names that may hold tracers inside a jitted ``fn``: its non-static
    parameters plus every name bound in its body. Names outside this set
    (static args, globals, builtins, modules) are trace-time constants, so
    ``int(...)`` over them is fine — e.g. ``int(math.log(n_clusters))``
    with ``n_clusters`` in static_argnames."""
    static: set = set()
    for dec in fn.decorator_list:
        if isinstance(dec, ast.Call) and _is_jit_decorator(dec):
            nums, names = _jit_static_params(dec)
            static.update(names)
            params = fn.args.args
            for i in nums:
                if isinstance(i, int) and 0 <= i < len(params):
                    static.add(params[i].arg)
    out = {a.arg for a in (fn.args.args + fn.args.kwonlyargs +
                           fn.args.posonlyargs)} - static
    if fn.args.vararg:
        out.add(fn.args.vararg.arg)
    if fn.args.kwarg:
        out.add(fn.args.kwarg.arg)
    for node in ast.walk(fn):
        if isinstance(node, ast.Name) and isinstance(node.ctx, ast.Store):
            out.add(node.id)
    return out


def _check_tracer_leaks(tree: ast.AST, path: str) -> Iterable[Finding]:
    jitted: list = [
        fn for fn in ast.walk(tree)
        if isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef))
        and any(_is_jit_decorator(d) for d in fn.decorator_list)
    ]
    for fn in jitted:
        traced = _traced_names(fn)
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            head = _dotted(node.func)
            # float(x) / int(x) / bool(x) on a potentially-traced value
            if head in ("float", "int", "bool") and node.args:
                arg = node.args[0]
                arg_names = {n.id for n in ast.walk(arg)
                             if isinstance(n, ast.Name)}
                if not isinstance(arg, ast.Constant) and arg_names & traced:
                    yield Finding(
                        "RK002", path, node.lineno, fn.name,
                        f"`{head}(...)` on a traced value inside jitted "
                        f"`{fn.name}` — concretizes the tracer (use jnp "
                        f"ops or hoist to host code)")
            elif head.split(".", 1)[0] in ("np", "numpy", "onp") and \
                    head.rsplit(".", 1)[-1] in _NP_COERCIONS:
                yield Finding(
                    "RK002", path, node.lineno, fn.name,
                    f"`{head}(...)` inside jitted `{fn.name}` — forces a "
                    f"host transfer under trace (use jnp.asarray)")
            elif isinstance(node.func, ast.Attribute) and \
                    node.func.attr in _METHOD_COERCIONS and not node.args:
                yield Finding(
                    "RK002", path, node.lineno, fn.name,
                    f"`.{node.func.attr}()` inside jitted `{fn.name}` — "
                    f"device->host sync under trace")


# ---------------------------------------------------------------------------
# RK003 — dead Pallas kernels


def _pallas_wrappers(tree: ast.AST):
    """Top-level functions whose body issues pl.pallas_call."""
    for fn in ast.walk(tree):
        if not isinstance(fn, ast.FunctionDef):
            continue
        for node in ast.walk(fn):
            if isinstance(node, ast.Call) and \
                    _dotted(node.func).endswith("pallas_call"):
                yield fn
                break


def _names_referenced(tree: ast.AST) -> set:
    out = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Name):
            out.add(node.id)
        elif isinstance(node, ast.Attribute):
            out.add(node.attr)
        elif isinstance(node, (ast.Import, ast.ImportFrom)):
            for alias in node.names:
                out.add(alias.name.rsplit(".", 1)[-1])
                if alias.asname:
                    out.add(alias.asname)
    return out


def _check_dead_kernels(files: dict) -> Iterable[Finding]:
    """files: {path: ast tree} over the whole lint target set."""
    kernel_files = {p: t for p, t in files.items()
                    if f"kernels{os.sep}" in p}
    if not kernel_files:
        return
    refs_by_file = {p: _names_referenced(t) for p, t in files.items()}
    for kpath, ktree in kernel_files.items():
        for fn in _pallas_wrappers(ktree):
            reachable = any(fn.name in refs for p, refs in
                            refs_by_file.items() if p != kpath)
            if not reachable:
                yield Finding(
                    "RK003", kpath, fn.lineno, fn.name,
                    f"Pallas kernel wrapper `{fn.name}` is never "
                    f"referenced outside its module — no dispatch table "
                    f"can reach it (dead kernel)")


# ---------------------------------------------------------------------------
# RK004 — non-hashable static args


def _check_static_args(tree: ast.AST, path: str) -> Iterable[Finding]:
    for fn in ast.walk(tree):
        if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        nums: tuple = ()
        names: tuple = ()
        for dec in fn.decorator_list:
            if isinstance(dec, ast.Call) and _is_jit_decorator(dec):
                n, s = _jit_static_params(dec)
                nums += n
                names += s
        if not nums and not names:
            continue
        params = fn.args.args
        kwonly = fn.args.kwonlyargs
        # positional defaults align to the tail of params
        pos_defaults = dict(zip(
            [p.arg for p in params[len(params) - len(fn.args.defaults):]],
            fn.args.defaults))
        kw_defaults = {p.arg: d for p, d in zip(kwonly, fn.args.kw_defaults)
                       if d is not None}
        defaults = {**pos_defaults, **kw_defaults}
        static_names = set(names)
        for i in nums:
            if isinstance(i, int) and 0 <= i < len(params):
                static_names.add(params[i].arg)
        for pname in static_names:
            d = defaults.get(pname)
            if d is not None and _is_unhashable_literal(d):
                yield Finding(
                    "RK004", path, d.lineno, fn.name,
                    f"static arg `{pname}` of jitted `{fn.name}` defaults "
                    f"to an unhashable {type(d).__name__.lower()} — jit "
                    f"hashes static args; use a tuple/frozen dataclass")


# ---------------------------------------------------------------------------
# driver


def lint_paths(paths: Iterable[str]) -> list:
    """Lint every .py file under ``paths`` (files or directories)."""
    files: dict = {}
    for root in paths:
        if os.path.isfile(root):
            targets = [root]
        else:
            targets = sorted(
                os.path.join(dp, f)
                for dp, _dn, fns in os.walk(root) for f in fns
                if f.endswith(".py"))
        for path in targets:
            with open(path, encoding="utf-8") as fh:
                src = fh.read()
            try:
                files[path] = ast.parse(src, filename=path)
            except SyntaxError as e:   # pragma: no cover
                raise SystemExit(f"{path}: cannot parse: {e}")
    findings: list = []
    for path, tree in files.items():
        findings.extend(_check_key_reuse(tree, path))
        findings.extend(_check_tracer_leaks(tree, path))
        findings.extend(_check_static_args(tree, path))
    findings.extend(_check_dead_kernels(files))
    findings.sort(key=lambda f: (f.path, f.line, f.rule))
    return findings


def apply_waivers(findings: list, waivers: list):
    """-> (active findings, waived findings, unused waivers)."""
    active, waived = [], []
    used = set()
    for f in findings:
        hit = None
        for i, w in enumerate(waivers):
            if w.matches(f):
                hit = i
                break
        if hit is None:
            active.append(f)
        else:
            used.add(hit)
            waived.append(f)
    unused = [w for i, w in enumerate(waivers) if i not in used]
    return active, waived, unused


def main(argv: Optional[list] = None) -> int:
    import argparse
    here = os.path.dirname(os.path.abspath(__file__))
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="repo-specific AST lint (RK001-RK004); exit 1 on any "
                    "unwaived finding")
    parser.add_argument("paths", nargs="*", default=None,
                        help="files/dirs to lint (default: src/repro)")
    parser.add_argument("--waivers",
                        default=os.path.join(here, "waivers.json"),
                        help="JSON waiver file (default: the checked-in "
                             "repro/analysis/waivers.json)")
    parser.add_argument("--no-waivers", action="store_true",
                        help="ignore the waiver file (show everything)")
    args = parser.parse_args(argv)

    paths = args.paths
    if not paths:
        pkg_root = os.path.dirname(here)         # .../src/repro
        paths = [pkg_root]
    waivers = [] if args.no_waivers else load_waivers(args.waivers)
    findings = lint_paths(paths)
    active, waived, unused = apply_waivers(findings, waivers)

    for f in active:
        print(f.render())
    if waived:
        print(f"[{len(waived)} finding(s) waived via "
              f"{os.path.basename(args.waivers)}]")
    for w in unused:
        print(f"warning: unused waiver {w.rule} {w.path} "
              f"{w.symbol or ''} ({w.reason})".rstrip())
    if active:
        print(f"{len(active)} unwaived finding(s)")
        return 1
    print(f"lint clean ({len(findings)} finding(s), all waived)"
          if findings else "lint clean")
    return 0
