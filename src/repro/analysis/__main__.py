"""``python -m repro.analysis`` — run the AST lint gate (RK001-RK004)."""
import sys

from .lint import main

if __name__ == "__main__":
    sys.exit(main())
