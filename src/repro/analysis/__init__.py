"""Static program auditing: jaxpr-level proofs + AST lint.

Layer 1 (``repro.analysis.jaxpr``) traces jitted hot paths and proves
collective counts, memory residency, Pallas dispatch, and host-sync
hygiene from the jaxpr — before anything runs. Layer 2
(``repro.analysis.lint``, ``python -m repro.analysis``) lints ``src/``
for the repo's key-discipline and jit-hygiene rules (RK001-RK004).
"""
from .jaxpr import (  # noqa: F401
    COLLECTIVE_PRIMS,
    HOST_SYNC_PRIMS,
    AuditError,
    LoopReport,
    ProgramReport,
    audit,
    collective_bill,
)
from .lint import (  # noqa: F401
    Finding,
    Waiver,
    apply_waivers,
    lint_paths,
    load_waivers,
)
