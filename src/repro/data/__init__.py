from .sampling import batch_indices, split_batches, stream_blocks
from .sparse import (CSRBatch, concat_csr, csr_from_dense, is_sparse,
                     pad_csr_capacity, shard_csr, shard_row_mask,
                     slice_rows, split_csr, take_rows, to_dense)
from .synthetic import (make_blobs, make_md_trajectory, make_mnist_like,
                        make_noisy_replicas, make_rcv1_like,
                        make_rcv1_sparse, toy2d)
from .loader import BatchSource, PrefetchLoader

__all__ = [
    "batch_indices", "split_batches", "stream_blocks",
    "CSRBatch", "concat_csr", "csr_from_dense", "is_sparse",
    "pad_csr_capacity", "shard_csr", "shard_row_mask", "slice_rows",
    "split_csr", "take_rows", "to_dense",
    "make_blobs", "make_md_trajectory", "make_mnist_like",
    "make_noisy_replicas", "make_rcv1_like", "make_rcv1_sparse", "toy2d",
    "BatchSource", "PrefetchLoader",
]
