from .sampling import batch_indices, split_batches, stream_blocks
from .synthetic import (make_blobs, make_md_trajectory, make_mnist_like,
                        make_noisy_replicas, make_rcv1_like, toy2d)
from .loader import PrefetchLoader

__all__ = [
    "batch_indices", "split_batches", "stream_blocks",
    "make_blobs", "make_md_trajectory", "make_mnist_like",
    "make_noisy_replicas", "make_rcv1_like", "toy2d",
    "PrefetchLoader",
]
