"""Synthetic dataset generators statistically matched to the paper's §4 data.

No network access in this environment, so MNIST / RCV1 / the MD trajectory are
replaced with generators that reproduce their (N, d, #classes, structure)
envelope — DESIGN.md §8 item 5. Every generator returns (X float32 [n, d],
y int32 [n]).
"""
from __future__ import annotations

import numpy as np


def toy2d(n_per_cluster: int = 10000, seed: int = 0):
    """The paper's 2D toy (§4): 4 isotropic gaussians, sigma=0.2, on a grid.

    (The paper lists 3 centers with one duplicated — an obvious typo; the
    figure shows the 4 corners of [0.25, 0.75]^2.)
    """
    rng = np.random.default_rng(seed)
    centers = np.array([[0.25, 0.25], [0.25, 0.75], [0.75, 0.25], [0.75, 0.75]])
    xs, ys = [], []
    for j, c in enumerate(centers):
        xs.append(rng.normal(c, 0.2, size=(n_per_cluster, 2)))
        ys.append(np.full(n_per_cluster, j))
    x = np.concatenate(xs).astype(np.float32)
    y = np.concatenate(ys).astype(np.int32)
    perm = rng.permutation(len(x))
    return x[perm], y[perm]


def make_blobs(n: int, d: int, n_classes: int, *, sep: float = 6.0,
               sigma: float = 1.0, seed: int = 0):
    """Gaussian mixture with controllable separation (building block)."""
    rng = np.random.default_rng(seed)
    centers = rng.normal(0.0, sep / np.sqrt(d), size=(n_classes, d))
    y = rng.integers(0, n_classes, size=n).astype(np.int32)
    x = centers[y] + rng.normal(0.0, sigma / np.sqrt(d), size=(n, d))
    return x.astype(np.float32), y


def make_mnist_like(n: int = 60000, seed: int = 0):
    """MNIST envelope: 784-d, 10 classes, non-isotropic class manifolds.

    Class structure: each class is a low-rank (r=16) affine manifold plus
    pixel noise, values clipped to [0, 1] — mimics digit images far better
    than isotropic blobs and keeps kernel k-means non-trivial.
    """
    d, n_classes, r = 784, 10, 16
    rng = np.random.default_rng(seed)
    x = np.empty((n, d), np.float32)
    y = rng.integers(0, n_classes, size=n).astype(np.int32)
    for j in range(n_classes):
        idx = np.where(y == j)[0]
        mean = rng.uniform(0.0, 0.6, size=d) * (rng.random(d) < 0.25)
        basis = rng.normal(0.0, 1.0, size=(r, d)) / np.sqrt(d)
        z = rng.normal(0.0, 1.0, size=(len(idx), r))
        x[idx] = mean + z @ basis + rng.normal(0, 0.05, size=(len(idx), d))
    return np.clip(x, 0.0, 1.0), y


def make_rcv1_like(n: int = 188000, d: int = 256, n_classes: int = 50,
                   seed: int = 0):
    """RCV1 envelope after the paper's preprocessing: log TF-IDF vectors
    random-projected to a dense 256-d space; ~50 surviving categories with a
    power-law class-size distribution (text corpora are heavy-tailed)."""
    rng = np.random.default_rng(seed)
    sizes = (1.0 / np.arange(1, n_classes + 1)) ** 1.1
    sizes = np.maximum((sizes / sizes.sum() * n).astype(np.int64), 1)
    sizes[0] += n - sizes.sum()
    y = np.repeat(np.arange(n_classes), sizes).astype(np.int32)
    # sparse topic vectors in a 2048-d "vocab", projected to d dense dims.
    vocab = 2048
    proj = rng.normal(0.0, 1.0 / np.sqrt(d), size=(vocab, d)).astype(np.float32)
    x = np.empty((n, d), np.float32)
    for j in range(n_classes):
        idx = np.where(y == j)[0]
        topic = rng.random(vocab) < (32.0 / vocab)
        base = rng.exponential(1.0, size=vocab) * topic
        docs = rng.poisson(lam=base, size=(len(idx), vocab)).astype(np.float32)
        docs *= rng.random((len(idx), vocab)) < 0.3       # per-doc word dropout
        docs = np.log1p(docs)
        norms = np.linalg.norm(docs, axis=1, keepdims=True)
        x[idx] = (docs / np.maximum(norms, 1e-9)) @ proj
    perm = rng.permutation(n)
    return x[perm], y[perm]


def make_rcv1_sparse(n: int = 188000, vocab: int = 20000,
                     n_classes: int = 50, *, words_per_topic: float = 48.0,
                     seed: int = 0):
    """RCV1 envelope *before* the paper's dense 256-d projection: log TF-IDF
    documents kept sparse over a ``vocab``-dimensional term space (~tens of
    nonzeros per document, heavy-tailed class sizes).

    Returns ``(CSRBatch [n, vocab], y int32 [n])`` — the workload the
    O(nnz) count-sketch path exists for; densifying it is exactly what
    ``benchmarks/tab2_rcv1.py``'s sparse grid avoids.
    """
    from .sparse import CSRBatch

    rng = np.random.default_rng(seed)
    sizes = (1.0 / np.arange(1, n_classes + 1)) ** 1.1
    sizes = np.maximum((sizes / sizes.sum() * n).astype(np.int64), 1)
    sizes[0] += n - sizes.sum()
    y = np.repeat(np.arange(n_classes), sizes).astype(np.int32)

    datas, cols, lens = [], [], []
    for j in range(n_classes):
        n_j = int(sizes[j])
        topic = np.where(rng.random(vocab) < (words_per_topic / vocab))[0]
        if len(topic) == 0:
            topic = rng.integers(0, vocab, size=8)
        base = rng.exponential(1.0, size=len(topic))
        counts = rng.poisson(lam=base, size=(n_j, len(topic)))
        counts = counts * (rng.random((n_j, len(topic))) < 0.5)
        vals = np.log1p(counts.astype(np.float32))
        norms = np.sqrt((vals ** 2).sum(axis=1, keepdims=True))
        vals = vals / np.maximum(norms, 1e-9)
        for r in range(n_j):
            nz = np.nonzero(vals[r])[0]
            datas.append(vals[r, nz])
            cols.append(topic[nz])
            lens.append(len(nz))

    perm = rng.permutation(n)
    indptr = np.zeros(n + 1, np.int64)
    np.cumsum(np.asarray(lens)[perm], out=indptr[1:])
    data = np.concatenate([datas[i] for i in perm]).astype(np.float32)
    indices = np.concatenate([cols[i] for i in perm]).astype(np.int32)
    batch = CSRBatch(data=data, indices=indices,
                     indptr=indptr.astype(np.int32), shape=(n, vocab))
    return batch, y[perm]


def make_noisy_replicas(x: np.ndarray, y: np.ndarray, *, n_replicas: int = 20,
                        frac_features: float = 0.2, seed: int = 0):
    """Paper's 'Noisy MNIST': each sample perturbed ``n_replicas`` times with
    uniform noise on ``frac_features`` of the features (§4, 1.2M samples)."""
    rng = np.random.default_rng(seed)
    n, d = x.shape
    out_x = np.repeat(x, n_replicas, axis=0)
    out_y = np.repeat(y, n_replicas, axis=0)
    k = int(frac_features * d)
    cols = rng.integers(0, d, size=(len(out_x), k))
    rows = np.arange(len(out_x))[:, None]
    out_x[rows, cols] = rng.random((len(out_x), k)).astype(x.dtype)
    perm = rng.permutation(len(out_x))
    return out_x[perm], out_y[perm]


def make_md_trajectory(n_frames: int = 100000, n_atoms: int = 64,
                       n_states: int = 20, *, dwell: float = 500.0,
                       seed: int = 0):
    """MD-trajectory envelope (§4.5): a Markov jump process over metastable
    conformations. Frames are 3*n_atoms coordinates fluctuating around one of
    ``n_states`` reference structures; consecutive frames are correlated
    (mean dwell time ``dwell`` frames) — exactly the concept-drift regime
    where block sampling struggles and stride sampling shines (Fig.4)."""
    rng = np.random.default_rng(seed)
    d = 3 * n_atoms
    refs = rng.normal(0.0, 1.0, size=(n_states, d)).astype(np.float32)
    y = np.empty(n_frames, np.int32)
    state = 0
    for t in range(n_frames):
        if rng.random() < 1.0 / dwell:
            state = rng.integers(0, n_states)
        y[t] = state
    x = refs[y] + rng.normal(0.0, 0.15, size=(n_frames, d)).astype(np.float32)
    return x, y
