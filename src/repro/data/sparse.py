"""CSR-style sparse mini-batches for the embedded (sketch) path.

Very high-dimensional sparse workloads (RCV1-style log TF-IDF: d ~ 50k,
~100 nonzeros per document) cannot afford the dense [n, d] batch the
RFF/Nystrom projections consume — but the count-sketch maps in
``repro.approx.sketch`` only ever touch the *nonzero* coordinates, so the
embedding step is O(nnz) when the batch stays sparse end-to-end.

``CSRBatch`` is the minimal shape-static CSR triplet (data/indices/indptr)
that flows through jit: the three arrays are pytree leaves, the logical
(n, d) shape is static aux data. ``to_dense`` is the *oracle* every sparse
code path is tested against — any operation on a ``CSRBatch`` must produce
bit-identical results to the same operation on ``to_dense(batch)``.

Host-side helpers (``csr_from_dense``, ``take_rows``, ``split_csr``,
``concat_csr``, ``slice_rows``, ``shard_csr``) are numpy — they run in the
streaming outer loop, not inside jit.

Capacity contract: a ``CSRBatch`` may carry *slack* nnz capacity — stored
slots at positions >= ``indptr[-1]`` that belong to no row (zero data,
column 0). ``shard_csr`` uses this to give every mesh shard identical leaf
shapes (shard_map needs them) without gathering; ``to_dense`` and every
other consumer honors only ``data[:indptr[-1]]``. Slack slots are inert in
the O(nnz) sketch paths too: their values are 0 and their scatter targets
fall outside (or add zero to) the embedding.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from .sampling import batch_indices

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class CSRBatch:
    """Compressed-sparse-row batch: row i owns data[indptr[i]:indptr[i+1]].

    ``data`` [nnz] f32, ``indices`` [nnz] int32 column ids, ``indptr``
    [n+1] int32 row offsets, ``shape`` = (n, d) static. Arrays may be
    numpy (host side) or jax (device side) — jit boundaries convert.
    """

    data: Array
    indices: Array
    indptr: Array
    shape: tuple[int, int]

    @property
    def nnz(self) -> int:
        """Stored slots, including any slack capacity (see module doc)."""
        return self.data.shape[0]

    def __len__(self) -> int:
        return self.shape[0]


jax.tree_util.register_pytree_node(
    CSRBatch,
    lambda b: ((b.data, b.indices, b.indptr), b.shape),
    lambda shape, leaves: CSRBatch(data=leaves[0], indices=leaves[1],
                                   indptr=leaves[2], shape=shape),
)


def is_sparse(x) -> bool:
    return isinstance(x, CSRBatch)


def csr_from_dense(x: np.ndarray) -> CSRBatch:
    """Dense [n, d] -> CSRBatch (numpy, host side)."""
    x = np.asarray(x)
    if x.ndim != 2:
        raise ValueError(f"need a 2-d array, got shape {x.shape}")
    rows, cols = np.nonzero(x)
    data = x[rows, cols].astype(np.float32)
    indptr = np.zeros(x.shape[0] + 1, np.int32)
    np.add.at(indptr, rows + 1, 1)
    return CSRBatch(data=data, indices=cols.astype(np.int32),
                    indptr=np.cumsum(indptr).astype(np.int32),
                    shape=(int(x.shape[0]), int(x.shape[1])))


def to_dense(batch: CSRBatch) -> np.ndarray:
    """CSRBatch -> dense [n, d] f32 (numpy) — the round-trip oracle.

    Honors the capacity contract: only ``data[:indptr[-1]]`` is row payload;
    slack slots (equal-shape mesh shards) are ignored.
    """
    n, d = batch.shape
    out = np.zeros((n, d), np.float32)
    indptr = np.asarray(batch.indptr)
    stored = int(indptr[-1])
    data = np.asarray(batch.data)[:stored]
    indices = np.asarray(batch.indices)[:stored]
    rows = np.repeat(np.arange(n), np.diff(indptr))
    out[rows, indices] = data
    return out


def row_ids(batch: CSRBatch) -> Array:
    """[nnz] int32 row id of every stored value (jit-friendly, O(nnz log n)).

    ``searchsorted`` instead of ``repeat`` because repeat counts are dynamic
    under jit while nnz and n are shape-static.
    """
    indptr = jnp.asarray(batch.indptr)
    pos = jnp.arange(batch.nnz, dtype=jnp.int32)
    return (jnp.searchsorted(indptr, pos, side="right") - 1).astype(jnp.int32)


def take_rows(batch: CSRBatch, idx: np.ndarray) -> CSRBatch:
    """Select rows ``idx`` (host side, preserves per-row order)."""
    idx = np.asarray(idx)
    data = np.asarray(batch.data)
    indices = np.asarray(batch.indices)
    indptr = np.asarray(batch.indptr).astype(np.int64)
    lens = np.diff(indptr)[idx]
    new_indptr = np.zeros(len(idx) + 1, np.int64)
    np.cumsum(lens, out=new_indptr[1:])
    total = int(new_indptr[-1])
    # vectorized gather: for output slot t in row r (new order),
    # gather[t] = indptr[idx[r]] + (t - new_indptr[r]).
    starts = np.repeat(indptr[idx] - new_indptr[:-1], lens)
    gather = starts + np.arange(total, dtype=np.int64)
    return CSRBatch(data=data[gather].astype(np.float32),
                    indices=indices[gather].astype(np.int32),
                    indptr=new_indptr.astype(np.int32),
                    shape=(int(len(idx)), batch.shape[1]))


def split_csr(batch: CSRBatch, n_batches: int,
              strategy: str = "stride") -> list[CSRBatch]:
    """Stride/block split a CSR dataset into mini-batches (repro.data.sampling
    semantics — same index sets as ``split_batches`` on the dense oracle)."""
    return [take_rows(batch, idx)
            for idx in batch_indices(len(batch), n_batches, strategy)]


def slice_rows(batch: CSRBatch, start: int, stop: int) -> CSRBatch:
    """Contiguous row slice [start, stop) — the O(slice nnz) primitive the
    streaming re-chunker is built on (no index gather, no concat churn)."""
    n = batch.shape[0]
    start, stop = max(0, min(n, int(start))), max(0, min(n, int(stop)))
    if stop < start:
        raise ValueError(f"need start <= stop, got [{start}, {stop})")
    indptr = np.asarray(batch.indptr)
    lo, hi = int(indptr[start]), int(indptr[stop])
    # data/indices stay VIEWS when dtypes already match — the streaming
    # re-chunker copies each row's payload once, at batch assembly, not here
    return CSRBatch(
        data=np.asarray(np.asarray(batch.data)[lo:hi], dtype=np.float32),
        indices=np.asarray(np.asarray(batch.indices)[lo:hi], dtype=np.int32),
        indptr=np.asarray(indptr[start:stop + 1] - lo, dtype=np.int32),
        shape=(stop - start, batch.shape[1]))


def concat_csr(parts: list[CSRBatch]) -> CSRBatch:
    """Row-stack CSR batches (host side). The inverse of slicing: indptr
    surgery only — per-part offsets accumulate, slack capacity is dropped."""
    if not parts:
        raise ValueError("need at least one CSRBatch to concatenate")
    d = parts[0].shape[1]
    if any(p.shape[1] != d for p in parts):
        raise ValueError(
            f"column counts differ: {[p.shape[1] for p in parts]}")
    datas, indices, indptrs = [], [], [np.zeros((1,), np.int64)]
    off = 0
    for p in parts:
        ptr = np.asarray(p.indptr).astype(np.int64)
        stored = int(ptr[-1])
        datas.append(np.asarray(p.data)[:stored])
        indices.append(np.asarray(p.indices)[:stored])
        indptrs.append(ptr[1:] + off)
        off += stored
    return CSRBatch(
        data=np.concatenate(datas).astype(np.float32),
        indices=np.concatenate(indices).astype(np.int32),
        indptr=np.concatenate(indptrs).astype(np.int32),
        shape=(sum(p.shape[0] for p in parts), d))


def shard_row_mask(n: int, n_shards: int) -> np.ndarray:
    """[n_shards, rows_per_shard] bool — True on real rows, False on the
    padded tail ``shard_csr`` appends so every shard has equal row count."""
    rows = -(-n // n_shards)
    gids = np.arange(n_shards * rows).reshape(n_shards, rows)
    return gids < n


def pad_csr_capacity(pieces: list[CSRBatch], *, rows: int | None = None,
                     nnz_multiple: int = 1) -> list[CSRBatch]:
    """Equalize a list of CSR pieces into mesh-ready shards: every output
    has ``rows`` rows (short pieces get empty tail rows) and one shared nnz
    capacity (max piece nnz rounded up to ``nnz_multiple``; slack beyond
    ``indptr[-1]`` per the capacity contract). The single O(nnz) copy of
    the sharding path — feed it view pieces (``slice_rows``/``take_rows``)
    and each stored value is copied exactly once."""
    if not pieces:
        raise ValueError("need at least one piece")
    rows = max(p.shape[0] for p in pieces) if rows is None else int(rows)
    cap = max(int(np.asarray(p.indptr)[-1]) for p in pieces)
    cap = -(-cap // nnz_multiple) * nnz_multiple
    out = []
    for p in pieces:
        if p.shape[0] > rows:
            raise ValueError(f"piece has {p.shape[0]} rows > rows={rows}")
        ptr = np.asarray(p.indptr).astype(np.int32)
        stored = int(ptr[-1])
        if p.shape[0] < rows:                       # empty-row tail padding
            ptr = np.concatenate(
                [ptr, np.full((rows - p.shape[0],), stored, np.int32)])
        data = np.zeros((cap,), np.float32)
        data[:stored] = np.asarray(p.data)[:stored]
        indices = np.zeros((cap,), np.int32)
        indices[:stored] = np.asarray(p.indices)[:stored]
        out.append(CSRBatch(data=data, indices=indices, indptr=ptr,
                            shape=(rows, p.shape[1])))
    return out


def shard_csr(batch: CSRBatch, n_shards: int, *,
              nnz_multiple: int = 1) -> list[CSRBatch]:
    """Row-split ``batch`` into ``n_shards`` equal-shape CSR shards — the
    indptr surgery that puts one mini-batch across the mesh.

    Shard k owns the contiguous rows [k*rows, (k+1)*rows) with
    rows = ceil(n / n_shards); its indptr is rebased to start at 0. Two
    paddings make the shards mesh-ready (identical leaf shapes for
    shard_map / device_put with a row NamedSharding):

    * row padding — trailing shards short on rows get *empty* rows
      appended. ``to_dense`` shows them as all-zero rows; they must be
      weight-masked downstream so they never bias centroids
      (``shard_row_mask`` gives the mask).
    * nnz padding — every shard's data/indices are zero-filled up to the
      max shard nnz (rounded up to ``nnz_multiple``). The slack lives
      beyond ``indptr[-1]`` per the module's capacity contract.

    Oracle: ``to_dense(shard_csr(b, p)[k])`` equals the dense row block
    ``to_dense(b)[k*rows:(k+1)*rows]`` zero-padded to ``rows`` rows.
    """
    if n_shards < 1:
        raise ValueError(f"need n_shards >= 1, got {n_shards}")
    n = batch.shape[0]
    rows = -(-n // n_shards)
    pieces = [slice_rows(batch, k * rows, min((k + 1) * rows, n))
              for k in range(n_shards)]
    return pad_csr_capacity(pieces, rows=rows, nnz_multiple=nnz_multiple)
