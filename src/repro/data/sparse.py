"""CSR-style sparse mini-batches for the embedded (sketch) path.

Very high-dimensional sparse workloads (RCV1-style log TF-IDF: d ~ 50k,
~100 nonzeros per document) cannot afford the dense [n, d] batch the
RFF/Nystrom projections consume — but the count-sketch maps in
``repro.approx.sketch`` only ever touch the *nonzero* coordinates, so the
embedding step is O(nnz) when the batch stays sparse end-to-end.

``CSRBatch`` is the minimal shape-static CSR triplet (data/indices/indptr)
that flows through jit: the three arrays are pytree leaves, the logical
(n, d) shape is static aux data. ``to_dense`` is the *oracle* every sparse
code path is tested against — any operation on a ``CSRBatch`` must produce
bit-identical results to the same operation on ``to_dense(batch)``.

Host-side helpers (``csr_from_dense``, ``take_rows``, ``split_csr``) are
numpy — they run in the streaming outer loop, not inside jit.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from .sampling import batch_indices

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class CSRBatch:
    """Compressed-sparse-row batch: row i owns data[indptr[i]:indptr[i+1]].

    ``data`` [nnz] f32, ``indices`` [nnz] int32 column ids, ``indptr``
    [n+1] int32 row offsets, ``shape`` = (n, d) static. Arrays may be
    numpy (host side) or jax (device side) — jit boundaries convert.
    """

    data: Array
    indices: Array
    indptr: Array
    shape: tuple[int, int]

    @property
    def nnz(self) -> int:
        return self.data.shape[0]

    def __len__(self) -> int:
        return self.shape[0]


jax.tree_util.register_pytree_node(
    CSRBatch,
    lambda b: ((b.data, b.indices, b.indptr), b.shape),
    lambda shape, leaves: CSRBatch(data=leaves[0], indices=leaves[1],
                                   indptr=leaves[2], shape=shape),
)


def is_sparse(x) -> bool:
    return isinstance(x, CSRBatch)


def csr_from_dense(x: np.ndarray) -> CSRBatch:
    """Dense [n, d] -> CSRBatch (numpy, host side)."""
    x = np.asarray(x)
    if x.ndim != 2:
        raise ValueError(f"need a 2-d array, got shape {x.shape}")
    rows, cols = np.nonzero(x)
    data = x[rows, cols].astype(np.float32)
    indptr = np.zeros(x.shape[0] + 1, np.int32)
    np.add.at(indptr, rows + 1, 1)
    return CSRBatch(data=data, indices=cols.astype(np.int32),
                    indptr=np.cumsum(indptr).astype(np.int32),
                    shape=(int(x.shape[0]), int(x.shape[1])))


def to_dense(batch: CSRBatch) -> np.ndarray:
    """CSRBatch -> dense [n, d] f32 (numpy) — the round-trip oracle."""
    n, d = batch.shape
    out = np.zeros((n, d), np.float32)
    data = np.asarray(batch.data)
    indices = np.asarray(batch.indices)
    indptr = np.asarray(batch.indptr)
    rows = np.repeat(np.arange(n), np.diff(indptr))
    out[rows, indices] = data
    return out


def row_ids(batch: CSRBatch) -> Array:
    """[nnz] int32 row id of every stored value (jit-friendly, O(nnz log n)).

    ``searchsorted`` instead of ``repeat`` because repeat counts are dynamic
    under jit while nnz and n are shape-static.
    """
    indptr = jnp.asarray(batch.indptr)
    pos = jnp.arange(batch.nnz, dtype=jnp.int32)
    return (jnp.searchsorted(indptr, pos, side="right") - 1).astype(jnp.int32)


def take_rows(batch: CSRBatch, idx: np.ndarray) -> CSRBatch:
    """Select rows ``idx`` (host side, preserves per-row order)."""
    idx = np.asarray(idx)
    data = np.asarray(batch.data)
    indices = np.asarray(batch.indices)
    indptr = np.asarray(batch.indptr).astype(np.int64)
    lens = np.diff(indptr)[idx]
    new_indptr = np.zeros(len(idx) + 1, np.int64)
    np.cumsum(lens, out=new_indptr[1:])
    total = int(new_indptr[-1])
    # vectorized gather: for output slot t in row r (new order),
    # gather[t] = indptr[idx[r]] + (t - new_indptr[r]).
    starts = np.repeat(indptr[idx] - new_indptr[:-1], lens)
    gather = starts + np.arange(total, dtype=np.int64)
    return CSRBatch(data=data[gather].astype(np.float32),
                    indices=indices[gather].astype(np.int32),
                    indptr=new_indptr.astype(np.int32),
                    shape=(int(len(idx)), batch.shape[1]))


def split_csr(batch: CSRBatch, n_batches: int,
              strategy: str = "stride") -> list[CSRBatch]:
    """Stride/block split a CSR dataset into mini-batches (repro.data.sampling
    semantics — same index sets as ``split_batches`` on the dense oracle)."""
    return [take_rows(batch, idx)
            for idx in batch_indices(len(batch), n_batches, strategy)]
