"""Mini-batch sampling strategies (paper §3.1, Fig.1b).

* stride sampling  — X^i = { x_{i + j*B} } : minimizes within-batch
  correlation when the whole dataset is batch-available. "When possible,
  this sampling should always be used" (§4.5).
* block  sampling  — X^i = { x_{i*N/B + j} } : streaming-friendly, clustering
  starts as soon as the first N/B samples arrive; risks concept drift
  (Fig.4a top row).
"""
from __future__ import annotations

from typing import Iterator

import numpy as np


def batch_indices(n: int, n_batches: int, strategy: str = "stride") -> list[np.ndarray]:
    """Disjoint index sets for B mini-batches. Trailing remainder samples are
    folded into the last batch (the paper assumes N % B == 0)."""
    if n_batches < 1 or n_batches > n:
        raise ValueError(f"need 1 <= B <= N, got B={n_batches}, N={n}")
    if strategy == "stride":
        return [np.arange(i, n, n_batches) for i in range(n_batches)]
    if strategy == "block":
        size = n // n_batches
        out = [np.arange(i * size, (i + 1) * size) for i in range(n_batches)]
        if n % n_batches:
            out[-1] = np.arange((n_batches - 1) * size, n)
        return out
    raise ValueError(f"unknown sampling strategy {strategy!r}")


def split_batches(x: np.ndarray, n_batches: int,
                  strategy: str = "stride") -> list[np.ndarray]:
    return [x[idx] for idx in batch_indices(len(x), n_batches, strategy)]


def stream_blocks(stream: Iterator[np.ndarray], batch_size: int) -> Iterator[np.ndarray]:
    """Re-chunk an arbitrary sample stream into block mini-batches — the
    'process a data stream' mode of §3.1 (clustering starts at first batch)."""
    buf: list[np.ndarray] = []
    have = 0
    for chunk in stream:
        buf.append(np.atleast_2d(chunk))
        have += len(buf[-1])
        while have >= batch_size:
            flat = np.concatenate(buf, axis=0)
            yield flat[:batch_size]
            rest = flat[batch_size:]
            buf, have = ([rest] if len(rest) else []), len(rest)
    if have:
        yield np.concatenate(buf, axis=0)
