"""Mini-batch sampling strategies (paper §3.1, Fig.1b).

* stride sampling  — X^i = { x_{i + j*B} } : minimizes within-batch
  correlation when the whole dataset is batch-available. "When possible,
  this sampling should always be used" (§4.5).
* block  sampling  — X^i = { x_{i*N/B + j} } : streaming-friendly, clustering
  starts as soon as the first N/B samples arrive; risks concept drift
  (Fig.4a top row).
"""
from __future__ import annotations

from collections import deque
from typing import Iterator

import numpy as np


def batch_indices(n: int, n_batches: int, strategy: str = "stride") -> list[np.ndarray]:
    """Disjoint index sets for B mini-batches. Trailing remainder samples are
    folded into the last batch (the paper assumes N % B == 0)."""
    if n_batches < 1 or n_batches > n:
        raise ValueError(f"need 1 <= B <= N, got B={n_batches}, N={n}")
    if strategy == "stride":
        return [np.arange(i, n, n_batches) for i in range(n_batches)]
    if strategy == "block":
        size = n // n_batches
        out = [np.arange(i * size, (i + 1) * size) for i in range(n_batches)]
        if n % n_batches:
            out[-1] = np.arange((n_batches - 1) * size, n)
        return out
    raise ValueError(f"unknown sampling strategy {strategy!r}")


def split_batches(x: np.ndarray, n_batches: int,
                  strategy: str = "stride") -> list[np.ndarray]:
    return [x[idx] for idx in batch_indices(len(x), n_batches, strategy)]


def _chunk_slice(chunk, start: int, stop: int):
    """Row slice of a dense array (view) or CSRBatch (O(slice nnz))."""
    from .sparse import is_sparse, slice_rows
    if is_sparse(chunk):
        return slice_rows(chunk, start, stop)
    return chunk[start:stop]


def _chunk_cat(pieces: list):
    """Assemble one mini-batch from buffered pieces. A batch touched by any
    CSR piece is promoted to CSR (dense pieces are sparsified — sparse data
    is NEVER densified, the whole point of the streaming CSR path).

    Pieces are views of chunks the rechunker already owns (copied on
    arrival, see ``stream_blocks``), so a single-piece batch is returned
    as-is — no second copy, and nothing can overwrite it."""
    from .sparse import concat_csr, csr_from_dense, is_sparse
    if len(pieces) == 1:
        return pieces[0]
    if any(is_sparse(p) for p in pieces):
        return concat_csr([p if is_sparse(p) else csr_from_dense(p)
                           for p in pieces])
    return np.concatenate(pieces, axis=0)


def stream_blocks(stream: Iterator, batch_size: int) -> Iterator:
    """Re-chunk an arbitrary sample stream into block mini-batches — the
    'process a data stream' mode of §3.1 (clustering starts at first batch).

    Chunks may be dense [k, d] arrays or ``repro.data.sparse.CSRBatch``es of
    any ragged sizes (heterogeneous streams are fine; a mixed batch comes
    out CSR). The buffer carries an offset into its head chunk instead of
    re-concatenating the whole tail on every yield — the old implementation
    was quadratic in chunks-per-batch.

    Each chunk is copied ONCE, on arrival: the stream must own its buffer,
    because chunks are held across subsequent pulls and producers routinely
    reuse one read buffer (``buf[:] = ...; yield buf``) — holding a view
    would let the next read silently corrupt queued batches. Slicing and
    single-chunk assembly are view-only after that.
    """
    from .sparse import CSRBatch, is_sparse

    if batch_size < 1:
        raise ValueError(f"need batch_size >= 1, got {batch_size}")
    buf: deque = deque()
    offset = 0                      # rows of buf[0] already consumed
    have = 0                        # unconsumed rows buffered

    def take(n_rows: int):
        nonlocal offset, have
        pieces = []
        need = n_rows
        while need:
            head = buf[0]
            avail = len(head) - offset
            use = min(avail, need)
            pieces.append(_chunk_slice(head, offset, offset + use))
            offset += use
            need -= use
            if offset == len(head):
                buf.popleft()
                offset = 0
        have -= n_rows
        return _chunk_cat(pieces)

    for chunk in stream:
        if is_sparse(chunk):          # own the chunk (see docstring)
            chunk = CSRBatch(data=np.array(chunk.data),
                             indices=np.array(chunk.indices),
                             indptr=np.array(chunk.indptr),
                             shape=chunk.shape)
        else:
            chunk = np.array(np.atleast_2d(chunk))
        if len(chunk) == 0:
            continue
        buf.append(chunk)
        have += len(chunk)
        while have >= batch_size:
            yield take(batch_size)
    if have:
        yield take(have)
