"""Sharded H2D staging: the host side of the paper's producer/consumer
offload scheme (§3.3, Fig.3), generalized to batch *pytrees*.

On the paper's CPU+GPU node, a dedicated thread feeds the GPU so that
K^{i+1} is produced while the host consumes K^i. On TPU the kernel matrix is
produced by the same chip that consumes it, so the equivalent overlap is
host-side: a background thread stages batch i+1 (disk fetch, dtype cast,
device put) while the device iterates the inner loop on batch i.

What "stage" means here is richer than the paper's memcpy leg: the hook may
pad a batch to divide the mesh, row-split a ``CSRBatch`` into per-device
shards (the ``repro.data.sparse`` indptr surgery — ``slice_rows``/
``shard_csr``; see ``DistributedEmbedKMeans._stage_csr``), and
``jax.device_put`` the resulting pytree onto a ``NamedSharding`` so the
async H2D copy lands *pre-sharded* on the mesh — the consumer never touches
a single-host [n, d] array, dense or sparse. That is this runtime's version
of Fig.3's 3-stage H2D/compute/D2H pipeline: the H2D leg overlaps the inner
loop, the D2H leg was removed by fusion (DESIGN.md §2), and with CSR shards
the bytes crossing the bus are O(nnz), not O(n*d).

Lifecycle: the producer is a daemon thread feeding a bounded queue. A
consumer that stops early (elastic re-mesh, error, ``break``) MUST call
``close()`` (or use the context manager) — otherwise the producer blocks
forever on the full queue. ``close()`` sets a stop flag and drains the
queue until the thread exits; it is idempotent.

``BatchSource`` is the one handle the fit loops consume: any iterable of
dense blocks or CSR mini-batches (list, generator, or a raw chunk stream
via ``from_stream``), with optional host-side ``skip`` (checkpoint resume —
skipped batches are never staged) and optional prefetch+stage.
"""
from __future__ import annotations

import contextlib
import queue
import threading
import time
from typing import Callable, Iterable, Iterator, Optional

import jax
import numpy as np


@contextlib.contextmanager
def closing_source(batches):
    """The fit loops' consume rule, in one place: whatever happens inside,
    a closable batch source (BatchSource / PrefetchLoader) is closed on
    exit so its producer thread never outlives the fit. ``close()`` is
    idempotent, so nested fit entry points may each apply this."""
    try:
        yield batches
    finally:
        close = getattr(batches, "close", None)
        if callable(close):
            close()


class PrefetchLoader:
    """Wrap a mini-batch iterable with ``depth`` batches of lookahead.

    ``stage`` maps a raw host batch to its device-resident form inside the
    producer thread; the default casts dense ndarrays to ``dtype`` and
    ``jax.device_put``s them (any other pytree — e.g. a ``CSRBatch`` — is
    device_put leaf-wise). Pass a mesh-aware hook (e.g.
    ``DistributedEmbedKMeans.stage``) to land batches pre-sharded.

    ``recorder`` (``repro.obs``) watches pipeline health from both sides:
    the producer thread times each stage call (``prefetch/stage_seconds``)
    and gauges the queue depth after every put, the consumer records how
    long it sat starved waiting for an item (``prefetch/starve_seconds``).
    A persistently shallow queue + starved consumer means ingestion is the
    bottleneck, not the mesh.
    """

    _SENTINEL = object()

    def __init__(self, batches: Iterable, *, depth: int = 2,
                 device: Optional[jax.Device] = None, dtype=np.float32,
                 stage: Optional[Callable] = None, recorder=None):
        from repro.obs import resolve
        self._src = iter(batches)
        self._q: queue.Queue = queue.Queue(maxsize=max(1, depth))
        self._device = device
        self._dtype = dtype
        self._stage = stage if stage is not None else self._default_stage
        self._rec = resolve(recorder)
        self._err: Optional[BaseException] = None
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._produce, daemon=True)
        self._thread.start()

    def _default_stage(self, batch):
        # array-like batches (ndarray, jax array, nested lists) keep the
        # historical coercion to one ``dtype`` device array; genuine batch
        # pytrees (CSRBatch, dicts) are device_put leaf-wise instead.
        if jax.tree_util.all_leaves([batch]) or \
                isinstance(batch, (list, tuple)):
            return jax.device_put(np.asarray(batch, dtype=self._dtype),
                                  self._device)  # async H2D
        return jax.device_put(batch, self._device)

    def _put(self, item) -> bool:
        """Blocking put that stays interruptible by ``close()``.

        The timeout only bounds how long the thread parks before re-checking
        the stop flag — a consumer freeing a slot wakes the put immediately
        regardless — so the backoff costs no throughput; it just keeps an
        abandoned (never-closed) loader's producer from waking 20x/s
        forever."""
        delay = 0.05
        while not self._stop.is_set():
            try:
                self._q.put(item, timeout=delay)
                return True
            except queue.Full:
                delay = min(2.0 * delay, 0.5)
        return False

    def _produce(self) -> None:
        from repro.obs import trace as obs_trace
        rec = self._rec
        try:
            for k, batch in enumerate(self._src):
                if self._stop.is_set():
                    return
                t0 = time.perf_counter()
                with obs_trace.annotate("obs:stage"):
                    staged = self._stage(batch)
                if rec.enabled:
                    rec.series("prefetch/stage_seconds",
                               time.perf_counter() - t0, index=k)
                if not self._put(staged):
                    return
                if rec.enabled:
                    rec.gauge("prefetch/queue_depth", self._q.qsize(),
                              index=k)
        except BaseException as e:  # surfaced on the consumer side
            self._err = e
        finally:
            self._put(self._SENTINEL)

    def __iter__(self) -> Iterator:
        rec = self._rec
        t_wait = None   # set when the consumer starts waiting for an item
        while True:
            if rec.enabled and t_wait is None:
                t_wait = time.perf_counter()
            try:
                item = self._q.get(timeout=0.05)
            except queue.Empty:
                # a closed (or crashed-without-sentinel) producer enqueues
                # nothing more — an untimed get would hang the consumer
                if self._stop.is_set() or not self._thread.is_alive():
                    if self._err is not None:
                        raise self._err
                    return
                continue
            if item is self._SENTINEL:
                if self._err is not None:
                    raise self._err
                return
            if rec.enabled:
                rec.series("prefetch/starve_seconds",
                           time.perf_counter() - t_wait)
                t_wait = None
            yield item

    def close(self, timeout: float = 10.0) -> None:
        """Stop the producer and release it (drain-on-close). Safe to call
        from a consumer that broke out mid-stream; idempotent."""
        self._stop.set()
        deadline = time.monotonic() + timeout
        while self._thread.is_alive() and time.monotonic() < deadline:
            try:                     # unblock a producer stuck in put()
                self._q.get_nowait()
            except queue.Empty:
                pass
            self._thread.join(timeout=0.02)

    def __enter__(self) -> "PrefetchLoader":
        return self

    def __exit__(self, *exc) -> bool:
        self.close()
        return False


class BatchSource:
    """One handle over the whole ingestion pipeline, disk -> host -> mesh.

    Wraps ANY mini-batch iterable — a list (stride split of a resident
    dataset), a generator (block sampling over a live stream), dense [n, d]
    blocks or ``CSRBatch``es — behind one lifecycle:

    * ``skip(k)`` — drop the first k batches host-side before staging
      anything (checkpoint resume: the committed prefix is never paid for);
    * ``stage=`` + ``prefetch=`` — background-thread staging onto the mesh
      (see ``PrefetchLoader``); with ``prefetch=0`` the stage hook still
      runs, synchronously;
    * ``close()`` / context manager — releases the producer thread; the fit
      loops call it when they finish or fail, so a source is single-use.

    Constructors: ``from_dataset`` stride/block-splits a resident dense
    array or CSR dataset; ``from_stream`` re-chunks a ragged dense/CSR
    chunk stream (``repro.data.sampling.stream_blocks``).
    """

    def __init__(self, batches: Iterable, *, stage: Optional[Callable] = None,
                 prefetch: int = 0, skip: int = 0, recorder=None):
        from repro.obs import resolve
        self._batches = batches
        self._stage = stage
        self._prefetch = prefetch
        self._skip = skip
        self._rec = resolve(recorder)
        self._loader: Optional[PrefetchLoader] = None

    @classmethod
    def from_dataset(cls, x, n_batches: int, strategy: str = "stride",
                     **kw) -> "BatchSource":
        """Split a resident dataset (dense [n, d] or CSRBatch)."""
        from .sampling import split_batches
        from .sparse import is_sparse, split_csr
        if is_sparse(x):
            parts = split_csr(x, n_batches, strategy=strategy)
        else:
            parts = split_batches(np.asarray(x), n_batches, strategy=strategy)
        return cls(parts, **kw)

    @classmethod
    def from_stream(cls, chunks: Iterable, batch_size: int,
                    **kw) -> "BatchSource":
        """Re-chunk a ragged dense/CSR chunk stream into block batches."""
        from .sampling import stream_blocks
        return cls(stream_blocks(iter(chunks), batch_size), **kw)

    def skip(self, n_batches: int) -> "BatchSource":
        """Drop the first ``n_batches`` host-side (resume). Returns self."""
        self._skip += int(n_batches)
        return self

    def __iter__(self) -> Iterator:
        it = iter(self._batches)
        try:
            for _ in range(self._skip):
                next(it)
        except StopIteration:
            return
        if self._prefetch > 0:
            self.close()   # re-iteration must not orphan a live producer
            self._loader = PrefetchLoader(it, depth=self._prefetch,
                                          stage=self._stage,
                                          recorder=self._rec)
            yield from self._loader
        elif self._stage is not None:
            for k, b in enumerate(it):
                if self._rec.enabled:
                    t0 = time.perf_counter()
                    staged = self._stage(b)
                    self._rec.series("prefetch/stage_seconds",
                                     time.perf_counter() - t0, index=k,
                                     sync=True)
                    yield staged
                else:
                    yield self._stage(b)
        else:
            yield from it

    def close(self) -> None:
        if self._loader is not None:
            self._loader.close()
            self._loader = None

    def __enter__(self) -> "BatchSource":
        return self

    def __exit__(self, *exc) -> bool:
        self.close()
        return False
