"""Double-buffered mini-batch prefetcher — the TPU-native analogue of the
paper's producer/consumer offload scheme (§3.3, Fig.3).

On the paper's CPU+GPU node, a dedicated thread feeds the GPU so that
K^{i+1} is produced while the host consumes K^i. On TPU the kernel matrix is
produced by the same chip that consumes it, so the equivalent overlap is
host-side: a background thread stages batch i+1 (disk fetch, dtype cast,
device put) while the device iterates the inner loop on batch i. With
``jax.device_put`` the H2D copy overlaps compute exactly like the paper's
3-stage H2D/compute/D2H pipeline (Fig.3b) minus the D2H leg, which fusion
removed (DESIGN.md §2).
"""
from __future__ import annotations

import queue
import threading
from typing import Iterable, Iterator, Optional

import jax
import numpy as np


class PrefetchLoader:
    """Wrap a mini-batch iterable with ``depth`` batches of lookahead."""

    _SENTINEL = object()

    def __init__(self, batches: Iterable[np.ndarray], *, depth: int = 2,
                 device: Optional[jax.Device] = None, dtype=np.float32):
        self._src = iter(batches)
        self._q: queue.Queue = queue.Queue(maxsize=max(1, depth))
        self._device = device
        self._dtype = dtype
        self._err: Optional[BaseException] = None
        self._thread = threading.Thread(target=self._produce, daemon=True)
        self._thread.start()

    def _produce(self) -> None:
        try:
            for batch in self._src:
                arr = np.asarray(batch, dtype=self._dtype)
                staged = jax.device_put(arr, self._device)  # async H2D
                self._q.put(staged)
        except BaseException as e:  # surfaced on the consumer side
            self._err = e
        finally:
            self._q.put(self._SENTINEL)

    def __iter__(self) -> Iterator:
        while True:
            item = self._q.get()
            if item is self._SENTINEL:
                if self._err is not None:
                    raise self._err
                return
            yield item
