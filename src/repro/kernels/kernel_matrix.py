"""Pallas TPU kernel: tiled Mercer kernel (Gram) block evaluation.

The paper offloads kernel-matrix evaluation to the GPU (§3.3, Fig.3). The
TPU-native adaptation computes each (bm x bn) Gram tile on the MXU from
(bm x bd)/(bn x bd) VMEM-resident feature tiles, streaming the feature
dimension, and fuses the kernel epilogue (norm combine + exp / poly / cosine)
into the same kernel so HBM only ever sees X, Y, and K.

TPU grid: (M/bm, N/bn, D/bd), feature dim innermost (reduction). The fp32
accumulator lives in a VMEM scratch tile; the epilogue fires on the last
feature step. MXU alignment: the wrapper (ops.py) pads every tile dim to
multiples of 128 (rows may use 8; 16 under bf16 — the Mosaic min-tile
second-minor) and slices the result back. Feature tiles arrive in the
caller's tile dtype (kernels/precision.py: bf16 halves HBM traffic);
accumulation is always f32. GPU body: register-accumulator row panels
(kernels/backend.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .backend import gpu_compiler_params
from .compat import CompilerParams


def _epilogue(kind: str, acc, xsq, ysq, *, gamma, coef0, degree):
    if kind == "linear":
        return acc
    if kind == "polynomial":
        return (gamma * acc + coef0) ** degree
    if kind == "cosine":
        denom = jnp.sqrt(jnp.maximum(xsq, 0.0)) * jnp.sqrt(jnp.maximum(ysq, 0.0))
        return acc / jnp.maximum(denom, 1e-12)
    if kind == "rbf":
        d2 = jnp.maximum(xsq + ysq - 2.0 * acc, 0.0)
        return jnp.exp(-gamma * d2)
    raise ValueError(kind)


def _kernel(x_ref, y_ref, xsq_ref, ysq_ref, out_ref, acc_ref, *,
            kind: str, gamma: float, coef0: float, degree: int,
            n_feat_steps: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...]  # [bm, bd]
    y = y_ref[...]  # [bn, bd]
    acc_ref[...] += jax.lax.dot_general(
        x, y, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(k == n_feat_steps - 1)
    def _fin():
        xsq = xsq_ref[...].astype(jnp.float32)        # [bm, 1]
        ysq = ysq_ref[...].astype(jnp.float32)        # [bn, 1]
        out_ref[...] = _epilogue(kind, acc_ref[...], xsq, ysq.T,
                                 gamma=gamma, coef0=coef0, degree=degree)


def _kernel_gpu(x_ref, y_ref, xsq_ref, ysq_ref, out_ref, *,
                kind: str, gamma: float, coef0: float, degree: int):
    acc = jax.lax.dot_general(
        x_ref[...], y_ref[...], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)
    xsq = xsq_ref[...].astype(jnp.float32)
    ysq = ysq_ref[...].astype(jnp.float32)
    out_ref[...] = _epilogue(kind, acc, xsq, ysq.T,
                             gamma=gamma, coef0=coef0, degree=degree)


def kernel_matrix_pallas(x, y, xsq, ysq, *, kind: str = "rbf",
                         gamma: float = 1.0, coef0: float = 1.0,
                         degree: int = 3, bm: int = 256, bn: int = 256,
                         bd: int = 512, interpret: bool = False,
                         backend: str = "tpu"):
    """K(X, Y) on pre-padded inputs.

    x: [M, D], y: [N, D] (M % bm == N % bn == D % bd == 0, zero padded, in
    the caller's tile dtype), xsq/ysq: [M, 1]/[N, 1] f32 row squared norms
    of the *unpadded* features (zero padding keeps the dot exact; norms are
    computed by ops.py).
    """
    m, d = x.shape
    n = y.shape[0]
    if backend == "gpu":
        kernel = functools.partial(
            _kernel_gpu, kind=kind, gamma=gamma, coef0=coef0, degree=degree)
        return pl.pallas_call(
            kernel,
            grid=(m // bm,),
            in_specs=[
                pl.BlockSpec((bm, d), lambda i: (i, 0)),
                pl.BlockSpec((n, d), lambda i: (0, 0)),
                pl.BlockSpec((bm, 1), lambda i: (i, 0)),
                pl.BlockSpec((n, 1), lambda i: (0, 0)),
            ],
            out_specs=pl.BlockSpec((bm, n), lambda i: (i, 0)),
            out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
            interpret=interpret,
            **gpu_compiler_params(interpret=interpret),
        )(x, y, xsq, ysq)
    grid = (m // bm, n // bn, d // bd)
    kernel = functools.partial(
        _kernel, kind=kind, gamma=gamma, coef0=coef0, degree=degree,
        n_feat_steps=grid[2])
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bd), lambda i, j, k: (i, k)),
            pl.BlockSpec((bn, bd), lambda i, j, k: (j, k)),
            pl.BlockSpec((bm, 1), lambda i, j, k: (i, 0)),
            pl.BlockSpec((bn, 1), lambda i, j, k: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(x, y, xsq, ysq)
