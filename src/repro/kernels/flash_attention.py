"""Pallas TPU kernel: causal flash attention (online softmax, scores
VMEM-resident).

The §Perf cell-C analysis (EXPERIMENTS.md) attributes most of the dense
train/prefill memory term to the pure-JAX chunked attention writing
[cq, Sk] f32 score tensors to HBM. This kernel keeps the running max/sum
and the output accumulator in VMEM scratch, so HBM sees only Q, K, V and
the output — the same HBM-elision discipline as kernels/assign.py.

Grid: (B, H, Sq/bq, Sk/bk), key dim innermost (reduction). GQA is handled
in the BlockSpec index maps (kv head = h // (H/KH)) — K/V are never
repeated in memory. Causal masking skips fully-masked key blocks via
``pl.when`` (the compute for those blocks is elided, not just masked).

Reachability triage (mixed-precision PR): this kernel was flagged as
possibly dead — it is NOT. The live call chain is
``repro.models.attention`` (``attn_impl="flash"``) -> ``kernels.ops
.flash_attention`` -> ``flash_attention_pallas`` here, exercised by the
model smoke tests and the training launcher, and the RK003 dead-kernel
lint passes without a waiver. It therefore carries the full precision
policy: ``ops.flash_attention(precision=)`` casts Q/K/V to the tile
dtype, the softmax state (m, l) and the output accumulator stay f32
whatever the tiles are, and ``launch/audit.py`` includes this kernel in
the both-dtype ``check_precision`` sweep next to the clustering kernels.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .compat import CompilerParams

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, causal: bool, softcap: float | None,
            bq: int, bk: int, n_k_steps: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # causal: key block strictly after the query block -> nothing to do
    live = (qi + 1) * bq > ki * bk if causal else True

    @pl.when(live)
    def _step():
        q = q_ref[0, 0]                                # [bq, dh]
        k = k_ref[0, 0]                                # [bk, dh]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # [bq, bk]
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        if causal:
            qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)

        m_prev = m_ref[...]                            # [bq, 1]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)                         # [bq, bk]
        corr = jnp.exp(m_prev - m_new)                 # [bq, 1]
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v_ref[0, 0].astype(jnp.float32), (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == n_k_steps - 1)
    def _fin():
        o_ref[0, 0] = (acc_ref[...]
                    / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, causal: bool = True,
                           softcap: float | None = None,
                           bq: int = 128, bk: int = 128,
                           interpret: bool = False):
    """q: [B, H, Sq, dh]; k/v: [B, KH, Sk, dh] with H % KH == 0 (GQA).

    Pre-padded inputs: Sq % bq == Sk % bk == 0; dh MXU-aligned. fp32
    softmax state; output in q.dtype.
    """
    b, h, sq, dh = q.shape
    kh, sk = k.shape[1], k.shape[2]
    groups = h // kh
    grid = (b, h, sq // bq, sk // bk)
    kernel = functools.partial(
        _kernel, scale=dh ** -0.5, causal=causal, softcap=softcap,
        bq=bq, bk=bk, n_k_steps=grid[3])
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, dh), lambda b_, h_, i, j: (b_, h_, i, 0)),
            pl.BlockSpec((1, 1, bk, dh),
                         lambda b_, h_, i, j: (b_, h_ // groups, j, 0)),
            pl.BlockSpec((1, 1, bk, dh),
                         lambda b_, h_, i, j: (b_, h_ // groups, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, dh),
                               lambda b_, h_, i, j: (b_, h_, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),    # running max
            pltpu.VMEM((bq, 1), jnp.float32),    # running sum
            pltpu.VMEM((bq, dh), jnp.float32),   # output accumulator
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v)
