"""Version-compat shims for the Pallas TPU API surface.

The pinned JAX exposes the TPU compiler-params dataclass as
``pltpu.TPUCompilerParams``; newer releases renamed it to
``pltpu.CompilerParams``. Every kernel in this package imports the name from
here so the rename never breaks a pinned environment again.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or getattr(pltpu, "TPUCompilerParams")

__all__ = ["CompilerParams"]
