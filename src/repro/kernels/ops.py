"""jit'd public wrappers around the Pallas kernels.

Handle padding (MXU tile alignment), norm precomputation, block-size
selection against the VMEM budget, and CPU fallback (interpret mode runs the
kernel body in Python — correct but slow, so the wrappers default to the
pure-jnp oracle off-TPU unless forced for testing).

Precision policy (kernels/precision.py): every wrapper takes a jit-static
``precision`` ("f32" | "bf16"). The TILE operands — the arrays that stream
through VMEM/register tiles and feed the MXU — are cast ONCE here at wrapper
entry; squared norms are computed FROM the cast values so the kernels match
the ``ref.py`` oracles (which round the same way) to f32-accumulation
tolerance, not bf16 tolerance. Accumulators are always f32
(``preferred_element_type``), statically enforced by
``repro.analysis.check_precision``. Small panels (one-hots, compactness,
value panels, norms) stay f32; the sketch sign table stores as int8 under
bf16 (±1 is exact in every format).

Backend seam (kernels/backend.py): ``backend`` ("tpu" | "gpu") picks the
Mosaic grid/scratch body or the Triton register-accumulator body behind the
same wrapper; both run under ``interpret=True`` on CPU for CI.

This module is also the DISPATCH TABLE the static analyzer audits: every
``*_pallas`` wrapper defined under ``kernels/`` must be imported (reached)
from here or another module, or lint rule RK003 flags it as a dead kernel
(``python -m repro.analysis``) — and ``repro.analysis.audit`` checks that
the ``pallas_call`` these wrappers stage actually appears in the traced
program whenever an engine mode promises one (the bug class where a
"fused" mode silently fell back to jnp).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from . import ref
from .assign import assign_fused_pallas
from .embed_assign import embed_assign_pallas
from .flash_attention import flash_attention_pallas
from .kernel_matrix import kernel_matrix_pallas
from .precision import resolve_precision
from .sketch_assign import sketch_assign_pallas

Array = jax.Array

_VMEM_BUDGET = 96 * 1024 * 1024 // 8   # conservative half of 16 MB VMEM, fp32 words... see _pick_blocks


def _round_up(v: int, m: int) -> int:
    return -(-v // m) * m


def _pad2(a: Array, rows: int, cols: int) -> Array:
    pr, pc = rows - a.shape[0], cols - a.shape[1]
    if pr == 0 and pc == 0:
        return a
    return jnp.pad(a, ((0, pr), (0, pc)))


def _sqnorms(a: Array, n_pad: int) -> Array:
    s = jnp.sum(a.astype(jnp.float32) ** 2, axis=1, keepdims=True)
    return jnp.pad(s, ((0, n_pad - a.shape[0]), (0, 0)))


def _pick_blocks(m: int, n: int, d: int, c: int = 0, *,
                 itemsize: int = 4,
                 double_buffer: bool = False) -> tuple[int, int, int]:
    """Block shapes fitting the VMEM working set:
    x(bm*bd) + y(bn*bd) tile-dtype bytes (x2 when the kernel hand-double-
    buffers its slots) + acc(bm*bn) + f(bm*c) fp32 bytes <= ~8 MB.
    Rows round to the Mosaic min-tile second-minor for the tile dtype
    (8 for f32, 16 for bf16); lanes are always 128. Defaults favour
    MXU-shaped 256x256 tiles with the full feature panel."""
    row = 16 if itemsize < 4 else 8
    bm = min(256, _round_up(m, row))
    bn = min(256, _round_up(n, 128))
    bd = min(512, _round_up(d, 128))
    slots = 2 if double_buffer else 1
    tile_bytes = slots * itemsize * (bm * bd + bn * bd)
    acc_bytes = 4 * (bm * bn + bm * max(c, 0))
    while tile_bytes + acc_bytes > 8 * 1024 * 1024 and bd > 128:
        bd //= 2
        tile_bytes = slots * itemsize * (bm * bd + bn * bd)
    return bm, bn, bd


def use_pallas(mode: str = "auto") -> bool:
    if mode == "always":
        return True
    if mode == "never":
        return False
    # both Pallas lowerings count: Mosaic on TPU, Triton on GPU
    return jax.default_backend() in ("tpu", "gpu")


@partial(jax.jit, static_argnames=("kind", "gamma", "coef0", "degree",
                                   "interpret", "precision", "backend"))
def kernel_matrix(x: Array, y: Array, *, kind: str = "rbf", gamma: float = 1.0,
                  coef0: float = 1.0, degree: int = 3,
                  interpret: bool = True, precision: str = "f32",
                  backend: str = "tpu") -> Array:
    """K(X, Y) -> [m, n] fp32 via the Pallas kernel (padded + sliced)."""
    p = resolve_precision(precision)
    x, y = p.cast_tiles(x), p.cast_tiles(y)
    m, d = x.shape
    n = y.shape[0]
    bm, bn, bd = _pick_blocks(m, n, d, itemsize=p.tile_itemsize)
    mp, np_, dp = _round_up(m, bm), _round_up(n, bn), _round_up(d, bd)
    out = kernel_matrix_pallas(
        _pad2(x, mp, dp), _pad2(y, np_, dp),
        _sqnorms(x, mp), _sqnorms(y, np_),
        kind=kind, gamma=gamma, coef0=coef0, degree=degree,
        bm=bm, bn=bn, bd=bd, interpret=interpret, backend=backend)
    return out[:m, :n]


@partial(jax.jit, static_argnames=("kind", "gamma", "coef0", "degree",
                                   "n_clusters", "interpret", "precision",
                                   "backend", "double_buffer"))
def assign_fused(x: Array, landmarks: Array, labels_l: Array, counts: Array,
                 g: Array, *, n_clusters: int, kind: str = "rbf",
                 gamma: float = 1.0, coef0: float = 1.0, degree: int = 3,
                 interpret: bool = True, precision: str = "f32",
                 backend: str = "tpu",
                 double_buffer: bool = True) -> tuple[Array, Array, Array]:
    """Fused Eq.15/17: labels, mind = argmin/min_j (g_j - 2 (K @ H)_ij).

    Builds the normalized one-hot H from landmark labels + counts, pads the
    cluster dim to a 128 lane multiple with +BIG compactness so padded
    clusters are never selected, then calls the fused kernel. Also returns
    the normalized f panel [n, C] (Eq.17) so the Eq.7 medoid argmin can run
    off the fused path without ever materializing K.
    """
    p = resolve_precision(precision)
    x, landmarks = p.cast_tiles(x), p.cast_tiles(landmarks)
    m, d = x.shape
    lm = landmarks.shape[0]
    cp = _round_up(max(n_clusters, 128), 128)
    bm, bl, bd = _pick_blocks(m, lm, d, cp, itemsize=p.tile_itemsize,
                              double_buffer=double_buffer and backend == "tpu")
    mp, lp, dp = _round_up(m, bm), _round_up(lm, bl), _round_up(d, bd)

    h = jax.nn.one_hot(labels_l, n_clusters, dtype=jnp.float32)
    h = h / jnp.maximum(counts, 1.0)[None, :]
    h = _pad2(h, lp, cp)
    gp = jnp.full((1, cp), 1e30, jnp.float32).at[0, :n_clusters].set(
        jnp.where(counts > 0, g, 1e30))

    labels, mind, f = assign_fused_pallas(
        _pad2(x, mp, dp), _pad2(landmarks, lp, dp),
        _sqnorms(x, mp), _sqnorms(landmarks, lp),
        h, gp, kind=kind, gamma=gamma, coef0=coef0, degree=degree,
        bm=bm, bl=bl, bd=bd, interpret=interpret, backend=backend,
        double_buffer=double_buffer)
    return labels[:m, 0], mind[:m, 0], f[:m, :n_clusters]


@partial(jax.jit, static_argnames=("kind", "gamma", "coef0", "degree",
                                   "interpret", "precision", "backend",
                                   "double_buffer"))
def gram_matvec(x: Array, landmarks: Array, h: Array, *, kind: str = "rbf",
                gamma: float = 1.0, coef0: float = 1.0, degree: int = 3,
                interpret: bool = True, precision: str = "f32",
                backend: str = "tpu", double_buffer: bool = True) -> Array:
    """K(x, landmarks) @ h -> [n, C] fp32 without materializing K in HBM.

    The Gram-free contraction behind the GramEngine ``fused`` mode
    (repro.core.engine): each Gram tile is rebuilt in VMEM and immediately
    consumed against ``h`` (any [L, C] panel — typically a one-hot of the
    landmark labels), so only the O(n*C) result ever touches HBM. Reuses the
    fused assignment kernel with a dummy compactness row; the argmin outputs
    are dead code the scheduler overlaps with the DMA of f.
    """
    p = resolve_precision(precision)
    x, landmarks = p.cast_tiles(x), p.cast_tiles(landmarks)
    m, d = x.shape
    lm, c = landmarks.shape[0], h.shape[1]
    cp = _round_up(max(c, 128), 128)
    bm, bl, bd = _pick_blocks(m, lm, d, cp, itemsize=p.tile_itemsize,
                              double_buffer=double_buffer and backend == "tpu")
    mp, lp, dp = _round_up(m, bm), _round_up(lm, bl), _round_up(d, bd)
    _, _, f = assign_fused_pallas(
        _pad2(x, mp, dp), _pad2(landmarks, lp, dp),
        _sqnorms(x, mp), _sqnorms(landmarks, lp),
        _pad2(h.astype(jnp.float32), lp, cp),
        jnp.zeros((1, cp), jnp.float32),
        kind=kind, gamma=gamma, coef0=coef0, degree=degree,
        bm=bm, bl=bl, bd=bd, interpret=interpret, backend=backend,
        double_buffer=double_buffer)
    return f[:m, :c]


def embed_panels(fmap, centroids: Array, counts: Array | None = None):
    """Lower a feature map + centroids to the fused kernel's raw panels.

    Returns ``(w, aux, v, csq, statics)`` where statics is the dict of
    compile-time params (map_kind/gamma/coef0/degree/scale). Shared between
    the Pallas wrapper and the oracle-comparison tests.
    """
    from repro.approx.nystrom import NystromMap
    from repro.approx.rff import RFFMap

    c32, csq = _masked_csq(centroids, counts)
    if isinstance(fmap, RFFMap):
        statics = dict(map_kind="rff", gamma=1.0, coef0=1.0, degree=1,
                       scale=fmap.scale)
        return fmap.w, fmap.b[:, None], c32.T, csq, statics
    if isinstance(fmap, NystromMap):
        spec = fmap.spec
        statics = dict(map_kind=spec.name, gamma=spec.gamma,
                       coef0=spec.coef0, degree=spec.degree, scale=1.0)
        aux = jnp.sum(fmap.landmarks.astype(jnp.float32) ** 2, axis=1,
                      keepdims=True)
        return fmap.landmarks, aux, fmap.proj.astype(jnp.float32) @ c32.T, \
            csq, statics
    raise TypeError(f"unsupported feature map {type(fmap).__name__}")


@partial(jax.jit, static_argnames=("map_kind", "gamma", "coef0", "degree",
                                   "scale", "interpret", "precision",
                                   "backend"))
def _embed_assign_padded(x, w, aux, v, csq, *, map_kind, gamma, coef0,
                         degree, scale, interpret, precision="f32",
                         backend="tpu"):
    p = resolve_precision(precision)
    x, w = p.cast_tiles(x), p.cast_tiles(w)
    if map_kind != "rff":
        # Mercer epilogues need |w|^2 of the TILE values: recompute from the
        # cast landmarks so the epilogue's norm/dot terms cancel exactly the
        # way the oracle's do (aux from embed_panels is f32-derived).
        aux = jnp.sum(w.astype(jnp.float32) ** 2, axis=1, keepdims=True)
    n, d = x.shape
    m = w.shape[0]
    cp = _round_up(max(csq.shape[0], 128), 128)
    bm, bme, bd = _pick_blocks(n, m, d, cp, itemsize=p.tile_itemsize)
    np_, mp, dp = _round_up(n, bm), _round_up(m, bme), _round_up(d, bd)
    csq_p = jnp.full((1, cp), 1e30, jnp.float32).at[0, :csq.shape[0]].set(csq)
    labels, score = embed_assign_pallas(
        _pad2(x, np_, dp), _pad2(w, mp, dp), _sqnorms(x, np_),
        _pad2(aux, mp, 1), _pad2(v, mp, cp), csq_p,
        map_kind=map_kind, gamma=gamma, coef0=coef0, degree=degree,
        scale=scale, bm=bm, bme=bme, bd=bd, interpret=interpret,
        backend=backend)
    return labels[:n, 0], score[:n, 0]


@partial(jax.jit, static_argnames=("interpret", "precision", "backend"))
def _sketch_assign_padded(x, h, sign, v, csq, *, interpret, precision="f32",
                          backend="tpu"):
    p = resolve_precision(precision)
    x = p.cast_tiles(x)
    n, d = x.shape
    m = v.shape[0]
    cp = _round_up(max(csq.shape[0], 128), 128)
    bm, bme, bd = _pick_blocks(n, m, d, cp, itemsize=p.tile_itemsize)
    np_, mp, dp = _round_up(n, bm), _round_up(m, bme), _round_up(d, bd)
    # padded columns: h = -1 matches no bucket, sign/x = 0 keep the dot
    # exact. Sign storage follows the policy (int8 under bf16 — ±1 exact).
    h_p = jnp.full((dp, 1), -1, jnp.int32).at[:d, 0].set(h)
    sign_p = jnp.zeros((dp, 1), p.sign_dtype).at[:d, 0].set(
        sign.astype(p.sign_dtype))
    csq_p = jnp.full((1, cp), 1e30, jnp.float32).at[0, :csq.shape[0]].set(csq)
    labels, score = sketch_assign_pallas(
        _pad2(x, np_, dp), h_p, sign_p, _pad2(v, mp, cp), csq_p,
        bm=bm, bme=bme, bd=bd, interpret=interpret, backend=backend)
    return labels[:n, 0], score[:n, 0]


def _masked_csq(centroids: Array, counts: Array | None):
    c32 = centroids.astype(jnp.float32)
    csq = jnp.sum(c32 * c32, axis=1)
    if counts is not None:
        csq = jnp.where(counts > 0, csq, 1e30)
    return c32, csq


def sketch_assign(x: Array, fmap, centroids: Array,
                  counts: Array | None = None, *,
                  interpret: bool = True, precision: str = "f32",
                  backend: str = "tpu") -> tuple[Array, Array]:
    """Fused count-sketch + nearest-centroid assignment (dense rows).

    Same contract as ``embed_assign``; the sketch tile is built in VMEM from
    the O(d) hash/sign tables (see kernels/sketch_assign.py) so Z never
    materializes in HBM.
    """
    c32, csq = _masked_csq(centroids, counts)
    return _sketch_assign_padded(x, fmap.h, fmap.sign, c32.T, csq,
                                 interpret=interpret, precision=precision,
                                 backend=backend)


@jax.jit
def _embed_assign_jnp(z: Array, centroids: Array, csq: Array):
    f = jax.lax.dot_general(z, centroids.astype(jnp.float32),
                            (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    score = csq[None, :] - 2.0 * f
    return jnp.argmin(score, axis=1).astype(jnp.int32), jnp.min(score, axis=1)


def embed_assign(x: Array, fmap, centroids: Array,
                 counts: Array | None = None, *,
                 interpret: bool = True, precision: str = "f32",
                 backend: str = "tpu") -> tuple[Array, Array]:
    """Fused feature-map + nearest-centroid assignment.

    labels, score = argmin/min_j (|c_j|^2 - 2 phi_m(x_i).c_j); the embedded
    batch never materializes in HBM (see kernels/embed_assign.py). ``counts``
    masks empty clusters (+BIG) like the exact assignment path.

    Dispatch: RFF/Nystrom go through the projection-epilogue kernel,
    CountSketch through the scatter-add variant (kernels/sketch_assign.py).
    TensorSketch has no fused kernel — its FFT convolution does not lower to
    a Pallas tile epilogue — so it takes the documented jnp fallback:
    Z materializes ([n, m] HBM round-trip), flops are unchanged.
    """
    from repro.approx.sketch import CountSketchMap, TensorSketchMap

    if isinstance(fmap, CountSketchMap):
        return sketch_assign(x, fmap, centroids, counts, interpret=interpret,
                             precision=precision, backend=backend)
    if isinstance(fmap, TensorSketchMap):
        # no fused kernel (FFT conv) => no tile-dtype knob either; the jnp
        # fallback runs the documented f32 path whatever the policy says.
        c32, csq = _masked_csq(centroids, counts)
        return _embed_assign_jnp(fmap(x), c32, csq)
    w, aux, v, csq, statics = embed_panels(fmap, centroids, counts)
    return _embed_assign_padded(x, w, aux, v, csq, interpret=interpret,
                                precision=precision, backend=backend,
                                **statics)


@partial(jax.jit, static_argnames=("map_kind", "gamma", "coef0", "degree",
                                   "scale", "fused", "interpret",
                                   "precision", "backend"))
def predict_assign(x: Array, w: Array, aux: Array, v: Array, csq: Array, *,
                   map_kind: str = "rff", gamma: float = 1.0,
                   coef0: float = 1.0, degree: int = 3, scale: float = 1.0,
                   fused: bool = False, interpret: bool = True,
                   precision: str = "f32",
                   backend: str = "tpu") -> tuple[Array, Array]:
    """Serving hot path: frozen-panel embed+assign for one query bucket.

    The query-batch variant of ``embed_assign``/``sketch_assign``: instead
    of a live feature map + centroids it consumes the panels a
    ``repro.serving.artifact`` froze once at build time — ``w``/``aux``
    the feature-map tables (RFF frequencies + phases, Nystrom landmarks,
    or hash/sign for ``map_kind="sketch"``), ``v`` [m, C] the value panel
    (proj already folded in for Nystrom) and ``csq`` [C] the masked
    centroid norms — so a predict call derives NOTHING per request.

    ``fused=True`` dispatches the Pallas pass (Mosaic/Triton per
    ``backend``; the embedded query Z never touches HBM); ``fused=False``
    runs the jnp oracle math (``ref.predict_assign_ref``) — the documented
    off-accelerator path, one XLA program per bucket shape either way.
    Returns (labels [n] int32, score [n] f32). This function is the ONE
    jit entry of the serving bucket ladder: its ``_cache_size()`` is the
    compiled-program count the bucket audit pins to the ladder size.
    """
    if map_kind == "sketch":
        if fused:
            return _sketch_assign_padded(x, w, aux, v, csq,
                                         interpret=interpret,
                                         precision=precision,
                                         backend=backend)
        return ref.sketch_assign_ref(x, w, aux, v, csq, precision=precision)
    if fused:
        return _embed_assign_padded(x, w, aux, v, csq, map_kind=map_kind,
                                    gamma=gamma, coef0=coef0, degree=degree,
                                    scale=scale, interpret=interpret,
                                    precision=precision, backend=backend)
    return ref.predict_assign_ref(x, w, aux, v, csq, map_kind=map_kind,
                                  gamma=gamma, coef0=coef0, degree=degree,
                                  scale=scale, precision=precision)


# re-exported oracles so tests/benchmarks import one module
kernel_matrix_ref = ref.kernel_matrix_ref
assign_fused_ref = ref.assign_fused_ref
embed_assign_ref = ref.embed_assign_ref
sketch_assign_ref = ref.sketch_assign_ref
predict_assign_ref = ref.predict_assign_ref


@partial(jax.jit, static_argnames=("causal", "softcap", "interpret",
                                   "precision"))
def flash_attention(q: Array, k: Array, v: Array, *, causal: bool = True,
                    softcap: float | None = None,
                    interpret: bool = True,
                    precision: str = "f32") -> Array:
    """Flash attention via the Pallas kernel (pads Sq/Sk to block multiples,
    slices back). q: [B, H, Sq, dh]; k/v: [B, KH, Sk, dh]. The softmax state
    and both accumulators stay f32 whatever tile dtype ``precision`` picks;
    the output comes back in the tile dtype (q.dtype after the cast)."""
    p = resolve_precision(precision)
    q, k, v = p.cast_tiles(q), p.cast_tiles(k), p.cast_tiles(v)
    b, h, sq, dh = q.shape
    kh, sk = k.shape[1], k.shape[2]
    bq = min(128, _round_up(sq, 16 if p.tile_itemsize < 4 else 8))
    bk = min(128, _round_up(sk, 128))
    sqp, skp = _round_up(sq, bq), _round_up(sk, bk)
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, sqp - sq), (0, 0)))
    # padded KEYS must never win the softmax: pad K with zeros and mask via
    # causal (padded q rows are sliced off; padded k cols get score 0 which
    # the causal mask removes for causal=True; for non-causal we pad with
    # -inf via a large negative V trick -> instead simply require callers
    # to pass causal=True or aligned Sk).
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, skp - sk), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, skp - sk), (0, 0)))
    if not causal and skp != sk:
        raise ValueError("non-causal flash_attention requires Sk % 128 == 0")
    out = flash_attention_pallas(qp, kp, vp, causal=causal, softcap=softcap,
                                 bq=bq, bk=bk, interpret=interpret)
    return out[:, :, :sq]


flash_attention_ref = ref.flash_attention_ref
