"""Multi-backend seam: the same kernels lower to Mosaic, Triton, or the
interpreter.

Every kernel module in this package ships two bodies behind one wrapper:

  * ``backend="tpu"`` — the Mosaic lowering: 2-D/3-D grid with reduction
    dims, VMEM scratch accumulators, DMA double buffering where the module
    implements it, ``dimension_semantics`` compiler params. This is the
    production path and the one interpret-mode CI executes by default.
  * ``backend="gpu"`` — the Triton lowering: row-block grid only, no
    scratch refs (Triton Pallas has no TPU-style scratch allocator in the
    pinned jax), accumulators live as loop values in registers, the whole
    landmark/value panel is a per-program block — the communication-
    avoiding GPU kernel-k-means layout (Bellavita et al., PAPERS.md). The
    body is plain ``pl``/``jnp`` so it ALSO runs under ``interpret=True``
    on CPU: CI exercises the GPU body without a GPU, and
    ``launch/audit.py --gpu-trace`` dry-traces the non-interpret Triton
    staging (the ``pallas_call`` binds without lowering) so backend
    regressions surface without GPU runners.

``kernel_backend("auto")`` resolves the seam at trace time from
``jax.default_backend()`` — which ``launch/env.py``'s ``--platform`` flag
pins before the first jax import (snippet-style ``set_platform`` idiom).
CPU resolves to the TPU body in interpret mode: it is the reference
lowering and the one the oracles pin tightest.
"""
from __future__ import annotations

BACKENDS = ("tpu", "gpu")


def kernel_backend(backend: str = "auto") -> str:
    """Resolve a backend request to a kernel body: "tpu" | "gpu".

    "auto" follows ``jax.default_backend()``; CPU gets the TPU body (run
    in interpret mode by the wrappers' dispatch). Explicit names pass
    through so tests and the audit CLI can trace either lowering anywhere.
    """
    if backend in BACKENDS:
        return backend
    if backend != "auto":
        raise ValueError(
            f"backend must be 'auto' or one of {BACKENDS}, got {backend!r}")
    import jax
    native = jax.default_backend()
    return native if native in BACKENDS else "tpu"


def gpu_compiler_params(*, interpret: bool, num_warps: int = 4,
                        num_stages: int = 2):
    """TritonCompilerParams for the gpu body — omitted under interpret
    mode (the interpreter rejects backend-specific params)."""
    if interpret:
        return {}
    from jax.experimental.pallas import triton as plgpu
    return {"compiler_params": plgpu.TritonCompilerParams(
        num_warps=num_warps, num_stages=num_stages)}
