"""Pure-jnp oracles for the Pallas kernels (the correctness contract).

Every Pallas kernel in this package must ``assert_allclose`` against these
functions across the shape/dtype sweep in tests/test_pallas_kernels.py.

``precision`` mirrors the kernel layer's policy (kernels/precision.py):
the oracle rounds its tile operands to the tile dtype FIRST and then runs
all math in f32 — exactly the ``preferred_element_type=float32`` semantics
of the Pallas bodies (bf16 tiles, f32 accumulation). That keeps
pallas-vs-oracle comparisons tight at every precision; bf16-vs-f32 drift
is bounded separately by tests/test_precision.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def _tile(a: Array, precision: str) -> Array:
    """Round a tile operand to the policy's tile dtype, then lift to f32
    (the accumulate dtype) — the oracle-side image of a bf16 VMEM tile
    feeding an f32 MXU accumulator."""
    if precision == "bf16":
        a = a.astype(jnp.bfloat16)
    return a.astype(jnp.float32)


def kernel_matrix_ref(x: Array, y: Array, *, kind: str = "rbf",
                      gamma: float = 1.0, coef0: float = 1.0,
                      degree: int = 3, precision: str = "f32") -> Array:
    """K(X, Y) -> [m, n] fp32, fp32 accumulation over tile-dtype operands."""
    xf = _tile(x, precision)
    yf = _tile(y, precision)
    dot = xf @ yf.T
    if kind == "linear":
        return dot
    if kind == "polynomial":
        return (gamma * dot + coef0) ** degree
    if kind == "cosine":
        xn = jnp.sqrt(jnp.sum(xf * xf, axis=1))[:, None]
        yn = jnp.sqrt(jnp.sum(yf * yf, axis=1))[None, :]
        return dot / jnp.maximum(xn * yn, 1e-12)
    if kind == "rbf":
        d2 = (jnp.sum(xf * xf, axis=1)[:, None]
              + jnp.sum(yf * yf, axis=1)[None, :] - 2.0 * dot)
        return jnp.exp(-gamma * jnp.maximum(d2, 0.0))
    raise ValueError(f"unknown kernel kind {kind!r}")


def assign_fused_ref(x: Array, landmarks: Array, h_norm: Array, g: Array,
                     *, kind: str = "rbf", gamma: float = 1.0,
                     coef0: float = 1.0, degree: int = 3,
                     precision: str = "f32"):
    """Fused assignment oracle.

    x: [n, d] rows; landmarks: [L, d]; h_norm: [L, C] one-hot(labels)/counts;
    g: [C] cluster compactness (+BIG on empty/padded clusters).
    Returns (labels [n] int32, mind [n] f32, f [n, C] f32) where
      f = K(x, landmarks) @ h_norm         (Eq.17)
      labels = argmin_j g_j - 2 f_ij       (Eq.15)
    """
    k = kernel_matrix_ref(x, landmarks, kind=kind, gamma=gamma,
                          coef0=coef0, degree=degree, precision=precision)
    f = k @ h_norm.astype(jnp.float32)
    dist = g[None, :].astype(jnp.float32) - 2.0 * f
    return (jnp.argmin(dist, axis=1).astype(jnp.int32),
            jnp.min(dist, axis=1), f)


def embed_assign_ref(x: Array, w: Array, v: Array, csq: Array, *,
                     map_kind: str = "rff", gamma: float = 1.0,
                     coef0: float = 1.0, degree: int = 3,
                     scale: float = 1.0, b: Array | None = None,
                     precision: str = "f32"):
    """Fused embed+assign oracle (the kernel's correctness contract).

    x: [n, d] rows; w: [M, d] RFF frequencies (map_kind="rff", with phases
    ``b`` [M]) or Nystrom landmarks (map_kind = Mercer kind); v: [M, C]
    value panel (centroids^T for RFF, proj @ centroids^T for Nystrom);
    csq: [C] centroid squared norms (+BIG on masked clusters).
    Returns (labels [n] int32, score [n] f32) with
      z = phi_m(x)                               (never materialized on TPU)
      score_ij = |c_j|^2 - 2 z_i . c_j           (= ||z-c||^2 - ||z||^2)
      labels = argmin_j score_ij.
    """
    if map_kind == "rff":
        a = _tile(x, precision) @ _tile(w, precision).T
        e = scale * jnp.cos(a + b.astype(jnp.float32)[None, :])
    else:
        e = kernel_matrix_ref(x, w, kind=map_kind, gamma=gamma,
                              coef0=coef0, degree=degree,
                              precision=precision)
    f = e @ v.astype(jnp.float32)
    score = csq[None, :].astype(jnp.float32) - 2.0 * f
    return jnp.argmin(score, axis=1).astype(jnp.int32), jnp.min(score, axis=1)


def sketch_assign_ref(x: Array, h: Array, sign: Array, v: Array, csq: Array,
                      *, precision: str = "f32"):
    """Fused count-sketch + assign oracle (kernels/sketch_assign.py contract).

    x: [n, d] rows; h: [d] int32 bucket ids (-1 = padded column, lands
    nowhere); sign: [d] f32; v: [m, C] value panel (centroids^T); csq: [C]
    centroid squared norms (+BIG on masked clusters).
    Returns (labels [n] int32, score [n] f32) with
      z_j = sum_{i: h_i = j} sign_i * x_i         (never materialized on TPU)
      score_ij = |c_j|^2 - 2 z_i . c_j
      labels = argmin_j score_ij.
    """
    m = v.shape[0]
    s = jax.nn.one_hot(h, m, dtype=jnp.float32) * sign.astype(jnp.float32)[:, None]
    z = _tile(x, precision) @ s
    score = csq[None, :].astype(jnp.float32) - 2.0 * z @ v.astype(jnp.float32)
    return jnp.argmin(score, axis=1).astype(jnp.int32), jnp.min(score, axis=1)


def predict_assign_ref(x: Array, w: Array, aux: Array, v: Array, csq: Array,
                       *, map_kind: str = "rff", gamma: float = 1.0,
                       coef0: float = 1.0, degree: int = 3,
                       scale: float = 1.0, precision: str = "f32"):
    """Serving predict oracle (``ops.predict_assign`` contract).

    One query bucket against a FROZEN artifact's panels
    (``repro.serving.artifact``): ``w``/``aux`` are the feature-map tables
    — RFF frequencies [m, d] with phases ``aux`` [m, 1], Nystrom landmarks
    (``aux`` ignored; norms are recomputed from the tile-cast landmarks,
    matching ``_embed_assign_padded``), or for ``map_kind="sketch"`` the
    hash [d] int32 / sign [d] tables — and ``v`` [m, C] / ``csq`` [C] are
    the value panel and masked centroid norms frozen at artifact-build
    time. Returns (labels [n] int32, score [n] f32); scores drop the
    row-constant ``|z|^2`` so argmin equals the nearest-centroid label.
    """
    if map_kind == "sketch":
        return sketch_assign_ref(x, w, aux, v, csq, precision=precision)
    b = aux[:, 0] if map_kind == "rff" else None
    return embed_assign_ref(x, w, v, csq, map_kind=map_kind, gamma=gamma,
                            coef0=coef0, degree=degree, scale=scale, b=b,
                            precision=precision)


def flash_attention_ref(q: Array, k: Array, v: Array, *,
                        causal: bool = True,
                        softcap: float | None = None) -> Array:
    """Attention oracle. q: [B, H, Sq, dh]; k/v: [B, KH, Sk, dh] (GQA)."""
    b, h, sq, dh = q.shape
    kh, sk = k.shape[1], k.shape[2]
    groups = h // kh
    kx = jnp.repeat(k, groups, axis=1).astype(jnp.float32)
    vx = jnp.repeat(v, groups, axis=1).astype(jnp.float32)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kx) * dh ** -0.5
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    if causal:
        mask = jnp.arange(sq)[:, None] >= jnp.arange(sk)[None, :]
        s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vx).astype(q.dtype)
