"""Pallas TPU kernel: FUSED kernel-evaluation + masked-reduction + argmin.

This is the beyond-paper optimization of the inner-loop assignment step
(DESIGN.md §2): instead of materializing the mini-batch kernel block
K^i [rows x |L|] in HBM (the paper's producer/consumer hand-off) and then
reducing it against the label one-hot, a single kernel

  1. builds each (bm x bl) Gram tile in VMEM from feature tiles (MXU),
  2. immediately contracts it against the normalized one-hot H [bl x C]
     to accumulate f = K @ H (Eq.17),
  3. on the last landmark tile emits f and computes
     argmin_j (g_j - 2 f_ij) (Eq.15).

K never touches HBM: per-row traffic drops from O(|L|) Gram elements to
O(d + C), raising arithmetic intensity from ~1 FLOP/byte to ~|L| FLOPs/byte
(see EXPERIMENTS.md §Perf for the measured roofline shift).

The f panel [rows, Cp] IS written back (O(C) per row — negligible next to
the O(|L|) Gram block it replaces): the outer loop needs the cluster-average
similarities at the fixpoint for the Eq.7 medoid argmin, and the GramEngine
``fused`` mode (repro.core.engine) uses the same kernel as a Gram-free
matvec K @ H when only the stats — not the assignment — are wanted.

Grid: (rows/bm, L/bl, D/bd); landmark and feature dims are reductions.
Scratch: fp32 Gram-tile accumulator [bm, bl] + fp32 f accumulator [bm, Cp].
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .compat import CompilerParams
from .kernel_matrix import _epilogue


def _kernel(x_ref, l_ref, xsq_ref, lsq_ref, h_ref, g_ref,
            labels_ref, mind_ref, f_ref, acc_k_ref, acc_f_ref, *,
            kind: str, gamma: float, coef0: float, degree: int,
            n_lm_steps: int, n_feat_steps: int):
    li = pl.program_id(1)
    k = pl.program_id(2)

    @pl.when(jnp.logical_and(li == 0, k == 0))
    def _init_f():
        acc_f_ref[...] = jnp.zeros_like(acc_f_ref)

    @pl.when(k == 0)
    def _init_k():
        acc_k_ref[...] = jnp.zeros_like(acc_k_ref)

    acc_k_ref[...] += jax.lax.dot_general(
        x_ref[...], l_ref[...], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(k == n_feat_steps - 1)
    def _contract():
        xsq = xsq_ref[...].astype(jnp.float32)          # [bm, 1]
        lsq = lsq_ref[...].astype(jnp.float32)          # [bl, 1]
        kblk = _epilogue(kind, acc_k_ref[...], xsq, lsq.T,
                         gamma=gamma, coef0=coef0, degree=degree)
        acc_f_ref[...] += jax.lax.dot_general(
            kblk, h_ref[...].astype(jnp.float32), (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

        @pl.when(li == n_lm_steps - 1)
        def _argmin():
            f_ref[...] = acc_f_ref[...]
            dist = g_ref[...].astype(jnp.float32) - 2.0 * acc_f_ref[...]
            # tie-break contract: jnp.argmin returns the FIRST (lowest)
            # index of the minimum — identical to the jnp oracle path, so
            # engine choice never changes labels (repro.core.engine).
            labels_ref[...] = jnp.argmin(dist, axis=1, keepdims=True
                                         ).astype(jnp.int32)
            mind_ref[...] = jnp.min(dist, axis=1, keepdims=True)


def assign_fused_pallas(x, landmarks, xsq, lsq, h_norm, g, *,
                        kind: str = "rbf", gamma: float = 1.0,
                        coef0: float = 1.0, degree: int = 3,
                        bm: int = 256, bl: int = 256, bd: int = 512,
                        interpret: bool = False):
    """Fused Eq.15/17 assignment on pre-padded inputs.

    x: [n, D] rows, landmarks: [L, D], xsq/lsq: [n, 1]/[L, 1] squared norms,
    h_norm: [L, Cp] one-hot/counts (zero rows for padded landmarks),
    g: [1, Cp] compactness (+BIG on padded clusters).
    Returns (labels [n, 1] int32, mind [n, 1] f32, f [n, Cp] f32).
    """
    n, d = x.shape
    lm = landmarks.shape[0]
    cp = h_norm.shape[1]
    grid = (n // bm, lm // bl, d // bd)
    kernel = functools.partial(
        _kernel, kind=kind, gamma=gamma, coef0=coef0, degree=degree,
        n_lm_steps=grid[1], n_feat_steps=grid[2])
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bd), lambda i, j, k: (i, k)),   # x
            pl.BlockSpec((bl, bd), lambda i, j, k: (j, k)),   # landmarks
            pl.BlockSpec((bm, 1), lambda i, j, k: (i, 0)),    # xsq
            pl.BlockSpec((bl, 1), lambda i, j, k: (j, 0)),    # lsq
            pl.BlockSpec((bl, cp), lambda i, j, k: (j, 0)),   # h_norm
            pl.BlockSpec((1, cp), lambda i, j, k: (0, 0)),    # g
        ],
        out_specs=[
            pl.BlockSpec((bm, 1), lambda i, j, k: (i, 0)),
            pl.BlockSpec((bm, 1), lambda i, j, k: (i, 0)),
            pl.BlockSpec((bm, cp), lambda i, j, k: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, 1), jnp.int32),
            jax.ShapeDtypeStruct((n, 1), jnp.float32),
            jax.ShapeDtypeStruct((n, cp), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bm, bl), jnp.float32),
            pltpu.VMEM((bm, cp), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary", "arbitrary"),
        ),
        interpret=interpret,
    )(x, landmarks, xsq, lsq, h_norm, g)
