"""Pallas kernel: FUSED kernel-evaluation + masked-reduction + argmin.

This is the beyond-paper optimization of the inner-loop assignment step
(DESIGN.md §2): instead of materializing the mini-batch kernel block
K^i [rows x |L|] in HBM (the paper's producer/consumer hand-off) and then
reducing it against the label one-hot, a single kernel

  1. builds each (bm x bl) Gram tile in VMEM from feature tiles (MXU),
  2. immediately contracts it against the normalized one-hot H [bl x C]
     to accumulate f = K @ H (Eq.17),
  3. on the last landmark tile emits f and computes
     argmin_j (g_j - 2 f_ij) (Eq.15).

K never touches HBM: per-row traffic drops from O(|L|) Gram elements to
O(d + C), raising arithmetic intensity from ~1 FLOP/byte to ~|L| FLOPs/byte
(see EXPERIMENTS.md §Perf for the measured roofline shift).

The f panel [rows, Cp] IS written back (O(C) per row — negligible next to
the O(|L|) Gram block it replaces): the outer loop needs the cluster-average
similarities at the fixpoint for the Eq.7 medoid argmin, and the GramEngine
``fused`` mode (repro.core.engine) uses the same kernel as a Gram-free
matvec K @ H when only the stats — not the assignment — are wanted.

TPU body (``backend="tpu"``): grid (rows/bm, L/bl); the feature reduction
runs INSIDE the kernel over explicitly DMA'd (bm x bd)/(bl x bd) tiles with
TWO VMEM slots per operand — while chunk k feeds the MXU, the DMAs for
chunk k+1 are already in flight (``double_buffer``; PR 5's stated
leftover), so HBM tile loads overlap MXU compute instead of serializing
ahead of it. Tiles are moved in the caller's dtype — bf16 tiles halve the
DMA bytes and double the effective MXU rate — while the Gram accumulator
is a loop-carried f32 value and the f accumulator f32 VMEM scratch
(``preferred_element_type=float32`` on every dot; the kernels/precision.py
contract, statically enforced by ``repro.analysis.check_precision``).

GPU body (``backend="gpu"``): Triton has no TPU-style scratch allocator in
the pinned jax, so the row-block body holds the whole landmark panel per
program and accumulates in registers — the communication-avoiding GPU
kernel-k-means layout (see kernels/backend.py). Runs under interpret mode
on CPU for CI.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .backend import gpu_compiler_params
from .compat import CompilerParams
from .kernel_matrix import _epilogue


def _kernel(x_hbm, l_hbm, xsq_ref, lsq_ref, h_ref, g_ref,
            labels_ref, mind_ref, f_ref,
            xbuf, lbuf, sem_x, sem_l, acc_f_ref, *,
            kind: str, gamma: float, coef0: float, degree: int,
            n_lm_steps: int, n_feat_steps: int,
            bm: int, bl: int, bd: int, prefetch: bool):
    i = pl.program_id(0)
    li = pl.program_id(1)

    @pl.when(li == 0)
    def _init_f():
        acc_f_ref[...] = jnp.zeros_like(acc_f_ref)

    def x_dma(slot, k):
        return pltpu.make_async_copy(
            x_hbm.at[pl.ds(i * bm, bm), pl.ds(k * bd, bd)],
            xbuf.at[slot], sem_x.at[slot])

    def l_dma(slot, k):
        return pltpu.make_async_copy(
            l_hbm.at[pl.ds(li * bl, bl), pl.ds(k * bd, bd)],
            lbuf.at[slot], sem_l.at[slot])

    if prefetch:
        # warm-up: chunk 0 in flight before the loop; each iteration then
        # starts chunk k+1 into the other slot BEFORE waiting on chunk k,
        # so the MXU contraction of chunk k overlaps the HBM loads of k+1.
        x_dma(0, 0).start()
        l_dma(0, 0).start()

    def body(k, acc):
        slot = jax.lax.rem(k, 2)
        if prefetch:
            nxt = jax.lax.rem(k + 1, 2)

            @pl.when(k + 1 < n_feat_steps)
            def _ahead():
                x_dma(nxt, k + 1).start()
                l_dma(nxt, k + 1).start()
        else:
            x_dma(slot, k).start()
            l_dma(slot, k).start()
        x_dma(slot, k).wait()
        l_dma(slot, k).wait()
        return acc + jax.lax.dot_general(
            xbuf[slot], lbuf[slot], (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)

    acc_k = jax.lax.fori_loop(
        0, n_feat_steps, body, jnp.zeros((bm, bl), jnp.float32))

    xsq = xsq_ref[...].astype(jnp.float32)          # [bm, 1]
    lsq = lsq_ref[...].astype(jnp.float32)          # [bl, 1]
    kblk = _epilogue(kind, acc_k, xsq, lsq.T,
                     gamma=gamma, coef0=coef0, degree=degree)
    acc_f_ref[...] += jax.lax.dot_general(
        kblk, h_ref[...].astype(jnp.float32), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(li == n_lm_steps - 1)
    def _argmin():
        f_ref[...] = acc_f_ref[...]
        dist = g_ref[...].astype(jnp.float32) - 2.0 * acc_f_ref[...]
        # tie-break contract: jnp.argmin returns the FIRST (lowest)
        # index of the minimum — identical to the jnp oracle path, so
        # engine choice never changes labels (repro.core.engine).
        labels_ref[...] = jnp.argmin(dist, axis=1, keepdims=True
                                     ).astype(jnp.int32)
        mind_ref[...] = jnp.min(dist, axis=1, keepdims=True)


def _kernel_gpu(x_ref, l_ref, xsq_ref, lsq_ref, h_ref, g_ref,
                labels_ref, mind_ref, f_ref, *,
                kind: str, gamma: float, coef0: float, degree: int):
    acc = jax.lax.dot_general(
        x_ref[...], l_ref[...], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)
    xsq = xsq_ref[...].astype(jnp.float32)
    lsq = lsq_ref[...].astype(jnp.float32)
    kblk = _epilogue(kind, acc, xsq, lsq.T,
                     gamma=gamma, coef0=coef0, degree=degree)
    f = jax.lax.dot_general(
        kblk, h_ref[...].astype(jnp.float32), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    f_ref[...] = f
    dist = g_ref[...].astype(jnp.float32) - 2.0 * f
    labels_ref[...] = jnp.argmin(dist, axis=1, keepdims=True
                                 ).astype(jnp.int32)
    mind_ref[...] = jnp.min(dist, axis=1, keepdims=True)


def assign_fused_pallas(x, landmarks, xsq, lsq, h_norm, g, *,
                        kind: str = "rbf", gamma: float = 1.0,
                        coef0: float = 1.0, degree: int = 3,
                        bm: int = 256, bl: int = 256, bd: int = 512,
                        interpret: bool = False, backend: str = "tpu",
                        double_buffer: bool = True):
    """Fused Eq.15/17 assignment on pre-padded inputs.

    x: [n, D] rows, landmarks: [L, D] (both in the TILE dtype the caller's
    precision policy picked — f32 or bf16), xsq/lsq: [n, 1]/[L, 1] f32
    squared norms, h_norm: [L, Cp] f32 one-hot/counts (zero rows for padded
    landmarks), g: [1, Cp] f32 compactness (+BIG on padded clusters).
    Returns (labels [n, 1] int32, mind [n, 1] f32, f [n, Cp] f32).
    """
    n, d = x.shape
    lm = landmarks.shape[0]
    cp = h_norm.shape[1]
    out_specs_shapes = (
        [
            pl.BlockSpec((bm, 1), lambda *a: (a[0], 0)),
            pl.BlockSpec((bm, 1), lambda *a: (a[0], 0)),
            pl.BlockSpec((bm, cp), lambda *a: (a[0], 0)),
        ],
        [
            jax.ShapeDtypeStruct((n, 1), jnp.int32),
            jax.ShapeDtypeStruct((n, 1), jnp.float32),
            jax.ShapeDtypeStruct((n, cp), jnp.float32),
        ],
    )
    if backend == "gpu":
        kernel = functools.partial(
            _kernel_gpu, kind=kind, gamma=gamma, coef0=coef0, degree=degree)
        return pl.pallas_call(
            kernel,
            grid=(n // bm,),
            in_specs=[
                pl.BlockSpec((bm, d), lambda i: (i, 0)),     # x row panel
                pl.BlockSpec((lm, d), lambda i: (0, 0)),     # landmarks
                pl.BlockSpec((bm, 1), lambda i: (i, 0)),     # xsq
                pl.BlockSpec((lm, 1), lambda i: (0, 0)),     # lsq
                pl.BlockSpec((lm, cp), lambda i: (0, 0)),    # h_norm
                pl.BlockSpec((1, cp), lambda i: (0, 0)),     # g
            ],
            out_specs=out_specs_shapes[0],
            out_shape=out_specs_shapes[1],
            interpret=interpret,
            **gpu_compiler_params(interpret=interpret),
        )(x, landmarks, xsq, lsq, h_norm, g)

    grid = (n // bm, lm // bl)
    kernel = functools.partial(
        _kernel, kind=kind, gamma=gamma, coef0=coef0, degree=degree,
        n_lm_steps=grid[1], n_feat_steps=d // bd,
        bm=bm, bl=bl, bd=bd, prefetch=double_buffer)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            # x/landmarks stay HBM-resident (ANY): the kernel streams their
            # feature chunks through the double-buffered VMEM slots itself.
            pl.BlockSpec(memory_space=pltpu.ANY),             # x
            pl.BlockSpec(memory_space=pltpu.ANY),             # landmarks
            pl.BlockSpec((bm, 1), lambda i, j: (i, 0)),       # xsq
            pl.BlockSpec((bl, 1), lambda i, j: (j, 0)),       # lsq
            pl.BlockSpec((bl, cp), lambda i, j: (j, 0)),      # h_norm
            pl.BlockSpec((1, cp), lambda i, j: (0, 0)),       # g
        ],
        out_specs=[
            pl.BlockSpec((bm, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((bm, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((bm, cp), lambda i, j: (i, 0)),
        ],
        out_shape=out_specs_shapes[1],
        scratch_shapes=[
            pltpu.VMEM((2, bm, bd), x.dtype),     # x tile slots
            pltpu.VMEM((2, bl, bd), landmarks.dtype),  # landmark tile slots
            pltpu.SemaphoreType.DMA((2,)),
            pltpu.SemaphoreType.DMA((2,)),
            pltpu.VMEM((bm, cp), jnp.float32),    # f accumulator
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(x, landmarks, xsq, lsq, h_norm, g)
