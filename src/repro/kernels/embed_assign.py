"""Pallas TPU kernel: FUSED feature-map application + centroid argmin.

Embedded-space counterpart of ``kernels/assign.py`` (same VMEM-resident tile
pattern): instead of materializing the embedded batch Z = phi_m(X) [n, m] in
HBM and then running a linear-k-means assignment over it, a single kernel

  1. builds each (bm x bme) projection tile A = X W^T in VMEM (MXU),
     streaming the feature dim,
  2. applies the map epilogue in-register —
       * ``rff``:    E = scale * cos(A + b)           (random Fourier map)
       * Mercer kinds: E = epilogue(A, |x|^2, |l|^2)  (Nystrom: W = landmarks,
         the whitening projection is folded into V outside the kernel),
  3. immediately contracts E against the "value" panel V [m, Cp]
     (centroids^T for RFF, proj @ centroids^T for Nystrom) to accumulate
     the cross term F = Z C^T,
  4. on the last embed tile computes argmin_j (|c_j|^2 - 2 F_ij).

Z never touches HBM: per-row traffic is O(d + C) regardless of m. The
returned score is ||z - c_j||^2 - ||z||^2 (the row-constant ||z||^2 is
dropped — it cannot change the argmin and, for Nystrom, is not computable
without materializing Z).

TPU grid: (rows/bm, M/bme, D/bd); embed and feature dims are reductions.
Scratch: fp32 projection tile [bm, bme] + fp32 F accumulator [bm, Cp] —
accumulators stay f32 whatever the tile dtype (x/w may arrive bf16 under
the kernels/precision.py policy: half the HBM/VMEM per tile, f32 math).
The per-tile HBM loads are pipelined against the MXU by the Mosaic grid
machinery (BlockSpec index maps); the fused exact-assignment kernel
(kernels/assign.py) additionally hand-double-buffers its tiles.

GPU body (``backend="gpu"``): register-accumulator row-block variant, see
kernels/backend.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .backend import gpu_compiler_params
from .compat import CompilerParams
from .kernel_matrix import _epilogue


def _kernel(x_ref, w_ref, xsq_ref, aux_ref, v_ref, csq_ref,
            labels_ref, score_ref, acc_a_ref, acc_f_ref, *,
            map_kind: str, gamma: float, coef0: float, degree: int,
            scale: float, n_embed_steps: int, n_feat_steps: int):
    j = pl.program_id(1)
    k = pl.program_id(2)

    @pl.when(jnp.logical_and(j == 0, k == 0))
    def _init_f():
        acc_f_ref[...] = jnp.zeros_like(acc_f_ref)

    @pl.when(k == 0)
    def _init_a():
        acc_a_ref[...] = jnp.zeros_like(acc_a_ref)

    acc_a_ref[...] += jax.lax.dot_general(
        x_ref[...], w_ref[...], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(k == n_feat_steps - 1)
    def _contract():
        aux = aux_ref[...].astype(jnp.float32)          # [bme, 1]
        if map_kind == "rff":
            e = scale * jnp.cos(acc_a_ref[...] + aux.T)
        else:
            xsq = xsq_ref[...].astype(jnp.float32)      # [bm, 1]
            e = _epilogue(map_kind, acc_a_ref[...], xsq, aux.T,
                          gamma=gamma, coef0=coef0, degree=degree)
        acc_f_ref[...] += jax.lax.dot_general(
            e, v_ref[...].astype(jnp.float32), (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

        @pl.when(j == n_embed_steps - 1)
        def _argmin():
            score = csq_ref[...].astype(jnp.float32) - 2.0 * acc_f_ref[...]
            labels_ref[...] = jnp.argmin(score, axis=1, keepdims=True
                                         ).astype(jnp.int32)
            score_ref[...] = jnp.min(score, axis=1, keepdims=True)


def _kernel_gpu(x_ref, w_ref, xsq_ref, aux_ref, v_ref, csq_ref,
                labels_ref, score_ref, *,
                map_kind: str, gamma: float, coef0: float, degree: int,
                scale: float):
    a = jax.lax.dot_general(
        x_ref[...], w_ref[...], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)
    aux = aux_ref[...].astype(jnp.float32)
    if map_kind == "rff":
        e = scale * jnp.cos(a + aux.T)
    else:
        xsq = xsq_ref[...].astype(jnp.float32)
        e = _epilogue(map_kind, a, xsq, aux.T,
                      gamma=gamma, coef0=coef0, degree=degree)
    f = jax.lax.dot_general(
        e, v_ref[...].astype(jnp.float32), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    score = csq_ref[...].astype(jnp.float32) - 2.0 * f
    labels_ref[...] = jnp.argmin(score, axis=1, keepdims=True
                                 ).astype(jnp.int32)
    score_ref[...] = jnp.min(score, axis=1, keepdims=True)


def embed_assign_pallas(x, w, xsq, aux, v, csq, *,
                        map_kind: str = "rff", gamma: float = 1.0,
                        coef0: float = 1.0, degree: int = 3,
                        scale: float = 1.0,
                        bm: int = 256, bme: int = 256, bd: int = 512,
                        interpret: bool = False, backend: str = "tpu"):
    """Fused embed+assign on pre-padded inputs.

    x: [n, D] rows; w: [M, D] frequencies/landmarks; xsq: [n, 1] squared
    norms (Mercer epilogues); aux: [M, 1] phases (rff) or landmark squared
    norms (Mercer); v: [M, Cp] value panel (zero rows for padded embed dims);
    csq: [1, Cp] centroid squared norms (+BIG on padded clusters).
    Returns (labels [n, 1] int32, score [n, 1] f32 = min_j |c_j|^2 - 2 z.c_j).
    """
    n, d = x.shape
    m = w.shape[0]
    cp = v.shape[1]
    if backend == "gpu":
        kernel = functools.partial(
            _kernel_gpu, map_kind=map_kind, gamma=gamma, coef0=coef0,
            degree=degree, scale=scale)
        return pl.pallas_call(
            kernel,
            grid=(n // bm,),
            in_specs=[
                pl.BlockSpec((bm, d), lambda i: (i, 0)),    # x row panel
                pl.BlockSpec((m, d), lambda i: (0, 0)),     # w
                pl.BlockSpec((bm, 1), lambda i: (i, 0)),    # xsq
                pl.BlockSpec((m, 1), lambda i: (0, 0)),     # aux
                pl.BlockSpec((m, cp), lambda i: (0, 0)),    # v
                pl.BlockSpec((1, cp), lambda i: (0, 0)),    # csq
            ],
            out_specs=[
                pl.BlockSpec((bm, 1), lambda i: (i, 0)),
                pl.BlockSpec((bm, 1), lambda i: (i, 0)),
            ],
            out_shape=[
                jax.ShapeDtypeStruct((n, 1), jnp.int32),
                jax.ShapeDtypeStruct((n, 1), jnp.float32),
            ],
            interpret=interpret,
            **gpu_compiler_params(interpret=interpret),
        )(x, w, xsq, aux, v, csq)
    grid = (n // bm, m // bme, d // bd)
    kernel = functools.partial(
        _kernel, map_kind=map_kind, gamma=gamma, coef0=coef0, degree=degree,
        scale=scale, n_embed_steps=grid[1], n_feat_steps=grid[2])
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bd), lambda i, j, k: (i, k)),    # x
            pl.BlockSpec((bme, bd), lambda i, j, k: (j, k)),   # w
            pl.BlockSpec((bm, 1), lambda i, j, k: (i, 0)),     # xsq
            pl.BlockSpec((bme, 1), lambda i, j, k: (j, 0)),    # aux
            pl.BlockSpec((bme, cp), lambda i, j, k: (j, 0)),   # v
            pl.BlockSpec((1, cp), lambda i, j, k: (0, 0)),     # csq
        ],
        out_specs=[
            pl.BlockSpec((bm, 1), lambda i, j, k: (i, 0)),
            pl.BlockSpec((bm, 1), lambda i, j, k: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, 1), jnp.int32),
            jax.ShapeDtypeStruct((n, 1), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bm, bme), jnp.float32),
            pltpu.VMEM((bm, cp), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary", "arbitrary"),
        ),
        interpret=interpret,
    )(x, w, xsq, aux, v, csq)
