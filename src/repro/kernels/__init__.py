# TPU compute hot-spots of the paper (kernel-matrix evaluation — the part the
# paper offloads to the accelerator) as Pallas kernels, plus the beyond-paper
# fused assignment and the embedded-space fused embed+assign.
# ops.py = jit'd wrappers; ref.py = pure-jnp oracles; precision.py = the
# tile-dtype policy (f32/bf16 tiles, f32 accumulation); backend.py = the
# Mosaic/Triton lowering seam.
from .backend import kernel_backend
from .ops import (assign_fused, assign_fused_ref, embed_assign,
                  embed_assign_ref, gram_matvec, kernel_matrix,
                  kernel_matrix_ref, sketch_assign, sketch_assign_ref)
from .precision import BF16, F32, PRECISIONS, Precision, resolve_precision

__all__ = ["assign_fused", "assign_fused_ref", "embed_assign",
           "embed_assign_ref", "gram_matvec", "kernel_matrix",
           "kernel_matrix_ref", "sketch_assign", "sketch_assign_ref",
           "Precision", "PRECISIONS", "F32", "BF16", "resolve_precision",
           "kernel_backend"]
