# TPU compute hot-spots of the paper (kernel-matrix evaluation — the part the
# paper offloads to the accelerator) as Pallas kernels, plus the beyond-paper
# fused assignment and the embedded-space fused embed+assign.
# ops.py = jit'd wrappers; ref.py = pure-jnp oracles.
from .ops import (assign_fused, assign_fused_ref, embed_assign,
                  embed_assign_ref, gram_matvec, kernel_matrix,
                  kernel_matrix_ref, sketch_assign, sketch_assign_ref)

__all__ = ["assign_fused", "assign_fused_ref", "embed_assign",
           "embed_assign_ref", "gram_matvec", "kernel_matrix",
           "kernel_matrix_ref", "sketch_assign", "sketch_assign_ref"]
