"""The kernel layer's precision policy — bf16 tiles, f32 accumulation.

Every Pallas kernel in this package moves feature/landmark tiles from HBM
into VMEM and contracts them on the MXU. The *tile* dtype is a policy knob
(``Precision.tile``): bf16 tiles halve the HBM traffic and the VMEM bytes
per block — which doubles the feasible block area and the effective MXU
rate — while the *accumulator* dtype is NOT a knob: every ``dot_general``
in every kernel body carries ``preferred_element_type=float32`` and every
scratch accumulator is allocated f32. That invariant is enforced twice:

  * here, at config time — ``Precision(accum=...)`` rejects anything but
    ``"f32"`` so a low-precision accumulator is unrepresentable in config;
  * statically, at trace time — ``repro.analysis``'s ``check_precision``
    walks the ``pallas_call`` inner jaxprs and fails the audit on any
    in-kernel dot whose output dtype is not f32 (``launch/audit.py``).

The sketch path gets an extra integer policy: under bf16 the Rademacher
sign table is stored int8 (cast to the tile dtype in-kernel — ±1 is exact
in every float format), cutting the replicated O(d) table bytes 4x. The
hash table stays int32 regardless: bucket ids range over the embedding
dim m, which exceeds int8 long before sketching is worth doing.

Correctness contract: the jnp oracles (``kernels/ref.py``) take the same
``precision`` and round their inputs to the tile dtype before the f32
math, so pallas-vs-oracle comparisons stay tight at every precision, and
bf16-vs-f32 drift is bounded by the acceptance tests (labels identical on
separated fixtures, NMI drift <= 1e-3 otherwise — tests/test_precision.py).
"""
from __future__ import annotations

import dataclasses

PRECISIONS = ("f32", "bf16")

#: precision name -> numpy/jnp dtype name of the HBM/VMEM tiles.
_TILE_DTYPES = {"f32": "float32", "bf16": "bfloat16"}


@dataclasses.dataclass(frozen=True)
class Precision:
    """Hashable (jit-static) precision policy of the kernel layer.

    tile:  dtype of the feature/landmark/frequency tiles the kernels move
           through HBM and VMEM — "f32" | "bf16".
    accum: accumulator dtype — always "f32"; any other value raises
           (the point: silent low-precision accumulation cannot be
           configured, only written as a bug, which ``check_precision``
           then catches statically).
    """
    tile: str = "f32"
    accum: str = "f32"

    def __post_init__(self):
        if self.tile not in PRECISIONS:
            raise ValueError(
                f"tile precision must be one of {PRECISIONS}, "
                f"got {self.tile!r}")
        if self.accum != "f32":
            raise ValueError(
                "accumulation is always f32 in this kernel layer "
                f"(got accum={self.accum!r}); bf16 applies to tiles only")

    @property
    def tile_dtype(self):
        """The tile dtype as a jnp dtype (lazy jax import)."""
        import jax.numpy as jnp
        return jnp.dtype(_TILE_DTYPES[self.tile])

    @property
    def tile_itemsize(self) -> int:
        """Bytes per tile element — the planner's bytes-per-element knob."""
        return 4 if self.tile == "f32" else 2

    @property
    def sign_dtype(self):
        """Storage dtype of the count-sketch sign table: int8 under bf16
        (±1 is exact in any float format the kernel casts to), f32 at full
        precision for bit-compatibility with the pre-policy layout."""
        import jax.numpy as jnp
        return jnp.dtype("int8") if self.tile == "bf16" \
            else jnp.dtype("float32")

    def cast_tiles(self, a):
        """Round an array to the tile dtype (no-op at f32)."""
        return a if self.tile == "f32" else a.astype(self.tile_dtype)


F32 = Precision()
BF16 = Precision(tile="bf16")


def resolve_precision(precision) -> Precision:
    """Accept a Precision or a name ("f32" | "bf16" — the MiniBatchConfig /
    GramEngine currency) and return the policy."""
    if isinstance(precision, Precision):
        return precision
    if isinstance(precision, str) and precision in PRECISIONS:
        return BF16 if precision == "bf16" else F32
    raise ValueError(
        f"precision must be a Precision or one of {PRECISIONS}, "
        f"got {precision!r}")
