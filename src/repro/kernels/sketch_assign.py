"""Pallas TPU kernel: FUSED count-sketch application + centroid argmin.

Scatter-add variant of ``kernels/embed_assign.py`` for the hashing map
(``repro.approx.sketch.CountSketchMap``): the embedding is not a dense
projection ``X @ W^T`` but a signed scatter of input columns into m buckets,

    z(x)_j = sum_{i : h(i) = j} sign_i * x_i.

TPUs have no efficient cross-lane scatter, so the kernel realizes the
scatter-add as a masked one-hot contraction on the MXU: each (bd x bme)
sketch tile

    S[c, j] = sign_c * [h_c == j_global]

is *built in VMEM* from the O(d) integer tables (never materialized in HBM —
a dense S would be an [m, d] array, exactly the footprint sketching exists
to avoid) and contracted against the row tile, A += X_tile @ S. The rest is
the embed_assign pipeline: on the last feature step the finished embedding
tile E = A is contracted against the value panel V = centroids^T, and the
last embed step computes ``argmin_j |c_j|^2 - 2 z.c_j``. Z never touches
HBM; per-row traffic is O(d + C) regardless of m.

Padding contract: padded feature columns carry ``h = -1`` (matches no
bucket), padded embed dims are buckets >= m (matched by no column, value
rows zeroed), padded clusters carry ``csq = +BIG``.

Off-TPU the wrapper (ops.sketch_assign) runs this body in interpret mode
for tests; production CPU/GPU prediction should use the jnp fallback path
(``predict_embedded(..., use_fused=False)``, i.e. ``fmap(x)`` +
``assign_embedded``) which materializes Z but costs the same
O(n(d + mC)) flops.

Grid: (rows/bm, M/bme, D/bd); embed and feature dims are reductions.
Scratch: fp32 sketch-accumulator tile [bm, bme] + fp32 F accumulator
[bm, Cp].
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .backend import gpu_compiler_params
from .compat import CompilerParams


def _kernel(x_ref, h_ref, sign_ref, v_ref, csq_ref,
            labels_ref, score_ref, acc_a_ref, acc_f_ref, *,
            n_embed_steps: int, n_feat_steps: int, bme: int):
    j = pl.program_id(1)
    k = pl.program_id(2)

    @pl.when(jnp.logical_and(j == 0, k == 0))
    def _init_f():
        acc_f_ref[...] = jnp.zeros_like(acc_f_ref)

    @pl.when(k == 0)
    def _init_a():
        acc_a_ref[...] = jnp.zeros_like(acc_a_ref)

    xt = x_ref[...]                                  # [bm, bd] tile dtype
    h = h_ref[...]                                   # [bd, 1] int32
    # sign table storage is a precision-policy choice (int8 under bf16 —
    # ±1 is exact in every float format); the in-VMEM sketch tile is built
    # in the x tile dtype so the MXU contraction sees matched operands.
    sign = sign_ref[...].astype(xt.dtype)            # [bd, 1]
    bd = h.shape[0]
    lane = jax.lax.broadcasted_iota(jnp.int32, (bd, bme), 1) + j * bme
    s = jnp.where(h == lane, sign, jnp.zeros((), xt.dtype))
    acc_a_ref[...] += jax.lax.dot_general(
        xt, s, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(k == n_feat_steps - 1)
    def _contract():
        acc_f_ref[...] += jax.lax.dot_general(
            acc_a_ref[...], v_ref[...].astype(jnp.float32),
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

        @pl.when(j == n_embed_steps - 1)
        def _argmin():
            score = csq_ref[...].astype(jnp.float32) - 2.0 * acc_f_ref[...]
            labels_ref[...] = jnp.argmin(score, axis=1, keepdims=True
                                         ).astype(jnp.int32)
            score_ref[...] = jnp.min(score, axis=1, keepdims=True)


def _kernel_gpu(x_ref, h_ref, sign_ref, v_ref, csq_ref,
                labels_ref, score_ref, *, m: int):
    xt = x_ref[...]                                  # [bm, D]
    h = h_ref[...]                                   # [D, 1] int32
    sign = sign_ref[...].astype(xt.dtype)            # [D, 1]
    d = h.shape[0]
    lane = jax.lax.broadcasted_iota(jnp.int32, (d, m), 1)
    s = jnp.where(h == lane, sign, jnp.zeros((), xt.dtype))
    z = jax.lax.dot_general(xt, s, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    f = jax.lax.dot_general(z, v_ref[...].astype(jnp.float32),
                            (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    score = csq_ref[...].astype(jnp.float32) - 2.0 * f
    labels_ref[...] = jnp.argmin(score, axis=1, keepdims=True
                                 ).astype(jnp.int32)
    score_ref[...] = jnp.min(score, axis=1, keepdims=True)


def sketch_assign_pallas(x, h, sign, v, csq, *,
                         bm: int = 256, bme: int = 256, bd: int = 512,
                         interpret: bool = False, backend: str = "tpu"):
    """Fused count-sketch + assign on pre-padded inputs.

    x: [n, D] rows (tile dtype); h: [D, 1] int32 bucket ids (-1 on padded
    columns); sign: [D, 1] Rademacher signs — f32 at full precision, int8
    under the bf16 policy (0 on padding either way); v: [M, Cp] value panel
    (centroids^T, zero rows for padded embed dims); csq: [1, Cp] centroid
    squared norms (+BIG on padded clusters).
    Returns (labels [n, 1] int32, score [n, 1] f32 = min_j |c_j|^2 - 2 z.c_j).
    """
    n, d = x.shape
    m = v.shape[0]
    cp = v.shape[1]
    if backend == "gpu":
        return pl.pallas_call(
            functools.partial(_kernel_gpu, m=m),
            grid=(n // bm,),
            in_specs=[
                pl.BlockSpec((bm, d), lambda i: (i, 0)),    # x row panel
                pl.BlockSpec((d, 1), lambda i: (0, 0)),     # h
                pl.BlockSpec((d, 1), lambda i: (0, 0)),     # sign
                pl.BlockSpec((m, cp), lambda i: (0, 0)),    # v
                pl.BlockSpec((1, cp), lambda i: (0, 0)),    # csq
            ],
            out_specs=[
                pl.BlockSpec((bm, 1), lambda i: (i, 0)),
                pl.BlockSpec((bm, 1), lambda i: (i, 0)),
            ],
            out_shape=[
                jax.ShapeDtypeStruct((n, 1), jnp.int32),
                jax.ShapeDtypeStruct((n, 1), jnp.float32),
            ],
            interpret=interpret,
            **gpu_compiler_params(interpret=interpret),
        )(x, h, sign, v, csq)
    grid = (n // bm, m // bme, d // bd)
    kernel = functools.partial(
        _kernel, n_embed_steps=grid[1], n_feat_steps=grid[2], bme=bme)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bd), lambda i, j, k: (i, k)),    # x
            pl.BlockSpec((bd, 1), lambda i, j, k: (k, 0)),     # h
            pl.BlockSpec((bd, 1), lambda i, j, k: (k, 0)),     # sign
            pl.BlockSpec((bme, cp), lambda i, j, k: (j, 0)),   # v
            pl.BlockSpec((1, cp), lambda i, j, k: (0, 0)),     # csq
        ],
        out_specs=[
            pl.BlockSpec((bm, 1), lambda i, j, k: (i, 0)),
            pl.BlockSpec((bm, 1), lambda i, j, k: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, 1), jnp.int32),
            jax.ShapeDtypeStruct((n, 1), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bm, bme), jnp.float32),
            pltpu.VMEM((bm, cp), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary", "arbitrary"),
        ),
        interpret=interpret,
    )(x, h, sign, v, csq)
