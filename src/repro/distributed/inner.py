"""Distributed inner GD loop — the paper's Alg.1 on a JAX device mesh.

Faithful mapping (1-D, paper §3.3): mini-batch rows are sharded over the data
axes; every device owns its rows of K^i, f and its slice of U. One iteration
performs exactly the paper's two collectives:

    line 10:  allgather U            -> jax.lax.all_gather over the row axes
    line 13:  allreduce sum g        -> jax.lax.psum

The kernel block never crosses the network (it is computed and consumed
shard-locally), matching the paper's communication bound of
Q*(N/(B*P) + 2C) bytes per iteration.

Beyond-paper 2-D extension (DESIGN.md §2): the landmark (column) dimension is
additionally sharded over the ``model`` axis; f and g gain one ``psum`` over
``model`` (C floats per row-block — still tiny) while per-device kernel-block
memory drops from rows_p x |L| to rows_p x |L|/M. Setting mesh model axis = 1
recovers the faithful algorithm exactly.

WHERE the per-device Gram blocks live is the ``GramEngine`` contract
(repro.core.engine) — the same engine, and literally the same stats code
(``engine_stats``), as the single-host loop; this module only adds the psum
hooks. Per device and per inner iteration (rows_p = N/(B*D), L_m = |L|/M):

=============  =======================  ==================  ================
engine mode    peak HBM                 Gram FLOPs          when it wins
=============  =======================  ==================  ================
materialize    rows_p*L_m + rows_p*C    0 (built once per   many inner
               (K resident + f)         batch, amortized)   iterations
fused          rows_p*C (f only; K      rows_p*L_m*d +      HBM-bound, few
               tiles live in VMEM,      L_d*L_m*d rebuilt   iterations, TPU
               Pallas; jnp fallback     every iteration     (Pallas path)
               recomputes per iter)
tiled          bm*L_m + rows_p*C        same rebuild as     full block
               (one row panel at a      fused               exceeds HBM;
               time, portable jnp)                          s = 1 survives
=============  =======================  ==================  ================

materialize reads the resident block once per iteration (O(L_m) bytes/row);
fused raises arithmetic intensity to ~L_m FLOPs/byte by rebuilding the tile
in VMEM (O(d + C) bytes/row); tiled pays fused's FLOP bill at HBM-panel
granularity so it runs on any backend. The planner
(``repro.core.memory.plan``) prices all three against the memory budget and
names the pick as ``Plan.engine``; ``benchmarks/roofline.py`` measures the
trade.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.engine import (GramEngine, assign_from_stats, engine_stats,
                               resolve_engine)
from repro.core.kernels import KernelSpec

from .compat import shard_map

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class DistributedInnerConfig:
    n_clusters: int
    kernel: KernelSpec = KernelSpec("rbf", gamma=1.0)
    max_iters: int = 100
    # Gram residency: "materialize" | "fused" | "tiled" or a GramEngine.
    engine: object = "materialize"
    row_axes: tuple[str, ...] = ("data",)
    col_axis: str | None = "model"   # None -> faithful 1-D distribution


class DistInnerResult(NamedTuple):
    labels: Array      # [n] int32, row-sharded
    f: Array           # [n, C] f32, row-sharded
    g: Array           # [C] replicated
    counts: Array      # [C] replicated
    n_iter: Array
    cost: Array


def _body_factory(cfg: DistributedInnerConfig, x_local, lm_cols, lm_rows,
                  diag_local, l_idx_cols, l_idx_rows, wgt_local,
                  n_local_rows: int):
    """Builds the while_loop body for one device's shard."""
    spec = cfg.kernel
    row_axes, col_axis = cfg.row_axes, cfg.col_axis
    C = cfg.n_clusters
    engine = resolve_engine(cfg.engine)

    # per-batch Gram operators (paper lines 3 & 11-12 precompute): the
    # materialize engine evaluates and keeps the blocks here; fused/tiled
    # only record the features and rebuild tiles/panels inside each
    # iteration's matvec.
    op_xl = engine.prepare(spec, x_local, lm_cols)        # rows_p x L/M
    op_ll = engine.prepare(spec, lm_rows, lm_cols)        # L/D x L/M

    # the mesh's collectives, handed to the SHARED stats code as hooks —
    # each wrapped in a named profiler span (repro.obs.trace) so a device
    # trace attributes reduce time to the specific collective.
    def red_cols_fn(v):
        with jax.named_scope("obs:psum_cols"):
            return jax.lax.psum(v, col_axis)

    red_cols = red_cols_fn if col_axis is not None else None
    g_axes = row_axes if col_axis is None else (*row_axes, col_axis)

    def red_g(v):
        with jax.named_scope("obs:psum_g"):
            return jax.lax.psum(v, g_axes)

    def iterate(u_local):
        # paper line 10: allgather U (tiled -> [n]) over the row axes.
        with jax.named_scope("obs:allgather_u"):
            u_full = jax.lax.all_gather(u_local, row_axes, tiled=True)
        f, g, counts = engine_stats(
            engine, spec, op_xl, op_ll,
            jnp.take(u_full, l_idx_cols), jnp.take(u_full, l_idx_rows),
            C, reduce_counts=red_cols, reduce_f=red_cols, reduce_g=red_g)
        u_new, mind = assign_from_stats(f, g, counts)
        # ghost rows (wgt 0) replicate real rows to divide the mesh; they
        # follow their source row's label but must not inflate the cost.
        cost = jax.lax.psum(
            jnp.sum(wgt_local * (diag_local.astype(jnp.float32) + mind)),
            row_axes)
        return u_new, f, g, counts, cost

    def body(state):
        u, _, t, _ = state
        u_new, f, g, counts, cost = iterate(u)
        changed = jax.lax.psum(
            jnp.sum((u_new != u).astype(jnp.int32)), row_axes) > 0
        return u_new, changed, t + 1, cost

    def cond(state):
        _, changed, t, _ = state
        return jnp.logical_and(changed, t < cfg.max_iters)

    return body, cond, iterate


def collectives_per_iteration(cfg: DistributedInnerConfig) -> dict:
    """Analytic per-iteration collective bill of the inner while_loop body
    — the jit-safe way to count them: the traced program is static, so the
    flight recorder multiplies these constants by the returned ``n_iter``
    instead of instrumenting inside the loop (which would change the
    lowered program). Returns ``{"allgather": ..., "psum": ...,
    "psum_bytes": ...}`` per Lloyd iteration (psum_bytes: the g/counts/f
    reduce payloads, 4-byte floats, per device).
    """
    c = cfg.n_clusters
    psum = 2                                 # cost + convergence flag
    psum_bytes = 4 * (1 + 1)
    psum += 1                                # g over rows (+ columns)
    psum_bytes += 4 * c
    if cfg.col_axis is not None:
        psum += 2                            # counts + f over the model axis
        psum_bytes += 4 * 2 * c              # counts [C] + f rows (>= C)
    return {"allgather": 1, "psum": psum, "psum_bytes": psum_bytes}


def _inner_shard_fn(x_local, lm_cols, lm_rows, diag_local, l_idx_cols,
                    l_idx_rows, u0_local, wgt_local, *,
                    cfg: DistributedInnerConfig):
    body, cond, iterate = _body_factory(
        cfg, x_local, lm_cols, lm_rows, diag_local, l_idx_cols, l_idx_rows,
        wgt_local, x_local.shape[0])
    init = (u0_local.astype(jnp.int32), jnp.array(True),
            jnp.array(0, jnp.int32), jnp.array(jnp.inf, jnp.float32))
    u, _, t, cost = jax.lax.while_loop(cond, body, init)
    # final consistent stats at the fixpoint (as in the single-device path).
    _, f, g, counts, cost = iterate(u)
    return u, f, g, counts, t, cost


def distributed_kkmeans_fit(mesh: Mesh, x: Array, landmarks: Array,
                            l_idx: Array, diag_k: Array, u0: Array, *,
                            cfg: DistributedInnerConfig,
                            wgt: Array | None = None) -> DistInnerResult:
    """Run the distributed inner loop on ``mesh``.

    x:        [n, d]  mini-batch rows (sharded over row axes or replicated —
                      in_specs below enforce the row sharding).
    landmarks:[L, d]  landmark features (replicated input; the shard_map
                      slices it over the column axis internally).
    l_idx:    [L]     landmark indices into the mini-batch (replicated).
    diag_k:   [n]     K(x_i, x_i).
    u0:       [n]     initial labels.
    wgt:      [n]     optional row weights — 0 on the modulo-replicated
                      ghost rows that pad a non-divisible batch, so they
                      never count in the cost (default: all ones).
    """
    row_axes, col_axis = cfg.row_axes, cfg.col_axis
    d_size = 1
    for a in row_axes:
        d_size *= mesh.shape[a]
    m_size = mesh.shape[col_axis] if col_axis is not None else 1
    bad_n = x.shape[0] % d_size != 0
    bad_l = landmarks.shape[0] % d_size != 0 or landmarks.shape[0] % m_size != 0
    if bad_n or bad_l:
        raise ValueError(
            f"n={x.shape[0]} must divide row-axes size {d_size} and "
            f"|L|={landmarks.shape[0]} must divide both {d_size} and {m_size};"
            " round |L| up with num_landmarks(multiple_of=lcm(D, M))")

    rowspec = P(row_axes)
    colspec = P(col_axis) if col_axis is not None else P()
    if wgt is None:
        wgt = jnp.ones((x.shape[0],), jnp.float32)

    fn = partial(_inner_shard_fn, cfg=cfg)
    shard_fn = shard_map(
        fn, mesh=mesh,
        in_specs=(
            P(row_axes, None),    # x rows
            P(col_axis, None) if col_axis else P(None, None),  # lm cols
            P(row_axes, None),    # lm rows (for the K_ll block)
            P(row_axes),          # diag
            colspec,              # l_idx cols
            rowspec,              # l_idx rows
            rowspec,              # u0
            rowspec,              # wgt
        ),
        out_specs=(rowspec, P(row_axes, None), P(), P(), P(), P()),
        check_vma=False,
    )
    u, f, g, counts, t, cost = shard_fn(x, landmarks, landmarks, diag_k,
                                        l_idx, l_idx, u0, wgt)
    return DistInnerResult(u, f, g, counts, t, cost)
