"""Distributed inner GD loop — the paper's Alg.1 on a JAX device mesh,
restructured as an s-step communication-avoiding iteration.

Faithful mapping (1-D, paper §3.3): mini-batch rows are sharded over the data
axes; every device owns its rows of K^i, f and its slice of U. The paper's
two collectives (line 10 allgather U, line 13 allreduce g) are packed into
exactly ONE allgather + ONE psum per global sync:

    allgather: the new labels U
    psum:      one flat [C + 2] buffer over the row axes — the g partials
               with the local cost and convergence count appended (counts
               and f are local once U is gathered; g's row reduction,
               the cost sum and the changed flag all share the one psum)

The kernel block never crosses the network (computed and consumed
shard-locally), matching the paper's communication bound of
Q*(N/(B*P) + 2C) bytes per iteration.

Beyond-paper 2-D extension (DESIGN.md §2): the landmark (column) dimension
is additionally sharded over the ``model`` axis; per-device kernel-block
memory drops from rows_p x |L| to rows_p x |L|/M. Here the landmark-ROW
block K_ll is replicated over the row axes ([|L|, |L|/M] per device
instead of a D-way row shard — the planner prices the growth,
``core.memory.engine_footprint_bytes``), which makes g local-over-rows
after the label gather so counts/f/g share ONE flat [rows_p + 2, C] psum
over the model axis, while the cost/changed scalars ride the label
allgather bit-packed into the same int32 buffer. Still exactly
1 allgather + 1 psum per sync. Setting mesh model axis = 1 recovers the
faithful algorithm exactly.

s-step mode (``DistributedInnerConfig.s_step = s``, after the
communication-avoiding kernel k-means of Bellavita et al., PAPERS.md): each
while-loop body runs one globally-consistent assignment plus s-1 LOCAL
Lloyd refinements against the replicated landmark stats — each refinement
scatters this shard's fresh labels into its carried estimate of the global
label vector and re-derives the stats as (frozen remote partials + fresh
local partials) — before the single fused sync. The collective bill per
Lloyd iteration is therefore (1 allgather + 1 psum) / s.

2-D replica consistency under s-step: the refinements are column-local, so
model-axis replicas of the same row block refine against DIFFERENT stat
estimates (each owns a different landmark-column slice) and their labels
legitimately diverge between syncs. The sync therefore widens the label
allgather to the model axis and takes model shard 0's labels/cost/changed
as THE canonical refinement — every replica leaves each sync with
identical labels, the model-axis stats psum reduces partials of one
consistent label vector, and the replication promised by the row-only
out_specs holds. The algorithm is exactly "refine with model shard 0's
column freshness", deterministic whatever M; 1-D needs none of this (row
shards own disjoint rows — no replicas to disagree).

Communication bill per SYNC (one sync per while-loop body; divide by s for
the per-Lloyd-iteration bill; D = row-shard count, M = model-axis size,
rows_p = N/(B*D), C clusters, 4-byte scalars):

==============  =====================  ===================================
mesh layout     collectives per sync   payload bytes per sync (per device)
==============  =====================  ===================================
1-D (data)      1 allgather + 1 psum   allgather 4*N/B (labels);
                                       psum 4*(C + 2) (g + cost + changed)
2-D (+model)    1 allgather + 1 psum   allgather 4*(N/B + 2*D) (labels
                                       + packed cost/changed; x M when
                                       s > 1 — the canonicalizing gather
                                       spans the model axis too);
                                       psum 4*C*(rows_p + 2)
                                       (f block + counts + g, one flat
                                       concat over the model axis)
==============  =====================  ===================================

There is NO fixpoint epilogue: the loop body is pipelined — it assigns from
the stats the previous sync produced, then syncs the stats of the labels it
just wrote — so at exit the carried stats already describe the final
labels. The one collective pair outside the loop is the PROLOGUE sync that
seeds the carry from u0, so the audited outside-the-loop bill is also
exactly {allgather: 1, psum: 1} (``launch.audit`` proves both statically).

Cost semantics at exit: the returned cost is the one synced WITH the final
labels — each row's min-distance measured against the stats of the
PREVIOUS sync (the stats the assignment argmin'd over). On converged exits
this equals the cost of the final labels under their own stats (the labels
did not change, so the previous sync's stats are theirs); when the loop is
cut off by ``max_iters`` it is the pipelined, one-sync-stale cost — NOT
recomputed against the final stats, which would cost an extra epilogue
psum and break the audited outside-the-loop bill.

WHERE the per-device Gram blocks live is the ``GramEngine`` contract
(repro.core.engine) — the same engine, and literally the same stats code
(``engine_stats_raw``/``finalize_stats``), as the single-host loop; this
module only adds the fused collectives (one batched ``ReducePlan`` instead
of per-quantity psum hooks). Per device and per inner iteration
(rows_p = N/(B*D), L_m = |L|/M):

=============  =======================  ==================  ================
engine mode    peak HBM                 Gram FLOPs          when it wins
=============  =======================  ==================  ================
materialize    rows_p*L_m + rows_p*C    0 (built once per   many inner
               (K resident + f; 2-D     batch, amortized)   iterations
               adds the replicated
               |L|*L_m K_ll block)
fused          rows_p*C (f only; K      rows_p*L_m*d +      HBM-bound, few
               tiles live in VMEM,      |L|*L_m*d rebuilt   iterations, TPU
               Pallas; jnp fallback     every iteration     (Pallas path)
               recomputes per iter)
tiled          2*bm*L_m + rows_p*C      same rebuild as     full block
               (double-buffered row     fused               exceeds HBM;
               panels, portable jnp)                        s = 1 survives
=============  =======================  ==================  ================

materialize reads the resident block once per iteration (O(L_m) bytes/row);
fused raises arithmetic intensity to ~L_m FLOPs/byte by rebuilding the tile
in VMEM (O(d + C) bytes/row); tiled pays fused's FLOP bill at HBM-panel
granularity so it runs on any backend — its panels are double-buffered
(engine ``double_buffer``) so the panel build overlaps the contraction and
any in-flight collective. The planner (``repro.core.memory.plan``) prices
all three against the memory budget and names the pick as ``Plan.engine``;
``benchmarks/roofline.py`` and ``benchmarks/fig6_scaling.py`` measure the
trade.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.engine import (GramEngine, ReducePlan, assign_from_stats,
                               engine_stats_raw, finalize_stats,
                               resolve_engine)
from repro.core.kernels import KernelSpec

from .compat import shard_map

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class DistributedInnerConfig:
    n_clusters: int
    kernel: KernelSpec = KernelSpec("rbf", gamma=1.0)
    max_iters: int = 100
    # Gram residency: "materialize" | "fused" | "tiled" or a GramEngine.
    engine: object = "materialize"
    # tile-dtype policy (repro.kernels.precision): "f32" | "bf16". Applied
    # through the engine: feature shards and (under materialize) resident
    # Gram blocks move as bf16 tiles, all accumulation and every collective
    # payload (counts/f/g partials) stays f32 — reduction order across the
    # mesh never meets rounded operands.
    precision: str = "f32"
    row_axes: tuple[str, ...] = ("data",)
    col_axis: str | None = "model"   # None -> faithful 1-D distribution
    # communication-avoiding depth: Lloyd refinements per global sync.
    # s_step=1 is the fully-synchronous loop (bit-identical labels to the
    # pre-s-step engine); s_step>1 trades s-1 locally-stale refinements
    # for 1/s of the collective bill.
    s_step: int = 1

    def __post_init__(self):
        if self.s_step < 1:
            raise ValueError(f"s_step must be >= 1, got {self.s_step}")
        resolve_engine(self.engine, self.precision)   # validates both


class DistInnerResult(NamedTuple):
    labels: Array      # [n] int32, row-sharded
    f: Array           # [n, C] f32, row-sharded
    g: Array           # [C] replicated
    counts: Array      # [C] replicated
    n_iter: Array
    cost: Array


def _body_factory(cfg: DistributedInnerConfig, x_local, lm_cols, lm_rows,
                  diag_local, l_idx_cols, l_idx_rows, wgt_local,
                  n_local_rows: int, row_strides: tuple[int, ...],
                  d_size: int, m_size: int):
    """Builds the while_loop body, cond, and carry init/unpack for one
    device's shard. ``row_strides``/``d_size``/``m_size`` linearize this
    device's position along the row axes and size the model axis (static,
    from the mesh shape)."""
    spec = cfg.kernel
    row_axes, col_axis = cfg.row_axes, cfg.col_axis
    C = cfg.n_clusters
    s = cfg.s_step
    engine = resolve_engine(cfg.engine, cfg.precision)
    two_d = col_axis is not None

    # per-batch Gram operators (paper lines 3 & 11-12 precompute): the
    # materialize engine evaluates and keeps the blocks here; fused/tiled
    # only record the features and rebuild tiles/panels inside each
    # iteration's matvec. 1-D: lm_rows is this shard's row slice (as in
    # the paper); 2-D: lm_rows is the FULL landmark set (replicated over
    # the row axes) so g needs no row reduction of its own.
    op_xl = engine.prepare(spec, x_local, lm_cols)        # rows_p x L/M
    op_ll = engine.prepare(spec, lm_rows, lm_cols)        # (L/D | L) x L/M

    def local_stats(u_full):
        """Raw per-shard partials of the gathered labels: counts/f are
        local totals in 1-D and model-axis partials in 2-D; g is a
        row-axes partial in 1-D and a model-axis partial in 2-D."""
        return engine_stats_raw(
            engine, spec, op_xl, op_ll,
            jnp.take(u_full, l_idx_cols), jnp.take(u_full, l_idx_rows), C)

    # the mesh's ONE stats collective, handed to the SHARED engine
    # contract as a batched ReducePlan (2-D): counts/f/g ride a single
    # flat [rows_p + 2, C] psum over the model axis. 1-D needs no stats
    # psum beyond g, which shares the scalar psum inside sync().
    if two_d:
        def _fused_psum(counts_p, f_p, g_p):
            with jax.named_scope("obs:psum_fused"):
                flat = jnp.concatenate(
                    [f_p, counts_p[None, :], g_p[None, :]], axis=0)
                flat = jax.lax.psum(flat, col_axis)
            return flat[-2], flat[:-2], flat[-1]
        reduce_plan = ReducePlan(_fused_psum)

    if s > 1:
        # this shard's row-block offset in the global label vector (for
        # scattering refined labels into the carried u_full estimate, and
        # for slicing the canonical labels back out after a 2-D sync).
        row_off = jnp.int32(0)
        for a, stride in zip(row_axes, row_strides):
            row_off = row_off + jax.lax.axis_index(a) * stride
        row_off = row_off * n_local_rows

    def sync(u_local, cost_loc, changed_loc):
        """THE global sync: exactly 1 allgather + 1 psum, whatever the
        layout. Returns (u_loc, u_full, totals, locals, cost, changed)
        with u_loc this shard's canonical labels (== ``u_local`` except
        in 2-D s-step mode, see below), u_full the canonical global label
        vector and totals/locals its raw (un-normalized) stats payload."""
        if not two_d:
            # 1-D: gather labels; ONE [C + 2] psum over the row axes
            # carries the g partials plus the cost/changed scalars —
            # counts and f are already local totals. (No canonicalization
            # needed at any s: row shards own DISJOINT rows, so there are
            # no replicas whose refinements could disagree.)
            with jax.named_scope("obs:allgather_u"):
                u_full = jax.lax.all_gather(u_local, row_axes, tiled=True)
            counts_p, f_p, g_p = local_stats(u_full)
            with jax.named_scope("obs:psum_fused"):
                flat = jnp.concatenate([
                    g_p, jnp.stack([cost_loc,
                                    changed_loc.astype(jnp.float32)])])
                flat = jax.lax.psum(flat, row_axes)
            locs = (counts_p, f_p, g_p)
            totals = (counts_p, f_p, flat[:-2])
            return (u_local, u_full, totals, locs, flat[-2],
                    flat[-1].astype(jnp.int32))
        # 2-D: the cost/changed scalars ride the label gather (bitcast
        # into the same int32 buffer) so the row-axes reduction costs no
        # extra collective; counts/f/g then share one flat psum over the
        # model axis.
        packed = jnp.concatenate([
            u_local,
            jax.lax.bitcast_convert_type(cost_loc[None], jnp.int32),
            changed_loc[None]])
        if s > 1:
            # s-step refinements are collective-free and column-LOCAL, so
            # model-axis replicas of the same row block legitimately
            # arrive here with DIFFERENT refined labels (each refined
            # against its own landmark-column slice of the stats). The
            # label gather is widened to the model axis and model shard
            # 0's labels/cost/changed are taken as THE canonical
            # refinement, so every replica leaves the sync with identical
            # labels and the model-axis stats psum below reduces partials
            # of one consistent label vector — restoring the replication
            # the out_specs promise. Still exactly 1 allgather + 1 psum;
            # the gather payload grows by the model-axis factor M.
            with jax.named_scope("obs:allgather_u"):
                buf = jax.lax.all_gather(
                    packed, row_axes + (col_axis,), tiled=True)
            buf = buf.reshape(d_size, m_size, n_local_rows + 2)[:, 0]
        else:
            with jax.named_scope("obs:allgather_u"):
                buf = jax.lax.all_gather(packed, row_axes, tiled=True)
            buf = buf.reshape(d_size, n_local_rows + 2)
        u_full = buf[:, :n_local_rows].reshape(-1)
        cost = jnp.sum(jax.lax.bitcast_convert_type(
            buf[:, n_local_rows], jnp.float32))
        changed = jnp.sum(buf[:, n_local_rows + 1])
        u_loc = (jax.lax.dynamic_slice(u_full, (row_off,), (n_local_rows,))
                 if s > 1 else u_local)
        locs = local_stats(u_full)
        totals = reduce_plan(*locs)
        return u_loc, u_full, totals, locs, cost, changed

    def _rem(totals, locs):
        """Frozen remote contribution = reduced totals - own partials.
        1-D: counts/f are local totals (remote = 0, kept scalar); only g
        has a cross-shard remainder."""
        if two_d:
            return tuple(t - l for t, l in zip(totals, locs))
        return (jnp.float32(0), jnp.float32(0), totals[2] - locs[2])

    def body(state):
        if s > 1:
            u, u_full, totals, rem, t, _, _ = state
        else:
            u, totals, t, _, _ = state
        # pipelined assignment: argmin against the stats the LAST sync
        # produced (for the first body, the prologue's stats of u0) —
        # the same labels the pre-s-step loop produced at this t.
        f, g, counts = finalize_stats(*totals)
        u_new, mind = assign_from_stats(f, g, counts)
        if s > 1:
            for _ in range(s - 1):
                # local refinement: scatter our fresh labels into the
                # carried global estimate, re-derive stats as frozen
                # remote + fresh local partials — no collectives.
                u_full = jax.lax.dynamic_update_slice(
                    u_full, u_new, (row_off,))
                locs = local_stats(u_full)
                est = tuple(r + l for r, l in zip(rem, locs))
                f, g, counts = finalize_stats(*est)
                u_new, mind = assign_from_stats(f, g, counts)
        changed_loc = jnp.sum((u_new != u).astype(jnp.int32))
        # ghost rows (wgt 0) replicate real rows to divide the mesh; they
        # follow their source row's label but must not inflate the cost.
        cost_loc = jnp.sum(
            wgt_local * (diag_local.astype(jnp.float32) + mind))
        u2, u_full2, totals2, locs2, cost2, changed2 = sync(
            u_new, cost_loc, changed_loc)
        if s > 1:
            return (u2, u_full2, totals2, _rem(totals2, locs2),
                    t + 1, cost2, changed2 > 0)
        return u2, totals2, t + 1, cost2, changed2 > 0

    def cond(state):
        changed, t = state[-1], state[-3]
        return jnp.logical_and(changed, t < cfg.max_iters)

    def init(u0_local):
        # PROLOGUE sync: seed the carry with the stats of u0 (dummy
        # cost/changed — overwritten by the first body's sync). This is
        # the only collective pair outside the while loop.
        u0 = u0_local.astype(jnp.int32)
        u0c, u_full0, totals0, locs0, _, _ = sync(
            u0, jnp.float32(0.0), jnp.int32(0))
        t0 = jnp.array(0, jnp.int32)
        cost0 = jnp.array(jnp.inf, jnp.float32)
        if s > 1:
            return (u0c, u_full0, totals0, _rem(totals0, locs0),
                    t0, cost0, jnp.array(True))
        return u0c, totals0, t0, cost0, jnp.array(True)

    def unpack(state):
        if s > 1:
            u, _, totals, _, t, cost, _ = state
        else:
            u, totals, t, cost, _ = state
        return u, totals, t, cost

    return body, cond, init, unpack


def collectives_per_iteration(cfg: DistributedInnerConfig,
                              n_local_rows: int | None = None) -> dict:
    """Analytic per-SYNC collective bill of the inner while_loop body —
    the jit-safe way to count them: the traced program is static, so the
    flight recorder multiplies these constants by the returned ``n_iter``
    instead of instrumenting inside the loop (which would change the
    lowered program). One sync per body; with ``cfg.s_step = s`` a body
    covers s Lloyd refinements, so the per-Lloyd-iteration bill is this
    divided by s. Returns ``{"allgather": 1, "psum": 1, "psum_bytes":
    ...}`` — the fused-payload contract ``launch.audit`` proves
    statically. ``psum_bytes`` is the per-device psum payload: the flat
    [C + 2] g/cost/changed buffer in 1-D, the flat [rows_p + 2, C]
    counts/f/g concat in 2-D (``n_local_rows`` = rows_p; defaults to C
    as a conservative floor when the shard shape is unknown).
    """
    c = cfg.n_clusters
    if cfg.col_axis is None:
        psum_bytes = 4 * (c + 2)
    else:
        rows = c if n_local_rows is None else n_local_rows
        psum_bytes = 4 * c * (rows + 2)
    return {"allgather": 1, "psum": 1, "psum_bytes": psum_bytes}


def _inner_shard_fn(x_local, lm_cols, lm_rows, diag_local, l_idx_cols,
                    l_idx_rows, u0_local, wgt_local, *,
                    cfg: DistributedInnerConfig,
                    row_strides: tuple[int, ...], d_size: int, m_size: int):
    body, cond, init, unpack = _body_factory(
        cfg, x_local, lm_cols, lm_rows, diag_local, l_idx_cols, l_idx_rows,
        wgt_local, x_local.shape[0], row_strides, d_size, m_size)
    state = jax.lax.while_loop(cond, body, init(u0_local))
    # NO fixpoint epilogue: the body syncs the stats of the labels it just
    # wrote, so at exit the carry already holds the final labels' stats
    # (and the cost of the assignment that produced them).
    u, totals, t, cost = unpack(state)
    f, g, counts = finalize_stats(*totals)
    return u, f, g, counts, t, cost


def distributed_kkmeans_fit(mesh: Mesh, x: Array, landmarks: Array,
                            l_idx: Array, diag_k: Array, u0: Array, *,
                            cfg: DistributedInnerConfig,
                            wgt: Array | None = None) -> DistInnerResult:
    """Run the distributed inner loop on ``mesh``.

    x:        [n, d]  mini-batch rows (sharded over row axes or replicated —
                      in_specs below enforce the row sharding).
    landmarks:[L, d]  landmark features (replicated input; the shard_map
                      slices it over the column axis internally; the row
                      side K_ll is row-sharded in 1-D, replicated in 2-D —
                      see module docstring).
    l_idx:    [L]     landmark indices into the mini-batch (replicated).
    diag_k:   [n]     K(x_i, x_i).
    u0:       [n]     initial labels.
    wgt:      [n]     optional row weights — 0 on the modulo-replicated
                      ghost rows that pad a non-divisible batch, so they
                      never count in the cost (default: all ones).
    """
    row_axes, col_axis = cfg.row_axes, cfg.col_axis
    d_size = 1
    for a in row_axes:
        d_size *= mesh.shape[a]
    m_size = mesh.shape[col_axis] if col_axis is not None else 1
    bad_n = x.shape[0] % d_size != 0
    bad_l = landmarks.shape[0] % d_size != 0 or landmarks.shape[0] % m_size != 0
    if bad_n or bad_l:
        raise ValueError(
            f"n={x.shape[0]} must divide row-axes size {d_size} and "
            f"|L|={landmarks.shape[0]} must divide both {d_size} and {m_size};"
            " round |L| up with num_landmarks(multiple_of=lcm(D, M))")

    # static row-major strides of the row axes (shard i of axis a starts
    # at axis_index(a) * stride(a) row blocks into the gathered vector —
    # the same order jax.lax.all_gather(..., tiled=True) concatenates).
    strides = []
    acc = 1
    for a in reversed(row_axes):
        strides.append(acc)
        acc *= mesh.shape[a]
    row_strides = tuple(reversed(strides))

    rowspec = P(row_axes)
    colspec = P(col_axis) if col_axis is not None else P()
    if wgt is None:
        wgt = jnp.ones((x.shape[0],), jnp.float32)

    fn = partial(_inner_shard_fn, cfg=cfg, row_strides=row_strides,
                 d_size=d_size, m_size=m_size)
    shard_fn = shard_map(
        fn, mesh=mesh,
        in_specs=(
            P(row_axes, None),    # x rows
            P(col_axis, None) if col_axis else P(None, None),  # lm cols
            # lm rows: the 1-D K_ll block is row-sharded (the paper's
            # layout); 2-D replicates it over the row axes so g is local
            # after the gather and can join the fused stats psum.
            P(row_axes, None) if col_axis is None else P(None, None),
            P(row_axes),          # diag
            colspec,              # l_idx cols
            rowspec if col_axis is None else P(),  # l_idx rows
            rowspec,              # u0
            rowspec,              # wgt
        ),
        out_specs=(rowspec, P(row_axes, None), P(), P(), P(), P()),
        check_vma=False,
    )
    u, f, g, counts, t, cost = shard_fn(x, landmarks, landmarks, diag_k,
                                        l_idx, l_idx, u0, wgt)
    return DistInnerResult(u, f, g, counts, t, cost)
