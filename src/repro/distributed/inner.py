"""Distributed inner GD loop — the paper's Alg.1 on a JAX device mesh.

Faithful mapping (1-D, paper §3.3): mini-batch rows are sharded over the data
axes; every device owns its rows of K^i, f and its slice of U. One iteration
performs exactly the paper's two collectives:

    line 10:  allgather U            -> jax.lax.all_gather over the row axes
    line 13:  allreduce sum g        -> jax.lax.psum

The kernel block never crosses the network (it is computed and consumed
shard-locally), matching the paper's communication bound of
Q*(N/(B*P) + 2C) bytes per iteration.

Beyond-paper 2-D extension (DESIGN.md §2): the landmark (column) dimension is
additionally sharded over the ``model`` axis; f and g gain one ``psum`` over
``model`` (C floats per row-block — still tiny) while per-device kernel-block
memory drops from rows_p x |L| to rows_p x |L|/M, which is what lets ``s = 1``
survive on big mini-batches. Setting mesh model axis = 1 recovers the faithful
algorithm exactly.

Two compute modes:
  * ``materialize`` — the paper's layout: K^i(p) computed once per batch,
    resident in device memory, consumed by every inner iteration.
  * ``fused``       — the Pallas-fused path (repro.kernels.assign): the Gram
    tile is rebuilt in VMEM per iteration and never hits HBM. More FLOPs,
    ~|L|x less HBM traffic per iteration; the §Perf tables quantify when each
    wins (few inner iterations -> fused, many -> materialize).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.kernels import KernelSpec
from repro.core.kkmeans import BIG

from .compat import shard_map

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class DistributedInnerConfig:
    n_clusters: int
    kernel: KernelSpec = KernelSpec("rbf", gamma=1.0)
    max_iters: int = 100
    mode: str = "materialize"        # "materialize" | "fused"
    row_axes: tuple[str, ...] = ("data",)
    col_axis: str | None = "model"   # None -> faithful 1-D distribution


class DistInnerResult(NamedTuple):
    labels: Array      # [n] int32, row-sharded
    f: Array           # [n, C] f32, row-sharded
    g: Array           # [C] replicated
    counts: Array      # [C] replicated
    n_iter: Array
    cost: Array


def _one_hot_stats(k_rows_cols, k_ll_rows_cols, labels_l_cols, labels_l_rows,
                   n_clusters: int, col_axis, row_axes):
    """f, g, counts with rows sharded over row_axes, landmark cols over
    col_axis (both possibly trivial). All reductions fp32."""
    h_cols = jax.nn.one_hot(labels_l_cols, n_clusters, dtype=jnp.float32)
    counts = jnp.sum(h_cols, axis=0)
    if col_axis is not None:
        counts = jax.lax.psum(counts, col_axis)              # [C]
    safe = jnp.maximum(counts, 1.0)

    f = jnp.dot(k_rows_cols.astype(jnp.float32), h_cols)     # [rows_p, C]
    if col_axis is not None:
        f = jax.lax.psum(f, col_axis)
    f = f / safe[None, :]

    # g via the (L/D x L/M) block of K_ll: diag_j of h_rows^T K h_cols.
    h_rows = jax.nn.one_hot(labels_l_rows, n_clusters, dtype=jnp.float32)
    t = jax.lax.dot_general(k_ll_rows_cols.astype(jnp.float32), h_cols,
                            (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)  # [Ld, C]
    g = jnp.sum(h_rows * t, axis=0)
    g = jax.lax.psum(g, row_axes if col_axis is None else (*row_axes, col_axis))
    g = g / (safe * safe)
    return f, g, counts


def _body_factory(cfg: DistributedInnerConfig, x_local, lm_cols, lm_rows,
                  diag_local, l_idx_cols, l_idx_rows, wgt_local,
                  n_local_rows: int):
    """Builds the while_loop body for one device's shard."""
    spec = cfg.kernel
    row_axes, col_axis = cfg.row_axes, cfg.col_axis
    C = cfg.n_clusters

    # loop-invariant kernel blocks (paper lines 3 & 11-12 precompute).
    if cfg.mode == "materialize":
        k_block = spec(x_local, lm_cols)           # [rows_p, L/M] resident
    k_ll_block = spec(lm_rows, lm_cols)            # [L/D, L/M]

    def gram_block():
        if cfg.mode == "materialize":
            return k_block
        # fused: recompute per iteration (VMEM-resident on TPU via Pallas;
        # portable jnp path otherwise — same math, same shapes).
        return spec(x_local, lm_cols)

    def iterate(u_local):
        # paper line 10: allgather U (tiled -> [n]) over the row axes.
        u_full = jax.lax.all_gather(u_local, row_axes, tiled=True)
        labels_l_cols = jnp.take(u_full, l_idx_cols)
        labels_l_rows = jnp.take(u_full, l_idx_rows)
        f, g, counts = _one_hot_stats(gram_block(), k_ll_block,
                                      labels_l_cols, labels_l_rows,
                                      C, col_axis, row_axes)
        dist = jnp.where(counts[None, :] > 0, g[None, :] - 2.0 * f, BIG)
        u_new = jnp.argmin(dist, axis=1).astype(jnp.int32)
        mind = jnp.min(dist, axis=1)
        # ghost rows (wgt 0) replicate real rows to divide the mesh; they
        # follow their source row's label but must not inflate the cost.
        cost = jax.lax.psum(
            jnp.sum(wgt_local * (diag_local.astype(jnp.float32) + mind)),
            row_axes)
        return u_new, f, g, counts, cost

    def body(state):
        u, _, t, _ = state
        u_new, f, g, counts, cost = iterate(u)
        changed = jax.lax.psum(
            jnp.sum((u_new != u).astype(jnp.int32)), row_axes) > 0
        return u_new, changed, t + 1, cost

    def cond(state):
        _, changed, t, _ = state
        return jnp.logical_and(changed, t < cfg.max_iters)

    return body, cond, iterate


def _inner_shard_fn(x_local, lm_cols, lm_rows, diag_local, l_idx_cols,
                    l_idx_rows, u0_local, wgt_local, *,
                    cfg: DistributedInnerConfig):
    body, cond, iterate = _body_factory(
        cfg, x_local, lm_cols, lm_rows, diag_local, l_idx_cols, l_idx_rows,
        wgt_local, x_local.shape[0])
    init = (u0_local.astype(jnp.int32), jnp.array(True),
            jnp.array(0, jnp.int32), jnp.array(jnp.inf, jnp.float32))
    u, _, t, cost = jax.lax.while_loop(cond, body, init)
    # final consistent stats at the fixpoint (as in the single-device path).
    _, f, g, counts, cost = iterate(u)
    return u, f, g, counts, t, cost


def distributed_kkmeans_fit(mesh: Mesh, x: Array, landmarks: Array,
                            l_idx: Array, diag_k: Array, u0: Array, *,
                            cfg: DistributedInnerConfig,
                            wgt: Array | None = None) -> DistInnerResult:
    """Run the distributed inner loop on ``mesh``.

    x:        [n, d]  mini-batch rows (sharded over row axes or replicated —
                      in_specs below enforce the row sharding).
    landmarks:[L, d]  landmark features (replicated input; the shard_map
                      slices it over the column axis internally).
    l_idx:    [L]     landmark indices into the mini-batch (replicated).
    diag_k:   [n]     K(x_i, x_i).
    u0:       [n]     initial labels.
    wgt:      [n]     optional row weights — 0 on the modulo-replicated
                      ghost rows that pad a non-divisible batch, so they
                      never count in the cost (default: all ones).
    """
    row_axes, col_axis = cfg.row_axes, cfg.col_axis
    d_size = 1
    for a in row_axes:
        d_size *= mesh.shape[a]
    m_size = mesh.shape[col_axis] if col_axis is not None else 1
    bad_n = x.shape[0] % d_size != 0
    bad_l = landmarks.shape[0] % d_size != 0 or landmarks.shape[0] % m_size != 0
    if bad_n or bad_l:
        raise ValueError(
            f"n={x.shape[0]} must divide row-axes size {d_size} and "
            f"|L|={landmarks.shape[0]} must divide both {d_size} and {m_size};"
            " round |L| up with num_landmarks(multiple_of=lcm(D, M))")

    rowspec = P(row_axes)
    colspec = P(col_axis) if col_axis is not None else P()
    if wgt is None:
        wgt = jnp.ones((x.shape[0],), jnp.float32)

    fn = partial(_inner_shard_fn, cfg=cfg)
    shard_fn = shard_map(
        fn, mesh=mesh,
        in_specs=(
            P(row_axes, None),    # x rows
            P(col_axis, None) if col_axis else P(None, None),  # lm cols
            P(row_axes, None),    # lm rows (for the K_ll block)
            P(row_axes),          # diag
            colspec,              # l_idx cols
            rowspec,              # l_idx rows
            rowspec,              # u0
            rowspec,              # wgt
        ),
        out_specs=(rowspec, P(row_axes, None), P(), P(), P(), P()),
        check_vma=False,
    )
    u, f, g, counts, t, cost = shard_fn(x, landmarks, landmarks, diag_k,
                                        l_idx, l_idx, u0, wgt)
    return DistInnerResult(u, f, g, counts, t, cost)
