"""Version-compat shims for the mesh / shard_map API surface.

The pinned JAX still hosts ``shard_map`` under ``jax.experimental.shard_map``
with a ``check_rep`` flag; newer releases moved it to ``jax.shard_map`` with
``check_vma``. ``make_mesh`` likewise only grew ``axis_types`` recently.
Every distributed module imports from here so one pin bump never fans out.
"""
from __future__ import annotations

import jax

if hasattr(jax, "shard_map"):
    def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
else:  # pinned JAX: experimental location, check_rep spelling
    from jax.experimental.shard_map import shard_map as _shard_map

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=check_vma)


def cost_analysis(compiled) -> dict:
    """``compiled.cost_analysis()`` as a flat dict on every JAX version.

    The pinned JAX returns a one-element list of per-computation dicts;
    newer releases return the dict directly.
    """
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return dict(cost)


def memory_stats(compiled) -> dict:
    """``compiled.memory_analysis()`` as the dryrun report dict.

    ``peak_memory_in_bytes`` only exists on newer JAX; older releases get
    the conservative upper bound temp + arguments + outputs instead of None.
    """
    mem = compiled.memory_analysis()
    temp = getattr(mem, "temp_size_in_bytes", None)
    args = getattr(mem, "argument_size_in_bytes", None)
    out = getattr(mem, "output_size_in_bytes", None)
    peak = getattr(mem, "peak_memory_in_bytes", None)
    if peak is None and None not in (temp, args, out):
        peak = temp + args + out
    return {
        "bytes_per_device": temp,
        "argument_bytes": args,
        "output_bytes": out,
        "peak_bytes": peak,
    }


def make_mesh(axis_shapes, axis_names):
    """``jax.make_mesh`` with Auto axis types where the API supports them."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(axis_shapes, axis_names,
                             axis_types=(axis_type.Auto,) * len(axis_shapes))
    return jax.make_mesh(axis_shapes, axis_names)


__all__ = ["shard_map", "make_mesh", "cost_analysis", "memory_stats"]
