"""Mesh utilities for the distributed clustering runtime.

The *production* mesh lives in ``repro.launch.mesh`` (16x16 single-pod /
2x16x16 multi-pod). The helpers here build correctness-test meshes from
whatever devices exist (e.g. 8 forced host devices) and answer axis-shape
questions without touching global device state.
"""
from __future__ import annotations

import math

import jax
import numpy as np
from jax.sharding import Mesh


def make_test_mesh(axes: dict[str, int] | None = None) -> Mesh:
    """Mesh over the available devices; default splits them into
    (data, model) with the largest power-of-two model axis <= sqrt(n)."""
    devices = jax.devices()
    n = len(devices)
    if axes is None:
        model = 1
        while model * 2 <= int(math.isqrt(n)) and n % (model * 2) == 0:
            model *= 2
        axes = {"data": n // model, "model": model}
    shape = tuple(axes.values())
    if math.prod(shape) != n:
        raise ValueError(f"mesh {axes} needs {math.prod(shape)} devices, have {n}")
    from .compat import make_mesh
    return make_mesh(shape, tuple(axes.keys()))


def axis_size(mesh: Mesh, names: tuple[str, ...] | str) -> int:
    if isinstance(names, str):
        names = (names,)
    out = 1
    for n in names:
        out *= mesh.shape[n]
    return out


def row_axes_of(mesh: Mesh) -> tuple[str, ...]:
    """Row (data-parallel) axes: every mesh axis except 'model'."""
    return tuple(n for n in mesh.axis_names if n != "model")


def ghost_row_ids(n: int, multiple: int) -> np.ndarray:
    """Source row ids for the ghost rows that pad an n-row batch up to a
    ``multiple`` of the mesh row count: head rows repeated modulo n, so a
    tail batch SMALLER than the mesh (a stream's last yield) pads correctly
    instead of indexing past the batch. Shared by the dense, CSR and exact
    staging paths — the replication convention must not drift apart."""
    if n < 1:
        raise ValueError("cannot stage an empty batch onto the mesh")
    return np.arange((-n) % multiple) % n
