from .mesh import axis_size, make_test_mesh, row_axes_of
from .embed import DistributedEmbedKMeans
from .inner import DistributedInnerConfig, distributed_kkmeans_fit
from .outer import DistributedMiniBatchKMeans

__all__ = [
    "axis_size", "make_test_mesh", "row_axes_of",
    "DistributedEmbedKMeans",
    "DistributedInnerConfig", "distributed_kkmeans_fit",
    "DistributedMiniBatchKMeans",
]
