"""Distributed embedded-space (RFF/Nystrom/sketch) mini-batch k-means.

The explicit feature map makes the heavy step embarrassingly parallel: each
device embeds only its own rows, z = phi_m(x_local), and the Lloyd sweep
needs exactly ONE collective per iteration — a psum of the per-cluster
partial sums and counts, C*(m+1) floats. Compare Alg.1's inner loop, which
allgathers the full label vector U (N/(B*P) ints) *and* psums g every
iteration: the embedded path communicates O(C*m) independent of the batch
size, strictly less whenever C*m < N/B (always, in the paper's regimes).

Row padding (to divide the mesh) is weight-masked rather than replicated, so
padded rows never bias the centroid means.

Ingestion is staged: ``stage`` turns a raw host batch — dense [n, d] rows
OR a ``repro.data.sparse.CSRBatch`` — into a mesh-resident ``StagedBatch``
whose leaves were ``device_put`` with the row ``NamedSharding``, so the H2D
copy lands pre-sharded. A CSR batch is row-split on the host with the
``slice_rows``/``take_rows`` indptr surgery (``shard_csr`` is the tested
reference form of that split; ``_stage_csr`` additionally replicates
weight-masked ghost rows and writes shards straight into flat staging
buffers) and each device runs the O(nnz) count-sketch on its own shard
inside shard_map
(``repro.approx.sketch.count_sketch_features_csr`` — the jnp twin of the
Pallas scatter-add kernel in ``kernels/sketch_assign.py``, which consumes
dense row tiles and therefore serves the dense/predict path). No [n, d]
dense array exists anywhere between disk and device. ``source`` wraps a raw
batch iterable in a ``BatchSource`` that runs ``stage`` in a background
prefetch thread (the paper's §3.3 producer/consumer offload).

Host-side outer loop mirrors ``repro.approx.embed_kmeans.fit_embedded``:
O(C*m) state across batches, exact Eq.12-style convex merge (no medoid
re-approximation — centroids are explicit vectors here).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Iterable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.approx.embed_kmeans import EmbedState, assign_embedded
from repro.core.init import kmeans_pp_indices
from repro.core.kernels import KernelSpec
from repro.core.kkmeans import BIG
from repro.core.minibatch import BatchStats, FitResult, MiniBatchConfig
from repro.data.loader import BatchSource
from repro.data.sparse import (CSRBatch, concat_csr, is_sparse, slice_rows,
                               take_rows)
from repro.obs import memory as obs_memory
from repro.obs import resolve as resolve_recorder
from repro.obs import trace as obs_trace

from .compat import shard_map
from .mesh import axis_size, ghost_row_ids, row_axes_of

Array = jax.Array

_LINEAR = KernelSpec("linear")


@dataclasses.dataclass(frozen=True)
class StagedBatch:
    """A mini-batch already resident on the mesh, row-sharded.

    Dense: ``x`` [P*rows, d] with spec (rows, None). CSR: the three CSR
    leaves flattened shard-major — device k owns shard k's slice of
    ``data``/``indices`` [P*cap] and ``indptr`` [P*(rows+1)] — so a
    shard_map body can rebuild its local ``CSRBatch`` with static shape
    (rows, d). ``wgt`` [P*rows] is 0 on padded rows (they never bias
    centroids). ``n`` is the logical (unpadded) row count.
    """

    wgt: Array
    n: int
    rows: int                 # rows per shard
    d: int
    x: Optional[Array] = None
    data: Optional[Array] = None
    indices: Optional[Array] = None
    indptr: Optional[Array] = None
    cap: int = 0              # nnz capacity per shard

    @property
    def sparse(self) -> bool:
        return self.x is None

    def __len__(self) -> int:
        return self.n


def _shard_lloyd(z_local, wgt_local, centroids0, mask0, *, row_axes,
                 n_clusters: int, max_iters: int):
    """Per-shard Lloyd body: local assign, ONE fused psum per iteration.

    The body is pipelined like ``distributed.inner``: it assigns from the
    CARRIED centroids/counts, then syncs the stats of the labels it just
    wrote — sums [C, m], counts [C], convergence flag and cost all ride a
    single flat ``concat`` psum of C*(m+1) + 2 floats. A prologue sync
    (same fused payload, dummy scalars) seeds the carry from the warm-start
    labels, so the stats in the carry always describe the final labels and
    no fixpoint ``means`` pass is needed after the loop.

    Deliberate semantic change vs. the pre-fused loop: the convergence
    count weights label flips by ``wgt_local``, so padded/ghost rows no
    longer count toward 'changed' (the historical count was unweighted).
    Ghost rows never enter the weighted stats, so a flip on one cannot
    move a centroid — stopping on real-row flips only can only end the
    loop earlier, never with different centroids."""
    m = z_local.shape[1]

    def sync(labels, changed_f, cost_loc):
        with jax.named_scope("obs:psum_fused"):
            h = jax.nn.one_hot(labels, n_clusters, dtype=jnp.float32)
            h = h * wgt_local[:, None]                   # padded rows -> 0
            sums_p = jax.lax.dot_general(h, z_local.astype(jnp.float32),
                                         (((0,), (0,)), ((), ())),
                                         preferred_element_type=jnp.float32)
            counts_p = jnp.sum(h, axis=0)
            flat = jax.lax.psum(
                jnp.concatenate([sums_p.ravel(), counts_p,
                                 jnp.stack([changed_f, cost_loc])]),
                row_axes)                                # [C*(m+1) + 2]
        sums = flat[:n_clusters * m].reshape(n_clusters, m)
        counts = flat[n_clusters * m:-2]
        cents = sums / jnp.maximum(counts, 1.0)[:, None]
        return cents, counts, flat[-2] > 0, flat[-1]

    def body(state):
        labels, cents, counts, _, t, _ = state
        new_labels, mind = assign_embedded(z_local, cents, counts)
        changed_f = jnp.sum((new_labels != labels).astype(jnp.float32)
                            * wgt_local)
        cost_loc = jnp.sum(mind * wgt_local)
        cents, counts, changed, cost = sync(new_labels, changed_f, cost_loc)
        return new_labels, cents, counts, changed, t + 1, cost

    def cond(state):
        _, _, _, changed, t, _ = state
        return jnp.logical_and(changed, t < max_iters)

    # init: nearest centroid0 (masked like the single-device warm start).
    # f32 upcast: z_local may be a bf16 tile under the precision policy.
    z32 = z_local.astype(jnp.float32)
    d2 = (jnp.sum(z32 ** 2, axis=1)[:, None]
          + jnp.sum(centroids0 ** 2, axis=1)[None, :]
          - 2.0 * z32 @ centroids0.T)
    d2 = jnp.where(mask0[None, :], d2, BIG)
    labels0 = jnp.argmin(d2, axis=1).astype(jnp.int32)

    # prologue sync: seed the carry with means(labels0) — the dummy scalars
    # are overridden (changed := True, cost := inf) before the carry forms.
    cents0, counts0, _, _ = sync(labels0, jnp.float32(0), jnp.float32(0))
    init = (labels0, cents0, counts0, jnp.array(True),
            jnp.array(0, jnp.int32), jnp.array(jnp.inf, jnp.float32))
    labels, cents, counts, _, t, cost = jax.lax.while_loop(cond, body, init)
    return labels, cents, counts, t, cost


def collectives_per_iteration(n_clusters: int, m: int) -> dict:
    """Analytic per-Lloyd-iteration collective bill of ``_shard_lloyd``
    (the jit-safe count — see ``distributed.inner.collectives_per_iteration``
    for why it is computed instead of instrumented): ONE fused psum of
    sums + counts + convergence flag + cost, payload C*(m+1) + 2 floats.
    The prologue sync before the loop is the same fused payload
    (``final_psum`` keeps its historical name for the outside-the-loop
    slot in the audited bill)."""
    payload = 4 * (n_clusters * (m + 1) + 2)
    return {"psum": 1, "psum_bytes": payload,
            "final_psum": 1, "final_psum_bytes": payload}


class DistributedEmbedKMeans:
    """Mesh-resident embedded-space mini-batch k-means.

    ``fmap`` may be passed pre-sampled (resume / multi-host determinism) or
    is sampled from the first batch per ``cfg.method`` / ``cfg.embed_dim``.
    """

    def __init__(self, mesh: Mesh, cfg: MiniBatchConfig, *, fmap=None,
                 recorder=None):
        if cfg.method == "exact":
            raise ValueError("DistributedEmbedKMeans needs an embedded "
                             "cfg.method ('rff', 'nystrom', 'sketch', "
                             "'tensorsketch'); use "
                             "DistributedMiniBatchKMeans for 'exact'")
        self.mesh = mesh
        self.cfg = cfg
        self.fmap = fmap
        # repro.obs flight recorder; hooks are host-side only. ``stage``
        # also records through it FROM THE PREFETCH PRODUCER THREAD, which
        # is why JsonlRecorder takes a lock.
        self.rec = resolve_recorder(recorder)
        self.row_axes = row_axes_of(mesh)
        self.d_size = axis_size(mesh, self.row_axes)
        self._row_sharding = NamedSharding(mesh, P(self.row_axes, None))
        self._vec_sharding = NamedSharding(mesh, P(self.row_axes))
        # mesh programs are built once and jitted: a streaming fit calls
        # them once per mini-batch, and rebuilding the shard_map wrapper
        # each call would re-trace (and re-compile) every batch.
        self._embed_fns: dict = {}
        self._bill_cache: dict = {}
        fn = partial(_shard_lloyd, row_axes=self.row_axes,
                     n_clusters=cfg.n_clusters,
                     max_iters=cfg.max_inner_iters)
        rowspec = P(self.row_axes)
        self._lloyd_fn = jax.jit(shard_map(
            lambda z, w, c, mk: fn(z, w, c, mk),
            mesh=self.mesh,
            in_specs=(P(self.row_axes, None), rowspec, P(None, None), P()),
            out_specs=(rowspec, P(), P(), P(), P()),
            check_vma=False))

    def _ensure_fmap(self, sample):
        """Sample the feature map from a batch (dense rows or CSRBatch); a
        pre-staged first batch passes a structural sample instead — enough
        for the data-oblivious maps (sketch/tensorsketch read only d; a
        dense StagedBatch hands its mesh-resident rows to RFF/Nystrom).

        Nystrom + ``selector="rls"`` on a staged dense batch takes the
        mesh-native route (``_make_nystrom_rls``): leverage scores from
        per-device partial sketches, one psum, the staged batch reused —
        no second pass over the stream and no host-side gather of rows.
        """
        if self.fmap is None:
            from repro import approx
            from repro.approx.selectors import name_of
            cfg = self.cfg
            if (cfg.method == "nystrom" and name_of(cfg.selector) == "rls"
                    and isinstance(sample, StagedBatch)
                    and not sample.sparse):
                m = cfg.embed_dim or approx.default_embed_dim(cfg.n_clusters)
                self.fmap = self._make_nystrom_rls(sample, m)
                return self.fmap
            if isinstance(sample, StagedBatch):
                # dense: the UNPADDED rows, so a data-dependent map
                # (Nystrom landmarks) sees exactly what the inline path's
                # raw batch gives it — ghost rows must not alter the model.
                sample = (CSRBatch(data=np.zeros((0,), np.float32),
                                   indices=np.zeros((0,), np.int32),
                                   indptr=np.zeros((1,), np.int32),
                                   shape=(0, sample.d))
                          if sample.sparse else sample.x[:sample.n])
            m = cfg.embed_dim or approx.default_embed_dim(cfg.n_clusters)
            self.fmap = approx.make_feature_map(
                cfg.method, jax.random.PRNGKey(cfg.seed), sample, m,
                cfg.kernel, orthogonal=cfg.rff_orthogonal,
                selector=cfg.selector)
        return self.fmap

    def _make_nystrom_rls(self, st: "StagedBatch", m: int):
        """Mesh-native ridge-leverage-score Nystrom from a staged batch.

        Same draws and estimator as the single-host ``RLSSelector`` (pilot
        and Gumbel keys are fold_in-keyed per global row id), but the
        [m, m] leverage sketch G = C^T diag(wgt) C is assembled from
        per-device partials with ONE psum and the scores are computed
        shard-locally — no device ever sees another shard's rows, and the
        already-staged batch is reused for the embedding right after.
        """
        from repro.approx import nystrom_from_landmarks, selectors

        cfg = self.cfg
        spec = cfg.kernel
        sel = selectors.resolve(cfg.selector)
        key = jax.random.PRNGKey(cfg.seed)
        gids = jnp.arange(st.n, dtype=jnp.int32)
        pilot = jnp.take(st.x, sel.pilot_indices(key, gids, m), axis=0)
        whiten = selectors.pilot_whitening(pilot, spec, eps=sel.eps)

        def shard_fn(x_local, wgt_local, pilot, whiten):
            c = jnp.dot(spec(x_local, pilot).astype(jnp.float32), whiten,
                        preferred_element_type=jnp.float32)   # [rows, m]
            # per-device partial leverage sketch, combined with one psum
            g = jax.lax.psum(
                jax.lax.dot_general(c, c * wgt_local[:, None],
                                    (((0,), (0,)), ((), ())),
                                    preferred_element_type=jnp.float32),
                self.row_axes)                                # [m, m]
            scores = selectors.rls_scores(c, spec.diag(x_local), g,
                                          delta=sel.delta)
            return jnp.where(wgt_local > 0, scores, 0.0)      # mask ghosts

        scores = jax.jit(shard_map(
            shard_fn, mesh=self.mesh,
            in_specs=(P(self.row_axes, None), P(self.row_axes),
                      P(None, None), P(None, None)),
            out_specs=P(self.row_axes), check_vma=False))(
                st.x, st.wgt, pilot, whiten)
        idx = sel.gumbel_top_m(key, scores[:st.n], gids, m)
        return nystrom_from_landmarks(jnp.take(st.x, idx, axis=0), spec)

    # -- staging: host batch -> mesh-resident, pre-sharded -----------------

    def stage(self, xb) -> "StagedBatch":
        """Pad + shard + device_put one raw batch (dense or CSR). Runs on
        the host (a PrefetchLoader producer thread via ``source``, or inline
        in ``fit``); the H2D copies land pre-sharded on the mesh."""
        if isinstance(xb, StagedBatch):
            return xb
        # timer + host-timeline annotation: staging usually runs on the
        # prefetch producer thread, so the trace shows whether H2D staging
        # overlaps the consumer's compute (the whole point of §3.3).
        with self.rec.timer("stage/seconds"), obs_trace.annotate("obs:stage"):
            if is_sparse(xb):
                return self._stage_csr(xb)
            return self._stage_dense(np.asarray(xb, np.float32))

    def _wgt(self, n: int, pad: int) -> np.ndarray:
        wgt = np.ones((n + pad,), np.float32)
        if pad:
            wgt[n:] = 0.0
        return wgt

    def _stage_dense(self, xb: np.ndarray) -> "StagedBatch":
        n = len(xb)
        idx = ghost_row_ids(n, self.d_size)
        pad = len(idx)
        if pad:   # replicate head rows so ghost rows are real points ...
            xb = np.concatenate([xb, xb[idx]], axis=0)
        wgt = self._wgt(n, pad)   # ... but weight-masked out of the means
        # device_put straight from the HOST array: routing through
        # jnp.asarray would commit the whole batch to the default device
        # first and reshard device-to-device — the copy must land sharded.
        x = jax.device_put(xb, self._row_sharding)
        return StagedBatch(
            wgt=jax.device_put(wgt, self._vec_sharding),
            n=n, rows=(n + pad) // self.d_size, d=xb.shape[1], x=x)

    def _stage_csr(self, xb: CSRBatch) -> "StagedBatch":
        n, d = xb.shape
        idx = ghost_row_ids(n, self.d_size)
        pad = len(idx)
        wgt = self._wgt(n, pad)
        # Shard the PADDED row space [batch ++ ghost rows] directly: pieces
        # are views (slice_rows) except where a shard straddles the ghost
        # boundary — this is the prefetch producer's hot path, and a
        # concat-then-reshard would copy every stored value twice.
        rows = (n + pad) // self.d_size
        pieces = []
        for k in range(self.d_size):
            a, z = k * rows, (k + 1) * rows
            if z <= n:
                pieces.append(slice_rows(xb, a, z))
            elif a >= n:
                pieces.append(take_rows(xb, idx[a - n:z - n]))
            else:
                pieces.append(concat_csr([slice_rows(xb, a, n),
                                          take_rows(xb, idx[:z - n])]))
        # nnz capacity quantized (geometric, ~12.5% max slack) so a long
        # stream of ragged batches maps to a handful of leaf shapes — each
        # distinct cap is a fresh trace + compile of the memoized embed
        # program otherwise.
        est = max(256, xb.nnz // self.d_size)   # lower bound on shard cap
        quantum = max(256, 1 << max(0, est.bit_length() - 3))
        stored = [int(np.asarray(p.indptr)[-1]) for p in pieces]
        cap = -(-max(stored) // quantum) * quantum
        # shard payloads are written straight into the flat [P*cap] staging
        # buffers — the one O(nnz) copy this path pays.
        p_ = self.d_size
        data_g = np.zeros((p_ * cap,), np.float32)
        idx_g = np.zeros((p_ * cap,), np.int32)
        ptr_g = np.empty((p_ * (rows + 1),), np.int32)
        for k, p in enumerate(pieces):
            s = stored[k]
            data_g[k * cap:k * cap + s] = np.asarray(p.data)[:s]
            idx_g[k * cap:k * cap + s] = np.asarray(p.indices)[:s]
            ptr_g[k * (rows + 1):(k + 1) * (rows + 1)] = \
                np.asarray(p.indptr, dtype=np.int32)
        put = lambda a: jax.device_put(a,   # noqa: E731  (host array in:
            self._vec_sharding)             # the H2D copy lands sharded)
        return StagedBatch(
            wgt=put(wgt), n=n, rows=rows, d=d,
            data=put(data_g), indices=put(idx_g),
            indptr=put(ptr_g), cap=cap)

    def source(self, batches: Iterable, *, depth: int = 2,
               skip: int = 0) -> BatchSource:
        """Wrap raw batches in a ``BatchSource`` whose background producer
        stages each one onto this mesh (pre-sharded H2D overlap, §3.3)."""
        return BatchSource(batches, stage=self.stage, prefetch=depth,
                           skip=skip, recorder=self.rec)

    # -- per-device embedding ----------------------------------------------

    def _embed_fn(self, kind_key):
        """Memoized jitted shard_map program for one batch geometry. The
        feature map rides in as a (replicated) pytree ARGUMENT, not a
        closure, so the callable — and its compile cache — survives across
        batches and fmap updates."""
        if kind_key not in self._embed_fns:
            rowvec = P(self.row_axes)
            if kind_key[0] == "csr":
                _, rows, d = kind_key

                def shard_fn(fmap, data, indices, indptr):
                    local = CSRBatch(data=data, indices=indices,
                                     indptr=indptr, shape=(rows, d))
                    return fmap(local).astype(jnp.float32)

                in_specs = (P(), rowvec, rowvec, rowvec)
            else:
                shard_fn = lambda fmap, xl: (  # noqa: E731
                    fmap(xl).astype(jnp.float32))
                in_specs = (P(), P(self.row_axes, None))
            self._embed_fns[kind_key] = jax.jit(shard_map(
                shard_fn, mesh=self.mesh, in_specs=in_specs,
                out_specs=P(self.row_axes, None), check_vma=False))
        return self._embed_fns[kind_key]

    def _embed(self, st: "StagedBatch") -> Array:
        """z = phi_m(rows) shard-locally; CSR shards run the O(nnz) sketch
        on their own (data, indices, indptr) slices — the embedding is the
        only dense array ever built from a sparse batch, and it is [rows, m]
        per device, never [n, d]."""
        from repro.kernels.precision import resolve_precision
        prec = resolve_precision(self.cfg.precision)
        with obs_trace.annotate("obs:embed_phi"):
            if st.sparse:
                fn = self._embed_fn(("csr", st.rows, st.d))
                z = fn(self.fmap, st.data, st.indices, st.indptr)
            else:
                z = self._embed_fn(("dense",))(self.fmap, st.x)
        # tile-dtype policy: the mesh-resident [rows, m] shard is the
        # dominant HBM term of this path — bf16 halves it; every Lloyd
        # contraction upcasts to f32 (see _shard_lloyd).
        return prec.cast_tiles(z)

    def _batch_step(self, x: Array, wgt: Array, centroids0: Array,
                    mask0: Array):
        return self._lloyd_fn(x, wgt, centroids0, mask0)

    def _audited_bill(self, z, wgt, centroids0, mask0):
        """Statically-audited collective bill of ``_lloyd_fn`` (see
        ``repro.analysis.collective_bill``), cached per embedded-batch
        shape; analytic fallback (+ ``audit_error`` event) if tracing
        fails — billing must never take the fit down."""
        key = (z.shape, centroids0.shape, str(z.dtype))
        bill = self._bill_cache.get(key)
        if bill is None:
            from repro.analysis import collective_bill
            try:
                bill = collective_bill(self._lloyd_fn, z, wgt, centroids0,
                                       mask0, name="embed_lloyd")
            except Exception as e:   # pragma: no cover - defensive
                self.rec.event("audit_error", where="embed_lloyd",
                               error=repr(e))
                m = getattr(self.fmap, "dim", 0)
                analytic = collectives_per_iteration(self.cfg.n_clusters, m)
                bill = {
                    "per_iteration": {"psum": analytic["psum"]},
                    "outside": {"psum": analytic["final_psum"]},
                    "per_iteration_bytes":
                        {"psum": analytic["psum_bytes"]},
                    "outside_bytes":
                        {"psum": analytic["final_psum_bytes"]},
                }
            self._bill_cache[key] = bill
        return bill

    def fit(self, batches: Iterable, *,
            state: Optional[EmbedState] = None,
            checkpoint_cb=None) -> FitResult:
        """Run the outer loop. ``batches`` may yield raw host batches (dense
        rows or ``CSRBatch`` — staged inline) or pre-staged ``StagedBatch``es
        (a ``source``/``BatchSource`` with the background producer). A
        closable source is closed on exit, success or failure, so an early
        error never leaks the producer thread."""
        from repro.data.loader import closing_source
        with closing_source(batches):
            return self._fit(batches, state=state,
                             checkpoint_cb=checkpoint_cb)

    def _fit(self, batches: Iterable, *, state, checkpoint_cb) -> FitResult:
        import time

        cfg = self.cfg
        rec = self.rec
        key = jax.random.PRNGKey(cfg.seed)
        history: list[BatchStats] = []
        start = int(state.batches_done) if state is not None else 0
        if state is not None and self.fmap is None:
            raise ValueError("resuming requires the original fmap")

        for i, xb in enumerate(batches, start=start):
            t_batch = time.perf_counter()
            self._ensure_fmap(xb)
            st = self.stage(xb)
            wgt = st.wgt
            # embed rows shard-locally (embarrassingly parallel, O(nnz) on
            # CSR shards).
            z = self._embed(st)

            sub = jax.random.fold_in(key, i)
            if state is None:
                # k-means++ seeds in embedded space (replicated, O(n*C)) —
                # over the UNPADDED rows only: ghost rows would double some
                # points' D^2 mass and shift every categorical draw, and the
                # seeding must match the single-host oracle bit-for-bit.
                zn = z[:st.n]
                zsq = jnp.sum(zn ** 2, axis=1)
                seeds = kmeans_pp_indices(zn, zsq, sub,
                                          n_clusters=cfg.n_clusters,
                                          spec=_LINEAR)
                centroids0 = jnp.take(zn, seeds, axis=0)
                mask0 = jnp.ones((cfg.n_clusters,), bool)
                cards = jnp.zeros((cfg.n_clusters,), jnp.float32)
            else:
                centroids0 = state.centroids
                mask0 = state.cardinalities > 0
                cards = state.cardinalities

            labels, cents, counts, t, cost = self._batch_step(
                z, wgt, centroids0, mask0)

            if state is None:
                new_centroids = cents
                disp = jnp.zeros((cfg.n_clusters,), jnp.float32)
                batches_done = jnp.array(1, jnp.int32)
            else:
                alpha = counts / jnp.maximum(counts + cards, 1.0)
                merged = ((1.0 - alpha)[:, None] * state.centroids
                          + alpha[:, None] * cents)
                keep = (counts == 0)[:, None]
                new_centroids = jnp.where(keep, state.centroids, merged)
                disp = jnp.sum((new_centroids - state.centroids) ** 2, axis=1)
                batches_done = state.batches_done + 1
            state = EmbedState(centroids=new_centroids,
                               cardinalities=cards + counts,
                               batches_done=batches_done)
            history.append(BatchStats(
                inner_iters=int(t), cost=float(cost),
                displacement=np.asarray(disp), counts=np.asarray(counts)))
            if checkpoint_cb is not None:
                checkpoint_cb(state, i)
            if rec.enabled:
                n_iter = history[-1].inner_iters
                # statically-audited bill (repro.analysis): per-iteration
                # while-body count x n_iter + the audited prologue sync
                # (the fixpoint ``means`` epilogue is gone — the pipelined
                # body syncs the stats of the labels it just wrote);
                # `collectives_per_iteration` remains the analytic
                # cross-check the audit must agree with.
                bill = self._audited_bill(z, wgt, centroids0, mask0)
                per, out = bill["per_iteration"], bill["outside"]
                rec.counter("collectives/psum",
                            per.get("psum", 0) * n_iter
                            + out.get("psum", 0), batch=i)
                rec.counter("collectives/psum_bytes",
                            bill["per_iteration_bytes"].get("psum", 0)
                            * n_iter
                            + bill["outside_bytes"].get("psum", 0),
                            batch=i)
                rec.series("batch/wall_seconds",
                           time.perf_counter() - t_batch, batch=i,
                           rows=st.n)
                rec.series("inner/cost", history[-1].cost, batch=i)
                rec.series("inner/iters", n_iter, batch=i)
                density = 1.0
                if st.sparse:
                    # indptr is shard-major [P*(rows+1)]; each shard's last
                    # entry is its stored nnz.
                    ptr = np.asarray(st.indptr).reshape(self.d_size,
                                                        st.rows + 1)
                    density = float(ptr[:, -1].sum()) / max(st.n * st.d, 1)
                obs_memory.watermark(
                    rec, batch=i, predicted_bytes=(
                        obs_memory.predicted_embed_footprint(
                            st.n, cfg.n_clusters, self.fmap,
                            sparse=st.sparse, density=density,
                            n_devices=self.d_size)))
                rec.batch_boundary(i)
        if state is None:
            raise ValueError("empty batch iterable")
        return FitResult(state, history, fmap=self.fmap, spec=cfg.kernel)
