"""Distributed embedded-space (RFF/Nystrom/sketch) mini-batch k-means.

The explicit feature map makes the heavy step embarrassingly parallel: each
device embeds only its own rows, z = phi_m(x_local), and the Lloyd sweep
needs exactly ONE collective per iteration — a psum of the per-cluster
partial sums and counts, C*(m+1) floats. Compare Alg.1's inner loop, which
allgathers the full label vector U (N/(B*P) ints) *and* psums g every
iteration: the embedded path communicates O(C*m) independent of the batch
size, strictly less whenever C*m < N/B (always, in the paper's regimes).

Row padding (to divide the mesh) is weight-masked rather than replicated, so
padded rows never bias the centroid means.

Host-side outer loop mirrors ``repro.approx.embed_kmeans.fit_embedded``:
O(C*m) state across batches, exact Eq.12-style convex merge (no medoid
re-approximation — centroids are explicit vectors here).
"""
from __future__ import annotations

from functools import partial
from typing import Iterable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.approx.embed_kmeans import EmbedState, assign_embedded
from repro.core.init import kmeans_pp_indices
from repro.core.kernels import KernelSpec
from repro.core.kkmeans import BIG
from repro.core.minibatch import BatchStats, FitResult, MiniBatchConfig

from .compat import shard_map
from .mesh import axis_size, row_axes_of

Array = jax.Array

_LINEAR = KernelSpec("linear")


def _shard_lloyd(z_local, wgt_local, centroids0, mask0, *, row_axes,
                 n_clusters: int, max_iters: int):
    """Per-shard Lloyd body: local assign, one psum per iteration."""

    def means(labels):
        h = jax.nn.one_hot(labels, n_clusters, dtype=jnp.float32)
        h = h * wgt_local[:, None]                       # padded rows -> 0
        counts = jax.lax.psum(jnp.sum(h, axis=0), row_axes)
        sums = jax.lax.psum(
            jax.lax.dot_general(h, z_local, (((0,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32),
            row_axes)                                    # [C, m]
        return sums / jnp.maximum(counts, 1.0)[:, None], counts

    def assign(cents, counts):
        labels, mind = assign_embedded(z_local, cents, counts)
        return labels, mind

    def body(state):
        labels, _, t, _ = state
        cents, counts = means(labels)
        new_labels, mind = assign(cents, counts)
        changed = jax.lax.psum(
            jnp.sum((new_labels != labels).astype(jnp.int32)), row_axes) > 0
        cost = jax.lax.psum(jnp.sum(mind * wgt_local), row_axes)
        return new_labels, changed, t + 1, cost

    def cond(state):
        _, changed, t, _ = state
        return jnp.logical_and(changed, t < max_iters)

    # init: nearest centroid0 (masked like the single-device warm start).
    d2 = (jnp.sum(z_local ** 2, axis=1)[:, None]
          + jnp.sum(centroids0 ** 2, axis=1)[None, :]
          - 2.0 * z_local @ centroids0.T)
    d2 = jnp.where(mask0[None, :], d2, BIG)
    labels0 = jnp.argmin(d2, axis=1).astype(jnp.int32)

    init = (labels0, jnp.array(True), jnp.array(0, jnp.int32),
            jnp.array(jnp.inf, jnp.float32))
    labels, _, t, cost = jax.lax.while_loop(cond, body, init)
    cents, counts = means(labels)
    return labels, cents, counts, t, cost


class DistributedEmbedKMeans:
    """Mesh-resident embedded-space mini-batch k-means.

    ``fmap`` may be passed pre-sampled (resume / multi-host determinism) or
    is sampled from the first batch per ``cfg.method`` / ``cfg.embed_dim``.
    """

    def __init__(self, mesh: Mesh, cfg: MiniBatchConfig, *, fmap=None):
        if cfg.method == "exact":
            raise ValueError("DistributedEmbedKMeans needs an embedded "
                             "cfg.method ('rff', 'nystrom', 'sketch', "
                             "'tensorsketch'); use "
                             "DistributedMiniBatchKMeans for 'exact'")
        self.mesh = mesh
        self.cfg = cfg
        self.fmap = fmap
        self.row_axes = row_axes_of(mesh)
        self.d_size = axis_size(mesh, self.row_axes)
        self._row_sharding = NamedSharding(mesh, P(self.row_axes, None))

    def _ensure_fmap(self, first_batch: Array):
        if self.fmap is None:
            from repro import approx
            cfg = self.cfg
            m = cfg.embed_dim or approx.default_embed_dim(cfg.n_clusters)
            self.fmap = approx.make_feature_map(
                cfg.method, jax.random.PRNGKey(cfg.seed), first_batch, m,
                cfg.kernel, orthogonal=cfg.rff_orthogonal)
        return self.fmap

    def _batch_step(self, x: Array, wgt: Array, centroids0: Array,
                    mask0: Array):
        fn = partial(_shard_lloyd, row_axes=self.row_axes,
                     n_clusters=self.cfg.n_clusters,
                     max_iters=self.cfg.max_inner_iters)
        rowspec = P(self.row_axes)
        return shard_map(
            lambda z, w, c, mk: fn(z, w, c, mk),
            mesh=self.mesh,
            in_specs=(P(self.row_axes, None), rowspec, P(None, None), P()),
            out_specs=(rowspec, P(), P(), P(), P()),
            check_vma=False,
        )(x, wgt, centroids0, mask0)

    def fit(self, batches: Iterable[np.ndarray], *,
            state: Optional[EmbedState] = None,
            checkpoint_cb=None) -> FitResult:
        cfg = self.cfg
        key = jax.random.PRNGKey(cfg.seed)
        history: list[BatchStats] = []
        start = int(state.batches_done) if state is not None else 0
        if state is not None and self.fmap is None:
            raise ValueError("resuming requires the original fmap")

        for i, xb in enumerate(batches, start=start):
            xb = np.asarray(xb, np.float32)
            fmap = self._ensure_fmap(jnp.asarray(xb))
            n = len(xb)
            pad = (-n) % self.d_size
            wgt = np.ones((n + pad,), np.float32)
            if pad:
                xb = np.concatenate([xb, xb[:pad]], axis=0)
                wgt[n:] = 0.0
            x = jax.device_put(jnp.asarray(xb), self._row_sharding)
            wgt = jax.device_put(jnp.asarray(wgt),
                                 NamedSharding(self.mesh, P(self.row_axes)))
            # embed rows shard-locally (embarrassingly parallel).
            z = shard_map(lambda xl: fmap(xl).astype(jnp.float32),
                          mesh=self.mesh,
                          in_specs=P(self.row_axes, None),
                          out_specs=P(self.row_axes, None),
                          check_vma=False)(x)

            sub = jax.random.fold_in(key, i)
            if state is None:
                # k-means++ seeds in embedded space (replicated, O(n*C)) —
                # same seeding as the single-device first batch.
                zsq = jnp.sum(z ** 2, axis=1)
                seeds = kmeans_pp_indices(z, zsq, sub,
                                          n_clusters=cfg.n_clusters,
                                          spec=_LINEAR)
                centroids0 = jnp.take(z, seeds, axis=0)
                mask0 = jnp.ones((cfg.n_clusters,), bool)
                cards = jnp.zeros((cfg.n_clusters,), jnp.float32)
            else:
                centroids0 = state.centroids
                mask0 = state.cardinalities > 0
                cards = state.cardinalities

            labels, cents, counts, t, cost = self._batch_step(
                z, wgt, centroids0, mask0)

            if state is None:
                new_centroids = cents
                disp = jnp.zeros((cfg.n_clusters,), jnp.float32)
                batches_done = jnp.array(1, jnp.int32)
            else:
                alpha = counts / jnp.maximum(counts + cards, 1.0)
                merged = ((1.0 - alpha)[:, None] * state.centroids
                          + alpha[:, None] * cents)
                keep = (counts == 0)[:, None]
                new_centroids = jnp.where(keep, state.centroids, merged)
                disp = jnp.sum((new_centroids - state.centroids) ** 2, axis=1)
                batches_done = state.batches_done + 1
            state = EmbedState(centroids=new_centroids,
                               cardinalities=cards + counts,
                               batches_done=batches_done)
            history.append(BatchStats(
                inner_iters=int(t), cost=float(cost),
                displacement=np.asarray(disp), counts=np.asarray(counts)))
            if checkpoint_cb is not None:
                checkpoint_cb(state, i)
        if state is None:
            raise ValueError("empty batch iterable")
        return FitResult(state, history, fmap=self.fmap, spec=cfg.kernel)
