"""Distributed mini-batch outer loop (paper Alg.1 end-to-end on a mesh).

Host-side orchestration identical to ``repro.core.minibatch`` but every
O(N/B) step runs sharded:

  * Eq.8 init + K~^i           -> row-sharded kernel vs C global medoids
  * inner GD loop              -> repro.distributed.inner (Alg.1 lines 9-16)
  * Eq.7 medoids               -> local argmin + cross-shard min-reduce
                                  (paper line 18 "allreduce min M^i")
  * Eq.12 merge                -> row-sharded score + same min-reduce
                                  (paper line 20 "allreduce min M")

Only O(C*d) state (medoid coordinates, diag, cardinalities) crosses batches,
so checkpoint/restart and elastic re-meshing are trivial: the state is mesh-
independent (repro.ft).

Non-divisible batches are padded with modulo-replicated ghost rows, exactly
like the embedded path — and, like there, the ghosts are weight-masked:
they are never landmark candidates (selection runs over the unpadded rows,
strategy-dispatched via ``cfg.selector`` — uniform / rls / kpp,
``repro.approx.selectors``), never win a medoid/merge argmin, and never
count in the cost, so a P∤(N/B) distributed fit reproduces the single-host
cardinalities and Eq.12 alphas exactly.
"""
from __future__ import annotations

import math
import time
from typing import Iterable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.engine import resolve_engine
from repro.core.init import kmeans_pp_indices
from repro.core.kkmeans import BIG
from repro.core.landmarks import (choose_landmarks, num_landmarks,
                                  select_landmark_indices)
from repro.core.minibatch import BatchStats, FitResult, GlobalState, MiniBatchConfig
from repro.obs import memory as obs_memory
from repro.obs import resolve as resolve_recorder

from .compat import shard_map
from .inner import (DistributedInnerConfig, collectives_per_iteration,
                    distributed_kkmeans_fit)
from .mesh import ghost_row_ids

Array = jax.Array


def _dist_argmin_rows(mesh: Mesh, row_axes, score: Array, n_local: int):
    """argmin over the (row-sharded) axis 0 of ``score`` [n, C] -> [C] global
    row indices. Local argmin then a gather+min over shards (the paper's
    allreduce-min with index payload)."""

    def shard_fn(score_local):
        idx = jnp.argmin(score_local, axis=0)                      # [C] local
        val = jnp.min(score_local, axis=0)                         # [C]
        # global row index = shard offset + local index.
        row_rank = jax.lax.axis_index(row_axes)
        gidx = row_rank * score_local.shape[0] + idx
        vals = jax.lax.all_gather(val, row_axes)                   # [D, C]
        gidxs = jax.lax.all_gather(gidx, row_axes)                 # [D, C]
        best = jnp.argmin(vals, axis=0)                            # [C]
        return jnp.take_along_axis(gidxs, best[None, :], axis=0)[0]

    return shard_map(
        shard_fn, mesh=mesh, in_specs=P(row_axes, None), out_specs=P(),
        check_vma=False)(score)


class DistributedMiniBatchKMeans:
    """Mesh-resident mini-batch kernel k-means (the production entry point)."""

    def __init__(self, mesh: Mesh, cfg: MiniBatchConfig, *,
                 mode: object = None, recorder=None):
        """``mode`` names the GramEngine of the inner loop — "materialize" |
        "fused" | "tiled" or a ``repro.core.engine.GramEngine`` instance;
        default: whatever ``cfg.engine`` says (itself "materialize" unless
        the planner picked otherwise). ``recorder`` is a ``repro.obs``
        flight recorder; all its hooks run host-side between the jitted
        mesh programs (the collective bill inside the inner while_loop is
        counted *statically* — ``repro.analysis.collective_bill`` walks the
        traced jaxpr once per batch shape and the recorder multiplies the
        per-iteration count by the returned n_iter, plus the audited
        outside-the-loop prologue sync — never by instrumenting the traced
        body; ``inner.collectives_per_iteration`` stays as the analytic
        cross-check the audit must agree with)."""
        self.mesh = mesh
        self.cfg = cfg
        self.rec = resolve_recorder(recorder)
        row_axes = tuple(n for n in mesh.axis_names if n != "model")
        col_axis = "model" if "model" in mesh.axis_names else None
        self.row_axes = row_axes
        self.col_axis = col_axis
        self.d_size = math.prod(mesh.shape[a] for a in row_axes)
        self.m_size = mesh.shape[col_axis] if col_axis else 1
        self.inner_cfg = DistributedInnerConfig(
            n_clusters=cfg.n_clusters, kernel=cfg.kernel,
            max_iters=cfg.max_inner_iters,
            engine=resolve_engine(cfg.engine if mode is None else mode),
            precision=getattr(cfg, "precision", "f32"),
            row_axes=row_axes, col_axis=col_axis,
            s_step=getattr(cfg, "s_step", 1))
        self._row_sharding = NamedSharding(mesh, P(row_axes, None))
        self._bill_cache: dict = {}

    # -- helpers -----------------------------------------------------------

    def _audited_bill(self, x, landmarks, l_idx, diag, u0, wgt):
        """Statically-audited collective bill of the inner mesh program
        (``repro.analysis.collective_bill`` over the traced jaxpr), cached
        per batch shape. Falls back to the analytic
        ``collectives_per_iteration`` (recording an ``audit_error`` event)
        if tracing fails — billing must never take the fit down."""
        key = (x.shape, landmarks.shape, str(x.dtype))
        bill = self._bill_cache.get(key)
        if bill is None:
            from repro.analysis import collective_bill
            try:
                bill = collective_bill(
                    lambda xa, lm, li, dg, u, w: distributed_kkmeans_fit(
                        self.mesh, xa, lm, li, dg, u, cfg=self.inner_cfg,
                        wgt=w),
                    x, landmarks, l_idx, diag, u0, wgt,
                    name="distributed_inner")
            except Exception as e:   # pragma: no cover - defensive
                self.rec.event("audit_error", where="distributed_inner",
                               error=repr(e))
                analytic = collectives_per_iteration(
                    self.inner_cfg, x.shape[0] // self.d_size)
                # analytic equivalent of the audited bill: one fused
                # allgather+psum sync per while-loop body, and the same
                # pair once outside (the prologue that seeds the carry —
                # there is no fixpoint epilogue any more).
                bill = {
                    "per_iteration": {"psum": analytic["psum"],
                                      "all_gather": analytic["allgather"]},
                    "outside": {"psum": analytic["psum"],
                                "all_gather": analytic["allgather"]},
                    "per_iteration_bytes": {"psum": analytic["psum_bytes"]},
                    "outside_bytes": {"psum": analytic["psum_bytes"]},
                }
            self._bill_cache[key] = bill
        return bill

    def _put_rows(self, x: np.ndarray) -> Array:
        return jax.device_put(jnp.asarray(x), self._row_sharding)

    def _landmark_count(self, n: int) -> int:
        return num_landmarks(
            n, self.cfg.s, n_clusters=self.cfg.n_clusters,
            multiple_of=int(np.lcm(self.d_size, self.m_size)))

    def _choose_landmarks(self, key, xb: np.ndarray, n_pad: int):
        """(l_idx, |L|) for one batch of ``n = len(xb)`` real rows padded
        by ``n_pad`` ghost rows.

        Landmarks are selected over the UNPADDED rows (strategy-dispatched:
        ``cfg.selector``): a ghost row is a modulo-replicated real row, and
        letting it into the landmark set double-counts its point in the
        Eq.14 expansion, the cardinalities and the Eq.12 alpha — the old
        O(P/(N/B)) ghost-row bias. Only when the batch is smaller than the
        landmark alignment itself (a tail batch under the mesh size) do we
        fall back to the padded row space, where the <= P-1 duplicated
        landmarks are unavoidable (documented residual bias).
        """
        n = len(xb)
        mult = int(np.lcm(self.d_size, self.m_size))
        if n >= mult:
            n_l = self._landmark_count(n)
            l_idx = select_landmark_indices(
                key, jnp.asarray(xb, jnp.float32), n_l, self.cfg.kernel,
                selector=self.cfg.selector)
        else:
            n_l = self._landmark_count(n + n_pad)
            l_idx = choose_landmarks(key, n + n_pad, n_l)
        return l_idx, n_l

    def _init_labels(self, x: Array, diag: Array, medoids: Array,
                     mdiag: Array):
        """Eq.8 on the mesh; also returns row-sharded K~^i for the merge."""
        spec = self.cfg.kernel

        def shard_fn(x_local, diag_local):
            kt = spec(x_local, medoids).astype(jnp.float32)
            d2 = diag_local.astype(jnp.float32)[:, None] + mdiag[None, :] \
                - 2.0 * kt
            return jnp.argmin(d2, axis=1).astype(jnp.int32), kt

        return shard_map(
            shard_fn, mesh=self.mesh,
            in_specs=(P(self.row_axes, None), P(self.row_axes)),
            out_specs=(P(self.row_axes), P(self.row_axes, None)),
            check_vma=False)(x, diag)

    def _medoid_merge(self, x: Array, diag: Array, res, k_tilde, state,
                      first: bool, wgt: Array):
        """Eq.7 batch medoids + Eq.12 merge, both via distributed argmin.

        ``wgt`` is 0 on ghost rows: masking them out of both argmins keeps
        the selected row *indices* identical to the single-host run (a
        ghost duplicate would otherwise be able to win a tie at a higher
        index).
        """
        spec, C = self.cfg.kernel, self.cfg.n_clusters
        ghost = (1.0 - wgt)[:, None] * BIG                        # sharded
        # Eq.7: batch medoid scores.
        score7 = diag.astype(jnp.float32)[:, None] - 2.0 * res.f + ghost
        m_idx = _dist_argmin_rows(self.mesh, self.row_axes, score7,
                                  x.shape[0] // self.d_size)
        batch_medoids = jnp.take(x, m_idx, axis=0)                # replicated
        if first:
            medoids = batch_medoids
            mdiag = spec.diag(batch_medoids)
            cards = res.counts
            disp = jnp.zeros((C,), jnp.float32)
        else:
            alpha = res.counts / jnp.maximum(res.counts + state.cardinalities,
                                             1.0)

            def score_fn(x_local, diag_local, kt_local):
                kxm = spec(x_local, batch_medoids).astype(jnp.float32)
                return (diag_local.astype(jnp.float32)[:, None]
                        - 2.0 * (1.0 - alpha)[None, :] * kt_local
                        - 2.0 * alpha[None, :] * kxm)

            score12 = shard_map(
                score_fn, mesh=self.mesh,
                in_specs=(P(self.row_axes, None), P(self.row_axes),
                          P(self.row_axes, None)),
                out_specs=P(self.row_axes, None), check_vma=False)(
                    x, diag, k_tilde) + ghost
            merge_idx = _dist_argmin_rows(self.mesh, self.row_axes, score12,
                                          x.shape[0] // self.d_size)
            merged = jnp.take(x, merge_idx, axis=0)
            keep = (res.counts == 0)[:, None]
            medoids = jnp.where(keep, state.medoids, merged)
            mdiag = jnp.where(keep[:, 0], state.medoid_diag, spec.diag(merged))
            cross = jax.vmap(lambda a, b: spec(a[None], b[None])[0, 0])(
                medoids, state.medoids)
            disp = jnp.maximum(mdiag + state.medoid_diag - 2.0 * cross, 0.0)
            cards = state.cardinalities + res.counts
        new_state = GlobalState(
            medoids=medoids, medoid_diag=mdiag, cardinalities=cards,
            batches_done=(state.batches_done + 1) if not first
            else jnp.array(1, jnp.int32))
        return new_state, disp

    # -- driver -------------------------------------------------------------

    def fit(self, batches: Iterable[np.ndarray], *,
            state: Optional[GlobalState] = None,
            checkpoint_cb=None) -> FitResult:
        cfg = self.cfg
        spec = cfg.kernel
        rec = self.rec
        monitor = None
        if rec.enabled:
            from repro.ft.straggler import StragglerMonitor
            monitor = StragglerMonitor(rec)
        key = jax.random.PRNGKey(cfg.seed)
        history: list[BatchStats] = []
        start = int(state.batches_done) if state is not None else 0

        for i, xb in enumerate(batches, start=start):
            t_batch = time.perf_counter()
            xb = np.asarray(xb, np.float32)
            n = len(xb)
            idx = ghost_row_ids(n, self.d_size)
            # pure per-batch schedule — batch i's draws depend only on
            # (cfg.seed, i), so a checkpoint-resumed fit replays the same
            # landmarks as the uninterrupted run (same fix as
            # core/minibatch.fit and the embedded path).
            k_lm, k_pp = jax.random.split(jax.random.fold_in(key, i))
            # landmark selection over the UNPADDED rows (ghost-bias fix;
            # see _choose_landmarks) BEFORE the batch is padded.
            l_idx, n_l = self._choose_landmarks(k_lm, xb, len(idx))
            if len(idx):
                # Replicate head rows so shapes divide the mesh; ``wgt``
                # masks them out of the cost and both medoid argmins, and
                # they can no longer be landmarks, so cardinalities and
                # the Eq.12 alpha match the single-host run exactly.
                xb = np.concatenate([xb, xb[idx]], axis=0)
            wgt_host = np.ones((len(xb),), np.float32)
            wgt_host[n:] = 0.0
            x = self._put_rows(xb)
            wgt = jax.device_put(wgt_host,
                                 NamedSharding(self.mesh, P(self.row_axes)))
            diag = shard_map(
                lambda xl: spec.diag(xl), mesh=self.mesh,
                in_specs=P(self.row_axes, None), out_specs=P(self.row_axes),
                check_vma=False)(x)
            landmarks = jnp.take(x, l_idx, axis=0)   # [L, d] replicated

            first = state is None
            if first:
                # distributed adaptation: k-means++ seeds FROM THE LANDMARK
                # SET (the subspace centroids live in anyway, §3.2) — keeps
                # the D^2 sampling single-pass and mesh-local.
                seeds = kmeans_pp_indices(
                    landmarks, spec.diag(landmarks), k_pp,
                    n_clusters=cfg.n_clusters, spec=spec)
                seed_x = jnp.take(landmarks, seeds, axis=0)
                u0, k_tilde = self._init_labels(x, diag, seed_x,
                                                spec.diag(seed_x))
                state_in = GlobalState(seed_x, spec.diag(seed_x),
                                       jnp.zeros((cfg.n_clusters,)),
                                       jnp.array(0, jnp.int32))
            else:
                u0, k_tilde = self._init_labels(x, diag, state.medoids,
                                                state.medoid_diag)
                state_in = state

            res = distributed_kkmeans_fit(
                self.mesh, x, landmarks, l_idx, diag, u0, cfg=self.inner_cfg,
                wgt=wgt)
            state, disp = self._medoid_merge(x, diag, res, k_tilde, state_in,
                                             first, wgt)
            history.append(BatchStats(
                inner_iters=int(res.n_iter), cost=float(res.cost),
                displacement=np.asarray(disp), counts=np.asarray(res.counts)))
            if checkpoint_cb is not None:
                checkpoint_cb(state, i)
            if rec.enabled:
                dt = time.perf_counter() - t_batch
                n_iter = history[-1].inner_iters
                # statically-audited bill: per-sync count x n_iter loop
                # sweeps + the audited outside-the-loop collectives (the
                # prologue sync that seeds the s-step carry; the old
                # fixpoint epilogue is gone — the pipelined body syncs
                # the stats of the labels it just wrote).
                bill = self._audited_bill(x, landmarks, l_idx, diag, u0,
                                          wgt)
                per, out = bill["per_iteration"], bill["outside"]
                per_b = bill["per_iteration_bytes"]
                out_b = bill["outside_bytes"]
                rec.counter("collectives/psum",
                            per.get("psum", 0) * n_iter
                            + out.get("psum", 0), batch=i)
                rec.counter("collectives/allgather",
                            per.get("all_gather", 0) * n_iter
                            + out.get("all_gather", 0), batch=i)
                rec.counter("collectives/psum_bytes",
                            per_b.get("psum", 0) * n_iter
                            + out_b.get("psum", 0), batch=i)
                rec.series("batch/wall_seconds", dt, batch=i, rows=n)
                rec.series("inner/cost", history[-1].cost, batch=i)
                rec.series("inner/iters", n_iter, batch=i)
                obs_memory.watermark(
                    rec, batch=i, engine=self.inner_cfg.engine.mode,
                    predicted_bytes=obs_memory.predicted_batch_footprint(
                        cfg, len(xb), xb.shape[1], n_devices=self.d_size))
                # single controller: all devices advance in lock-step, so
                # the timing unit is this process (a multi-host launch
                # contributes one per host).
                monitor.observe(i, {jax.process_index(): dt}, n_rows=len(xb))
                rec.batch_boundary(i)
        if state is None:
            raise ValueError("empty batch iterable")
        return FitResult(state, history, spec=cfg.kernel)
