"""Hypothesis property tests on the system's invariants."""
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import (MachineSpec, b_min, b_min_paper, clustering_accuracy,
                        footprint_bytes, nmi, num_landmarks)
from repro.data.sampling import batch_indices
from repro.ft.straggler import WorkerStatus, replan_rows

# ---------------------------------------------------------------------------
# metrics invariants
# ---------------------------------------------------------------------------

labels_pair = st.integers(2, 6).flatmap(
    lambda c: st.tuples(
        st.lists(st.integers(0, c - 1), min_size=8, max_size=200),
        st.just(c)))


@given(labels_pair, st.randoms())
@settings(max_examples=40, deadline=None)
def test_accuracy_invariant_under_cluster_relabeling(pair, rnd):
    """The majority-vote mapping makes accuracy invariant to any PERMUTATION
    of the predicted cluster ids."""
    labels, c = pair
    y = np.asarray(labels)
    u = np.asarray(labels)[::-1].copy()   # some prediction
    perm = list(range(c))
    rnd.shuffle(perm)
    u_perm = np.asarray(perm)[u]
    assert clustering_accuracy(y, u) == clustering_accuracy(y, u_perm)
    assert abs(nmi(y, u) - nmi(y, u_perm)) < 1e-12


@given(labels_pair)
@settings(max_examples=40, deadline=None)
def test_nmi_bounds_and_perfect(pair):
    labels, _ = pair
    y = np.asarray(labels)
    if len(np.unique(y)) > 1:
        assert abs(nmi(y, y) - 1.0) < 1e-9
    assert -1e-9 <= nmi(y, np.zeros_like(y)) <= 1.0 + 1e-9
    assert clustering_accuracy(y, y) == 1.0


# ---------------------------------------------------------------------------
# sampling invariants (paper §3.1: B disjoint mini-batches covering X)
# ---------------------------------------------------------------------------


@given(st.integers(1, 200), st.integers(1, 17),
       st.sampled_from(["stride", "block"]))
@settings(max_examples=60, deadline=None)
def test_batches_partition_dataset(n, b, strategy):
    if b > n:
        b = n
    idx = batch_indices(n, b, strategy)
    assert len(idx) == b
    allidx = np.concatenate(idx)
    assert len(allidx) == n
    assert len(np.unique(allidx)) == n          # disjoint + complete


@given(st.integers(1, 500), st.floats(0.01, 1.0), st.integers(1, 8),
       st.integers(1, 8))
@settings(max_examples=60, deadline=None)
def test_num_landmarks_bounds(batch, s, c, mult):
    c = min(c, batch)
    if mult > batch:
        mult = 1
    try:
        l = num_landmarks(batch, s, n_clusters=c, multiple_of=mult)
    except ValueError:
        return  # batch too small for C landmarks in multiples — documented
    assert c <= l <= batch or l == (batch // mult) * mult
    assert l >= 1
    if mult > 1:
        assert l % mult == 0


# ---------------------------------------------------------------------------
# memory planner (Eq.19) invariants
# ---------------------------------------------------------------------------


@given(st.integers(10_000, 10_000_000), st.integers(2, 100),
       st.integers(1, 1024))
@settings(max_examples=60, deadline=None)
def test_bmin_is_minimal_and_sufficient(n, c, p):
    m = MachineSpec(memory_bytes=2e9, n_processors=p)
    b = b_min(n, c, m)
    assert footprint_bytes(n, b, c, p) <= m.memory_bytes * (1 + 1e-9)
    if b > 1:
        assert footprint_bytes(n, b - 1, c, p) > m.memory_bytes


@given(st.integers(100_000, 10_000_000), st.integers(2, 50))
@settings(max_examples=30, deadline=None)
def test_bmin_matches_paper_formula_in_paper_regime(n, c):
    """The paper's printed Eq.19 drops a 4/P factor on R/Q under the root
    (repro.core.memory docstring), so for C << R/Q the exact solution is
    sqrt(P/4) x the printed one. Verify THAT relationship — it documents
    the transcription bug faithfully. At P = 4 the two coincide."""
    m = MachineSpec(memory_bytes=8e9, n_processors=16)
    exact, printed = b_min(n, c, m), b_min_paper(n, c, m)
    if printed >= 4:                       # below that, ceil() dominates
        ratio = exact / printed
        assert 0.8 <= ratio / 2.0 <= 1.3   # sqrt(16/4) = 2

    m4 = MachineSpec(memory_bytes=8e9, n_processors=4)
    assert abs(b_min(n, c, m4) - b_min_paper(n, c, m4)) <= 1


@given(st.integers(10_000, 1_000_000), st.integers(2, 20),
       st.floats(0.05, 1.0))
@settings(max_examples=40, deadline=None)
def test_footprint_monotonic(n, c, s):
    """More batches -> less memory; sparser landmarks -> less memory;
    the fused path never needs more than the materializing path."""
    p = 16
    f1 = footprint_bytes(n, 1, c, p, s=s)
    f4 = footprint_bytes(n, 4, c, p, s=s)
    assert f4 < f1
    assert footprint_bytes(n, 4, c, p, s=s / 2) <= f4
    assert footprint_bytes(n, 4, c, p, s=s, fused=True) <= f4


# ---------------------------------------------------------------------------
# straggler replanner invariants
# ---------------------------------------------------------------------------


@given(st.integers(1, 64), st.integers(0, 63),
       st.lists(st.floats(0.1, 100.0), min_size=1, max_size=16),
       st.integers(0, 3))
@settings(max_examples=60, deadline=None)
def test_replan_rows_exact_cover(nq, extra, speeds, n_dead):
    n_rows = nq * 8 + extra
    statuses = [WorkerStatus(i, rows_per_second=s)
                for i, s in enumerate(speeds)]
    for i in range(min(n_dead, len(statuses) - 1)):
        statuses[i] = WorkerStatus(i, healthy=False)
    plan = replan_rows(n_rows, statuses)
    spans = sorted(plan.values())
    # exact, non-overlapping cover of [0, n_rows)
    cursor = 0
    for start, size in spans:
        assert start == cursor and size >= 0
        cursor += size
    assert cursor == n_rows
    for i in range(min(n_dead, len(statuses) - 1)):
        assert i not in plan                      # dead workers get nothing


# ---------------------------------------------------------------------------
# merge rule (Eq.11-13) invariants
# ---------------------------------------------------------------------------


@given(st.lists(st.floats(0.0, 1e6), min_size=2, max_size=8),
       st.lists(st.floats(0.0, 1e6), min_size=2, max_size=8))
@settings(max_examples=60, deadline=None)
def test_merge_alpha_is_convex_and_empty_safe(batch_counts, global_counts):
    k = min(len(batch_counts), len(global_counts))
    bc = jnp.asarray(batch_counts[:k], jnp.float32)
    gc = jnp.asarray(global_counts[:k], jnp.float32)
    alpha = bc / jnp.maximum(bc + gc, 1.0)
    a = np.asarray(alpha)
    assert np.all(a >= 0.0) and np.all(a <= 1.0)       # convex combination
    assert np.all(a[np.asarray(bc) == 0.0] == 0.0)     # empty batch cluster
