"""Streaming sharded ingestion: CSR shard/concat/slice primitives against
the dense oracle, the sketch-on-shard invariant, and the planner's host
staging footprint."""
import jax
import numpy as np
import pytest

from repro.approx import make_count_sketch
from repro.core import KernelSpec, MachineSpec, host_staging_bytes, plan
from repro.data.sparse import (concat_csr, csr_from_dense, shard_csr,
                               shard_row_mask, slice_rows, split_csr,
                               to_dense)

# ---------------------------------------------------------------------------
# shard_csr — property-style oracle checks
# ---------------------------------------------------------------------------


def _random_sparse(n, d, density, seed):
    rng = np.random.default_rng(seed)
    return (rng.normal(size=(n, d)).astype(np.float32)
            * (rng.random((n, d)) < density))


@pytest.mark.parametrize("n,d,density,p", [
    (23, 17, 0.3, 1), (23, 17, 0.3, 3), (23, 17, 0.3, 4),
    (24, 8, 0.5, 4),                    # divides exactly: no row padding
    (5, 8, 0.0, 2),                     # all-zero matrix: zero nnz capacity
    (7, 8, 0.4, 7), (7, 8, 0.4, 10),    # one row per shard / p > n
    (64, 33, 0.05, 8),
])
def test_shard_csr_matches_dense_row_split(n, d, density, p):
    """to_dense(shard_csr(b, p)[k]) == the dense row block, zero-padded to
    equal rows; all shards share one leaf geometry; the mask flags exactly
    the padded tail."""
    x = _random_sparse(n, d, density, seed=n + p)
    shards = shard_csr(csr_from_dense(x), p)
    mask = shard_row_mask(n, p)
    rows = -(-n // p)
    assert len(shards) == p
    assert {(s.shape, s.nnz, len(np.asarray(s.indptr))) for s in shards} \
        == {((rows, d), shards[0].nnz, rows + 1)}
    for k, s in enumerate(shards):
        want = np.zeros((rows, d), np.float32)
        blk = x[min(k * rows, n):min((k + 1) * rows, n)]
        want[:len(blk)] = blk
        np.testing.assert_array_equal(to_dense(s), want)
        assert int(mask[k].sum()) == len(blk)
        # padded rows are empty, not replicated — the mask plus empty rows
        # is what keeps them out of the centroid means.
        assert (to_dense(s)[~mask[k]] == 0.0).all()


def test_shard_csr_nnz_multiple_alignment():
    b = csr_from_dense(_random_sparse(10, 16, 0.5, 0))
    for s in shard_csr(b, 3, nnz_multiple=8):
        assert s.nnz % 8 == 0
        assert s.nnz >= int(np.asarray(s.indptr)[-1])


def test_sketch_on_slack_shard_equals_sketch_on_oracle():
    """The O(nnz) count-sketch must ignore slack capacity and padded rows —
    z(shard) == z(to_dense(shard)) bit-for-bit is the invariant the
    per-device distributed embed relies on."""
    x = _random_sparse(19, 32, 0.3, 1)
    fmap = make_count_sketch(jax.random.PRNGKey(0), 32, 16,
                             KernelSpec("linear"))
    for s in shard_csr(csr_from_dense(x), 4):
        np.testing.assert_array_equal(np.asarray(fmap(s)),
                                      np.asarray(fmap(to_dense(s))))


def test_concat_slice_roundtrip_and_indptr_surgery():
    x = _random_sparse(31, 9, 0.4, 2)
    b = csr_from_dense(x)
    parts = [slice_rows(b, i, j) for i, j in [(0, 4), (4, 4), (4, 20), (20, 31)]]
    assert parts[1].shape == (0, 9)                      # empty slice ok
    back = concat_csr(parts)
    np.testing.assert_array_equal(to_dense(back), x)
    # concat of slack-capacity shards drops the slack
    np.testing.assert_array_equal(to_dense(concat_csr(shard_csr(b, 4))),
                                  np.concatenate([x, np.zeros((1, 9))]))


def test_concat_csr_rejects_mismatched_columns():
    a = csr_from_dense(_random_sparse(3, 4, 0.5, 3))
    c = csr_from_dense(_random_sparse(3, 5, 0.5, 3))
    with pytest.raises(ValueError, match="column counts"):
        concat_csr([a, c])


def test_split_csr_unchanged_by_capacity_contract():
    """split_csr (stride) still matches the dense index-set oracle after the
    slack-capacity changes."""
    x = _random_sparse(22, 11, 0.35, 4)
    b = csr_from_dense(x)
    for sp, dn in zip(split_csr(b, 3, strategy="stride"),
                      [x[i::3] for i in range(3)]):
        np.testing.assert_array_equal(to_dense(sp), dn)


def test_exact_method_rejects_csr_batches_clearly():
    """method='exact' cannot consume CSR — must fail with a named error at
    the fit boundary, not an obscure TypeError deep in the kernel path."""
    from repro.core import MiniBatchConfig
    from repro.core.minibatch import fit_dataset

    b = csr_from_dense(_random_sparse(30, 8, 0.5, 6))
    cfg = MiniBatchConfig(n_clusters=3, n_batches=2)
    with pytest.raises(ValueError, match="exact.*CSRBatch"):
        fit_dataset(b, cfg)


# ---------------------------------------------------------------------------
# planner: host-side staging footprint
# ---------------------------------------------------------------------------


def test_plan_host_footprint_counts_prefetch_depth():
    mach = MachineSpec(memory_bytes=16e9, n_processors=64)
    p0 = plan(1_000_000, 50, mach, d=256, prefetch_depth=0)
    p3 = plan(1_000_000, 50, mach, d=256, prefetch_depth=3)
    assert p3.host_footprint == pytest.approx(4.0 * p0.host_footprint)
    # a staged dense batch is Q * N/B * d bytes
    assert p0.host_footprint == pytest.approx(4.0 * (1_000_000 / p0.b) * 256)


def test_plan_host_footprint_prices_sparse_when_sketch_wins():
    mach = MachineSpec(memory_bytes=16e9, n_processors=64)
    sk = plan(1_000_000, 50, mach, d=47236, sketchable=True, density=2e-3,
              prefetch_depth=2)
    dn = plan(1_000_000, 50, mach, d=47236, prefetch_depth=2)
    assert sk.method == "sketch"
    assert sk.host_footprint < 0.05 * dn.host_footprint   # nnz-priced
    nb = 1_000_000 / sk.b
    assert sk.host_footprint == pytest.approx(
        3.0 * (2 * 4 * 2e-3 * nb * 47236 + 4 * (nb + 1)))
    assert host_staging_bytes(1000, 10, d=64, prefetch_depth=2) == \
        pytest.approx(3 * 4 * 100 * 64)
