"""Distributed runtime tests. Multi-device cases run in a SUBPROCESS with
XLA_FLAGS=--xla_force_host_platform_device_count=8 so the rest of the suite
keeps seeing exactly one device (jax locks the count on first init)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_subprocess(body: str) -> dict:
    """Run ``body`` under 8 forced host devices; it must print one JSON."""
    script = textwrap.dedent("""\
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import json
        import numpy as np
        import jax
        import jax.numpy as jnp
    """) + textwrap.dedent(body)
    env = dict(os.environ,
               PYTHONPATH=os.path.join(_ROOT, "src"))
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=540)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-4000:]}"
    return json.loads(out.stdout.strip().splitlines()[-1])


@pytest.mark.slow
@pytest.mark.parametrize("mode", ["materialize", "fused", "tiled"])
def test_distributed_matches_single_device(mode):
    """The 2-D sharded inner loop (rows x landmarks) must produce the same
    labels and medoids as the single-device reference, all three GramEngine
    modes."""
    res = _run_subprocess(f"""
        from repro.core import MiniBatchConfig, KernelSpec
        from repro.core.minibatch import fit_dataset, predict
        from repro.distributed.outer import DistributedMiniBatchKMeans
        from repro.data.sampling import split_batches

        rng = np.random.default_rng(0)
        centers = np.array([[0.25,0.25],[0.75,0.75],[0.25,0.75],[0.75,0.25]])
        X = np.concatenate([rng.normal(c, 0.05, size=(512,2))
                            for c in centers]).astype(np.float32)
        y = np.repeat(np.arange(4), 512)
        perm = rng.permutation(len(X)); X, y = X[perm], y[perm]

        mesh = jax.make_mesh((4, 2), ("data", "model"))
        cfg = MiniBatchConfig(n_clusters=4, n_batches=4, s=1.0,
                              kernel=KernelSpec("rbf", gamma=8.0), seed=0)
        km = DistributedMiniBatchKMeans(mesh, cfg, mode="{mode}")
        res = km.fit(split_batches(X, 4, strategy="stride"))
        labels = predict(jnp.asarray(X), res.state.medoids,
                         res.state.medoid_diag, spec=cfg.kernel)

        from repro.core.metrics import clustering_accuracy
        acc = clustering_accuracy(y, np.asarray(labels))
        total = int(np.asarray(res.state.cardinalities).sum())
        print(json.dumps({{"acc": acc, "total": total, "n": len(X)}}))
    """)
    assert res["acc"] > 0.95
    assert res["total"] == res["n"]


@pytest.mark.slow
def test_distributed_inner_identical_to_host_inner():
    """Bitwise-level agreement (labels) between repro.core.kkmeans and the
    shard_map inner loop from the SAME init on the SAME batch — the shared
    GramEngine means this must hold under every engine mode."""
    res = _run_subprocess("""
        from repro.core import KernelSpec
        from repro.core.kkmeans import kkmeans_fit
        from repro.distributed.inner import (DistributedInnerConfig,
                                             distributed_kkmeans_fit)

        rng = np.random.default_rng(1)
        X = rng.normal(size=(256, 8)).astype(np.float32)
        spec = KernelSpec("rbf", gamma=0.2)
        x = jnp.asarray(X)
        diag = spec.diag(x)
        l_idx = jnp.arange(256, dtype=jnp.int32)      # s = 1
        u0 = jnp.asarray(rng.integers(0, 5, 256), jnp.int32)

        host = kkmeans_fit(x, l_idx, diag, u0, spec=spec, n_clusters=5)

        mesh = jax.make_mesh((4, 2), ("data", "model"))
        out = {}
        for mode in ("materialize", "fused", "tiled"):
            cfg = DistributedInnerConfig(n_clusters=5, kernel=spec,
                                         engine=mode,
                                         row_axes=("data",),
                                         col_axis="model")
            dist = distributed_kkmeans_fit(mesh, x, x, l_idx, diag, u0,
                                           cfg=cfg)
            out[mode] = {
                "same": bool(jnp.all(host.labels == dist.labels)),
                "g_err": float(jnp.max(jnp.abs(host.g - dist.g))),
                "cost_err": abs(float(host.cost) - float(dist.cost))}
        print(json.dumps(out))
    """)
    for mode, r in res.items():
        assert r["same"], f"{mode}: distributed labels diverged from host"
        assert r["g_err"] < 1e-4, mode
        assert r["cost_err"] < 1e-2, mode


@pytest.mark.slow
def test_faithful_1d_distribution_mode():
    """col_axis=None recovers the paper's exact 1-D row-wise algorithm."""
    res = _run_subprocess("""
        from repro.core import KernelSpec
        from repro.core.kkmeans import kkmeans_fit
        from repro.distributed.inner import (DistributedInnerConfig,
                                             distributed_kkmeans_fit)

        rng = np.random.default_rng(2)
        X = rng.normal(size=(128, 4)).astype(np.float32)
        spec = KernelSpec("rbf", gamma=0.3)
        x = jnp.asarray(X)
        diag = spec.diag(x)
        l_idx = jnp.arange(128, dtype=jnp.int32)
        u0 = jnp.asarray(rng.integers(0, 3, 128), jnp.int32)
        host = kkmeans_fit(x, l_idx, diag, u0, spec=spec, n_clusters=3)

        mesh = jax.make_mesh((8,), ("data",))
        cfg = DistributedInnerConfig(n_clusters=3, kernel=spec,
                                     row_axes=("data",), col_axis=None)
        dist = distributed_kkmeans_fit(mesh, x, x, l_idx, diag, u0, cfg=cfg)
        print(json.dumps({"same": bool(jnp.all(host.labels == dist.labels))}))
    """)
    assert res["same"]


@pytest.mark.slow
def test_collective_structure_matches_paper():
    """The compiled inner iteration must contain the paper's two collectives
    (all-gather U, all-reduce g) and must NOT move the kernel matrix: total
    collective bytes per iteration << |K| bytes."""
    res = _run_subprocess("""
        from repro.core import KernelSpec
        from repro.distributed.inner import (DistributedInnerConfig,
                                             distributed_kkmeans_fit)
        from repro.launch.dryrun import collective_bytes
        from functools import partial

        rng = np.random.default_rng(3)
        n, d, C = 1024, 16, 4
        x = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
        spec = KernelSpec("rbf", gamma=0.1)
        diag = spec.diag(x)
        l_idx = jnp.arange(n, dtype=jnp.int32)
        u0 = jnp.zeros((n,), jnp.int32)

        mesh = jax.make_mesh((8,), ("data",))
        cfg = DistributedInnerConfig(n_clusters=C, kernel=spec,
                                     row_axes=("data",), col_axis=None)
        fn = partial(distributed_kkmeans_fit, mesh, cfg=cfg)
        lowered = jax.jit(lambda *a: fn(*a)).lower(x, x, l_idx, diag, u0)
        txt = lowered.compile().as_text()
        coll = collective_bytes(txt)
        k_bytes = n * n * 4
        print(json.dumps({
            "ag": coll["counts"]["all-gather"],
            "ar": coll["counts"]["all-reduce"],
            "total": coll["total_bytes"], "k_bytes": k_bytes}))
    """)
    assert res["ag"] >= 1, "missing the paper's all-gather(U)"
    assert res["ar"] >= 1, "missing the paper's all-reduce(g)"
    # kernel matrix never crosses the network (paper's key property):
    assert res["total"] < 0.05 * res["k_bytes"]


@pytest.mark.slow
def test_distributed_csr_fit_equals_dense_oracle():
    """Sharded-CSR ingestion correctness (interpret-mode, 8 host devices):
    the distributed fit on prefetch-staged CSR shards must label exactly
    like the single-host fit on the densified oracle — the pipeline
    (shard_csr surgery, slack capacity, masked padding, per-device O(nnz)
    sketch, psum merge) adds nothing."""
    res = _run_subprocess("""
        from repro.core import KernelSpec, MiniBatchConfig
        from repro.core.minibatch import fit
        from repro.data import split_batches, split_csr, to_dense
        from repro.data.synthetic import make_rcv1_sparse
        from repro.distributed.embed import DistributedEmbedKMeans

        xs, y = make_rcv1_sparse(2048, vocab=4096, n_classes=8, seed=0)
        cfg = MiniBatchConfig(n_clusters=8, n_batches=4,
                              kernel=KernelSpec("linear"), seed=0,
                              method="sketch", embed_dim=128)
        dense = to_dense(xs)
        res_host = fit(split_batches(dense, 4, strategy="stride"), cfg)

        mesh = jax.make_mesh((8,), ("data",))
        km = DistributedEmbedKMeans(mesh, cfg)
        with km.source(split_csr(xs, 4, strategy="stride"), depth=2) as src:
            res_dist = km.fit(src)

        lab_d = np.asarray(res_dist.predict(xs))
        lab_h = np.asarray(res_host.predict(dense))
        cerr = float(np.abs(np.asarray(res_dist.state.centroids)
                            - np.asarray(res_host.state.centroids)).max())
        print(json.dumps({
            "same": bool((lab_d == lab_h).all()), "cerr": cerr,
            "total": float(np.asarray(res_dist.state.cardinalities).sum()),
            "n": len(xs)}))
    """)
    assert res["same"], "distributed CSR labels diverged from dense oracle"
    assert res["cerr"] < 1e-5
    assert res["total"] == res["n"]     # masked padding never hits counts


@pytest.mark.slow
def test_staging_tail_batch_smaller_than_mesh():
    """Regression: a stream's last batch can be SMALLER than the mesh row
    count — staging used to index past the batch (CSR) or ship a short
    array into the row sharding (dense). Modulo-replicated ghost rows must
    keep the fit running with exact masked cardinalities; and a pre-staged
    first batch must give a data-dependent map (Nystrom) the same sample as
    the inline path — identical centroids either way."""
    res = _run_subprocess("""
        from repro.core import KernelSpec, MiniBatchConfig
        from repro.data.sparse import csr_from_dense
        from repro.distributed.embed import DistributedEmbedKMeans

        rng = np.random.default_rng(0)
        n = 2048 + 3                              # 3-row tail on 8 devices
        x = rng.normal(size=(n, 64)).astype(np.float32)
        x *= (rng.random((n, 64)) < 0.2)
        mesh = jax.make_mesh((8,), ("data",))

        cfg = MiniBatchConfig(n_clusters=4, n_batches=2,
                              kernel=KernelSpec("linear"), seed=0,
                              method="sketch", embed_dim=64)
        batches = [csr_from_dense(x[:2048]), csr_from_dense(x[2048:])]
        km = DistributedEmbedKMeans(mesh, cfg)
        with km.source(batches, depth=2) as src:
            res_csr = km.fit(src)
        dense_total = float(np.asarray(
            DistributedEmbedKMeans(mesh, cfg).fit([x[:2048], x[2048:]])
            .state.cardinalities).sum())

        # nystrom: staged-first-batch sampling == inline sampling (pad > 0)
        cfg_ny = MiniBatchConfig(n_clusters=3, n_batches=1,
                                 kernel=KernelSpec("rbf", gamma=0.5),
                                 seed=1, method="nystrom", embed_dim=12)
        xb = rng.normal(size=(1021, 16)).astype(np.float32)   # pad = 3
        inline = DistributedEmbedKMeans(mesh, cfg_ny).fit([xb])
        km2 = DistributedEmbedKMeans(mesh, cfg_ny)
        with km2.source([xb], depth=1) as src:
            staged = km2.fit(src)
        ny_same = bool((np.asarray(inline.state.centroids)
                        == np.asarray(staged.state.centroids)).all())

        # non-divisible FIRST batch: k-means++ must seed over the unpadded
        # rows, or ghost rows shift every D^2 draw and the distributed fit
        # silently diverges from the single-host oracle.
        from repro.core.minibatch import fit
        from repro.data.sparse import split_csr, to_dense
        first_nd = [csr_from_dense(x[:1027]), csr_from_dense(x[1027:2048])]
        km_nd = DistributedEmbedKMeans(mesh, cfg)
        res_nd = km_nd.fit(first_nd)
        host_nd = fit([x[:1027], x[1027:2048]], cfg)
        seed_same = bool((np.asarray(res_nd.predict(csr_from_dense(x[:2048])))
                          == np.asarray(host_nd.predict(x[:2048]))).all())

        # exact path on a stream with the same 3-row tail (elastic
        # advertises live streams for every method): modulo padding must
        # keep the row sharding divisible.
        cfg_ex = MiniBatchConfig(n_clusters=4, n_batches=2, s=1.0,
                                 kernel=KernelSpec("rbf", gamma=0.5), seed=0)
        from repro.distributed.outer import DistributedMiniBatchKMeans
        res_ex = DistributedMiniBatchKMeans(mesh, cfg_ex).fit(
            [x[:2048], x[2048:]])
        exact_batches = int(res_ex.state.batches_done)

        print(json.dumps({
            "csr_total": float(np.asarray(res_csr.state.cardinalities).sum()),
            "dense_total": dense_total, "n": n, "ny_same": ny_same,
            "seed_same": seed_same, "exact_batches": exact_batches}))
    """)
    assert res["csr_total"] == res["n"]      # ghost rows masked out
    assert res["dense_total"] == res["n"]
    assert res["ny_same"], "staged Nystrom sampling diverged from inline"
    assert res["seed_same"], "non-divisible first batch: seeding diverged"
    assert res["exact_batches"] == 2         # tail batch staged, not crashed


@pytest.mark.slow
def test_exact_ghost_rows_unbiased_when_p_does_not_divide_batch():
    """Regression for the exact-path ghost-row bias (old ROADMAP item):
    with P∤(N/B), the modulo-replicated padding rows used to be landmark
    candidates and to score in the medoid/merge argmins, perturbing
    cardinalities and the Eq.12 alpha by O(P/(N/B)). Selection now runs
    over the unpadded rows and the argmins/cost are weight-masked, so —
    starting both paths from the same state — the distributed fit must
    reproduce the single-host cardinalities exactly and the medoids
    bit-for-bit."""
    res = _run_subprocess("""
        from repro.core import KernelSpec, MiniBatchConfig
        from repro.core.minibatch import fit as host_fit
        from repro.distributed.outer import DistributedMiniBatchKMeans

        rng = np.random.default_rng(0)
        x = rng.normal(size=(2048 + 1027, 8)).astype(np.float32)
        mesh = jax.make_mesh((8,), ("data",))
        # landmark_multiple_of matches the mesh so |L| agrees across paths
        cfg = MiniBatchConfig(n_clusters=5, n_batches=2, s=0.5,
                              kernel=KernelSpec("rbf", gamma=0.5),
                              max_inner_iters=4, seed=3,
                              landmark_multiple_of=8)
        batches = [x[:2048], x[2048:]]          # second batch: 1027 % 8 != 0
        st0 = host_fit([batches[0]], cfg).state  # shared starting state

        dist = DistributedMiniBatchKMeans(mesh, cfg).fit([batches[1]],
                                                         state=st0)
        host = host_fit([batches[1]], cfg, state=st0)
        cards_equal = bool((np.asarray(dist.state.cardinalities)
                            == np.asarray(host.state.cardinalities)).all())
        medoid_diff = float(np.abs(np.asarray(dist.state.medoids)
                                   - np.asarray(host.state.medoids)).max())
        # with s=0.5 cardinalities count LANDMARK rows (Eq.14 expansion):
        # 1024 for the first batch + 520 for the 1027-row tail batch —
        # any ghost landmark would show up as excess mass here.
        total = float(np.asarray(dist.state.cardinalities).sum())
        print(json.dumps({"cards_equal": cards_equal,
                          "medoid_diff": medoid_diff,
                          "total": total}))
    """)
    assert res["cards_equal"], "ghost rows still biased the cardinalities"
    assert res["medoid_diff"] == 0.0
    assert res["total"] == 1024 + 520


@pytest.mark.slow
def test_distributed_exact_resume_bit_identical():
    """Regression (same class as PR 2's minibatch fix): the distributed
    exact path must draw per-batch keys purely from (seed, i), so a
    checkpoint-resumed fit is bit-identical to the uninterrupted run —
    non-separable data, s < 1, truncated inner loop, so any key divergence
    shows in the medoids."""
    res = _run_subprocess("""
        from repro.core import KernelSpec, MiniBatchConfig
        from repro.data.sampling import split_batches
        from repro.distributed.outer import DistributedMiniBatchKMeans

        rng = np.random.default_rng(11)
        x = rng.normal(size=(1024, 8)).astype(np.float32)
        cfg = MiniBatchConfig(n_clusters=6, n_batches=4, s=0.4,
                              kernel=KernelSpec("rbf", gamma=0.5),
                              max_inner_iters=3, seed=5,
                              landmark_multiple_of=8)
        batches = split_batches(x, 4, strategy="stride")
        mesh = jax.make_mesh((8,), ("data",))

        km = DistributedMiniBatchKMeans(mesh, cfg)
        straight = km.fit(batches)
        half = DistributedMiniBatchKMeans(mesh, cfg).fit(batches[:2])
        resumed = DistributedMiniBatchKMeans(mesh, cfg).fit(
            batches[2:], state=half.state)
        same = bool((np.asarray(straight.state.medoids)
                     == np.asarray(resumed.state.medoids)).all())
        print(json.dumps({"same": same}))
    """)
    assert res["same"], "exact distributed resume diverged (key schedule)"


@pytest.mark.slow
def test_streaming_sharded_csr_end_to_end():
    """Acceptance: RCV1-scale synthetic stream (d >= 40k) through the full
    pipeline — ragged CSR chunks -> stream_blocks -> prefetch staging ->
    per-device O(nnz) sketch -> psum Lloyd — with the dense paths BOOBY-
    TRAPPED so any [n, d] densification anywhere in the pipeline fails the
    test. Labels must equal the single-host dense oracle bit-for-bit, and a
    mid-stream checkpoint resume (elastic: smaller mesh) must reproduce the
    straight run exactly."""
    res = _run_subprocess("""
        import tempfile
        from repro.core import KernelSpec, MiniBatchConfig
        from repro.core.minibatch import fit
        from repro.data import BatchSource, split_batches
        from repro.data.sparse import is_sparse, slice_rows, to_dense
        from repro.data.synthetic import make_rcv1_sparse
        from repro.distributed.embed import DistributedEmbedKMeans
        from repro.ft.checkpoint import CheckpointManager
        from repro.ft.elastic import ElasticClusteringRunner, SimulatedFailure
        import repro.approx.sketch as sketch_mod
        import repro.data.sparse as sparse_mod

        n, vocab, c, b = 2048, 40960, 10, 4
        xs, y = make_rcv1_sparse(n, vocab=vocab, n_classes=c, seed=0)
        dense = to_dense(xs)                       # oracle, built up front
        cfg = MiniBatchConfig(n_clusters=c, n_batches=b, sampling="block",
                              kernel=KernelSpec("linear"), seed=0,
                              method="sketch", embed_dim=128)

        rng = np.random.default_rng(1)
        def stream():
            bounds = np.unique(np.concatenate(
                [[0], rng.integers(1, n, size=17), [n]]))
            for a, z in zip(bounds[:-1], bounds[1:]):
                chunk = slice_rows(xs, int(a), int(z))
                assert is_sparse(chunk)
                yield chunk

        # booby-trap every densification route while the pipeline runs
        def boom(*a, **k):
            raise AssertionError("dense [n, d] path hit in CSR pipeline")
        saved = (sparse_mod.to_dense, sketch_mod.count_sketch_features,
                 sketch_mod.tensor_sketch_features)
        sparse_mod.to_dense = boom
        sketch_mod.count_sketch_features = boom
        sketch_mod.tensor_sketch_features = boom

        mesh = jax.make_mesh((8,), ("data",))
        km = DistributedEmbedKMeans(mesh, cfg)
        src = BatchSource.from_stream(stream(), n // b, stage=km.stage,
                                      prefetch=2)
        with src:
            straight = km.fit(src)

        # mid-stream failure after 2 committed batches, elastic resume on a
        # SMALLER mesh from the checkpoint (fmap restored from disk).
        with tempfile.TemporaryDirectory() as ckdir:
            runner = ElasticClusteringRunner(cfg, CheckpointManager(ckdir))
            try:
                runner.run(mesh, BatchSource.from_stream(stream(), n // b),
                           fail_after=2)
                raise SystemExit("expected SimulatedFailure")
            except SimulatedFailure:
                pass
            resumed = runner.run(
                jax.make_mesh((4,), ("data",)),
                BatchSource.from_stream(stream(), n // b))

        (sparse_mod.to_dense, sketch_mod.count_sketch_features,
         sketch_mod.tensor_sketch_features) = saved

        # oracle: single-host fit on the dense matrix, same block batches
        oracle = fit(split_batches(dense, b, strategy="block"), cfg)
        lab_s = np.asarray(straight.predict(xs))
        lab_o = np.asarray(oracle.predict(dense))
        lab_r = np.asarray(resumed.predict(xs))
        print(json.dumps({
            "d": vocab,
            "oracle_same": bool((lab_s == lab_o).all()),
            "resume_same": bool((lab_r == lab_s).all()),
            "batches": int(resumed.state.batches_done),
            "cards": float(np.asarray(straight.state.cardinalities).sum())}))
    """)
    assert res["d"] >= 40000
    assert res["oracle_same"], "streaming labels != single-host dense oracle"
    assert res["resume_same"], "mid-stream resume diverged from straight run"
    assert res["batches"] == 4
    assert res["cards"] == 2048.0


@pytest.mark.slow
def test_sstep_matches_synchronous_on_both_layouts():
    """s_step=2 runs two local Lloyd refinements per global sync against
    frozen remote stats — a different trajectory than the fully-synchronous
    loop, but on separable data it must land on the SAME final partition,
    on both the paper's 1-D layout and the 2-D rows x landmarks mesh,
    without inflating the global sync count (n_iter counts loop bodies
    = syncs)."""
    res = _run_subprocess("""
        from repro.core import KernelSpec
        from repro.distributed.inner import (DistributedInnerConfig,
                                             distributed_kkmeans_fit)

        rng = np.random.default_rng(7)
        centers = np.array([[0.2, 0.2], [0.8, 0.8], [0.2, 0.8], [0.8, 0.2]])
        X = np.concatenate([rng.normal(c, 0.05, size=(128, 2))
                            for c in centers]).astype(np.float32)
        perm = rng.permutation(len(X))
        x = jnp.asarray(X[perm])
        spec = KernelSpec("rbf", gamma=8.0)
        diag = spec.diag(x)
        l_idx = jnp.arange(512, dtype=jnp.int32)
        u0 = jnp.asarray(rng.integers(0, 4, 512), jnp.int32)

        layouts = {
            "1d": (jax.make_mesh((8,), ("data",)),
                   dict(row_axes=("data",), col_axis=None)),
            "2d": (jax.make_mesh((4, 2), ("data", "model")),
                   dict(row_axes=("data",), col_axis="model")),
        }
        out = {}
        for name, (mesh, ax) in layouts.items():
            runs = {}
            for s in (1, 2):
                cfg = DistributedInnerConfig(n_clusters=4, kernel=spec,
                                             s_step=s, **ax)
                runs[s] = distributed_kkmeans_fit(mesh, x, x, l_idx, diag,
                                                  u0, cfg=cfg)
            # same PARTITION, modulo cluster index permutation: the s-step
            # trajectory differs (refinements argmin stale stats), so the
            # index an escaping cluster lands on may permute even when the
            # induced partition is identical.
            l1 = np.asarray(runs[1].labels).tolist()
            l2 = np.asarray(runs[2].labels).tolist()
            pairs = set(zip(l1, l2))
            out[name] = {
                "same": len(pairs) == len(set(l1)) == len(set(l2)),
                "cost_err": abs(float(runs[1].cost) - float(runs[2].cost)),
                "syncs_1": int(runs[1].n_iter),
                "syncs_2": int(runs[2].n_iter)}
        print(json.dumps(out))
    """)
    for name, r in res.items():
        assert r["same"], f"{name}: s_step=2 partition != synchronous loop"
        assert r["cost_err"] < 1e-3, name
        # the communication-avoiding point: global syncs must not blow up
        # relative to the synchronous loop (+s allowed: on tiny problems
        # that converge in a couple of sweeps, certifying the fixpoint
        # under frozen remote stats can cost extra syncs; the ~1/s
        # reduction is measured on longer runs by
        # benchmarks/fig6_scaling.py).
        assert r["syncs_2"] <= r["syncs_1"] + 2, name
        assert r["syncs_2"] >= 1, name


@pytest.mark.slow
def test_sstep_2d_replicas_stay_consistent():
    """s-step refinements are column-local, so model-axis replicas of the
    same row block would silently diverge on a 2-D mesh if the sync did
    not canonicalize labels over the model axis — the stats psum would
    then mix partials of DIFFERENT label vectors and the returned f/g/
    counts would not describe the returned labels at all. NON-separable
    data (uniform noise, no converged fixpoint in a few sweeps) forces
    real divergence; the contract under test: the mesh result's f/g/counts
    are the stats of its labels, to a host-side recompute."""
    res = _run_subprocess("""
        from repro.core import KernelSpec
        from repro.core.engine import (GramEngine, engine_stats_raw,
                                       finalize_stats)
        from repro.distributed.inner import (DistributedInnerConfig,
                                             distributed_kkmeans_fit)

        rng = np.random.default_rng(3)
        x = jnp.asarray(rng.uniform(size=(512, 6)), jnp.float32)
        spec = KernelSpec("rbf", gamma=2.0)
        diag = spec.diag(x)
        l_idx = jnp.arange(512, dtype=jnp.int32)
        u0 = jnp.asarray(rng.integers(0, 5, 512), jnp.int32)

        mesh = jax.make_mesh((4, 2), ("data", "model"))
        out = {}
        for s in (2, 4):
            cfg = DistributedInnerConfig(
                n_clusters=5, kernel=spec, max_iters=8, s_step=s,
                row_axes=("data",), col_axis="model")
            res = distributed_kkmeans_fit(mesh, x, x, l_idx, diag, u0,
                                          cfg=cfg)
            # host-side stats of the labels the mesh returned
            eng = GramEngine(mode="materialize")
            op_xl = eng.prepare(spec, x, x)
            u = res.labels
            f, g, counts = finalize_stats(*engine_stats_raw(
                eng, spec, op_xl, op_xl, u, u, 5))
            out[s] = {
                "counts_ok": bool(jnp.all(counts == res.counts)),
                "f_err": float(jnp.max(jnp.abs(f - res.f))),
                "g_err": float(jnp.max(jnp.abs(g - res.g)))}
        print(json.dumps(out))
    """)
    for s, r in res.items():
        assert r["counts_ok"], \
            f"s={s}: returned counts != counts of returned labels"
        # fp-reduction-order tolerance only — a single flipped label moves
        # f/g entries by O(kernel value) >> this.
        assert r["f_err"] < 1e-4, f"s={s}: f inconsistent with labels"
        assert r["g_err"] < 1e-4, f"s={s}: g inconsistent with labels"
