"""Distributed runtime tests. Multi-device cases run in a SUBPROCESS with
XLA_FLAGS=--xla_force_host_platform_device_count=8 so the rest of the suite
keeps seeing exactly one device (jax locks the count on first init)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_subprocess(body: str) -> dict:
    """Run ``body`` under 8 forced host devices; it must print one JSON."""
    script = textwrap.dedent("""\
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import json
        import numpy as np
        import jax
        import jax.numpy as jnp
    """) + textwrap.dedent(body)
    env = dict(os.environ,
               PYTHONPATH=os.path.join(_ROOT, "src"))
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=540)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-4000:]}"
    return json.loads(out.stdout.strip().splitlines()[-1])


@pytest.mark.slow
@pytest.mark.parametrize("mode", ["materialize", "fused"])
def test_distributed_matches_single_device(mode):
    """The 2-D sharded inner loop (rows x landmarks) must produce the same
    labels and medoids as the single-device reference, both compute modes."""
    res = _run_subprocess(f"""
        from repro.core import MiniBatchConfig, KernelSpec
        from repro.core.minibatch import fit_dataset, predict
        from repro.distributed.outer import DistributedMiniBatchKMeans
        from repro.data.sampling import split_batches

        rng = np.random.default_rng(0)
        centers = np.array([[0.25,0.25],[0.75,0.75],[0.25,0.75],[0.75,0.25]])
        X = np.concatenate([rng.normal(c, 0.05, size=(512,2))
                            for c in centers]).astype(np.float32)
        y = np.repeat(np.arange(4), 512)
        perm = rng.permutation(len(X)); X, y = X[perm], y[perm]

        mesh = jax.make_mesh((4, 2), ("data", "model"))
        cfg = MiniBatchConfig(n_clusters=4, n_batches=4, s=1.0,
                              kernel=KernelSpec("rbf", gamma=8.0), seed=0)
        km = DistributedMiniBatchKMeans(mesh, cfg, mode="{mode}")
        res = km.fit(split_batches(X, 4, strategy="stride"))
        labels = predict(jnp.asarray(X), res.state.medoids,
                         res.state.medoid_diag, spec=cfg.kernel)

        from repro.core.metrics import clustering_accuracy
        acc = clustering_accuracy(y, np.asarray(labels))
        total = int(np.asarray(res.state.cardinalities).sum())
        print(json.dumps({{"acc": acc, "total": total, "n": len(X)}}))
    """)
    assert res["acc"] > 0.95
    assert res["total"] == res["n"]


@pytest.mark.slow
def test_distributed_inner_identical_to_host_inner():
    """Bitwise-level agreement (labels) between repro.core.kkmeans and the
    shard_map inner loop from the SAME init on the SAME batch."""
    res = _run_subprocess("""
        from repro.core import KernelSpec
        from repro.core.kkmeans import kkmeans_fit
        from repro.distributed.inner import (DistributedInnerConfig,
                                             distributed_kkmeans_fit)

        rng = np.random.default_rng(1)
        X = rng.normal(size=(256, 8)).astype(np.float32)
        spec = KernelSpec("rbf", gamma=0.2)
        x = jnp.asarray(X)
        diag = spec.diag(x)
        l_idx = jnp.arange(256, dtype=jnp.int32)      # s = 1
        u0 = jnp.asarray(rng.integers(0, 5, 256), jnp.int32)

        k_full = spec(x, x)
        host = kkmeans_fit(k_full, l_idx, diag, u0, n_clusters=5)

        mesh = jax.make_mesh((4, 2), ("data", "model"))
        cfg = DistributedInnerConfig(n_clusters=5, kernel=spec,
                                     row_axes=("data",), col_axis="model")
        dist = distributed_kkmeans_fit(mesh, x, x, l_idx, diag, u0, cfg=cfg)

        same = bool(jnp.all(host.labels == dist.labels))
        g_err = float(jnp.max(jnp.abs(host.g - dist.g)))
        cost_err = abs(float(host.cost) - float(dist.cost))
        print(json.dumps({"same": same, "g_err": g_err,
                          "cost_err": cost_err}))
    """)
    assert res["same"], "distributed labels diverged from host reference"
    assert res["g_err"] < 1e-4
    assert res["cost_err"] < 1e-2


@pytest.mark.slow
def test_faithful_1d_distribution_mode():
    """col_axis=None recovers the paper's exact 1-D row-wise algorithm."""
    res = _run_subprocess("""
        from repro.core import KernelSpec
        from repro.core.kkmeans import kkmeans_fit
        from repro.distributed.inner import (DistributedInnerConfig,
                                             distributed_kkmeans_fit)

        rng = np.random.default_rng(2)
        X = rng.normal(size=(128, 4)).astype(np.float32)
        spec = KernelSpec("rbf", gamma=0.3)
        x = jnp.asarray(X)
        diag = spec.diag(x)
        l_idx = jnp.arange(128, dtype=jnp.int32)
        u0 = jnp.asarray(rng.integers(0, 3, 128), jnp.int32)
        host = kkmeans_fit(spec(x, x), l_idx, diag, u0, n_clusters=3)

        mesh = jax.make_mesh((8,), ("data",))
        cfg = DistributedInnerConfig(n_clusters=3, kernel=spec,
                                     row_axes=("data",), col_axis=None)
        dist = distributed_kkmeans_fit(mesh, x, x, l_idx, diag, u0, cfg=cfg)
        print(json.dumps({"same": bool(jnp.all(host.labels == dist.labels))}))
    """)
    assert res["same"]


@pytest.mark.slow
def test_collective_structure_matches_paper():
    """The compiled inner iteration must contain the paper's two collectives
    (all-gather U, all-reduce g) and must NOT move the kernel matrix: total
    collective bytes per iteration << |K| bytes."""
    res = _run_subprocess("""
        from repro.core import KernelSpec
        from repro.distributed.inner import (DistributedInnerConfig,
                                             distributed_kkmeans_fit)
        from repro.launch.dryrun import collective_bytes
        from functools import partial

        rng = np.random.default_rng(3)
        n, d, C = 1024, 16, 4
        x = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
        spec = KernelSpec("rbf", gamma=0.1)
        diag = spec.diag(x)
        l_idx = jnp.arange(n, dtype=jnp.int32)
        u0 = jnp.zeros((n,), jnp.int32)

        mesh = jax.make_mesh((8,), ("data",))
        cfg = DistributedInnerConfig(n_clusters=C, kernel=spec,
                                     row_axes=("data",), col_axis=None)
        fn = partial(distributed_kkmeans_fit, mesh, cfg=cfg)
        lowered = jax.jit(lambda *a: fn(*a)).lower(x, x, l_idx, diag, u0)
        txt = lowered.compile().as_text()
        coll = collective_bytes(txt)
        k_bytes = n * n * 4
        print(json.dumps({
            "ag": coll["counts"]["all-gather"],
            "ar": coll["counts"]["all-reduce"],
            "total": coll["total_bytes"], "k_bytes": k_bytes}))
    """)
    assert res["ag"] >= 1, "missing the paper's all-gather(U)"
    assert res["ar"] >= 1, "missing the paper's all-reduce(g)"
    # kernel matrix never crosses the network (paper's key property):
    assert res["total"] < 0.05 * res["k_bytes"]
