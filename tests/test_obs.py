"""Flight-recorder (repro.obs) tests: recorder contract, JSONL content,
jit-safety (enabling metrics adds ZERO compilations and changes no labels),
pipeline/straggler instrumentation, and the export summary."""
import json
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import KernelSpec, MiniBatchConfig
from repro.core.minibatch import (_first_batch_step, _next_batch_step,
                                  fit, fit_dataset)
from repro.data.synthetic import make_blobs
from repro.obs import (NULL, JsonlRecorder, MetricsRecorder, NullRecorder,
                       export, resolve)


def _events(path, kind=None, name=None):
    out = export.read_events(path)
    if kind is not None:
        out = [e for e in out if e.get("kind") == kind]
    if name is not None:
        out = [e for e in out if e.get("name") == name]
    return out


def test_null_recorder_contract():
    """NULL is the zero-overhead default: disabled, every hook a no-op,
    resolve(None) hands it back."""
    assert resolve(None) is NULL
    assert isinstance(NULL, NullRecorder)
    assert NULL.enabled is False
    r = resolve(NULL)
    r.counter("c", 3, batch=0)
    r.gauge("g", 1.0)
    r.series("s", jnp.float32(1.0))
    r.event("e", detail="x")
    with r.timer("t"):
        pass
    r.batch_boundary(0)
    r.close()
    # a custom recorder passes through resolve untouched
    mine = JsonlRecorder.__new__(JsonlRecorder)
    assert resolve(mine) is mine


def test_jsonl_recorder_roundtrip(tmp_path):
    path = str(tmp_path / "log.jsonl")
    rec = JsonlRecorder(path, header=export.run_header(case="unit"))
    assert rec.enabled is True
    rec.counter("collectives/psum", 5, batch=0)
    rec.counter("collectives/psum", 7, batch=1)
    rec.gauge("queue", 2, batch=0)
    rec.series("wall", 0.25, batch=0)
    rec.series("cost", jnp.float32(3.5), batch=0)   # deferred device value
    with rec.timer("stage") as t:
        pass
    rec.event("hbm_watermark", batch=0, source="host_rss",
              measured_bytes=100, peak_bytes=100, predicted_bytes=80.0)
    rec.batch_boundary(0)
    rec.close()

    header = _events(path, kind="header")
    assert len(header) == 1
    assert header[0]["backend"] == jax.default_backend()
    assert header[0]["case"] == "unit"
    # counter totals accumulate
    counters = _events(path, kind="counter")
    assert counters[-1]["total"] == 12
    # the deferred jax scalar was drained to a plain float at the boundary
    cost = _events(path, kind="series", name="cost")
    assert len(cost) == 1 and cost[0]["value"] == pytest.approx(3.5)
    assert t.seconds >= 0.0
    # every line is valid JSON (numpy/jax leak would have raised in dumps)
    with open(path) as f:
        for line in f:
            json.loads(line)

    s = export.summarize(path)
    assert s["events"] == len(export.read_events(path))
    assert s["counters"]["collectives/psum"] == 12
    assert s["stats"]["wall"]["count"] == 1
    assert s["last_watermark"]["predicted_bytes"] == 80.0


def _exact_cfg(c=4, b=2):
    return MiniBatchConfig(n_clusters=c, n_batches=b, s=1.0,
                           kernel=KernelSpec("rbf", gamma=0.5), seed=0)


def test_recorder_is_jit_safe_exact(tmp_path):
    """THE acceptance criterion: running the exact fit with the JSONL
    recorder compiles nothing beyond what the NullRecorder run compiled,
    and produces bit-identical results."""
    x, _ = make_blobs(160, 8, 4, sep=6.0, seed=0)
    cfg = _exact_cfg()

    res_null = fit_dataset(x, cfg)                    # warm the caches
    first0 = _first_batch_step._cache_size()
    next0 = _next_batch_step._cache_size()

    path = str(tmp_path / "exact.jsonl")
    with JsonlRecorder(path) as rec:
        res_obs = fit_dataset(x, cfg, recorder=rec)

    assert _first_batch_step._cache_size() == first0
    assert _next_batch_step._cache_size() == next0
    np.testing.assert_array_equal(np.asarray(res_null.state.medoids),
                                  np.asarray(res_obs.state.medoids))
    assert [h.cost for h in res_null.history] == \
        [h.cost for h in res_obs.history]

    # per-batch wall times, one per batch
    walls = _events(path, kind="series", name="batch/wall_seconds")
    assert len(walls) == cfg.n_batches
    assert all(w["value"] > 0 for w in walls)
    # deferred cost/iter series drained and matching the history
    costs = _events(path, kind="series", name="inner/cost")
    assert [c["value"] for c in costs] == \
        pytest.approx([h.cost for h in res_obs.history])
    # measured-vs-predicted watermark pair on every batch
    marks = _events(path, kind="event", name="hbm_watermark")
    assert len(marks) == cfg.n_batches
    for m in marks:
        assert m["measured_bytes"] is not None and m["measured_bytes"] > 0
        assert m["predicted_bytes"] is not None and m["predicted_bytes"] > 0
        assert m["source"] in ("device", "host_rss")
        assert m["engine"] == "materialize"
    # boundaries flushed per batch + the close() drain
    assert len(_events(path, kind="boundary")) == cfg.n_batches + 1


def test_recorder_is_jit_safe_embedded(tmp_path):
    """Same contract for the embedded path (method != 'exact')."""
    from repro.approx import embed_kmeans
    x, _ = make_blobs(192, 8, 4, sep=6.0, seed=1)
    cfg = MiniBatchConfig(n_clusters=4, n_batches=2, kernel=KernelSpec("rbf",
                          gamma=0.5), seed=0, method="rff", embed_dim=32)

    res_null = fit_dataset(x, cfg)
    first0 = embed_kmeans._first_batch_step._cache_size()
    next0 = embed_kmeans._next_batch_step._cache_size()

    path = str(tmp_path / "embed.jsonl")
    with JsonlRecorder(path) as rec:
        res_obs = fit_dataset(x, cfg, recorder=rec)

    assert embed_kmeans._first_batch_step._cache_size() == first0
    assert embed_kmeans._next_batch_step._cache_size() == next0
    np.testing.assert_array_equal(np.asarray(res_null.state.centroids),
                                  np.asarray(res_obs.state.centroids))

    marks = _events(path, kind="event", name="hbm_watermark")
    assert len(marks) == cfg.n_batches
    assert all(m["predicted_bytes"] > 0 for m in marks)


def test_distributed_exact_recorder_identity(tmp_path):
    """Mesh path: recorder on vs off — identical medoids, and the log
    carries the statically-audited collective bill (analytic == static ==
    recorded) + straggler timing events."""
    from repro.distributed.mesh import make_test_mesh
    from repro.distributed.outer import DistributedMiniBatchKMeans

    x, _ = make_blobs(128, 6, 3, sep=6.0, seed=2)
    cfg = _exact_cfg(c=3, b=2)
    mesh = make_test_mesh({"data": 1})
    batches = [x[:64], x[64:]]

    res_off = DistributedMiniBatchKMeans(mesh, cfg).fit(list(batches))
    path = str(tmp_path / "dist.jsonl")
    with JsonlRecorder(path) as rec:
        km_on = DistributedMiniBatchKMeans(mesh, cfg, recorder=rec)
        res_on = km_on.fit(list(batches))

    np.testing.assert_array_equal(np.asarray(res_off.state.medoids),
                                  np.asarray(res_on.state.medoids))

    psums = _events(path, kind="counter", name="collectives/psum")
    gathers = _events(path, kind="counter", name="collectives/allgather")
    assert len(psums) == 2 and len(gathers) == 2

    # analytic == static: the audited per-sync while-body counts must
    # equal the hand-derived bill exactly — ONE fused psum and ONE
    # allgather per sync.
    from repro.distributed.inner import collectives_per_iteration
    analytic = collectives_per_iteration(km_on.inner_cfg)
    (static,) = km_on._bill_cache.values()   # both batches share one shape
    per, out = static["per_iteration"], static["outside"]
    assert analytic["psum"] == 1 and analytic["allgather"] == 1
    assert per["psum"] == analytic["psum"]
    assert per["all_gather"] == analytic["allgather"]

    # static == recorded: per-sync x n_iter + the audited prologue sync
    # (which pays the identical fused pair; no fixpoint epilogue).
    n0 = res_on.history[0].inner_iters
    assert psums[0]["inc"] == per["psum"] * n0 + out["psum"]
    assert gathers[0]["inc"] == per["all_gather"] * n0 + out["all_gather"]
    assert psums[0]["inc"] == analytic["psum"] * (n0 + 1)

    timings = _events(path, kind="event", name="batch_timing")
    assert len(timings) == 2
    assert str(jax.process_index()) in timings[0]["timings"]


def test_distributed_embed_recorder_with_prefetch(tmp_path):
    """Streaming embed path with the recorder through the BatchSource:
    queue-depth gauges + stage timings from the producer thread, psum
    counters + watermarks from the consumer, identical centroids."""
    from repro.distributed.embed import DistributedEmbedKMeans
    from repro.distributed.mesh import make_test_mesh

    x, _ = make_blobs(192, 8, 4, sep=6.0, seed=3)
    cfg = MiniBatchConfig(n_clusters=4, n_batches=3, kernel=KernelSpec("rbf",
                          gamma=0.5), seed=0, method="rff", embed_dim=32)
    mesh = make_test_mesh({"data": 1})
    batches = [x[:64], x[64:128], x[128:]]

    res_off = DistributedEmbedKMeans(mesh, cfg).fit(list(batches))
    path = str(tmp_path / "embed_dist.jsonl")
    with JsonlRecorder(path) as rec:
        km = DistributedEmbedKMeans(mesh, cfg, recorder=rec)
        res_on = km.fit(km.source(list(batches), depth=2))

    np.testing.assert_array_equal(np.asarray(res_off.state.centroids),
                                  np.asarray(res_on.state.centroids))

    assert len(_events(path, kind="gauge", name="prefetch/queue_depth")) == 3
    stage = _events(path, kind="series", name="prefetch/stage_seconds")
    assert len(stage) == 3 and all(s["value"] > 0 for s in stage)
    assert len(_events(path, kind="series",
                       name="prefetch/starve_seconds")) == 3
    assert len(_events(path, kind="counter", name="collectives/psum")) == 3
    marks = _events(path, kind="event", name="hbm_watermark")
    assert len(marks) == 3 and all(m["predicted_bytes"] > 0 for m in marks)


def test_straggler_monitor(tmp_path):
    """Satellite: detect_stragglers finally has call sites and tests."""
    from repro.ft.straggler import StragglerMonitor, detect_stragglers

    assert detect_stragglers({}) == []
    assert detect_stragglers({0: 1.0, 1: 1.1, 2: 1.0}) == []
    assert detect_stragglers({0: 1.0, 1: 1.1, 2: 5.0}) == [2]

    path = str(tmp_path / "strag.jsonl")
    rec = JsonlRecorder(path)
    mon = StragglerMonitor(rec, threshold=1.5)
    # healthy round: no event beyond the timing record
    assert mon.observe(0, {0: 1.0, 1: 1.05, 2: 0.95}, n_rows=1200) == []
    # worker 2 tanks: flagged, replan emitted over the rolling throughputs
    assert mon.observe(1, {0: 1.0, 1: 1.0, 2: 4.0}, n_rows=1200) == [2]
    rec.close()

    assert len(_events(path, kind="event", name="batch_timing")) == 2
    det = _events(path, kind="event", name="straggler_detected")
    assert len(det) == 1
    assert det[0]["stragglers"] == ["2"]
    replan = det[0]["replan"]
    assert replan is not None
    # the slow worker is assigned the fewest rows
    sizes = {k: v[1] for k, v in replan.items()}
    assert sizes["2"] == min(sizes.values())
    assert sum(sizes.values()) > 0


def test_elastic_runner_events(tmp_path):
    """elastic/resume + elastic/checkpoint appear next to batch metrics."""
    from repro.distributed.mesh import make_test_mesh
    from repro.ft.checkpoint import CheckpointManager
    from repro.ft.elastic import ElasticClusteringRunner

    x, _ = make_blobs(128, 6, 3, sep=6.0, seed=4)
    cfg = MiniBatchConfig(n_clusters=3, n_batches=2, kernel=KernelSpec("rbf",
                          gamma=0.5), seed=0, method="rff", embed_dim=16)
    path = str(tmp_path / "elastic.jsonl")
    with JsonlRecorder(path) as rec:
        runner = ElasticClusteringRunner(
            cfg, CheckpointManager(str(tmp_path / "ckpt")), recorder=rec)
        runner.run(make_test_mesh({"data": 1}), [x[:64], x[64:]])

    resume = _events(path, kind="event", name="elastic/resume")
    assert len(resume) == 1 and resume[0]["resumed"] is False
    assert len(_events(path, kind="event", name="elastic/checkpoint")) == 2


def test_collectives_per_iteration_counts():
    from repro.distributed.embed import \
        collectives_per_iteration as embed_bill
    from repro.distributed.inner import (DistributedInnerConfig,
                                         collectives_per_iteration)

    cfg_1d = DistributedInnerConfig(n_clusters=8, col_axis=None)
    cfg_2d = DistributedInnerConfig(n_clusters=8, col_axis="model")
    # ONE fused psum per sync on both layouts (the s-step contract);
    # payload: [C+2] floats in 1-D (g row-partials + cost + changed),
    # [rows_p+2, C] in 2-D (f + counts + g, scalars riding the gather).
    assert collectives_per_iteration(cfg_1d)["psum"] == 1
    assert collectives_per_iteration(cfg_2d)["psum"] == 1
    assert collectives_per_iteration(cfg_1d)["allgather"] == 1
    assert collectives_per_iteration(cfg_2d)["allgather"] == 1
    assert collectives_per_iteration(cfg_1d)["psum_bytes"] == 4 * (8 + 2)
    assert collectives_per_iteration(
        cfg_2d, n_local_rows=64)["psum_bytes"] == 4 * 8 * (64 + 2)

    b = embed_bill(8, 32)
    # embed: ONE fused psum/iteration (sums+counts+flag+cost), and the
    # prologue sync outside the loop pays the same payload.
    assert b["psum"] == 1 and b["final_psum"] == 1
    assert b["psum_bytes"] == 4 * (8 * 33 + 2)
    assert b["final_psum_bytes"] == b["psum_bytes"]


def test_jsonl_recorder_thread_safety(tmp_path):
    """Producer-thread writes interleave with the consumer without losing
    or corrupting records (the PrefetchLoader contract)."""
    import threading

    path = str(tmp_path / "mt.jsonl")
    rec = JsonlRecorder(path)

    def hammer(tid):
        for k in range(200):
            rec.counter("n", 1, thread=tid)
            rec.series(f"s{tid}", float(k))

    threads = [threading.Thread(target=hammer, args=(t,)) for t in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    rec.close()
    assert rec.totals["n"] == 800
    counters = _events(path, kind="counter", name="n")
    assert len(counters) == 800
    with open(path) as f:
        for line in f:
            json.loads(line)   # no torn lines


def test_memory_watermark_cpu_fallback():
    """On backends without allocator stats the watermark still produces a
    measured value, tagged host_rss — the measured-vs-predicted pair must
    exist on every backend."""
    from repro.obs import memory as obs_memory

    class Sink(MetricsRecorder):
        enabled = True

        def __init__(self):
            self.events = []

        def event(self, name, **fields):
            self.events.append((name, fields))

    sink = Sink()
    obs_memory.watermark(sink, batch=0, predicted_bytes=123.0)
    (name, fields), = sink.events
    assert name == "hbm_watermark"
    assert fields["predicted_bytes"] == 123.0
    assert fields["measured_bytes"] is None or fields["measured_bytes"] > 0
    if jax.default_backend() == "cpu" and not fields["devices"]:
        assert fields["source"] == "host_rss"


def test_fit_list_batches_with_recorder(tmp_path):
    """fit() over plain list batches (the sparse/sketch benchmark shape)
    records without disturbing results."""
    from repro.data.sparse import split_csr
    from repro.data.synthetic import make_rcv1_sparse

    xs, _ = make_rcv1_sparse(200, vocab=64, n_classes=4, seed=0)
    cfg = MiniBatchConfig(n_clusters=4, n_batches=2,
                          kernel=KernelSpec("linear"), seed=0,
                          method="sketch", embed_dim=32)
    res_null = fit(split_csr(xs, 2, strategy="stride"), cfg)
    path = str(tmp_path / "sparse.jsonl")
    with JsonlRecorder(path) as rec:
        res_obs = fit(split_csr(xs, 2, strategy="stride"), cfg, recorder=rec)
    np.testing.assert_array_equal(np.asarray(res_null.state.centroids),
                                  np.asarray(res_obs.state.centroids))
    marks = _events(path, kind="event", name="hbm_watermark")
    assert len(marks) == 2 and all(m["predicted_bytes"] > 0 for m in marks)
