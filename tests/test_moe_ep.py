"""Expert-parallel MoE dispatch (§Perf hillclimb B) correctness."""
import dataclasses

import jax

from repro.distributed.compat import make_mesh
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.models import Axes, get_model
from repro.models.common import set_ambient_mesh

AXES = Axes(dp=("data",), tp="model")


@pytest.fixture(autouse=True)
def _clear_mesh():
    yield
    set_ambient_mesh(None)


def _setup(ep_groups, capacity_factor=100.0):
    base = get_arch("qwen3-moe-235b-a22b", smoke=True)
    cfg = dataclasses.replace(base, capacity_factor=capacity_factor,
                              moe_ep_groups=ep_groups)
    api = get_model(cfg, tp_size=1)
    mesh = make_mesh((1, 1), ("data", "model"))
    rng = np.random.default_rng(0)
    tok = jnp.asarray(rng.integers(1, base.vocab_size, (2, 32)), jnp.int32)
    batch = {"tokens": tok, "labels": jnp.roll(tok, -1, 1)}
    return cfg, api, mesh, batch


def test_ep_gspmd_path_matches_dense_no_drops():
    """With capacity so large nothing drops, the EP (GSPMD fallback, no
    ambient mesh) and dense-dispatch paths are bitwise-identical."""
    cfg0, api0, mesh, batch = _setup(0)
    _, api1, _, _ = _setup(2)
    params, _ = api0.init(jax.random.PRNGKey(0), jnp.float32)
    with mesh:
        l0 = api0.loss(params, batch, AXES, remat=False)
        l1 = api1.loss(params, batch, AXES, remat=False)
    assert float(l0) == float(l1)


def test_ep_shardmap_path_matches_dense_no_drops():
    """Same check through the shard_map dispatch (ambient mesh set)."""
    cfg0, api0, mesh, batch = _setup(0)
    _, api1, _, _ = _setup(1)
    params, _ = api0.init(jax.random.PRNGKey(0), jnp.float32)
    set_ambient_mesh(mesh)
    with mesh:
        l0 = api0.loss(params, batch, AXES, remat=False)
        l1 = api1.loss(params, batch, AXES, remat=False)
        grads = jax.grad(lambda p: api1.loss(p, batch, AXES,
                                             remat=False))(params)
    assert float(l0) == float(l1)
    assert all(bool(jnp.all(jnp.isfinite(g.astype(jnp.float32))))
               for g in jax.tree.leaves(grads))


def test_ep_paper_capacity_close_to_dense():
    """At the paper-style capacity factor the EP path drops (slightly
    different) tokens but the loss stays within noise of dense dispatch."""
    cfg0, api0, mesh, batch = _setup(0, capacity_factor=1.25)
    _, api1, _, _ = _setup(2, capacity_factor=1.25)
    params, _ = api0.init(jax.random.PRNGKey(0), jnp.float32)
    with mesh:
        l0 = api0.loss(params, batch, AXES, remat=False)
        l1 = api1.loss(params, batch, AXES, remat=False)
    assert abs(float(l0) - float(l1)) / float(l0) < 0.02


def test_ep_multidevice_shardmap():
    """EP over a real (2, 2) device mesh in a subprocess: loss finite and
    equal to the single-device shard_map run (no drops)."""
    import os
    import subprocess
    import sys
    import textwrap
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    script = textwrap.dedent("""\
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import dataclasses, json
        import numpy as np
        import jax, jax.numpy as jnp
        from repro.configs import get_arch
        from repro.models import Axes, get_model
        from repro.models.common import set_ambient_mesh
        from repro.distributed.compat import make_mesh

        AXES = Axes(dp=("data",), tp="model")
        base = get_arch("qwen3-moe-235b-a22b", smoke=True)
        rng = np.random.default_rng(0)
        tok = jnp.asarray(rng.integers(1, base.vocab_size, (2, 32)),
                          jnp.int32)
        batch = {"tokens": tok, "labels": jnp.roll(tok, -1, 1)}

        def run(mesh_shape, ep):
            mesh = make_mesh(mesh_shape, ("data", "model"))
            cfg = dataclasses.replace(base, capacity_factor=100.0,
                                      moe_ep_groups=ep)
            api = get_model(cfg, tp_size=mesh_shape[1])
            params, _ = api.init(jax.random.PRNGKey(0), jnp.float32)
            set_ambient_mesh(mesh)
            with mesh:
                out = float(api.loss(params, batch, AXES, remat=False))
            set_ambient_mesh(None)
            return out

        l1 = run((1, 1), 1)
        l4 = run((2, 2), 2)
        print(json.dumps({"l1": l1, "l4": l4}))
    """)
    env = dict(os.environ, PYTHONPATH=os.path.join(root, "src"))
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=540)
    assert out.returncode == 0, out.stderr[-3000:]
    import json
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert abs(res["l1"] - res["l4"]) < 2e-3, res
