"""Baselines (Lloyd k-means, Sculley SGD) and clustering metrics."""
import numpy as np
import pytest

from repro.baselines.lloyd import kmeans
from repro.baselines.sculley import sgd_minibatch_kmeans
from repro.core.metrics import (clustering_accuracy, contingency, elbow,
                                nmi)

from conftest import four_blobs


def test_lloyd_recovers_blobs():
    x, y = four_blobs(n_per=250, seed=11)
    res = kmeans(x, 4, n_init=3, seed=0)
    assert clustering_accuracy(y, np.asarray(res.labels)) > 0.98
    assert float(res.cost) > 0


def test_lloyd_cost_decreases_with_restarts():
    x, _ = four_blobs(n_per=100, seed=12)
    c1 = float(kmeans(x, 4, n_init=1, seed=5).cost)
    c5 = float(kmeans(x, 4, n_init=5, seed=5).cost)
    assert c5 <= c1 + 1e-6


def test_sculley_sgd_runs_and_clusters():
    """Sculley SGD is NOISY (its random init can collapse clusters — the
    variance the paper's Fig.8 points at), so assert on the best of 3 seeds
    rather than a single run."""
    x, y = four_blobs(n_per=250, seed=13)
    accs = [clustering_accuracy(
        y, np.asarray(sgd_minibatch_kmeans(x, 4, batch_size=100,
                                           n_iters=100, seed=s).labels))
        for s in (0, 1, 2)]
    assert max(accs) > 0.95


def test_contingency_counts():
    y = np.array([0, 0, 1, 1, 2])
    u = np.array([1, 1, 0, 0, 0])
    o = contingency(y, u)
    assert o.shape == (2, 3)
    assert o[1, 0] == 2 and o[0, 1] == 2 and o[0, 2] == 1


def test_accuracy_majority_mapping_handles_merged_clusters():
    # one predicted cluster covering two true classes -> majority wins
    y = np.array([0, 0, 1, 1])
    u = np.array([0, 0, 0, 0])
    assert clustering_accuracy(y, u) == 0.5


def test_nmi_known_value():
    y = np.array([0, 0, 1, 1])
    u = np.array([0, 1, 0, 1])     # independent labelling
    assert nmi(y, u) == pytest.approx(0.0, abs=1e-12)
    assert nmi(y, y) == pytest.approx(1.0, abs=1e-12)


def test_elbow_finds_knee():
    # cost drops fast until C=3, then flattens: elbow at index of C=3
    costs = [100.0, 40.0, 10.0, 8.0, 7.0, 6.5]
    assert elbow(costs) in (1, 2)


def test_elbow_on_real_cost_curve():
    """Elbow over a kernel k-means C-sweep on 4 blobs lands near C = 4."""
    import jax.numpy as jnp
    from repro.core import KernelSpec, MiniBatchConfig, fit_dataset
    x, _ = four_blobs(n_per=64, seed=14)
    costs = []
    for c in range(2, 8):
        cfg = MiniBatchConfig(n_clusters=c, n_batches=1, s=1.0,
                              kernel=KernelSpec("rbf", gamma=8.0), seed=0)
        res = fit_dataset(x, cfg)
        costs.append(res.history[-1].cost)
    c_star = elbow(costs) + 2
    assert 3 <= c_star <= 5
