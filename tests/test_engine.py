"""GramEngine (repro.core.engine): the three Gram-residency modes of the
exact inner loop must be interchangeable — identical labels, matching
stats, same tie-breaks — and the tiled mode must honor its residency
contract (never materialize the full [n, L] block)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core.kernels as kernels_mod
from repro.core import (GramEngine, KernelSpec, MachineSpec,
                        MiniBatchConfig, clustering_accuracy, fit_dataset,
                        kkmeans_fit, kkmeans_fit_gram, plan, resolve_engine)
from repro.core.engine import assign_from_stats
from repro.core.minibatch import predict
from repro.kernels import ops as kops

from conftest import four_blobs


def _problem(n=200, d=6, c=5, s=0.4, seed=0, gamma=0.3):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    spec = KernelSpec("rbf", gamma=gamma)
    lm = int(n * s)
    l_idx = jnp.asarray(np.sort(rng.choice(n, lm, replace=False)), jnp.int32)
    u0 = jnp.asarray(rng.integers(0, c, n), jnp.int32)
    return x, spec, spec.diag(x), l_idx, u0, c


ENGINES = {
    "materialize": GramEngine("materialize"),
    "fused-jnp": GramEngine("fused", pallas="never"),
    "fused-pallas": GramEngine("fused", pallas="always", interpret=True),
    "tiled": GramEngine("tiled", tile_rows=64),
}


# ---------------------------------------------------------------------------
# oracle suite: every mode == the precomputed-Gram oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", sorted(ENGINES))
def test_engine_matches_gram_oracle(name):
    """Labels identical to kkmeans_fit_gram on the precomputed block;
    f/g/cost within fp tolerance."""
    x, spec, diag, l_idx, u0, c = _problem()
    k_xl = spec(x, jnp.take(x, l_idx, axis=0))
    want = kkmeans_fit_gram(k_xl, l_idx, diag, u0, n_clusters=c)
    got = kkmeans_fit(x, l_idx, diag, u0, spec=spec, n_clusters=c,
                      engine=ENGINES[name])
    assert bool(jnp.all(got.labels == want.labels)), name
    assert int(got.n_iter) == int(want.n_iter)
    np.testing.assert_allclose(np.asarray(got.f), np.asarray(want.f),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(got.g), np.asarray(want.g),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_array_equal(np.asarray(got.counts),
                                  np.asarray(want.counts))
    np.testing.assert_allclose(float(got.cost), float(want.cost), rtol=1e-4)


@pytest.mark.parametrize("kind", ["linear", "polynomial"])
def test_engine_modes_agree_on_non_rbf_kernels(kind):
    x, _, _, l_idx, u0, c = _problem(n=160, d=5, s=0.5, seed=3)
    spec = KernelSpec(kind, gamma=0.2, coef0=1.0, degree=2)
    diag = spec.diag(x)
    results = {n: kkmeans_fit(x, l_idx, diag, u0, spec=spec, n_clusters=c,
                              engine=e) for n, e in ENGINES.items()}
    base = results["materialize"]
    for name, r in results.items():
        assert bool(jnp.all(r.labels == base.labels)), name


@pytest.mark.parametrize("name", sorted(ENGINES))
def test_minibatch_fit_engine_parametrized(name):
    """End-to-end fit_dataset under each engine: same predicted labels and
    exactly-once cardinality accounting as the materialize baseline."""
    x, y = four_blobs(n_per=150, seed=7)
    base_cfg = MiniBatchConfig(n_clusters=4, n_batches=3, s=1.0,
                               kernel=KernelSpec("rbf", gamma=8.0), seed=0)
    res0 = fit_dataset(x, base_cfg)
    cfg = MiniBatchConfig(n_clusters=4, n_batches=3, s=1.0,
                          kernel=KernelSpec("rbf", gamma=8.0), seed=0,
                          engine=ENGINES[name])
    res = fit_dataset(x, cfg)
    np.testing.assert_array_equal(np.asarray(res.state.medoids),
                                  np.asarray(res0.state.medoids))
    labels = predict(jnp.asarray(x), res.state.medoids,
                     res.state.medoid_diag, spec=cfg.kernel)
    assert clustering_accuracy(y, np.asarray(labels)) > 0.95
    assert int(np.asarray(res.state.cardinalities).sum()) == len(x)


# ---------------------------------------------------------------------------
# residency contract: the tiled mode must never build the full block
# ---------------------------------------------------------------------------


def _arm_gram_trap(monkeypatch, max_elems: int):
    """Booby-trap every rbf Gram evaluation: any block larger than
    ``max_elems`` elements fails the test at trace time."""
    orig = kernels_mod._REGISTRY["rbf"]

    def guarded(x, y, *, gamma):
        elems = x.shape[0] * y.shape[0]
        assert elems <= max_elems, \
            f"materialized a {x.shape[0]}x{y.shape[0]} Gram block " \
            f"({elems} > {max_elems} elements)"
        return orig(x, y, gamma=gamma)

    monkeypatch.setitem(kernels_mod._REGISTRY, "rbf", guarded)


def test_tiled_survives_block_exceeding_plan_budget(monkeypatch):
    """Booby-trapped: a batch whose full [n, L] block exceeds a fake plan
    budget must still fit under the planner-chosen tiled engine — and the
    trap must actually fire if anything materializes the block."""
    n, d, c, s = 384, 2, 4, 0.5
    lm = int(n * s)                                   # 192
    # fake machine: tiled fits (two 64-row panels live at once — the matvec
    # is double-buffered), the resident block does not (b pinned at 1)
    machine = MachineSpec(memory_bytes=250e3, n_processors=1)
    p = plan(n, c, machine, d=d, b=1, tile_rows=64)
    assert p.engine == "tiled"
    assert p.engine_footprints["materialize"] > machine.memory_bytes
    assert p.engine_footprints["tiled"] <= machine.memory_bytes

    # the priced pick round-trips as a runnable engine (mode + the
    # tile_rows the footprint was validated with)
    eng = p.gram_engine()
    assert eng == GramEngine("tiled", tile_rows=64)

    # trap: one 64-row panel (64*192) passes, the full block (384*192) dies
    _arm_gram_trap(monkeypatch, max_elems=20_000)
    x, y = four_blobs(n_per=n // 4, seed=1)
    cfg = MiniBatchConfig(n_clusters=c, n_batches=1, s=s,
                          kernel=KernelSpec("rbf", gamma=8.0), seed=0,
                          engine=eng)
    res = fit_dataset(x, cfg)
    labels = predict(jnp.asarray(x), res.state.medoids,
                     res.state.medoid_diag, spec=cfg.kernel)
    assert clustering_accuracy(y, np.asarray(labels)) > 0.9

    # prove the trap is live: the materialize engine must trip it
    cfg_mat = MiniBatchConfig(n_clusters=c, n_batches=1, s=s,
                              kernel=KernelSpec("rbf", gamma=7.9), seed=0)
    with pytest.raises(AssertionError, match="materialized a"):
        fit_dataset(x, cfg_mat)


# ---------------------------------------------------------------------------
# regression: the fused mode must actually invoke the Pallas kernel
# ---------------------------------------------------------------------------


def test_fused_mode_invokes_pallas_kernel(monkeypatch):
    """The old distributed 'fused' mode silently recomputed with plain jnp
    and never called the Pallas kernel. The engine must dispatch to the
    kernel wrappers when fused+pallas is selected — and must NOT when the
    portable fallback is selected."""
    calls = {"assign": 0, "matvec": 0}
    real_assign, real_matvec = kops.assign_fused, kops.gram_matvec

    def spy_assign(*a, **k):
        calls["assign"] += 1
        return real_assign(*a, **k)

    def spy_matvec(*a, **k):
        calls["matvec"] += 1
        return real_matvec(*a, **k)

    monkeypatch.setattr(kops, "assign_fused", spy_assign)
    monkeypatch.setattr(kops, "gram_matvec", spy_matvec)

    x, spec, diag, l_idx, u0, c = _problem(n=224, d=5, c=3, s=0.5, seed=11)
    want = kkmeans_fit(x, l_idx, diag, u0, spec=spec, n_clusters=c,
                       engine=GramEngine("materialize"))

    got = kkmeans_fit(x, l_idx, diag, u0, spec=spec, n_clusters=c,
                      engine=GramEngine("fused", pallas="always",
                                        interpret=True))
    assert calls["assign"] >= 1, "fused one-shot Pallas pass never invoked"
    assert calls["matvec"] >= 1, "fused Pallas matvec (g stats) never invoked"
    assert bool(jnp.all(got.labels == want.labels))

    calls["assign"] = calls["matvec"] = 0
    kkmeans_fit(x, l_idx, diag, u0, spec=spec, n_clusters=c,
                engine=GramEngine("fused", pallas="never"))
    assert calls["assign"] == 0 and calls["matvec"] == 0


def test_distributed_inner_fused_invokes_pallas(monkeypatch):
    """Same regression at the shard_map layer (1-device mesh, interpret
    mode on CPU): distributed/inner's fused engine must reach the Pallas
    matvec, not the jnp recompute."""
    from repro.distributed.inner import (DistributedInnerConfig,
                                         distributed_kkmeans_fit)

    calls = {"matvec": 0}
    real_matvec = kops.gram_matvec

    def spy_matvec(*a, **k):
        calls["matvec"] += 1
        return real_matvec(*a, **k)

    monkeypatch.setattr(kops, "gram_matvec", spy_matvec)

    rng = np.random.default_rng(4)
    n, c = 192, 4
    x = jnp.asarray(rng.normal(size=(n, 6)).astype(np.float32))
    spec = KernelSpec("rbf", gamma=0.25)
    diag = spec.diag(x)
    l_idx = jnp.arange(n, dtype=jnp.int32)
    u0 = jnp.asarray(rng.integers(0, c, n), jnp.int32)
    mesh = jax.make_mesh((1,), ("data",))

    host = kkmeans_fit(x, l_idx, diag, u0, spec=spec, n_clusters=c)
    cfg = DistributedInnerConfig(
        n_clusters=c, kernel=spec, row_axes=("data",), col_axis=None,
        engine=GramEngine("fused", pallas="always", interpret=True))
    dist = distributed_kkmeans_fit(mesh, x, x, l_idx, diag, u0, cfg=cfg)
    assert calls["matvec"] >= 1, "Pallas path shadowed by the jnp fallback"
    assert bool(jnp.all(host.labels == dist.labels))


# ---------------------------------------------------------------------------
# deterministic tie-breaking: lowest cluster index wins, every path
# ---------------------------------------------------------------------------


def test_argmin_ties_resolve_to_lowest_cluster_index():
    """Clusters 0 and 1 are built from IDENTICAL landmark point-sets, so
    every row's f columns tie bitwise; with the compactness tied too, the
    distance columns are exactly equal — and BOTH argmin implementations
    (the shared jnp authority and the Pallas kernel) must pick cluster 0,
    never 1. There are exactly two argmin implementations behind every
    engine mode, so this pins 'lowest cluster index wins' for all of them.
    """
    rng = np.random.default_rng(5)
    base = rng.normal(size=(8, 6)).astype(np.float32)
    landmarks = jnp.asarray(np.concatenate([base, base]))     # [16, 6]
    x = jnp.asarray(rng.normal(size=(40, 6)).astype(np.float32))
    labels_l = jnp.asarray([0] * 8 + [1] * 8, jnp.int32)
    c = 2
    spec = KernelSpec("rbf", gamma=0.3)

    h = jax.nn.one_hot(labels_l, c, dtype=jnp.float32)
    counts = jnp.sum(h, axis=0)
    k = spec(x, landmarks)
    f = k @ (h / counts[None, :])
    # the duplicated landmark set ties the f columns bitwise ...
    np.testing.assert_array_equal(np.asarray(f[:, 0]), np.asarray(f[:, 1]))
    # ... and we tie g explicitly (summing the duplicate halves of K_ll in
    # different reduction orders can differ by an ulp, which would be a
    # numeric difference, not a tie — this test is about the tie RULE).
    k_ll = spec(landmarks, landmarks)
    g_val = jnp.sum(h * (k_ll @ h), axis=0)[0] / (counts[0] * counts[0])
    g = jnp.full((c,), g_val, jnp.float32)

    # 1. the shared jnp argmin authority (materialize / tiled / fused-jnp)
    lab, _ = assign_from_stats(f, g, counts)
    np.testing.assert_array_equal(np.asarray(lab), 0)

    # 2. the Pallas fused kernel (fused mode, interpret on CPU) — same f
    #    bitwise, same tie rule
    lab_p, _, f_p = kops.assign_fused(x, landmarks, labels_l, counts, g,
                                      n_clusters=c, kind="rbf", gamma=0.3,
                                      interpret=True)
    np.testing.assert_array_equal(np.asarray(f_p[:, 0]),
                                  np.asarray(f_p[:, 1]))
    np.testing.assert_array_equal(np.asarray(lab_p), 0)
    np.testing.assert_allclose(np.asarray(f_p), np.asarray(f),
                               rtol=1e-5, atol=1e-5)

    # 3. the engine assign stage under every mode, fed the same tied stats
    #    through a precomputed operator — label 0 everywhere
    for name, eng in ENGINES.items():
        op = GramEngine.from_matrix(k)
        f_e = eng.matvec(spec, op, h / counts[None, :])
        lab_e, _ = assign_from_stats(f_e, g, counts)
        assert (np.asarray(lab_e) == 0).all(), name


# ---------------------------------------------------------------------------
# config plumbing
# ---------------------------------------------------------------------------


def test_resolve_engine_and_config_validation():
    assert resolve_engine("tiled").mode == "tiled"
    eng = GramEngine("fused", pallas="never")
    assert resolve_engine(eng) is eng
    with pytest.raises(ValueError, match="engine"):
        resolve_engine("vmem")
    with pytest.raises(ValueError, match="unknown engine mode"):
        GramEngine("resident")
    with pytest.raises(ValueError, match="engine"):
        MiniBatchConfig(n_clusters=4, method="rff", engine="tiled")
    # mode names thread through the config unchanged
    cfg = MiniBatchConfig(n_clusters=4, engine="tiled")
    assert resolve_engine(cfg.engine).mode == "tiled"


def test_plan_prices_all_three_engine_modes():
    machine = MachineSpec(memory_bytes=16e9, n_processors=256)
    p = plan(10_000_000, 100, machine, d=784)
    assert set(p.engine_footprints) == {"materialize", "fused", "tiled"}
    assert p.engine in p.engine_footprints
    # fused keeps only the f panel; tiled adds one panel; materialize the block
    assert p.engine_footprints["fused"] < p.engine_footprints["tiled"]
    assert p.engine_footprints["tiled"] < p.engine_footprints["materialize"]
    # a generous budget keeps the paper's resident layout
    big = plan(100_000, 10, MachineSpec(memory_bytes=1e12, n_processors=1),
               d=8)
    assert big.engine == "materialize"
    # an impossible budget must say so, not pretend fused rescues it
    tiny = plan(100_000, 8, MachineSpec(memory_bytes=10e3, n_processors=1),
                d=16, b=1)
    assert tiny.engine_footprints["fused"] > 10e3
    assert "DOES NOT FIT" in tiny.note


def test_frontier_ranks_exact_tiled_against_approximations():
    machine = MachineSpec(memory_bytes=16e9, n_processors=64)
    p = plan(2_000_000, 50, machine, d=256, selector="rls", sketchable=True,
             density=0.01)
    front = p.frontier()
    names = [r["method"] for r in front]
    assert "exact-tiled" in names
    rec = front[names.index("exact-tiled")]
    assert rec["selector"] == "rls"                 # exact pays ITS selector
    assert 1 <= rec["m"] <= p.n / p.b               # |L| bounded by the batch
    assert rec["bytes"] <= p.embed_footprint + p.selector_footprint + 1
    assert 0.0 <= rec["predicted_accuracy"] <= 1.0
