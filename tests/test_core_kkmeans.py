"""Inner-loop (Eq.4-7) and mini-batch outer-loop (Alg.1) correctness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (KernelSpec, MiniBatchConfig, clustering_accuracy,
                        fit_dataset, gamma_from_dmax, get_kernel,
                        kkmeans_fit_full, kkmeans_fit_gram, medoid_indices,
                        nmi)
from repro.core.kkmeans import kkmeans_fit
from repro.core.minibatch import predict

from conftest import four_blobs


def _kernel_and_diag(x, spec):
    k = spec(jnp.asarray(x), jnp.asarray(x))
    return k, spec.diag(jnp.asarray(x))


def test_inner_loop_converges_to_label_fixpoint(blobs):
    """GD from a RANDOM labelling reaches a label fixpoint (Bottou & Bengio
    a.s.-convergence); accuracy is NOT asserted here — random init can merge
    clusters, which is exactly why the paper seeds with k-means++."""
    x, _ = blobs
    spec = KernelSpec("rbf", gamma=8.0)
    k, diag = _kernel_and_diag(x, spec)
    labels0 = jnp.asarray(np.random.default_rng(0).integers(0, 4, len(x)),
                          jnp.int32)
    res = kkmeans_fit_full(k, diag, labels0, n_clusters=4)
    # fixpoint: one more sweep must not change labels
    res2 = kkmeans_fit_full(k, diag, res.labels, n_clusters=4)
    assert bool(jnp.all(res2.labels == res.labels))
    assert int(res2.n_iter) == 1


def test_inner_loop_with_pp_seeding_recovers_blobs(blobs):
    """With the paper's kernel k-means++ seeding the blobs are recovered."""
    x, y = blobs
    spec = KernelSpec("rbf", gamma=8.0)
    k, diag = _kernel_and_diag(x, spec)
    from repro.core import assign_to_medoids, kmeans_pp_indices
    seeds = kmeans_pp_indices(jnp.asarray(x), diag, jax.random.PRNGKey(0),
                              n_clusters=4, spec=spec)
    seed_x = jnp.take(jnp.asarray(x), seeds, axis=0)
    labels0, _ = assign_to_medoids(jnp.asarray(x), diag, seed_x,
                                   spec.diag(seed_x), spec=spec)
    res = kkmeans_fit_full(k, diag, labels0, n_clusters=4)
    assert clustering_accuracy(y, np.asarray(res.labels)) > 0.98


def test_inner_loop_cost_not_worse_than_init(blobs):
    x, _ = blobs
    spec = KernelSpec("rbf", gamma=8.0)
    k, diag = _kernel_and_diag(x, spec)
    rng = np.random.default_rng(1)
    labels0 = jnp.asarray(rng.integers(0, 4, len(x)), jnp.int32)

    # cost of the INITIAL labelling (one assignment sweep from labels0)
    res1 = kkmeans_fit_full(k, diag, labels0, n_clusters=4, max_iters=1)
    res = kkmeans_fit_full(k, diag, labels0, n_clusters=4)
    assert float(res.cost) <= float(res1.cost) + 1e-3


def test_landmarks_s1_equals_full(blobs):
    """s = 1 (landmarks == batch) must equal exact kernel k-means — via the
    precomputed-Gram entry AND the engine entry on raw features."""
    x, _ = blobs
    spec = KernelSpec("rbf", gamma=8.0)
    k, diag = _kernel_and_diag(x, spec)
    labels0 = jnp.zeros((len(x),), jnp.int32).at[: len(x) // 2].set(1)
    full = kkmeans_fit_full(k, diag, labels0, n_clusters=4)
    lidx = jnp.arange(len(x), dtype=jnp.int32)
    lm = kkmeans_fit_gram(k, lidx, diag, labels0, n_clusters=4)
    assert bool(jnp.all(full.labels == lm.labels))
    np.testing.assert_allclose(float(full.cost), float(lm.cost), rtol=1e-6)
    eng = kkmeans_fit(jnp.asarray(x), lidx, diag, labels0, spec=spec,
                      n_clusters=4)
    assert bool(jnp.all(full.labels == eng.labels))


def test_medoid_is_brute_force_argmin(blobs):
    x, _ = blobs
    spec = KernelSpec("rbf", gamma=8.0)
    k, diag = _kernel_and_diag(x, spec)
    labels0 = jnp.asarray(np.random.default_rng(2).integers(0, 4, len(x)),
                          jnp.int32)
    res = kkmeans_fit_full(k, diag, labels0, n_clusters=4)
    m_idx = medoid_indices(diag, res.f, res.labels, res.counts)
    # brute force Eq.7: argmin_l K_ll - 2 f_{l,j}
    score = np.asarray(diag)[:, None] - 2.0 * np.asarray(res.f)
    np.testing.assert_array_equal(np.asarray(m_idx), score.argmin(axis=0))


@pytest.mark.parametrize("sampling", ["stride", "block"])
def test_minibatch_fit_recovers_blobs(sampling):
    x, y = four_blobs(n_per=300, seed=3)
    cfg = MiniBatchConfig(n_clusters=4, n_batches=4, s=1.0,
                          kernel=KernelSpec("rbf", gamma=8.0),
                          sampling=sampling, seed=0)
    res = fit_dataset(x, cfg)
    labels = predict(jnp.asarray(x), res.state.medoids,
                     res.state.medoid_diag, spec=cfg.kernel)
    assert clustering_accuracy(y, np.asarray(labels)) > 0.95
    assert nmi(y, np.asarray(labels)) > 0.85
    # cardinalities account for every sample exactly once
    assert int(np.asarray(res.state.cardinalities).sum()) == len(x)


def test_minibatch_b1_equals_full_kkmeans(blobs):
    """B = 1 runs the exact algorithm; predicted labels must match running
    kkmeans_fit_full directly from the same initialization."""
    x, y = blobs
    cfg = MiniBatchConfig(n_clusters=4, n_batches=1, s=1.0,
                          kernel=KernelSpec("rbf", gamma=8.0), seed=0)
    res = fit_dataset(x, cfg)
    assert len(res.history) == 1
    labels = predict(jnp.asarray(x), res.state.medoids,
                     res.state.medoid_diag, spec=cfg.kernel)
    assert clustering_accuracy(y, np.asarray(labels)) > 0.98


def test_sparsity_knob_still_reasonable(blobs):
    """s = 0.25 on easy blobs should barely hurt (paper Fig.5: robust for
    s >= 0.2)."""
    x, y = blobs
    cfg = MiniBatchConfig(n_clusters=4, n_batches=2, s=0.25,
                          kernel=KernelSpec("rbf", gamma=8.0), seed=0)
    res = fit_dataset(x, cfg)
    labels = predict(jnp.asarray(x), res.state.medoids,
                     res.state.medoid_diag, spec=cfg.kernel)
    assert clustering_accuracy(y, np.asarray(labels)) > 0.9


def test_empty_cluster_keeps_global_medoid():
    """A batch that cannot populate cluster j must leave m_j untouched
    (alpha = 0 rule)."""
    rng = np.random.default_rng(4)
    # batch 0: two clusters near origin; batch 1: only one of them present
    b0 = np.concatenate([rng.normal(0.0, 0.05, (64, 2)),
                         rng.normal(5.0, 0.05, (64, 2))]).astype(np.float32)
    b1 = rng.normal(0.0, 0.05, (128, 2)).astype(np.float32)
    from repro.core.minibatch import fit
    cfg = MiniBatchConfig(n_clusters=2, n_batches=2, s=1.0,
                          kernel=KernelSpec("rbf", gamma=0.5), seed=0)
    res = fit([b0, b1], cfg)
    # one medoid stays at ~5.0 even though batch 1 never saw that cluster
    med = np.asarray(res.state.medoids)
    dist_to_far = np.abs(med - 5.0).sum(axis=1).min()
    assert dist_to_far < 0.5


def test_gamma_from_dmax_mimics_linear(blobs):
    """sigma = 4 d_max -> gamma so small the RBF kernel is near-linear
    (paper §4.4); on blobs it must still cluster perfectly."""
    x, y = blobs
    gamma = gamma_from_dmax(jnp.asarray(x))
    assert 0 < gamma < 10.0
    cfg = MiniBatchConfig(n_clusters=4, n_batches=1, s=1.0,
                          kernel=KernelSpec("rbf", gamma=gamma), seed=1)
    res = fit_dataset(x, cfg)
    labels = predict(jnp.asarray(x), res.state.medoids,
                     res.state.medoid_diag, spec=cfg.kernel)
    assert clustering_accuracy(y, np.asarray(labels)) > 0.95


@pytest.mark.parametrize("name", ["linear", "rbf", "polynomial", "cosine"])
def test_kernel_registry_psd_diag(name):
    spec = KernelSpec(name, gamma=0.3)
    x = jnp.asarray(np.random.default_rng(5).normal(size=(30, 5)),
                    jnp.float32)
    k = np.asarray(get_kernel(spec)(x, x))
    np.testing.assert_allclose(np.diagonal(k), np.asarray(spec.diag(x)),
                               rtol=1e-5, atol=1e-6)
    # Mercer kernels are symmetric PSD
    np.testing.assert_allclose(k, k.T, atol=1e-5)
    w = np.linalg.eigvalsh((k + k.T) / 2)
    assert w.min() > -1e-3
