"""Mixed-precision policy tests (kernels/precision.py + the bf16 tile path).

The policy's correctness contract, bounded here rather than assumed:

  * bf16 tiles + f32 accumulation produce IDENTICAL labels to the f32 path
    on well-separated data — across every Pallas wrapper and every
    GramEngine mode (rounding the operands cannot flip an argmin whose
    margin dwarfs the bf16 ulp).
  * on non-separable data the clustering-quality drift is bounded:
    |NMI_f32 - NMI_bf16| vs ground truth <= 1e-3.
  * the Pallas bodies match the ``ref.py`` oracles at BOTH precisions to
    f32-accumulation tolerance (the oracle rounds its tiles the same way,
    so bf16 is not an excuse for loose comparisons).
  * ``check_precision`` statically catches a kernel that accumulates at
    tile precision — the booby-trap test writes that bug on purpose.
  * the planner prices tiles by dtype: the same workload can sit on
    different sides of the materialize/tiled frontier at f32 vs bf16.
"""
from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from sklearn.datasets import make_blobs

from repro.analysis import audit
from repro.core import GramEngine, KernelSpec, MachineSpec, nmi, plan
from repro.core.kkmeans import kkmeans_fit
from repro.kernels import ops, ref
from repro.kernels.precision import (BF16, F32, PRECISIONS, Precision,
                                     resolve_precision)

PREC_IDS = list(PRECISIONS)
BACKENDS = ["tpu", "gpu"]
ENGINE_MODES = ["materialize", "fused", "tiled"]


# ---------------------------------------------------------------- fixtures

def _separated(n=192, d=16, c=5, seed=0):
    """Well-separated blobs: margins >> bf16 ulp, labels must not move."""
    x, y = make_blobs(n_samples=n, n_features=d, centers=c, cluster_std=0.4,
                      center_box=(-8.0, 8.0), random_state=seed)
    return jnp.asarray(x.astype(np.float32)), y


def _nonseparable(n=300, d=12, c=6, seed=3):
    """Overlapping blobs: labels MAY move, quality drift must be bounded."""
    x, y = make_blobs(n_samples=n, n_features=d, centers=c, cluster_std=1.5,
                      center_box=(-5.0, 5.0), random_state=seed)
    return jnp.asarray(x.astype(np.float32)), y


def _assign_inputs(x, c, seed=0):
    """Landmark/label/compactness panels for the fused assignment kernel."""
    rng = np.random.default_rng(seed)
    lm = x[jnp.asarray(np.sort(rng.choice(x.shape[0], 64, replace=False)))]
    labels_l = jnp.asarray(rng.integers(0, c, 64), jnp.int32)
    counts = jnp.maximum(
        jnp.zeros(c).at[labels_l].add(1.0), 1.0).astype(jnp.float32)
    g = jnp.asarray(rng.uniform(0.5, 1.5, c), jnp.float32)
    return lm, labels_l, counts, g


# ------------------------------------------------------------ policy object

def test_precision_policy_object():
    p = resolve_precision("bf16")
    assert p.tile_dtype == jnp.bfloat16
    assert p.tile_itemsize == 2
    assert p.sign_dtype == jnp.dtype("int8")   # the int8 sign-table path
    f = resolve_precision("f32")
    assert f.tile_dtype == jnp.float32
    assert f.tile_itemsize == 4
    assert f.sign_dtype == jnp.dtype("float32")
    assert resolve_precision(BF16) is BF16 and resolve_precision(F32) is F32
    with pytest.raises(ValueError):
        resolve_precision("fp8")
    with pytest.raises(ValueError):
        Precision(tile="bf16", accum="bf16")   # not configurable, by design
    with pytest.raises(ValueError):
        GramEngine("materialize", precision="fp8")


# ----------------------------------------- Pallas vs oracle, both precisions

@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("precision", PREC_IDS)
def test_kernel_matrix_matches_oracle(precision, backend):
    """Oracle rounds tiles the same way -> tight tolerance at EVERY
    precision (bf16 is not an excuse for a loose comparison)."""
    x, _ = _separated(n=96, d=24)
    y = x[:40] + 0.25
    got = ops.kernel_matrix(x, y, kind="rbf", gamma=0.05, interpret=True,
                            precision=precision, backend=backend)
    want = ref.kernel_matrix_ref(x, y, kind="rbf", gamma=0.05,
                                 precision=precision)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("precision", PREC_IDS)
def test_assign_fused_matches_oracle(precision, backend):
    x, _ = _separated(n=160, d=16, c=5)
    lm, labels_l, counts, g = _assign_inputs(x, 5)
    labels, mind, f = ops.assign_fused(
        x, lm, labels_l, counts, g, n_clusters=5, kind="rbf", gamma=0.05,
        interpret=True, precision=precision, backend=backend)
    h = jax.nn.one_hot(labels_l, 5, dtype=jnp.float32) / counts[None, :]
    wl, wm, wf = ref.assign_fused_ref(x, lm, h, g, kind="rbf", gamma=0.05,
                                      precision=precision)
    assert bool(jnp.all(labels == wl))
    np.testing.assert_allclose(np.asarray(mind), np.asarray(wm),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(f), np.asarray(wf),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("precision", PREC_IDS)
def test_sketch_assign_matches_oracle(precision, backend):
    from repro.approx.sketch import make_count_sketch
    x, _ = _separated(n=128, d=32, c=4)
    fmap = make_count_sketch(jax.random.PRNGKey(1), 32, 16,
                             KernelSpec("linear"))
    cents = jnp.asarray(
        np.random.default_rng(2).normal(size=(4, 16)), jnp.float32)
    labels, score = ops.sketch_assign(x, fmap, cents, interpret=True,
                                      precision=precision, backend=backend)
    csq = jnp.sum(cents * cents, axis=1)
    wl, ws = ref.sketch_assign_ref(x, fmap.h, fmap.sign, cents.T, csq,
                                   precision=precision)
    assert bool(jnp.all(labels == wl))
    np.testing.assert_allclose(np.asarray(score), np.asarray(ws),
                               rtol=1e-5, atol=1e-5)


# ------------------------------------- labels identical on separated data

@pytest.mark.parametrize("wrapper",
                         ["assign_fused", "embed_assign", "sketch_assign"])
def test_labels_identical_bf16_vs_f32(wrapper):
    """On well-separated fixtures the bf16 tile path returns the SAME
    labels as f32 for every fused assignment wrapper."""
    x, _ = _separated()
    out = {}
    for precision in PRECISIONS:
        if wrapper == "assign_fused":
            lm, labels_l, counts, g = _assign_inputs(x, 5)
            labels, _, _ = ops.assign_fused(
                x, lm, labels_l, counts, g, n_clusters=5, kind="rbf",
                gamma=0.05, interpret=True, precision=precision)
        elif wrapper == "embed_assign":
            from repro.approx.rff import make_rff
            fmap = make_rff(jax.random.PRNGKey(0), 16, 64,
                            KernelSpec("rbf", gamma=0.05))
            cents = x[:5]                       # one seed row per blob
            labels, _ = ops.embed_assign(x, fmap, cents, interpret=True,
                                         precision=precision)
        else:
            from repro.approx.sketch import make_count_sketch
            fmap = make_count_sketch(jax.random.PRNGKey(1), 16, 16,
                                     KernelSpec("linear"))
            # centroids = class means in SKETCH space, so the separation
            # of the blobs survives the hash (random centroids would not
            # guarantee a margin and the assert would test luck, not the
            # precision policy)
            _, y = _separated()
            s = jax.nn.one_hot(fmap.h, 16, dtype=jnp.float32) \
                * fmap.sign[:, None]
            z = x @ s
            cents = jnp.stack([z[y == j].mean(0) for j in range(5)])
            labels, _ = ops.sketch_assign(x, fmap, cents, interpret=True,
                                          precision=precision)
        out[precision] = np.asarray(labels)
    assert (out["f32"] == out["bf16"]).all()


@pytest.mark.parametrize("mode", ENGINE_MODES)
def test_engine_labels_identical_bf16_vs_f32(mode):
    """Full kkmeans fit, per GramEngine mode: bf16 tiles do not move a
    single label on separated blobs."""
    x, _ = _separated(n=240, d=12, c=4)
    spec = KernelSpec("rbf", gamma=1.0 / 12)
    diag = spec.diag(x)
    rng = np.random.default_rng(0)
    l_idx = jnp.asarray(np.sort(rng.choice(240, 96, replace=False)),
                        jnp.int32)
    u0 = jnp.asarray(rng.integers(0, 4, 240), jnp.int32)
    labs = {}
    for precision in PRECISIONS:
        eng = GramEngine(mode, tile_rows=64, interpret=True,
                         precision=precision)
        res = kkmeans_fit(x, l_idx, diag, u0, spec=spec, n_clusters=4,
                          engine=eng)
        labs[precision] = np.asarray(res.labels)
    assert (labs["f32"] == labs["bf16"]).all()


def test_nmi_drift_bounded_nonseparable():
    """Overlapping blobs: labels may legitimately differ between
    precisions, but clustering quality vs ground truth must not —
    |NMI_f32 - NMI_bf16| <= 1e-3 (measured ~4.5e-4 on this fixture)."""
    x, y = _nonseparable()
    spec = KernelSpec("rbf", gamma=1.0 / 12)
    diag = spec.diag(x)
    rng = np.random.default_rng(0)
    l_idx = jnp.asarray(np.sort(rng.choice(300, 100, replace=False)),
                        jnp.int32)
    u0 = jnp.asarray(rng.integers(0, 6, 300), jnp.int32)
    labs = {}
    for precision in PRECISIONS:
        eng = GramEngine("materialize", precision=precision)
        res = kkmeans_fit(x, l_idx, diag, u0, spec=spec, n_clusters=6,
                          engine=eng)
        labs[precision] = np.asarray(res.labels)
    drift = abs(nmi(y, labs["f32"]) - nmi(y, labs["bf16"]))
    assert drift <= 1e-3, f"NMI drift {drift:.2e} > 1e-3"
    # the two labelings themselves stay close — overwhelmingly same points
    assert nmi(labs["f32"], labs["bf16"]) >= 0.9


# ------------------------------------------------- static precision audit

def test_check_precision_clean_on_shipped_kernels():
    """Both-dtype sweep over a shipped wrapper: zero violations, and the
    report actually saw a pallas_call (the check has teeth)."""
    x, _ = _separated(n=64, d=16)
    y = x[:32]
    for precision in PRECISIONS:
        rep = audit(
            lambda a, b: ops.kernel_matrix(
                a, b, kind="rbf", gamma=0.05, interpret=True,
                precision=precision),
            x, y, name=f"kernel_matrix[{precision}]")
        assert rep.pallas_calls >= 1
        assert rep.check_precision() == []


def test_check_precision_catches_bf16_accumulator():
    """Booby trap: a Pallas kernel whose dot_general accumulates in bf16 —
    exactly the bug a missing preferred_element_type introduces. The
    static audit must flag it."""
    from jax.experimental import pallas as pl

    def bad_kernel(x_ref, y_ref, o_ref):
        o_ref[...] = jax.lax.dot_general(
            x_ref[...], y_ref[...], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.bfloat16)    # the bug

    def bad(x, y):
        return pl.pallas_call(
            bad_kernel,
            out_shape=jax.ShapeDtypeStruct((128, 128), jnp.bfloat16),
            interpret=True)(x, y)

    x = jnp.zeros((128, 128), jnp.bfloat16)
    rep = audit(bad, x, x, name="booby_trap")
    violations = rep.check_precision()
    assert violations, "bf16 accumulator not flagged"
    assert any("bfloat16" in v for v in violations)
    with pytest.raises(Exception):
        rep.verify(violations)
    # report serialization must not drag kernel jaxprs into JSON
    d = rep.to_dict()
    assert "pallas_kernel_jaxprs" not in d
    json.dumps(d)


# ------------------------------------------------------- planner pricing

def test_plan_prices_tile_dtype():
    """Same workload, different tile dtype, different engine pick: the
    materialized Gram panel halves under bf16 and crosses back under the
    budget (the q_tile term in core.memory.engine_footprint_bytes)."""
    machine = MachineSpec(memory_bytes=0.6e9, n_processors=8)
    picks = {}
    for precision in PRECISIONS:
        p = plan(4_000_000, 64, machine, d=64, b=100, precision=precision)
        picks[precision] = p.engine
        assert p.precision == precision
        assert p.gram_engine().precision == precision
    # the note spells the non-default pricing out for the obs header
    assert "tiles priced at bf16" in \
        plan(4_000_000, 64, machine, d=64, b=100, precision="bf16").note
    assert picks["f32"] == "tiled"
    assert picks["bf16"] == "materialize"
    with pytest.raises(ValueError):
        plan(4_000_000, 64, machine, d=64, precision="fp8")


# --------------------------------------------- benchmark record columns

def test_record_bench_dtype_backend_columns(tmp_path, monkeypatch):
    import benchmarks.common as common
    monkeypatch.setenv("REPRO_BENCH", str(tmp_path))
    common.record_bench("precision_smoke", 1.25, mode="fused",
                        params={"n": 10}, dtype="bf16", backend="cpu")
    rec = json.loads((tmp_path / "BENCH_precision_smoke.json").read_text())
    assert rec["dtype"] == "bf16"
    assert rec["backend"] == "cpu"
    # backend defaults to the live jax platform when omitted
    common.record_bench("precision_smoke", 1.0, mode="fused")
    rec = json.loads((tmp_path / "BENCH_precision_smoke.json").read_text())
    assert rec["dtype"] == "f32"
    assert rec["backend"] == jax.default_backend()
