"""Integration: the multi-pod dry-run entry point itself (deliverable e).

Runs repro.launch.dryrun in a subprocess (it forces 512 host devices at
import, which must never leak into this test process) on one cell per
program kind, on BOTH production meshes, and checks the emitted JSON
schema that §Roofline consumes.
"""
import json
import os
import subprocess
import sys

import pytest

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_dryrun(tmp_path, *args):
    env = dict(os.environ, PYTHONPATH=os.path.join(_ROOT, "src"))
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--out", str(tmp_path),
         *args],
        env=env, capture_output=True, text=True, timeout=560, cwd=_ROOT)
    return out


@pytest.mark.slow
def test_dryrun_train_cell_both_meshes(tmp_path):
    out = _run_dryrun(tmp_path, "--arch", "olmo-1b", "--shape", "train_4k",
                      "--both-meshes")
    assert out.returncode == 0, out.stdout[-2000:] + out.stderr[-2000:]
    for mesh, chips in (("sp", 256), ("mp", 512)):
        d = json.load(open(tmp_path / f"olmo-1b__train_4k__{mesh}.json"))
        assert d["ok"], d.get("error")
        assert d["n_params"] > 1e9
        la = d["loop_aware"]
        assert la["flops_per_device"] > 0
        assert la["bytes_per_device"] > 0
        assert la["collective_bytes"] > 0
        assert d["memory_analysis"]["peak_bytes"] is not None
    # multi-pod halves per-device train FLOPs (batch shards over pod too)
    sp = json.load(open(tmp_path / "olmo-1b__train_4k__sp.json"))
    mp = json.load(open(tmp_path / "olmo-1b__train_4k__mp.json"))
    ratio = sp["loop_aware"]["flops_per_device"] \
        / mp["loop_aware"]["flops_per_device"]
    assert 1.6 < ratio < 2.4, ratio


@pytest.mark.slow
def test_dryrun_decode_cell(tmp_path):
    out = _run_dryrun(tmp_path, "--arch", "rwkv6-7b", "--shape",
                      "long_500k")
    assert out.returncode == 0, out.stdout[-2000:] + out.stderr[-2000:]
    d = json.load(open(tmp_path / "rwkv6-7b__long_500k__sp.json"))
    assert d["ok"]
    assert d["tokens_per_step"] == 1          # long_500k: global_batch 1


@pytest.mark.slow
def test_dryrun_cluster_cell(tmp_path):
    env = dict(os.environ, PYTHONPATH=os.path.join(_ROOT, "src"))
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun_cluster",
         "--mode", "paper-1d", "--out", str(tmp_path),
         "--rows", str(2**18), "--landmarks", "16384"],
        env=env, capture_output=True, text=True, timeout=560, cwd=_ROOT)
    assert out.returncode == 0, out.stdout[-2000:] + out.stderr[-2000:]
    d = json.load(open(tmp_path / "kkmeans-paper-1d__minibatch_1m__sp.json"))
    assert d["ok"]
    # the paper's bound: per-sweep collective bytes ~ |U| + C floats,
    # orders of magnitude below the K-block memory traffic
    la = d["loop_aware"]
    assert la["collective_bytes"] < 0.05 * la["bytes_per_device"]
