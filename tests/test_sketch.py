"""Sketch feature maps (count-sketch / TensorSketch) + the sparse path.

Covers: the shared FeatureMap contract over every map the system ships
(rff / orf / nystrom / sketch / tensorsketch), sketch unbiasedness
E[z(x).z(y)] ~= K(x, y), the CSR O(nnz) application against the dense
oracle, end-to-end ``method="sketch"`` fit/predict on CSR batches matching
the dense-oracle labels exactly, the fused Pallas sketch+assign kernel vs
its jnp oracle (interpret mode), and the planner's sketch footprint.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.approx import (CountSketchMap, TensorSketchMap, make_count_sketch,
                          make_feature_map, make_tensor_sketch)
from repro.core import (KernelSpec, MachineSpec, MiniBatchConfig, nmi, plan,
                        sketch_footprint_bytes)
from repro.core.minibatch import FitResult, GlobalState, fit
from repro.data.sampling import split_batches
from repro.data.sparse import (CSRBatch, csr_from_dense, split_csr,
                               take_rows, to_dense)
from repro.data.synthetic import make_rcv1_sparse
from repro.kernels import ops, ref

# ---------------------------------------------------------------------------
# FeatureMap contract — every map the system ships
# ---------------------------------------------------------------------------

_SPECS = {
    "rff": KernelSpec("rbf", gamma=0.5),
    "orf": KernelSpec("rbf", gamma=0.5),
    "nystrom": KernelSpec("rbf", gamma=0.5),
    "sketch": KernelSpec("linear"),
    "tensorsketch": KernelSpec("polynomial", gamma=1.0, coef0=0.5, degree=2),
}


def _make_map(case: str, key, x, m: int):
    method = "rff" if case == "orf" else case
    return make_feature_map(method, key, x, m, _SPECS[case],
                            orthogonal=(case == "orf"))


@pytest.mark.parametrize("case", sorted(_SPECS))
def test_feature_map_contract(case):
    n, d, m = 40, 12, 24
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    fmap = _make_map(case, jax.random.PRNGKey(0), x, m)

    assert fmap.dim == m
    assert fmap.in_dim == d
    z = fmap(x)
    assert z.shape == (n, m)
    assert z.dtype == jnp.float32

    # pytree round-trip preserves behaviour (checkpointing / shard_map)
    leaves, treedef = jax.tree_util.tree_flatten(fmap)
    rebuilt = jax.tree_util.tree_unflatten(treedef, leaves)
    assert type(rebuilt) is type(fmap)
    np.testing.assert_allclose(np.asarray(rebuilt(x)), np.asarray(z),
                               rtol=1e-6, atol=1e-6)

    # jit-ability with the map as a traced pytree argument
    z_jit = jax.jit(lambda f, xs: f(xs))(fmap, x)
    np.testing.assert_allclose(np.asarray(z_jit), np.asarray(z),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("case", ["sketch", "tensorsketch"])
def test_sketch_unbiased(case):
    """E[z(x).z(y)] ~= K(x, y), error shrinking ~1/sqrt(#seeds)."""
    n_seeds, d, m = 300, 30, 64
    rng = np.random.default_rng(1)
    x = rng.normal(size=(25, d)).astype(np.float32)
    y = rng.normal(size=(25, d)).astype(np.float32)
    x /= np.linalg.norm(x, axis=1, keepdims=True)
    y /= np.linalg.norm(y, axis=1, keepdims=True)
    spec = _SPECS[case]
    k = np.asarray(spec(jnp.asarray(x), jnp.asarray(y)))
    xj, yj = jnp.asarray(x), jnp.asarray(y)

    est = np.zeros_like(k)
    for s in range(n_seeds):
        fmap = _make_map(case, jax.random.PRNGKey(s), xj, m)
        est += np.asarray(fmap(xj) @ fmap(yj).T)
    err = np.abs(est / n_seeds - k).mean()
    assert err < 0.02, (case, err, np.abs(k).mean())


def test_sketch_gates_wrong_kernels():
    key = jax.random.PRNGKey(0)
    with pytest.raises(ValueError, match="linear"):
        make_count_sketch(key, 8, 16, KernelSpec("rbf"))
    with pytest.raises(ValueError, match="polynomial"):
        make_tensor_sketch(key, 8, 16, KernelSpec("rbf"))
    with pytest.raises(ValueError, match="gamma"):
        make_tensor_sketch(key, 8, 16,
                           KernelSpec("polynomial", gamma=-1.0))


# ---------------------------------------------------------------------------
# CSR batches: round-trip oracle + O(nnz) application
# ---------------------------------------------------------------------------


def _random_sparse(n, d, density, seed):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, d)).astype(np.float32)
    x[rng.random(x.shape) >= density] = 0.0
    return x


def test_csr_dense_roundtrip():
    x = _random_sparse(37, 53, 0.1, 2)
    b = csr_from_dense(x)
    np.testing.assert_array_equal(to_dense(b), x)
    assert b.nnz == int((x != 0).sum())
    # row selection matches dense row selection
    idx = np.asarray([31, 4, 4, 0])
    np.testing.assert_array_equal(to_dense(take_rows(b, idx)), x[idx])
    # stride split matches the dense splitter
    dense_parts = split_batches(x, 3, strategy="stride")
    for sp, dn in zip(split_csr(b, 3, strategy="stride"), dense_parts):
        np.testing.assert_array_equal(to_dense(sp), dn)


@pytest.mark.parametrize("case", ["sketch", "tensorsketch"])
def test_sketch_csr_matches_dense(case):
    x = _random_sparse(50, 64, 0.08, 3)
    b = csr_from_dense(x)
    fmap = _make_map(case, jax.random.PRNGKey(0), jnp.asarray(x), 32)
    z_dense = np.asarray(fmap(jnp.asarray(x)))
    z_csr = np.asarray(fmap(b))
    np.testing.assert_allclose(z_csr, z_dense, rtol=1e-5, atol=1e-5)


def test_sparse_sample_rejected_for_dense_maps():
    b = csr_from_dense(_random_sparse(16, 8, 0.2, 4))
    with pytest.raises(ValueError, match="dense"):
        make_feature_map("rff", jax.random.PRNGKey(0), b, 16,
                         KernelSpec("rbf"))


# ---------------------------------------------------------------------------
# end-to-end sparse fit/predict == dense oracle
# ---------------------------------------------------------------------------


def test_sketch_fit_csr_matches_dense_oracle():
    """fit/predict on CSR batches must label exactly like the same fit on
    the densified batches — the O(nnz) path changes cost, not results."""
    xs, y = make_rcv1_sparse(1500, vocab=512, n_classes=6, seed=0)
    cfg = MiniBatchConfig(n_clusters=6, n_batches=3,
                          kernel=KernelSpec("linear"), seed=0,
                          method="sketch", embed_dim=64)
    res_sparse = fit(split_csr(xs, 3, strategy="stride"), cfg)
    xd = to_dense(xs)
    res_dense = fit(split_batches(xd, 3, strategy="stride"), cfg)

    labels_sparse = np.asarray(res_sparse.predict(xs))
    labels_dense = np.asarray(res_dense.predict(jnp.asarray(xd)))
    np.testing.assert_array_equal(labels_sparse, labels_dense)
    np.testing.assert_allclose(
        np.asarray(res_sparse.state.centroids),
        np.asarray(res_dense.state.centroids), rtol=1e-4, atol=1e-5)
    assert int(np.asarray(res_sparse.state.cardinalities).sum()) == len(xs)
    assert nmi(y, labels_sparse) >= 0.5      # clusters are real, not noise
    assert isinstance(res_sparse.fmap, CountSketchMap)


def test_tensorsketch_fit_runs_on_csr():
    xs, y = make_rcv1_sparse(900, vocab=256, n_classes=4, seed=1)
    cfg = MiniBatchConfig(n_clusters=4, n_batches=3,
                          kernel=KernelSpec("polynomial", gamma=1.0,
                                            coef0=0.5, degree=2),
                          seed=0, method="tensorsketch", embed_dim=64)
    res = fit(split_csr(xs, 3, strategy="stride"), cfg)
    assert isinstance(res.fmap, TensorSketchMap)
    labels = np.asarray(res.predict(xs))
    assert labels.shape == (len(xs),)
    assert nmi(y, labels) >= 0.3


# ---------------------------------------------------------------------------
# fused Pallas sketch+assign kernel vs oracle (interpret mode)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("shape", [(64, 16, 32, 5), (100, 30, 77, 13),
                                   (300, 520, 260, 130)],
                         ids=["small", "ragged", "multiblock"])
def test_sketch_assign_matches_oracle(shape):
    n, d, m, c = shape
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    centroids = jnp.asarray(rng.normal(size=(c, m)).astype(np.float32))
    fmap = make_count_sketch(jax.random.PRNGKey(0), d, m,
                             KernelSpec("linear"))
    labels, score = ops.embed_assign(x, fmap, centroids, interpret=True)
    c32 = centroids.astype(jnp.float32)
    csq = jnp.sum(c32 * c32, axis=1)
    want_labels, want_score = ref.sketch_assign_ref(x, fmap.h, fmap.sign,
                                                    c32.T, csq)
    np.testing.assert_array_equal(np.asarray(labels),
                                  np.asarray(want_labels))
    np.testing.assert_allclose(np.asarray(score), np.asarray(want_score),
                               rtol=1e-4, atol=1e-4)
    # and the oracle itself agrees with the materialized embedding
    z = fmap(x)
    d2 = jnp.argmin(((z[:, None, :] - c32[None]) ** 2).sum(-1), axis=1)
    np.testing.assert_array_equal(np.asarray(labels), np.asarray(d2))


def test_sketch_assign_masks_empty_clusters():
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.normal(size=(32, 24)).astype(np.float32))
    centroids = jnp.asarray(rng.normal(size=(4, 16)).astype(np.float32))
    fmap = make_count_sketch(jax.random.PRNGKey(0), 24, 16,
                             KernelSpec("linear"))
    counts = jnp.asarray([5.0, 0.0, 3.0, 2.0])
    labels, _ = ops.embed_assign(x, fmap, centroids, counts, interpret=True)
    assert not np.any(np.asarray(labels) == 1)


def test_fused_sketch_predict_matches_jnp_path():
    from repro.approx import EmbedState, predict_embedded

    rng = np.random.default_rng(6)
    x = jnp.asarray(rng.normal(size=(40, 20)).astype(np.float32))
    fmap = make_count_sketch(jax.random.PRNGKey(0), 20, 16,
                             KernelSpec("linear"))
    centroids = jnp.asarray(rng.normal(size=(3, 16)).astype(np.float32))
    state = EmbedState(centroids=centroids,
                       cardinalities=jnp.asarray([10.0, 4.0, 10.0]),
                       batches_done=jnp.array(1, jnp.int32))
    l_jnp = np.asarray(predict_embedded(x, state, fmap, use_fused=False))
    l_fused = np.asarray(predict_embedded(x, state, fmap, use_fused=True))
    np.testing.assert_array_equal(l_jnp, l_fused)


def test_distributed_embed_sketch_single_device_mesh():
    """The sketch map flows through the row-sharded distributed path (the
    pytree registration makes it shard_map-closable) and reproduces the
    single-device fit on a 1-device mesh."""
    from repro.core.minibatch import fit_dataset
    from repro.distributed import DistributedEmbedKMeans, make_test_mesh

    rng = np.random.default_rng(0)
    centers = np.array([[0.25, 0.25], [0.75, 0.75],
                        [0.25, 0.75], [0.75, 0.25]])
    x = np.concatenate([rng.normal(c, 0.05, size=(200, 2))
                        for c in centers]).astype(np.float32)
    y = np.repeat(np.arange(4), 200)
    perm = rng.permutation(len(x))
    x, y = x[perm], y[perm]

    cfg = MiniBatchConfig(n_clusters=4, n_batches=4,
                          kernel=KernelSpec("linear"), seed=0,
                          method="sketch", embed_dim=16)
    single = fit_dataset(x, cfg)
    dist = DistributedEmbedKMeans(make_test_mesh({"data": 1}), cfg).fit(
        split_batches(x, 4, strategy="stride"))
    labels = np.asarray(dist.predict(jnp.asarray(x)))
    assert nmi(np.asarray(single.predict(x)), labels) >= 0.99
    assert nmi(y, labels) >= 0.9
    assert int(np.asarray(dist.state.cardinalities).sum()) == len(x)


# ---------------------------------------------------------------------------
# planner + predict-spec guard
# ---------------------------------------------------------------------------


def test_plan_names_sketch_for_sparse_highdim():
    machine = MachineSpec(memory_bytes=16e9, n_processors=256)
    # RCV1-ish: huge sparse d, linear kernel -> sketch must win
    p = plan(1_000_000, 50, machine, d=47236, embed_dim=256,
             sketchable=True, density=2e-3)
    assert np.isfinite(p.sketch_footprint)
    assert p.sketch_footprint < p.embed_footprint
    assert p.method == "sketch"
    assert "sketch" in p.note
    # default stays sketch-free (planner can't know the kernel is linear)
    p0 = plan(1_000_000, 50, machine, d=47236, embed_dim=256)
    assert p0.method in ("exact", "embed")
    assert not np.isfinite(p0.sketch_footprint)


def test_sketch_footprint_scaling():
    base = sketch_footprint_bytes(1_000_000, 10, 16, 8, m=64, d=50_000,
                                  density=1e-2)
    # sketch map tables are O(d), dense-embedded map params are O(m*d):
    from repro.core import embed_footprint_bytes
    assert base < embed_footprint_bytes(1_000_000, 10, 16, 8, m=64,
                                        d=50_000)
    # denser rows cost more
    assert sketch_footprint_bytes(1_000_000, 10, 16, 8, m=64, d=50_000,
                                  density=1e-1) > base


def test_predict_requires_spec():
    state = GlobalState(
        medoids=jnp.zeros((2, 2), jnp.float32),
        medoid_diag=jnp.ones((2,), jnp.float32),
        cardinalities=jnp.ones((2,), jnp.float32),
        batches_done=jnp.array(1, jnp.int32))
    res = FitResult(state, [], spec=None)
    with pytest.raises(ValueError, match="KernelSpec"):
        res.predict(np.zeros((3, 2), np.float32))
