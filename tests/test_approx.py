"""Tests for the explicit feature-map subsystem (repro.approx).

Covers: Gram approximation quality (error shrinks with m), end-to-end
``method="rff"|"nystrom"`` fits recovering the exact clustering, the fused
embed+assign Pallas kernel vs its jnp oracle (interpret mode), the planner's
embedded-space footprint, and the row-sharded distributed embedded path
(subprocess, 8 forced host devices — same pattern as test_distributed.py).
"""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import four_blobs
from repro.approx import (default_embed_dim, make_feature_map, make_nystrom,
                          make_rff, nystrom_features, rff_features)
from repro.core import (KernelSpec, MachineSpec, MiniBatchConfig,
                        embed_footprint_bytes, footprint_bytes, nmi, plan)
from repro.core.minibatch import fit_dataset
from repro.kernels import ops, ref

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _gram_err(k_approx, k_exact):
    return float(jnp.mean(jnp.abs(k_approx - k_exact)))


# ---------------------------------------------------------------------------
# feature-map approximation quality
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("orthogonal", [False, True], ids=["iid", "orf"])
def test_rff_gram_error_shrinks_with_m(orthogonal):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(200, 6)).astype(np.float32))
    y = jnp.asarray(rng.normal(size=(120, 6)).astype(np.float32))
    spec = KernelSpec("rbf", gamma=0.3)
    k = spec(x, y)
    errs = []
    for m in (32, 2048):
        fmap = make_rff(jax.random.PRNGKey(0), 6, m, spec,
                        orthogonal=orthogonal)
        errs.append(_gram_err(rff_features(x, fmap) @ rff_features(y, fmap).T,
                              k))
    assert errs[1] < errs[0] / 2, errs          # O(1/sqrt(m)) decay
    assert errs[1] < 0.05


def test_orthogonal_rff_beats_iid_at_same_m():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(256, 8)).astype(np.float32))
    spec = KernelSpec("rbf", gamma=0.25)
    k = spec(x, x)
    errs = {}
    for orth in (False, True):
        e = []
        for seed in range(5):
            fmap = make_rff(jax.random.PRNGKey(seed), 8, 64, spec,
                            orthogonal=orth)
            z = rff_features(x, fmap)
            e.append(_gram_err(z @ z.T, k))
        errs[orth] = np.mean(e)
    assert errs[True] <= errs[False] * 1.05     # ORF no worse on average


def test_nystrom_gram_error_shrinks_with_m():
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(size=(300, 5)).astype(np.float32))
    spec = KernelSpec("rbf", gamma=0.5)
    k = spec(x, x)
    errs = []
    for m in (10, 150):
        fmap = make_nystrom(jax.random.PRNGKey(0), x, m, spec)
        z = nystrom_features(x, fmap)
        errs.append(_gram_err(z @ z.T, k))
    assert errs[1] < errs[0] / 2, errs
    assert errs[1] < 0.02


def test_nystrom_exact_on_landmarks():
    """The Nystrom map reproduces K exactly on the landmark set itself."""
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(64, 4)).astype(np.float32))
    spec = KernelSpec("rbf", gamma=1.0)
    fmap = make_nystrom(jax.random.PRNGKey(0), x, 64, spec)  # all landmarks
    z = nystrom_features(fmap.landmarks, fmap)
    np.testing.assert_allclose(np.asarray(z @ z.T),
                               np.asarray(spec(fmap.landmarks,
                                               fmap.landmarks)),
                               rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("selector", ["uniform", "rls", "kpp"])
def test_nystrom_feature_map_contract_over_selectors(selector):
    """The FeatureMap contract must hold for every landmark-selection
    strategy: dim/in_dim, [n, m] f32 output, pytree round-trip, jit with
    the map as a traced argument, and selection determinism."""
    n, d, m = 80, 10, 24
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    spec = KernelSpec("rbf", gamma=0.4)
    fmap = make_feature_map("nystrom", jax.random.PRNGKey(1), x, m, spec,
                            selector=selector)
    assert fmap.dim == m and fmap.in_dim == d
    z = fmap(x)
    assert z.shape == (n, m) and z.dtype == jnp.float32

    leaves, treedef = jax.tree_util.tree_flatten(fmap)
    rebuilt = jax.tree_util.tree_unflatten(treedef, leaves)
    assert type(rebuilt) is type(fmap)
    np.testing.assert_allclose(np.asarray(rebuilt(x)), np.asarray(z),
                               rtol=1e-6, atol=1e-6)
    z_jit = jax.jit(lambda f, xs: f(xs))(fmap, x)
    np.testing.assert_allclose(np.asarray(z_jit), np.asarray(z),
                               rtol=1e-5, atol=1e-5)

    again = make_feature_map("nystrom", jax.random.PRNGKey(1), x, m, spec,
                             selector=selector)
    np.testing.assert_array_equal(np.asarray(again.landmarks),
                                  np.asarray(fmap.landmarks))
    # the map reproduces K exactly on its own landmark set (rank-m
    # property, selector-independent)
    zl = fmap(fmap.landmarks)
    np.testing.assert_allclose(np.asarray(zl @ zl.T),
                               np.asarray(spec(fmap.landmarks,
                                               fmap.landmarks)),
                               rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("selector", ["rls", "kpp"])
def test_embedded_nystrom_fit_with_selector(selector, blobs):
    x, y = blobs
    cfg = MiniBatchConfig(n_clusters=4, n_batches=4,
                          kernel=KernelSpec("rbf", gamma=8.0), seed=0,
                          method="nystrom", embed_dim=24, selector=selector)
    res = fit_dataset(x, cfg)
    assert nmi(y, np.asarray(res.predict(x))) >= 0.9
    assert int(np.asarray(res.state.cardinalities).sum()) == len(x)


def test_rff_rejects_non_shift_invariant_kernels():
    with pytest.raises(ValueError, match="shift-invariant"):
        make_rff(jax.random.PRNGKey(0), 4, 16, KernelSpec("polynomial"))
    with pytest.raises(ValueError):
        make_feature_map("bogus", jax.random.PRNGKey(0),
                         jnp.zeros((8, 4)), 16, KernelSpec("rbf"))
    # "sketch" is a valid method now but still gated to the linear kernel
    with pytest.raises(ValueError, match="linear"):
        make_feature_map("sketch", jax.random.PRNGKey(0),
                         jnp.zeros((8, 4)), 16, KernelSpec("rbf"))


# ---------------------------------------------------------------------------
# end-to-end embedded fits
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("method", ["rff", "nystrom"])
def test_embedded_fit_matches_exact_on_blobs(method, blobs):
    x, y = blobs
    spec = KernelSpec("rbf", gamma=8.0)
    exact = fit_dataset(x, MiniBatchConfig(n_clusters=4, n_batches=4,
                                           kernel=spec, seed=0))
    labels_exact = np.asarray(exact.predict(x))
    cfg = MiniBatchConfig(n_clusters=4, n_batches=4, kernel=spec, seed=0,
                          method=method)          # embed_dim=0 -> m = 4*C
    res = fit_dataset(x, cfg)
    assert res.fmap is not None and res.fmap.dim == default_embed_dim(4)
    labels = np.asarray(res.predict(x))
    assert nmi(labels_exact, labels) >= 0.9
    assert nmi(y, labels) >= 0.9
    # the convex merge accumulates every sample exactly once
    assert int(np.asarray(res.state.cardinalities).sum()) == len(x)


def test_embedded_fit_single_batch_and_config_validation(blobs):
    x, y = blobs
    res = fit_dataset(x, MiniBatchConfig(n_clusters=4, n_batches=1,
                                         kernel=KernelSpec("rbf", gamma=8.0),
                                         seed=0, method="rff", embed_dim=32))
    assert res.fmap.dim == 32
    assert nmi(y, np.asarray(res.predict(x))) >= 0.9
    with pytest.raises(ValueError, match="method"):
        MiniBatchConfig(n_clusters=4, method="bogus")


# ---------------------------------------------------------------------------
# fused embed+assign Pallas kernel vs oracle (interpret mode)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("map_kind", ["rff", "nystrom"])
@pytest.mark.parametrize("shape", [(64, 16, 32, 5), (100, 30, 77, 13),
                                   (300, 40, 260, 130)],
                         ids=["small", "ragged", "multiblock"])
def test_embed_assign_matches_oracle(map_kind, shape):
    n, d, m, c = shape
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    centroids = jnp.asarray(rng.normal(size=(c, m)).astype(np.float32))
    spec = KernelSpec("rbf", gamma=0.5)
    key = jax.random.PRNGKey(0)
    if map_kind == "rff":
        fmap = make_rff(key, d, m, spec)
    else:
        fmap = make_nystrom(key, x, m, spec)
    labels, score = ops.embed_assign(x, fmap, centroids, interpret=True)
    w, aux, v, csq, statics = ops.embed_panels(fmap, centroids)
    b = aux[:, 0] if map_kind == "rff" else None
    want_labels, want_score = ref.embed_assign_ref(x, w, v, csq, b=b,
                                                   **statics)
    np.testing.assert_array_equal(np.asarray(labels),
                                  np.asarray(want_labels))
    np.testing.assert_allclose(np.asarray(score), np.asarray(want_score),
                               rtol=1e-4, atol=1e-4)


def test_fused_predict_masks_empty_clusters_like_jnp_path():
    """predict_embedded must agree between the fused and jnp paths even
    when a cluster is empty (zero centroid would otherwise win every
    |c|^2 - 2 z.c comparison in the fused score)."""
    from repro.approx import EmbedState, predict_embedded

    rng = np.random.default_rng(6)
    x = jnp.asarray(rng.normal(size=(20, 8)).astype(np.float32))
    fmap = make_rff(jax.random.PRNGKey(0), 8, 16, KernelSpec("rbf"))
    centroids = jnp.asarray(rng.normal(size=(3, 16)).astype(np.float32))
    centroids = centroids.at[1].set(0.0)          # empty cluster, zero mean
    state = EmbedState(centroids=centroids,
                       cardinalities=jnp.asarray([10.0, 0.0, 10.0]),
                       batches_done=jnp.array(1, jnp.int32))
    l_jnp = np.asarray(predict_embedded(x, state, fmap, use_fused=False))
    l_fused = np.asarray(predict_embedded(x, state, fmap, use_fused=True))
    np.testing.assert_array_equal(l_jnp, l_fused)
    assert not np.any(l_fused == 1)


def test_embed_assign_masks_empty_clusters():
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.normal(size=(32, 8)).astype(np.float32))
    centroids = jnp.asarray(rng.normal(size=(4, 16)).astype(np.float32))
    fmap = make_rff(jax.random.PRNGKey(0), 8, 16, KernelSpec("rbf"))
    counts = jnp.asarray([5.0, 0.0, 3.0, 2.0])
    labels, _ = ops.embed_assign(x, fmap, centroids, counts, interpret=True)
    assert not np.any(np.asarray(labels) == 1)


# ---------------------------------------------------------------------------
# memory planner
# ---------------------------------------------------------------------------


def test_plan_reports_embedded_footprint():
    machine = MachineSpec(memory_bytes=16e9, n_processors=256)
    p = plan(10_000_000, 100, machine, d=784)
    assert p.embed_dim == 400                       # default m = 4*C
    assert np.isfinite(p.embed_footprint) and p.embed_footprint > 0
    # embedded rows are m wide vs s*N/B kernel columns: embed must win here
    assert p.embed_footprint < p.footprint
    assert p.method == "embed"
    # explicit m overrides the default
    assert plan(10_000_000, 100, machine, embed_dim=64).embed_dim == 64


def test_embed_footprint_scaling():
    base = embed_footprint_bytes(1_000_000, 10, 16, 8, m=64)
    assert embed_footprint_bytes(1_000_000, 10, 16, 8, m=128) > base
    assert embed_footprint_bytes(1_000_000, 20, 16, 8, m=64) < base
    # kernel-block footprint grows with N/B quadratically; embedded linearly
    k = footprint_bytes(1_000_000, 10, 16, 8)
    assert base < k


# ---------------------------------------------------------------------------
# distributed embedded path
# ---------------------------------------------------------------------------


def _run_subprocess(body: str) -> dict:
    script = textwrap.dedent("""\
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import json
        import numpy as np
        import jax
        import jax.numpy as jnp
    """) + textwrap.dedent(body)
    env = dict(os.environ, PYTHONPATH=os.path.join(_ROOT, "src"))
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=540)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-4000:]}"
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_distributed_embed_single_device_mesh(blobs):
    """1-device mesh must reproduce the single-device embedded fit."""
    from repro.data.sampling import split_batches
    from repro.distributed import DistributedEmbedKMeans, make_test_mesh

    x, y = blobs
    cfg = MiniBatchConfig(n_clusters=4, n_batches=4,
                          kernel=KernelSpec("rbf", gamma=8.0), seed=0,
                          method="rff")
    single = fit_dataset(x, cfg)
    mesh = make_test_mesh({"data": 1})
    dist = DistributedEmbedKMeans(mesh, cfg).fit(
        split_batches(x, 4, strategy="stride"))
    assert nmi(np.asarray(single.predict(x)),
               np.asarray(dist.predict(x))) >= 0.99
    assert int(np.asarray(dist.state.cardinalities).sum()) == len(x)


@pytest.mark.slow
@pytest.mark.parametrize("method", ["rff", "nystrom"])
def test_distributed_embed_matches_truth_8dev(method):
    """Row-sharded embedded path on 8 devices recovers the clustering,
    including the weight-masked row padding (n not divisible by 8)."""
    res = _run_subprocess(f"""
        from repro.core import MiniBatchConfig, KernelSpec
        from repro.core.metrics import nmi
        from repro.data.sampling import split_batches
        from repro.distributed import DistributedEmbedKMeans
        from repro.distributed.compat import make_mesh

        rng = np.random.default_rng(0)
        centers = np.array([[0.25,0.25],[0.75,0.75],[0.25,0.75],[0.75,0.25]])
        X = np.concatenate([rng.normal(c, 0.05, size=(515, 2))
                            for c in centers]).astype(np.float32)
        y = np.repeat(np.arange(4), 515)
        perm = rng.permutation(len(X)); X, y = X[perm], y[perm]

        mesh = make_mesh((8,), ("data",))
        cfg = MiniBatchConfig(n_clusters=4, n_batches=4, seed=0,
                              kernel=KernelSpec("rbf", gamma=8.0),
                              method="{method}")
        km = DistributedEmbedKMeans(mesh, cfg)
        res = km.fit(split_batches(X, 4, strategy="stride"))
        labels = np.asarray(res.predict(jnp.asarray(X)))
        total = int(np.asarray(res.state.cardinalities).sum())
        print(json.dumps({{"nmi": nmi(y, labels), "total": total,
                           "n": len(X)}}))
    """)
    assert res["nmi"] >= 0.9
    assert res["total"] == res["n"]     # padding never counted
