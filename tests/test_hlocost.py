"""Unit tests for the loop-aware HLO cost model (repro.launch.hlocost) —
every §Roofline number depends on it, so its FLOPs/trip-count/collective
accounting is locked here against analytically-known programs."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import pytest

from repro.launch import hlocost

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _hlo_of(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_scan_of_matmuls_flops_exact():
    """12-layer scan of [128,256]x[256,256] matmuls: trip-multiplied FLOPs
    must match 12 * 2MNK within 1% (cost_analysis reports ~1/12 of this)."""
    def f(x, w):
        def body(h, wi):
            return jnp.tanh(h @ wi), None
        h, _ = jax.lax.scan(body, x, w)
        return h.sum()

    x = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    w = jax.ShapeDtypeStruct((12, 256, 256), jnp.float32)
    cost = hlocost.analyze(_hlo_of(f, x, w))
    expect = 12 * 2 * 128 * 256 * 256
    assert abs(cost.flops - expect) / expect < 0.01


def test_single_matmul_flops_and_bytes():
    def f(a, b):
        return a @ b

    a = jax.ShapeDtypeStruct((64, 512), jnp.float32)
    b = jax.ShapeDtypeStruct((512, 128), jnp.float32)
    cost = hlocost.analyze(_hlo_of(f, a, b))
    expect_flops = 2 * 64 * 512 * 128
    assert abs(cost.flops - expect_flops) / expect_flops < 0.01
    # traffic >= operands + result (may include copies)
    min_bytes = (64 * 512 + 512 * 128 + 64 * 128) * 4
    assert cost.bytes >= min_bytes
    assert cost.bytes < 4 * min_bytes


def test_nested_scan_trip_multiplication():
    """outer scan 4 x inner scan 8 -> 32x the body cost."""
    def f(x, w):
        def inner(h, wi):
            return h @ wi, None

        def outer(h, _):
            h, _ = jax.lax.scan(inner, h, w)
            return h, None

        h, _ = jax.lax.scan(outer, x, None, length=4)
        return h.sum()

    x = jax.ShapeDtypeStruct((32, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((8, 64, 64), jnp.float32)
    cost = hlocost.analyze(_hlo_of(f, x, w))
    expect = 4 * 8 * 2 * 32 * 64 * 64
    assert abs(cost.flops - expect) / expect < 0.05


def test_comment_stripping_in_tuples():
    """Long HLO tuples embed /*index=N*/ comments whose '=' used to break
    the instruction regex (regression guard)."""
    comps = hlocost._split_computations(
        "ENTRY %main (a: f32[4]) -> f32[4] {\n"
        "  %t = (f32[4]{0}, /*index=1*/f32[4]{0}) tuple(%a, %a)\n"
        "  ROOT %r = f32[4]{0} get-tuple-element(%t), index=0\n"
        "}\n")
    assert "main" in comps
    assert len(comps["main"]) == 2
    m = hlocost._INSTR_RE.match(comps["main"][0])
    assert m and m.group(3) == "tuple"


def test_known_trip_count_preferred():
    line = ('%w = (s32[]) while(%t), condition=%c, body=%b, '
            'backend_config={"known_trip_count":{"n":"42"}}')
    assert hlocost._trip_count(line, ["%k = s32[] constant(99999)"]) == 42


def test_trip_count_root_compare_fallback():
    cond = [
        "%big = s32[] constant(151936)",          # decoy (vocab-sized)
        "%lim = s32[] constant(16)",
        "%i = s32[] get-tuple-element(%arg), index=0",
        "ROOT %cmp = pred[] compare(%i, %lim), direction=LT",
    ]
    assert hlocost._trip_count("%w = (s32[]) while(%t), condition=%c, "
                               "body=%b", cond) == 16


@pytest.mark.slow
def test_sharded_collective_accounting():
    """8-way sharded matmul sum: per-device FLOPs = total/8 and exactly one
    all-reduce is recorded with ring cost 2*(g-1)/g * bytes."""
    script = textwrap.dedent("""\
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import json
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch import hlocost

        def f(a, b):
            return jnp.sum(a @ b)

        from repro.distributed.compat import make_mesh
        mesh = make_mesh((8,), ("d",))
        a = jax.ShapeDtypeStruct((256, 128), jnp.float32)
        b = jax.ShapeDtypeStruct((128, 64), jnp.float32)
        hlo = jax.jit(f, in_shardings=(
            NamedSharding(mesh, P("d", None)),
            NamedSharding(mesh, P(None, None)))).lower(a, b) \\
            .compile().as_text()
        c = hlocost.analyze(hlo)
        print(json.dumps({"flops": c.flops,
                          "ar": c.coll_counts["all-reduce"],
                          "coll": c.coll_bytes}))
    """)
    env = dict(os.environ, PYTHONPATH=os.path.join(_ROOT, "src"))
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=540)
    assert out.returncode == 0, out.stderr[-2000:]
    import json
    res = json.loads(out.stdout.strip().splitlines()[-1])
    expect = 2 * 256 * 128 * 64 / 8
    assert abs(res["flops"] - expect) / expect < 0.05
    assert res["ar"] >= 1
    # scalar all-reduce: 2*(8-1)/8 * 4 bytes = 7
    assert 1 <= res["coll"] <= 64


# ---------------------------------------------------------------------------
# compiled-program compat helpers (PR 7 satellite: first direct coverage)


class _FakeMem:
    temp_size_in_bytes = 100
    argument_size_in_bytes = 40
    output_size_in_bytes = 8
    # no peak_memory_in_bytes attr: the CPU/old-JAX shape


class _FakeCompiled:
    def __init__(self, cost, mem="raise"):
        self._cost = cost
        self._mem = mem

    def cost_analysis(self):
        return self._cost

    def memory_analysis(self):
        if self._mem == "raise":
            raise NotImplementedError("no memory analysis on this backend")
        return self._mem


def test_xla_cost_list_vs_dict_vs_none():
    # pinned JAX: one-element list of per-computation dicts
    assert hlocost.xla_cost(_FakeCompiled([{"flops": 5.0}])) == {
        "flops": 5.0}
    # newer JAX: the dict directly
    assert hlocost.xla_cost(_FakeCompiled({"flops": 7.0})) == {"flops": 7.0}
    # backends with no analysis: None and [] both collapse to {}
    assert hlocost.xla_cost(_FakeCompiled(None)) == {}
    assert hlocost.xla_cost(_FakeCompiled([])) == {}


def test_xla_memory_guarded_on_cpu_shapes():
    empty = {"bytes_per_device": None, "argument_bytes": None,
             "output_bytes": None, "peak_bytes": None}
    # memory_analysis() raising (CPU) or returning None: all-None dict
    assert hlocost.xla_memory(_FakeCompiled({}, mem="raise")) == empty
    assert hlocost.xla_memory(_FakeCompiled({}, mem=None)) == empty
    # missing peak_memory_in_bytes attr: conservative temp+args+out bound
    got = hlocost.xla_memory(_FakeCompiled({}, mem=_FakeMem()))
    assert got["peak_bytes"] == 148
    assert got["argument_bytes"] == 40


def test_compiled_cost_terms_matmul():
    """End-to-end on a real compiled program: the loop-aware FLOPs match
    the analytic matmul count and every compat key is present."""
    n = 64

    def f(a, b):
        return a @ b

    a = jnp.ones((n, n), jnp.float32)
    b = jnp.ones((n, n), jnp.float32)
    terms = hlocost.compiled_cost_terms(f, a, b)
    expect = 2 * n ** 3
    assert abs(terms["flops"] - expect) / expect < 0.05
    assert terms["hbm_bytes"] >= 3 * n * n * 4
    assert terms["coll_counts"] == {}
    assert set(terms["memory"]) == {"bytes_per_device", "argument_bytes",
                                    "output_bytes", "peak_bytes"}
    # xla_flops may be None on backends without cost_analysis, but when
    # present it must agree with the loop-aware count (no loops here).
    if terms["xla_flops"] is not None:
        assert abs(terms["xla_flops"] - expect) / expect < 0.05


def test_compiled_cost_terms_static_kwargs_and_loops():
    """kwargs close over static config, and scan FLOPs are trip-multiplied
    (the whole reason this module exists)."""
    steps = 5
    n = 32

    def f(a, *, n_steps):
        def step(c, _):
            return c @ a, None
        out, _ = jax.lax.scan(step, a, None, length=n_steps)
        return out

    a = jnp.ones((n, n), jnp.float32)
    terms = hlocost.compiled_cost_terms(f, a, n_steps=steps)
    expect = steps * 2 * n ** 3
    assert abs(terms["flops"] - expect) / expect < 0.10
