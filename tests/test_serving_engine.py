"""Continuous-batching engine: scheduling + per-slot-cursor correctness."""
import jax

from repro.distributed.compat import make_mesh
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.models import Axes, get_model
from repro.serving import ServeConfig, ServingEngine

AXES = Axes(dp=("data",), tp="model")


def _mesh():
    return make_mesh((1, 1), ("data", "model"))


def _engine(arch, **kw):
    cfg = get_arch(arch, smoke=True)
    api = get_model(cfg, tp_size=1)
    params, _ = api.init(jax.random.PRNGKey(0), jnp.float32)
    return cfg, api, params


@pytest.mark.parametrize("arch", ["olmo-1b", "rwkv6-7b", "zamba2-2.7b"])
def test_engine_completes_more_requests_than_slots(arch):
    cfg, api, params = _engine(arch)
    eng = ServingEngine(api, params, ServeConfig(
        max_batch=4, max_len=64, max_new_tokens=8, eos_token=-1))
    rng = np.random.default_rng(0)
    uids = [eng.submit(rng.integers(1, cfg.vocab_size, size=l))
            for l in (5, 9, 3, 7, 6, 4)]
    with _mesh():
        out = eng.run(AXES)
    assert sorted(out) == sorted(uids)
    assert all(len(v) == 8 for v in out.values())
    # 6 requests x 7 decode ticks each, 4 slots -> batching must beat
    # sequential (42 ticks); allow scheduler slack.
    assert eng.ticks < 30


@pytest.mark.parametrize("arch", ["olmo-1b", "rwkv6-7b"])
def test_continuous_batching_matches_sequential(arch):
    """Requests decoded together (different cursors, shared cache) must
    produce exactly the tokens they produce alone — no cross-slot leakage."""
    cfg, api, params = _engine(arch)
    rng = np.random.default_rng(1)
    prompts = [rng.integers(1, cfg.vocab_size, size=l) for l in (5, 9, 3)]
    with _mesh():
        eng = ServingEngine(api, params, ServeConfig(
            max_batch=2, max_len=64, max_new_tokens=6, eos_token=-1))
        uids = [eng.submit(p) for p in prompts]
        batch_out = eng.run(AXES)
        for u, p in zip(uids, prompts):
            solo = ServingEngine(api, params, ServeConfig(
                max_batch=1, max_len=64, max_new_tokens=6, eos_token=-1))
            su = solo.submit(p)
            assert solo.run(AXES)[su] == batch_out[u], \
                f"slot interference for request {u}"


def test_eos_frees_slot_early():
    cfg, api, params = _engine("olmo-1b")
    with _mesh():
        # find the greedy first token for the probe prompt, then use it as
        # the EOS so the request terminates after one token.
        probe = ServingEngine(api, params, ServeConfig(
            max_batch=1, max_len=32, max_new_tokens=4, eos_token=-1))
        up = probe.submit([5, 6, 7])
        first = probe.run(AXES)[up][0]
        eng = ServingEngine(api, params, ServeConfig(
            max_batch=1, max_len=32, max_new_tokens=4, eos_token=first))
        u = eng.submit([5, 6, 7])
        out = eng.run(AXES)
    assert out[u] == [first]


def test_encdec_rejected():
    cfg = get_arch("seamless-m4t-medium", smoke=True)
    api = get_model(cfg, tp_size=1)
    with pytest.raises(ValueError, match="enc-dec"):
        ServingEngine(api, None, ServeConfig())
