"""Per-architecture smoke tests (assignment requirement f).

Each of the 10 assigned archs instantiates its REDUCED config, runs one
forward/train step on CPU, and asserts output shapes + finite values. The
full configs are exercised only via the dry-run (no allocation here).
"""
import jax

from repro.distributed.compat import make_mesh
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, ShapeConfig, TrainConfig, get_arch
from repro.configs.base import ModelConfig
from repro.models import Axes, get_model
from repro.models.common import padded_vocab_size
from repro.training.optim import adamw_init
from repro.training.step import make_train_step

AXES = Axes(dp=("data",), tp="model")
B, S = 2, 32


def _mesh():
    return make_mesh((1, 1), ("data", "model"))


def _batch(cfg: ModelConfig, key=0):
    rng = np.random.default_rng(key)
    tok = jnp.asarray(rng.integers(1, cfg.vocab_size, (B, S)), jnp.int32)
    batch = {"tokens": tok, "labels": jnp.roll(tok, -1, axis=1)}
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            rng.normal(size=(B, S, cfg.d_model)), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_smoke_forward_loss(arch):
    cfg = get_arch(arch, smoke=True)
    api = get_model(cfg, tp_size=1)
    params, specs = api.init(jax.random.PRNGKey(0))
    # params and specs trees must match exactly
    assert jax.tree.structure(params) == jax.tree.structure(
        specs, is_leaf=lambda x: not isinstance(x, dict))
    with _mesh():
        loss = api.loss(params, _batch(cfg), AXES, remat=False)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch}: loss not finite"


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_smoke_train_step(arch):
    cfg = get_arch(arch, smoke=True)
    api = get_model(cfg, tp_size=1)
    params, _ = api.init(jax.random.PRNGKey(0))
    tcfg = TrainConfig(remat=False, learning_rate=1e-3)
    opt = adamw_init(params, tcfg)
    step = make_train_step(api, tcfg, AXES)
    batch = _batch(cfg)
    with _mesh():
        p1, opt1, metrics = jax.jit(step)(params, opt, batch)
        p2, opt2, metrics2 = jax.jit(step)(p1, opt1, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics2["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    assert int(opt2.step) == 2
    # params actually moved
    moved = any(
        float(jnp.max(jnp.abs(a.astype(jnp.float32)
                              - b.astype(jnp.float32)))) > 0
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)))
    assert moved, f"{arch}: train step did not update params"


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_smoke_prefill_decode_shapes(arch):
    cfg = get_arch(arch, smoke=True)
    api = get_model(cfg, tp_size=1)
    params, _ = api.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    vp = padded_vocab_size(cfg.vocab_size)
    with _mesh():
        if cfg.family == "encdec":
            pre_batch = {"frames": batch["frames"],
                         "tokens": batch["tokens"][:, :4]}
            cache, logits = api.prefill(params, pre_batch, AXES, max_len=S)
            pos0 = 4
        else:
            cache, logits = api.prefill(params, batch, AXES, max_len=S)
            pos0 = S
        assert logits.shape[0] == B and logits.shape[-1] in (cfg.vocab_size, vp)
        assert bool(jnp.all(jnp.isfinite(
            jnp.where(jnp.arange(logits.shape[-1]) < cfg.vocab_size,
                      logits, 0.0))))
        tok = jnp.argmax(logits[:, :cfg.vocab_size], axis=-1).astype(jnp.int32)
        logits2, cache2 = api.decode(params, cache, tok,
                                     jnp.asarray(pos0, jnp.int32), AXES)
    assert logits2.shape == logits.shape
    assert jax.tree.structure(cache2) == jax.tree.structure(cache)


def test_moe_routes_to_multiple_experts():
    cfg = get_arch("qwen3-moe-235b-a22b", smoke=True)
    assert cfg.n_experts > 1 and cfg.moe_top_k >= 1
    api = get_model(cfg, tp_size=1)
    params, _ = api.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    from repro.models.transformer import forward
    with _mesh():
        hidden, _ = forward(params, batch["tokens"], cfg, AXES, remat=False)
    assert bool(jnp.all(jnp.isfinite(hidden.astype(jnp.float32))))


def test_gemma2_softcap_bounds_logits():
    cfg = get_arch("gemma2-2b", smoke=True)
    api = get_model(cfg, tp_size=1)
    params, _ = api.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    with _mesh():
        cache, logits = api.prefill(params, batch, AXES, max_len=S)
    assert cfg.final_softcap is not None
    assert float(jnp.max(jnp.abs(logits))) <= cfg.final_softcap + 1e-3


def test_full_configs_match_assignment():
    """The FULL configs must carry the exact paper-pool hyperparameters."""
    expect = {
        "qwen3-32b": (64, 5120, 64, 8, 25600, 151936),
        "internlm2-20b": (48, 6144, 48, 8, 16384, 92544),
        "gemma2-2b": (26, 2304, 8, 4, 9216, 256000),
        "olmo-1b": (16, 2048, 16, 16, 8192, 50304),
        "qwen3-moe-235b-a22b": (94, 4096, 64, 4, 1536, 151936),
        "grok-1-314b": (64, 6144, 48, 8, 32768, 131072),
        # seamless is enc-dec: 12L means 12 encoder + 12 decoder layers
        "seamless-m4t-medium": (24, 1024, 16, 16, 4096, 256206),
        "chameleon-34b": (48, 8192, 64, 8, 22016, 65536),
        "zamba2-2.7b": (54, 2560, 32, 32, 10240, 32000),
        "rwkv6-7b": (32, 4096, 64, 64, 14336, 65536),
    }
    for arch, (L, d, h, kv, ff, v) in expect.items():
        cfg = get_arch(arch)
        assert cfg.n_layers == L, arch
        assert cfg.d_model == d, arch
        assert cfg.n_heads == h, arch
        assert cfg.n_kv_heads == kv, arch
        assert cfg.d_ff == ff, arch
        assert cfg.vocab_size == v, arch
    moe = get_arch("qwen3-moe-235b-a22b")
    assert moe.n_experts == 128 and moe.moe_top_k == 8
    grok = get_arch("grok-1-314b")
    assert grok.n_experts == 8 and grok.moe_top_k == 2
    zamba = get_arch("zamba2-2.7b")
    assert zamba.ssm_state == 64
    sm = get_arch("seamless-m4t-medium")
    assert sm.n_enc_layers == 12 and sm.n_dec_layers == 12
    assert get_arch("gemma2-2b").attn_softcap == 50.0
    assert get_arch("qwen3-32b").qk_norm
    assert not get_arch("olmo-1b").parametric_norm
