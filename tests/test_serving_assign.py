"""Tests for the assignment-serving subsystem (repro.serving.artifact /
repro.serving.assign — ISSUE 10).

Covers: frozen artifact <-> FitResult predict parity (bit-identical labels
at f32, bounded NMI drift at bf16), npz save/load round-trip (bf16 tiles
exactly preserved), CSR == dense labels across every feature-map method and
both precisions, the booby-trapped padding proof (garbage padding rows
never perturb real rows' labels), the compile-count regression of the
bucket-routed ``FitResult.predict``, the continuous-batching service
(FIFO packing, partial consumption, admission control, AOT program count
== ladder size), and ``serve_footprint_bytes`` == measured
``artifact_nbytes`` at bucket=0.
"""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.approx import make_nystrom, make_rff
from repro.approx.sketch import make_count_sketch, make_tensor_sketch
from repro.core import KernelSpec, MiniBatchConfig, nmi
from repro.core.memory import serve_footprint_bytes
from repro.core.minibatch import fit_dataset
from repro.data.sparse import csr_from_dense
from repro.data.synthetic import make_blobs
from repro.kernels import ops
from repro.serving import (DEFAULT_BUCKETS, AssignServeConfig, AssignService,
                           QueueFull, artifact_nbytes, bucket_for, freeze,
                           freeze_map, load_artifact, predict_frozen,
                           save_artifact)

_PRECISIONS = ("f32", "bf16")

#: (method id, feature-map builder) — every map the serving layer freezes.
#: orf is the orthogonal RFF variant (same artifact kind, different tables).
_MAPS = {
    "rff": lambda key, d, m: make_rff(key, d, m,
                                      KernelSpec("rbf", gamma=0.5)),
    "orf": lambda key, d, m: make_rff(key, d, m,
                                      KernelSpec("rbf", gamma=0.5),
                                      orthogonal=True),
    "nystrom": lambda key, d, m: make_nystrom(
        key, jax.random.normal(key, (4 * m, d)), m,
        KernelSpec("rbf", gamma=0.5)),
    "sketch": lambda key, d, m: make_count_sketch(key, d, m,
                                                  KernelSpec("linear")),
    "tensorsketch": lambda key, d, m: make_tensor_sketch(
        key, d, m, KernelSpec("polynomial", gamma=0.5, coef0=1.0, degree=2)),
}


def _blob_artifact(method, precision="f32", *, d=6, m=32, c=4, seed=0):
    """Synthetic frozen artifact + query rows: centroids from blob means
    pushed through the map, so labels are well-separated (no float ties)."""
    x, y = make_blobs(200, d, c, sep=8.0, seed=seed)
    key = jax.random.PRNGKey(seed)
    fmap = _MAPS[method](key, d, m)
    z = np.asarray(fmap(jnp.asarray(x)), np.float64)
    centroids = np.stack([z[y == j].mean(0) for j in range(c)])
    counts = np.bincount(y, minlength=c).astype(np.float32)
    art = freeze_map(fmap, jnp.asarray(centroids, jnp.float32),
                     jnp.asarray(counts), precision=precision)
    return art, x, y


# ---------------------------------------------------------------------------
# artifact: freeze / save / load / pricing
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("method", ["rff", "nystrom", "sketch",
                                    "tensorsketch"])
@pytest.mark.parametrize("precision", _PRECISIONS)
def test_roundtrip_preserves_arrays(tmp_path, method, precision):
    art, _, _ = _blob_artifact(method, precision)
    path = str(tmp_path / "art.npz")
    save_artifact(art, path)
    art2 = load_artifact(path)
    assert art2.kind == art.kind and art2.precision == art.precision
    assert art2.statics == art.statics
    for k in art.arrays:
        a, b = np.asarray(art.arrays[k]), np.asarray(art2.arrays[k])
        assert a.dtype == b.dtype, k
        # bf16 -> f32 -> bf16 is lossless: bitwise equality, not allclose
        np.testing.assert_array_equal(
            a.view(np.uint16) if a.dtype.name == "bfloat16" else a,
            b.view(np.uint16) if b.dtype.name == "bfloat16" else b,
            err_msg=k)


@pytest.mark.parametrize("method", ["rff", "nystrom", "sketch",
                                    "tensorsketch"])
@pytest.mark.parametrize("precision", _PRECISIONS)
def test_serve_footprint_prices_the_artifact(method, precision):
    """The analytic price at bucket=0 IS the measured resident bytes."""
    art, _, _ = _blob_artifact(method, precision)
    predicted = serve_footprint_bytes(
        art.n_clusters, art.dim, art.in_dim, method=art.kind,
        q_tile=2 if precision == "bf16" else None,
        degree=int(art.statics.get("degree", 2)))
    assert predicted == artifact_nbytes(art)


def test_exact_footprint_and_freeze():
    x, _ = make_blobs(120, 5, 3, seed=1)
    cfg = MiniBatchConfig(n_clusters=3, n_batches=2,
                          kernel=KernelSpec("rbf", gamma=0.5))
    res = fit_dataset(x, cfg)
    art = freeze(res)
    assert art.kind == "exact"
    assert serve_footprint_bytes(3, 0, 5, method="exact") \
        == artifact_nbytes(art)
    np.testing.assert_array_equal(np.asarray(res.predict(x)),
                                  np.asarray(predict_frozen(art, x)))


def test_freeze_requires_spec_on_exact_path():
    x, _ = make_blobs(60, 4, 2, seed=0)
    res = fit_dataset(x, MiniBatchConfig(n_clusters=2, n_batches=1))
    with pytest.raises(ValueError, match="KernelSpec"):
        freeze(res._replace(spec=None))


# ---------------------------------------------------------------------------
# predict parity: frozen vs FitResult, CSR vs dense, f32 vs bf16
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("method,cfg_kw", [
    ("rff", dict(method="rff")),
    ("orf", dict(method="rff", rff_orthogonal=True)),
    ("nystrom", dict(method="nystrom")),
    ("sketch", dict(method="sketch", kernel=KernelSpec("linear"))),
    ("tensorsketch", dict(method="tensorsketch",
                          kernel=KernelSpec("polynomial", gamma=0.5,
                                            coef0=1.0, degree=2))),
])
def test_frozen_matches_live_predict_f32(method, cfg_kw):
    """freeze(result) predicts bit-identically to the live embedded path."""
    from repro.approx import predict_embedded
    x, _ = make_blobs(180, 6, 4, sep=8.0, seed=2)
    cfg = MiniBatchConfig(n_clusters=4, n_batches=2, embed_dim=32, seed=3,
                          **cfg_kw)
    res = fit_dataset(x, cfg)
    live = np.asarray(predict_embedded(jnp.asarray(x), res.state, res.fmap))
    frozen = np.asarray(predict_frozen(freeze(res), x))
    np.testing.assert_array_equal(live, frozen)
    # FitResult.predict is itself routed through the frozen bucket ladder
    np.testing.assert_array_equal(frozen, np.asarray(res.predict(x)))


@pytest.mark.parametrize("method", sorted(_MAPS))
@pytest.mark.parametrize("precision", _PRECISIONS)
def test_csr_matches_dense(method, precision):
    """CSR ingestion must label exactly like the dense path — sketch kinds
    through their O(nnz) program, the rest via row-local densification."""
    art, x, _ = _blob_artifact(method, precision)
    dense = np.asarray(predict_frozen(art, x))
    sparse = np.asarray(predict_frozen(art, csr_from_dense(x)))
    np.testing.assert_array_equal(dense, sparse)


@pytest.mark.parametrize("method", ["rff", "nystrom", "sketch"])
def test_bf16_drift_is_bounded(method):
    """bf16 tiles may flip near-tie labels, but cluster structure holds:
    NMI(f32 labels, bf16 labels) >= 0.95 on separated blobs."""
    art32, x, _ = _blob_artifact(method, "f32")
    art16, _, _ = _blob_artifact(method, "bf16")
    l32 = np.asarray(predict_frozen(art32, x))
    l16 = np.asarray(predict_frozen(art16, x))
    assert nmi(l32, l16) >= 0.95


# ---------------------------------------------------------------------------
# padding: the booby-trapped proof
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("method", ["rff", "sketch"])
def test_garbage_padding_never_perturbs_real_rows(method):
    """Pad a 5-row query to its 8-bucket with GARBAGE (1e6-scale rows,
    NaNs would poison a cross-row reduction) instead of zeros: the real
    rows' labels must be unchanged — the argmin is row-independent, which
    is exactly why zero-padding in the engine is safe."""
    from repro.serving.assign import _predict_padded
    art, x, _ = _blob_artifact(method)
    rows = np.asarray(x[:5], np.float32)
    clean = np.zeros((8, rows.shape[1]), np.float32)
    clean[:5] = rows
    trapped = np.full((8, rows.shape[1]), 1e6, np.float32)
    trapped[:5] = rows
    kw = dict(fused=False, interpret=True, backend="tpu")
    l_clean = np.asarray(_predict_padded(art, jnp.asarray(clean), **kw))
    l_trap = np.asarray(_predict_padded(art, jnp.asarray(trapped), **kw))
    np.testing.assert_array_equal(l_clean[:5], l_trap[:5])
    # and the engine's sliced output equals the clean bucket's real rows
    svc = AssignService(art, AssignServeConfig(warm=False))
    np.testing.assert_array_equal(np.asarray(svc.predict(rows)), l_clean[:5])


# ---------------------------------------------------------------------------
# compile-count regression: the bucket ladder bounds retracing
# ---------------------------------------------------------------------------


def test_predict_compile_count_bounded_by_buckets():
    """FitResult.predict at many distinct query counts may compile at most
    one program per DISTINCT BUCKET touched — the ISSUE 10 bugfix (it used
    to retrace per distinct query-batch shape)."""
    x, _ = make_blobs(200, 6, 4, sep=8.0, seed=4)
    cfg = MiniBatchConfig(n_clusters=4, n_batches=2, method="rff",
                          embed_dim=32, seed=4)
    res = fit_dataset(x, cfg)
    res.predict(x[:8])          # warm the 8-bucket
    base = ops.predict_assign._cache_size()
    for n in (3, 5, 7, 8):      # all land in the warmed 8-bucket
        res.predict(x[:n])
    assert ops.predict_assign._cache_size() == base
    res.predict(x[:60])         # first touch of the 64-bucket
    res.predict(x[:33])
    assert ops.predict_assign._cache_size() == base + 1
    res.predict(x[:200])        # chunks: 2 x 64-bucket + (72 ->) one more
    assert ops.predict_assign._cache_size() <= base + 2


def test_bucket_for():
    assert [bucket_for(n, DEFAULT_BUCKETS) for n in (1, 2, 8, 9, 64, 65,
                                                     512)] \
        == [1, 8, 8, 64, 64, 512, 512]
    with pytest.raises(ValueError):
        bucket_for(513, DEFAULT_BUCKETS)


# ---------------------------------------------------------------------------
# the continuous-batching service
# ---------------------------------------------------------------------------


def test_service_warm_compiles_ladder_and_only_ladder():
    art, x, _ = _blob_artifact("rff")
    svc = AssignService(art)
    assert svc.compiled_programs == len(DEFAULT_BUCKETS)
    svc.predict(x[:3])
    svc.predict(x[:100])
    assert svc.compiled_programs == len(DEFAULT_BUCKETS)


def test_service_packs_fifo_and_completes_all():
    """Many small requests ride one bucket; a large one drains chunked —
    every request gets exactly its own rows' labels back."""
    art, x, _ = _blob_artifact("rff", c=4)
    want = np.asarray(predict_frozen(art, x))
    svc = AssignService(art, AssignServeConfig(buckets=(1, 8, 64),
                                               max_queue_rows=4096))
    slices = [(0, 2), (2, 5), (5, 6), (6, 40), (40, 200)]
    uids = {svc.submit(x[a:b]): (a, b) for a, b in slices}
    done = svc.drain()
    assert sorted(done) == sorted(uids)
    for uid, (a, b) in uids.items():
        np.testing.assert_array_equal(done[uid], want[a:b])


def test_service_admission_control():
    art, x, _ = _blob_artifact("rff")
    svc = AssignService(art, AssignServeConfig(max_queue_rows=10,
                                               warm=False))
    svc.submit(x[:6])
    with pytest.raises(QueueFull):
        svc.submit(x[:5])
    svc.drain()
    svc.submit(x[:5])           # capacity freed once the queue drains


def test_service_csr_and_dense_interleave():
    art, x, _ = _blob_artifact("sketch")
    want = np.asarray(predict_frozen(art, x))
    svc = AssignService(art)
    u1 = svc.submit(x[:7])
    u2 = svc.submit(csr_from_dense(np.asarray(x[7:30])))
    u3 = svc.submit(x[30:31])
    done = svc.drain()
    np.testing.assert_array_equal(done[u1], want[:7])
    np.testing.assert_array_equal(done[u2], want[7:30])
    np.testing.assert_array_equal(done[u3], want[30:31])


def test_service_records_request_obs(tmp_path):
    from repro.obs import JsonlRecorder, export
    art, x, _ = _blob_artifact("rff")
    path = str(tmp_path / "serve.jsonl")
    with JsonlRecorder(path) as rec:
        svc = AssignService(art, recorder=rec)
        svc.predict(x[:5])
        svc.predict(x[:70])
    summary = export.summarize(path)
    assert summary["counters"]["serve/submitted"] == 2
    assert summary["stats"]["serve/queue_seconds"]["count"] == 2
    assert summary["stats"]["serve/compute_seconds"]["count"] == 2
    # warm + one event per request are in the log
    with open(path) as fh:
        lines = fh.read()
    assert lines.count('"serve/request"') == 2
    assert lines.count('"serve/warm"') == 1


def test_service_rejects_bad_width():
    art, x, _ = _blob_artifact("rff")
    svc = AssignService(art, AssignServeConfig(warm=False))
    with pytest.raises(ValueError, match="queries must be"):
        svc.submit(np.zeros((3, art.in_dim + 1), np.float32))
    with pytest.raises(ValueError, match="empty"):
        svc.submit(np.zeros((0, art.in_dim), np.float32))
