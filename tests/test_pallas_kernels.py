"""Per-kernel correctness: Pallas (interpret mode on CPU) vs the pure-jnp
oracle in repro.kernels.ref, swept over shapes / dtypes / kernel kinds.

The sweep deliberately includes shapes that do NOT divide the default block
sizes (padding paths) and bf16 inputs (fp32 accumulation contract).
"""
import jax

from repro.distributed.compat import make_mesh
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

KINDS = ["rbf", "linear", "polynomial", "cosine"]
SHAPES = [
    (8, 8, 4),          # tiny, everything padded
    (100, 77, 30),      # ragged
    (256, 256, 128),    # exactly one block
    (300, 520, 129),    # multi-block ragged in all dims
]
DTYPES = [jnp.float32, jnp.bfloat16]


def _data(m, n, d, dtype, seed=0):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(m, d)).astype(np.float32)).astype(dtype)
    y = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32)).astype(dtype)
    return x, y


def _tol(dtype):
    # bf16 features -> fp32 accumulation: error is bounded by input rounding.
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("kind", KINDS)
@pytest.mark.parametrize("shape", SHAPES, ids=lambda s: "x".join(map(str, s)))
@pytest.mark.parametrize("dtype", DTYPES, ids=["f32", "bf16"])
def test_kernel_matrix_matches_oracle(kind, shape, dtype):
    m, n, d = shape
    x, y = _data(m, n, d, dtype)
    got = ops.kernel_matrix(x, y, kind=kind, gamma=0.05, interpret=True)
    want = ref.kernel_matrix_ref(x.astype(jnp.float32),
                                 y.astype(jnp.float32), kind=kind, gamma=0.05)
    assert got.shape == (m, n) and got.dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **_tol(dtype))


@pytest.mark.parametrize("kind", ["rbf", "linear"])
@pytest.mark.parametrize("shape", [(64, 32, 16), (300, 130, 40)],
                         ids=["small", "ragged"])
@pytest.mark.parametrize("n_clusters", [3, 7, 130])
def test_assign_fused_matches_oracle(kind, shape, n_clusters):
    m, lm, d = shape
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(m, d)).astype(np.float32))
    landmarks = jnp.asarray(rng.normal(size=(lm, d)).astype(np.float32))
    labels_l = jnp.asarray(rng.integers(0, n_clusters, lm).astype(np.int32))
    counts = jnp.bincount(labels_l, length=n_clusters).astype(jnp.float32)
    g = jnp.asarray(rng.random(n_clusters).astype(np.float32))

    got_lab, got_min, got_f = ops.assign_fused(
        x, landmarks, labels_l, counts, g, n_clusters=n_clusters, kind=kind,
        gamma=0.05, interpret=True)

    h = jax.nn.one_hot(labels_l, n_clusters) / jnp.maximum(counts, 1.0)[None]
    g_masked = jnp.where(counts > 0, g, 1e30)
    want_lab, want_min, want_f = ref.assign_fused_ref(x, landmarks, h,
                                                      g_masked, kind=kind,
                                                      gamma=0.05)
    assert bool(jnp.all(got_lab == want_lab))
    np.testing.assert_allclose(np.asarray(got_min), np.asarray(want_min),
                               rtol=1e-4, atol=1e-5)
    # the f panel (Eq.17) feeds the Eq.7 medoid argmin — it must match too
    assert got_f.shape == (m, n_clusters)
    np.testing.assert_allclose(np.asarray(got_f), np.asarray(want_f),
                               rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("kind", ["rbf", "linear"])
@pytest.mark.parametrize("shape", [(64, 32, 16), (300, 130, 40)],
                         ids=["small", "ragged"])
def test_gram_matvec_matches_oracle(kind, shape):
    """The Gram-free matvec (GramEngine fused mode): K @ h without K in
    HBM must equal the materialized product for an arbitrary h panel."""
    m, lm, d = shape
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.normal(size=(m, d)).astype(np.float32))
    landmarks = jnp.asarray(rng.normal(size=(lm, d)).astype(np.float32))
    h = jnp.asarray(rng.random((lm, 5)).astype(np.float32))
    got = ops.gram_matvec(x, landmarks, h, kind=kind, gamma=0.05,
                          interpret=True)
    want = ref.kernel_matrix_ref(x, landmarks, kind=kind, gamma=0.05) @ h
    assert got.shape == (m, 5) and got.dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_assign_fused_empty_cluster_never_selected():
    """Clusters with zero landmarks must be unjoinable (+BIG distance)."""
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(size=(50, 8)).astype(np.float32))
    landmarks = x[:20]
    labels_l = jnp.asarray((np.arange(20) % 3).astype(np.int32))  # 0..2 only
    n_clusters = 5                                                # 3, 4 empty
    counts = jnp.bincount(labels_l, length=n_clusters).astype(jnp.float32)
    g = jnp.zeros((n_clusters,), jnp.float32)
    lab, _, _ = ops.assign_fused(x, landmarks, labels_l, counts, g,
                                 n_clusters=n_clusters, interpret=True)
    assert int(jnp.max(lab)) <= 2


def test_kernel_matrix_rbf_diag_is_one():
    x = jnp.asarray(np.random.default_rng(3).normal(size=(40, 6)),
                    jnp.float32)
    k = ops.kernel_matrix(x, x, kind="rbf", gamma=0.7, interpret=True)
    # ||x||^2 + ||x||^2 - 2 x.x cancels catastrophically in fp32: diag is
    # 1 +- a few ulps of the squared norms, not exactly 1.
    np.testing.assert_allclose(np.asarray(jnp.diagonal(k)), 1.0, atol=1e-5)
    # symmetry (not exploited by the layout — but true of the values)
    np.testing.assert_allclose(np.asarray(k), np.asarray(k).T, atol=1e-5)


# ---------------------------------------------------------------------------
# flash attention (EXPERIMENTS.md §Perf C3 kernel)
# ---------------------------------------------------------------------------

FLASH_CASES = [
    # (B, H, KH, Sq, Sk, dh, causal, softcap)
    (2, 4, 4, 128, 128, 64, True, None),      # MHA, aligned
    (1, 8, 2, 100, 100, 64, True, None),      # GQA + ragged (padding path)
    (2, 4, 2, 256, 256, 128, True, 50.0),     # gemma-style softcap
    (1, 2, 2, 64, 256, 64, False, None),      # cross attention (non-causal)
    (1, 4, 1, 200, 200, 64, True, None),      # MQA
]


@pytest.mark.parametrize("case", FLASH_CASES,
                         ids=[f"B{c[0]}H{c[1]}KH{c[2]}S{c[3]}x{c[4]}"
                              for c in FLASH_CASES])
def test_flash_attention_matches_oracle(case):
    b, h, kh, sq, sk, dh, causal, cap = case
    rng = np.random.default_rng(7)
    q = jnp.asarray(rng.normal(size=(b, h, sq, dh)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(b, kh, sk, dh)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(b, kh, sk, dh)).astype(np.float32))
    got = ops.flash_attention(q, k, v, causal=causal, softcap=cap,
                              interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=causal, softcap=cap)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_bf16():
    rng = np.random.default_rng(8)
    q = jnp.asarray(rng.normal(size=(1, 4, 128, 64)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(1, 2, 128, 64)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(1, 2, 128, 64)), jnp.bfloat16)
    got = ops.flash_attention(q, k, v, interpret=True)
    want = ref.flash_attention_ref(q.astype(jnp.float32),
                                   k.astype(jnp.float32),
                                   v.astype(jnp.float32))
    assert got.dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=3e-2, atol=3e-2)


def test_attn_impl_flash_equals_chunked_end_to_end():
    """attn_impl='flash' (Pallas path, interpret on CPU) produces the same
    loss as the chunked pure-JAX attention on a full smoke model."""
    import dataclasses
    from repro.configs import get_arch
    from repro.models import Axes, get_model
    axes = Axes(dp=("data",), tp="model")
    mesh = make_mesh((1, 1), ("data", "model"))
    base = get_arch("olmo-1b", smoke=True)
    apic = get_model(base, tp_size=1)
    apif = get_model(dataclasses.replace(base, attn_impl="flash"), tp_size=1)
    params, _ = apic.init(jax.random.PRNGKey(0), jnp.float32)
    rng = np.random.default_rng(0)
    tok = jnp.asarray(rng.integers(1, base.vocab_size, (2, 64)), jnp.int32)
    batch = {"tokens": tok, "labels": jnp.roll(tok, -1, 1)}
    with mesh:
        lc = apic.loss(params, batch, axes, remat=False)
        lf = apif.loss(params, batch, axes, remat=False)
    assert float(lc) == float(lf)
