"""Benchmark-layer smoke coverage.

The Tab.2 baseline once shipped a nearest-centroid assignment that dropped
the per-cluster +||c||^2 term and misreported every baseline metric — a
class of bug only catchable at the benchmark layer. Two guards:

* a fast unit test of ``benchmarks.common.nearest_centroid`` on a case the
  broken formula gets wrong, and
* a ``slow``-marked subprocess smoke of ``benchmarks/run.py --fast --only
  tab2_rcv1`` (CI sizes) asserting the run finishes and emits the full JSON
  schema, sparse sketch grid included.
"""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, _ROOT)

from benchmarks.common import nearest_centroid  # noqa: E402


def test_nearest_centroid_includes_center_norms():
    """Dropping ||c||^2 makes big-norm centroids win every argmin — the
    exact bug the Tab.2 baseline shipped with."""
    centers = np.array([[0.0, 0.0], [10.0, 0.0]], np.float32)
    x = np.array([[1.0, 0.0], [9.0, 0.0]], np.float32)
    # without +||c||^2 the scores for row 0 are [1, -19] -> wrong label 1
    np.testing.assert_array_equal(nearest_centroid(x, centers), [0, 1])


def test_nearest_centroid_matches_bruteforce():
    rng = np.random.default_rng(0)
    x = rng.normal(size=(100, 7)) * 3.0
    centers = rng.normal(size=(11, 7)) * np.arange(1, 12)[:, None]
    want = np.argmin(((x[:, None, :] - centers[None]) ** 2).sum(-1), axis=1)
    np.testing.assert_array_equal(nearest_centroid(x, centers), want)


@pytest.mark.slow
def test_tab2_fast_smoke(tmp_path):
    env = dict(os.environ,
               PYTHONPATH=os.path.join(_ROOT, "src"),
               REPRO_RESULTS=str(tmp_path))
    out = subprocess.run(
        [sys.executable, "-m", "benchmarks.run", "--fast",
         "--only", "tab2_rcv1"],
        cwd=_ROOT, env=env, capture_output=True, text=True, timeout=540)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-4000:]}"
    with open(tmp_path / "tab2_rcv1.json") as f:
        payload = json.load(f)
    assert {"baseline", "B", "sparse"} <= set(payload)
    assert payload["baseline"]["acc"] > 0.2          # not the broken formula
    assert payload["sparse"]["B"], "sparse sketch grid missing"
    for rec in payload["sparse"]["B"].values():
        assert 0.0 <= rec["acc"] <= 1.0 and rec["seconds"] > 0
    # the O(nnz) path clusters the sparse envelope at least as well as the
    # dense linear baseline (it sees the un-projected vocab space)
    assert payload["claim_sparse_beats_baseline_nmi"]
