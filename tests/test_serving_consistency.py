"""Prefill/decode consistency: decoding token-by-token from a prefix cache
must reproduce the full-sequence forward logits (the serving-correctness
contract for every family's KV/state cache)."""
import jax

from repro.distributed.compat import make_mesh
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_arch
from repro.models import Axes, get_model

AXES = Axes(dp=("data",), tp="model")
B, PREFIX, EXTRA = 2, 12, 4


def _mesh():
    return make_mesh((1, 1), ("data", "model"))


def _tokens(cfg, s, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(1, cfg.vocab_size, (B, s)), jnp.int32)


# decode caches are validated per family; listing archs keeps failures
# attributable (gemma2 additionally exercises the ring-buffer local cache).
CONSISTENCY_ARCHS = ["olmo-1b", "qwen3-32b", "gemma2-2b",
                     "qwen3-moe-235b-a22b", "rwkv6-7b", "zamba2-2.7b",
                     "seamless-m4t-medium"]


@pytest.mark.parametrize("arch", CONSISTENCY_ARCHS)
def test_decode_matches_prefill_extension(arch):
    """logits(prefill(t[:k+j])) == logits(prefill(t[:k]) + j decode steps)."""
    cfg = get_arch(arch, smoke=True)
    api = get_model(cfg, tp_size=1)
    # fp32 params keep the comparison numerically honest
    params, _ = api.init(jax.random.PRNGKey(0), jnp.float32)
    total = PREFIX + EXTRA
    tok = _tokens(cfg, total)

    with _mesh():
        if cfg.family == "encdec":
            frames = jnp.asarray(
                np.random.default_rng(1).normal(size=(B, 16, cfg.d_model)),
                jnp.float32)
            cache, logits = api.prefill(
                params, {"frames": frames, "tokens": tok[:, :PREFIX]}, AXES,
                max_len=total)
            for j in range(EXTRA):
                step_logits, cache = api.decode(
                    params, cache, tok[:, PREFIX + j],
                    jnp.asarray(PREFIX + j, jnp.int32), AXES)
            want_cache, want = api.prefill(
                params, {"frames": frames, "tokens": tok}, AXES,
                max_len=total)
        else:
            cache, logits = api.prefill(params, {"tokens": tok[:, :PREFIX]},
                                        AXES, max_len=total)
            for j in range(EXTRA):
                step_logits, cache = api.decode(
                    params, cache, tok[:, PREFIX + j],
                    jnp.asarray(PREFIX + j, jnp.int32), AXES)
            _, want = api.prefill(params, {"tokens": tok}, AXES,
                                  max_len=total)

    got = np.asarray(step_logits, np.float32)
    wantv = np.asarray(want, np.float32)
    # same next-token distribution (top-1 must agree; values close)
    np.testing.assert_allclose(got, wantv, rtol=2e-2, atol=2e-2)
    np.testing.assert_array_equal(got.argmax(-1), wantv.argmax(-1))


@pytest.mark.parametrize("arch", ["gemma2-2b"])
def test_local_window_ring_buffer_wraps(arch):
    """Decode far past the window: the ring buffer must keep only the last
    ``window`` positions and still match the full forward."""
    cfg = get_arch(arch, smoke=True)          # smoke window = 8
    api = get_model(cfg, tp_size=1)
    params, _ = api.init(jax.random.PRNGKey(0), jnp.float32)
    total = 24                                 # 3x the window
    tok = _tokens(cfg, total, seed=2)
    with _mesh():
        cache, _ = api.prefill(params, {"tokens": tok[:, :4]}, AXES,
                               max_len=total)
        for j in range(4, total):
            logits, cache = api.decode(params, cache, tok[:, j],
                                       jnp.asarray(j, jnp.int32), AXES)
        _, want = api.prefill(params, {"tokens": tok}, AXES, max_len=total)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(want),
                               rtol=3e-2, atol=3e-2)
