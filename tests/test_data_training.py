"""Data pipeline + optimizer/training-step unit tests."""
import jax

from repro.distributed.compat import make_mesh
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import TrainConfig
from repro.data.loader import PrefetchLoader
from repro.data.sampling import split_batches, stream_blocks
from repro.data.synthetic import (make_blobs, make_md_trajectory,
                                  make_mnist_like, make_noisy_replicas,
                                  make_rcv1_like, toy2d)
from repro.training.optim import adamw_init, adamw_update, lr_schedule

# ---------------------------------------------------------------------------
# synthetic generators
# ---------------------------------------------------------------------------


def test_toy2d_envelope():
    x, y = toy2d(n_per_cluster=100)
    assert x.shape == (400, 2) and y.shape == (400,)
    assert set(np.unique(y)) == {0, 1, 2, 3}


def test_mnist_like_envelope():
    x, y = make_mnist_like(n=2000)
    assert x.shape == (2000, 784)
    assert x.min() >= 0.0 and x.max() <= 1.0
    assert len(np.unique(y)) == 10


def test_rcv1_like_envelope():
    x, y = make_rcv1_like(n=3000, d=256, n_classes=20)
    assert x.shape == (3000, 256)
    assert len(np.unique(y)) == 20
    sizes = np.bincount(y)
    assert sizes.max() > 3 * sizes.min()        # heavy-tailed classes


def test_noisy_replicas_multiplies_dataset():
    x, y = make_blobs(100, 20, 4, seed=1)
    nx, ny = make_noisy_replicas(x, y, n_replicas=5)
    assert nx.shape == (500, 20) and ny.shape == (500,)
    # the noise touches ~20% of features, so replicas differ from originals
    assert not np.allclose(nx[:5], np.repeat(x[:1], 5, axis=0))


def test_md_trajectory_has_dwell_correlation():
    x, y = make_md_trajectory(n_frames=5000, n_atoms=8, n_states=5,
                              dwell=200.0, seed=0)
    assert x.shape == (5000, 24)
    # consecutive frames usually share a state (metastability)
    same = float(np.mean(y[1:] == y[:-1]))
    assert same > 0.9


# ---------------------------------------------------------------------------
# sampling / loader
# ---------------------------------------------------------------------------


def test_stride_vs_block_sampling_composition():
    x = np.arange(20, dtype=np.float32)[:, None]
    stride = split_batches(x, 4, "stride")
    block = split_batches(x, 4, "block")
    np.testing.assert_array_equal(stride[0][:, 0], [0, 4, 8, 12, 16])
    np.testing.assert_array_equal(block[0][:, 0], [0, 1, 2, 3, 4])
    for batches in (stride, block):
        allv = np.sort(np.concatenate([b[:, 0] for b in batches]))
        np.testing.assert_array_equal(allv, np.arange(20))


def test_stream_blocks_rechunks_exactly():
    chunks = [np.ones((3, 2)) * i for i in range(7)]      # 21 rows total
    batches = list(stream_blocks(iter(chunks), batch_size=5))
    assert [len(b) for b in batches] == [5, 5, 5, 5, 1]
    total = np.concatenate(batches)
    assert total.shape == (21, 2)


def test_prefetch_loader_preserves_order_and_values():
    batches = [np.full((4, 3), i, np.float32) for i in range(10)]
    out = list(PrefetchLoader(batches, depth=3))
    assert len(out) == 10
    for i, b in enumerate(out):
        np.testing.assert_array_equal(np.asarray(b), batches[i])


def test_prefetch_loader_propagates_errors():
    def gen():
        yield np.ones((2, 2))
        raise RuntimeError("disk died")

    loader = PrefetchLoader(gen(), depth=2)
    with pytest.raises(RuntimeError, match="disk died"):
        list(loader)


def test_stream_blocks_ragged_chunk_sizes():
    """Offset-carrying re-chunker must be exact over adversarially ragged
    chunks (regression for the buffer-reconcat rewrite): sizes straddle the
    batch boundary every way — sub-batch, exact, multi-batch, empty."""
    rng = np.random.default_rng(3)
    sizes = [1, 7, 0, 2, 23, 5, 0, 1, 1, 12, 4]
    chunks = [rng.normal(size=(s, 3)).astype(np.float32) for s in sizes]
    flat = np.concatenate(chunks, axis=0)                 # 56 rows
    for bs in (1, 4, 5, 56, 100):
        out = list(stream_blocks(iter(chunks), bs))
        lens = [len(b) for b in out]
        n = len(flat)
        assert lens == [bs] * (n // bs) + ([n % bs] if n % bs else [])
        np.testing.assert_array_equal(np.concatenate(out, axis=0), flat)


def test_stream_blocks_copies_out_of_reused_buffers():
    """Regression: a reader that reuses one read buffer must not have
    queued batches corrupted — chunks are owned on arrival, including when
    a batch spans several pulls (the buffered reference would otherwise see
    the NEXT read's bytes)."""
    buf = np.empty((4, 2), np.float32)

    def reader(n_chunks):
        for i in range(n_chunks):
            buf[:] = float(i + 1)
            yield buf

    out = list(stream_blocks(reader(3), 4))    # one batch per chunk
    for i, b in enumerate(out):
        assert not np.shares_memory(b, buf)
        np.testing.assert_array_equal(b, np.full((4, 2), i + 1, np.float32))

    out = list(stream_blocks(reader(4), 8))    # each batch spans two pulls
    want = np.repeat(np.arange(1.0, 5.0), 4).astype(np.float32)
    np.testing.assert_array_equal(np.concatenate(out)[:, 0], want)


def test_stream_blocks_csr_and_mixed_chunks():
    """CSR chunk streams stay CSR; a batch touched by both kinds is
    promoted to CSR (sparse data is never densified)."""
    from repro.data.sparse import csr_from_dense, is_sparse, slice_rows, to_dense

    rng = np.random.default_rng(4)
    x = (rng.random((20, 6)) * (rng.random((20, 6)) < 0.4)).astype(np.float32)
    b = csr_from_dense(x)

    csr_chunks = [slice_rows(b, i, j) for i, j in [(0, 3), (3, 11), (11, 20)]]
    out = list(stream_blocks(iter(csr_chunks), 7))
    assert all(is_sparse(c) for c in out)
    np.testing.assert_array_equal(
        np.concatenate([to_dense(c) for c in out]), x)

    mixed = [slice_rows(b, 0, 3), x[3:11], slice_rows(b, 11, 20)]
    out = list(stream_blocks(iter(mixed), 7))
    assert all(is_sparse(c) for c in out)      # promotion, not densification
    np.testing.assert_array_equal(
        np.concatenate([to_dense(c) for c in out]), x)


def test_prefetch_loader_close_releases_producer():
    """Regression: a consumer that breaks out early (elastic re-mesh,
    error) must be able to release the producer thread — it used to block
    forever on the full queue."""
    def endless():
        i = 0
        while True:
            yield np.full((2, 2), i, np.float32)
            i += 1

    loader = PrefetchLoader(endless(), depth=2)
    it = iter(loader)
    next(it)                          # consume one batch, then abandon
    assert loader._thread.is_alive()  # producer parked on the full queue
    loader.close()
    assert not loader._thread.is_alive()
    loader.close()                    # idempotent


def test_prefetch_loader_iteration_after_close_terminates():
    """Regression: next() on an iterator whose loader was closed must end
    the iteration once the queue drains, not block forever on get()."""
    def endless():
        while True:
            yield np.zeros((1, 1), np.float32)

    loader = PrefetchLoader(endless(), depth=2)
    it = iter(loader)
    next(it)
    loader.close()
    rest = list(it)                  # leftover staged items, then clean end
    assert len(rest) <= 2


def test_prefetch_loader_context_manager():
    def endless():
        while True:
            yield np.zeros((1, 1), np.float32)

    with PrefetchLoader(endless(), depth=1) as loader:
        next(iter(loader))
    assert not loader._thread.is_alive()


def test_prefetch_loader_coerces_array_likes():
    """Historical contract: list batches and off-dtype arrays come out as
    single float32 device arrays, not pytrees of scalars."""
    out = list(PrefetchLoader([[[1.0, 2.0], [3.0, 4.0]],
                               np.ones((2, 2), np.float64)], depth=2))
    for b in out:
        assert isinstance(b, jax.Array)
        assert b.shape == (2, 2) and b.dtype == jnp.float32


def test_prefetch_loader_stages_pytree_batches():
    """CSR batches flow through the loader as pytrees: leaves device_put,
    values bit-preserved."""
    from repro.data.sparse import CSRBatch, csr_from_dense, to_dense

    rng = np.random.default_rng(5)
    x = (rng.random((9, 5)) * (rng.random((9, 5)) < 0.5)).astype(np.float32)
    out = list(PrefetchLoader([csr_from_dense(x), x], depth=2))
    assert isinstance(out[0], CSRBatch)
    assert isinstance(out[0].data, jax.Array)
    np.testing.assert_array_equal(to_dense(out[0]), x)
    np.testing.assert_array_equal(np.asarray(out[1]), x)


def test_batch_source_skip_and_lifecycle():
    """BatchSource: from_dataset splits, skip() drops host-side (resume),
    from_stream re-chunks, close() releases the prefetch producer."""
    from repro.data.loader import BatchSource

    x = np.arange(40, dtype=np.float32).reshape(20, 2)
    parts = [len(b) for b in BatchSource.from_dataset(x, 4, "block")]
    assert parts == [5, 5, 5, 5]

    src = BatchSource.from_dataset(x, 4, "block").skip(2)
    got = np.concatenate(list(src))
    np.testing.assert_array_equal(got, x[10:])

    chunks = [x[:3], x[3:16], x[16:]]
    with BatchSource.from_stream(chunks, 6, prefetch=2) as src:
        first = next(iter(src))
        assert len(first) == 6
    assert src._loader is None or not src._loader._thread.is_alive()


def test_batch_source_reiteration_closes_previous_producer():
    """Regression: abandoning one iteration and starting another must not
    orphan the first producer thread (close() only knew the latest)."""
    from repro.data.loader import BatchSource

    def endless():
        while True:
            yield np.zeros((2, 2), np.float32)

    src = BatchSource(endless(), prefetch=2)
    next(iter(src))
    first_loader = src._loader
    assert first_loader._thread.is_alive()
    next(iter(src))                 # second iteration spawns a new producer
    assert not first_loader._thread.is_alive()   # previous one released
    second_loader = src._loader
    src.close()
    assert not second_loader._thread.is_alive()


# ---------------------------------------------------------------------------
# optimizer / schedule / grad accumulation
# ---------------------------------------------------------------------------


def test_adamw_converges_on_quadratic():
    tcfg = TrainConfig(learning_rate=0.1, warmup_steps=0, total_steps=200,
                       weight_decay=0.0, grad_clip=0.0)
    params = {"w": jnp.asarray([5.0, -3.0])}
    opt = adamw_init(params, tcfg)
    loss_fn = lambda p: jnp.sum(p["w"] ** 2)              # noqa: E731
    for _ in range(200):
        g = jax.grad(loss_fn)(params)
        params, opt, _ = adamw_update(params, g, opt, tcfg)
    assert float(loss_fn(params)) < 1e-3


def test_lr_schedule_warmup_and_decay():
    tcfg = TrainConfig(learning_rate=1e-3, warmup_steps=10, total_steps=100)
    lrs = [float(lr_schedule(jnp.asarray(s), tcfg)) for s in range(101)]
    assert lrs[0] == 0.0
    assert lrs[10] == pytest.approx(1e-3, rel=1e-5)       # warmup peak
    assert lrs[100] == pytest.approx(1e-4, rel=1e-2)      # 10% floor
    assert all(a >= b - 1e-12 for a, b in zip(lrs[10:], lrs[11:]))  # decay


def test_grad_clip_bounds_update():
    tcfg = TrainConfig(learning_rate=1.0, warmup_steps=0, grad_clip=1e-3,
                       weight_decay=0.0)
    params = {"w": jnp.zeros(4)}
    opt = adamw_init(params, tcfg)
    huge = {"w": jnp.full(4, 1e9)}
    _, _, metrics = adamw_update(params, huge, opt, tcfg)
    assert float(metrics["grad_norm"]) == pytest.approx(2e9, rel=1e-5)


def test_microbatch_accumulation_matches_full_batch():
    """grad-accum over 4 microbatches == single big batch (same loss/update,
    up to fp32 accumulation order)."""
    from repro.configs import get_arch
    from repro.models import Axes, get_model
    from repro.training.step import make_train_step

    cfg = get_arch("olmo-1b", smoke=True)
    api = get_model(cfg, tp_size=1)
    params, _ = api.init(jax.random.PRNGKey(0))
    axes = Axes(dp=("data",), tp="model")
    rng = np.random.default_rng(0)
    tok = jnp.asarray(rng.integers(1, cfg.vocab_size, (8, 16)), jnp.int32)
    batch = {"tokens": tok, "labels": jnp.roll(tok, -1, 1)}

    mesh = make_mesh((1, 1), ("data", "model"))
    with mesh:
        t1 = TrainConfig(remat=False, microbatches=1)
        t4 = TrainConfig(remat=False, microbatches=4)
        p1, _, m1 = jax.jit(make_train_step(api, t1, axes))(
            params, adamw_init(params, t1), batch)
        p4, _, m4 = jax.jit(make_train_step(api, t4, axes))(
            params, adamw_init(params, t4), batch)
    assert float(m1["loss"]) == pytest.approx(float(m4["loss"]), rel=2e-3)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32),
            rtol=2e-2, atol=2e-4)


def test_opt_state_bf16_mode():
    tcfg = TrainConfig(opt_state_dtype="bfloat16")
    params = {"w": jnp.zeros((8,), jnp.bfloat16)}
    opt = adamw_init(params, tcfg)
    assert opt.m["w"].dtype == jnp.bfloat16
    g = {"w": jnp.ones((8,), jnp.bfloat16)}
    p2, opt2, _ = adamw_update(params, g, opt, tcfg)
    assert opt2.v["w"].dtype == jnp.bfloat16
    assert bool(jnp.all(jnp.isfinite(p2["w"].astype(jnp.float32))))
