"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests must see exactly
one device; multi-device tests spawn subprocesses (see tests/_subproc.py)."""
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def four_blobs(n_per: int = 200, sigma: float = 0.05, seed: int = 0):
    """Well-separated 4-cluster 2D dataset (shuffled) + labels."""
    rng = np.random.default_rng(seed)
    centers = np.array([[0.25, 0.25], [0.75, 0.75],
                        [0.25, 0.75], [0.75, 0.25]])
    x = np.concatenate([rng.normal(c, sigma, size=(n_per, 2))
                        for c in centers]).astype(np.float32)
    y = np.repeat(np.arange(4), n_per).astype(np.int32)
    perm = rng.permutation(len(x))
    return x[perm], y[perm]


@pytest.fixture(scope="session")
def blobs():
    return four_blobs()
