"""Tests for the landmark-selection subsystem (repro.approx.selectors).

Covers: restart determinism (same key => same landmarks), streaming folds
over a ``BatchSource`` bit-identical to the offline sample under any
re-chunking, ``SelectorState`` checkpoint round-trip + mid-stream resume
through ``repro.ft.checkpoint``, RLS actually covering starved clusters,
the consolidated ``num_landmarks`` feasibility errors, selector dispatch
through the exact path and the config validation, and the mesh-native
psum RLS selection matching the single-host selector (subprocess, 8
forced host devices — same pattern as test_distributed.py).
"""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.approx import make_feature_map, selectors
from repro.core import (KernelSpec, MiniBatchConfig, nmi, num_landmarks,
                        select_landmark_indices)
from repro.core.minibatch import fit_dataset
from repro.data.loader import BatchSource
from repro.ft.checkpoint import CheckpointManager

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_SPEC = KernelSpec("rbf", gamma=0.4)


def _data(n=400, d=6, seed=0):
    rng = np.random.default_rng(seed)
    return rng.normal(size=(n, d)).astype(np.float32)


# ---------------------------------------------------------------------------
# determinism + streaming/offline equivalence
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", selectors.NAMES)
def test_same_key_same_landmarks_across_restarts(name):
    """Selection is a pure function of (key, data): re-running — as a
    restarted process would — draws identical landmarks; a different key
    draws different ones."""
    x = _data()
    sel = selectors.resolve(name)
    a = np.asarray(sel.select_indices(jax.random.PRNGKey(3), x, 24, _SPEC))
    b = np.asarray(sel.select_indices(jax.random.PRNGKey(3), x, 24, _SPEC))
    np.testing.assert_array_equal(a, b)
    assert len(np.unique(a)) == 24
    assert (np.sort(a) == a).all()          # sorted: DMA-friendly gathers
    c = np.asarray(sel.select_indices(jax.random.PRNGKey(4), x, 24, _SPEC))
    assert not (a == c).all()


@pytest.mark.parametrize("name", selectors.NAMES)
@pytest.mark.parametrize("n_chunks", [1, 3, 7])
def test_streaming_matches_offline_bitwise(name, n_chunks):
    """Folding a BatchSource — under ANY re-chunking — selects landmarks
    bit-identical to the offline sample (per-gid fold_in keys)."""
    x = _data()
    key = jax.random.PRNGKey(11)
    sel = selectors.resolve(name)
    offline = np.asarray(sel.select(key, x, 20, _SPEC))
    src = BatchSource(np.array_split(x, n_chunks))
    streamed, state = selectors.select_streaming(name, key, src, 20, _SPEC)
    np.testing.assert_array_equal(np.asarray(streamed), offline)
    assert int(state.rows_seen) == len(x)
    assert int(state.folds) == n_chunks


def test_streaming_pool_caps_memory_and_stays_boundary_invariant():
    x = _data(n=300)
    key = jax.random.PRNGKey(5)
    sel = selectors.RLSSelector(pool=128)
    lm3, st3 = selectors.select_streaming(sel, key, np.array_split(x, 3),
                                          16, _SPEC)
    lm5, st5 = selectors.select_streaming(sel, key, np.array_split(x, 5),
                                          16, _SPEC)
    assert st3.rows.shape[0] == 128          # capped
    np.testing.assert_array_equal(np.asarray(lm3), np.asarray(lm5))


def test_selector_state_checkpoint_roundtrip_and_resume(tmp_path):
    """SelectorState is a checkpointable pytree: fold half the stream,
    checkpoint via ft.checkpoint, 'crash', restore, fold the rest — the
    final landmarks are bit-identical to the uninterrupted fold AND to the
    offline sample (the elastic mid-stream resume guarantee)."""
    x = _data(n=360, d=5)
    key = jax.random.PRNGKey(9)
    batches = np.array_split(x, 6)
    sel = selectors.resolve("rls")
    ckpt = CheckpointManager(str(tmp_path), keep=10)

    def cb(state, i):
        ckpt.save(i, state, extra={"d": x.shape[1]})

    # straight run (also exercises checkpoint_cb on every fold)
    straight, _ = selectors.select_streaming("rls", key, batches, 18, _SPEC,
                                             checkpoint_cb=cb)
    # crash after fold 2 (steps 0..2 committed), restore, resume
    step = 2
    like = selectors.state_like(x.shape[1])
    restored = selectors.SelectorState(*ckpt.restore(step, like))
    assert int(restored.folds) == step + 1
    src = BatchSource(batches).skip(int(restored.folds))
    resumed, _ = selectors.select_streaming("rls", key, src, 18, _SPEC,
                                            state=restored)
    np.testing.assert_array_equal(np.asarray(resumed), np.asarray(straight))
    np.testing.assert_array_equal(np.asarray(resumed),
                                  np.asarray(sel.select(key, x, 18, _SPEC)))


def test_streaming_rejects_sparse_and_empty():
    from repro.data.sparse import csr_from_dense
    with pytest.raises(ValueError, match="dense"):
        selectors.resolve("rls").fold(
            selectors.resolve("rls").init(jax.random.PRNGKey(0), 4),
            csr_from_dense(_data(8, 4)))
    with pytest.raises(ValueError, match="empty"):
        selectors.select_streaming("uniform", jax.random.PRNGKey(0), [],
                                   4, _SPEC)


# ---------------------------------------------------------------------------
# selection quality: RLS covers what uniform starves
# ---------------------------------------------------------------------------


def test_rls_covers_starved_clusters_better_than_uniform():
    """One dominant cluster (97%) + three tiny ones: a uniform m-sample
    usually leaves tiny clusters without any landmark; high ridge leverage
    lives exactly there, so RLS must cover more of them."""
    rng = np.random.default_rng(2)
    centers = np.array([[0, 0], [8, 8], [-8, 8], [8, -8]], np.float32)
    sizes = [970, 10, 10, 10]
    x = np.concatenate([rng.normal(c, 0.3, size=(s, 2))
                        for c, s in zip(centers, sizes)]).astype(np.float32)
    y = np.repeat(np.arange(4), sizes)
    perm = rng.permutation(len(x))
    x, y = x[perm], y[perm]
    spec = KernelSpec("rbf", gamma=0.5)

    def tiny_covered(name, key):
        idx = np.asarray(selectors.resolve(name).select_indices(
            key, jnp.asarray(x), 8, spec))
        return len(set(y[idx]) - {0})      # distinct tiny clusters hit

    keys = [jax.random.PRNGKey(k) for k in range(8)]
    unif = sum(tiny_covered("uniform", k) for k in keys)
    rls = sum(tiny_covered("rls", k) for k in keys)
    assert rls > unif, (rls, unif)
    assert rls >= 8 * 3 - 4                # RLS nearly always covers all 3


# ---------------------------------------------------------------------------
# dispatch: exact path, config validation, make_feature_map gating
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", ["rls", "kpp"])
def test_exact_path_selector_dispatch(name, blobs):
    x, y = blobs
    cfg = MiniBatchConfig(n_clusters=4, n_batches=4, s=0.4,
                          kernel=KernelSpec("rbf", gamma=8.0), seed=0,
                          selector=name)
    res = fit_dataset(x, cfg)
    assert nmi(y, np.asarray(res.predict(x))) >= 0.9
    # resumed == uninterrupted (pure per-batch fold_in schedule)
    from repro.data.sampling import split_batches
    from repro.core.minibatch import fit
    batches = split_batches(x, 4, strategy="stride")
    half = fit(batches[:2], cfg)
    resumed = fit(batches[2:], cfg, state=half.state)
    np.testing.assert_array_equal(np.asarray(resumed.state.medoids),
                                  np.asarray(res.state.medoids))


def test_select_landmark_indices_uniform_is_choose_landmarks():
    from repro.core import choose_landmarks
    x = _data(100, 3)
    key = jax.random.PRNGKey(1)
    np.testing.assert_array_equal(
        np.asarray(select_landmark_indices(key, jnp.asarray(x), 16, _SPEC)),
        np.asarray(choose_landmarks(key, 100, 16)))


def test_config_rejects_selector_on_data_oblivious_methods():
    with pytest.raises(ValueError, match="selector"):
        MiniBatchConfig(n_clusters=4, method="rff", selector="rls")
    with pytest.raises(ValueError, match="selector"):
        MiniBatchConfig(n_clusters=4, method="sketch", selector="kpp")
    with pytest.raises(ValueError, match="unknown landmark selector"):
        MiniBatchConfig(n_clusters=4, selector="bogus")
    # landmark-based methods accept any selector (incl. instances)
    MiniBatchConfig(n_clusters=4, selector="rls")
    MiniBatchConfig(n_clusters=4, method="nystrom",
                    selector=selectors.RLSSelector(delta=1e-3))
    with pytest.raises(ValueError, match="selector"):
        make_feature_map("rff", jax.random.PRNGKey(0), _data(16, 4), 8,
                         KernelSpec("rbf"), selector="rls")


def test_num_landmarks_consolidated_feasibility_errors():
    # C > batch: no silent min() clamp below C any more
    with pytest.raises(ValueError, match="infeasible"):
        num_landmarks(8, 1.0, n_clusters=16)
    # no multiple of `multiple_of` in [C, batch]
    with pytest.raises(ValueError, match="infeasible"):
        num_landmarks(10, 0.5, n_clusters=5, multiple_of=16)
    # feasible combinations keep the documented bounds
    assert num_landmarks(100, 0.3, n_clusters=4) == 30
    assert num_landmarks(100, 0.3, n_clusters=4, multiple_of=8) == 32
    assert num_landmarks(100, 1.0, n_clusters=4, multiple_of=8) == 96
    assert num_landmarks(4, 0.1, n_clusters=4) == 4


# ---------------------------------------------------------------------------
# planner: selector cost + frontier
# ---------------------------------------------------------------------------


def test_plan_selector_term_and_frontier():
    from repro.core import MachineSpec, plan, selector_footprint_bytes
    machine = MachineSpec(memory_bytes=16e9, n_processors=64)
    p = plan(2_000_000, 50, machine, d=256, selector="rls", sketchable=True,
             density=0.01)
    assert p.selector == "rls"
    assert p.selector_footprint > 0
    assert p.selector_footprint > selector_footprint_bytes(
        2_000_000, p.b, 64, m=p.embed_dim, selector="uniform")
    front = p.frontier()
    names = [f"{r['method']}:{r['selector']}" for r in front]
    assert "nystrom:rls" in names and "nystrom:uniform" in names
    assert "sketch:-" in names
    # the frontier's whole claim: leverage selection buys more accuracy
    # from the same byte budget than uniform sampling
    assert names.index("nystrom:rls") < names.index("nystrom:uniform")
    for r in front:
        assert r["bytes"] <= p.embed_footprint + p.selector_footprint + 1
        assert 0.0 <= r["predicted_accuracy"] <= 1.0
    # explicit budget: more bytes -> at least as much predicted accuracy
    small = p.frontier(budget_bytes=front[0]["bytes"] / 4)
    if small:
        assert small[0]["predicted_accuracy"] <= front[0]["predicted_accuracy"]
    with pytest.raises(ValueError, match="unknown selector"):
        plan(2_000_000, 50, machine, d=256, selector="bogus")


# ---------------------------------------------------------------------------
# distributed: mesh-native psum RLS == single-host selector
# ---------------------------------------------------------------------------


def _run_subprocess(body: str) -> dict:
    script = textwrap.dedent("""\
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import json
        import numpy as np
        import jax
        import jax.numpy as jnp
    """) + textwrap.dedent(body)
    env = dict(os.environ, PYTHONPATH=os.path.join(_ROOT, "src"))
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=540)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-4000:]}"
    return json.loads(out.stdout.strip().splitlines()[-1])


@pytest.mark.slow
def test_distributed_rls_selection_matches_single_host():
    """The mesh-native RLS path (per-device partial leverage sketches, one
    psum, ghost rows masked) must select the same landmarks as the
    single-host selector and produce the same labels — including a
    non-divisible batch (pad > 0)."""
    res = _run_subprocess("""
        from repro.core import KernelSpec, MiniBatchConfig
        from repro.core.minibatch import fit as host_fit
        from repro.core.metrics import nmi
        from repro.distributed.embed import DistributedEmbedKMeans

        rng = np.random.default_rng(0)
        centers = np.array([[0.25,0.25],[0.75,0.75],[0.25,0.75],[0.75,0.25]])
        X = np.concatenate([rng.normal(c, 0.05, size=(515, 2))
                            for c in centers]).astype(np.float32)
        y = np.repeat(np.arange(4), 515)
        perm = rng.permutation(len(X)); X, y = X[perm], y[perm]
        batches = [X[i::4] for i in range(4)]      # 515 rows: pad = 5 on 8

        mesh = jax.make_mesh((8,), ("data",))
        cfg = MiniBatchConfig(n_clusters=4, n_batches=4, seed=0,
                              kernel=KernelSpec("rbf", gamma=8.0),
                              method="nystrom", embed_dim=24,
                              selector="rls")
        km = DistributedEmbedKMeans(mesh, cfg)
        with km.source(batches, depth=2) as src:
            dist = km.fit(src)
        host = host_fit(batches, cfg)
        lm_same = bool((np.asarray(dist.fmap.landmarks)
                        == np.asarray(host.fmap.landmarks)).all())
        labels = np.asarray(dist.predict(jnp.asarray(X)))
        label_same = bool(
            (labels == np.asarray(host.predict(jnp.asarray(X)))).all())
        print(json.dumps({
            "lm_same": lm_same, "label_same": label_same,
            "nmi": nmi(y, labels),
            "total": float(np.asarray(dist.state.cardinalities).sum()),
            "n": len(X)}))
    """)
    assert res["lm_same"], "mesh RLS selected different landmarks"
    assert res["label_same"]
    assert res["nmi"] >= 0.9
    assert res["total"] == res["n"]      # ghost rows masked out
