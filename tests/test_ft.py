"""Fault tolerance: checkpoint atomicity, restart equality, elastic
re-shard, straggler replanning."""
import os
import subprocess
import sys
import textwrap

import jax

from repro.distributed.compat import make_mesh
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import KernelSpec, MiniBatchConfig
from repro.core.minibatch import GlobalState, fit
from repro.data.sampling import split_batches
from repro.ft.checkpoint import CheckpointManager

from conftest import four_blobs

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_checkpoint_roundtrip_and_gc(tmp_path):
    cm = CheckpointManager(str(tmp_path), keep=2)
    tree = {"w": jnp.arange(12.0).reshape(3, 4),
            "opt": {"m": jnp.ones((5,)), "step": jnp.asarray(7)}}
    for s in (1, 2, 3, 4):
        cm.save(s, tree, extra={"batch": s})
    assert cm.all_steps() == [3, 4]                      # keep=2 GC
    like = jax.tree.map(lambda a: np.zeros(a.shape, a.dtype), tree)
    got = cm.restore(4, like)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert cm.extra(4) == {"batch": 4}


def test_checkpoint_atomic_no_partial_visible(tmp_path):
    """A crash mid-save (simulated: orphan .tmp dir) must stay invisible."""
    cm = CheckpointManager(str(tmp_path))
    cm.save(1, {"a": jnp.ones(3)})
    os.makedirs(os.path.join(str(tmp_path), "step_000000002.tmp"))
    assert cm.all_steps() == [1]
    assert cm.latest_step() == 1
    cm.save(2, {"a": jnp.ones(3)})                       # tmp dir reclaimed
    assert cm.latest_step() == 2


def test_restart_resumes_equal(tmp_path):
    """fit(4 batches) == fit(2 batches) -> restore -> fit(remaining 2).
    The mini-batch boundary is the paper's natural restart domain."""
    x, _ = four_blobs(n_per=256, seed=7)
    cfg = MiniBatchConfig(n_clusters=4, n_batches=4, s=1.0,
                          kernel=KernelSpec("rbf", gamma=8.0), seed=3)
    batches = split_batches(x, 4, strategy="stride")

    straight = fit(batches, cfg)

    cm = CheckpointManager(str(tmp_path))
    cb = lambda s, i: cm.save(i, s)                      # noqa: E731
    fit(batches[:2], cfg, checkpoint_cb=cb)              # "crash" after 2
    step = cm.latest_step()
    assert step == 1                                     # batches 0,1 done
    like = GlobalState(
        medoids=np.zeros((4, 2), np.float32),
        medoid_diag=np.zeros((4,), np.float32),
        cardinalities=np.zeros((4,), np.float32),
        batches_done=np.zeros((), np.int32))
    state = GlobalState(*cm.restore(step, like))
    assert int(state.batches_done) == 2
    resumed = fit(batches[2:], cfg, state=state)

    np.testing.assert_allclose(np.asarray(straight.state.medoids),
                               np.asarray(resumed.state.medoids))
    np.testing.assert_allclose(np.asarray(straight.state.cardinalities),
                               np.asarray(resumed.state.cardinalities))


def test_restart_bit_identical_key_schedule():
    """Resumed exact-path fits must be BIT-identical to uninterrupted ones.

    Regression for the stateful ``key, sub = split(fold_in(key, i))``
    schedule: the reassignment made batch i's key depend on how many batches
    this process had already run, so a resumed run (i starting at
    batches_done) drew different landmarks than the uninterrupted run. On
    separable data both still converge to the same medoids — this test uses
    non-separable data, subsampled landmarks (s < 1) and a truncated inner
    loop so any key divergence shows up in the medoids.
    """
    rng = np.random.default_rng(11)
    x = rng.normal(size=(800, 8)).astype(np.float32)
    cfg = MiniBatchConfig(n_clusters=6, n_batches=4, s=0.4,
                          kernel=KernelSpec("rbf", gamma=0.5),
                          max_inner_iters=3, seed=5)
    batches = split_batches(x, 4, strategy="stride")

    straight = fit(batches, cfg)
    half = fit(batches[:2], cfg)
    resumed = fit(batches[2:], cfg, state=half.state)

    np.testing.assert_array_equal(np.asarray(straight.state.medoids),
                                  np.asarray(resumed.state.medoids))
    np.testing.assert_array_equal(
        np.asarray(straight.state.cardinalities),
        np.asarray(resumed.state.cardinalities))


@pytest.mark.slow
def test_elastic_reshard_across_meshes():
    """Run 2 batches on a (4,2) mesh, fail, resume the remaining 2 on a
    (2,2) mesh (elastic shrink: 8 -> 4 devices). Global state is
    mesh-independent so the result must match the uninterrupted run."""
    script = textwrap.dedent("""\
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import json, tempfile
        import numpy as np
        import jax
        from repro.core import KernelSpec, MiniBatchConfig
        from repro.data.sampling import split_batches
        from repro.ft.checkpoint import CheckpointManager
        from repro.ft.elastic import ElasticClusteringRunner, SimulatedFailure
        from repro.distributed.compat import make_mesh

        rng = np.random.default_rng(0)
        centers = np.array([[0.25,0.25],[0.75,0.75],[0.25,0.75],[0.75,0.25]])
        X = np.concatenate([rng.normal(c, 0.05, size=(512,2))
                            for c in centers]).astype(np.float32)
        perm = rng.permutation(len(X)); X = X[perm]
        batches = split_batches(X, 4, strategy="stride")
        cfg = MiniBatchConfig(n_clusters=4, n_batches=4, s=1.0,
                              kernel=KernelSpec("rbf", gamma=8.0), seed=0)

        with tempfile.TemporaryDirectory() as d:
            runner = ElasticClusteringRunner(cfg, CheckpointManager(d))
            mesh_big = make_mesh((4, 2), ("data", "model"))
            try:
                runner.run(mesh_big, batches, fail_after=2)
                raise SystemExit("expected SimulatedFailure")
            except SimulatedFailure:
                pass
            mesh_small = jax.make_mesh((2, 2), ("data", "model"))
            resumed = runner.run(mesh_small, batches)

        with tempfile.TemporaryDirectory() as d:
            runner2 = ElasticClusteringRunner(cfg, CheckpointManager(d))
            straight = runner2.run(jax.make_mesh((4, 2), ("data", "model")),
                                   batches)

        err = float(np.abs(np.asarray(resumed.state.medoids)
                           - np.asarray(straight.state.medoids)).max())
        print(json.dumps({"err": err,
                          "batches": int(resumed.state.batches_done)}))
    """)
    env = dict(os.environ, PYTHONPATH=os.path.join(_ROOT, "src"))
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=540)
    assert out.returncode == 0, out.stderr[-4000:]
    import json
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["batches"] == 4
    assert res["err"] < 1e-5, "elastic resume diverged from straight run"


def test_elastic_resume_rls_nystrom_bit_identical():
    """Embedded Nystrom fit with RLS-selected landmarks: fail after 2
    mini-batches, resume on a smaller mesh. The feature map (with its
    leverage-selected landmarks) is checkpointed next to the EmbedState and
    the selector name in the manifest, so the resumed stream must use
    bit-identical landmarks and land on bit-identical centroids."""
    script = textwrap.dedent("""\
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import json, tempfile
        import numpy as np
        import jax
        from repro.core import KernelSpec, MiniBatchConfig
        from repro.data.sampling import split_batches
        from repro.ft.checkpoint import CheckpointManager
        from repro.ft.elastic import ElasticClusteringRunner, SimulatedFailure
        from repro.distributed.compat import make_mesh

        rng = np.random.default_rng(0)
        centers = np.array([[0.25,0.25],[0.75,0.75],[0.25,0.75],[0.75,0.25]])
        X = np.concatenate([rng.normal(c, 0.05, size=(515,2))
                            for c in centers]).astype(np.float32)
        perm = rng.permutation(len(X)); X = X[perm]
        batches = split_batches(X, 4, strategy="stride")
        cfg = MiniBatchConfig(n_clusters=4, n_batches=4, seed=0,
                              kernel=KernelSpec("rbf", gamma=8.0),
                              method="nystrom", embed_dim=16,
                              selector="rls")

        with tempfile.TemporaryDirectory() as d:
            ckpt = CheckpointManager(d)
            runner = ElasticClusteringRunner(cfg, ckpt)
            mesh_big = make_mesh((8,), ("data",))
            try:
                runner.run(mesh_big, batches, fail_after=2)
                raise SystemExit("expected SimulatedFailure")
            except SimulatedFailure:
                pass
            extra = ckpt.extra(ckpt.latest_step())
            mesh_small = make_mesh((4,), ("data",))
            resumed = runner.run(mesh_small, batches)

        with tempfile.TemporaryDirectory() as d:
            straight = ElasticClusteringRunner(cfg, CheckpointManager(d)).run(
                make_mesh((8,), ("data",)), batches)

        cent_err = float(np.abs(np.asarray(resumed.state.centroids)
                                - np.asarray(straight.state.centroids)).max())
        lm_same = bool((np.asarray(resumed.fmap.landmarks)
                        == np.asarray(straight.fmap.landmarks)).all())
        print(json.dumps({"cent_err": cent_err, "lm_same": lm_same,
                          "selector": extra.get("selector"),
                          "batches": int(resumed.state.batches_done)}))
    """)
    env = dict(os.environ, PYTHONPATH=os.path.join(_ROOT, "src"))
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=540)
    assert out.returncode == 0, out.stderr[-4000:]
    import json
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["batches"] == 4
    assert res["selector"] == "rls"         # manifest records the strategy
    assert res["lm_same"], "resumed fit re-selected different landmarks"
    # psum partials regroup on the smaller mesh: allclose, not bitwise
    assert res["cent_err"] < 1e-5, "elastic rls resume diverged"


def test_training_checkpoint_restore_exact(tmp_path):
    """Full train-state checkpoint: params + AdamW state roundtrip, then one
    more step gives identical metrics to an uninterrupted run."""
    from repro.configs import TrainConfig, get_arch
    from repro.models import Axes, get_model
    from repro.training.optim import adamw_init
    from repro.training.step import make_train_step

    cfg = get_arch("olmo-1b", smoke=True)
    api = get_model(cfg, tp_size=1)
    params, _ = api.init(jax.random.PRNGKey(0))
    tcfg = TrainConfig(remat=False)
    opt = adamw_init(params, tcfg)
    axes = Axes(dp=("data",), tp="model")
    step = jax.jit(make_train_step(api, tcfg, axes))
    rng = np.random.default_rng(0)
    tok = jnp.asarray(rng.integers(1, cfg.vocab_size, (2, 32)), jnp.int32)
    batch = {"tokens": tok, "labels": jnp.roll(tok, -1, 1)}

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with mesh:
        p1, o1, _ = step(params, opt, batch)
        cm = CheckpointManager(str(tmp_path))
        cm.save(1, {"params": p1, "opt": o1})
        like = jax.tree.map(lambda a: np.zeros(a.shape, a.dtype),
                            {"params": p1, "opt": o1})
        got = cm.restore(1, like)
        p2a, o2a, m_a = step(p1, o1, batch)
        p2b, o2b, m_b = step(got["params"],
                             jax.tree.unflatten(
                                 jax.tree.structure(o1),
                                 jax.tree.leaves(got["opt"])), batch)
    assert float(m_a["loss"]) == pytest.approx(float(m_b["loss"]), abs=1e-6)
    for a, b in zip(jax.tree.leaves(p2a), jax.tree.leaves(p2b)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
