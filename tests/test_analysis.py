"""repro.analysis: the auditor's booby-trap suite + lint round-trips.

Every static check must FIRE on an intentionally-bad program (a hidden
psum, a materialized Gram block in tiled mode, a jnp-only "fused" step, a
host callback in a loop, a reused key) and stay silent on the shipped hot
paths — the contract tests at the bottom pin the audited invariants
across engine modes and mesh axes.
"""
import json
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import (AuditError, ProgramReport, audit,
                            collective_bill)
from repro.analysis.lint import (Finding, apply_waivers, lint_paths,
                                 load_waivers)

jax.config.update("jax_platform_name", "cpu")


# ---------------------------------------------------------------------------
# auditor mechanics


def test_audit_counts_primitives_and_bytes():
    def f(a, b):
        return jnp.dot(a, b) + 1.0

    a = jnp.ones((8, 4), jnp.float32)
    b = jnp.ones((4, 2), jnp.float32)
    r = audit(f, a, b)
    assert r.primitive_counts.get("dot_general", 0) == 1
    assert r.input_bytes == (32 + 8) * 4
    assert r.output_bytes == 16 * 4
    assert r.pallas_calls == 0
    assert not r.loops


def test_audit_liveness_peak_vs_sum():
    """A big intermediate that dies early must not stack with a later one:
    peak < total allocated."""
    def f(x):
        big = jnp.outer(x, x)            # [n, n], dies after the sum
        s = jnp.sum(big)
        big2 = jnp.outer(x, x) * 2.0     # second [n, n]
        return s + jnp.sum(big2)

    x = jnp.ones((64,), jnp.float32)
    r = audit(f, x)
    one_block = 64 * 64 * 4
    assert r.largest_intermediate_bytes == one_block
    # liveness: the two [n, n] blocks never coexist.
    assert r.peak_live_bytes < 2 * one_block + r.input_bytes


def test_audit_scan_multiplier():
    """Collectives inside a scan body are multiplied by the static trip
    count — the hidden-psum-in-a-scan booby-trap."""
    from repro.distributed.compat import make_mesh, shard_map
    from jax.sharding import PartitionSpec as P

    mesh = make_mesh((1,), ("data",))
    length = 7

    def body(x):
        def step(c, xi):
            return c + jax.lax.psum(xi, "data"), None
        out, _ = jax.lax.scan(step, 0.0, x)
        return out

    f = shard_map(body, mesh=mesh, in_specs=P("data"), out_specs=P(),
                  check_vma=False)
    r = audit(f, jnp.ones((length,), jnp.float32))
    # scan is static: the psum count is exact, and it is NOT a while loop,
    # so it lands in the outside (unconditional) bill.
    assert r.collectives_outside.get("psum") == length
    assert not r.loops
    # a bill that promised zero psums must be rejected
    violations = r.check_collectives({}, {"psum": 0})
    assert violations and "psum" in violations[0]


def test_audit_hidden_psum_in_while_body():
    """A while body smuggling an extra psum breaks the per-iteration bill."""
    from repro.distributed.compat import make_mesh, shard_map
    from jax.sharding import PartitionSpec as P

    mesh = make_mesh((1,), ("data",))

    def body(x):
        def cond(c):
            i, _ = c
            return i < 3

        def step(c):
            i, a = c
            a = jax.lax.psum(a, "data")          # billed
            a = a + jax.lax.psum(a * 2, "data")  # smuggled
            return i + 1, a

        return jax.lax.while_loop(cond, step, (0, jnp.sum(x)))[1]

    f = shard_map(body, mesh=mesh, in_specs=P("data"), out_specs=P(),
                  check_vma=False)
    r = audit(f, jnp.ones((4,), jnp.float32))
    assert len(r.loops) == 1
    assert r.collectives_per_iteration == {"psum": 2}
    violations = r.check_collectives({"psum": 1})
    assert violations, "the smuggled psum must be caught"
    with pytest.raises(AuditError):
        r.verify(violations)


def test_audit_unbilled_collective_kind():
    """A collective kind the analytic bill has no entry for is flagged."""
    from repro.distributed.compat import make_mesh, shard_map
    from jax.sharding import PartitionSpec as P

    mesh = make_mesh((1,), ("data",))

    def body(x):
        def cond(c):
            return c[0] < 2

        def step(c):
            i, a = c
            g = jax.lax.all_gather(a, "data")
            return i + 1, jnp.sum(g)

        return jax.lax.while_loop(cond, step, (0, jnp.sum(x)))[1]

    f = shard_map(body, mesh=mesh, in_specs=P("data"), out_specs=P(),
                  check_vma=False)
    r = audit(f, jnp.ones((4,), jnp.float32))
    violations = r.check_collectives({"psum": 0})
    assert any("unbilled" in v and "all_gather" in v for v in violations)


def test_audit_oversized_intermediate_fires():
    """The tiled residency booby-trap: materializing the full [n, L] Gram
    block is a static failure, no runtime spy needed."""
    n, L = 128, 64

    def bad_tiled_step(x, lm):
        k = jnp.exp(-jnp.sum((x[:, None, :] - lm[None, :, :]) ** 2, -1))
        return jnp.sum(k, axis=1)        # full [n, L] materialized

    x = jnp.ones((n, 4), jnp.float32)
    lm = jnp.ones((L, 4), jnp.float32)
    r = audit(bad_tiled_step, x, lm)
    assert r.largest_intermediate_bytes >= n * L * 4
    violations = r.check_max_intermediate(n * L * 4)
    assert violations
    with pytest.raises(AuditError):
        r.verify(violations)


def test_audit_jnp_only_fused_step_fires():
    """The PR 5 dead-kernel bug: a 'fused' step that never dispatches a
    pallas_call is rejected before anything runs."""
    def fake_fused(x, lm, h):
        return jnp.exp(-((x @ lm.T) ** 2)) @ h   # pure jnp, no kernel

    x = jnp.ones((32, 4), jnp.float32)
    lm = jnp.ones((16, 4), jnp.float32)
    h = jnp.ones((16, 3), jnp.float32)
    r = audit(fake_fused, x, lm, h)
    assert r.pallas_calls == 0
    assert r.check_pallas(expected=True)

    # and the converse: a real Pallas dispatch where none was promised
    from repro.kernels import ops as kops
    r2 = audit(lambda *a: kops.gram_matvec(*a, kind="rbf", gamma=1.0,
                                           interpret=True), x, lm, h)
    assert r2.pallas_calls >= 1
    assert r2.check_pallas(expected=False)
    assert not r2.check_pallas(expected=True)


def test_audit_host_callback_in_loop_fires():
    def bad(x):
        def cond(c):
            return c[0] < 3

        def step(c):
            i, a = c
            a = a + jax.pure_callback(
                lambda v: np.asarray(v, np.float32),
                jax.ShapeDtypeStruct((), jnp.float32), jnp.sum(a))
            return i + 1, a

        return jax.lax.while_loop(cond, step, (0, x))[1]

    r = audit(bad, jnp.ones((4,), jnp.float32))
    assert r.host_callbacks_in_loop.get("pure_callback") == 1
    assert r.check_host_sync()
    # same callback outside any loop: recorded but not a violation
    r2 = audit(lambda x: jax.pure_callback(
        lambda v: np.asarray(v, np.float32),
        jax.ShapeDtypeStruct((), jnp.float32), jnp.sum(x)),
        jnp.ones((4,), jnp.float32))
    assert r2.host_callbacks.get("pure_callback") == 1
    assert not r2.check_host_sync()


def test_collective_bill_shape():
    from repro.distributed.compat import make_mesh, shard_map
    from jax.sharding import PartitionSpec as P

    mesh = make_mesh((1,), ("data",))

    def body(x):
        def cond(c):
            return c[0] < 2

        def step(c):
            i, a = c
            return i + 1, jax.lax.psum(a, "data")

        out = jax.lax.while_loop(cond, step, (0, jnp.sum(x)))[1]
        return jax.lax.psum(out, "data")     # epilogue

    f = shard_map(body, mesh=mesh, in_specs=P("data"), out_specs=P(),
                  check_vma=False)
    bill = collective_bill(f, jnp.ones((4,), jnp.float32))
    assert bill["per_iteration"] == {"psum": 1}
    assert bill["outside"] == {"psum": 1}
    assert bill["per_iteration_bytes"]["psum"] == 4
    assert bill["outside_bytes"]["psum"] == 4


def test_report_totals_and_json_round_trip():
    r = ProgramReport(name="p")
    r.loops.append(
        __import__("repro.analysis", fromlist=["LoopReport"]).LoopReport(
            path="while", collectives={"psum": 3, "all_gather": 1}))
    r.collectives_outside = {"psum": 2}
    assert r.collective_totals(10) == {"psum": 32, "all_gather": 10}
    d = json.loads(json.dumps(r.to_dict()))
    assert d["collectives_per_iteration"] == {"psum": 3, "all_gather": 1}


# ---------------------------------------------------------------------------
# lint: each rule fires on a fixture, waivers round-trip


def _lint_src(tmp_path, source, fname="mod.py"):
    p = tmp_path / fname
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(textwrap.dedent(source))
    return lint_paths([str(tmp_path)])


def test_lint_rk001_key_reuse(tmp_path):
    findings = _lint_src(tmp_path, """
        import jax

        def sampler(key):
            a = jax.random.normal(key, (3,))
            b = jax.random.uniform(key, (3,))    # reuse!
            return a + b
    """)
    assert [f.rule for f in findings] == ["RK001"]
    assert "key `key`" in findings[0].message

    clean = _lint_src(tmp_path, """
        import jax

        def sampler(key):
            k1, k2 = jax.random.split(key)
            a = jax.random.normal(k1, (3,))
            b = jax.random.uniform(k2, (3,))
            return a + b

        def folded(key, i):
            a = jax.random.normal(jax.random.fold_in(key, 0), (3,))
            b = jax.random.normal(jax.random.fold_in(key, 1), (3,))
            return a + b
    """, fname="clean.py")
    assert not [f for f in clean if f.rule == "RK001"
                and f.path.endswith("clean.py")]


def test_lint_rk002_tracer_leaks(tmp_path):
    findings = _lint_src(tmp_path, """
        import jax
        import numpy as np
        from functools import partial

        @jax.jit
        def leaky(x):
            return float(x.sum())

        @partial(jax.jit, static_argnames=("n",))
        def fine(x, *, n):
            import math
            return x * int(math.log(n))     # n is static: trace-time int

        @jax.jit
        def leaky2(x):
            return np.asarray(x) + x.item()
    """)
    rk2 = [f for f in findings if f.rule == "RK002"]
    assert {f.symbol for f in rk2} == {"leaky", "leaky2"}
    assert len([f for f in rk2 if f.symbol == "leaky2"]) == 2


def test_lint_rk003_dead_kernel(tmp_path):
    (tmp_path / "kernels").mkdir()
    (tmp_path / "kernels" / "dead.py").write_text(textwrap.dedent("""
        from jax.experimental import pallas as pl

        def _kernel(x_ref, o_ref):
            o_ref[...] = x_ref[...]

        def dead_pallas(x):
            return pl.pallas_call(_kernel, out_shape=x)(x)
    """))
    (tmp_path / "kernels" / "live.py").write_text(textwrap.dedent("""
        from jax.experimental import pallas as pl

        def live_pallas(x):
            return pl.pallas_call(lambda i, o: None, out_shape=x)(x)
    """))
    (tmp_path / "ops.py").write_text(
        "from kernels.live import live_pallas\n")
    findings = lint_paths([str(tmp_path)])
    rk3 = [f for f in findings if f.rule == "RK003"]
    assert [f.symbol for f in rk3] == ["dead_pallas"]


def test_lint_rk004_unhashable_static(tmp_path):
    findings = _lint_src(tmp_path, """
        import jax
        from functools import partial

        @partial(jax.jit, static_argnames=("shape",))
        def bad(x, *, shape=[1, 2]):
            return x.reshape(shape)

        @partial(jax.jit, static_argnums=(1,))
        def bad2(x, opts={}):
            return x

        @partial(jax.jit, static_argnames=("shape",))
        def good(x, *, shape=(1, 2)):
            return x.reshape(shape)
    """)
    rk4 = [f for f in findings if f.rule == "RK004"]
    assert {f.symbol for f in rk4} == {"bad", "bad2"}


def test_waiver_round_trip(tmp_path):
    f1 = Finding("RK003", "src/kernels/dead.py", 7, "dead_pallas", "dead")
    f2 = Finding("RK001", "src/x.py", 3, "g", "reuse")
    wpath = tmp_path / "waivers.json"
    wpath.write_text(json.dumps([
        {"rule": "RK003", "path": "kernels/dead.py",
         "symbol": "dead_pallas", "reason": "staged for PR 8 dispatch"},
        {"rule": "RK002", "path": "never/hit.py", "reason": "stale"},
    ]))
    waivers = load_waivers(str(wpath))
    active, waived, unused = apply_waivers([f1, f2], waivers)
    assert [f.rule for f in active] == ["RK001"]
    assert [f.rule for f in waived] == ["RK003"]
    assert [w.rule for w in unused] == ["RK002"]

    # a waiver without a reason is rejected outright
    wpath.write_text(json.dumps([{"rule": "RK001", "path": "x.py"}]))
    with pytest.raises(ValueError, match="reason"):
        load_waivers(str(wpath))


def test_lint_cli_green_on_shipped_tree():
    """The gate the CI job enforces: python -m repro.analysis exits 0."""
    from repro.analysis.lint import main
    assert main([]) == 0


# ---------------------------------------------------------------------------
# contract tests: the shipped hot paths, engine x mesh


@pytest.mark.parametrize("mode", ["materialize", "fused", "tiled"])
def test_contract_engine_modes(mode):
    from repro.launch.audit import audit_engine_modes

    results = audit_engine_modes(n=256, d=8, n_landmarks=256, c=4,
                                 tile_rows=64, interpret=True,
                                 with_hlo=False)
    by_name = {r.name: (r, v) for r, v in results}
    # the sweep covers every tile precision per mode (kernels/precision.py)
    for precision in ("f32", "bf16"):
        r, violations = by_name[f"kkmeans_fit[{mode},{precision}]"]
        assert violations == []
        assert (r.pallas_calls > 0) == (mode == "fused")
        assert r.check_precision() == []
        if mode == "tiled":
            assert r.largest_intermediate_bytes < 256 * 256 * 4


@pytest.mark.parametrize("s_step", [1, 2])
@pytest.mark.parametrize("with_model_axis", [False, True])
def test_contract_mesh_path(with_model_axis, s_step):
    """The s-step contract, statically proven: exactly ONE allgather and
    ONE fused psum per sync on BOTH layouts, whatever s — and the same
    pair outside the loop (the prologue sync that seeds the carry; there
    is no fixpoint epilogue)."""
    from repro.launch.audit import audit_mesh_path

    r, violations = audit_mesh_path(n=64, d=4, n_landmarks=16, c=4,
                                    with_model_axis=with_model_axis,
                                    s_step=s_step)
    assert violations == []
    per, out = r.collectives_per_iteration, r.collectives_outside
    assert per == {"psum": 1, "all_gather": 1}
    assert out == {"psum": 1, "all_gather": 1}


def test_sstep_fused_sync_is_single_collective_pair():
    """Booby-trapped form of the contract: audit the REAL mesh program
    directly (not through audit_mesh_path) and check that a bill
    promising anything other than 1 psum + 1 allgather per sync is
    rejected — the check must actually be able to fire."""
    import jax.numpy as jnp
    from repro.analysis import audit
    from repro.core import GramEngine, KernelSpec
    from repro.distributed import inner as dinner
    from repro.distributed.compat import make_mesh

    spec = KernelSpec(name="rbf", gamma=0.5)
    mesh = make_mesh((1, 1), ("data", "model"))
    cfg = dinner.DistributedInnerConfig(
        n_clusters=4, kernel=spec, max_iters=5,
        engine=GramEngine(mode="materialize"), col_axis="model", s_step=2)
    x = jax.random.normal(jax.random.PRNGKey(0), (64, 4), jnp.float32)
    r = audit(lambda *a: dinner.distributed_kkmeans_fit(mesh, *a, cfg=cfg),
              x, x[:16], jnp.arange(16, dtype=jnp.int32), spec.diag(x),
              jnp.zeros((64,), jnp.int32), name="sstep_trap")
    assert r.collectives_per_iteration == {"psum": 1, "all_gather": 1}
    # the trap: stricter and looser bills must both be caught
    assert r.check_collectives({"psum": 0, "allgather": 1})
    assert r.check_collectives({"psum": 2, "allgather": 1})
    assert r.check_collectives({"psum": 1, "allgather": 0})
    assert not r.check_collectives({"psum": 1, "allgather": 1},
                                   {"psum": 1, "allgather": 1})


def test_contract_embed_and_predict():
    from repro.launch.audit import audit_embed_path, audit_predict_path

    r, violations = audit_embed_path(n=64, d=4, m=16, c=4)
    assert violations == []
    # one fused psum per Lloyd iteration (sums+counts+flag+cost in a
    # single flat payload), one identical prologue sync outside.
    assert r.collectives_per_iteration == {"psum": 1}
    assert r.collectives_outside == {"psum": 1}

    r2, violations2 = audit_predict_path(n=64, d=4, c=4)
    assert violations2 == []
    assert not r2.loops and not r2.host_callbacks


def test_audit_cli_smoke(tmp_path):
    """The CI smoke: full CLI over every path, report artifact written."""
    from repro.launch.audit import main

    out = tmp_path / "report.json"
    assert main(["--n", "256", "--d", "8", "--landmarks", "256",
                 "--clusters", "4", "--tile-rows", "64",
                 "--out", str(out)]) == 0
    payload = json.loads(out.read_text())
    assert payload["ok"] and not payload["violations"]
    # 3 engine modes x 2 precisions + 5 kernel wrappers x 2 precisions
    # + 4 mesh programs + embedded Lloyd + serving predict
    # + 4 serving shape-bucket programs
    assert len(payload["reports"]) == 26
    names = {r["name"] for r in payload["reports"]}
    assert "kkmeans_fit[fused,f32]" in names
    assert "kkmeans_fit[fused,bf16]" in names
    assert "assign_fused[bf16,tpu]" in names
    assert "serving_predict" in names
    assert "distributed_inner[data, s=2]" in names
    assert "distributed_inner[data x model, s=2]" in names
