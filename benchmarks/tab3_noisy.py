"""Paper Tab.3: noisy MNIST at 10^6+ samples, B in {32, 64} — the
"kernel methods on a desktop" capstone. The full-size baseline column is
"—" in the paper (kernel k-means without approximation cannot run at 1.2M
samples: the Gram matrix alone is 5.8 PB); that infeasibility is exactly the
point, and is reproduced by the memory planner below rather than by OOM.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core import (KernelSpec, MachineSpec, MiniBatchConfig, b_min,
                        clustering_accuracy, gamma_from_dmax, nmi)
from repro.core.minibatch import fit_dataset, predict
from repro.data.synthetic import make_mnist_like, make_noisy_replicas

from .common import Timer, save, table


def run(fast: bool = True):
    base_n = 3000 if fast else 60000
    reps = 5 if fast else 20
    bs = [8, 16] if fast else [32, 64]
    x0, y0 = make_mnist_like(base_n, seed=0)
    x, y = make_noisy_replicas(x0, y0, n_replicas=reps, frac_features=0.2,
                               seed=1)
    n = len(x)

    # the planner's verdict on the UNapproximated problem (B = 1):
    ws = MachineSpec(memory_bytes=64e9, n_processors=1)  # the paper's desktop
    b_needed = b_min(n, 10, ws)
    gram_tb = n * n * 4 / 1e12
    print(f"[tab3] N={n}: full Gram = {gram_tb:.2f} TB -> B=1 infeasible on "
          f"a 64 GB desktop; Eq.19 says B_min={b_needed}")

    gamma = gamma_from_dmax(jnp.asarray(x[:4096]))
    spec = KernelSpec("rbf", gamma=gamma)
    rows, payload = [], {"B": {}, "n": n, "gram_tb": gram_tb,
                         "b_min_desktop": int(b_needed)}
    for b in bs:
        cfg = MiniBatchConfig(n_clusters=10, n_batches=b, s=1.0,
                              kernel=spec, seed=0)
        with Timer() as t:
            res = fit_dataset(x, cfg)
        # evaluate on the clean originals (the paper scores vs true labels)
        labels = np.asarray(predict(jnp.asarray(x0), res.state.medoids,
                                    res.state.medoid_diag, spec=spec))
        acc, nm = clustering_accuracy(y0, labels), nmi(y0, labels)
        rows.append([f"B={b}", f"{acc*100:.2f}", f"{nm:.3f}",
                     f"{t.seconds:.1f}s"])
        payload["B"][b] = {"acc": acc, "nmi": nm, "seconds": t.seconds}

    rows.insert(0, ["baseline (full kernel)", "—", "—",
                    f"infeasible ({gram_tb:.1f} TB Gram)"])
    table(f"Tab.3 — noisy MNIST-like ({n} samples), B sweep",
          ["run", "accuracy %", "NMI", "time"], rows)
    times = [payload["B"][b]["seconds"] for b in bs]
    payload["claim_time_drops_with_B"] = bool(times[-1] < times[0])
    save("tab3_noisy", payload)
    return payload


if __name__ == "__main__":
    run(fast=False)
