"""Paper Fig.4: 2D toy — cluster-centre evolution under stride vs block
sampling, the displacement diagnostic, and the partial/global cost traces.

Claim validated: stride sampling keeps the per-batch medoid displacement
small and flat; block sampling over a CONCEPT-DRIFTING stream shows spikes
(Fig.4b), and the inner loop lowers the global cost (Fig.4d).
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core import (KernelSpec, MiniBatchConfig, clustering_accuracy,
                        mean_displacement)
from repro.core.minibatch import fit, predict
from repro.data.sampling import split_batches
from repro.data.synthetic import toy2d

from .common import save, table


def _drifting_toy(n_per=2500, seed=0):
    """The toy with samples ORDERED by cluster — the worst case for block
    sampling (each early block sees a subset of clusters: concept drift)."""
    x, y = toy2d(n_per_cluster=n_per, seed=seed)
    order = np.argsort(y, kind="stable")
    return x[order], y[order]


def run(fast: bool = True):
    n_per = 1000 if fast else 10000
    b = 4
    x, y = _drifting_toy(n_per=n_per)
    spec = KernelSpec("rbf", gamma=4.0)

    rows, payload = [], {}
    for strategy in ("stride", "block"):
        cfg = MiniBatchConfig(n_clusters=4, n_batches=b, s=1.0, kernel=spec,
                              sampling=strategy, seed=0)
        res = fit(split_batches(x, b, strategy), cfg)
        labels = np.asarray(predict(jnp.asarray(x), res.state.medoids,
                                    res.state.medoid_diag, spec=spec))
        disp = mean_displacement(res.history)
        acc = clustering_accuracy(y, labels)
        costs = [h.cost for h in res.history]
        rows.append([strategy, f"{acc:.3f}",
                     np.array2string(disp, precision=3),
                     np.array2string(np.asarray(costs), precision=0)])
        payload[strategy] = {"acc": acc, "displacement": disp.tolist(),
                             "costs": costs,
                             "inner_iters": [h.inner_iters
                                             for h in res.history]}

    table("Fig.4 — sampling strategies on the 2D toy (ordered stream)",
          ["sampling", "accuracy", "displacement/batch", "cost/batch"], rows)
    # the paper's qualitative claim:
    stride_disp = np.mean(payload["stride"]["displacement"][1:])
    block_disp = np.mean(payload["block"]["displacement"][1:])
    verdict = ("CONFIRMED" if block_disp > 2.0 * stride_disp
               else "NOT confirmed")
    print(f"[fig4] block sampling displacement {block_disp:.4f} vs stride "
          f"{stride_disp:.4f} -> paper claim (spikes under drift) {verdict}")
    payload["claim_block_gt_stride"] = bool(block_disp > 2.0 * stride_disp)
    save("fig4_toy", payload)
    return payload


if __name__ == "__main__":
    run(fast=False)
