"""Paper Tab.2: RCV1 (log TF-IDF -> 256-d random projection) for
B in {4, 16, 64}, plus the O(nnz) sparse high-dim path the projection
exists to avoid.

Paper: acc ~16-17%, NMI 0.13-0.15 (50+ heavy-tailed classes are HARD), time
falls ~B x. Claims validated: same envelope on the synthetic RCV1 generator
— the mini-batch approximation stays within noise of B=4 and time drops
with B. NOTE: an earlier revision reported the linear baseline with a
nearest-centroid formula that dropped the per-cluster +||c||^2 term
(benchmarks/common.nearest_centroid fixes it); with the correct assignment
the linear baseline is strong on this synthetic envelope and the
"kernel NMI >= linear" claim is recorded but not expected to hold.

The ``sparse`` grid runs ``MiniBatchConfig(method="sketch")`` directly on
the CSR term vectors (no dense 256-d projection at all): count-sketch
embeds each mini-batch in O(nnz), so the full vocab dimensionality flows
through fit/predict while only [n, m] embeddings ever materialize.

The ``streaming`` grid goes one step further: the same CSR corpus arrives
as a ragged chunk stream (documents trickling off disk), is re-chunked by
``BatchSource.from_stream``, staged shard-by-shard onto the mesh by the
prefetch producer thread (``DistributedEmbedKMeans.source``), and fit
through the distributed embedded path — no [n, d] dense array exists
anywhere between the generator and the devices. When B divides N, block
re-chunking makes the stream bit-reproducible against the offline block
split, recorded as ``claim_streaming_matches_offline`` (a live stream
cannot fold a remainder into the previous batch — it does not know the
corpus ended — so for B∤N it yields one extra tail batch instead).

The ``selector`` column compares, at one embedding width m, uniform- vs
ridge-leverage-selected Nystrom (dense 256-d view) and the count-sketch —
the measured counterpart of ``core.memory.plan(...).frontier()``; RLS vs
uniform is recorded as ``claim_rls_ge_uniform_nmi``.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.baselines.lloyd import kmeans
from repro.core import (KernelSpec, MiniBatchConfig, clustering_accuracy,
                        gamma_from_dmax, nmi)
from repro.core.minibatch import fit, fit_dataset, predict
from repro.data.loader import BatchSource
from repro.data.sparse import slice_rows, split_csr, take_rows
from repro.data.synthetic import make_rcv1_like, make_rcv1_sparse
from repro.distributed.embed import DistributedEmbedKMeans
from repro.distributed.mesh import make_test_mesh

from .common import Timer, nearest_centroid, save, table


def run(fast: bool = True):
    import os

    from repro.obs import JsonlRecorder, export

    n = 12000 if fast else 188000
    n_test = 1000 if fast else 5844
    n_classes = 30 if fast else 50
    bs = [4, 16] if fast else [4, 16, 64]

    # flight recorder: one JSONL for the whole benchmark — per-batch wall
    # times, collective counts and HBM watermarks for every grid below.
    obs_dir = os.environ.get("REPRO_OBS", "results/obs")
    os.makedirs(obs_dir, exist_ok=True)
    obs_path = os.path.join(obs_dir, "tab2_rcv1.jsonl")
    rec = JsonlRecorder(obs_path, header=export.run_header(
        benchmark="tab2_rcv1", fast=fast))
    x, y = make_rcv1_like(n + n_test, n_classes=n_classes, seed=0)
    x_tr, x_te, y_te = x[:n], x[n:], y[n:]
    gamma = gamma_from_dmax(jnp.asarray(x_tr[:4096]))
    spec = KernelSpec("rbf", gamma=gamma)
    c = n_classes  # cluster count = category count (paper uses elbow)

    rows, payload = [], {"B": {}}
    with Timer() as t:
        base = kmeans(x_tr[:20000], c, n_init=1, seed=0)
    bl = nearest_centroid(x_te, np.asarray(base.centers))
    payload["baseline"] = {"acc": clustering_accuracy(y_te, bl),
                           "nmi": nmi(y_te, bl), "seconds": t.seconds}
    rows.append(["baseline (linear)",
                 f"{payload['baseline']['acc']*100:.2f}",
                 f"{payload['baseline']['nmi']:.3f}", f"{t.seconds:.1f}s"])

    for b in bs:
        cfg = MiniBatchConfig(n_clusters=c, n_batches=b, s=1.0,
                              kernel=spec, seed=0)
        rec.event("grid", grid="exact", B=b)
        with Timer() as t:
            res = fit_dataset(x_tr, cfg, recorder=rec)
        labels = np.asarray(predict(jnp.asarray(x_te), res.state.medoids,
                                    res.state.medoid_diag, spec=spec))
        acc, nm = clustering_accuracy(y_te, labels), nmi(y_te, labels)
        rows.append([f"B={b}", f"{acc*100:.2f}", f"{nm:.3f}",
                     f"{t.seconds:.1f}s"])
        payload["B"][b] = {"acc": acc, "nmi": nm, "seconds": t.seconds}

    # -- true sparse high-dim path: CSR term vectors, count-sketch embedding,
    #    no dense projection; d = full vocab, embedding cost O(nnz).
    vocab = 4096 if fast else 47236
    xs, ys = make_rcv1_sparse(n + n_test, vocab=vocab,
                              n_classes=n_classes, seed=0)
    xs_tr = take_rows(xs, np.arange(n))
    xs_te = take_rows(xs, np.arange(n, n + n_test))
    ys_te = ys[n:]
    payload["sparse"] = {"vocab": vocab, "nnz_per_row": xs.nnz / len(xs),
                         "B": {}}
    for b in bs:
        cfg = MiniBatchConfig(n_clusters=c, n_batches=b,
                              kernel=KernelSpec("linear"), seed=0,
                              method="sketch", embed_dim=256)
        rec.event("grid", grid="sparse_sketch", B=b)
        with Timer() as t:
            res = fit(split_csr(xs_tr, b, strategy="stride"), cfg,
                      recorder=rec)
        labels = np.asarray(res.predict(xs_te))
        acc, nm = clustering_accuracy(ys_te, labels), nmi(ys_te, labels)
        rows.append([f"sketch d={vocab} B={b}", f"{acc*100:.2f}",
                     f"{nm:.3f}", f"{t.seconds:.1f}s"])
        payload["sparse"]["B"][b] = {"acc": acc, "nmi": nm,
                                     "seconds": t.seconds}

    # -- streaming sharded ingestion: ragged CSR chunks -> BatchSource ->
    #    prefetch-staged mesh shards -> distributed O(nnz) sketch fit.
    rng = np.random.default_rng(7)
    payload["streaming"] = {"B": {}}
    for b in bs:
        batch = n // b
        cfg = MiniBatchConfig(n_clusters=c, n_batches=b, sampling="block",
                              kernel=KernelSpec("linear"), seed=0,
                              method="sketch", embed_dim=256)
        cuts = np.unique(rng.integers(0, n, size=3 * b))
        bounds = np.concatenate([[0], cuts, [n]])
        chunks = (slice_rows(xs_tr, int(a), int(z))
                  for a, z in zip(bounds[:-1], bounds[1:]) if z > a)
        rec.event("grid", grid="streaming", B=b)
        km = DistributedEmbedKMeans(make_test_mesh(), cfg, recorder=rec)
        src = BatchSource.from_stream(chunks, batch, stage=km.stage,
                                      prefetch=2, recorder=rec)
        with src, Timer() as t:
            res = km.fit(src)
        labels = np.asarray(res.predict(xs_te))
        acc, nm = clustering_accuracy(ys_te, labels), nmi(ys_te, labels)
        rows.append([f"stream d={vocab} B={b}", f"{acc*100:.2f}",
                     f"{nm:.3f}", f"{t.seconds:.1f}s"])
        payload["streaming"]["B"][b] = {"acc": acc, "nmi": nm,
                                        "seconds": t.seconds}
        if b == bs[0] and n % b == 0:
            # offline block split == the stream re-chunked (same batches,
            # same seeds => identical labels; only well-defined when B | N,
            # see module docstring).
            off = fit(split_csr(xs_tr, b, strategy="block"), cfg)
            payload["claim_streaming_matches_offline"] = bool(
                (np.asarray(off.predict(xs_te)) == labels).all())

    # -- landmark-selection column: uniform vs RLS Nystrom (dense 256-d
    #    view, rbf) vs the count-sketch at the same embedding width — the
    #    accuracy-per-byte comparison core.memory.plan(...).frontier()
    #    models. Text classes are heavy-tailed, exactly the regime where
    #    uniform landmark sampling starves the tail categories.
    m_sel = 64 if fast else 128
    payload["selector"] = {"m": m_sel}
    for sel in ("uniform", "rls"):
        cfg = MiniBatchConfig(n_clusters=c, n_batches=bs[0], kernel=spec,
                              seed=0, method="nystrom", embed_dim=m_sel,
                              selector=sel)
        with Timer() as t:
            res = fit_dataset(x_tr, cfg)
        labels = np.asarray(res.predict(jnp.asarray(x_te)))
        acc, nm = clustering_accuracy(y_te, labels), nmi(y_te, labels)
        rows.append([f"nystrom {sel} m={m_sel}", f"{acc*100:.2f}",
                     f"{nm:.3f}", f"{t.seconds:.1f}s"])
        payload["selector"][sel] = {"acc": acc, "nmi": nm,
                                    "seconds": t.seconds}
    cfg = MiniBatchConfig(n_clusters=c, n_batches=bs[0],
                          kernel=KernelSpec("linear"), seed=0,
                          method="sketch", embed_dim=m_sel)
    with Timer() as t:
        res = fit(split_csr(xs_tr, bs[0], strategy="stride"), cfg)
    labels = np.asarray(res.predict(xs_te))
    acc, nm = clustering_accuracy(ys_te, labels), nmi(ys_te, labels)
    rows.append([f"sketch m={m_sel}", f"{acc*100:.2f}", f"{nm:.3f}",
                 f"{t.seconds:.1f}s"])
    payload["selector"]["sketch"] = {"acc": acc, "nmi": nm,
                                     "seconds": t.seconds}

    table(f"Tab.2 — RCV1-like ({n} docs, {c} classes), B sweep",
          ["run", "accuracy %", "NMI", "time"], rows)
    times = [payload["B"][b]["seconds"] for b in bs]
    payload["claim_time_drops_with_B"] = bool(times[-1] < times[0])
    payload["claim_kernel_nmi_ge_linear"] = bool(
        payload["B"][bs[0]]["nmi"] >= payload["baseline"]["nmi"] - 0.01)
    payload["claim_sparse_beats_baseline_nmi"] = bool(
        max(payload["sparse"]["B"][b]["nmi"] for b in bs)
        >= payload["baseline"]["nmi"] - 0.01)
    payload["claim_rls_ge_uniform_nmi"] = bool(
        payload["selector"]["rls"]["nmi"]
        >= payload["selector"]["uniform"]["nmi"] - 0.01)
    nmi_b = ["%.3f" % payload["B"][b]["nmi"] for b in bs]
    nmi_sp = ["%.3f" % payload["sparse"]["B"][b]["nmi"] for b in bs]
    print("[tab2] NMI(B): %s vs linear %.3f; sparse sketch NMI(B): %s"
          % (nmi_b, payload["baseline"]["nmi"], nmi_sp))
    print("[tab2] selector column (m=%d): uniform %.3f, rls %.3f, "
          "sketch %.3f" % (m_sel, payload["selector"]["uniform"]["nmi"],
                           payload["selector"]["rls"]["nmi"],
                           payload["selector"]["sketch"]["nmi"]))
    payload["bench"] = {"n": n, "B": bs, "s": 1.0, "m": 256,
                        "m_selector": m_sel, "vocab": vocab,
                        "method": "exact+sketch+nystrom"}
    rec.close()
    payload["obs"] = export.summarize(obs_path)
    print(f"[tab2] obs: {payload['obs']['events']} events -> {obs_path}")
    save("tab2_rcv1", payload)
    return payload


if __name__ == "__main__":
    run(fast=False)
