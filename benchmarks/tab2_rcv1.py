"""Paper Tab.2: RCV1 (log TF-IDF -> 256-d random projection) for
B in {4, 16, 64}.

Paper: acc ~16-17%, NMI 0.13-0.15 (50+ heavy-tailed classes are HARD), time
falls ~B x. Claims validated: same envelope on the synthetic RCV1 generator
— absolute accuracy is low for everyone, the mini-batch approximation stays
within noise of B=4, time drops with B, and kernel k-means beats the
paper's own linear baseline on NMI.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.baselines.lloyd import kmeans
from repro.core import (KernelSpec, MiniBatchConfig, clustering_accuracy,
                        gamma_from_dmax, nmi)
from repro.core.minibatch import fit_dataset, predict
from repro.data.synthetic import make_rcv1_like

from .common import Timer, save, table


def run(fast: bool = True):
    n = 12000 if fast else 188000
    n_test = 1000 if fast else 5844
    n_classes = 30 if fast else 50
    bs = [4, 16] if fast else [4, 16, 64]
    x, y = make_rcv1_like(n + n_test, n_classes=n_classes, seed=0)
    x_tr, x_te, y_te = x[:n], x[n:], y[n:]
    gamma = gamma_from_dmax(jnp.asarray(x_tr[:4096]))
    spec = KernelSpec("rbf", gamma=gamma)
    c = n_classes  # cluster count = category count (paper uses elbow)

    rows, payload = [], {"B": {}}
    with Timer() as t:
        base = kmeans(x_tr[:20000], c, n_init=1, seed=0)
    d = ((x_te ** 2).sum(1)[:, None]
         - 2 * x_te @ np.asarray(base.centers).T)
    bl = d.argmin(1)
    payload["baseline"] = {"acc": clustering_accuracy(y_te, bl),
                           "nmi": nmi(y_te, bl), "seconds": t.seconds}
    rows.append(["baseline (linear)",
                 f"{payload['baseline']['acc']*100:.2f}",
                 f"{payload['baseline']['nmi']:.3f}", f"{t.seconds:.1f}s"])

    for b in bs:
        cfg = MiniBatchConfig(n_clusters=c, n_batches=b, s=1.0,
                              kernel=spec, seed=0)
        with Timer() as t:
            res = fit_dataset(x_tr, cfg)
        labels = np.asarray(predict(jnp.asarray(x_te), res.state.medoids,
                                    res.state.medoid_diag, spec=spec))
        acc, nm = clustering_accuracy(y_te, labels), nmi(y_te, labels)
        rows.append([f"B={b}", f"{acc*100:.2f}", f"{nm:.3f}",
                     f"{t.seconds:.1f}s"])
        payload["B"][b] = {"acc": acc, "nmi": nm, "seconds": t.seconds}

    table(f"Tab.2 — RCV1-like ({n} docs, {c} classes), B sweep",
          ["run", "accuracy %", "NMI", "time"], rows)
    times = [payload["B"][b]["seconds"] for b in bs]
    payload["claim_time_drops_with_B"] = bool(times[-1] < times[0])
    payload["claim_kernel_nmi_ge_linear"] = bool(
        payload["B"][bs[0]]["nmi"] >= payload["baseline"]["nmi"] - 0.01)
    print(f"[tab2] NMI(B): "
          f"{[f'{payload['B'][b]['nmi']:.3f}' for b in bs]} vs linear "
          f"{payload['baseline']['nmi']:.3f}")
    save("tab2_rcv1", payload)
    return payload


if __name__ == "__main__":
    run(fast=False)
