"""Shared benchmark utilities: result recording, table printing, and the
nearest-centroid assignment every linear baseline shares."""
from __future__ import annotations

import json
import os
import time

import numpy as np

RESULTS_DIR = os.environ.get("REPRO_RESULTS", "results/benchmarks")


def nearest_centroid(x: np.ndarray, centers: np.ndarray) -> np.ndarray:
    """argmin_j ||x_i - c_j||^2 -> [n] labels.

    The full expansion ||x||^2 - 2 x.c + ||c||^2 — the per-cluster
    ||c_j||^2 term varies with j and MUST be included (dropping it once
    misreported every Tab.2 baseline metric); the row-constant ||x||^2 is
    kept only so the distances are true squared distances.
    """
    x = np.asarray(x, np.float64)
    centers = np.asarray(centers, np.float64)
    d = ((x ** 2).sum(1)[:, None] - 2.0 * x @ centers.T
         + (centers ** 2).sum(1)[None, :])
    return d.argmin(1)


def git_commit() -> str:
    """Short commit hash of the repo the benchmarks run from ("unknown"
    outside a git checkout) — stamped into every BENCH record so perf
    trajectories can be pinned to code states."""
    import subprocess
    try:
        out = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, timeout=10,
            cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
        return out.stdout.strip() or "unknown"
    except Exception:
        return "unknown"


def load_bench(name: str) -> dict | None:
    """Read the recorded ``results/BENCH_<name>.json`` baseline (the
    previous revision's wall time + params), or None when this benchmark
    has never been recorded."""
    bench_dir = os.environ.get("REPRO_BENCH", "results")
    path = os.path.join(bench_dir, f"BENCH_{name}.json")
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, json.JSONDecodeError):
        return None


def default_backend() -> str:
    """The jax platform this process runs on ("unknown" without jax) —
    the comparability column next to ``dtype``: a cpu interpret-mode
    record must never baseline a tpu run."""
    try:
        import jax
        return jax.default_backend()
    except Exception:
        return "unknown"


def record_bench(name: str, seconds: float, *, mode: str,
                 params: dict | None = None,
                 obs: dict | None = None,
                 dtype: str = "f32",
                 backend: str | None = None) -> str:
    """Append-point of the perf trajectory: one ``results/BENCH_<name>.json``
    per benchmark run — wall time, the workload knobs the benchmark reports
    (n/B/s/m/method, via its payload's ``bench`` dict), mode and commit —
    so future revisions have a baseline to diff against. ``obs`` is the
    flight-recorder summary (``repro.obs.export.summarize`` — the payload's
    ``obs`` dict when the benchmark ran with a recorder): folded into the
    record so a perf regression comes with its per-batch evidence
    attached.

    ``dtype`` is the kernel-layer tile precision the run was configured
    with ("f32" unless the benchmark says otherwise — sweeps that cover
    both dtypes internally, like roofline, still record one run-level
    value) and ``backend`` the jax platform (defaulted from the live
    process). Both are comparability columns: benchmarks/run.py refuses to
    diff a record against a baseline whose dtype or backend differs."""
    bench_dir = os.environ.get("REPRO_BENCH", "results")
    os.makedirs(bench_dir, exist_ok=True)
    path = os.path.join(bench_dir, f"BENCH_{name}.json")
    rec = {"benchmark": name, "seconds": seconds, "mode": mode,
           "dtype": dtype,
           "backend": backend if backend is not None else default_backend(),
           "commit": git_commit(), "params": params or {}}
    if obs:
        rec["obs"] = obs
    with open(path, "w") as f:
        json.dump(rec, f, indent=1, default=float)
    return path


def save(name: str, payload: dict):
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, default=float)
    return path


def table(title: str, headers: list[str], rows: list[list]):
    widths = [max(len(str(h)), *(len(str(r[i])) for r in rows))
              for i, h in enumerate(headers)]
    line = "  ".join(str(h).ljust(w) for h, w in zip(headers, widths))
    print(f"\n== {title} ==")
    print(line)
    print("-" * len(line))
    for r in rows:
        print("  ".join(str(c).ljust(w) for c, w in zip(r, widths)))


class Timer:
    def __enter__(self):
        self.t0 = time.time()
        return self

    def __exit__(self, *a):
        self.seconds = time.time() - self.t0
