"""Shared benchmark utilities: result recording + table printing."""
from __future__ import annotations

import json
import os
import time

RESULTS_DIR = os.environ.get("REPRO_RESULTS", "results/benchmarks")


def save(name: str, payload: dict):
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, default=float)
    return path


def table(title: str, headers: list[str], rows: list[list]):
    widths = [max(len(str(h)), *(len(str(r[i])) for r in rows))
              for i, h in enumerate(headers)]
    line = "  ".join(str(h).ljust(w) for h, w in zip(headers, widths))
    print(f"\n== {title} ==")
    print(line)
    print("-" * len(line))
    for r in rows:
        print("  ".join(str(c).ljust(w) for c, w in zip(r, widths)))


class Timer:
    def __enter__(self):
        self.t0 = time.time()
        return self

    def __exit__(self, *a):
        self.seconds = time.time() - self.t0
